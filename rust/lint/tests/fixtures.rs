//! The linter's own test bed: every known-bad fixture must be caught
//! (with the exact rule and count), the known-good fixture must pass,
//! and — the self-enforcing part — the real `invariants.toml` must run
//! clean over the real `rust/src/` tree, so `cargo test -p ddslint`
//! fails the moment an unannotated violation lands anywhere.

use std::path::PathBuf;

use ddslint::{check_control, run, scan_source, Registry, Violation};

/// Registry used for the fixture scans. Exercises the TOML-subset
/// parser on the same shapes the real registry uses; the pseudo
/// rel-paths below put fixtures inside data-path modules / the pump
/// file list.
const FIXTURE_REGISTRY: &str = r#"
[unsafe_rule]
lookback = 6

[annotations]
lookback = 4

[[atomics]]
name = "bell.seq"
patterns = [".seq.load(", ".seq.store(", ".seq.fetch_add("]
why = "fixture doorbell sequence"

[copy_rule]
modules = ["ring"]
methods = ["to_vec", "to_owned", "extend_from_slice"]
clone_receiver_idents = ["data", "bytes", "payload"]
clone_receiver_suffixes = ["as_slice()"]

[pump_rule]
files = ["pump/bad_sleep.rs", "pump/bad_recv.rs", "ring/good.rs"]

[control_rule]
enum_file = "fixtures/control/msgs.rs"
enum_name = "ControlMsg"
impl_file = "fixtures/control/client.rs"
impl_type = "DdsClient"
exempt = ["Shutdown"]
rename = []
"#;

fn manifest_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn fixture_registry() -> Registry {
    Registry::from_toml(FIXTURE_REGISTRY).expect("fixture registry parses")
}

/// Scan one fixture file under a pseudo scan-root-relative path.
fn scan_fixture(rel: &str, file: &str, reg: &Registry) -> Vec<Violation> {
    let path = manifest_dir().join("fixtures").join(file);
    let src = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    scan_source(rel, &src, reg)
}

fn count_rule(vs: &[Violation], rule: &str) -> usize {
    vs.iter().filter(|v| v.rule == rule).count()
}

#[test]
fn missing_safety_is_caught() {
    let reg = fixture_registry();
    let vs = scan_fixture("buf/bad_missing_safety.rs", "bad_missing_safety.rs", &reg);
    // unsafe block + unsafe fn + unsafe impl; the unsafe block inside
    // #[cfg(test)] is exempt.
    assert_eq!(count_rule(&vs, "unsafe-safety"), 3, "violations: {vs:#?}");
    assert_eq!(vs.len(), 3, "violations: {vs:#?}");
}

#[test]
fn relaxed_on_registered_atomic_is_caught() {
    let reg = fixture_registry();
    let vs = scan_fixture("idle.rs", "bad_relaxed.rs", &reg);
    // fetch_add + load on `seq`; the SeqCst load and the unregistered
    // stats counter are legal.
    assert_eq!(count_rule(&vs, "relaxed-ordering"), 2, "violations: {vs:#?}");
    assert_eq!(vs.len(), 2, "violations: {vs:#?}");
}

#[test]
fn unmetered_copies_are_caught() {
    let reg = fixture_registry();
    let vs = scan_fixture("ring/bad_copy.rs", "bad_copy.rs", &reg);
    // to_vec + extend_from_slice + data.clone(); the Arc handle clone
    // (refcount bump) is legal.
    assert_eq!(count_rule(&vs, "copy-smell"), 3, "violations: {vs:#?}");
    assert_eq!(vs.len(), 3, "violations: {vs:#?}");
}

#[test]
fn copies_outside_data_path_modules_are_not_flagged() {
    let reg = fixture_registry();
    // Same source, scanned as a module that is not in the copy rule.
    let vs = scan_fixture("metrics/bad_copy.rs", "bad_copy.rs", &reg);
    assert_eq!(count_rule(&vs, "copy-smell"), 0, "violations: {vs:#?}");
}

#[test]
fn sleeping_pump_is_caught() {
    let reg = fixture_registry();
    let vs = scan_fixture("pump/bad_sleep.rs", "bad_sleep.rs", &reg);
    assert_eq!(count_rule(&vs, "pump-discipline"), 1, "violations: {vs:#?}");
    assert_eq!(vs.len(), 1, "violations: {vs:#?}");
}

#[test]
fn unbounded_recv_in_pump_is_caught() {
    let reg = fixture_registry();
    let vs = scan_fixture("pump/bad_recv.rs", "bad_recv.rs", &reg);
    // try_recv is the sanctioned shape; only the bare recv() trips.
    assert_eq!(count_rule(&vs, "pump-discipline"), 1, "violations: {vs:#?}");
    assert_eq!(vs.len(), 1, "violations: {vs:#?}");
}

#[test]
fn pump_rules_only_apply_to_listed_files() {
    let reg = fixture_registry();
    let vs = scan_fixture("fault/bad_sleep.rs", "bad_sleep.rs", &reg);
    assert!(vs.is_empty(), "violations: {vs:#?}");
}

#[test]
fn uncovered_control_variant_is_caught() {
    let reg = fixture_registry();
    let vs = check_control(&reg, &manifest_dir()).expect("control check runs");
    assert_eq!(vs.len(), 1, "violations: {vs:#?}");
    assert_eq!(vs[0].rule, "control-coverage");
    assert!(vs[0].msg.contains("Orphaned"), "msg: {}", vs[0].msg);
    assert!(vs[0].msg.contains("orphaned"), "msg: {}", vs[0].msg);
}

#[test]
fn good_fixture_is_clean_under_every_rule() {
    let reg = fixture_registry();
    // Scanned as a data-path module AND listed as a pump file, so all
    // annotation paths are exercised at once.
    let vs = scan_fixture("ring/good.rs", "good.rs", &reg);
    assert!(vs.is_empty(), "violations: {vs:#?}");
}

#[test]
fn annotations_expire_outside_the_lookback_window() {
    let reg = fixture_registry();
    // The annotation sits too far above the flagged call: still bad.
    let src = r#"
pub fn f(data: &[u8]) -> Vec<u8> {
    // LINT: copy-ok(too far away to count)
    let _a = 1;
    let _b = 2;
    let _c = 3;
    let _d = 4;
    data.to_vec()
}
"#;
    let vs = scan_source("ring/far.rs", src, &reg);
    assert_eq!(count_rule(&vs, "copy-smell"), 1, "violations: {vs:#?}");
}

#[test]
fn marker_inside_string_literal_does_not_satisfy_the_rule() {
    let reg = fixture_registry();
    let src = r#"
pub fn f(data: &[u8]) -> Vec<u8> {
    let _s = "LINT: copy-ok(not a comment)";
    data.to_vec()
}
"#;
    let vs = scan_source("ring/strlit.rs", src, &reg);
    assert_eq!(count_rule(&vs, "copy-smell"), 1, "violations: {vs:#?}");
}

/// The self-enforcing check: the real registry over the real tree.
/// This is the satellite "the lint's first clean run is the audit",
/// kept green forever after.
#[test]
fn real_tree_is_clean() {
    let repo_root = manifest_dir().join("../..");
    let scan_root = repo_root.join("rust/src");
    if !scan_root.is_dir() {
        // Packaged/vendored builds may not ship the main tree.
        eprintln!("skipping: {} not present", scan_root.display());
        return;
    }
    let text = std::fs::read_to_string(manifest_dir().join("invariants.toml"))
        .expect("read invariants.toml");
    let reg = Registry::from_toml(&text).expect("real registry parses");
    let vs = run(&repo_root, &scan_root, &reg).expect("scan runs");
    assert!(
        vs.is_empty(),
        "ddslint violations in rust/src:\n{}",
        vs.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
    );
}

/// Guard the registry itself: rules that name concrete files/modules
/// must keep pointing at things that exist, or the rule silently
/// stops applying.
#[test]
fn registry_targets_exist() {
    let repo_root = manifest_dir().join("../..");
    let scan_root = repo_root.join("rust/src");
    if !scan_root.is_dir() {
        eprintln!("skipping: {} not present", scan_root.display());
        return;
    }
    let text = std::fs::read_to_string(manifest_dir().join("invariants.toml"))
        .expect("read invariants.toml");
    let reg = Registry::from_toml(&text).expect("real registry parses");
    for f in &reg.pump_files {
        assert!(scan_root.join(f).is_file(), "pump_rule.files entry `{f}` does not exist");
    }
    for m in &reg.copy_modules {
        let dir = scan_root.join(m);
        let file = scan_root.join(format!("{m}.rs"));
        assert!(dir.is_dir() || file.is_file(), "copy_rule.modules entry `{m}` does not exist");
    }
    let ctl = reg.control.as_ref().expect("control rule present");
    for f in [&ctl.enum_file, &ctl.impl_file] {
        assert!(repo_root.join(f).is_file(), "control_rule file `{f}` does not exist");
    }
}
