#!/usr/bin/env python3
"""Line-level Python mirror of ddslint (rust/lint/src/lib.rs).

The authoritative checker is the Rust crate: a syn AST walk with real
spans, run blocking in CI. This mirror approximates the same rules with
line scanning so the invariant registry can be exercised in
environments without a Rust toolchain (it is how the repo's annotation
audit was driven). Divergences are possible in pathological code (raw
strings containing `//`, braces in string literals); when the two
disagree, the Rust crate wins.

Usage:
    python3 rust/lint/mirror.py                 # real registry over rust/src
    python3 rust/lint/mirror.py --fixtures      # fixture expectations
"""

import argparse
import os
import re
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO_ROOT = os.path.normpath(os.path.join(HERE, "..", ".."))


# ── registry (same TOML subset as the Rust parser) ───────────────────

def parse_value(raw, line_no):
    raw = raw.strip()
    if raw.startswith('"'):
        end = raw.index('"', 1)
        return raw[1:end]
    if raw.startswith("["):
        if not raw.endswith("]"):
            raise ValueError(f"line {line_no}: arrays must be single-line")
        items = []
        rest = raw[1:-1].strip()
        while rest:
            if not rest.startswith('"'):
                raise ValueError(f"line {line_no}: array items must be strings")
            end = rest.index('"', 1)
            items.append(rest[1:end])
            rest = rest[end + 1:].strip()
            if rest.startswith(","):
                rest = rest[1:].strip()
        return items
    return int(raw)


def parse_registry(text):
    reg = {
        "safety_lookback": 6,
        "annotation_lookback": 4,
        "atomics": [],
        "copy_modules": [],
        "copy_methods": [],
        "clone_receiver_idents": [],
        "clone_receiver_suffixes": [],
        "pump_files": [],
        "control": None,
    }
    section = ""
    for idx, raw_line in enumerate(text.splitlines()):
        line_no = idx + 1
        line = raw_line
        hash_at = raw_line.find("#")
        if hash_at >= 0 and '"' not in raw_line[:hash_at]:
            line = raw_line[:hash_at]
        line = line.strip()
        if not line:
            continue
        if line.startswith("[[") and line.endswith("]]"):
            section = line[2:-2]
            if section == "atomics":
                reg["atomics"].append({"name": "", "patterns": [], "why": ""})
            else:
                raise ValueError(f"line {line_no}: unknown array section `{section}`")
            continue
        if line.startswith("[") and line.endswith("]"):
            section = line[1:-1]
            if section == "control_rule" and reg["control"] is None:
                reg["control"] = {
                    "enum_file": "", "enum_name": "", "impl_file": "",
                    "impl_type": "", "exempt": [], "rename": [],
                }
            continue
        key, _, raw_val = line.partition("=")
        key = key.strip()
        val = parse_value(raw_val, line_no)
        if section == "unsafe_rule" and key == "lookback":
            reg["safety_lookback"] = int(val)
        elif section == "annotations" and key == "lookback":
            reg["annotation_lookback"] = int(val)
        elif section == "atomics":
            reg["atomics"][-1][key] = val
        elif section == "copy_rule":
            if key == "modules":
                reg["copy_modules"] = val
            elif key == "methods":
                reg["copy_methods"] = val
            elif key == "clone_receiver_idents":
                reg["clone_receiver_idents"] = val
            elif key == "clone_receiver_suffixes":
                reg["clone_receiver_suffixes"] = val
        elif section == "pump_rule" and key == "files":
            reg["pump_files"] = val
        elif section == "control_rule":
            if key == "rename":
                reg["control"][key] = [tuple(x.split("=", 1)) for x in val]
            else:
                reg["control"][key] = val
    return reg


# ── scanning helpers ─────────────────────────────────────────────────

def code_part(line):
    """Best-effort strip of a trailing // comment (quote-parity check)."""
    i = line.find("//")
    while i >= 0:
        if line[:i].count('"') % 2 == 0:
            return line[:i]
        i = line.find("//", i + 1)
    return line


def comment_has(line, marker):
    i = line.find("//")
    return i >= 0 and marker in line[i:]


def annotated(lines, line, marker, lookback):
    idx = min(line - 1, len(lines) - 1)
    lo = max(0, idx - lookback)
    return any(comment_has(l, marker) for l in lines[lo:idx + 1])


def exempt_spans(lines):
    """(start, end) 0-based inclusive line ranges of #[cfg(test/loom/miri)]
    items, matched by brace counting."""
    spans = []
    i, n = 0, len(lines)
    cfg_re = re.compile(r"^\s*#\[cfg\(")
    word_re = re.compile(r"\b(test|loom|miri)\b")
    while i < n:
        if cfg_re.match(lines[i]) and word_re.search(lines[i]):
            j = i
            while j < n and "{" not in code_part(lines[j]):
                if code_part(lines[j]).rstrip().endswith(";"):
                    break  # gated `use`/item without a body
                j += 1
            if j < n and "{" in code_part(lines[j]):
                depth, k = 0, j
                while k < n:
                    c = code_part(lines[k])
                    depth += c.count("{") - c.count("}")
                    if depth <= 0 and k >= j:
                        break
                    k += 1
                spans.append((i, k))
                i = k + 1
                continue
        i += 1
    return spans


def in_spans(spans, idx):
    return any(a <= idx <= b for a, b in spans)


def normalize(s):
    return re.sub(r"\s+", "", s)


def scan_file(rel, text, reg):
    lines = text.splitlines()
    out = []
    spans = exempt_spans(lines)
    module = rel.split("/", 1)[0].removesuffix(".rs")
    in_data_path = module in reg["copy_modules"]
    is_pump = rel in reg["pump_files"]

    unsafe_re = re.compile(r"\bunsafe\s*(\{|fn\b|impl\b)")
    clone_ident_re = None
    if reg["clone_receiver_idents"]:
        idents = "|".join(map(re.escape, reg["clone_receiver_idents"]))
        clone_ident_re = re.compile(
            r"(?:^|[^A-Za-z0-9_])(?:" + idents + r")\.clone\(\)")

    def push(i, rule, msg):
        out.append((rel, i + 1, rule, msg))

    for i, raw in enumerate(lines):
        if in_spans(spans, i):
            continue
        code = code_part(raw)
        if not code.strip():
            continue
        norm = normalize(code)

        for m in unsafe_re.finditer(code):
            if not annotated(lines, i + 1, "SAFETY:", reg["safety_lookback"]):
                push(i, "unsafe-safety", f"`unsafe {m.group(1)}` without // SAFETY:")

        if "Ordering::Relaxed" in norm:
            window = norm
            if code.strip().startswith("."):
                window = "".join(
                    normalize(code_part(lines[k])) for k in range(max(0, i - 2), i + 1))
            for rule in reg["atomics"]:
                if any(p in window for p in rule["patterns"]):
                    if not annotated(lines, i + 1, "LINT: relaxed-ok",
                                     reg["annotation_lookback"]):
                        push(i, "relaxed-ordering",
                             f"Relaxed on registered `{rule['name']}` without relaxed-ok")
                    break

        if in_data_path:
            for meth in reg["copy_methods"]:
                if f".{meth}(" in norm and not annotated(
                        lines, i + 1, "LINT: copy-ok", reg["annotation_lookback"]):
                    push(i, "copy-smell", f"data-path `{meth}` without copy-ok")
            hit_clone = (clone_ident_re and clone_ident_re.search(norm)) or any(
                (s + ".clone()") in norm for s in reg["clone_receiver_suffixes"])
            if hit_clone and not annotated(
                    lines, i + 1, "LINT: copy-ok", reg["annotation_lookback"]):
                push(i, "copy-smell", "data-path byte-buffer clone without copy-ok")

        if is_pump:
            if "thread::sleep(" in norm and not annotated(
                    lines, i + 1, "LINT: sleep-ok", reg["annotation_lookback"]):
                push(i, "pump-discipline", "pump file thread::sleep without sleep-ok")
            if ".recv()" in norm and not annotated(
                    lines, i + 1, "LINT: recv-ok", reg["annotation_lookback"]):
                push(i, "pump-discipline", "pump file unbounded recv() without recv-ok")

    return out


def snake_case(name):
    return re.sub(r"(?<!^)([A-Z])", r"_\1", name).lower()


def check_control(reg, repo_root):
    ctl = reg["control"]
    if not ctl:
        return []
    with open(os.path.join(repo_root, ctl["enum_file"])) as f:
        enum_lines = f.read().splitlines()
    with open(os.path.join(repo_root, ctl["impl_file"])) as f:
        impl_text = f.read()

    variants = []
    depth, inside = 0, False
    head_re = re.compile(r"\benum\s+" + re.escape(ctl["enum_name"]) + r"\b")
    var_re = re.compile(r"^\s*([A-Z][A-Za-z0-9]*)\s*[\({,]?")
    for i, raw in enumerate(enum_lines):
        code = code_part(raw)
        if not inside and head_re.search(code):
            inside = True
            depth = 0
        if inside:
            if depth == 1:
                m = var_re.match(code)
                if m:
                    variants.append((m.group(1), i + 1))
            depth += code.count("{") - code.count("}")
            if depth <= 0 and "{" in "".join(enum_lines[:i + 1]):
                if "}" in code:
                    break

    impl_m = re.search(r"impl\s+" + re.escape(ctl["impl_type"]) + r"\s*\{", impl_text)
    methods = set()
    if impl_m:
        depth, j = 0, impl_m.end() - 1
        body_start = j
        for j in range(body_start, len(impl_text)):
            if impl_text[j] == "{":
                depth += 1
            elif impl_text[j] == "}":
                depth -= 1
                if depth == 0:
                    break
        body = impl_text[body_start:j]
        methods = set(re.findall(r"\bfn\s+([a-z_][a-z0-9_]*)", body))

    out = []
    renames = dict(ctl["rename"])
    for variant, line in variants:
        if variant in ctl["exempt"]:
            continue
        want = renames.get(variant, snake_case(variant))
        if want not in methods:
            out.append((ctl["enum_file"], line, "control-coverage",
                        f"{ctl['enum_name']}::{variant} has no "
                        f"{ctl['impl_type']}::{want} accessor"))
    return out


def run(repo_root, scan_root, reg):
    out = []
    for dirpath, dirnames, filenames in os.walk(scan_root):
        dirnames.sort()
        for fn in sorted(filenames):
            if not fn.endswith(".rs"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, scan_root).replace(os.sep, "/")
            with open(path) as f:
                out.extend(scan_file(rel, f.read(), reg))
    out.extend(check_control(reg, repo_root))
    return out


# ── fixture self-test (mirrors rust/lint/tests/fixtures.rs) ──────────

FIXTURE_REGISTRY = """
[[atomics]]
name = "bell.seq"
patterns = [".seq.load(", ".seq.store(", ".seq.fetch_add("]
why = "fixture doorbell sequence"

[copy_rule]
modules = ["ring"]
methods = ["to_vec", "to_owned", "extend_from_slice"]
clone_receiver_idents = ["data", "bytes", "payload"]
clone_receiver_suffixes = ["as_slice()"]

[pump_rule]
files = ["pump/bad_sleep.rs", "pump/bad_recv.rs", "ring/good.rs"]

[control_rule]
enum_file = "fixtures/control/msgs.rs"
enum_name = "ControlMsg"
impl_file = "fixtures/control/client.rs"
impl_type = "DdsClient"
exempt = ["Shutdown"]
rename = []
"""

FIXTURE_EXPECT = [
    ("buf/bad_missing_safety.rs", "bad_missing_safety.rs", "unsafe-safety", 3),
    ("idle.rs", "bad_relaxed.rs", "relaxed-ordering", 2),
    ("ring/bad_copy.rs", "bad_copy.rs", "copy-smell", 3),
    ("metrics/bad_copy.rs", "bad_copy.rs", "copy-smell", 0),
    ("pump/bad_sleep.rs", "bad_sleep.rs", "pump-discipline", 1),
    ("pump/bad_recv.rs", "bad_recv.rs", "pump-discipline", 1),
    ("fault/bad_sleep.rs", "bad_sleep.rs", "pump-discipline", 0),
    ("ring/good.rs", "good.rs", None, 0),
]


def fixtures_main():
    reg = parse_registry(FIXTURE_REGISTRY)
    failures = 0
    for rel, fname, rule, want in FIXTURE_EXPECT:
        with open(os.path.join(HERE, "fixtures", fname)) as f:
            vs = scan_file(rel, f.read(), reg)
        got = len([v for v in vs if rule is None or v[2] == rule])
        status = "ok" if got == want else "FAIL"
        if got != want:
            failures += 1
            for v in vs:
                print("   ", f"{v[0]}:{v[1]}: [{v[2]}] {v[3]}")
        print(f"{status:4} {rel:28} {rule or '(any)':18} want={want} got={got}")
    vs = check_control(reg, HERE)
    ok = len(vs) == 1 and "Orphaned" in vs[0][3]
    print(f"{'ok' if ok else 'FAIL':4} control-coverage fixture     want=1 got={len(vs)}")
    failures += 0 if ok else 1
    return 1 if failures else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fixtures", action="store_true",
                    help="run the fixture expectations instead of the tree scan")
    ap.add_argument("--repo-root", default=REPO_ROOT)
    ap.add_argument("--scan-root", default=None)
    ap.add_argument("--registry", default=os.path.join(HERE, "invariants.toml"))
    args = ap.parse_args()

    if args.fixtures:
        sys.exit(fixtures_main())

    scan_root = args.scan_root or os.path.join(args.repo_root, "rust", "src")
    with open(args.registry) as f:
        reg = parse_registry(f.read())
    vs = run(args.repo_root, scan_root, reg)
    for rel, line, rule, msg in vs:
        print(f"{rel}:{line}: [{rule}] {msg}")
    if vs:
        print(f"mirror: {len(vs)} violation(s)")
        sys.exit(1)
    print(f"mirror: clean ({scan_root})")


if __name__ == "__main__":
    main()
