//! Fixture: every pattern the linter hunts, each with its
//! justification in place. Scanned as both a data-path file
//! (`ring/good.rs`) and a pump file. Expected violations: 0.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::time::Duration;

pub struct Bell {
    seq: AtomicU64,
    ptr: *mut u8,
}

impl Bell {
    pub fn ring(&self) {
        self.seq.fetch_add(1, Ordering::SeqCst);
    }

    pub fn fast_peek(&self) -> u64 {
        // LINT: relaxed-ok(hint only; callers re-check with SeqCst before parking)
        self.seq.load(Ordering::Relaxed)
    }

    pub fn first_byte(&self) -> u8 {
        // SAFETY: `ptr` is non-null and points into a live, pinned
        // allocation for the lifetime of `self` (set by the ctor).
        unsafe { *self.ptr }
    }

    /// # Safety
    /// Caller must guarantee `ptr` outlives `self`.
    // SAFETY: documented contract above; no derefs happen here.
    pub unsafe fn adopt(&mut self, ptr: *mut u8) {
        self.ptr = ptr;
    }
}

pub fn snapshot(data: &[u8]) -> Vec<u8> {
    // LINT: copy-ok(ledger-metered snapshot at the API boundary)
    data.to_vec()
}

pub fn shutdown_drain(rx: &Receiver<u64>) -> u64 {
    // LINT: recv-ok(shutdown path; sender drop unblocks it)
    let last = rx.recv().unwrap_or(0);
    // LINT: sleep-ok(bounded settle before exit; off the hot path)
    std::thread::sleep(Duration::from_millis(1));
    last
}
