//! Fixture: a pump-loop file calling `thread::sleep` without
//! `// LINT: sleep-ok(reason)` must be flagged (rule
//! `pump-discipline`). Expected violations: 1.

use std::time::Duration;

pub fn pump_once(budget: &mut u32) {
    if *budget == 0 {
        // Parks the pump without telling the governor.
        std::thread::sleep(Duration::from_millis(1));
        *budget = 8;
    }
    *budget -= 1;
}
