//! Fixture: control-plane enum for the `control-coverage` rule.
//! `Orphaned` has no client accessor and must be flagged;
//! `Shutdown` is exempt by registry; the rest are covered.

pub enum ControlMsg {
    CreateFile,
    CpuStats,
    Orphaned,
    Shutdown,
}
