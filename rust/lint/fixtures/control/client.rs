//! Fixture: client side of the `control-coverage` rule. Covers
//! `CreateFile` and `CpuStats`; deliberately lacks `orphaned()`.

pub struct DdsClient;

impl DdsClient {
    pub fn create_file(&self) {}
    pub fn cpu_stats(&self) {}
}
