//! Fixture: `unsafe` without `// SAFETY:` must be flagged
//! (rule `unsafe-safety`). Expected violations: 3.

pub struct Slot {
    ptr: *mut u8,
    len: usize,
}

impl Slot {
    pub fn read_first(&self) -> u8 {
        // A comment that is not a safety argument.
        unsafe { *self.ptr }
    }

    pub unsafe fn set_len(&mut self, len: usize) {
        self.len = len;
    }
}

unsafe impl Send for Slot {}

#[cfg(test)]
mod tests {
    // Exempt scope: unsafe in tests is not flagged.
    pub fn touch(p: *mut u8) -> u8 {
        unsafe { *p }
    }
}
