//! Fixture: unmetered data-path copies must be flagged (rule
//! `copy-smell`). Scanned as `ring/bad_copy.rs`, i.e. inside a
//! registered data-path module. Expected violations: 3
//! (`to_vec`, `extend_from_slice`, `data.clone()`); the handle clone
//! is a refcount bump and stays legal.

use std::sync::Arc;

pub struct Frame {
    data: Vec<u8>,
    pool: Arc<String>,
}

impl Frame {
    pub fn copy_out(&self) -> Vec<u8> {
        self.data.to_vec()
    }

    pub fn append_to(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.data);
    }

    pub fn duplicate(&self) -> Vec<u8> {
        self.data.clone()
    }

    pub fn share_pool(&self) -> Arc<String> {
        // Refcount bump, not a byte copy: not flagged.
        self.pool.clone()
    }
}
