//! Fixture: `Ordering::Relaxed` on a registered atomic without a
//! `// LINT: relaxed-ok(reason)` annotation must be flagged (rule
//! `relaxed-ordering`). Expected violations: 2 (the annotated SeqCst
//! and unregistered-counter uses are fine).

use std::sync::atomic::{AtomicU64, Ordering};

pub struct Bell {
    seq: AtomicU64,
    stat_wakes: AtomicU64,
}

impl Bell {
    pub fn ring(&self) {
        // Lost-wakeup edge: must not be Relaxed.
        self.seq.fetch_add(1, Ordering::Relaxed);
    }

    pub fn peek(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    pub fn seq_ok(&self) -> u64 {
        self.seq.load(Ordering::SeqCst)
    }

    pub fn count(&self) -> u64 {
        // Unregistered stats counter: Relaxed is fine without notes.
        self.stat_wakes.load(Ordering::Relaxed)
    }
}
