//! Fixture: a pump-loop file blocking on unbounded `recv()` without
//! `// LINT: recv-ok(reason)` must be flagged (rule
//! `pump-discipline`). Expected violations: 1 (the `try_recv` is the
//! sanctioned shape and stays legal).

use std::sync::mpsc::Receiver;

pub fn drain(rx: &Receiver<u64>) -> u64 {
    let mut sum = 0;
    while let Ok(v) = rx.try_recv() {
        sum += v;
    }
    sum
}

pub fn block_forever(rx: &Receiver<u64>) -> u64 {
    rx.recv().unwrap_or(0)
}
