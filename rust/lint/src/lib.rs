//! `ddslint` — the DDS repo's project-specific invariant checker.
//!
//! A syn-based AST walk over `rust/src/` enforcing the concurrency and
//! zero-copy contracts the code comments assert, from a checked-in
//! registry (`rust/lint/invariants.toml`):
//!
//! * **unsafe-safety** — every `unsafe` block / fn / impl carries a
//!   `// SAFETY:` comment within a few lines above it.
//! * **relaxed-ordering** — atomics registered as lost-wakeup- or
//!   coherence-critical (doorbell sequence, ring head/tail words, tier
//!   epoch cells, the SSD queue's emptiness mirrors) may not be
//!   accessed with `Ordering::Relaxed` unless the site is annotated
//!   `// LINT: relaxed-ok(reason)`.
//! * **copy-smell** — data-path modules may not call `to_vec`,
//!   `to_owned`, `extend_from_slice`, or clone a byte buffer without a
//!   `// LINT: copy-ok(reason)` justification, so the `CopyLedger`
//!   guarantee ("every data-path memcpy is deliberate and metered") is
//!   enforced at the AST, not just at runtime.
//! * **pump-discipline** — pump-loop files may not call
//!   `std::thread::sleep` or unbounded `recv()` without a
//!   `// LINT: sleep-ok(...)` / `// LINT: recv-ok(...)` annotation
//!   (parks must go through the doorbell/governor machinery).
//! * **control-coverage** — every `ControlMsg` variant has a matching
//!   `DdsClient` accessor (snake_case of the variant name), so the
//!   control plane cannot grow service-side verbs the host library
//!   cannot reach.
//!
//! `#[cfg(test)]` and `#[cfg(loom)]` modules are exempt: tests copy
//! freely, and the loom mutation self-tests *deliberately* contain the
//! orderings this linter forbids.
//!
//! syn discards comments, so the AST walk anchors each finding to a
//! source line and the annotation/SAFETY checks re-read the raw lines
//! around that anchor — AST precision for *what* is called, raw text
//! for *how it is justified*.

use std::fmt;
use std::path::{Path, PathBuf};

use quote::ToTokens;
use syn::spanned::Spanned;
use syn::visit::Visit;

/// Atomic method names whose argument list can carry an `Ordering`.
const ATOMIC_METHODS: &[&str] = &[
    "load",
    "store",
    "swap",
    "fetch_add",
    "fetch_sub",
    "fetch_and",
    "fetch_or",
    "fetch_xor",
    "fetch_max",
    "fetch_min",
    "fetch_update",
    "compare_exchange",
    "compare_exchange_weak",
];

/// One finding. `line` is 1-based.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// A registered lost-wakeup-/coherence-critical atomic.
#[derive(Debug, Clone, Default)]
pub struct AtomicRule {
    pub name: String,
    /// Whitespace-free substrings matched against the normalized call
    /// expression, e.g. `.tail.0.` or `comp_len.`.
    pub patterns: Vec<String>,
    pub why: String,
}

/// The `ControlMsg` ↔ `DdsClient` completeness rule.
#[derive(Debug, Clone, Default)]
pub struct ControlRule {
    pub enum_file: String,
    pub enum_name: String,
    pub impl_file: String,
    pub impl_type: String,
    /// Variants with no accessor by design (e.g. `Shutdown`, which is
    /// sent by the service handle's `Drop`).
    pub exempt: Vec<String>,
    /// `"Variant=accessor"` overrides for names that are not plain
    /// snake_case of the variant.
    pub rename: Vec<(String, String)>,
}

/// The parsed `invariants.toml`.
#[derive(Debug, Clone)]
pub struct Registry {
    /// How many lines above an `unsafe` token a `// SAFETY:` comment
    /// may sit.
    pub safety_lookback: usize,
    /// How many lines above a flagged call a `// LINT: ...-ok`
    /// annotation may sit.
    pub annotation_lookback: usize,
    pub atomics: Vec<AtomicRule>,
    /// Top-level `rust/src` modules under the copy-smell rule.
    pub copy_modules: Vec<String>,
    /// Flagged method names (`to_vec`, ...).
    pub copy_methods: Vec<String>,
    /// `x.clone()` is flagged when the receiver's last path segment is
    /// one of these identifiers...
    pub clone_receiver_idents: Vec<String>,
    /// ...or when the normalized receiver ends with one of these
    /// suffixes (e.g. `as_slice()`).
    pub clone_receiver_suffixes: Vec<String>,
    /// Files (relative to the scan root) under the pump-discipline
    /// rule.
    pub pump_files: Vec<String>,
    pub control: Option<ControlRule>,
}

impl Default for Registry {
    fn default() -> Self {
        Registry {
            safety_lookback: 6,
            annotation_lookback: 4,
            atomics: Vec::new(),
            copy_modules: Vec::new(),
            copy_methods: Vec::new(),
            clone_receiver_idents: Vec::new(),
            clone_receiver_suffixes: Vec::new(),
            pump_files: Vec::new(),
            control: None,
        }
    }
}

/// Minimal TOML value for the subset the registry uses.
#[derive(Debug, Clone)]
enum Value {
    Str(String),
    Int(i64),
    List(Vec<String>),
}

fn parse_value(raw: &str, line_no: usize) -> Result<Value, String> {
    let raw = raw.trim();
    if let Some(rest) = raw.strip_prefix('"') {
        let end = rest.find('"').ok_or_else(|| format!("line {line_no}: unterminated string"))?;
        return Ok(Value::Str(rest[..end].to_string()));
    }
    if raw.starts_with('[') {
        if !raw.ends_with(']') {
            return Err(format!("line {line_no}: arrays must be single-line"));
        }
        let body = &raw[1..raw.len() - 1];
        let mut items = Vec::new();
        let mut rest = body.trim();
        while !rest.is_empty() {
            let inner = rest
                .strip_prefix('"')
                .ok_or_else(|| format!("line {line_no}: array items must be strings"))?;
            let end =
                inner.find('"').ok_or_else(|| format!("line {line_no}: unterminated string"))?;
            items.push(inner[..end].to_string());
            rest = inner[end + 1..].trim();
            rest = rest.strip_prefix(',').unwrap_or(rest).trim();
        }
        return Ok(Value::List(items));
    }
    raw.parse::<i64>()
        .map(Value::Int)
        .map_err(|_| format!("line {line_no}: unsupported value `{raw}`"))
}

impl Registry {
    /// Parse the registry from the TOML subset it is written in:
    /// `[section]` / `[[section]]` headers, `key = "str" | int |
    /// ["a", "b"]` pairs, `#` comments. No external TOML crate — the
    /// grammar is small enough to own, and the Python mirror
    /// (`rust/lint/mirror.py`) implements the identical subset.
    pub fn from_toml(text: &str) -> Result<Registry, String> {
        let mut reg = Registry::default();
        let mut section = String::new();
        for (idx, raw_line) in text.lines().enumerate() {
            let line_no = idx + 1;
            let line = match raw_line.find('#') {
                // `#` inside a quoted value does not occur in this
                // registry; the subset forbids it.
                Some(i) if !raw_line[..i].contains('"') => &raw_line[..i],
                _ => raw_line,
            };
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(h) = line.strip_prefix("[[").and_then(|l| l.strip_suffix("]]")) {
                section = h.to_string();
                if section == "atomics" {
                    reg.atomics.push(AtomicRule::default());
                } else {
                    return Err(format!("line {line_no}: unknown array section `{section}`"));
                }
                continue;
            }
            if let Some(h) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = h.to_string();
                if section == "control_rule" && reg.control.is_none() {
                    reg.control = Some(ControlRule::default());
                }
                continue;
            }
            let (key, raw_val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {line_no}: expected `key = value`"))?;
            let key = key.trim();
            let val = parse_value(raw_val, line_no)?;
            reg.apply(&section, key, val, line_no)?;
        }
        Ok(reg)
    }

    fn apply(&mut self, section: &str, key: &str, val: Value, line_no: usize) -> Result<(), String> {
        let bad = || format!("line {line_no}: bad type for `{section}.{key}`");
        match (section, key) {
            ("unsafe_rule", "lookback") => match val {
                Value::Int(n) => self.safety_lookback = n.max(0) as usize,
                _ => return Err(bad()),
            },
            ("annotations", "lookback") => match val {
                Value::Int(n) => self.annotation_lookback = n.max(0) as usize,
                _ => return Err(bad()),
            },
            ("atomics", _) => {
                let rule = self
                    .atomics
                    .last_mut()
                    .ok_or_else(|| format!("line {line_no}: key outside [[atomics]]"))?;
                match (key, val) {
                    ("name", Value::Str(s)) => rule.name = s,
                    ("why", Value::Str(s)) => rule.why = s,
                    ("patterns", Value::List(l)) => rule.patterns = l,
                    _ => return Err(bad()),
                }
            }
            ("copy_rule", "modules") => match val {
                Value::List(l) => self.copy_modules = l,
                _ => return Err(bad()),
            },
            ("copy_rule", "methods") => match val {
                Value::List(l) => self.copy_methods = l,
                _ => return Err(bad()),
            },
            ("copy_rule", "clone_receiver_idents") => match val {
                Value::List(l) => self.clone_receiver_idents = l,
                _ => return Err(bad()),
            },
            ("copy_rule", "clone_receiver_suffixes") => match val {
                Value::List(l) => self.clone_receiver_suffixes = l,
                _ => return Err(bad()),
            },
            ("pump_rule", "files") => match val {
                Value::List(l) => self.pump_files = l,
                _ => return Err(bad()),
            },
            ("control_rule", _) => {
                let ctl = self.control.as_mut().expect("control_rule section initialized");
                match (key, val) {
                    ("enum_file", Value::Str(s)) => ctl.enum_file = s,
                    ("enum_name", Value::Str(s)) => ctl.enum_name = s,
                    ("impl_file", Value::Str(s)) => ctl.impl_file = s,
                    ("impl_type", Value::Str(s)) => ctl.impl_type = s,
                    ("exempt", Value::List(l)) => ctl.exempt = l,
                    ("rename", Value::List(l)) => {
                        ctl.rename = l
                            .iter()
                            .map(|item| {
                                item.split_once('=')
                                    .map(|(a, b)| (a.trim().to_string(), b.trim().to_string()))
                                    .ok_or_else(|| {
                                        format!("line {line_no}: rename items are `Variant=fn`")
                                    })
                            })
                            .collect::<Result<_, _>>()?;
                    }
                    _ => return Err(bad()),
                }
            }
            // Unknown keys in known sections (and whole unknown
            // sections) are ignored so the registry can grow without
            // lock-stepping the binary.
            _ => {}
        }
        Ok(())
    }
}

/// Strip all whitespace — token streams print with spaces between
/// every token, the registry patterns are written without them.
fn normalize(tokens: &str) -> String {
    tokens.chars().filter(|c| !c.is_whitespace()).collect()
}

/// Does `line` carry `marker` inside a `//` comment?
fn comment_has(line: &str, marker: &str) -> bool {
    match line.find("//") {
        Some(i) => line[i..].contains(marker),
        None => false,
    }
}

/// Is `marker` present in a comment on `line` (1-based) or within
/// `lookback` lines above it?
fn annotated(lines: &[&str], line: usize, marker: &str, lookback: usize) -> bool {
    if line == 0 || lines.is_empty() {
        return false;
    }
    let idx = (line - 1).min(lines.len() - 1);
    let lo = idx.saturating_sub(lookback);
    lines[lo..=idx].iter().any(|l| comment_has(l, marker))
}

/// CamelCase → snake_case (`CpuStats` → `cpu_stats`).
fn snake_case(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 4);
    for (i, c) in name.chars().enumerate() {
        if c.is_ascii_uppercase() {
            if i > 0 {
                out.push('_');
            }
            out.push(c.to_ascii_lowercase());
        } else {
            out.push(c);
        }
    }
    out
}

/// Is this item gated out of the lint's scope (`#[cfg(test)]` /
/// `#[cfg(loom)]` and combinations)? The loom mutation self-tests
/// *deliberately* contain forbidden orderings.
fn attrs_exempt(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| {
        let s = a.to_token_stream().to_string();
        s.contains("cfg") && (s.contains("test") || s.contains("loom") || s.contains("miri"))
    })
}

struct Checker<'a> {
    reg: &'a Registry,
    rel: &'a str,
    lines: Vec<&'a str>,
    in_data_path: bool,
    is_pump: bool,
    out: Vec<Violation>,
}

impl Checker<'_> {
    fn push(&mut self, line: usize, rule: &'static str, msg: String) {
        self.out.push(Violation { file: self.rel.to_string(), line, rule, msg });
    }

    fn require_safety(&mut self, line: usize, what: &str) {
        if !annotated(&self.lines, line, "SAFETY:", self.reg.safety_lookback) {
            self.push(
                line,
                "unsafe-safety",
                format!("{what} without a `// SAFETY:` comment within reach"),
            );
        }
    }

    fn require_annotation(&mut self, line: usize, rule: &'static str, marker: &str, msg: String) {
        if !annotated(&self.lines, line, marker, self.reg.annotation_lookback) {
            self.push(line, rule, msg);
        }
    }
}

impl<'a, 'ast> Visit<'ast> for Checker<'a> {
    fn visit_item_mod(&mut self, node: &'ast syn::ItemMod) {
        if attrs_exempt(&node.attrs) {
            return; // do not descend into test/loom modules
        }
        syn::visit::visit_item_mod(self, node);
    }

    fn visit_item_fn(&mut self, node: &'ast syn::ItemFn) {
        if attrs_exempt(&node.attrs) {
            return;
        }
        if let Some(tok) = &node.sig.unsafety {
            let line = tok.span.start().line;
            self.require_safety(line, "`unsafe fn`");
        }
        syn::visit::visit_item_fn(self, node);
    }

    fn visit_impl_item_fn(&mut self, node: &'ast syn::ImplItemFn) {
        if attrs_exempt(&node.attrs) {
            return;
        }
        if let Some(tok) = &node.sig.unsafety {
            let line = tok.span.start().line;
            self.require_safety(line, "`unsafe fn`");
        }
        syn::visit::visit_impl_item_fn(self, node);
    }

    fn visit_item_impl(&mut self, node: &'ast syn::ItemImpl) {
        if attrs_exempt(&node.attrs) {
            return;
        }
        if let Some(tok) = &node.unsafety {
            let line = tok.span.start().line;
            self.require_safety(line, "`unsafe impl`");
        }
        syn::visit::visit_item_impl(self, node);
    }

    fn visit_expr_unsafe(&mut self, node: &'ast syn::ExprUnsafe) {
        let line = node.unsafe_token.span.start().line;
        self.require_safety(line, "`unsafe` block");
        syn::visit::visit_expr_unsafe(self, node);
    }

    fn visit_expr_call(&mut self, node: &'ast syn::ExprCall) {
        if self.is_pump {
            let callee = normalize(&node.func.to_token_stream().to_string());
            if callee.ends_with("thread::sleep") || callee == "sleep" {
                let line = node.func.span().start().line;
                self.require_annotation(
                    line,
                    "pump-discipline",
                    "LINT: sleep-ok",
                    "pump-loop file calls thread::sleep without `// LINT: sleep-ok(reason)` \
                     (parks must go through the doorbell/governor)"
                        .to_string(),
                );
            }
        }
        syn::visit::visit_expr_call(self, node);
    }

    fn visit_expr_method_call(&mut self, node: &'ast syn::ExprMethodCall) {
        let method = node.method.to_string();
        let line = node.method.span().start().line;

        if self.in_data_path && self.reg.copy_methods.iter().any(|m| *m == method) {
            self.require_annotation(
                line,
                "copy-smell",
                "LINT: copy-ok",
                format!(
                    "data-path call to `{method}` without `// LINT: copy-ok(reason)` \
                     (the CopyLedger contract: every data-path memcpy is deliberate)"
                ),
            );
        }

        if self.in_data_path && method == "clone" && node.args.is_empty() {
            let recv = normalize(&node.receiver.to_token_stream().to_string());
            let last = recv.rsplit('.').next().unwrap_or(&recv);
            let by_ident = self.reg.clone_receiver_idents.iter().any(|id| last == *id);
            let by_suffix = self.reg.clone_receiver_suffixes.iter().any(|s| recv.ends_with(s));
            if by_ident || by_suffix {
                self.require_annotation(
                    line,
                    "copy-smell",
                    "LINT: copy-ok",
                    format!(
                        "data-path `.clone()` of a byte buffer (`{recv}`) without \
                         `// LINT: copy-ok(reason)`"
                    ),
                );
            }
        }

        if self.is_pump && method == "recv" && node.args.is_empty() {
            self.require_annotation(
                line,
                "pump-discipline",
                "LINT: recv-ok",
                "pump-loop file calls unbounded `recv()` without `// LINT: recv-ok(reason)` \
                 (use try_recv / recv_timeout via the governor)"
                    .to_string(),
            );
        }

        if ATOMIC_METHODS.contains(&method.as_str()) {
            let call = normalize(&node.to_token_stream().to_string());
            if call.contains("Ordering::Relaxed") {
                let hits: Vec<&AtomicRule> = self
                    .reg
                    .atomics
                    .iter()
                    .filter(|rule| rule.patterns.iter().any(|p| call.contains(p.as_str())))
                    .collect();
                if let Some(rule) = hits.first() {
                    self.require_annotation(
                        line,
                        "relaxed-ordering",
                        "LINT: relaxed-ok",
                        format!(
                            "`Ordering::Relaxed` on registered atomic `{}` ({}) without \
                             `// LINT: relaxed-ok(reason)`",
                            rule.name, rule.why
                        ),
                    );
                }
            }
        }

        syn::visit::visit_expr_method_call(self, node);
    }
}

/// Scan one source file (already read) under its scan-root-relative
/// path, e.g. `ring/response.rs`.
pub fn scan_source(rel: &str, src: &str, reg: &Registry) -> Vec<Violation> {
    let ast = match syn::parse_file(src) {
        Ok(ast) => ast,
        Err(e) => {
            return vec![Violation {
                file: rel.to_string(),
                line: e.span().start().line.max(1),
                rule: "parse",
                msg: format!("not parseable as Rust: {e}"),
            }];
        }
    };
    let module = rel.split('/').next().unwrap_or(rel).trim_end_matches(".rs");
    let mut checker = Checker {
        reg,
        rel,
        lines: src.lines().collect(),
        in_data_path: reg.copy_modules.iter().any(|m| m == module),
        is_pump: reg.pump_files.iter().any(|f| f == rel),
        out: Vec::new(),
    };
    checker.visit_file(&ast);
    checker.out
}

/// Enum-variant ↔ client-accessor completeness (`control-coverage`).
/// Paths in the rule are repo-root-relative; `repo_root` anchors them.
pub fn check_control(reg: &Registry, repo_root: &Path) -> Result<Vec<Violation>, String> {
    let Some(ctl) = &reg.control else {
        return Ok(Vec::new());
    };
    let read = |rel: &str| -> Result<String, String> {
        std::fs::read_to_string(repo_root.join(rel)).map_err(|e| format!("{rel}: {e}"))
    };
    let enum_src = read(&ctl.enum_file)?;
    let enum_ast = syn::parse_file(&enum_src).map_err(|e| format!("{}: {e}", ctl.enum_file))?;
    let impl_src = read(&ctl.impl_file)?;
    let impl_ast = syn::parse_file(&impl_src).map_err(|e| format!("{}: {e}", ctl.impl_file))?;

    let mut variants: Vec<(String, usize)> = Vec::new();
    for item in &enum_ast.items {
        if let syn::Item::Enum(e) = item {
            if e.ident == ctl.enum_name {
                for v in &e.variants {
                    variants.push((v.ident.to_string(), v.ident.span().start().line));
                }
            }
        }
    }
    if variants.is_empty() {
        return Err(format!("{}: enum `{}` not found", ctl.enum_file, ctl.enum_name));
    }

    let mut methods: Vec<String> = Vec::new();
    for item in &impl_ast.items {
        if let syn::Item::Impl(imp) = item {
            if imp.trait_.is_none()
                && normalize(&imp.self_ty.to_token_stream().to_string()) == ctl.impl_type
            {
                for ii in &imp.items {
                    if let syn::ImplItem::Fn(f) = ii {
                        methods.push(f.sig.ident.to_string());
                    }
                }
            }
        }
    }
    if methods.is_empty() {
        return Err(format!("{}: no inherent impl of `{}` found", ctl.impl_file, ctl.impl_type));
    }

    let mut out = Vec::new();
    for (variant, line) in variants {
        if ctl.exempt.iter().any(|e| *e == variant) {
            continue;
        }
        let want = ctl
            .rename
            .iter()
            .find(|(v, _)| *v == variant)
            .map(|(_, f)| f.clone())
            .unwrap_or_else(|| snake_case(&variant));
        if !methods.iter().any(|m| *m == want) {
            out.push(Violation {
                file: ctl.enum_file.clone(),
                line,
                rule: "control-coverage",
                msg: format!(
                    "`{}::{variant}` has no `{}::{want}` accessor (add one or register an \
                     exemption/rename in invariants.toml)",
                    ctl.enum_name, ctl.impl_type
                ),
            });
        }
    }
    Ok(out)
}

/// All `.rs` files under `root`, sorted for deterministic output.
pub fn collect_rs_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    fn rec(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
        let mut entries: Vec<_> =
            std::fs::read_dir(dir)?.collect::<Result<Vec<_>, std::io::Error>>()?;
        entries.sort_by_key(|e| e.path());
        for e in entries {
            let p = e.path();
            if p.is_dir() {
                rec(&p, out)?;
            } else if p.extension().is_some_and(|x| x == "rs") {
                out.push(p);
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    rec(root, &mut out)?;
    Ok(out)
}

/// Run every check: the per-file scans over `scan_root` plus the
/// control-coverage pass (anchored at `repo_root`).
pub fn run(repo_root: &Path, scan_root: &Path, reg: &Registry) -> Result<Vec<Violation>, String> {
    let mut out = Vec::new();
    let files = collect_rs_files(scan_root).map_err(|e| format!("{}: {e}", scan_root.display()))?;
    for path in files {
        let src = std::fs::read_to_string(&path).map_err(|e| format!("{}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(scan_root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        out.extend(scan_source(&rel, &src, reg));
    }
    out.extend(check_control(reg, repo_root)?);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snake_case_matches_accessor_convention() {
        assert_eq!(snake_case("CreateDirectory"), "create_directory");
        assert_eq!(snake_case("CpuStats"), "cpu_stats");
        assert_eq!(snake_case("Shutdown"), "shutdown");
    }

    #[test]
    fn registry_subset_parses() {
        let reg = Registry::from_toml(
            r#"
# comment
[unsafe_rule]
lookback = 3

[[atomics]]
name = "doorbell.seq"
patterns = [".seq.load(", ".seq.fetch_add("]
why = "Dekker pair"

[copy_rule]
modules = ["ring", "buf"]
methods = ["to_vec"]

[pump_rule]
files = ["idle.rs"]

[control_rule]
enum_file = "a.rs"
enum_name = "E"
impl_file = "b.rs"
impl_type = "C"
exempt = ["Shutdown"]
rename = ["CreatePoll=create_poll"]
"#,
        )
        .unwrap();
        assert_eq!(reg.safety_lookback, 3);
        assert_eq!(reg.atomics.len(), 1);
        assert_eq!(reg.atomics[0].patterns.len(), 2);
        assert_eq!(reg.copy_modules, vec!["ring", "buf"]);
        let ctl = reg.control.unwrap();
        assert_eq!(ctl.exempt, vec!["Shutdown"]);
        assert_eq!(ctl.rename, vec![("CreatePoll".to_string(), "create_poll".to_string())]);
    }

    #[test]
    fn annotation_lookback_is_bounded() {
        let lines = vec!["// LINT: copy-ok(x)", "", "", "", "", "let v = b.to_vec();"];
        assert!(annotated(&lines, 6, "LINT: copy-ok", 5));
        assert!(!annotated(&lines, 6, "LINT: copy-ok", 2));
    }

    #[test]
    fn comment_marker_must_be_in_comment() {
        // The marker inside a string literal on a code line does not
        // count; after `//` it does.
        assert!(!comment_has("let s = \"SAFETY: nope\";", "SAFETY:"));
        assert!(comment_has("foo(); // SAFETY: fine", "SAFETY:"));
    }
}
