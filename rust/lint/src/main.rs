//! `ddslint` CLI.
//!
//! ```text
//! ddslint [--repo-root DIR] [--scan-root DIR] [--registry FILE]
//! ```
//!
//! Defaults assume invocation from the repo root (what CI does):
//! repo-root `.`, scan-root `rust/src`, registry
//! `rust/lint/invariants.toml`. Prints one `file:line: [rule] msg`
//! line per violation and exits non-zero if any were found.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut repo_root = PathBuf::from(".");
    let mut scan_root: Option<PathBuf> = None;
    let mut registry: Option<PathBuf> = None;

    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        let mut take = |name: &str| match args.next() {
            Some(v) => Some(PathBuf::from(v)),
            None => {
                eprintln!("ddslint: {name} requires a value");
                None
            }
        };
        match arg.as_str() {
            "--repo-root" => match take("--repo-root") {
                Some(v) => repo_root = v,
                None => return ExitCode::from(2),
            },
            "--scan-root" => match take("--scan-root") {
                Some(v) => scan_root = Some(v),
                None => return ExitCode::from(2),
            },
            "--registry" => match take("--registry") {
                Some(v) => registry = Some(v),
                None => return ExitCode::from(2),
            },
            "--help" | "-h" => {
                println!(
                    "ddslint [--repo-root DIR] [--scan-root DIR] [--registry FILE]\n\
                     defaults: --repo-root . --scan-root <root>/rust/src \
                     --registry <root>/rust/lint/invariants.toml"
                );
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("ddslint: unknown argument `{other}`");
                return ExitCode::from(2);
            }
        }
    }

    let scan_root = scan_root.unwrap_or_else(|| repo_root.join("rust/src"));
    let registry = registry.unwrap_or_else(|| repo_root.join("rust/lint/invariants.toml"));

    let text = match std::fs::read_to_string(&registry) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("ddslint: cannot read registry {}: {e}", registry.display());
            return ExitCode::from(2);
        }
    };
    let reg = match ddslint::Registry::from_toml(&text) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("ddslint: registry {}: {e}", registry.display());
            return ExitCode::from(2);
        }
    };

    match ddslint::run(&repo_root, &scan_root, &reg) {
        Ok(violations) if violations.is_empty() => {
            println!("ddslint: clean ({} ok)", scan_root.display());
            ExitCode::SUCCESS
        }
        Ok(violations) => {
            for v in &violations {
                println!("{v}");
            }
            println!("ddslint: {} violation(s)", violations.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("ddslint: {e}");
            ExitCode::from(2)
        }
    }
}
