//! Lost-wakeup stress suite for the CPU plane (DESIGN.md "The CPU
//! plane"), plus the busy-fraction acceptance checks.
//!
//! Strategy: the park points are armed with a HUGE park timeout so a
//! lost wakeup does not degrade into the bounded-latency blip the
//! production default (1 ms) turns it into, but into a test-failing
//! stall — if any producer edge fails to ring its pump, the bounded
//! waits below expire instead of the suite passing slowly.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use dds::coordinator::{StorageServer, StorageServerConfig};
use dds::fileservice::FileServiceConfig;
use dds::idle::{Doorbell, IdlePolicy};
use dds::sim::Rng;

/// Long enough that any lost wakeup blows the per-op latency bound.
const HUGE_PARK: Duration = Duration::from_secs(30);

fn storage_with(idle: IdlePolicy) -> StorageServer {
    let cfg = StorageServerConfig {
        ssd_bytes: 16 << 20,
        service: FileServiceConfig { idle, ..Default::default() },
        ..Default::default()
    };
    StorageServer::build(cfg, None).expect("storage")
}

fn hair_trigger() -> IdlePolicy {
    // No spin budget (only the fixed yield rung) and an effectively
    // unbounded park cap. Note the backoff still escalates from 64 µs,
    // so a single missed ring is found at the next short timeout — the
    // latency bounds below are therefore necessary but not sufficient.
    // The sufficient check is the `wakes` counter: parks that end in a
    // ring are counted as wakes, parks that merely time out are not,
    // so a missing producer edge drives the wakes delta to ~zero even
    // while latency stays low.
    IdlePolicy::Adaptive { spin_iters: 0, park_timeout: HUGE_PARK }
}

/// Raw doorbell: a producer races the consumer's park from another
/// thread over many seeded interleavings; every published token must
/// be consumed promptly (a lost ring would strand the consumer in a
/// 30 s wait).
#[test]
fn doorbell_never_loses_a_racing_ring() {
    const TOKENS: u64 = 2000;
    for seed in 0..8u64 {
        let bell = Doorbell::new();
        let work = Arc::new(AtomicU64::new(0));
        let consumer = {
            let bell = bell.clone();
            let work = work.clone();
            std::thread::spawn(move || {
                let mut got = 0u64;
                while got < TOKENS {
                    // Sequence BEFORE the work scan — the lost-wakeup
                    // protocol every pump follows.
                    let seen = bell.seq();
                    let avail = work.load(Ordering::Acquire);
                    if avail > got {
                        got = avail;
                        continue;
                    }
                    bell.wait(seen, HUGE_PARK);
                }
            })
        };
        let mut rng = Rng::new(0xD00B_E11 ^ seed);
        let t0 = Instant::now();
        for _ in 0..TOKENS {
            work.fetch_add(1, Ordering::Release);
            bell.ring();
            // Jitter the race window: sometimes publish back-to-back,
            // sometimes give the consumer time to reach the park.
            match rng.next_range(16) {
                0..=11 => {}
                12..=14 => std::thread::yield_now(),
                _ => std::thread::sleep(Duration::from_micros(rng.next_range(200))),
            }
        }
        consumer.join().unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(15),
            "seed {seed}: a park slept through a ring (lost wakeup)"
        );
    }
}

/// A parked service must be woken by request-ring pushes: every data
/// op completes promptly, and — the sufficient check — most parks end
/// in a RING (`wakes`), not a backoff timeout. Seeded idle gaps let
/// the service reach the park rung at different depths before each op.
#[test]
fn parked_service_wakes_on_request_push() {
    let storage = storage_with(hair_trigger());
    let fe = storage.front_end();
    let dir = fe.create_directory("d").unwrap();
    let mut f = fe.create_file(dir, "f").unwrap();
    let group = fe.create_poll().unwrap();
    fe.poll_add(&mut f, &group);
    let mut rng = Rng::new(42);
    let before = storage.cpu_stats();
    for i in 0..40u64 {
        // Let the service reach the park rung (only the 16-iteration
        // yield rung stands between an empty pass and the first park).
        std::thread::sleep(Duration::from_micros(500 + rng.next_range(3000)));
        let data = vec![(i % 251) as u8; 600];
        let t0 = Instant::now();
        let wid = fe.write_file(&f, i * 600, &data).expect("issue write");
        let evs = group.poll_wait(Duration::from_secs(5));
        assert!(
            evs.iter().any(|e| e.req_id == wid && e.ok),
            "op {i}: write did not complete (lost wakeup?)"
        );
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "op {i}: completion took {:?} — the push did not ring the parked service",
            t0.elapsed()
        );
    }
    assert_eq!(group.in_flight(), 0);
    let d = storage.cpu_stats().since(&before);
    // With the push edge wired, nearly every op lands in a park and
    // rings it awake; with the edge missing, parks only ever time out
    // and this stays ~0 (the latency bound alone cannot tell — the
    // escalating backoff starts at 64 µs). Threshold is deliberately
    // far below the wired-edge expectation (~40) and far above the
    // broken-edge one (~0) so CI scheduling jitter in the park windows
    // cannot flip the verdict either way.
    assert!(d.wakes >= 8, "only {} of 40 ops rang the parked service awake ({d:?})", d.wakes);
}

/// A parked service must be woken by control-plane sends — checked by
/// the `wakes` delta like the push edge above.
#[test]
fn parked_service_wakes_on_control_send() {
    let storage = storage_with(hair_trigger());
    let fe = storage.front_end();
    let before = storage.cpu_stats();
    for i in 0..16 {
        std::thread::sleep(Duration::from_millis(3));
        let t0 = Instant::now();
        fe.create_directory(&format!("dir-{i}")).expect("create directory");
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "control call took {:?} against a parked service",
            t0.elapsed()
        );
    }
    let d = storage.cpu_stats().since(&before);
    // Same threshold reasoning as the push-edge test: wired ~16,
    // broken ~0, margin absorbs jitter.
    assert!(d.wakes >= 3, "only {} of 16 control sends rang the parked service ({d:?})", d.wakes);
}

/// With SSD worker threads, completions are posted asynchronously
/// while the service pump sits in its bounded-nap state (staging
/// outstanding > 0 — completions cannot ring a FULL park, which is
/// why the pump naps there; the `AsyncSsd` waker edge itself is
/// unit-tested in `ssd/async.rs`). This asserts the roundtrip stays
/// bounded under worker mode with the hair-trigger policy.
#[test]
fn parked_service_wakes_on_worker_completion() {
    let cfg = StorageServerConfig {
        ssd_bytes: 16 << 20,
        service: FileServiceConfig {
            idle: hair_trigger(),
            ssd_workers: 2,
            ..Default::default()
        },
        ..Default::default()
    };
    let storage = StorageServer::build(cfg, None).expect("storage");
    let fe = storage.front_end();
    let dir = fe.create_directory("d").unwrap();
    let mut f = fe.create_file(dir, "f").unwrap();
    let group = fe.create_poll().unwrap();
    fe.poll_add(&mut f, &group);
    let payload = vec![7u8; 4096];
    for i in 0..20u64 {
        let t0 = Instant::now();
        let wid = fe.write_file(&f, i * 4096, &payload).expect("issue write");
        let evs = group.poll_wait(Duration::from_secs(5));
        assert!(evs.iter().any(|e| e.req_id == wid && e.ok), "op {i} incomplete");
        let rid = fe.read_file(&f, i * 4096, 4096).expect("issue read");
        let evs = group.poll_wait(Duration::from_secs(5));
        let ev = evs.iter().find(|e| e.req_id == rid).expect("read completion");
        assert!(ev.ok && ev.data == payload, "op {i}: read not byte-exact");
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "op {i}: roundtrip took {:?} — a completion ring was lost",
            t0.elapsed()
        );
    }
}

/// Measure idle busy fraction over up to `tries` windows and return
/// the best one seen. Wall-clock busy segments absorb scheduler
/// preemption on loaded CI runners (sibling tests in this binary spin
/// threads), so a single noisy window must not flake the suite — a
/// real busy-loop regression fails EVERY window, noise fails one.
fn best_idle_window(stats: impl Fn() -> dds::metrics::CpuStats, window: Duration, tries: u32) -> f64 {
    let mut best = f64::MAX;
    for _ in 0..tries {
        let before = stats();
        std::thread::sleep(window);
        let d = stats().since(&before);
        best = best.min(d.busy_fraction());
        if best < 0.05 {
            break;
        }
    }
    best
}

/// The acceptance criterion's CPU half: an idle service pump under
/// Adaptive reports a busy fraction under 5% (it is parked nearly the
/// whole window), while the same pump under Poll burns the core
/// (busy fraction ~100%).
#[test]
fn idle_busy_fraction_adaptive_vs_poll() {
    let window = Duration::from_millis(500);

    let adaptive = storage_with(IdlePolicy::Adaptive {
        spin_iters: 64,
        park_timeout: Duration::from_millis(5),
    });
    let before = adaptive.cpu_stats();
    std::thread::sleep(window);
    let d = adaptive.cpu_stats().since(&before);
    assert!(d.parks > 10, "idle adaptive pump barely parked: {d:?}");
    let best = best_idle_window(|| adaptive.cpu_stats(), window, 3);
    assert!(best < 0.05, "idle adaptive pump busy fraction {best:.4} >= 5% in every window");
    drop(adaptive);

    let poll = storage_with(IdlePolicy::Poll);
    let before = poll.cpu_stats();
    std::thread::sleep(window);
    let d = poll.cpu_stats().since(&before);
    assert_eq!(d.parks, 0, "Poll must never park: {d:?}");
    assert!(
        d.busy_fraction() > 0.5,
        "Poll pump should burn the core, busy fraction {:.4} ({d:?})",
        d.busy_fraction()
    );
}

/// Same for the sharded plane: an idle 2-shard server's pumps all sit
/// parked under the default Adaptive policy.
#[test]
fn idle_sharded_pumps_park() {
    use dds::apps::RawFileApp;
    use dds::coordinator::{ShardedServer, ShardedServerConfig};
    use dds::director::AppSignature;
    use dds::offload::RawFileOffload;

    let logic = Arc::new(RawFileOffload);
    let storage = StorageServer::build(
        StorageServerConfig { ssd_bytes: 16 << 20, ..Default::default() },
        Some(logic.clone()),
    )
    .expect("storage");
    let file = storage.create_filled_file("bench", "data", 1 << 20).expect("fill");
    let cfg = ShardedServerConfig {
        shards: 2,
        idle: IdlePolicy::Adaptive { spin_iters: 64, park_timeout: Duration::from_millis(5) },
        ..Default::default()
    };
    let server = ShardedServer::over(
        storage,
        cfg,
        logic,
        AppSignature::server_port(5000),
        |_s, st| RawFileApp::over(st, &file),
    )
    .expect("sharded server");
    let before = server.all_cpu_stats();
    std::thread::sleep(Duration::from_millis(400));
    let after = server.all_cpu_stats();
    for (i, (b, a)) in before.iter().zip(&after).enumerate() {
        let d = a.since(b);
        assert!(d.parks > 5, "idle pump {i} barely parked: {d:?}");
    }
    // Busy fraction over the best of a few windows (see
    // best_idle_window: absorbs CI scheduler noise, which inflates
    // wall-clock busy segments; a real spin regression fails all).
    for (i, _) in before.iter().enumerate() {
        let best = best_idle_window(
            || server.all_cpu_stats()[i],
            Duration::from_millis(400),
            3,
        );
        assert!(best < 0.05, "idle pump {i} busy fraction {best:.4} >= 5% in every window");
    }
}

/// Shutdown must stay bounded with a deep backlog still queued on the
/// shard inputs (the server-level face of the shard-loop stop fix:
/// stop is honored mid-backlog instead of only after the queue runs
/// dry). Sends happen while the server is live; shutdown races the
/// drain.
#[test]
fn sharded_shutdown_bounded_with_deep_backlog() {
    use dds::apps::RawFileApp;
    use dds::coordinator::{ShardedServer, ShardedServerConfig};
    use dds::director::AppSignature;
    use dds::net::FiveTuple;
    use dds::offload::RawFileOffload;

    let logic = Arc::new(RawFileOffload);
    let storage = StorageServer::build(
        StorageServerConfig { ssd_bytes: 16 << 20, ..Default::default() },
        Some(logic.clone()),
    )
    .expect("storage");
    let file = storage.create_filled_file("bench", "data", 1 << 20).expect("fill");
    let mut server = ShardedServer::over(
        storage,
        ShardedServerConfig { shards: 2, ..Default::default() },
        logic,
        AppSignature::server_port(5000),
        |_s, st| RawFileApp::over(st, &file),
    )
    .expect("sharded server");
    // Pile a deep backlog of cheap forward-path batches onto every
    // shard, then shut down while it is still being drained.
    for p in 0..4u16 {
        let tuple = FiveTuple::new(0x0a00_0002, 50_000 + p, 0x0a00_00ff, 9999);
        for _ in 0..50_000 {
            let seg =
                dds::net::tcp::Segment { seq: 0, payload: dds::buf::BufView::empty(), ack: 0 };
            server.send(&tuple, vec![seg]).expect("send");
        }
    }
    let t0 = Instant::now();
    server.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "shutdown took {:?} with a deep backlog queued",
        t0.elapsed()
    );
}
