//! Property tests: transport reliability under adversarial delivery,
//! and director routing invariants. (Hand-rolled generators; seeds
//! printed on failure.)

use std::sync::Arc;

use dds::cache::{CacheItem, CuckooCache};
use dds::director::{rss_core, AppSignature};
use dds::net::tcp::{Segment, TcpEndpoint};
use dds::net::FiveTuple;
use dds::offload::{OffloadLogic, RawFileOffload};
use dds::proto::{AppRequest, NetMsg};
use dds::sim::Rng;

/// Reliability: random loss + reordering + duplication; the receiver
/// must deliver exactly the sent byte stream.
#[test]
fn tcp_delivers_stream_under_loss_reorder_duplication() {
    for seed in 1..=25u64 {
        let mut rng = Rng::new(seed);
        let mut a = TcpEndpoint::new();
        let mut b = TcpEndpoint::new();
        let data: Vec<u8> = (0..30_000).map(|_| rng.next_range(256) as u8).collect();
        let mut in_flight: Vec<Segment> = a.send(&data);
        let mut to_a: Vec<Segment> = Vec::new();
        let mut delivered = Vec::new();
        for _round in 0..2000 {
            // Adversarial channel a→b.
            let mut arriving = Vec::new();
            for s in in_flight.drain(..) {
                let roll = rng.next_f64();
                if roll < 0.1 {
                    continue; // lost
                }
                if roll < 0.2 {
                    arriving.push(s.clone()); // duplicated
                }
                arriving.push(s);
            }
            // Random reordering.
            for i in (1..arriving.len()).rev() {
                let j = rng.next_range(i as u64 + 1) as usize;
                arriving.swap(i, j);
            }
            for s in &arriving {
                to_a.extend(b.on_segment(s));
            }
            delivered.extend(b.deliver());
            // ACK channel is reliable (asymmetric loss is enough to
            // exercise retransmission).
            for s in to_a.drain(..) {
                in_flight.extend(a.on_segment(&s));
            }
            if delivered.len() >= data.len() {
                break;
            }
            if in_flight.is_empty() {
                // Timeout path: retransmit outstanding.
                in_flight = a.retransmit_all();
                if in_flight.is_empty() {
                    break;
                }
            }
        }
        assert_eq!(delivered.len(), data.len(), "seed {seed}: truncated stream");
        assert_eq!(delivered, data, "seed {seed}: corrupted stream");
    }
}

/// The Fig 11 pathology, property form: for ANY contiguous offloaded
/// range (not a prefix), the host receiver dup-ACKs and the client
/// retransmits at least the offloaded bytes — while the PEP split never
/// retransmits.
#[test]
fn partial_offload_always_pathological_without_pep() {
    for seed in 30..=45u64 {
        let mut rng = Rng::new(seed);
        let mut client = TcpEndpoint::new();
        let mut host = TcpEndpoint::new();
        let nseg = 6 + rng.next_range(10) as usize;
        let data: Vec<u8> = vec![7u8; nseg * dds::net::tcp::MSS];
        let segs = client.send(&data);
        // Offload a contiguous run that is NOT a prefix and leaves at
        // least 3 trailing segments (so 3 dup-ACKs can fire).
        let start = 1 + rng.next_range((nseg - 5) as u64) as usize;
        let end = start + 1 + rng.next_range((nseg - start - 4) as u64) as usize;
        let mut replies = Vec::new();
        for (i, s) in segs.iter().enumerate() {
            if (start..end).contains(&i) {
                continue; // consumed by the DPU
            }
            replies.extend(host.on_segment(s));
        }
        assert!(host.dup_acks_sent >= 3, "seed {seed}: no dup-ACK storm (range {start}..{end})");
        let mut retrans = Vec::new();
        for r in &replies {
            retrans.extend(client.on_segment(r));
        }
        assert!(
            client.retransmitted_segments as usize >= end - start,
            "seed {seed}: offloaded range not fully retransmitted"
        );
    }
}

/// OffPred routing is a partition: every request lands in exactly one
/// of (host, dpu), order and indices preserved.
#[test]
fn off_pred_partitions_batches() {
    for seed in 50..=70u64 {
        let mut rng = Rng::new(seed);
        let cache = CuckooCache::new(64);
        let n = 1 + rng.next_range(30) as usize;
        let requests: Vec<AppRequest> = (0..n)
            .map(|_| {
                if rng.next_f64() < 0.5 {
                    AppRequest::Read { file_id: 1, offset: rng.next_range(1 << 20), size: 128 }
                } else {
                    AppRequest::Write { file_id: 1, offset: 0, data: vec![1] }
                }
            })
            .collect();
        let msg = NetMsg { msg_id: seed, requests: requests.clone() };
        let (host, dpu) = RawFileOffload.off_pred(&msg, &cache);
        assert_eq!(host.len() + dpu.len(), n, "seed {seed}: partition size");
        let mut seen = vec![false; n];
        for r in host.iter().chain(dpu.iter()) {
            assert_eq!(r.msg_id, seed);
            assert!(!seen[r.idx as usize], "seed {seed}: duplicate idx {}", r.idx);
            seen[r.idx as usize] = true;
            assert_eq!(msg.requests[r.idx as usize], r.req, "seed {seed}: request moved");
        }
        assert!(seen.iter().all(|&s| s), "seed {seed}: request dropped");
        // Within each list, indices are strictly increasing (order
        // preserved).
        for list in [&host, &dpu] {
            for w in list.windows(2) {
                assert!(w[0].idx < w[1].idx, "seed {seed}: order violated");
            }
        }
    }
}

/// Signature matching is consistent with its wildcard semantics for
/// random tuples.
#[test]
fn signature_wildcard_semantics() {
    let mut rng = Rng::new(77);
    for _ in 0..2000 {
        let t = FiveTuple::new(
            rng.next_u64() as u32,
            rng.next_u64() as u16,
            rng.next_u64() as u32,
            rng.next_u64() as u16,
        );
        let sig = AppSignature {
            client_ip: if rng.next_f64() < 0.5 { None } else { Some(t.client_ip) },
            client_port: if rng.next_f64() < 0.5 { None } else { Some(t.client_port) },
            server_ip: if rng.next_f64() < 0.5 { None } else { Some(t.server_ip) },
            server_port: if rng.next_f64() < 0.5 { None } else { Some(t.server_port) },
        };
        assert!(sig.matches(&t), "sig built from tuple must match");
        // Perturb one constrained field → must not match.
        if let Some(port) = sig.server_port {
            let mut t2 = t;
            t2.server_port = port.wrapping_add(1);
            assert!(!sig.matches(&t2));
        }
    }
}

/// RSS: symmetric for all flows, deterministic, and within bounds.
#[test]
fn rss_symmetric_and_bounded() {
    let mut rng = Rng::new(99);
    for _ in 0..3000 {
        let t = FiveTuple::new(
            rng.next_u64() as u32,
            rng.next_u64() as u16,
            rng.next_u64() as u32,
            rng.next_u64() as u16,
        );
        let rev = FiveTuple::new(t.server_ip, t.server_port, t.client_ip, t.client_port);
        for cores in [1usize, 3, 8] {
            let c = rss_core(&t, cores);
            assert!(c < cores);
            assert_eq!(c, rss_core(&rev, cores), "asymmetric steering");
            assert_eq!(c, rss_core(&t, cores), "non-deterministic");
        }
    }
}

/// Cache-on-write / invalidate-on-read round trip at the logic level:
/// whatever PageServerOffload caches, a covering read invalidates.
#[test]
fn cache_invalidate_roundtrip_pageserver_logic() {
    use dds::apps::{PageServer, PageServerOffload, PAGE_SIZE};
    use dds::dpufs::FileId;
    use dds::offload::{ReadOp, WriteOp};
    let logic = PageServerOffload { rbpex_file: FileId(3) };
    let mut rng = Rng::new(123);
    for _ in 0..200 {
        let page_id = rng.next_range(1 << 30);
        let lsn = rng.next_range(1 << 20);
        let data = PageServer::page_image(page_id, lsn, 0xCD);
        let items = logic.cache(&WriteOp {
            file_id: FileId(3),
            offset: page_id * PAGE_SIZE as u64,
            data: &data,
        });
        assert_eq!(items.len(), 1);
        assert_eq!(items[0].0, page_id);
        let keys = logic.invalidate(&ReadOp {
            file_id: FileId(3),
            offset: page_id * PAGE_SIZE as u64,
            size: PAGE_SIZE as u32,
        });
        assert!(keys.contains(&page_id), "read must invalidate what the write cached");
    }
    // Arc to satisfy the OffloadLogic trait-object usage elsewhere.
    let _: Arc<dyn OffloadLogic> = Arc::new(logic);
    let _ = CacheItem::default();
}
