//! Property suite for the DPU read-cache tier: seeded concurrent
//! READ/WRITE/invalidate traffic against a versioned-block model.
//!
//! The coherence property under test is the tier's one contract:
//! **no probe ever returns bytes older than the last acked WRITE to
//! that extent**. Writers model the durable-WRITE pipeline in the
//! order the real one runs it — commit the new bytes, invalidate the
//! tier, then ack — and readers assert every hit decodes to a version
//! at least as new as the last ack they observed before probing.
//! Payloads are self-describing (key + version + derived body), so a
//! cross-key mixup or torn payload is also caught byte-for-byte.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;

use dds::buf::{BufPool, BufView};
use dds::cache::{Probe, ReadCacheTier};
use dds::sim::Rng;

#[path = "chaos_common.rs"]
mod chaos_common;
use chaos_common::chaos_seed;

/// Bytes per cached block.
const BLK: u64 = 512;

/// Self-describing payload: `[key | version | body(version)]`. The
/// "SSD" in these tests is the model — a read materializes whatever
/// version the model says is committed right now.
fn encode(pool: &BufPool, key: u64, ver: u64, len: usize) -> BufView {
    let mut buf = pool.allocate(len);
    let s = buf.as_mut_slice();
    s[..8].copy_from_slice(&key.to_le_bytes());
    s[8..16].copy_from_slice(&ver.to_le_bytes());
    for (i, x) in s[16..].iter_mut().enumerate() {
        *x = (ver as usize).wrapping_add(i) as u8;
    }
    buf.freeze()
}

fn decode(s: &[u8]) -> (u64, u64) {
    let key = u64::from_le_bytes(s[..8].try_into().unwrap());
    let ver = u64::from_le_bytes(s[8..16].try_into().unwrap());
    (key, ver)
}

/// Concurrent half: 2 writers + 1 spurious invalidator + 4 readers
/// over 32 one-block keys, with a tier budget that only holds half of
/// them (CLOCK eviction churns the whole run). Readers assert the
/// coherence property against the `acked` floor they sampled before
/// each probe; any hit older than that floor is a stale read the
/// epoch guard failed to block.
#[test]
fn concurrent_reads_never_observe_pre_ack_bytes() {
    const KEYS: u64 = 32;
    const WRITER_OPS: usize = 4000;
    const READER_OPS: usize = 8000;

    let seed = chaos_seed();
    let pool = BufPool::new(64, 1024);
    // Half the keyspace fits: hits, misses and evictions all happen.
    let tier = Arc::new(ReadCacheTier::new((KEYS / 2) * BLK));
    let committed: Arc<Vec<AtomicU64>> =
        Arc::new((0..KEYS).map(|_| AtomicU64::new(0)).collect());
    let acked: Arc<Vec<AtomicU64>> =
        Arc::new((0..KEYS).map(|_| AtomicU64::new(0)).collect());

    let mut writers = Vec::new();
    for w in 0..2u64 {
        let (tier, committed, acked) = (tier.clone(), committed.clone(), acked.clone());
        writers.push(thread::spawn(move || {
            let mut rng = Rng::new(seed ^ 0xA11C_E000 ^ (w << 40));
            for _ in 0..WRITER_OPS {
                let k = rng.next_range(KEYS);
                // The durable-WRITE order: commit, invalidate, ack.
                let v = committed[k as usize].fetch_add(1, Ordering::SeqCst) + 1;
                tier.invalidate(k + 1, 0, BLK);
                acked[k as usize].fetch_max(v, Ordering::SeqCst);
            }
        }));
    }
    // Spurious invalidations (no data change) are legal noise: they
    // may only cost hits, never correctness.
    {
        let tier = tier.clone();
        writers.push(thread::spawn(move || {
            let mut rng = Rng::new(seed ^ 0x1274_0000);
            for _ in 0..2000 {
                let k = rng.next_range(KEYS);
                tier.invalidate(k + 1, 0, BLK);
            }
        }));
    }

    let mut readers = Vec::new();
    for r in 0..4u64 {
        let (tier, committed, acked, pool) =
            (tier.clone(), committed.clone(), acked.clone(), pool.clone());
        readers.push(thread::spawn(move || {
            let mut rng = Rng::new(seed ^ 0xBEEF_0000 ^ (r << 40));
            let mut hits = 0u64;
            for _ in 0..READER_OPS {
                let k = rng.next_range(KEYS);
                // The last ack observed BEFORE the probe is the floor
                // no returned payload may be older than.
                let floor = acked[k as usize].load(Ordering::SeqCst);
                match tier.probe(k + 1, 0, BLK) {
                    Probe::Hit(view) => {
                        let s = view.as_slice();
                        let (ek, ever) = decode(s);
                        assert_eq!(ek, k + 1, "hit served another key's payload");
                        assert!(
                            ever >= floor,
                            "stale read: key {k} served version {ever} < last \
                             acked {floor} (seed {seed})"
                        );
                        for (i, x) in s[16..].iter().enumerate() {
                            assert_eq!(
                                *x,
                                (ever as usize).wrapping_add(i) as u8,
                                "torn payload at byte {i} (key {k}, seed {seed})"
                            );
                        }
                        hits += 1;
                    }
                    Probe::Miss(t) => {
                        // The model SSD: whatever is committed now.
                        let dv = committed[k as usize].load(Ordering::SeqCst);
                        let view = encode(&pool, k + 1, dv, BLK as usize);
                        let _ = tier.fill(&t, &view); // dropped fills are legal
                    }
                }
            }
            hits
        }));
    }

    for w in writers {
        w.join().expect("writer panicked");
    }
    let hits: u64 = readers.into_iter().map(|r| r.join().expect("reader panicked")).sum();

    let s = tier.stats();
    assert!(hits > 0, "the run never hit the tier — the property went untested: {s:?}");
    assert!(s.fills > 0, "no fill installed: {s:?}");
    assert!(
        s.invalidations >= (2 * WRITER_OPS as u64) + 2000,
        "every invalidate call must be counted: {s:?}"
    );
    assert!(s.bytes_cached <= s.budget_bytes, "budget overrun: {s:?}");
    // Every pooled view is either transient (dropped above) or pinned
    // by the tier; clearing it must drain the pool completely.
    tier.clear();
    assert_eq!(pool.in_use(), 0, "cleared tier leaks pooled views");
}

/// Deterministic half: one thread, a seeded WRITE/READ/invalidate mix
/// over 16 keys with an 8-entry budget. Single-threaded there is no
/// legal lag: a hit must decode to EXACTLY the model's current
/// version, across eviction churn and spurious invalidations.
#[test]
fn seeded_single_thread_hits_match_the_model_exactly() {
    const KEYS: u64 = 16;

    let seed = chaos_seed();
    let pool = BufPool::new(64, 1024);
    let tier = ReadCacheTier::new((KEYS / 2) * BLK);
    let mut rng = Rng::new(seed ^ 0x51D3_0000);
    let mut model = vec![0u64; KEYS as usize];
    for op in 0..20_000 {
        let k = rng.next_range(KEYS);
        match rng.next_range(10) {
            // WRITE: commit + invalidate (the ack is implicit — same
            // thread).
            0..=3 => {
                model[k as usize] += 1;
                tier.invalidate(k + 1, 0, BLK);
            }
            // Spurious invalidation: no data change, no model change.
            4 => tier.invalidate(k + 1, 0, BLK),
            // READ.
            _ => match tier.probe(k + 1, 0, BLK) {
                Probe::Hit(view) => {
                    let (ek, ever) = decode(view.as_slice());
                    assert_eq!(ek, k + 1, "hit served another key's payload (op {op})");
                    assert_eq!(
                        ever, model[k as usize],
                        "hit serves a non-current version (key {k}, op {op}, seed {seed})"
                    );
                }
                Probe::Miss(t) => {
                    let view = encode(&pool, k + 1, model[k as usize], BLK as usize);
                    let _ = tier.fill(&t, &view);
                }
            },
        }
    }
    let s = tier.stats();
    assert!(
        s.hits > 0 && s.fills > 0 && s.evictions > 0,
        "the mix must exercise hit, fill and evict: {s:?}"
    );
    assert!(s.bytes_cached <= s.budget_bytes, "budget overrun: {s:?}");
    tier.clear();
    assert_eq!(pool.in_use(), 0, "cleared tier leaks pooled views");
}
