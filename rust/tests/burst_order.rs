//! Property: the burst pipeline preserves per-flow FIFO order and
//! byte-exactness, at every burst size and under SSD chaos.
//!
//! Each case pipelines several messages per connection (so real bursts
//! form inside the shard loop and the delivery stage), then checks the
//! arrival stream per flow:
//!
//! * **Byte-exactness** — every OK response carries exactly the fill
//!   pattern its offset predicts; ERR responses carry no payload.
//! * **Survivor FIFO** — OK responses arrive in issue order within a
//!   flow. Injected drops/delays may ERR or stall individual requests,
//!   but must never reorder the survivors around each other (§4.3
//!   ordered staging / engine in-order emission).
//! * **Bounded completion** — every request resolves OK or ERR within
//!   the case deadline.
//!
//! Burst sizes 1 (degenerate: the pipeline must not require batching),
//! 7 (odd, smaller than a wave) and 64 (the default) are each run
//! clean and under `ssd_chaos`-grade fault rates. Seeded via
//! `DDS_CHAOS_SEED` like the chaos suites.

#[path = "chaos_common.rs"]
mod chaos_common;

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use chaos_common::chaos_seed;
use dds::apps::RawFileApp;
use dds::coordinator::{
    tuple_for_shard, ClientConn, ShardedServer, ShardedServerConfig, StorageServer,
    StorageServerConfig,
};
use dds::director::AppSignature;
use dds::fault::{FaultConfig, FaultPlane, SsdFaultConfig};
use dds::net::FiveTuple;
use dds::offload::{OffloadEngineConfig, RawFileOffload};
use dds::proto::{AppRequest, NetMsg, NetResp};
use dds::sim::Rng;
use dds::workload::RandomIoGen;

const FILE_BYTES: u64 = 1 << 20;
const READ_SIZE: u32 = 512;
const SHARDS: usize = 2;
/// Messages in flight per connection per wave — what actually forms
/// multi-message bursts inside the shard loop.
const WINDOW: usize = 3;
const WAVES: usize = 4;
const BATCH: usize = 4;

struct Flow {
    shard: usize,
    tuple: FiveTuple,
    client: ClientConn,
    /// Expected payload per outstanding request, keyed `(msg_id, idx)`.
    expected: HashMap<(u64, u16), Vec<u8>>,
    /// Issue order of every request this wave; arrival order of OK
    /// responses must be a subsequence of this.
    issued: Vec<(u64, u16)>,
    /// `(msg_id, idx)` of OK responses in arrival order.
    ok_arrivals: Vec<(u64, u16)>,
    ok: u64,
    err: u64,
    last_rx: Instant,
}

fn run_case(seed: u64, burst: usize, chaos: bool) {
    let faults = if chaos {
        FaultConfig {
            seed,
            ssd: SsdFaultConfig { fail_p: 0.08, drop_p: 0.08, delay_p: 0.25, delay_polls: 3 },
            ..Default::default()
        }
    } else {
        FaultConfig { seed, ..Default::default() }
    };
    let plane = FaultPlane::new(faults);

    let logic = Arc::new(RawFileOffload);
    let server_cfg = StorageServerConfig { ssd_bytes: 32 << 20, ..Default::default() };
    let storage = StorageServer::build(server_cfg, Some(logic.clone())).expect("storage");
    let file = storage.create_filled_file("burst", "data", FILE_BYTES).expect("fill");
    let fid = file.id.0;
    let cfg = ShardedServerConfig {
        shards: SHARDS,
        burst,
        // Short pending timeout so dropped completions ERR quickly.
        engine_total: OffloadEngineConfig {
            pending_timeout: Duration::from_millis(500),
            ..Default::default()
        },
        faults: chaos.then(|| plane.clone()),
        ..Default::default()
    };
    let server = ShardedServer::over(
        storage,
        cfg,
        logic,
        AppSignature::server_port(5000),
        |_shard, st| RawFileApp::over(st, &file),
    )
    .expect("sharded server");
    plane.arm_ssd();

    let mut flows: Vec<Flow> = (0..SHARDS)
        .map(|s| {
            let tuple =
                tuple_for_shard(s, SHARDS, 0x0a00_0001, 40_000 + s as u16 * 101, 0x0a00_00ff, 5000);
            Flow {
                shard: s,
                tuple,
                client: ClientConn::new(tuple),
                expected: HashMap::new(),
                issued: Vec::new(),
                ok_arrivals: Vec::new(),
                ok: 0,
                err: 0,
                last_rx: Instant::now(),
            }
        })
        .collect();

    let mut next_msg_id = 1u64;
    for wave in 0..WAVES {
        // Pipeline WINDOW messages per flow before reading anything
        // back — this is what makes bursts real.
        for flow in flows.iter_mut() {
            for _ in 0..WINDOW {
                let msg_id = next_msg_id;
                next_msg_id += 1;
                let mut rng = Rng::new(seed ^ msg_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let mut requests = Vec::with_capacity(BATCH);
                for idx in 0..BATCH {
                    let offset = rng.next_range(FILE_BYTES - READ_SIZE as u64);
                    requests.push(AppRequest::Read { file_id: fid, offset, size: READ_SIZE });
                    flow.expected.insert(
                        (msg_id, idx as u16),
                        RandomIoGen::expected_fill(offset, READ_SIZE as usize),
                    );
                    flow.issued.push((msg_id, idx as u16));
                }
                let segs = flow.client.send_msg(&NetMsg { msg_id, requests });
                server.send(&flow.tuple, segs).expect("send");
            }
            flow.last_rx = Instant::now();
        }

        // Drain until every pipelined request has resolved OK or ERR.
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let mut outstanding = false;
            for flow in flows.iter_mut() {
                if flow.expected.is_empty() {
                    continue;
                }
                outstanding = true;
                pump(&server, flow, burst, chaos);
            }
            if !outstanding {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "burst={burst} chaos={chaos} seed={seed}: wave {wave} did not resolve \
                 (bounded completion violated)"
            );
        }
    }

    // Survivor FIFO: per flow, OK responses arrived in issue order.
    let total = (WAVES * WINDOW * BATCH) as u64;
    for flow in &flows {
        let mut cursor = 0usize;
        for got in &flow.ok_arrivals {
            let pos = flow.issued[cursor..]
                .iter()
                .position(|i| i == got)
                .unwrap_or_else(|| {
                    panic!(
                        "burst={burst} chaos={chaos} seed={seed}: flow {} OK response \
                         {got:?} arrived OUT OF ORDER (already passed in issue order)",
                        flow.shard
                    )
                });
            cursor += pos + 1;
        }
        assert_eq!(
            flow.ok + flow.err,
            total,
            "burst={burst} chaos={chaos} seed={seed}: flow {} lost responses",
            flow.shard
        );
        if !chaos {
            assert_eq!(
                flow.err, 0,
                "burst={burst} seed={seed}: clean run must not error (flow {})",
                flow.shard
            );
        }
    }
}

/// One pump step: absorb a server batch for `flow`, verify and account
/// its responses; on a stall, walk the timeout retransmission path.
fn pump(server: &ShardedServer, flow: &mut Flow, burst: usize, chaos: bool) {
    match server.recv_timeout(flow.shard, Duration::from_millis(5)) {
        Some((tuple, segs)) => {
            assert_eq!(
                tuple, flow.tuple,
                "shard {} emitted segments for a connection it does not own",
                flow.shard
            );
            flow.last_rx = Instant::now();
            let mut acks = Vec::new();
            let resps = flow.client.on_segments(&segs, &mut acks);
            if !acks.is_empty() {
                server.send(&flow.tuple, acks).expect("send acks");
            }
            for r in resps {
                let key = (r.msg_id, r.idx);
                let Some(expect) = flow.expected.remove(&key) else {
                    continue; // duplicate (TCP retransmit)
                };
                if r.status == NetResp::OK {
                    assert_eq!(
                        r.payload, expect,
                        "burst={burst} chaos={chaos}: OK response {key:?} with wrong bytes"
                    );
                    flow.ok_arrivals.push(key);
                    flow.ok += 1;
                } else {
                    assert!(
                        r.payload.is_empty(),
                        "burst={burst} chaos={chaos}: ERR response {key:?} carried payload"
                    );
                    flow.err += 1;
                }
            }
        }
        None => {
            if flow.last_rx.elapsed() >= Duration::from_millis(50) {
                let re = flow.client.ep.retransmit_all();
                if !re.is_empty() {
                    server.send(&flow.tuple, re).expect("retransmit");
                }
                flow.last_rx = Instant::now();
            }
        }
    }
}

#[test]
fn burst_1_fifo_and_byte_exact() {
    let seed = chaos_seed();
    run_case(seed, 1, false);
    run_case(seed, 1, true);
}

#[test]
fn burst_7_fifo_and_byte_exact() {
    let seed = chaos_seed();
    run_case(seed, 7, false);
    run_case(seed, 7, true);
}

#[test]
fn burst_64_fifo_and_byte_exact() {
    let seed = chaos_seed();
    run_case(seed, 64, false);
    run_case(seed, 64, true);
}
