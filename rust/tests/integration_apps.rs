//! Integration: the production-system integrations of §9 — page server
//! (Hyperscale) and MiniFaster (KV) on the full DDS stack.

use std::sync::Arc;
use std::time::Duration;

use dds::apps::{FasterOffload, MiniFaster, PageServer, PageServerOffload, PAGE_SIZE};
use dds::coordinator::{run_request, ClientConn, DisaggregatedServer, StorageServer, StorageServerConfig};
use dds::director::AppSignature;
use dds::dpufs::FileId;
use dds::net::FiveTuple;
use dds::offload::OffloadEngineConfig;
use dds::proto::{AppRequest, NetMsg};

fn tuple(port: u16) -> FiveTuple {
    FiveTuple::new(0x0a000001, 40000, 0x0a0000ff, port)
}

fn build_page_server(n_pages: u64) -> (DisaggregatedServer<PageServer>, Arc<PageServerOffload>) {
    let rbpex_file = FileId(1);
    let logic = Arc::new(PageServerOffload { rbpex_file });
    let storage =
        StorageServer::build(StorageServerConfig::default(), Some(logic.clone())).unwrap();
    let fe = storage.front_end();
    let dir = fe.create_directory("db").unwrap();
    let file = fe.create_file(dir, "rbpex").unwrap();
    assert_eq!(file.id, rbpex_file);
    let group = fe.create_poll().unwrap();
    let app = PageServer::new(fe, file, group, n_pages).unwrap();
    let server = DisaggregatedServer::new(
        storage,
        logic.clone(),
        AppSignature::server_port(1433),
        OffloadEngineConfig { pool_buf_size: PAGE_SIZE + 64, ..Default::default() },
        app,
    );
    (server, logic)
}

#[test]
fn getpage_offloads_when_lsn_fresh_enough() {
    let (mut server, _) = build_page_server(32);
    let mut client = ClientConn::new(tuple(1433));
    let msg = NetMsg {
        msg_id: 1,
        requests: (0..8u64).map(|p| AppRequest::GetPage { page_id: p, lsn: 1 }).collect(),
    };
    let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(5)).unwrap();
    assert_eq!(resps.len(), 8);
    for (resp, req) in resps.iter().zip(&msg.requests) {
        let AppRequest::GetPage { page_id, .. } = req else { unreachable!() };
        assert_eq!(resp.status, 0);
        assert_eq!(resp.payload.len(), PAGE_SIZE);
        assert_eq!(u64::from_le_bytes(resp.payload[..8].try_into().unwrap()), *page_id);
    }
    assert_eq!(server.director.reqs_offloaded, 8);
    assert_eq!(server.director.reqs_to_host, 0);
}

#[test]
fn getpage_too_new_lsn_bounces_to_host_and_fails_cleanly() {
    let (mut server, _) = build_page_server(8);
    let mut client = ClientConn::new(tuple(1433));
    // Requested LSN 99 > applied LSN 1: the predicate must not offload
    // (cached lsn < requested), and the host rejects it (page behind).
    let msg = NetMsg { msg_id: 2, requests: vec![AppRequest::GetPage { page_id: 3, lsn: 99 }] };
    let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(5)).unwrap();
    assert_eq!(server.director.reqs_offloaded, 0);
    assert_eq!(server.director.reqs_to_host, 1);
    assert_eq!(resps[0].status, 1, "host must refuse a page behind the LSN");
}

#[test]
fn log_replay_refreshes_page_and_dpu_serves_new_lsn() {
    let (mut server, _) = build_page_server(8);
    // Replay a log record for page 5 at LSN 7.
    server.app.replay_log(5, 7).unwrap();
    let mut client = ClientConn::new(tuple(1433));
    // Request at LSN 7: the write-back re-cached the page with LSN 7 →
    // offloadable, and the payload must carry the new LSN.
    let msg = NetMsg { msg_id: 3, requests: vec![AppRequest::GetPage { page_id: 5, lsn: 7 }] };
    let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(5)).unwrap();
    assert_eq!(resps[0].status, 0);
    let lsn = u64::from_le_bytes(resps[0].payload[8..16].try_into().unwrap());
    assert_eq!(lsn, 7);
    assert_eq!(server.director.reqs_offloaded, 1);
}

fn build_kv(n_keys: u64) -> DisaggregatedServer<MiniFaster> {
    let idevice = FileId(1);
    let logic = Arc::new(FasterOffload { idevice_file: idevice });
    let storage =
        StorageServer::build(StorageServerConfig::default(), Some(logic.clone())).unwrap();
    let fe = storage.front_end();
    let dir = fe.create_directory("kv").unwrap();
    let file = fe.create_file(dir, "idevice").unwrap();
    assert_eq!(file.id, idevice);
    let group = fe.create_poll().unwrap();
    let mut kv = MiniFaster::new(fe, file, group, 4 << 10).with_cache(storage.cache.clone());
    for k in 0..n_keys {
        kv.upsert(k, format!("value-{k}-v1").into_bytes()).unwrap();
    }
    kv.flush().unwrap();
    DisaggregatedServer::new(
        storage,
        logic,
        AppSignature::server_port(6379),
        OffloadEngineConfig::default(),
        kv,
    )
}

fn kv_value(payload: &[u8]) -> &[u8] {
    // DPU path returns the whole record (header + value); host path the
    // bare value.
    if payload.len() > dds::apps::faster::REC_HEADER
        && u32::from_le_bytes(payload[8..12].try_into().unwrap()) as usize
            == payload.len() - dds::apps::faster::REC_HEADER
    {
        &payload[dds::apps::faster::REC_HEADER..]
    } else {
        payload
    }
}

#[test]
fn kv_gets_offload_after_flush() {
    let mut server = build_kv(100);
    assert_eq!(server.storage.cache.len(), 100, "flush must cache every record");
    let mut client = ClientConn::new(tuple(6379));
    let msg = NetMsg {
        msg_id: 1,
        requests: (0..10u64).map(|k| AppRequest::KvGet { key: k * 7 }).collect(),
    };
    let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(5)).unwrap();
    for (resp, req) in resps.iter().zip(&msg.requests) {
        let AppRequest::KvGet { key } = req else { unreachable!() };
        assert_eq!(resp.status, 0);
        assert_eq!(kv_value(&resp.payload), format!("value-{key}-v1").as_bytes());
    }
    assert_eq!(server.director.reqs_offloaded, 10);
}

#[test]
fn rmw_invalidates_and_remote_read_sees_new_value() {
    let mut server = build_kv(50);
    // RMW key 21 on the host: bumps to v2 in the mutable tail and must
    // invalidate the DPU entry.
    server
        .app
        .rmw(21, |v| {
            let s = String::from_utf8(v.clone()).unwrap().replace("-v1", "-v2");
            *v = s.into_bytes();
        })
        .unwrap();
    assert!(server.storage.cache.get(21).is_none(), "RMW must invalidate the key");

    let mut client = ClientConn::new(tuple(6379));
    let msg = NetMsg {
        msg_id: 1,
        requests: vec![AppRequest::KvGet { key: 21 }, AppRequest::KvGet { key: 22 }],
    };
    let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(5)).unwrap();
    // Key 21: host path, NEW value. Key 22: DPU path, old value.
    assert_eq!(kv_value(&resps[0].payload), b"value-21-v2");
    assert_eq!(kv_value(&resps[1].payload), b"value-22-v1");
    assert_eq!(server.director.reqs_offloaded, 1);
    assert_eq!(server.director.reqs_to_host, 1);
}

#[test]
fn missing_key_errors_via_host() {
    let mut server = build_kv(10);
    let mut client = ClientConn::new(tuple(6379));
    let msg = NetMsg { msg_id: 1, requests: vec![AppRequest::KvGet { key: 12345 }] };
    let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(5)).unwrap();
    assert_eq!(resps[0].status, 1);
    assert_eq!(server.director.reqs_to_host, 1);
}

#[test]
fn upsert_then_flush_recaches_new_version() {
    let mut server = build_kv(10);
    // Upsert key 3 (disk → invalidate, tail holds v2), then flush →
    // cache-on-write re-caches the NEW location.
    server.app.upsert(3, b"value-3-v2".to_vec()).unwrap();
    assert!(server.storage.cache.get(3).is_none());
    server.app.flush().unwrap();
    assert!(server.storage.cache.get(3).is_some(), "flush re-caches");

    let mut client = ClientConn::new(tuple(6379));
    let msg = NetMsg { msg_id: 9, requests: vec![AppRequest::KvGet { key: 3 }] };
    let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(5)).unwrap();
    assert_eq!(kv_value(&resps[0].payload), b"value-3-v2");
    assert_eq!(server.director.reqs_offloaded, 1, "served by the DPU");
}
