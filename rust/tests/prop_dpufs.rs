//! Property tests for the DPU file system + durability plane
//! (hand-rolled generators — no proptest offline; seeds printed in
//! assertion messages):
//!
//! * seeded random op sequences (create/delete/write/grow/remove-dir)
//!   model-checked against in-memory maps, with the bitmap and
//!   file-mapping invariants asserted after **every** op;
//! * `mount(persist(fs)) ≡ model` at rolling checkpoints — a fresh
//!   mount of the synced device equals both the live fs and the model,
//!   including file bytes read back;
//! * mounting is idempotent and write-free on a cleanly synced image.

use std::collections::HashMap;
use std::sync::Arc;

use dds::dpufs::{DirId, DpuFs, FileId, FsConfig, FsError, RESERVED_SEGMENTS};
use dds::sim::Rng;
use dds::ssd::Ssd;

const SEG: u64 = 1 << 16; // 64 KiB segments
const SSD_BYTES: u64 = 8 << 20; // 128 segments

fn cfg() -> FsConfig {
    FsConfig { segment_size: SEG }
}

struct ModelFile {
    dir: DirId,
    name: String,
    size: u64,
    /// Bytes `[0, data.len())` are defined (written contiguously from
    /// 0); `size` may extend further via `ensure_size`, where content
    /// is unspecified (recycled segments) and never compared.
    data: Vec<u8>,
}

#[derive(Default)]
struct Model {
    dirs: HashMap<DirId, String>,
    files: HashMap<FileId, ModelFile>,
}

/// Bitmap + file-mapping invariants, asserted after every op.
fn assert_invariants(fs: &DpuFs, model: &Model, ctx: &str) {
    let mut seen = std::collections::HashSet::new();
    let mut total = 0usize;
    for (&id, mf) in &model.files {
        let meta = fs.file_meta(id).unwrap_or_else(|e| panic!("{ctx}: file {id:?}: {e}"));
        assert_eq!(meta.size, mf.size, "{ctx}: size of {id:?}");
        assert_eq!(
            meta.segments.len() as u64,
            mf.size.div_ceil(SEG),
            "{ctx}: mapping length of {id:?}"
        );
        for &s in &meta.segments {
            assert!(
                (s as usize) >= RESERVED_SEGMENTS && (s as usize) < fs.num_segments(),
                "{ctx}: segment {s} reserved or out of range"
            );
            assert!(seen.insert(s), "{ctx}: segment {s} double-allocated");
            total += 1;
        }
    }
    assert_eq!(
        fs.free_segments(),
        fs.num_segments() - RESERVED_SEGMENTS - total,
        "{ctx}: bitmap accounting"
    );
    assert_eq!(fs.list_dirs().len(), model.dirs.len(), "{ctx}: dir count");
}

/// Full equality of a (re)mounted fs against the model, bytes included.
fn assert_mount_matches(mounted: &DpuFs, model: &Model, ctx: &str) {
    let dirs: HashMap<DirId, String> =
        mounted.list_dirs().into_iter().map(|(d, n)| (d, n.to_string())).collect();
    assert_eq!(dirs, model.dirs, "{ctx}: dirs");
    assert_invariants(mounted, model, ctx);
    for (&id, mf) in &model.files {
        let meta = mounted.file_meta(id).unwrap();
        assert_eq!((meta.dir, meta.name.as_str()), (mf.dir, mf.name.as_str()), "{ctx}: {id:?}");
        if !mf.data.is_empty() {
            let mut out = vec![0u8; mf.data.len()];
            mounted.read(id, 0, &mut out).unwrap_or_else(|e| panic!("{ctx}: read {id:?}: {e}"));
            assert_eq!(out, mf.data, "{ctx}: bytes of {id:?}");
        }
    }
}

#[test]
fn dpufs_ops_model_checked_and_mount_roundtrips() {
    for seed in 1..=6u64 {
        let mut rng = Rng::new(seed);
        let ssd = Arc::new(Ssd::new(SSD_BYTES, 512));
        let mut fs = DpuFs::format(ssd.clone(), cfg()).unwrap();
        let mut model = Model::default();
        let mut step_names = 0usize;

        for step in 0..150 {
            let ctx = format!("seed {seed} step {step}");
            match rng.next_range(12) {
                0..=1 => {
                    step_names += 1;
                    let name = format!("d{step_names}");
                    let id = fs.create_directory(&name).unwrap();
                    model.dirs.insert(id, name);
                }
                2 => {
                    // Duplicate directory name must be refused and
                    // change nothing.
                    if let Some(name) = model.dirs.values().next().cloned() {
                        assert_eq!(
                            fs.create_directory(&name),
                            Err(FsError::AlreadyExists),
                            "{ctx}: duplicate dir admitted"
                        );
                    }
                }
                3..=5 => {
                    let Some(&dir) = model.dirs.keys().min() else { continue };
                    step_names += 1;
                    let name = format!("f{step_names}");
                    let id = fs.create_file(dir, &name).unwrap();
                    model.files.insert(id, ModelFile { dir, name, size: 0, data: Vec::new() });
                }
                6..=8 => {
                    // Write contiguously from within the defined prefix
                    // so every byte below `data.len()` stays defined.
                    let Some(&id) = model.files.keys().min() else { continue };
                    let written = model.files[&id].data.len() as u64;
                    let off = rng.next_range(written + 1);
                    let len = 1 + rng.next_range(3000) as usize;
                    let bytes: Vec<u8> =
                        (0..len).map(|j| ((off as usize + j + step) % 251) as u8).collect();
                    fs.write(id, off, &bytes).unwrap_or_else(|e| panic!("{ctx}: write: {e}"));
                    let mf = model.files.get_mut(&id).unwrap();
                    if mf.data.len() < off as usize + len {
                        mf.data.resize(off as usize + len, 0);
                    }
                    mf.data[off as usize..off as usize + len].copy_from_slice(&bytes);
                    mf.size = mf.size.max(off + len as u64);
                }
                9 => {
                    // Grow without writing (mapping extends, bytes
                    // unspecified past the written prefix).
                    let Some(&id) = model.files.keys().max() else { continue };
                    let grow = model.files[&id].size + 1 + rng.next_range(16 << 10);
                    fs.ensure_size(id, grow).unwrap_or_else(|e| panic!("{ctx}: grow: {e}"));
                    let mf = model.files.get_mut(&id).unwrap();
                    mf.size = mf.size.max(grow);
                }
                10 => {
                    let Some(&id) = model.files.keys().max() else { continue };
                    fs.delete_file(id).unwrap();
                    model.files.remove(&id);
                    assert_eq!(fs.read(id, 0, &mut [0u8; 1]), Err(FsError::NoSuchFile), "{ctx}");
                }
                _ => {
                    // Remove a directory: must refuse while non-empty.
                    let Some(&dir) = model.dirs.keys().max() else { continue };
                    let occupied = model.files.values().any(|f| f.dir == dir);
                    let r = fs.remove_directory(dir);
                    if occupied {
                        assert_eq!(r, Err(FsError::DirNotEmpty), "{ctx}");
                    } else {
                        assert_eq!(r, Ok(()), "{ctx}");
                        model.dirs.remove(&dir);
                    }
                }
            }
            assert_invariants(&fs, &model, &ctx);

            if step % 30 == 29 {
                // Checkpoint: persist, then a fresh mount must equal
                // the model — twice (mounting a clean image is
                // idempotent and write-free).
                fs.sync_metadata().unwrap_or_else(|e| panic!("{ctx}: sync: {e}"));
                let (m1, r1) = DpuFs::mount_with_report(ssd.clone(), cfg())
                    .unwrap_or_else(|e| panic!("{ctx}: mount: {e}"));
                assert!(!r1.rolled_forward && !r1.repaired_superblock, "{ctx}: clean image");
                assert_eq!(r1.recovered_seq, fs.metadata_seq(), "{ctx}: recovered seq");
                assert_mount_matches(&m1, &model, &ctx);
                drop(m1);
                let (m2, r2) = DpuFs::mount_with_report(ssd.clone(), cfg()).unwrap();
                assert_eq!(r2, r1, "{ctx}: mount not idempotent");
                assert_mount_matches(&m2, &model, &format!("{ctx} (second mount)"));
            }
        }
    }
}

/// Sequence numbers are monotonic across sync/mount cycles, and the
/// journal wrap keeps recovering cleanly over many syncs.
#[test]
fn many_syncs_wrap_the_journal_and_keep_recovering() {
    // 64 KiB journal segment: ~120 B per sync ⇒ the cursor wraps every
    // ~500 syncs, several times over this run.
    let ssd = Arc::new(Ssd::new(1 << 20, 512));
    let cfg = FsConfig { segment_size: 1 << 16 };
    let mut fs = DpuFs::format(ssd.clone(), cfg.clone()).unwrap();
    let d = fs.create_directory("d").unwrap();
    fs.create_file(d, "f").unwrap();
    // Far more syncs than one journal segment holds: the append cursor
    // must wrap (often) and every remount must still land on the exact
    // last committed sequence.
    let mut last_seq = fs.metadata_seq();
    for round in 0..2000 {
        fs.sync_metadata().unwrap();
        assert_eq!(fs.metadata_seq(), last_seq + 1, "round {round}: seq must be monotonic");
        last_seq += 1;
        if round % 400 == 0 {
            let (m, r) = DpuFs::mount_with_report(ssd.clone(), cfg.clone()).unwrap();
            assert_eq!(r.recovered_seq, last_seq, "round {round}");
            assert_eq!(m.list_dirs().len(), 1);
        }
    }
}
