//! End-to-end chaos suite: the named fault scenarios of
//! `dds::fault::scenario` against the threaded sharded server.
//!
//! Every scenario is fully seeded. To reproduce a CI run, set
//! `DDS_CHAOS_SEED=<seed>` (each test prints the seed it used).

use dds::fault::{cache_chaos, crash_recovery, data_crash, run_scenario, FaultAction, Scenario};

#[path = "chaos_common.rs"]
mod chaos_common;
use chaos_common::chaos_seed;

#[test]
fn nominal_scenario_is_clean() {
    let sc = Scenario::nominal(chaos_seed());
    let r = run_scenario(&sc).expect("nominal scenario");
    assert_eq!(r.ok, sc.total_requests(), "every response OK and byte-exact");
    assert_eq!(r.err, 0);
    assert!(r.schedule.is_empty(), "no faults configured, none injected: {:?}", r.schedule);
}

/// The acceptance-criterion scenario: with engine failure injected on
/// one shard, a full request batch completes with byte-exact responses
/// via the host slow path.
#[test]
fn engine_failover_completes_byte_exact_on_host_slow_path() {
    let sc = Scenario::engine_failover(chaos_seed());
    let r = run_scenario(&sc).expect("engine_failover scenario");
    assert_eq!(r.err, 0, "failover must be client-invisible (no errors)");
    assert_eq!(r.ok, sc.total_requests(), "every read byte-exact despite the dead engine");
    // Shard 0's engine died before round 1: all its remaining rounds
    // rerouted through the host file service.
    let failed_over = (sc.batch * (sc.rounds - 1)) as u64;
    assert_eq!(r.per_shard[0].reqs_failed_over, failed_over);
    assert_eq!(r.per_shard[1].reqs_failed_over, 0, "healthy shard untouched");
    assert_eq!(r.stats.reqs_failed_over, failed_over);
    assert!(
        r.schedule.iter().any(|e| e.action == FaultAction::EngineFail),
        "scheduled failure must appear in the schedule"
    );
}

#[test]
fn engine_restart_resumes_offloading() {
    let sc = Scenario::engine_restart(chaos_seed());
    let r = run_scenario(&sc).expect("engine_restart scenario");
    assert_eq!(r.err, 0);
    assert_eq!(r.ok, sc.total_requests());
    // Failed for rounds 1..4 only.
    assert_eq!(r.per_shard[0].reqs_failed_over, (sc.batch * 3) as u64);
    let actions: Vec<_> = r.schedule.iter().map(|e| e.action).collect();
    assert!(actions.contains(&FaultAction::EngineFail));
    assert!(actions.contains(&FaultAction::EngineRestore));
}

#[test]
fn ssd_chaos_is_bounded_and_byte_exact() {
    let sc = Scenario::ssd_chaos(chaos_seed());
    let r = run_scenario(&sc).expect("ssd_chaos scenario");
    // run_scenario already enforced byte-exactness and bounded
    // completion; check the error accounting against the schedule.
    assert_eq!(r.ok + r.err, sc.total_requests());
    let lethal = r.ssd_fail_or_drop_events() as u64;
    assert!(
        r.err >= lethal,
        "every injected fail/drop must surface as an ERR (events={lethal}, err={})",
        r.err
    );
    assert!(!r.schedule.is_empty(), "chaos probabilities must fire over this many ops");
    // Lost completions were recovered by a pending-timeout somewhere.
    if r.schedule.iter().any(|e| e.action == FaultAction::SsdDrop) {
        assert!(r.stats.reqs_timed_out > 0, "drops surface via the engine pending-timeout");
    }
}

#[test]
fn wire_chaos_recovers_to_lossless_byte_exact_delivery() {
    let sc = Scenario::wire_chaos(chaos_seed());
    let r = run_scenario(&sc).expect("wire_chaos scenario");
    assert_eq!(r.err, 0, "transport faults must be fully recovered, not surfaced");
    assert_eq!(r.ok, sc.total_requests());
    assert!(!r.schedule.is_empty(), "wire chaos must have injected something");
}

#[test]
fn group_stall_delays_but_loses_nothing() {
    let sc = Scenario::group_stall(chaos_seed());
    let r = run_scenario(&sc).expect("group_stall scenario");
    assert_eq!(r.err, 0);
    assert_eq!(r.ok, sc.total_requests());
    // All engines were failed from round 0, so every request crossed
    // the (stalled) poll groups.
    assert_eq!(r.stats.reqs_failed_over, sc.total_requests());
    let (_, iterations) = sc.stall_groups.unwrap();
    // Groups 1..=shards are the shard host apps; each served its full
    // stall budget (traffic after the stall forced it to elapse).
    for (g, gc) in r.group_stats.iter().enumerate().skip(1) {
        assert_eq!(gc.stalled, iterations as u64, "group {g} stall budget");
        assert_eq!(gc.delivered, gc.requests, "group {g} drained its backlog");
        assert_eq!(gc.outstanding, 0);
    }
}

/// The durability-plane scenario: a seed-chosen power cut tears one
/// device write mid-metadata-op; every later op surfaces as a clean
/// bounded error; the remount recovers exactly the committed state and
/// serves traffic again. (`crash_recovery` itself enforces the model
/// equality, allocation and counter invariants, and the post-recovery
/// write/read roundtrip — a returned report means they all held.)
#[test]
fn crash_recovery_scenario_recovers_committed_state() {
    let seed = chaos_seed();
    let r = crash_recovery(seed).expect("crash_recovery scenario");
    assert!(
        r.schedule.iter().any(|e| matches!(e.action, FaultAction::PowerCut { .. })),
        "the power cut must appear in the canonical schedule"
    );
    assert!(r.ops_failed > 0, "the torn op must surface as an error");
    assert!(
        r.recovery.recovered_seq >= 1 + r.ops_acked,
        "every acked metadata op must survive the crash"
    );
    // Same seed ⇒ same cut point, same outcome counts, same recovery.
    let r2 = crash_recovery(seed).expect("crash_recovery replay");
    assert_eq!((r.cut_write, r.cut_bytes), (r2.cut_write, r2.cut_bytes), "cut not seeded");
    assert_eq!((r.ops_acked, r.ops_failed), (r2.ops_acked, r2.ops_failed));
    assert_eq!(r.recovery, r2.recovery, "recovery not deterministic");
    println!(
        "crash_recovery(seed={}): cut at write {} byte {}, {} acked / {} failed, \
         recovered seq {} (rolled_forward={}) with {} files in {:?}",
        r.seed,
        r.cut_write,
        r.cut_bytes,
        r.ops_acked,
        r.ops_failed,
        r.recovery.recovered_seq,
        r.recovery.rolled_forward,
        r.recovered_files,
        r.elapsed
    );
}

/// The data-durability scenario: multi-tenant durable WRITE load with
/// `durable_data` on, a seed-chosen power cut torn mid-write, a
/// concurrent dead-device burst, then a remount. (`data_crash` itself
/// enforces the torn-write contract — every acked WRITE byte-exact,
/// the torn op all-old or all-new, no leaked shadow segments, the
/// control-plane recovery report matching the mount's, and a durable
/// post-recovery roundtrip — a returned report means they all held.)
#[test]
fn data_crash_scenario_keeps_acked_writes_byte_exact() {
    let seed = chaos_seed();
    let r = data_crash(seed).expect("data_crash scenario");
    assert!(
        r.schedule.iter().any(|e| matches!(e.action, FaultAction::PowerCut { .. })),
        "the power cut must appear in the canonical schedule"
    );
    assert!(r.writes_failed > 0, "the torn WRITE must surface as an error");
    // A seed may legally cut the very first device write (nothing acked
    // yet); when WRITEs did ack, their remap records must have replayed.
    if r.writes_acked > 0 {
        assert!(
            r.recovery.remaps_applied > 0,
            "{} WRITEs acked but no remap replayed (cut at write {} byte {})",
            r.writes_acked,
            r.cut_write,
            r.cut_bytes
        );
    }
    println!(
        "data_crash(seed={}): cut at write {} byte {}, {} acked / {} failed \
         (ambiguous tenant {:?}), {} remaps replayed, {} extents quarantined, \
         sizes {:?} in {:?}",
        r.seed,
        r.cut_write,
        r.cut_bytes,
        r.writes_acked,
        r.writes_failed,
        r.ambiguous_tenant,
        r.recovery.remaps_applied,
        r.recovery.quarantined_extents,
        r.recovered_sizes,
        r.elapsed
    );
}

/// The cache-coherence crash scenario: the read-cache tier in the loop
/// under host-SSD faults plus a power cut, under `durable_data`.
/// (`cache_chaos` itself enforces the coherence contract — every OK
/// READ byte-equals the last acked WRITE whether the tier or the SSD
/// served it, the crash leaks no pooled buffers through the tier, the
/// remount cold-starts the tier empty, and the device carries the
/// committed image modulo the one torn op — a returned report means
/// they all held.)
#[test]
fn cache_chaos_tier_stays_coherent_across_faults_and_power_cut() {
    let seed = chaos_seed();
    let r = cache_chaos(seed).expect("cache_chaos scenario");
    assert!(
        r.schedule.iter().any(|e| matches!(e.action, FaultAction::PowerCut { .. })),
        "the power cut must appear in the canonical schedule"
    );
    assert!(r.ops_failed > 0, "the cut must fail at least the op it tears");
    assert!(r.pre_cut.hits > 0, "the tier never served a read before the cut");
    assert!(r.pre_cut.invalidations > 0, "acked WRITEs never invalidated the tier");
    assert_eq!(r.post_remount.entries, 1, "post-crash exercise caches its read");
    println!(
        "cache_chaos(seed={}): cut at write {}, {} acked / {} reads OK / {} failed, \
         pre-cut tier {:?}, {} remaps replayed, post-remount tier {:?} in {:?}",
        r.seed,
        r.cut_write,
        r.writes_acked,
        r.reads_ok,
        r.ops_failed,
        r.pre_cut,
        r.recovery.remaps_applied,
        r.post_remount,
        r.elapsed
    );
}

/// The CPU-plane scenario: adaptive pumps park between batches while
/// SSD chaos, an engine failure and a group stall rage — bounded
/// completion and byte-exactness must survive every park point, and
/// after quiesce the pumps must actually be parked (run_scenario
/// enforces the park/productive deltas against the CpuLedger; a
/// returned report means they held).
#[test]
fn idle_wake_parks_pumps_and_stays_bounded() {
    let sc = Scenario::idle_wake(chaos_seed());
    let r = run_scenario(&sc).expect("idle_wake scenario");
    assert_eq!(r.ok + r.err, sc.total_requests(), "bounded completion");
    assert!(r.ok > 0, "chaos must not kill everything");
    // Ledger shape: every pump parked, and at least one park ended in
    // a doorbell/channel wake (the wake graph actually fired).
    assert!(r.cpu.iter().all(|c| c.parks > 0), "every pump must have parked: {:?}", r.cpu);
    assert!(r.cpu.iter().any(|c| c.wakes > 0), "no pump ever woke by a ring: {:?}", r.cpu);
    println!(
        "idle_wake(seed={}): ok={} err={} cpu={:?} in {:?}",
        r.seed, r.ok, r.err, r.cpu, r.elapsed
    );
}

#[test]
fn everything_at_once_survives() {
    let sc = Scenario::everything(chaos_seed());
    let r = run_scenario(&sc).expect("everything scenario");
    assert_eq!(r.ok + r.err, sc.total_requests());
    assert!(r.ok > 0, "some requests must still succeed under combined chaos");
    assert!(!r.schedule.is_empty());
    println!(
        "everything(seed={}): ok={} err={} injections={} in {:?}",
        r.seed,
        r.ok,
        r.err,
        r.schedule.len(),
        r.elapsed
    );
}
