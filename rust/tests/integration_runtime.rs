//! Integration: the L1↔L3 bridge — AOT Pallas kernels executed from
//! rust via PJRT against the rust-native implementations.
//!
//! Requires `make artifacts`; every test skips (with a message) when
//! artifacts are absent so `cargo test` works standalone.

use dds::cache::{CacheItem, CuckooCache};
use dds::runtime::{checksum_ref, KernelRuntime, CHECKSUM_BATCH, CHECKSUM_PAGE, PREDICATE_BATCH, PREDICATE_SLOTS};
use dds::sim::Rng;

fn runtime() -> Option<KernelRuntime> {
    let dir = KernelRuntime::artifacts_dir();
    let mut rt = KernelRuntime::cpu().ok()?;
    match rt.load_dir(&dir) {
        Ok(names) if !names.is_empty() => Some(rt),
        _ => {
            eprintln!("SKIP: no artifacts in {dir:?} — run `make artifacts`");
            None
        }
    }
}

fn table_with(entries: usize, seed: u64) -> (CuckooCache, Vec<(u64, u64)>) {
    let cache = CuckooCache::new(PREDICATE_SLOTS / 2);
    let mut rng = Rng::new(seed);
    let mut placed = Vec::new();
    for _ in 0..entries {
        let key = rng.next_range(1 << 48) + 1;
        let lsn = rng.next_range(10_000) + 1;
        if cache.insert(key, CacheItem::new(lsn, 7, key * 8192, 8192)) {
            placed.push((key, lsn));
        }
    }
    (cache, placed)
}

#[test]
fn predicate_kernel_agrees_with_scalar_cuckoo() {
    let Some(rt) = runtime() else { return };
    for seed in [1u64, 2, 3] {
        let (cache, placed) = table_with(PREDICATE_SLOTS / 4, seed);
        let dense = cache.export_dense();
        assert_eq!(dense.keys.len(), PREDICATE_SLOTS);
        let mut rng = Rng::new(seed * 31);
        let keys: Vec<u64> = (0..PREDICATE_BATCH)
            .map(|i| match i % 3 {
                0 => rng.next_range(1 << 48) + (1 << 55), // miss
                _ => placed[rng.next_range(placed.len() as u64) as usize].0,
            })
            .collect();
        let lsns: Vec<u64> = keys.iter().map(|_| rng.next_range(12_000)).collect();
        let hits = rt.predicate_batch(&dense, &keys, &lsns).unwrap();
        for (i, hit) in hits.iter().enumerate() {
            let scalar = cache.get(keys[i]).filter(|item| item.a >= lsns[i]);
            match (hit.offload, scalar) {
                (true, Some(item)) => {
                    assert_eq!((hit.a, hit.b, hit.c, hit.d), (item.a, item.b, item.c, item.d));
                }
                (false, None) => {}
                // Kernel may miss chained entries (dense export skips
                // chains) — conservative toward the host, never wrong.
                (false, Some(_)) => {}
                (true, None) => panic!("kernel offloaded a request rust rejects (i={i})"),
            }
        }
    }
}

#[test]
fn predicate_kernel_partial_batch_padding() {
    let Some(rt) = runtime() else { return };
    let (cache, placed) = table_with(100, 9);
    let dense = cache.export_dense();
    // A batch smaller than the AOT shape: padding must not fabricate
    // offloads.
    let keys: Vec<u64> = placed.iter().take(5).map(|(k, _)| *k).collect();
    let lsns: Vec<u64> = placed.iter().take(5).map(|(_, l)| *l).collect();
    let hits = rt.predicate_batch(&dense, &keys, &lsns).unwrap();
    assert_eq!(hits.len(), 5);
    for hit in &hits {
        assert!(hit.offload, "exact-LSN request must offload");
    }
}

#[test]
fn predicate_kernel_rejects_wrong_table_size() {
    let Some(rt) = runtime() else { return };
    let cache = CuckooCache::new(64); // wrong dense size
    let dense = cache.export_dense();
    assert!(rt.predicate_batch(&dense, &[1], &[1]).is_err());
}

#[test]
fn checksum_kernel_matches_rust_reference() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(4);
    let pages: Vec<u8> =
        (0..CHECKSUM_BATCH * CHECKSUM_PAGE).map(|_| rng.next_range(256) as u8).collect();
    let sums = rt.checksum_batch(&pages).unwrap();
    for (i, page) in pages.chunks(CHECKSUM_PAGE).enumerate() {
        assert_eq!(sums[i], checksum_ref(page), "page {i}");
    }
}

#[test]
fn checksum_kernel_detects_single_byte_flip() {
    let Some(rt) = runtime() else { return };
    let mut pages = vec![3u8; CHECKSUM_BATCH * CHECKSUM_PAGE];
    let base = rt.checksum_batch(&pages).unwrap();
    pages[5 * CHECKSUM_PAGE + 1234] ^= 0x40;
    let flipped = rt.checksum_batch(&pages).unwrap();
    for i in 0..CHECKSUM_BATCH {
        if i == 5 {
            assert_ne!(base[i], flipped[i]);
        } else {
            assert_eq!(base[i], flipped[i]);
        }
    }
}
