//! Integration: the unified storage path (§4) end to end —
//! host file library ⇄ DMA rings ⇄ DPU file service ⇄ file system ⇄ SSD.

use std::sync::Arc;
use std::time::Duration;

use dds::coordinator::{StorageServer, StorageServerConfig};
use dds::dpufs::{DpuFs, FsConfig};
use dds::filelib::LibError;
use dds::fileservice::FileServiceConfig;

fn server(cfg: StorageServerConfig) -> StorageServer {
    StorageServer::build(cfg, None).expect("build storage server")
}

fn wait_all(group: &dds::filelib::PollGroup, mut ids: Vec<u64>) -> Vec<dds::filelib::CompletionEvent> {
    let mut out = Vec::new();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while !ids.is_empty() {
        assert!(std::time::Instant::now() < deadline, "timed out waiting for completions");
        for ev in group.poll_wait(Duration::from_millis(50)) {
            ids.retain(|&id| id != ev.req_id);
            out.push(ev);
        }
    }
    out
}

#[test]
fn write_read_roundtrip_through_rings() {
    let s = server(StorageServerConfig::default());
    let fe = s.front_end();
    let dir = fe.create_directory("t").unwrap();
    let mut f = fe.create_file(dir, "data").unwrap();
    let g = fe.create_poll().unwrap();
    fe.poll_add(&mut f, &g);

    let payload: Vec<u8> = (0..50_000).map(|i| (i % 251) as u8).collect();
    let w = fe.write_file(&f, 1234, &payload).unwrap();
    let evs = wait_all(&g, vec![w]);
    assert!(evs[0].ok);

    let r = fe.read_file(&f, 1234, payload.len() as u32).unwrap();
    let evs = wait_all(&g, vec![r]);
    assert!(evs[0].ok);
    assert_eq!(evs[0].data, payload);
}

#[test]
fn many_outstanding_requests_ordered_and_complete() {
    let s = server(StorageServerConfig::default());
    let fe = s.front_end();
    let dir = fe.create_directory("t").unwrap();
    let mut f = fe.create_file(dir, "data").unwrap();
    let g = fe.create_poll().unwrap();
    fe.poll_add(&mut f, &g);

    // Preallocate and fill.
    let n = 200u64;
    let io = 512u32;
    fe.ensure_size(&f, n * io as u64).unwrap();
    let mut ids = Vec::new();
    for i in 0..n {
        let data = vec![(i % 256) as u8; io as usize];
        loop {
            match fe.write_file(&f, i * io as u64, &data) {
                Ok(id) => {
                    ids.push(id);
                    break;
                }
                Err(LibError::RingFull) => {
                    let _ = g.poll_wait(Duration::from_millis(5));
                    ids.retain(|_| true);
                    // Drain bookkeeping: wait_all at the end picks up rest.
                    for ev in g.poll_wait(Duration::from_millis(5)) {
                        ids.retain(|&x| x != ev.req_id);
                    }
                }
                Err(e) => panic!("{e}"),
            }
        }
    }
    wait_all(&g, ids);

    // Read everything back, many outstanding.
    let mut ids = Vec::new();
    for i in 0..n {
        loop {
            match fe.read_file(&f, i * io as u64, io) {
                Ok(id) => {
                    ids.push((i, id));
                    break;
                }
                Err(LibError::RingFull) => {
                    std::thread::yield_now();
                    for _ev in g.poll_wait(Duration::from_millis(5)) {}
                }
                Err(e) => panic!("{e}"),
            }
        }
    }
    // Collect and verify each read's content matches its offset.
    let mut remaining: std::collections::HashMap<u64, u64> = ids.iter().map(|&(i, id)| (id, i)).collect();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while !remaining.is_empty() {
        assert!(std::time::Instant::now() < deadline, "timeout");
        for ev in g.poll_wait(Duration::from_millis(50)) {
            if let Some(i) = remaining.remove(&ev.req_id) {
                assert!(ev.ok);
                assert!(ev.data.iter().all(|&b| b == (i % 256) as u8), "data mismatch at {i}");
            }
        }
    }
}

#[test]
fn concurrent_host_threads_share_one_group() {
    let s = server(StorageServerConfig::default());
    let fe = Arc::new(s.front_end());
    let dir = fe.create_directory("t").unwrap();
    let mut f = fe.create_file(dir, "data").unwrap();
    let g = fe.create_poll().unwrap();
    fe.poll_add(&mut f, &g);
    fe.ensure_size(&f, 1 << 20).unwrap();
    let f = Arc::new(f);

    // 4 producer threads issue interleaved writes; a collector thread
    // polls the shared group (multi-producer request ring +
    // multi-consumer response ring).
    let mut handles = Vec::new();
    let issued = Arc::new(std::sync::Mutex::new(std::collections::HashSet::new()));
    for tix in 0..4u64 {
        let fe = fe.clone();
        let f = f.clone();
        let g = g.clone();
        let issued = issued.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..50u64 {
                let off = (tix * 50 + i) * 1024;
                let data = vec![(tix + 1) as u8; 1024];
                loop {
                    match fe.write_file(&f, off, &data) {
                        Ok(id) => {
                            issued.lock().unwrap().insert(id);
                            break;
                        }
                        Err(LibError::RingFull) => std::thread::yield_now(),
                        Err(e) => panic!("{e}"),
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let mut pending: std::collections::HashSet<u64> = issued.lock().unwrap().clone();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while !pending.is_empty() {
        assert!(std::time::Instant::now() < deadline, "timeout");
        for ev in g.poll_wait(Duration::from_millis(50)) {
            assert!(ev.ok);
            pending.remove(&ev.req_id);
        }
    }
}

#[test]
fn gathered_write_scattered_read() {
    let s = server(StorageServerConfig::default());
    let fe = s.front_end();
    let dir = fe.create_directory("t").unwrap();
    let mut f = fe.create_file(dir, "gs").unwrap();
    let g = fe.create_poll().unwrap();
    fe.poll_add(&mut f, &g);

    let a = vec![1u8; 100];
    let b = vec![2u8; 200];
    let c = vec![3u8; 50];
    let w = fe.gather_write(&f, 0, &[&a, &b, &c]).unwrap();
    wait_all(&g, vec![w]);

    let r = fe.scatter_read(&f, 0, &[100, 200, 50]).unwrap();
    let evs = wait_all(&g, vec![r]);
    let parts = evs[0].scatter();
    assert_eq!(parts[0], &a[..]);
    assert_eq!(parts[1], &b[..]);
    assert_eq!(parts[2], &c[..]);
}

#[test]
fn out_of_range_read_reports_error_not_hang() {
    let s = server(StorageServerConfig::default());
    let fe = s.front_end();
    let dir = fe.create_directory("t").unwrap();
    let mut f = fe.create_file(dir, "small").unwrap();
    let g = fe.create_poll().unwrap();
    fe.poll_add(&mut f, &g);
    let w = fe.write_file(&f, 0, &[1u8; 100]).unwrap();
    wait_all(&g, vec![w]);

    let r = fe.read_file(&f, 90, 100).unwrap(); // beyond EOF
    let evs = wait_all(&g, vec![r]);
    assert!(!evs[0].ok, "out-of-range read must complete with an error");
}

#[test]
fn too_large_write_rejected_cleanly() {
    let s = server(StorageServerConfig::default());
    let fe = s.front_end();
    let dir = fe.create_directory("t").unwrap();
    let mut f = fe.create_file(dir, "big").unwrap();
    let g = fe.create_poll().unwrap();
    fe.poll_add(&mut f, &g);
    let huge = vec![0u8; 1 << 20];
    match fe.write_file(&f, 0, &huge) {
        Err(LibError::TooLarge { .. }) => {}
        other => panic!("expected TooLarge, got {other:?}"),
    }
    assert_eq!(g.in_flight(), 0, "failed issue must not leak bookkeeping");
}

#[test]
fn delivery_batching_still_delivers_everything() {
    // TailB - TailC >= batch threshold before DMA-write (§4.3).
    let mut cfg = StorageServerConfig::default();
    cfg.service = FileServiceConfig { delivery_batch: 16, ..Default::default() };
    let s = server(cfg);
    let fe = s.front_end();
    let dir = fe.create_directory("t").unwrap();
    let mut f = fe.create_file(dir, "batched").unwrap();
    let g = fe.create_poll().unwrap();
    fe.poll_add(&mut f, &g);
    fe.ensure_size(&f, 1 << 20).unwrap();
    let ids: Vec<u64> =
        (0..64u64).map(|i| fe.read_file(&f, i * 512, 512).unwrap()).collect();
    wait_all(&g, ids);
}

#[test]
fn extra_copy_mode_is_functionally_identical() {
    let mut cfg = StorageServerConfig::default();
    cfg.service = FileServiceConfig { extra_copy: true, ..Default::default() };
    let s = server(cfg);
    let fe = s.front_end();
    let dir = fe.create_directory("t").unwrap();
    let mut f = fe.create_file(dir, "copy").unwrap();
    let g = fe.create_poll().unwrap();
    fe.poll_add(&mut f, &g);
    let payload: Vec<u8> = (0..10_000).map(|i| (i % 241) as u8).collect();
    let w = fe.write_file(&f, 5, &payload).unwrap();
    wait_all(&g, vec![w]);
    let r = fe.read_file(&f, 5, payload.len() as u32).unwrap();
    let evs = wait_all(&g, vec![r]);
    assert_eq!(evs[0].data, payload);
}

#[test]
fn worker_mode_out_of_order_completions_delivered_in_order() {
    // ssd_workers > 0 → genuinely out-of-order completions; the
    // TailA/B/C staging must still deliver responses in request order
    // and nothing may be lost.
    let mut cfg = StorageServerConfig::default();
    cfg.service = FileServiceConfig { ssd_workers: 3, ..Default::default() };
    let s = server(cfg);
    let fe = s.front_end();
    let dir = fe.create_directory("t").unwrap();
    let mut f = fe.create_file(dir, "ooo").unwrap();
    let g = fe.create_poll().unwrap();
    fe.poll_add(&mut f, &g);
    fe.ensure_size(&f, 1 << 20).unwrap();
    let ids: Vec<u64> =
        (0..128u64).map(|i| fe.read_file(&f, i * 4096, 1024).unwrap()).collect();
    // Responses arrive in request order on the response ring; the
    // library hands them out as polled. Verify order by req id
    // monotonicity of the drain.
    let mut seen = Vec::new();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while seen.len() < ids.len() {
        assert!(std::time::Instant::now() < deadline, "timeout");
        for ev in g.poll_wait(Duration::from_millis(50)) {
            assert!(ev.ok);
            seen.push(ev.req_id);
        }
    }
    assert_eq!(seen, ids, "responses must be delivered in request order");
}

/// Copy-ledger acceptance: the steady-state READ hot path through the
/// whole storage path (ring intake → SSD → staging → vectored response
/// delivery) performs ZERO heap allocations and ZERO software copies —
/// every buffer request is a pool hit, and the completion view is
/// DMA-written to the host ring by reference.
#[test]
fn read_hot_path_copy_ledger_steady_state() {
    let s = server(StorageServerConfig::default());
    let fe = s.front_end();
    let dir = fe.create_directory("t").unwrap();
    let mut f = fe.create_file(dir, "ledger").unwrap();
    let g = fe.create_poll().unwrap();
    fe.poll_add(&mut f, &g);
    // Fill 1 MiB (one segment — 4 KiB-aligned reads below stay
    // single-extent, the common case the ledger contract covers).
    let file_bytes = 1u64 << 20;
    fe.ensure_size(&f, file_bytes).unwrap();
    let chunk = 64usize << 10;
    let mut ids = Vec::new();
    for off in (0..file_bytes).step_by(chunk) {
        let data: Vec<u8> = (off..off + chunk as u64).map(|i| (i % 253) as u8).collect();
        loop {
            match fe.write_file(&f, off, &data) {
                Ok(id) => {
                    ids.push(id);
                    break;
                }
                Err(LibError::RingFull) => {
                    for ev in g.poll_wait(Duration::from_millis(10)) {
                        ids.retain(|&x| x != ev.req_id);
                    }
                }
                Err(e) => panic!("{e}"),
            }
        }
    }
    wait_all(&g, ids);

    // Issue reads in waves that stay comfortably inside the service
    // pool's slot budget (each in-flight completion pins one slot; the
    // default pool has 64). "Steady state" means a working set the pool
    // covers — unbounded queue depth is legitimately allowed to spill
    // into counted heap fallbacks.
    let do_reads = |n: u64| {
        for wave in 0..n.div_ceil(16) {
            let ids: Vec<u64> = (0..16.min(n - wave * 16))
                .map(|i| {
                    let k = wave * 16 + i;
                    fe.read_file(&f, (k % 256) * 4096, 4096).unwrap()
                })
                .collect();
            let evs = wait_all(&g, ids);
            for ev in &evs {
                assert!(ev.ok);
                assert_eq!(ev.data.len(), 4096);
            }
        }
    };
    // Warm-up establishes the pool working set.
    do_reads(32);
    let before = s.buf_pool.stats();
    do_reads(96);
    let d = s.buf_pool.stats() - before;
    assert_eq!(d.fallbacks, 0, "steady-state reads never fall back to the heap");
    assert_eq!(d.heap_allocs, 0, "0 heap allocations per steady-state read");
    assert_eq!(d.bytes_copied, 0, "0 bytes memcpy'd per steady-state read");
    assert!(d.pool_hits >= 96, "completions + batch staging all served from the slab");
    assert_eq!(d.allocs, d.pool_hits, "every buffer request was a pool hit");
}

/// Buffer accounting under the straw-man: `extra_copy` stages every
/// request and completion once more — the ledger must show it.
#[test]
fn extra_copy_mode_is_visible_on_the_ledger() {
    let mut cfg = StorageServerConfig::default();
    cfg.service = FileServiceConfig { extra_copy: true, ..Default::default() };
    let s = server(cfg);
    let fe = s.front_end();
    let dir = fe.create_directory("t").unwrap();
    let mut f = fe.create_file(dir, "straw").unwrap();
    let g = fe.create_poll().unwrap();
    fe.poll_add(&mut f, &g);
    let w = fe.write_file(&f, 0, &vec![9u8; 8192]).unwrap();
    wait_all(&g, vec![w]);
    let before = s.buf_pool.stats();
    let r = fe.read_file(&f, 0, 4096).unwrap();
    let evs = wait_all(&g, vec![r]);
    assert!(evs[0].ok);
    let d = s.buf_pool.stats() - before;
    assert!(
        d.bytes_copied >= 4096,
        "straw-man copies the 4 KiB completion (got {} bytes)",
        d.bytes_copied
    );
}

#[test]
fn metadata_persists_across_remount() {
    // Build a server, write, sync metadata, then remount the same
    // device image with a fresh DpuFs and read directly.
    let s = server(StorageServerConfig::default());
    let fe = s.front_end();
    let dir = fe.create_directory("db").unwrap();
    let mut f = fe.create_file(dir, "f").unwrap();
    let g = fe.create_poll().unwrap();
    fe.poll_add(&mut f, &g);
    let payload = vec![0x5au8; 4096];
    let w = fe.write_file(&f, 8192, &payload).unwrap();
    wait_all(&g, vec![w]);
    fe.sync_metadata().unwrap();

    let ssd = s.ssd.clone();
    let fs2 = DpuFs::mount(ssd, FsConfig::default()).expect("remount");
    let mut out = vec![0u8; 4096];
    fs2.read(f.id, 8192, &mut out).unwrap();
    assert_eq!(out, payload);
}
