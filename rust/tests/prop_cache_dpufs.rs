//! Property tests: cuckoo cache table vs a HashMap model, and the DPU
//! file system vs a flat byte-array model. (Hand-rolled generators —
//! no proptest offline; seeds printed on failure.)

use std::collections::HashMap;
use std::sync::Arc;

use dds::cache::{CacheItem, CuckooCache};
use dds::dpufs::{DpuFs, FsConfig};
use dds::sim::Rng;
use dds::ssd::Ssd;

#[test]
fn cache_matches_hashmap_model() {
    for seed in 1..=15u64 {
        let mut rng = Rng::new(seed);
        let cap = 512usize;
        let table = CuckooCache::new(cap);
        let mut model: HashMap<u64, CacheItem> = HashMap::new();
        for step in 0..5000 {
            let key = 1 + rng.next_range(300);
            match rng.next_range(10) {
                0..=4 => {
                    let item = CacheItem::new(rng.next_u64(), rng.next_u64(), step, key);
                    let want_ok = model.contains_key(&key) || model.len() < cap;
                    let ok = table.insert(key, item);
                    assert_eq!(ok, want_ok, "seed {seed} step {step}: insert admission");
                    if ok {
                        model.insert(key, item);
                    }
                }
                5..=7 => {
                    assert_eq!(
                        table.get(key),
                        model.get(&key).copied(),
                        "seed {seed} step {step}: get({key})"
                    );
                }
                _ => {
                    assert_eq!(
                        table.remove(key),
                        model.remove(&key).is_some(),
                        "seed {seed} step {step}: remove({key})"
                    );
                }
            }
            assert_eq!(table.len(), model.len(), "seed {seed} step {step}: len");
        }
        // Final full-content check.
        for (k, v) in &model {
            assert_eq!(table.get(*k), Some(*v), "seed {seed}: final get({k})");
        }
    }
}

#[test]
fn cache_dense_export_covers_slot_entries() {
    for seed in 20..=25u64 {
        let mut rng = Rng::new(seed);
        let table = CuckooCache::new(1024);
        let mut keys = Vec::new();
        for _ in 0..700 {
            let k = 1 + rng.next_range(1 << 40);
            if table.insert(k, CacheItem::new(k, 1, 2, 3)) {
                keys.push(k);
            }
        }
        let dense = table.export_dense();
        let stats = table.stats();
        let exported = dense.keys.iter().filter(|&&k| k != dds::cache::EMPTY).count();
        assert_eq!(exported, stats.slot_items, "seed {seed}");
        // Every exported key sits in one of its two hash buckets and
        // carries its item.
        for (flat, &k) in dense.keys.iter().enumerate() {
            if k == dds::cache::EMPTY {
                continue;
            }
            assert_eq!(dense.items[flat * 4], k, "seed {seed}: item a");
            let item = table.get(k).expect("exported key must be present");
            assert_eq!(item.a, k);
        }
    }
}

/// Churn at capacity: evict a random resident and insert a fresh key,
/// thousands of times, with the table pinned at its capacity limit the
/// whole run — the regime that stresses cuckoo displacement chains and
/// the overflow chains. No entry may be lost, no capacity overshoot,
/// and admission control must refuse exactly when full.
#[test]
fn cache_churn_no_lost_entries_capacity_respected() {
    for seed in 60..=66u64 {
        let mut rng = Rng::new(seed);
        let cap = 256usize;
        let table = CuckooCache::new(cap);
        let mut model: HashMap<u64, CacheItem> = HashMap::new();
        let mut next_key = 1u64;
        // Fill to capacity.
        while model.len() < cap {
            let item = CacheItem::new(next_key, 0, 0, 0);
            assert!(table.insert(next_key, item), "seed {seed}: insert below capacity");
            model.insert(next_key, item);
            next_key += 1;
        }
        assert_eq!(table.len(), cap);
        // At capacity, a brand-new key must be refused…
        assert!(!table.insert(next_key, CacheItem::default()), "seed {seed}: over-admission");
        // …but updating a resident must still succeed.
        let resident = *model.keys().min().unwrap();
        assert!(table.insert(resident, CacheItem::new(9, 9, 9, 9)), "seed {seed}: update at cap");
        model.insert(resident, CacheItem::new(9, 9, 9, 9));

        // Sorted, NOT HashMap iteration order: the victim sequence must
        // be a pure function of the seed so a printed seed replays the
        // exact failing schedule.
        let mut keys: Vec<u64> = model.keys().copied().collect();
        keys.sort_unstable();
        for step in 0..20_000u64 {
            let vi = rng.next_range(keys.len() as u64) as usize;
            let victim = keys[vi];
            assert!(table.remove(victim), "seed {seed} step {step}: entry {victim} lost");
            model.remove(&victim);
            let item = CacheItem::new(next_key, step, 0, 0);
            assert!(
                table.insert(next_key, item),
                "seed {seed} step {step}: insert below capacity refused"
            );
            model.insert(next_key, item);
            keys[vi] = next_key;
            next_key += 1;
            assert!(table.len() <= cap, "seed {seed} step {step}: capacity exceeded");
            // Sampled integrity probes (full scans are the final check).
            if step % 512 == 0 {
                assert!(table.get(victim).is_none(), "seed {seed}: evicted key resurfaced");
                let probe = keys[rng.next_range(keys.len() as u64) as usize];
                assert_eq!(
                    table.get(probe),
                    model.get(&probe).copied(),
                    "seed {seed} step {step}: probe({probe})"
                );
            }
        }
        // Full sweep: every modeled entry present with its exact item,
        // accounting consistent.
        assert_eq!(table.len(), cap);
        for (k, v) in &model {
            assert_eq!(table.get(*k), Some(*v), "seed {seed}: final get({k})");
        }
        let stats = table.stats();
        assert_eq!(stats.items, cap);
        assert_eq!(stats.slot_items + stats.chain_items, cap, "seed {seed}: split accounting");
    }
}

#[test]
fn dpufs_matches_flat_file_model() {
    for seed in 1..=8u64 {
        let mut rng = Rng::new(seed);
        let ssd = Arc::new(Ssd::new(32 << 20, 512));
        let mut fs = DpuFs::format(ssd, FsConfig { segment_size: 1 << 18 }).unwrap();
        let dir = fs.create_directory("d").unwrap();
        let file = fs.create_file(dir, "f").unwrap();
        let max = 4 << 20;
        let mut model = vec![0u8; max];
        let mut written_end = 0usize;
        for step in 0..300 {
            let off = rng.next_range((max - 1) as u64) as usize;
            let len = 1 + rng.next_range(20_000.min((max - off) as u64)) as usize;
            if rng.next_f64() < 0.6 {
                let data: Vec<u8> = (0..len).map(|_| rng.next_range(256) as u8).collect();
                fs.write(file, off as u64, &data).unwrap();
                model[off..off + len].copy_from_slice(&data);
                written_end = written_end.max(off + len);
            } else if written_end > 0 {
                let off = off.min(written_end - 1);
                let len = len.min(written_end - off);
                let mut out = vec![0u8; len];
                fs.read(file, off as u64, &mut out).unwrap();
                assert_eq!(
                    out,
                    &model[off..off + len],
                    "seed {seed} step {step}: read({off},{len})"
                );
            }
        }
    }
}

#[test]
fn dpufs_extents_partition_every_request() {
    for seed in 30..=36u64 {
        let mut rng = Rng::new(seed);
        let ssd = Arc::new(Ssd::new(32 << 20, 512));
        let mut fs = DpuFs::format(ssd, FsConfig { segment_size: 1 << 16 }).unwrap();
        let dir = fs.create_directory("d").unwrap();
        let file = fs.create_file(dir, "f").unwrap();
        fs.ensure_size(file, 8 << 20).unwrap();
        let seg = 1u64 << 16;
        for _ in 0..500 {
            let off = rng.next_range(8 << 20);
            let len = 1 + rng.next_range((8 << 20) - off);
            let extents = fs.map_extents(file, off, len).unwrap();
            // Lengths sum to the request.
            assert_eq!(extents.iter().map(|e| e.len).sum::<u64>(), len, "seed {seed}");
            // No extent crosses a segment boundary; none lands in the
            // metadata segment.
            for e in &extents {
                assert!(e.addr >= seg, "seed {seed}: extent in metadata segment");
                assert_eq!(
                    e.addr / seg,
                    (e.addr + e.len - 1) / seg,
                    "seed {seed}: extent crosses a segment"
                );
            }
            // Interior extents are segment-aligned runs.
            for w in extents.windows(2) {
                assert_eq!((w[1].addr) % seg, 0, "seed {seed}: follow-up extent misaligned");
            }
        }
    }
}

#[test]
fn dpufs_mount_roundtrip_random_trees() {
    for seed in 40..=45u64 {
        let mut rng = Rng::new(seed);
        let ssd = Arc::new(Ssd::new(32 << 20, 512));
        let mut files = Vec::new();
        {
            let mut fs = DpuFs::format(ssd.clone(), FsConfig::default()).unwrap();
            for d in 0..1 + rng.next_range(4) {
                let dir = fs.create_directory(&format!("dir{d}")).unwrap();
                for f in 0..1 + rng.next_range(5) {
                    let id = fs.create_file(dir, &format!("file{f}")).unwrap();
                    let len = 1 + rng.next_range(100_000) as usize;
                    let fill = (seed + d + f) as u8;
                    fs.write(id, 0, &vec![fill; len]).unwrap();
                    files.push((id, len, fill));
                }
            }
            fs.sync_metadata().unwrap();
        }
        let fs = DpuFs::mount(ssd, FsConfig::default()).unwrap();
        for (id, len, fill) in files {
            let mut out = vec![0u8; len];
            fs.read(id, 0, &mut out).unwrap();
            assert!(out.iter().all(|&b| b == fill), "seed {seed}: file {id:?}");
            assert_eq!(fs.file_meta(id).unwrap().size, len as u64);
        }
    }
}
