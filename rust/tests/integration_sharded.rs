//! Integration: the sharded data plane (§7) — N shard threads, RSS
//! steering, per-shard engines and host apps, byte-exact responses on
//! the issuing connection only.
//!
//! Cross-shard leakage is structurally asserted: each [`ShardDriver`]
//! owns exactly the connections RSS steers to its shard, and
//! `ShardDriver::absorb` errors out if a shard ever emits segments for
//! a connection it does not own.

use std::sync::Arc;
use std::time::Duration;

use dds::apps::RawFileApp;
use dds::coordinator::{
    run_sharded_request, tuple_for_shard, ShardDriver, ShardedServer, ShardedServerConfig,
    StorageServer, StorageServerConfig,
};
use dds::director::AppSignature;
use dds::offload::RawFileOffload;
use dds::proto::{AppRequest, NetMsg};

const FILE_BYTES: u64 = 1 << 20;

fn fill_pattern(offset: u64, len: usize) -> Vec<u8> {
    (offset..offset + len as u64).map(|i| (i % 253) as u8).collect()
}

/// Build a sharded server over a pre-filled file; returns it with the
/// file id the clients address.
fn build(shards: usize) -> (ShardedServer, u32) {
    let logic = Arc::new(RawFileOffload);
    let server_cfg = StorageServerConfig { ssd_bytes: 32 << 20, ..Default::default() };
    let storage = StorageServer::build(server_cfg, Some(logic.clone())).expect("storage");
    let file = storage.create_filled_file("bench", "data", FILE_BYTES).expect("fill");
    let fid = file.id.0;
    // NB: `cfg.server` is only read by `build()`; `over()` uses the
    // storage path constructed above.
    let cfg = ShardedServerConfig { shards, ..Default::default() };
    let server = ShardedServer::over(
        storage,
        cfg,
        logic,
        AppSignature::server_port(5000),
        // One host-app instance per shard, each with its own poll
        // group — the file service drains all of them round-robin.
        |_shard, st| RawFileApp::over(st, &file),
    )
    .expect("sharded server");
    (server, fid)
}

#[test]
fn multi_shard_reads_return_correct_bytes_on_their_connection() {
    let shards = 4usize;
    let (server, fid) = build(shards);
    let mut drivers: Vec<ShardDriver> = (0..shards).map(ShardDriver::new).collect();
    // Two connections per shard, steered there by RSS.
    let mut tuples: Vec<(usize, dds::net::FiveTuple)> = Vec::new();
    for s in 0..shards {
        for c in 0..2u16 {
            let t = tuple_for_shard(
                s,
                shards,
                0x0a00_0001 + c as u32,
                40_000 + (s as u16) * 97 + c * 13,
                0x0a00_00ff,
                5000,
            );
            drivers[s].connect(&server, t).unwrap();
            tuples.push((s, t));
        }
    }
    let mut msg_id = 1u64;
    for round in 0..3u64 {
        for (k, (s, t)) in tuples.iter().enumerate() {
            // Per-connection distinct offsets so byte-exactness also
            // proves no cross-connection mixing.
            let base = ((k as u64 * 37 + round * 11) * 512) % (FILE_BYTES - 2048);
            let reqs: Vec<AppRequest> = (0..4u64)
                .map(|j| AppRequest::Read { file_id: fid, offset: base + j * 512, size: 512 })
                .collect();
            let msg = NetMsg { msg_id, requests: reqs.clone() };
            msg_id += 1;
            let resps =
                run_sharded_request(&server, &mut drivers[*s], t, &msg, Duration::from_secs(10))
                    .unwrap();
            assert_eq!(resps.len(), reqs.len());
            for (r, req) in resps.iter().zip(&reqs) {
                let AppRequest::Read { offset, size, .. } = req else { unreachable!() };
                assert_eq!(r.status, 0);
                assert_eq!(r.payload, fill_pattern(*offset, *size as usize), "offset {offset}");
            }
        }
    }
    // Every shard handled exactly its own connections.
    for (s, st) in server.shard_stats().iter().enumerate() {
        assert_eq!(st.flows, 2, "shard {s} owns its two connections");
        assert_eq!(st.msgs_in, 6, "shard {s}: 2 conns x 3 rounds");
    }
    let agg = server.stats();
    assert_eq!(agg.flows, (shards * 2) as u64);
    assert_eq!(agg.msgs_in, (shards * 2 * 3) as u64);
    assert_eq!(agg.reqs_offloaded, (shards * 2 * 3 * 4) as u64, "every read offloaded");
    assert_eq!(agg.reqs_to_host, 0);
}

#[test]
fn writes_flow_through_per_shard_poll_groups() {
    let shards = 2usize;
    let (server, fid) = build(shards);
    for s in 0..shards {
        let mut driver = ShardDriver::new(s);
        let t = tuple_for_shard(
            s,
            shards,
            0x0a00_0009,
            41_000 + s as u16 * 31,
            0x0a00_00ff,
            5000,
        );
        driver.connect(&server, t).unwrap();
        let off = (s as u64 + 1) * (128 << 10);
        let data = vec![0xA0u8 + s as u8; 1024];
        let wmsg = NetMsg {
            msg_id: 900 + s as u64,
            requests: vec![AppRequest::Write { file_id: fid, offset: off, data: data.clone() }],
        };
        let resps =
            run_sharded_request(&server, &mut driver, &t, &wmsg, Duration::from_secs(10)).unwrap();
        assert_eq!(resps[0].status, 0, "write must succeed");
        // Read back through the offload engine: the engine observes the
        // bytes the host app just wrote through its own poll group.
        let rmsg = NetMsg {
            msg_id: 910 + s as u64,
            requests: vec![AppRequest::Read { file_id: fid, offset: off, size: 1024 }],
        };
        let resps =
            run_sharded_request(&server, &mut driver, &t, &rmsg, Duration::from_secs(10)).unwrap();
        assert_eq!(resps[0].status, 0);
        assert_eq!(resps[0].payload, data);
    }
    let agg = server.stats();
    assert_eq!(agg.reqs_to_host, shards as u64, "one write per shard went to the host app");
    assert_eq!(agg.reqs_offloaded, shards as u64, "one read per shard ran on the DPU");
    // The (single) file service drained every shard's poll group:
    // group 0 is the fill group, groups 1..=shards belong to the shard
    // host apps.
    let fe = server.storage.front_end();
    let gs = fe.group_stats().unwrap();
    assert_eq!(gs.len(), 1 + shards);
    for (i, g) in gs.iter().enumerate().skip(1) {
        assert!(g.requests >= 1, "poll group {i} was never drained");
        assert_eq!(g.delivered, g.requests, "group {i}: every request answered");
        assert_eq!(g.outstanding, 0);
    }
}

#[test]
fn non_power_of_two_shard_counts_work() {
    let shards = 3usize;
    let (server, fid) = build(shards);
    for s in 0..shards {
        let mut driver = ShardDriver::new(s);
        let t = tuple_for_shard(s, shards, 0x0a00_0002, 42_000 + s as u16, 0x0a00_00ff, 5000);
        driver.connect(&server, t).unwrap();
        let off = 512 * (s as u64 + 3);
        let msg = NetMsg {
            msg_id: 50 + s as u64,
            requests: vec![AppRequest::Read { file_id: fid, offset: off, size: 512 }],
        };
        let resps =
            run_sharded_request(&server, &mut driver, &t, &msg, Duration::from_secs(10)).unwrap();
        assert_eq!(resps[0].payload, fill_pattern(off, 512));
    }
    assert_eq!(server.stats().flows, shards as u64);
}

#[test]
fn single_shard_is_the_degenerate_case() {
    let (server, fid) = build(1);
    assert_eq!(server.num_shards(), 1);
    let mut driver = ShardDriver::new(0);
    let t = tuple_for_shard(0, 1, 0x0a00_0001, 40_000, 0x0a00_00ff, 5000);
    driver.connect(&server, t).unwrap();
    let msg = NetMsg {
        msg_id: 7,
        requests: vec![AppRequest::Read { file_id: fid, offset: 2048, size: 256 }],
    };
    let resps =
        run_sharded_request(&server, &mut driver, &t, &msg, Duration::from_secs(10)).unwrap();
    assert_eq!(resps[0].payload, fill_pattern(2048, 256));
}
