//! Integration: the network path + offload engine (§5, §6) — full
//! DisaggregatedServer pumps with partial offloading.

use std::sync::Arc;
use std::time::Duration;

use dds::apps::RawFileApp;
use dds::coordinator::{run_request, ClientConn, DisaggregatedServer, StorageServer, StorageServerConfig};
use dds::director::AppSignature;
use dds::net::FiveTuple;
use dds::offload::{OffloadEngineConfig, RawFileOffload};
use dds::proto::{AppRequest, NetMsg};
use dds::workload::RandomIoGen;

const FILE_BYTES: u64 = 4 << 20;

fn build(offload: bool, engine_cfg: OffloadEngineConfig) -> (DisaggregatedServer<RawFileApp>, u32) {
    let logic = Arc::new(RawFileOffload);
    let storage = StorageServer::build(StorageServerConfig::default(), Some(logic.clone()))
        .expect("storage");
    let fe = storage.front_end();
    let dir = fe.create_directory("bench").unwrap();
    let mut file = fe.create_file(dir, "data").unwrap();
    let group = fe.create_poll().unwrap();
    fe.poll_add(&mut file, &group);
    // Fill with a deterministic pattern.
    let chunk = 64 << 10;
    let mut ids = Vec::new();
    for off in (0..FILE_BYTES).step_by(chunk) {
        let data: Vec<u8> = (off..off + chunk as u64).map(|i| (i % 253) as u8).collect();
        loop {
            match fe.write_file(&file, off, &data) {
                Ok(id) => {
                    ids.push(id);
                    break;
                }
                Err(dds::filelib::LibError::RingFull) => {
                    for ev in group.poll_wait(Duration::from_millis(10)) {
                        ids.retain(|&x| x != ev.req_id);
                    }
                }
                Err(e) => panic!("{e}"),
            }
        }
    }
    while !ids.is_empty() {
        for ev in group.poll_wait(Duration::from_millis(20)) {
            ids.retain(|&x| x != ev.req_id);
        }
    }
    let fid = file.id.0;
    let app = RawFileApp { client: fe, file, group };
    let sig = AppSignature::server_port(5000);
    let server = if offload {
        DisaggregatedServer::new(storage, logic, sig, engine_cfg, app)
    } else {
        DisaggregatedServer::baseline(storage, sig, app)
    };
    (server, fid)
}

fn tuple() -> FiveTuple {
    FiveTuple::new(0x0a000001, 44444, 0x0a0000ff, 5000)
}

#[test]
fn offloaded_reads_return_correct_data() {
    let (mut server, fid) = build(true, OffloadEngineConfig::default());
    let mut client = ClientConn::new(tuple());
    let mut gen = RandomIoGen::new(fid, FILE_BYTES, 1024, 1.0, 8, 3);
    for _ in 0..20 {
        let msg = gen.next_msg();
        let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(5)).unwrap();
        assert_eq!(resps.len(), msg.requests.len());
        for (resp, req) in resps.iter().zip(&msg.requests) {
            let AppRequest::Read { offset, size, .. } = req else { unreachable!() };
            assert_eq!(resp.status, 0);
            let expect: Vec<u8> =
                (*offset..offset + *size as u64).map(|i| (i % 253) as u8).collect();
            assert_eq!(resp.payload, expect, "offset {offset}");
        }
    }
    assert!(server.director.reqs_offloaded >= 150, "reads should offload");
    assert_eq!(server.director.reqs_to_host, 0);
}

#[test]
fn mixed_batches_split_between_dpu_and_host() {
    let (mut server, fid) = build(true, OffloadEngineConfig::default());
    let mut client = ClientConn::new(tuple());
    // Batch with interleaved reads and writes: writes must go to the
    // host, reads to the DPU, and responses must line up per index.
    let msg = NetMsg {
        msg_id: 1,
        requests: vec![
            AppRequest::Read { file_id: fid, offset: 0, size: 64 },
            AppRequest::Write { file_id: fid, offset: 1 << 20, data: vec![9u8; 64] },
            AppRequest::Read { file_id: fid, offset: 1024, size: 64 },
            AppRequest::Write { file_id: fid, offset: (1 << 20) + 64, data: vec![8u8; 64] },
            AppRequest::Read { file_id: fid, offset: 2048, size: 64 },
        ],
    };
    let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(5)).unwrap();
    assert_eq!(resps.len(), 5);
    for r in &resps {
        assert_eq!(r.status, 0, "idx {}", r.idx);
    }
    assert_eq!(server.director.reqs_offloaded, 3);
    assert_eq!(server.director.reqs_to_host, 2);
    // Verify the writes actually landed by reading them back.
    let msg2 = NetMsg {
        msg_id: 2,
        requests: vec![AppRequest::Read { file_id: fid, offset: 1 << 20, size: 64 }],
    };
    let resps = run_request(&mut client, &mut server, &msg2, Duration::from_secs(5)).unwrap();
    assert_eq!(resps[0].payload, vec![9u8; 64]);
}

#[test]
fn baseline_mode_sends_everything_to_host() {
    let (mut server, fid) = build(false, OffloadEngineConfig::default());
    let mut client = ClientConn::new(tuple());
    let mut gen = RandomIoGen::new(fid, FILE_BYTES, 512, 1.0, 4, 9);
    for _ in 0..5 {
        let msg = gen.next_msg();
        let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(5)).unwrap();
        assert!(resps.iter().all(|r| r.status == 0));
    }
    assert_eq!(server.director.reqs_offloaded, 0);
    assert_eq!(server.director.reqs_to_host, 20);
}

#[test]
fn non_matching_flow_is_forwarded_untouched() {
    let (mut server, _fid) = build(true, OffloadEngineConfig::default());
    // Signature is port 5000; this flow targets port 9999.
    let other = FiveTuple::new(0x0a000001, 44444, 0x0a0000ff, 9999);
    let mut client = ClientConn::new(other);
    let msg = NetMsg { msg_id: 1, requests: vec![AppRequest::KvGet { key: 1 }] };
    let segs = client.send_msg(&msg);
    let n_segs = segs.len() as u64;
    let out = server.director.on_client_packets(&other, segs, &mut server.engine);
    assert_eq!(out.forwarded, n_segs, "bump-in-the-wire passthrough");
    assert!(out.to_client.is_empty());
    assert_eq!(server.director.msgs_in, 0, "payload never inspected");
}

#[test]
fn tiny_context_ring_bounces_overflow_to_host() {
    let cfg = OffloadEngineConfig { contexts: 2, pool_bufs: 2, ..Default::default() };
    let (mut server, fid) = build(true, cfg.clone());
    // The default engine uses inline polled-mode SSD (completions drain
    // at submit), so a 2-slot ring never fills. Swap in a worker-mode
    // AsyncSsd so completions are genuinely deferred and the Fig 13
    // ring-full bounce path (lines 5-7) triggers.
    server.engine = dds::offload::OffloadEngine::new(
        Arc::new(RawFileOffload),
        server.storage.cache.clone(),
        server.storage.dpufs.clone(),
        dds::ssd::AsyncSsd::new(server.storage.ssd.clone(), 2),
        cfg,
    );
    let mut client = ClientConn::new(tuple());
    // 16 reads with only 2 contexts: the overflow must be served by the
    // host — and every response must still be correct.
    let msg = NetMsg {
        msg_id: 7,
        requests: (0..16u64)
            .map(|i| AppRequest::Read { file_id: fid, offset: i * 4096, size: 256 })
            .collect(),
    };
    let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(5)).unwrap();
    assert_eq!(resps.len(), 16);
    for (resp, req) in resps.iter().zip(&msg.requests) {
        let AppRequest::Read { offset, size, .. } = req else { unreachable!() };
        let expect: Vec<u8> =
            (*offset..offset + *size as u64).map(|i| (i % 253) as u8).collect();
        assert_eq!(resp.status, 0);
        assert_eq!(resp.payload, expect);
    }
    assert!(server.director.reqs_to_host > 0, "overflow must bounce");
    assert!(server.engine.bounced_full > 0);
}

/// Acceptance criterion of the zero-copy buffer plane: in steady state,
/// an offloaded READ performs ZERO heap allocations and ZERO software
/// copies end-to-end — SSD completion → context ring → response payload
/// → client-bound segments, all by reference (asserted via the engine
/// pool's stats, exactly as Fig 12 describes the hardware path).
#[test]
fn steady_state_offloaded_reads_zero_heap_allocs() {
    let (mut server, fid) = build(true, OffloadEngineConfig::default());
    let mut client = ClientConn::new(tuple());
    // 4 KiB-aligned reads: single-extent (the 1 MiB segments of the
    // file mapping are never crossed), the overwhelmingly common case.
    let run_batch = |server: &mut DisaggregatedServer<RawFileApp>,
                         client: &mut ClientConn,
                         msg_id: u64| {
        let msg = NetMsg {
            msg_id,
            requests: (0..8u64)
                .map(|i| AppRequest::Read {
                    file_id: fid,
                    offset: ((msg_id * 8 + i) % 256) * 4096,
                    size: 4096,
                })
                .collect(),
        };
        let resps = run_request(client, server, &msg, Duration::from_secs(5)).unwrap();
        assert_eq!(resps.len(), 8);
        for (resp, req) in resps.iter().zip(&msg.requests) {
            let AppRequest::Read { offset, .. } = req else { unreachable!() };
            let expect: Vec<u8> =
                (*offset..offset + 4096).map(|i| (i % 253) as u8).collect();
            assert_eq!(resp.status, 0);
            assert_eq!(resp.payload, expect);
        }
    };
    // Warm-up: pool working set + TCP ramp.
    for m in 1..=4 {
        run_batch(&mut server, &mut client, m);
    }
    let before = server.engine.pool().stats();
    let reads = 10 * 8u64;
    for m in 5..15 {
        run_batch(&mut server, &mut client, m);
    }
    let d = server.engine.pool().stats() - before;
    assert_eq!(d.allocs, reads, "one pooled read buffer per offloaded read");
    assert_eq!(d.pool_hits, reads, "every buffer request served from the slab");
    assert_eq!(d.fallbacks, 0, "steady state never falls back to the heap");
    assert_eq!(d.heap_allocs, 0, "0 heap allocations per offloaded read");
    assert_eq!(d.bytes_copied, 0, "0 bytes memcpy'd per offloaded read");
    assert_eq!(server.director.reqs_to_host, 0, "everything offloaded");
}

#[test]
fn pep_prevents_client_retransmissions() {
    // End-to-end: after a full mixed workload, the client's TCP
    // endpoint must have retransmitted nothing (the PEP terminates
    // connection 1 on the DPU; offloading never creates gaps — §5.2).
    let (mut server, fid) = build(true, OffloadEngineConfig::default());
    let mut client = ClientConn::new(tuple());
    let mut gen = RandomIoGen::new(fid, FILE_BYTES, 1024, 0.7, 8, 21);
    for _ in 0..10 {
        let msg = gen.next_msg();
        let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(5)).unwrap();
        assert!(resps.iter().all(|r| r.status == 0));
    }
    assert_eq!(client.ep.retransmitted_segments, 0);
    assert_eq!(client.ep.dup_acks_sent, 0);
}
