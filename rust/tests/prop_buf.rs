//! Property tests for the zero-copy buffer plane (`dds::buf`).
//!
//! Seeded randomized model checking (no external proptest dependency —
//! the repo's own deterministic `Rng` drives the op sequences):
//!
//! * **Aliasing safety** — a recycled slab slot is never visible
//!   through a stale view: every live view always reads back exactly
//!   the pattern written when its buffer was filled, across arbitrary
//!   interleavings of allocate / fill / freeze / slice / drop.
//! * **Exhaustion liveness** — the pool keeps serving under exhaustion
//!   (fallback to owned heap, counted), and occupancy returns to zero
//!   when every view drops.

use dds::buf::{BufPool, BufView, ByteRope};
use dds::sim::rng::Rng;

/// Deterministic fill pattern derived from a tag.
fn pattern(tag: u64, len: usize) -> Vec<u8> {
    (0..len).map(|i| ((tag as usize).wrapping_mul(31).wrapping_add(i) % 251) as u8).collect()
}

#[test]
fn prop_stale_views_never_observe_recycling() {
    for seed in [1u64, 7, 42, 1337] {
        let mut rng = Rng::new(seed);
        let slots = 4usize;
        let slot_size = 256usize;
        let pool = BufPool::new(slots, slot_size);
        // Live views with the pattern tag they must keep reading.
        let mut live: Vec<(u64, usize, BufView)> = Vec::new();
        let mut next_tag = 0u64;
        for _ in 0..2000 {
            match rng.next_range(4) {
                // Allocate + fill + freeze (sometimes oversize to force
                // the heap-fallback path into the interleaving).
                0 | 1 => {
                    let len = if rng.next_range(10) == 0 {
                        slot_size + 1 + rng.next_range(64) as usize
                    } else {
                        1 + rng.next_range(slot_size as u64) as usize
                    };
                    let tag = next_tag;
                    next_tag += 1;
                    let mut b = pool.allocate(len);
                    b.as_mut_slice().copy_from_slice(&pattern(tag, len));
                    live.push((tag, len, b.freeze()));
                }
                // Slice a random live view (shares storage; inherits
                // the sliced window of the pattern).
                2 if !live.is_empty() => {
                    let i = rng.next_range(live.len() as u64) as usize;
                    let (tag, len, v) = &live[i];
                    if *len > 1 {
                        let start = rng.next_range(*len as u64 - 1) as usize;
                        let end = start + 1 + rng.next_range((*len - start - 1).max(1) as u64) as usize;
                        let end = end.min(*len);
                        let sub = v.slice(start..end);
                        assert!(sub.shares_storage(v));
                        // A sliced view is checked against the parent
                        // pattern window; reuse the tag with an offset
                        // encoded by re-deriving from the parent.
                        assert_eq!(
                            sub.as_slice(),
                            &pattern(*tag, *len)[start..end],
                            "seed {seed}: slice observed foreign bytes"
                        );
                    }
                }
                // Drop a random live view (slot may recycle iff it was
                // the last reference).
                _ if !live.is_empty() => {
                    let i = rng.next_range(live.len() as u64) as usize;
                    live.swap_remove(i);
                }
                _ => {}
            }
            // Invariant: EVERY live view still reads its own pattern,
            // no matter how many slots were recycled meanwhile.
            for (tag, len, v) in &live {
                assert_eq!(
                    v.as_slice(),
                    pattern(*tag, *len).as_slice(),
                    "seed {seed}: stale view observed a recycled slot"
                );
            }
            // Invariant: occupancy (slab slots out + outstanding
            // fallbacks) equals the number of live buffers exactly.
            assert_eq!(pool.in_use(), live.len(), "seed {seed}: occupancy drifted");
        }
        drop(live);
        assert_eq!(pool.in_use(), 0, "seed {seed}: slots leaked");
        let s = pool.stats();
        assert_eq!(s.allocs, s.pool_hits + s.fallbacks, "every alloc is a hit or a fallback");
    }
}

#[test]
fn prop_exhaustion_fallback_keeps_serving() {
    let pool = BufPool::new(2, 128);
    // Grab 50 concurrent buffers from a 2-slot pool: all must be
    // usable, all must read back their own fill.
    let views: Vec<BufView> = (0..50u64)
        .map(|tag| {
            let mut b = pool.allocate(64);
            b.as_mut_slice().copy_from_slice(&pattern(tag, 64));
            b.freeze()
        })
        .collect();
    for (tag, v) in views.iter().enumerate() {
        assert_eq!(v.as_slice(), pattern(tag as u64, 64).as_slice());
    }
    let s = pool.stats();
    assert_eq!(s.allocs, 50);
    assert_eq!(s.pool_hits, 2, "only the slab's two slots hit");
    assert_eq!(s.fallbacks, 48, "the rest fell back to owned heap — and still served");
    drop(views);
    assert_eq!(pool.in_use(), 0);
    assert_eq!(pool.available(), 2, "fallback buffers never join the slab");
}

#[test]
fn prop_rope_concatenation_equals_parts() {
    let mut rng = Rng::new(99);
    for _ in 0..200 {
        let n = 1 + rng.next_range(8) as usize;
        let mut rope = ByteRope::new();
        let mut expect = Vec::new();
        for tag in 0..n as u64 {
            let len = rng.next_range(100) as usize;
            let bytes = pattern(tag, len);
            expect.extend_from_slice(&bytes);
            rope.push(BufView::from_vec(bytes));
        }
        assert_eq!(rope.len(), expect.len());
        assert_eq!(rope.to_vec(), expect);
    }
}
