//! Shared helper for the chaos suites (`chaos_scenarios.rs`,
//! `chaos_determinism.rs`), included via `#[path]` so both crates use
//! one seed source. Not a test target itself.

/// Base seed for every chaos test: `DDS_CHAOS_SEED` env override first
/// (the CI matrix and failure reproduction), then a fixed default.
/// Always printed so any run can be replayed.
pub fn chaos_seed() -> u64 {
    let seed = std::env::var("DDS_CHAOS_SEED")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(0xD15_A66);
    println!("chaos seed = {seed} (set DDS_CHAOS_SEED to override)");
    seed
}
