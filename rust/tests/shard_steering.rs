//! Satellite coverage for the sharded data plane's two foundations:
//! the progress ring under head/tail wraparound, and the stability /
//! symmetry of RSS shard steering at several shard counts (including
//! non-power-of-two).

use dds::director::rss_core;
use dds::net::FiveTuple;
use dds::ring::{ProgressRing, RequestRing, RingStatus};

/// Push far more messages than the ring capacity, several in flight at
/// a time, so the head/tail offsets wrap the data buffer many times and
/// individual records straddle the wrap boundary. Every message must
/// come back intact and in order.
#[test]
fn progress_ring_survives_many_wraparounds() {
    let capacity = 256usize;
    let ring = ProgressRing::new(capacity, 128);
    let mut next_push = 0u64;
    let mut next_pop = 0u64;
    let total = 10_000u64; // >> capacity: wraps the buffer hundreds of times
    // Odd record length forces 8-byte padding and makes records land at
    // every alignment relative to the wrap point over time.
    let len = 13usize;
    while next_pop < total {
        // Keep a few messages in flight so pops cross the wrap boundary
        // mid-batch, not only at record edges.
        while next_push < total {
            let mut msg = vec![0u8; len];
            msg[..8].copy_from_slice(&next_push.to_le_bytes());
            match ring.try_push(&msg) {
                RingStatus::Ok => next_push += 1,
                _ => break, // backlog at max progress: drain first
            }
        }
        let popped = ring.pop_batch(&mut |m| {
            assert_eq!(m.len(), len);
            let got = u64::from_le_bytes(m[..8].try_into().unwrap());
            assert_eq!(got, next_pop, "FIFO order across wraparound");
            next_pop += 1;
        });
        assert!(popped > 0 || next_push > next_pop, "ring stuck");
    }
    assert_eq!(next_pop, total);
    assert_eq!(ring.backlog(), 0);
}

/// A single record split across the physical end of the buffer must be
/// reassembled correctly (two-memcpy wrap path).
#[test]
fn progress_ring_record_straddles_wrap_boundary() {
    let ring = ProgressRing::new(64, 32);
    // Each 20-byte payload occupies align8(4+20) = 24 bytes. 24 does
    // not divide 64, so successive records start at every residue mod 8
    // over time — including starts like 48 and 56 whose record body
    // physically straddles the end of the buffer (the two-memcpy wrap
    // path on both write and read).
    for round in 0..50u8 {
        let msg = vec![round; 20];
        assert_eq!(ring.try_push(&msg), RingStatus::Ok);
        let mut got = Vec::new();
        assert_eq!(ring.pop_batch(&mut |m| got.push(m.to_vec())), 1);
        assert_eq!(got, vec![msg], "round {round}");
    }
    assert_eq!(ring.backlog(), 0);
}

/// Shard assignment must be (a) stable across repeated evaluation,
/// (b) symmetric between the forward and reverse directions of a flow,
/// at power-of-two and non-power-of-two shard counts alike.
#[test]
fn rss_steering_stable_and_symmetric_at_many_shard_counts() {
    for &shards in &[1usize, 2, 3, 4, 5, 7, 8, 12] {
        for i in 0..500u32 {
            let fwd = FiveTuple::new(
                0x0a00_0000 + i,
                (2000 + i * 13) as u16,
                0x0a00_00ff,
                5000,
            );
            let rev = FiveTuple::new(
                0x0a00_00ff,
                5000,
                0x0a00_0000 + i,
                (2000 + i * 13) as u16,
            );
            let c = rss_core(&fwd, shards);
            assert!(c < shards);
            assert_eq!(c, rss_core(&fwd, shards), "stable for {shards} shards");
            assert_eq!(
                c,
                rss_core(&rev, shards),
                "symmetric for {shards} shards (flow {i})"
            );
        }
    }
}

/// With enough flows, every shard receives some — no shard is starved
/// by the hash, including at non-power-of-two counts.
#[test]
fn rss_steering_covers_every_shard() {
    for &shards in &[2usize, 3, 5, 8] {
        let mut counts = vec![0usize; shards];
        for i in 0..4000u32 {
            let t = FiveTuple::new(0x0a00_0000 + i, (1000 + i * 7) as u16, 0x0a00_00ff, 5000);
            counts[rss_core(&t, shards)] += 1;
        }
        for (s, &n) in counts.iter().enumerate() {
            assert!(
                n > 4000 / shards / 3,
                "shard {s}/{shards} starved: {n} of 4000 flows"
            );
        }
    }
}
