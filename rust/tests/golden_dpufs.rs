//! Golden byte vectors for the durability plane's on-disk formats
//! (mirroring `golden_wire.rs` for the wire protocol): the segment-0
//! metadata image encoding and the checksummed frame format shared by
//! the journal records and the superblock slots. Any accidental field
//! reorder, width change, endianness slip, or checksum-convention
//! change fails loudly; truncated and bit-flipped input of every
//! possible length/position must be rejected, never accepted or
//! panicked on.

use std::collections::HashMap;
use std::sync::Arc;

use dds::dpufs::journal::{
    crc32, decode_frame, encode_frame, read_slots, write_slot, FRAME_HEADER_LEN,
    JOURNAL_COMMIT_MAGIC, JOURNAL_DATA_MAGIC, SUPER_MAGIC,
};
use dds::dpufs::meta::{self, DirId, FileId, FileMeta};
use dds::ssd::Ssd;

/// Published CRC-32 (IEEE) check values pin the polynomial, the
/// reflection, and the init/final-xor conventions — everything the
/// frame checksums depend on.
#[test]
fn golden_crc32() {
    assert_eq!(crc32(b""), 0x0000_0000);
    assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
}

#[test]
fn golden_metadata_image() {
    let mut dirs = HashMap::new();
    dirs.insert(DirId(1), "db".to_string());
    let mut files = HashMap::new();
    files.insert(
        FileId(7),
        FileMeta {
            id: FileId(7),
            dir: DirId(1),
            name: "rbpex".into(),
            size: 123456,
            segments: vec![3, 9, 12],
        },
    );
    let golden: Vec<u8> = vec![
        0x00, 0xF5, 0xD5, 0x0D, // magic 0x0DD5F500 LE
        0x02, 0x00, 0x00, 0x00, // next_dir = 2
        0x08, 0x00, 0x00, 0x00, // next_file = 8
        0x01, 0x00, 0x00, 0x00, // ndirs = 1
        0x01, 0x00, 0x00, 0x00, // nfiles = 1
        0x01, 0x00, 0x00, 0x00, // dir id 1
        0x02, 0x00, 0x00, 0x00, 0x64, 0x62, // "db"
        0x07, 0x00, 0x00, 0x00, // file id 7
        0x01, 0x00, 0x00, 0x00, // dir 1
        0x05, 0x00, 0x00, 0x00, 0x72, 0x62, 0x70, 0x65, 0x78, // "rbpex"
        0x40, 0xE2, 0x01, 0x00, 0x00, 0x00, 0x00, 0x00, // size 123456
        0x03, 0x00, 0x00, 0x00, // 3 segments
        0x03, 0x00, 0x00, 0x00, 0x09, 0x00, 0x00, 0x00, 0x0C, 0x00, 0x00, 0x00,
    ];
    let enc = meta::encode(&dirs, &files, 2, 8, 1 << 20).unwrap();
    assert_eq!(enc, golden);
    let (d2, f2, nd, nf) = meta::decode(&golden).unwrap();
    assert_eq!((d2, f2, nd, nf), (dirs, files, 2, 8));
    // Every strict prefix must reject (truncated metadata), not panic.
    for cut in 0..golden.len() {
        assert!(
            meta::decode(&golden[..cut]).is_err(),
            "truncation to {cut}/{} bytes was accepted",
            golden.len()
        );
    }
}

/// The shared frame layout, pinned byte for byte:
/// `magic u32 | seq u64 | len u32 | payload_crc u32 | header_crc u32 |
/// payload`.
#[test]
fn golden_journal_data_record() {
    let frame = encode_frame(JOURNAL_DATA_MAGIC, 0x0102_0304_0506_0708, b"meta");
    let golden: Vec<u8> = vec![
        0x01, 0x3D, 0xD5, 0x0D, // magic 0x0DD53D01 LE
        0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01, // seq
        0x04, 0x00, 0x00, 0x00, // payload len
        0x35, 0x14, 0xF2, 0xD7, // crc32("meta")
        0x9B, 0x4D, 0x66, 0x46, // crc32(header[..20])
        0x6D, 0x65, 0x74, 0x61, // "meta"
    ];
    assert_eq!(frame, golden);
    assert_eq!(golden.len(), FRAME_HEADER_LEN + 4);
    let (magic, seq, payload, total) = decode_frame(&golden).expect("valid frame");
    assert_eq!(
        (magic, seq, payload, total),
        (JOURNAL_DATA_MAGIC, 0x0102_0304_0506_0708, &b"meta"[..], golden.len())
    );
    assert_rejects_all_corruption(&golden);
}

#[test]
fn golden_journal_commit_record() {
    let frame = encode_frame(JOURNAL_COMMIT_MAGIC, 5, b"");
    let golden: Vec<u8> = vec![
        0x01, 0x3C, 0xD5, 0x0D, // magic 0x0DD53C01 LE
        0x05, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // seq 5
        0x00, 0x00, 0x00, 0x00, // payload len 0
        0x00, 0x00, 0x00, 0x00, // crc32("") = 0
        0xA8, 0x28, 0xE5, 0x09, // crc32(header[..20])
    ];
    assert_eq!(frame, golden);
    let (magic, seq, payload, _) = decode_frame(&golden).expect("valid frame");
    assert_eq!((magic, seq, payload.len()), (JOURNAL_COMMIT_MAGIC, 5, 0));
    assert_rejects_all_corruption(&golden);
}

#[test]
fn golden_superblock_slot_frame() {
    let frame = encode_frame(SUPER_MAGIC, 2, b"img");
    let golden: Vec<u8> = vec![
        0x01, 0x5B, 0xD5, 0x0D, // magic 0x0DD55B01 LE
        0x02, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // seq 2
        0x03, 0x00, 0x00, 0x00, // payload len
        0xAC, 0xC8, 0xC2, 0xBB, // crc32("img")
        0xC4, 0x78, 0x5F, 0x66, // crc32(header[..20])
        0x69, 0x6D, 0x67, // "img"
    ];
    assert_eq!(frame, golden);
    assert_rejects_all_corruption(&golden);
}

/// Slot placement: even sequences land in slot 0, odd in slot 1, so
/// successive syncs never overwrite the last committed image.
#[test]
fn golden_superblock_slot_placement() {
    let seg = 1u64 << 13;
    let ssd = Arc::new(Ssd::new(4 * seg, 512));
    write_slot(&ssd, seg, 2, b"even").unwrap();
    write_slot(&ssd, seg, 3, b"odd").unwrap();
    let mut sb = vec![0u8; seg as usize];
    ssd.read_into(0, &mut sb).unwrap();
    // Slot 0 starts at offset 0, slot 1 at segment_size / 2.
    assert_eq!(&sb[..4], &0x0DD5_5B01u32.to_le_bytes()[..]);
    assert_eq!(&sb[(seg / 2) as usize..(seg / 2) as usize + 4], &0x0DD5_5B01u32.to_le_bytes()[..]);
    let slots = read_slots(&sb);
    assert_eq!(slots[0], Some((2, b"even".to_vec())));
    assert_eq!(slots[1], Some((3, b"odd".to_vec())));
}

/// Every strict prefix and every single-bit flip of a valid frame must
/// be rejected: header flips fail the header checksum, payload flips
/// the payload checksum, checksum-field flips the comparison.
fn assert_rejects_all_corruption(frame: &[u8]) {
    for cut in 0..frame.len() {
        assert!(decode_frame(&frame[..cut]).is_none(), "prefix of {cut} bytes accepted");
    }
    for byte in 0..frame.len() {
        for bit in 0..8 {
            let mut bad = frame.to_vec();
            bad[byte] ^= 1 << bit;
            assert!(
                decode_frame(&bad).is_none(),
                "bit flip at byte {byte} bit {bit} accepted"
            );
        }
    }
}
