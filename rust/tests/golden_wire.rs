//! Golden wire-format tests: every message type pinned to fixed byte
//! vectors, byte for byte. These freeze the little-endian layouts of
//! Fig 9 (host↔DPU ring records) and the §8.1 client protocol — any
//! accidental field reorder, width change, or endianness slip fails
//! loudly, and truncated input of every possible length must be
//! rejected, never panic.

use dds::proto::wire::{Reader, Writer};
use dds::proto::{framing, AppRequest, FileOpKind, FileRequest, FileResponse, NetMsg, NetResp, Status};

/// Every strict prefix of a valid encoding must decode to None (and
/// must not panic).
fn assert_prefixes_rejected<T: std::fmt::Debug>(bytes: &[u8], decode: impl Fn(&[u8]) -> Option<T>) {
    for cut in 0..bytes.len() {
        assert!(
            decode(&bytes[..cut]).is_none(),
            "truncation to {cut}/{} bytes was accepted",
            bytes.len()
        );
    }
}

#[test]
fn golden_writer_reader_layout() {
    let mut w = Writer::new();
    w.u8(0x01);
    w.u16(0x0203);
    w.u32(0x0405_0607);
    w.u64(0x1122_3344_5566_7788);
    w.bytes(b"ab");
    let bytes = w.into_vec();
    assert_eq!(
        bytes,
        vec![
            0x01, // u8
            0x03, 0x02, // u16 LE
            0x07, 0x06, 0x05, 0x04, // u32 LE
            0x88, 0x77, 0x66, 0x55, 0x44, 0x33, 0x22, 0x11, // u64 LE
            b'a', b'b',
        ]
    );
    let mut r = Reader::new(&bytes);
    assert_eq!(r.u8(), Some(0x01));
    assert_eq!(r.u16(), Some(0x0203));
    assert_eq!(r.u32(), Some(0x0405_0607));
    assert_eq!(r.u64(), Some(0x1122_3344_5566_7788));
    assert_eq!(r.take(2), Some(&b"ab"[..]));
    assert_eq!(r.remaining(), 0);
}

#[test]
fn golden_file_request_read() {
    let req = FileRequest::read(0x0102_0304_0506_0708, 0x1122_3344, 0x5566_7788_99AA_BBCC, 0xFF);
    let golden = vec![
        0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01, // req_id
        0x44, 0x33, 0x22, 0x11, // file_id
        0x00, // kind = Read
        0xCC, 0xBB, 0xAA, 0x99, 0x88, 0x77, 0x66, 0x55, // offset
        0xFF, 0x00, 0x00, 0x00, // size
        0x00, 0x00, 0x00, 0x00, // data len
    ];
    assert_eq!(req.encode(), golden);
    assert_eq!(FileRequest::decode(&golden), Some(req));
    assert_prefixes_rejected(&golden, FileRequest::decode);
}

#[test]
fn golden_file_request_write() {
    let req = FileRequest::write(1, 2, 3, vec![0xAA, 0xBB]);
    let golden = vec![
        0x01, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // req_id
        0x02, 0x00, 0x00, 0x00, // file_id
        0x01, // kind = Write
        0x03, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // offset
        0x02, 0x00, 0x00, 0x00, // size (== data len for writes)
        0x02, 0x00, 0x00, 0x00, // data len
        0xAA, 0xBB, // inlined payload (Fig 9: one DMA moves it all)
    ];
    assert_eq!(req.encode(), golden);
    let back = FileRequest::decode(&golden).unwrap();
    assert_eq!(back.kind, FileOpKind::Write);
    assert_eq!(back, req);
    assert_prefixes_rejected(&golden, FileRequest::decode);
    // An unknown op kind must reject, not default.
    let mut bad = golden.clone();
    bad[12] = 0x02;
    assert_eq!(FileRequest::decode(&bad), None);
}

#[test]
fn golden_file_response() {
    let resp = FileResponse { req_id: 0x0A, status: Status::Ok, data: vec![1, 2, 3] };
    let golden = vec![
        0x0A, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // req_id
        0x01, // status = Ok
        0x03, 0x00, 0x00, 0x00, // data len
        0x01, 0x02, 0x03,
    ];
    assert_eq!(resp.encode(), golden);
    assert_eq!(FileResponse::decode(&golden), Some(resp));
    assert_prefixes_rejected(&golden, FileResponse::decode);
    // All three status codes round-trip; a fourth rejects.
    for (code, status) in [(0u8, Status::Pending), (1, Status::Ok), (2, Status::Error)] {
        let mut v = golden.clone();
        v[8] = code;
        assert_eq!(FileResponse::decode(&v).unwrap().status, status);
    }
    let mut bad = golden;
    bad[8] = 3;
    assert_eq!(FileResponse::decode(&bad), None);
}

#[test]
fn golden_net_msg_every_request_kind() {
    let msg = NetMsg {
        msg_id: 7,
        requests: vec![
            AppRequest::Read { file_id: 1, offset: 2, size: 3 },
            AppRequest::Write { file_id: 4, offset: 5, data: vec![9] },
            AppRequest::GetPage { page_id: 6, lsn: 7 },
            AppRequest::KvGet { key: 8 },
            AppRequest::KvUpsert { key: 9, value: vec![0xFF] },
        ],
    };
    let golden = vec![
        0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // msg_id
        0x05, 0x00, // request count
        // Read { file_id: 1, offset: 2, size: 3 }
        0x00, 0x01, 0x00, 0x00, 0x00, 0x02, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x03,
        0x00, 0x00, 0x00,
        // Write { file_id: 4, offset: 5, data: [9] }
        0x01, 0x04, 0x00, 0x00, 0x00, 0x05, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x01,
        0x00, 0x00, 0x00, 0x09,
        // GetPage { page_id: 6, lsn: 7 }
        0x02, 0x06, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x07, 0x00, 0x00, 0x00, 0x00,
        0x00, 0x00, 0x00,
        // KvGet { key: 8 }
        0x03, 0x08, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
        // KvUpsert { key: 9, value: [0xFF] }
        0x04, 0x09, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x01, 0x00, 0x00, 0x00, 0xFF,
    ];
    assert_eq!(msg.encode(), golden);
    assert_eq!(NetMsg::decode(&golden), Some(msg));
    assert_prefixes_rejected(&golden, NetMsg::decode);
    // An unknown request tag rejects the whole message.
    let mut bad = golden;
    bad[10] = 0x05;
    assert_eq!(NetMsg::decode(&bad), None);
}

#[test]
fn golden_net_resp() {
    let resp = NetResp { msg_id: 0x10, idx: 2, status: NetResp::ERR, payload: vec![0xDE, 0xAD].into() };
    let golden = vec![
        0x10, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // msg_id
        0x02, 0x00, // idx
        0x01, // status = ERR
        0x02, 0x00, 0x00, 0x00, // payload len
        0xDE, 0xAD,
    ];
    assert_eq!(resp.encode(), golden);
    assert_eq!(NetResp::decode(&golden), Some(resp));
    assert_prefixes_rejected(&golden, NetResp::decode);
}

#[test]
fn golden_framing() {
    let mut stream = Vec::new();
    framing::write_frame(&mut stream, b"hi");
    assert_eq!(stream, vec![0x02, 0x00, 0x00, 0x00, b'h', b'i']);
    // Incomplete frames wait for more bytes instead of erroring.
    for cut in 0..stream.len() {
        let mut partial = stream[..cut].to_vec();
        assert_eq!(framing::read_frame(&mut partial), None);
        assert_eq!(partial.len(), cut, "partial input must not be consumed");
    }
    let mut full = stream;
    assert_eq!(framing::read_frame(&mut full), Some(b"hi".to_vec()));
    assert!(full.is_empty());
}

/// A corrupted length field larger than the buffer must reject cleanly
/// for the length-prefixed types.
#[test]
fn oversized_length_fields_reject() {
    let req = FileRequest::write(1, 2, 3, vec![0; 8]);
    let mut enc = req.encode();
    // data-len field sits at bytes 25..29.
    enc[25..29].copy_from_slice(&u32::MAX.to_le_bytes());
    assert_eq!(FileRequest::decode(&enc), None);

    let resp = NetResp { msg_id: 1, idx: 0, status: 0, payload: vec![0; 4].into() };
    let mut enc = resp.encode();
    // payload-len field sits at bytes 11..15.
    enc[11..15].copy_from_slice(&u32::MAX.to_le_bytes());
    assert_eq!(NetResp::decode(&enc), None);
}
