//! Crash-point enumeration harness — the acceptance test of the
//! durability plane.
//!
//! A seeded metadata op sequence (create/delete/write/grow, each
//! metadata op followed by the crash-consistent sync) is first run
//! clean while tracing every device write. Then **every SSD-write
//! prefix** of that schedule becomes a crash point: for each write `k`
//! and each byte offset `n` within it, a fresh run is cut at exactly
//! `(k, n)` — the write persists only its first `n` bytes and the
//! device dies — and the image is remounted. The invariants, at every
//! single point:
//!
//! * `mount` succeeds — no panic, no `Corrupt` rejection;
//! * the recovered file system equals the in-memory model at the last
//!   committed sequence (no metadata loss: every acked sync survives;
//!   nothing uncommitted is invented);
//! * no segment is double-allocated or out of range, the bitmap
//!   accounting balances, and the id counters cannot reuse a live id;
//! * a re-crash *during recovery's own repair writes* recovers to the
//!   identical state (idempotent replay).
//!
//! The **WRITE crash matrix** applies the same discipline to the
//! data path: a seeded mixed read/write/grow sequence of *durable*
//! WRITEs (`write_durable`: redirect-on-write shadows + journaled
//! remap commit) is traced, every byte prefix of every device write
//! becomes a crash point, and the recovered image must equal the
//! committed byte model **exactly** — every acked WRITE byte-exact,
//! the in-flight WRITE visible iff its remap record (the ack point)
//! fully persisted, never a mix of old and new bytes, and no shadow
//! segment leaked.
//!
//! `DDS_CRASH_STRIDE` (default 1 = every byte) coarsens the byte
//! enumeration for quick local runs; `DDS_CHAOS_SEED` picks the op
//! sequence. On a matrix failure the failing crash point and the full
//! device write schedule are written to `$DDS_CRASH_ARTIFACT` (when
//! set) so CI can upload a reproducer.

use std::sync::Arc;

use dds::dpufs::{DirId, DpuFs, FileId, FsConfig, RecoveryReport};
use dds::fault::scenario::{verify_recovered_fs, MetaModel};
use dds::sim::Rng;
use dds::ssd::Ssd;

#[path = "chaos_common.rs"]
mod chaos_common;
use chaos_common::chaos_seed;

/// Small segments keep every metadata image (and therefore every crash
/// point's replay) byte-cheap while still exercising multi-extent I/O.
const SEG: u64 = 1 << 13;
const SSD_BYTES: u64 = 512 << 10; // 64 segments
const OPS: usize = 12;

fn cfg() -> FsConfig {
    FsConfig { segment_size: SEG }
}

fn stride() -> usize {
    std::env::var("DDS_CRASH_STRIDE")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .filter(|&s| s >= 1)
        .unwrap_or(1)
}

struct Run {
    /// `(seq, model)` per attempted sync; seq 1 = formatted-empty.
    /// The model is the scenario harness's [`MetaModel`], so both
    /// suites check recovery through one verifier.
    snapshots: Vec<(u64, MetaModel)>,
    /// Highest sequence whose sync returned Ok.
    acked_seq: u64,
}

impl Run {
    fn model_at(&self, seq: u64) -> Option<&MetaModel> {
        self.snapshots.iter().rev().find(|(s, _)| *s == seq).map(|(_, m)| m)
    }
}

/// Apply the seeded op sequence to a freshly formatted fs, mirroring
/// the file service's durability policy (sync after every metadata op;
/// data-plane writes don't sync). Stops at the first device error —
/// that is the armed power cut firing; in-memory-only ops can't fail.
fn apply_ops(fs: &mut DpuFs, seed: u64) -> Run {
    let mut rng = Rng::new(seed ^ 0xC4A5_4002);
    let mut model = MetaModel::default();
    let mut dir_ids: Vec<DirId> = Vec::new();
    let mut live: Vec<(FileId, String, String, u64)> = Vec::new();
    let mut snapshots = vec![(1u64, MetaModel::default())];
    let mut acked_seq = 1u64;

    // Deterministic bootstrap: one committed dir + file regardless of
    // the seed's draw luck, so every op branch has a target and a quiet
    // seed can never produce an empty cut window (which would trip the
    // harness asserts, not the durability plane).
    for boot in 0..2 {
        let mut m = model.clone();
        if boot == 0 {
            dir_ids.push(fs.create_directory("d-base").expect("fresh fs"));
            m.dirs.push("d-base".into());
        } else {
            let id = fs.create_file(dir_ids[0], "f-base").expect("fresh fs");
            live.push((id, "d-base".into(), "f-base".into(), 0));
            m.files.push(("d-base".into(), "f-base".into(), 0));
        }
        snapshots.push((acked_seq + 1, m.clone()));
        if fs.sync_metadata().is_err() {
            return Run { snapshots, acked_seq };
        }
        model = m;
        acked_seq += 1;
    }

    for i in 0..OPS {
        match rng.next_range(10) {
            0..=2 => {
                let name = format!("d{i}");
                dir_ids.push(fs.create_directory(&name).expect("unique dir name"));
                let mut m = model.clone();
                m.dirs.push(name);
                snapshots.push((acked_seq + 1, m.clone()));
                if fs.sync_metadata().is_err() {
                    return Run { snapshots, acked_seq };
                }
                model = m;
                acked_seq += 1;
            }
            3..=5 => {
                let Some(&dir) = dir_ids.last() else { continue };
                let dname = model.dirs.last().expect("dir_ids tracks model.dirs").clone();
                let name = format!("f{i}");
                let id = fs.create_file(dir, &name).expect("unique file name");
                live.push((id, dname.clone(), name.clone(), 0));
                let mut m = model.clone();
                m.files.push((dname, name, 0));
                snapshots.push((acked_seq + 1, m.clone()));
                if fs.sync_metadata().is_err() {
                    return Run { snapshots, acked_seq };
                }
                model = m;
                acked_seq += 1;
            }
            6..=7 => {
                // Data-plane append: device writes, no metadata sync.
                if live.is_empty() {
                    continue;
                }
                let fi = rng.next_range(live.len() as u64) as usize;
                let len = 1 + rng.next_range(48) as usize;
                let off = live[fi].3;
                let data: Vec<u8> =
                    (0..len).map(|j| ((off as usize + j) % 251) as u8).collect();
                if fs.write(live[fi].0, off, &data).is_err() {
                    return Run { snapshots, acked_seq };
                }
                live[fi].3 = off + len as u64;
                let (_, ref d, ref n, sz) = live[fi];
                let e = model
                    .files
                    .iter_mut()
                    .find(|(fd, fnm, _)| fd == d && fnm == n)
                    .expect("model tracks every live file");
                e.2 = sz;
            }
            8 => {
                // Explicit grow — a metadata op: synced.
                if live.is_empty() {
                    continue;
                }
                let fi = rng.next_range(live.len() as u64) as usize;
                let grow = live[fi].3 + 1 + rng.next_range(SEG);
                fs.ensure_size(live[fi].0, grow).expect("growth stays within the device");
                live[fi].3 = live[fi].3.max(grow);
                let mut m = model.clone();
                {
                    let (_, ref d, ref n, _) = live[fi];
                    let e = m
                        .files
                        .iter_mut()
                        .find(|(fd, fnm, _)| fd == d && fnm == n)
                        .expect("model tracks every live file");
                    e.2 = e.2.max(grow);
                }
                snapshots.push((acked_seq + 1, m.clone()));
                if fs.sync_metadata().is_err() {
                    return Run { snapshots, acked_seq };
                }
                model = m;
                acked_seq += 1;
            }
            _ => {
                if live.is_empty() {
                    continue;
                }
                let fi = rng.next_range(live.len() as u64) as usize;
                let (id, d, n, _) = live.remove(fi);
                fs.delete_file(id).expect("live file");
                let mut m = model.clone();
                m.files.retain(|(fd, fnm, _)| !(fd == &d && fnm == &n));
                snapshots.push((acked_seq + 1, m.clone()));
                if fs.sync_metadata().is_err() {
                    return Run { snapshots, acked_seq };
                }
                model = m;
                acked_seq += 1;
            }
        }
    }
    Run { snapshots, acked_seq }
}

/// Full recovered-state check through the ONE shared verifier
/// (`dds::fault::scenario::verify_recovered_fs`): model equality +
/// segment/bitmap/counter invariants.
fn assert_fs_matches(fs: &DpuFs, model: &MetaModel, ctx: &str) {
    verify_recovered_fs(fs, model, ctx).unwrap_or_else(|e| panic!("{e}"));
}

/// Build the crashed-at-`(k, n)` device image by replaying the op
/// sequence against a fresh device with the cut armed.
fn crash_image(seed: u64, k: u64, n: usize) -> (Arc<Ssd>, Run) {
    let ssd = Arc::new(Ssd::new(SSD_BYTES, 512));
    let mut fs = DpuFs::format(ssd.clone(), cfg()).unwrap();
    ssd.arm_power_cut(k, n);
    let run = apply_ops(&mut fs, seed);
    drop(fs);
    ssd.power_restore();
    (ssd, run)
}

/// One crash point: remount the torn image and check every invariant.
fn check_crash_point(seed: u64, k: u64, n: usize) -> RecoveryReport {
    let (ssd, run) = crash_image(seed, k, n);
    let ctx = format!("seed {seed}, cut (write {k}, byte {n})");
    let (fs, report) = DpuFs::mount_with_report(ssd.clone(), cfg())
        .unwrap_or_else(|e| panic!("{ctx}: mount failed: {e}"));
    assert!(
        report.recovered_seq >= run.acked_seq,
        "{ctx}: committed op LOST — recovered seq {} < acked seq {}",
        report.recovered_seq,
        run.acked_seq
    );
    let model = run
        .model_at(report.recovered_seq)
        .unwrap_or_else(|| panic!("{ctx}: recovered seq {} never attempted", report.recovered_seq));
    assert_fs_matches(&fs, model, &ctx);
    drop(fs);
    if report.rolled_forward {
        // The mount repaired the superblock: a second mount must see a
        // clean image and land on the identical state.
        let (fs2, r2) = DpuFs::mount_with_report(ssd, cfg())
            .unwrap_or_else(|e| panic!("{ctx}: second mount failed: {e}"));
        assert_eq!(r2.recovered_seq, report.recovered_seq, "{ctx}: repair not idempotent");
        assert!(!r2.rolled_forward, "{ctx}: repair did not stick");
        assert_fs_matches(&fs2, model, &format!("{ctx} (second mount)"));
    }
    report
}

/// THE acceptance test: every SSD-write prefix of the seeded op
/// sequence is a crash point, and every one recovers consistently.
#[test]
fn crash_point_enumeration_recovers_every_write_prefix() {
    let seed = chaos_seed();
    // Scout pass: learn the deterministic write schedule.
    let ssd = Arc::new(Ssd::new(SSD_BYTES, 512));
    let mut fs = DpuFs::format(ssd.clone(), cfg()).unwrap();
    ssd.start_write_trace();
    let scout = apply_ops(&mut fs, seed);
    let trace = ssd.take_write_trace();
    drop(fs);
    assert!(scout.acked_seq > 1, "bootstrap must commit metadata ops");
    // Floor = the deterministic bootstrap's two syncs (3 writes each).
    assert!(trace.len() >= 6, "op sequence too quiet: {} writes", trace.len());

    let stride = stride();
    let (mut points, mut rolled) = (0u64, 0u64);
    for (k, &(_, len)) in trace.iter().enumerate() {
        let mut n = 0usize;
        loop {
            let report = check_crash_point(seed, k as u64, n);
            points += 1;
            rolled += report.rolled_forward as u64;
            if n >= len {
                break;
            }
            n = (n + stride).min(len);
        }
    }
    println!(
        "crash enumeration: {} writes, {points} crash points (stride {stride}), \
         {rolled} rolled forward",
        trace.len()
    );
    assert!(rolled > 0, "enumeration never hit a roll-forward window");
}

/// Durability-policy rollback: a control-plane op whose sync fails
/// non-fatally (metadata image grown past the superblock slot's
/// capacity) must be rolled back in memory — NOT left applied to be
/// silently persisted by a later op's successful sync.
#[test]
fn refused_metadata_op_is_rolled_back_not_persisted_later() {
    use dds::coordinator::{StorageServer, StorageServerConfig};
    let storage = StorageServer::build(
        StorageServerConfig { ssd_bytes: 64 << 10, segment_size: 4096, ..Default::default() },
        None,
    )
    .unwrap();
    let fe = storage.front_end();
    let dir = fe.create_directory("d").unwrap();
    // Create files until the metadata image no longer fits its slot
    // (slot capacity = segment_size/2 - frame header).
    let mut created = Vec::new();
    let refused = loop {
        let name = format!("file-{:04}", created.len());
        match fe.create_file(dir, &name) {
            Ok(f) => created.push(f),
            Err(_) => break name,
        }
        assert!(created.len() < 10_000, "image never hit the slot capacity");
    };
    // Free image space; the previously refused name must now be
    // creatable — a phantom in-memory file would collide instead.
    fe.delete_file(created.pop().unwrap()).unwrap();
    fe.delete_file(created.pop().unwrap()).unwrap();
    let f = fe.create_file(dir, &refused)
        .expect("refused op lingered in memory (rollback missing)");
    let n_files = created.len() + 1;
    // And nothing phantom survives a remount either.
    let ssd = storage.ssd.clone();
    drop(storage);
    let (fs, _) =
        DpuFs::mount_with_report(ssd, FsConfig { segment_size: 4096 }).unwrap();
    let metas = fs.list_dir(dir);
    assert_eq!(metas.len(), n_files, "remount must agree with the acked op set");
    assert!(metas.iter().any(|m| m.id == f.id && m.name == refused));
}

/// Idempotent replay: re-crash *inside recovery's own repair writes* —
/// every byte prefix of every repair write — and recover again to the
/// identical state.
#[test]
fn recrash_during_recovery_replays_idempotently() {
    let seed = chaos_seed();
    let ssd = Arc::new(Ssd::new(SSD_BYTES, 512));
    let mut fs = DpuFs::format(ssd.clone(), cfg()).unwrap();
    ssd.start_write_trace();
    apply_ops(&mut fs, seed);
    let trace = ssd.take_write_trace();
    drop(fs);

    let stride = stride();
    let mut outer = 0u64;
    let mut inner_points = 0u64;
    for (k, &(addr, len)) in trace.iter().enumerate() {
        if addr >= SEG {
            continue; // superblock-slot writes only: guaranteed roll-forward
        }
        let (k, n) = (k as u64, len / 2);
        // Scout this crash point's recovery write schedule.
        let (ssd, run) = crash_image(seed, k, n);
        ssd.start_write_trace();
        let (fs1, r1) = DpuFs::mount_with_report(ssd.clone(), cfg())
            .unwrap_or_else(|e| panic!("outer cut ({k},{n}): mount failed: {e}"));
        let rec_trace = ssd.take_write_trace();
        if !r1.rolled_forward {
            // Rare but legitimate: the torn slot bytes coincided with
            // the previous occupant's (images share long prefixes), so
            // the slot still checksums as the intended image — nothing
            // to repair, nothing to re-crash.
            assert!(rec_trace.is_empty(), "clean mount must not write");
            continue;
        }
        outer += 1;
        assert!(!rec_trace.is_empty(), "roll-forward must repair the superblock");
        let model = run.model_at(r1.recovered_seq).expect("attempted seq").clone();
        drop(fs1);

        for (rk, &(_, rlen)) in rec_trace.iter().enumerate() {
            let mut m = 0usize;
            loop {
                let ctx = format!(
                    "seed {seed}, outer cut ({k},{n}), recovery cut (write {rk}, byte {m})"
                );
                // Rebuild the crashed image, then cut recovery itself.
                let (ssd, _) = crash_image(seed, k, n);
                ssd.arm_power_cut(rk as u64, m);
                let cut_mount = DpuFs::mount_with_report(ssd.clone(), cfg());
                assert!(
                    cut_mount.is_err(),
                    "{ctx}: mount acknowledged success while its repair write died"
                );
                drop(cut_mount);
                // Reboot again: recovery must converge to the same state.
                ssd.power_restore();
                let (fs3, r3) = DpuFs::mount_with_report(ssd, cfg())
                    .unwrap_or_else(|e| panic!("{ctx}: post-recrash mount failed: {e}"));
                assert_eq!(
                    r3.recovered_seq, r1.recovered_seq,
                    "{ctx}: replay landed on a different sequence"
                );
                assert_fs_matches(&fs3, &model, &ctx);
                inner_points += 1;
                if m >= rlen {
                    break;
                }
                m = (m + stride).min(rlen);
            }
        }
    }
    assert!(outer > 0, "no superblock writes in the trace?");
    println!("re-crash enumeration: {outer} roll-forward points, {inner_points} recovery cuts");
}

// ---------------------------------------------------------------------
// WRITE crash matrix: every byte prefix of the durable data path
// ---------------------------------------------------------------------

/// Tiny segments keep every shadow pre-image (and therefore every data
/// crash point's byte enumeration) cheap while still forcing
/// multi-extent redirects; 64 segments is exactly the trailer table's
/// capacity at this segment size.
const DSEG: u64 = 1 << 10;
const DSSD_BYTES: u64 = 64 << 10;
/// Durable WRITE attempts per run (the first two are the base fills).
const DOPS: usize = 10;
/// Base image per file — 1.5 segments, so in-place writes can straddle
/// a segment boundary (two shadows, one commit record).
const DFILL: usize = (DSEG + DSEG / 2) as usize;
/// Ops in the journal-wrap run — enough small remap records to wrap
/// the one-segment journal and force wrap-guard checkpoints.
const WRAP_OPS: usize = 48;

fn dcfg() -> FsConfig {
    FsConfig { segment_size: DSEG }
}

fn splice(image: &mut Vec<u8>, offset: u64, data: &[u8]) {
    let end = offset as usize + data.len();
    if image.len() < end {
        image.resize(end, 0); // growth holes read as zeros (prepare zero-fills)
    }
    image[offset as usize..end].copy_from_slice(data);
}

/// Byte-image model of a durable-WRITE run. `snapshots[j]` is every
/// tracked file's contents after the first `j` WRITEs applied; entry
/// `acked + 1` (always present when an op failed) is the image the
/// in-flight op would have committed.
struct DataRun {
    snapshots: Vec<Vec<Vec<u8>>>,
    acked: usize,
}

/// Deterministic payload for op `i` — recovery verification recomputes
/// expected images from `(seed, op, offset)` alone.
fn dpattern(seed: u64, i: usize, offset: u64, len: u64) -> Vec<u8> {
    (0..len).map(|j| ((seed ^ (i as u64).wrapping_mul(31) ^ (offset + j)) % 253) as u8).collect()
}

/// Committed metadata bootstrap for the data matrix: one dir, the
/// tracked files, a single sync. Crash points start after this, so
/// every point's recovered namespace is fixed and only data moves.
fn data_bootstrap(fs: &mut DpuFs, names: &[&str]) -> Vec<FileId> {
    let d = fs.create_directory("d").expect("fresh fs");
    let ids = names.iter().map(|n| fs.create_file(d, n).expect("fresh fs")).collect();
    fs.sync_metadata().expect("bootstrap sync runs pre-cut");
    ids
}

/// The seeded durable WRITE mix: base fills, in-place overwrites,
/// segment-boundary straddles, and hole-leaving growth. Stops at the
/// first device error — the armed cut firing.
fn apply_data_ops(fs: &mut DpuFs, files: &[FileId], seed: u64) -> DataRun {
    let mut rng = Rng::new(seed ^ 0xDA7A_4002);
    let mut images: Vec<Vec<u8>> = vec![Vec::new(); files.len()];
    let mut snapshots = vec![images.clone()];
    let mut acked = 0usize;
    for i in 0..DOPS {
        let (f, offset, len) = if i < files.len() {
            (i, 0u64, DFILL as u64)
        } else {
            let f = rng.next_range(files.len() as u64) as usize;
            let len = 1 + rng.next_range(600);
            let cur = images[f].len() as u64;
            let offset = match rng.next_range(10) {
                // In-place overwrite inside the committed image.
                0..=5 => rng.next_range(cur.saturating_sub(len).max(1)),
                // Straddle the first segment boundary.
                6..=7 => DSEG.saturating_sub(len / 2),
                // Growth past EOF, sometimes leaving a zero hole.
                _ => cur + rng.next_range(DSEG / 2),
            };
            (f, offset, len)
        };
        let data = dpattern(seed, i, offset, len);
        let mut w = images.clone();
        splice(&mut w[f], offset, &data);
        snapshots.push(w.clone());
        if fs.write_durable(files[f], offset, &data).is_err() {
            return DataRun { snapshots, acked };
        }
        images = w;
        acked += 1;
    }
    DataRun { snapshots, acked }
}

/// A file's recovered bytes, straight off the device through its
/// extent mapping.
fn read_file_bytes(fs: &DpuFs, ssd: &Ssd, id: FileId, ctx: &str) -> Vec<u8> {
    let size = fs.file_meta(id).unwrap_or_else(|e| panic!("{ctx}: file lost: {e:?}")).size;
    let mut buf = vec![0u8; size as usize];
    fs.read(id, 0, &mut buf).unwrap_or_else(|e| panic!("{ctx}: read failed: {e:?}"));
    buf
}

/// Matrix failure: persist the failing crash point + the device write
/// schedule for CI artifact upload (satellite of the randomized-seed
/// job), then panic with the human-readable verdict.
fn matrix_fail(seed: u64, k: u64, n: usize, trace: &[(u64, usize)], msg: &str) -> ! {
    if let Ok(path) = std::env::var("DDS_CRASH_ARTIFACT") {
        let mut s = format!(
            "# failing WRITE crash point (reproduce: DDS_CHAOS_SEED={seed} \
             DDS_CRASH_STRIDE=1 cargo test --test crash_recovery)\n\
             seed={seed}\ncut_write={k}\ncut_bytes={n}\nreason={msg}\n\
             # device write schedule: index addr len\n"
        );
        for (i, (addr, len)) in trace.iter().enumerate() {
            s.push_str(&format!("{i} {addr} {len}\n"));
        }
        let _ = std::fs::write(&path, s);
    }
    panic!("{msg}");
}

/// One data crash point, with an **exact** expectation: the in-flight
/// WRITE is visible iff the cut landed on its remap-record append
/// (journal segment) and persisted every byte — the append IS the ack
/// point, so any shorter prefix anywhere leaves the WRITE invisible.
fn check_data_crash_point(seed: u64, k: u64, n: usize, trace: &[(u64, usize)]) {
    let ssd = Arc::new(Ssd::new(DSSD_BYTES, 512));
    let mut fs = DpuFs::format(ssd.clone(), dcfg()).unwrap();
    let files = data_bootstrap(&mut fs, &["f0", "f1"]);
    ssd.arm_power_cut(k, n);
    let run = apply_data_ops(&mut fs, &files, seed);
    drop(fs);
    ssd.power_restore();

    let ctx = format!("data matrix: seed {seed}, cut (write {k}, byte {n})");
    if run.acked >= DOPS {
        matrix_fail(seed, k, n, trace, &format!("{ctx}: armed cut never fired"));
    }
    let (addr, wlen) = trace[k as usize];
    let append_persisted = addr >= DSEG && addr < 2 * DSEG && n == wlen;
    let committed = run.acked + if append_persisted { 1 } else { 0 };
    let want = &run.snapshots[committed];

    let (fs, _report) = DpuFs::mount_with_report(ssd.clone(), dcfg())
        .unwrap_or_else(|e| matrix_fail(seed, k, n, trace, &format!("{ctx}: mount failed: {e}")));
    for (fi, id) in files.iter().enumerate() {
        let got = read_file_bytes(&fs, &ssd, *id, &ctx);
        if got != want[fi] {
            let other = &run.snapshots[run.acked + 1 - (committed - run.acked)][fi];
            matrix_fail(
                seed,
                k,
                n,
                trace,
                &format!(
                    "{ctx}: torn-write contract violated on f{fi}: recovered {} bytes, \
                     expected the {} image ({} bytes{}) — acked WRITE lost, un-acked \
                     WRITE surfaced, or a byte mix",
                    got.len(),
                    if append_persisted { "committed+in-flight" } else { "committed" },
                    want[fi].len(),
                    if got == *other { "; matches the OTHER side of the in-flight op" } else { "" },
                ),
            );
        }
    }
    // Structural invariants: mapping lengths, segment uniqueness,
    // bitmap accounting (no leaked shadow segments), id counters.
    let model = MetaModel {
        dirs: vec!["d".into()],
        files: files
            .iter()
            .enumerate()
            .map(|(fi, _)| ("d".to_string(), format!("f{fi}"), want[fi].len() as u64))
            .collect(),
    };
    verify_recovered_fs(&fs, &model, &ctx)
        .unwrap_or_else(|e| matrix_fail(seed, k, n, trace, &e.to_string()));
}

/// THE data-path acceptance test: every SSD-write prefix of the seeded
/// durable WRITE sequence is a crash point, and every one recovers to
/// the exact committed byte image.
#[test]
fn write_crash_matrix_recovers_every_byte_prefix() {
    let seed = chaos_seed();
    // Scout pass: learn the deterministic durable-write schedule, and
    // read back every committed image (the "read" leg of the mix).
    let ssd = Arc::new(Ssd::new(DSSD_BYTES, 512));
    let mut fs = DpuFs::format(ssd.clone(), dcfg()).unwrap();
    let files = data_bootstrap(&mut fs, &["f0", "f1"]);
    ssd.start_write_trace();
    let scout = apply_data_ops(&mut fs, &files, seed);
    let trace = ssd.take_write_trace();
    assert_eq!(scout.acked, DOPS, "scout pass must run fault-free");
    for (fi, id) in files.iter().enumerate() {
        let img = &scout.snapshots[DOPS][fi];
        let mut buf = vec![0u8; img.len()];
        fs.read(*id, 0, &mut buf).expect("clean-run read");
        assert_eq!(&buf, img, "clean-run read-back mismatch on f{fi}");
    }
    drop(fs);
    // Floor: every op writes at least a shadow pre-image, a trailer,
    // and the remap append.
    assert!(trace.len() >= 3 * DOPS, "durable path too quiet: {} writes", trace.len());

    let stride = stride();
    let (mut points, mut committed_flips) = (0u64, 0u64);
    for (k, &(_, len)) in trace.iter().enumerate() {
        let mut n = 0usize;
        loop {
            check_data_crash_point(seed, k as u64, n, &trace);
            points += 1;
            if n >= len {
                break;
            }
            n = (n + stride).min(len);
        }
        let (addr, _) = trace[k];
        committed_flips += (addr >= DSEG && addr < 2 * DSEG) as u64;
    }
    println!(
        "WRITE crash matrix: {} writes, {points} crash points (stride {stride}), \
         {committed_flips} ack-point writes",
        trace.len()
    );
    assert!(committed_flips > 0, "no remap appends in the trace?");
}

/// Satellite regression: a power cut during a **journal wrap** while a
/// data remap record is in flight. The wrap guard checkpoints the
/// metadata image (a superblock-slot write) *before* burning the
/// commit sequence, so a cut anywhere in that window — including mid-
/// checkpoint — must roll the in-flight WRITE back cleanly: committed
/// bytes intact, superseded shadows reclaimed, bitmap equal to the
/// model.
#[test]
fn journal_wrap_crash_with_inflight_remap_rolls_back_cleanly() {
    let seed = chaos_seed();

    fn apply_wrap_ops(fs: &mut DpuFs, file: FileId, seed: u64) -> DataRun {
        let mut rng = Rng::new(seed ^ 0xDA7A_4003);
        let mut image: Vec<u8> = Vec::new();
        let mut snapshots = vec![vec![image.clone()]];
        let mut acked = 0usize;
        for i in 0..WRAP_OPS {
            let (offset, len) = if i == 0 {
                (0u64, DFILL as u64)
            } else {
                let len = 1 + rng.next_range(96);
                (rng.next_range(image.len() as u64 + 32), len)
            };
            let data = dpattern(seed, i, offset, len);
            let mut w = image.clone();
            splice(&mut w, offset, &data);
            snapshots.push(vec![w.clone()]);
            if fs.write_durable(file, offset, &data).is_err() {
                return DataRun { snapshots, acked };
            }
            image = w;
            acked += 1;
        }
        DataRun { snapshots, acked }
    }

    // Scout: find the wrap-guard checkpoint writes. Post-bootstrap the
    // op mix never syncs metadata, so every superblock-segment write in
    // the trace IS a wrap checkpoint with a remap record in flight.
    let ssd = Arc::new(Ssd::new(DSSD_BYTES, 512));
    let mut fs = DpuFs::format(ssd.clone(), dcfg()).unwrap();
    let file = data_bootstrap(&mut fs, &["w"])[0];
    ssd.start_write_trace();
    let scout = apply_wrap_ops(&mut fs, file, seed);
    let trace = ssd.take_write_trace();
    drop(fs);
    assert_eq!(scout.acked, WRAP_OPS, "scout pass must run fault-free");
    let wraps: Vec<usize> = trace
        .iter()
        .enumerate()
        .filter(|&(_, &(addr, _))| addr < DSEG)
        .map(|(i, _)| i)
        .collect();
    assert!(!wraps.is_empty(), "{WRAP_OPS} remap records never wrapped the journal");

    for &k in &wraps {
        let len = trace[k].1;
        for n in [0, len / 2, len] {
            let ctx = format!("wrap crash: seed {seed}, checkpoint write {k}, byte {n}");
            let ssd = Arc::new(Ssd::new(DSSD_BYTES, 512));
            let mut fs = DpuFs::format(ssd.clone(), dcfg()).unwrap();
            let file = data_bootstrap(&mut fs, &["w"])[0];
            ssd.arm_power_cut(k as u64, n);
            let run = apply_wrap_ops(&mut fs, file, seed);
            drop(fs);
            ssd.power_restore();
            assert!(run.acked < WRAP_OPS, "{ctx}: cut never fired");

            // The torn write is the checkpoint, never the remap append:
            // the in-flight WRITE must be invisible at every prefix.
            let (fs, _) = DpuFs::mount_with_report(ssd.clone(), dcfg())
                .unwrap_or_else(|e| panic!("{ctx}: mount failed: {e}"));
            let got = read_file_bytes(&fs, &ssd, file, &ctx);
            assert_eq!(
                got, run.snapshots[run.acked][0],
                "{ctx}: in-flight WRITE not rolled back to the committed image"
            );
            let model = MetaModel {
                dirs: vec!["d".into()],
                files: vec![("d".into(), "w".into(), got.len() as u64)],
            };
            verify_recovered_fs(&fs, &model, &ctx).unwrap_or_else(|e| panic!("{e}"));
        }
    }
    println!("journal-wrap crash: {} checkpoint writes × 3 prefixes recovered", wraps.len());
}
