//! Crash-point enumeration harness — the acceptance test of the
//! durability plane.
//!
//! A seeded metadata op sequence (create/delete/write/grow, each
//! metadata op followed by the crash-consistent sync) is first run
//! clean while tracing every device write. Then **every SSD-write
//! prefix** of that schedule becomes a crash point: for each write `k`
//! and each byte offset `n` within it, a fresh run is cut at exactly
//! `(k, n)` — the write persists only its first `n` bytes and the
//! device dies — and the image is remounted. The invariants, at every
//! single point:
//!
//! * `mount` succeeds — no panic, no `Corrupt` rejection;
//! * the recovered file system equals the in-memory model at the last
//!   committed sequence (no metadata loss: every acked sync survives;
//!   nothing uncommitted is invented);
//! * no segment is double-allocated or out of range, the bitmap
//!   accounting balances, and the id counters cannot reuse a live id;
//! * a re-crash *during recovery's own repair writes* recovers to the
//!   identical state (idempotent replay).
//!
//! `DDS_CRASH_STRIDE` (default 1 = every byte) coarsens the byte
//! enumeration for quick local runs; `DDS_CHAOS_SEED` picks the op
//! sequence.

use std::sync::Arc;

use dds::dpufs::{DirId, DpuFs, FileId, FsConfig, RecoveryReport};
use dds::fault::scenario::{verify_recovered_fs, MetaModel};
use dds::sim::Rng;
use dds::ssd::Ssd;

#[path = "chaos_common.rs"]
mod chaos_common;
use chaos_common::chaos_seed;

/// Small segments keep every metadata image (and therefore every crash
/// point's replay) byte-cheap while still exercising multi-extent I/O.
const SEG: u64 = 1 << 13;
const SSD_BYTES: u64 = 512 << 10; // 64 segments
const OPS: usize = 12;

fn cfg() -> FsConfig {
    FsConfig { segment_size: SEG }
}

fn stride() -> usize {
    std::env::var("DDS_CRASH_STRIDE")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .filter(|&s| s >= 1)
        .unwrap_or(1)
}

struct Run {
    /// `(seq, model)` per attempted sync; seq 1 = formatted-empty.
    /// The model is the scenario harness's [`MetaModel`], so both
    /// suites check recovery through one verifier.
    snapshots: Vec<(u64, MetaModel)>,
    /// Highest sequence whose sync returned Ok.
    acked_seq: u64,
}

impl Run {
    fn model_at(&self, seq: u64) -> Option<&MetaModel> {
        self.snapshots.iter().rev().find(|(s, _)| *s == seq).map(|(_, m)| m)
    }
}

/// Apply the seeded op sequence to a freshly formatted fs, mirroring
/// the file service's durability policy (sync after every metadata op;
/// data-plane writes don't sync). Stops at the first device error —
/// that is the armed power cut firing; in-memory-only ops can't fail.
fn apply_ops(fs: &mut DpuFs, seed: u64) -> Run {
    let mut rng = Rng::new(seed ^ 0xC4A5_4002);
    let mut model = MetaModel::default();
    let mut dir_ids: Vec<DirId> = Vec::new();
    let mut live: Vec<(FileId, String, String, u64)> = Vec::new();
    let mut snapshots = vec![(1u64, MetaModel::default())];
    let mut acked_seq = 1u64;

    // Deterministic bootstrap: one committed dir + file regardless of
    // the seed's draw luck, so every op branch has a target and a quiet
    // seed can never produce an empty cut window (which would trip the
    // harness asserts, not the durability plane).
    for boot in 0..2 {
        let mut m = model.clone();
        if boot == 0 {
            dir_ids.push(fs.create_directory("d-base").expect("fresh fs"));
            m.dirs.push("d-base".into());
        } else {
            let id = fs.create_file(dir_ids[0], "f-base").expect("fresh fs");
            live.push((id, "d-base".into(), "f-base".into(), 0));
            m.files.push(("d-base".into(), "f-base".into(), 0));
        }
        snapshots.push((acked_seq + 1, m.clone()));
        if fs.sync_metadata().is_err() {
            return Run { snapshots, acked_seq };
        }
        model = m;
        acked_seq += 1;
    }

    for i in 0..OPS {
        match rng.next_range(10) {
            0..=2 => {
                let name = format!("d{i}");
                dir_ids.push(fs.create_directory(&name).expect("unique dir name"));
                let mut m = model.clone();
                m.dirs.push(name);
                snapshots.push((acked_seq + 1, m.clone()));
                if fs.sync_metadata().is_err() {
                    return Run { snapshots, acked_seq };
                }
                model = m;
                acked_seq += 1;
            }
            3..=5 => {
                let Some(&dir) = dir_ids.last() else { continue };
                let dname = model.dirs.last().expect("dir_ids tracks model.dirs").clone();
                let name = format!("f{i}");
                let id = fs.create_file(dir, &name).expect("unique file name");
                live.push((id, dname.clone(), name.clone(), 0));
                let mut m = model.clone();
                m.files.push((dname, name, 0));
                snapshots.push((acked_seq + 1, m.clone()));
                if fs.sync_metadata().is_err() {
                    return Run { snapshots, acked_seq };
                }
                model = m;
                acked_seq += 1;
            }
            6..=7 => {
                // Data-plane append: device writes, no metadata sync.
                if live.is_empty() {
                    continue;
                }
                let fi = rng.next_range(live.len() as u64) as usize;
                let len = 1 + rng.next_range(48) as usize;
                let off = live[fi].3;
                let data: Vec<u8> =
                    (0..len).map(|j| ((off as usize + j) % 251) as u8).collect();
                if fs.write(live[fi].0, off, &data).is_err() {
                    return Run { snapshots, acked_seq };
                }
                live[fi].3 = off + len as u64;
                let (_, ref d, ref n, sz) = live[fi];
                let e = model
                    .files
                    .iter_mut()
                    .find(|(fd, fnm, _)| fd == d && fnm == n)
                    .expect("model tracks every live file");
                e.2 = sz;
            }
            8 => {
                // Explicit grow — a metadata op: synced.
                if live.is_empty() {
                    continue;
                }
                let fi = rng.next_range(live.len() as u64) as usize;
                let grow = live[fi].3 + 1 + rng.next_range(SEG);
                fs.ensure_size(live[fi].0, grow).expect("growth stays within the device");
                live[fi].3 = live[fi].3.max(grow);
                let mut m = model.clone();
                {
                    let (_, ref d, ref n, _) = live[fi];
                    let e = m
                        .files
                        .iter_mut()
                        .find(|(fd, fnm, _)| fd == d && fnm == n)
                        .expect("model tracks every live file");
                    e.2 = e.2.max(grow);
                }
                snapshots.push((acked_seq + 1, m.clone()));
                if fs.sync_metadata().is_err() {
                    return Run { snapshots, acked_seq };
                }
                model = m;
                acked_seq += 1;
            }
            _ => {
                if live.is_empty() {
                    continue;
                }
                let fi = rng.next_range(live.len() as u64) as usize;
                let (id, d, n, _) = live.remove(fi);
                fs.delete_file(id).expect("live file");
                let mut m = model.clone();
                m.files.retain(|(fd, fnm, _)| !(fd == &d && fnm == &n));
                snapshots.push((acked_seq + 1, m.clone()));
                if fs.sync_metadata().is_err() {
                    return Run { snapshots, acked_seq };
                }
                model = m;
                acked_seq += 1;
            }
        }
    }
    Run { snapshots, acked_seq }
}

/// Full recovered-state check through the ONE shared verifier
/// (`dds::fault::scenario::verify_recovered_fs`): model equality +
/// segment/bitmap/counter invariants.
fn assert_fs_matches(fs: &DpuFs, model: &MetaModel, ctx: &str) {
    verify_recovered_fs(fs, model, ctx).unwrap_or_else(|e| panic!("{e}"));
}

/// Build the crashed-at-`(k, n)` device image by replaying the op
/// sequence against a fresh device with the cut armed.
fn crash_image(seed: u64, k: u64, n: usize) -> (Arc<Ssd>, Run) {
    let ssd = Arc::new(Ssd::new(SSD_BYTES, 512));
    let mut fs = DpuFs::format(ssd.clone(), cfg()).unwrap();
    ssd.arm_power_cut(k, n);
    let run = apply_ops(&mut fs, seed);
    drop(fs);
    ssd.power_restore();
    (ssd, run)
}

/// One crash point: remount the torn image and check every invariant.
fn check_crash_point(seed: u64, k: u64, n: usize) -> RecoveryReport {
    let (ssd, run) = crash_image(seed, k, n);
    let ctx = format!("seed {seed}, cut (write {k}, byte {n})");
    let (fs, report) = DpuFs::mount_with_report(ssd.clone(), cfg())
        .unwrap_or_else(|e| panic!("{ctx}: mount failed: {e}"));
    assert!(
        report.recovered_seq >= run.acked_seq,
        "{ctx}: committed op LOST — recovered seq {} < acked seq {}",
        report.recovered_seq,
        run.acked_seq
    );
    let model = run
        .model_at(report.recovered_seq)
        .unwrap_or_else(|| panic!("{ctx}: recovered seq {} never attempted", report.recovered_seq));
    assert_fs_matches(&fs, model, &ctx);
    drop(fs);
    if report.rolled_forward {
        // The mount repaired the superblock: a second mount must see a
        // clean image and land on the identical state.
        let (fs2, r2) = DpuFs::mount_with_report(ssd, cfg())
            .unwrap_or_else(|e| panic!("{ctx}: second mount failed: {e}"));
        assert_eq!(r2.recovered_seq, report.recovered_seq, "{ctx}: repair not idempotent");
        assert!(!r2.rolled_forward, "{ctx}: repair did not stick");
        assert_fs_matches(&fs2, model, &format!("{ctx} (second mount)"));
    }
    report
}

/// THE acceptance test: every SSD-write prefix of the seeded op
/// sequence is a crash point, and every one recovers consistently.
#[test]
fn crash_point_enumeration_recovers_every_write_prefix() {
    let seed = chaos_seed();
    // Scout pass: learn the deterministic write schedule.
    let ssd = Arc::new(Ssd::new(SSD_BYTES, 512));
    let mut fs = DpuFs::format(ssd.clone(), cfg()).unwrap();
    ssd.start_write_trace();
    let scout = apply_ops(&mut fs, seed);
    let trace = ssd.take_write_trace();
    drop(fs);
    assert!(scout.acked_seq > 1, "bootstrap must commit metadata ops");
    // Floor = the deterministic bootstrap's two syncs (3 writes each).
    assert!(trace.len() >= 6, "op sequence too quiet: {} writes", trace.len());

    let stride = stride();
    let (mut points, mut rolled) = (0u64, 0u64);
    for (k, &(_, len)) in trace.iter().enumerate() {
        let mut n = 0usize;
        loop {
            let report = check_crash_point(seed, k as u64, n);
            points += 1;
            rolled += report.rolled_forward as u64;
            if n >= len {
                break;
            }
            n = (n + stride).min(len);
        }
    }
    println!(
        "crash enumeration: {} writes, {points} crash points (stride {stride}), \
         {rolled} rolled forward",
        trace.len()
    );
    assert!(rolled > 0, "enumeration never hit a roll-forward window");
}

/// Durability-policy rollback: a control-plane op whose sync fails
/// non-fatally (metadata image grown past the superblock slot's
/// capacity) must be rolled back in memory — NOT left applied to be
/// silently persisted by a later op's successful sync.
#[test]
fn refused_metadata_op_is_rolled_back_not_persisted_later() {
    use dds::coordinator::{StorageServer, StorageServerConfig};
    let storage = StorageServer::build(
        StorageServerConfig { ssd_bytes: 64 << 10, segment_size: 4096, ..Default::default() },
        None,
    )
    .unwrap();
    let fe = storage.front_end();
    let dir = fe.create_directory("d").unwrap();
    // Create files until the metadata image no longer fits its slot
    // (slot capacity = segment_size/2 - frame header).
    let mut created = Vec::new();
    let refused = loop {
        let name = format!("file-{:04}", created.len());
        match fe.create_file(dir, &name) {
            Ok(f) => created.push(f),
            Err(_) => break name,
        }
        assert!(created.len() < 10_000, "image never hit the slot capacity");
    };
    // Free image space; the previously refused name must now be
    // creatable — a phantom in-memory file would collide instead.
    fe.delete_file(created.pop().unwrap()).unwrap();
    fe.delete_file(created.pop().unwrap()).unwrap();
    let f = fe.create_file(dir, &refused)
        .expect("refused op lingered in memory (rollback missing)");
    let n_files = created.len() + 1;
    // And nothing phantom survives a remount either.
    let ssd = storage.ssd.clone();
    drop(storage);
    let (fs, _) =
        DpuFs::mount_with_report(ssd, FsConfig { segment_size: 4096 }).unwrap();
    let metas = fs.list_dir(dir);
    assert_eq!(metas.len(), n_files, "remount must agree with the acked op set");
    assert!(metas.iter().any(|m| m.id == f.id && m.name == refused));
}

/// Idempotent replay: re-crash *inside recovery's own repair writes* —
/// every byte prefix of every repair write — and recover again to the
/// identical state.
#[test]
fn recrash_during_recovery_replays_idempotently() {
    let seed = chaos_seed();
    let ssd = Arc::new(Ssd::new(SSD_BYTES, 512));
    let mut fs = DpuFs::format(ssd.clone(), cfg()).unwrap();
    ssd.start_write_trace();
    apply_ops(&mut fs, seed);
    let trace = ssd.take_write_trace();
    drop(fs);

    let stride = stride();
    let mut outer = 0u64;
    let mut inner_points = 0u64;
    for (k, &(addr, len)) in trace.iter().enumerate() {
        if addr >= SEG {
            continue; // superblock-slot writes only: guaranteed roll-forward
        }
        let (k, n) = (k as u64, len / 2);
        // Scout this crash point's recovery write schedule.
        let (ssd, run) = crash_image(seed, k, n);
        ssd.start_write_trace();
        let (fs1, r1) = DpuFs::mount_with_report(ssd.clone(), cfg())
            .unwrap_or_else(|e| panic!("outer cut ({k},{n}): mount failed: {e}"));
        let rec_trace = ssd.take_write_trace();
        if !r1.rolled_forward {
            // Rare but legitimate: the torn slot bytes coincided with
            // the previous occupant's (images share long prefixes), so
            // the slot still checksums as the intended image — nothing
            // to repair, nothing to re-crash.
            assert!(rec_trace.is_empty(), "clean mount must not write");
            continue;
        }
        outer += 1;
        assert!(!rec_trace.is_empty(), "roll-forward must repair the superblock");
        let model = run.model_at(r1.recovered_seq).expect("attempted seq").clone();
        drop(fs1);

        for (rk, &(_, rlen)) in rec_trace.iter().enumerate() {
            let mut m = 0usize;
            loop {
                let ctx = format!(
                    "seed {seed}, outer cut ({k},{n}), recovery cut (write {rk}, byte {m})"
                );
                // Rebuild the crashed image, then cut recovery itself.
                let (ssd, _) = crash_image(seed, k, n);
                ssd.arm_power_cut(rk as u64, m);
                let cut_mount = DpuFs::mount_with_report(ssd.clone(), cfg());
                assert!(
                    cut_mount.is_err(),
                    "{ctx}: mount acknowledged success while its repair write died"
                );
                drop(cut_mount);
                // Reboot again: recovery must converge to the same state.
                ssd.power_restore();
                let (fs3, r3) = DpuFs::mount_with_report(ssd, cfg())
                    .unwrap_or_else(|e| panic!("{ctx}: post-recrash mount failed: {e}"));
                assert_eq!(
                    r3.recovered_seq, r1.recovered_seq,
                    "{ctx}: replay landed on a different sequence"
                );
                assert_fs_matches(&fs3, &model, &ctx);
                inner_points += 1;
                if m >= rlen {
                    break;
                }
                m = (m + stride).min(rlen);
            }
        }
    }
    assert!(outer > 0, "no superblock writes in the trace?");
    println!("re-crash enumeration: {outer} roll-forward points, {inner_points} recovery cuts");
}
