//! Determinism of the fault plane: the same seed must replay the
//! identical fault schedule AND the identical per-request outcome
//! trace, run after run, fresh server each time.
//!
//! Wire-chaos scenarios are excluded here on purpose: their decision
//! streams are consumed per segment, and the *number* of segments
//! depends on ACK timing, so only their per-seed reproducibility within
//! one interleaving is meaningful — the SSD/engine/stall planes consume
//! decisions in request order and replay exactly.

use dds::fault::{data_crash, run_scenario, Scenario};

#[path = "chaos_common.rs"]
mod chaos_common;
use chaos_common::chaos_seed;

/// Acceptance criterion: the same seed replays the identical fault
/// schedule (and outcome trace) across independent runs.
#[test]
fn same_seed_replays_identical_schedule_and_outcomes() {
    let seed = chaos_seed();
    for sc in [
        Scenario::ssd_chaos(seed),
        Scenario::engine_failover(seed),
        Scenario::engine_restart(seed),
        Scenario::group_stall(seed),
    ] {
        let a = run_scenario(&sc).unwrap_or_else(|e| panic!("{} run 1: {e}", sc.name));
        let b = run_scenario(&sc).unwrap_or_else(|e| panic!("{} run 2: {e}", sc.name));
        assert_eq!(
            a.schedule, b.schedule,
            "scenario '{}' (seed {seed}): fault schedule not reproducible",
            sc.name
        );
        assert_eq!(
            a.outcomes, b.outcomes,
            "scenario '{}' (seed {seed}): outcome trace not reproducible",
            sc.name
        );
        assert_eq!((a.ok, a.err), (b.ok, b.err), "scenario '{}' totals", sc.name);
        println!(
            "{}: replayed {} injections / {} outcomes identically",
            sc.name,
            a.schedule.len(),
            a.outcomes.len()
        );
    }
}

/// The data-crash scenario's same-seed contract: identical fault
/// schedule, identical per-WRITE outcome trace, identical recovered
/// file sizes and recovery report, run after run. The WRITE driver is
/// deliberately serialized so the device write schedule (and therefore
/// the cut point's meaning) cannot drift between runs.
#[test]
fn data_crash_same_seed_replays_identical_outcome_trace() {
    let seed = chaos_seed();
    let a = data_crash(seed).expect("data_crash run 1");
    let b = data_crash(seed).expect("data_crash run 2");
    assert_eq!(a.schedule, b.schedule, "seed {seed}: fault schedule not reproducible");
    assert_eq!(
        (a.cut_write, a.cut_bytes),
        (b.cut_write, b.cut_bytes),
        "seed {seed}: cut point not seeded"
    );
    assert_eq!(a.outcomes, b.outcomes, "seed {seed}: WRITE outcome trace not reproducible");
    assert_eq!(
        (a.writes_acked, a.writes_failed, a.ambiguous_tenant),
        (b.writes_acked, b.writes_failed, b.ambiguous_tenant),
        "seed {seed}: outcome totals drifted"
    );
    assert_eq!(a.recovered_sizes, b.recovered_sizes, "seed {seed}: recovered state drifted");
    assert_eq!(a.recovery, b.recovery, "seed {seed}: recovery report not deterministic");
    println!(
        "data_crash: replayed {} outcomes identically (cut write {} byte {})",
        a.outcomes.len(),
        a.cut_write,
        a.cut_bytes
    );
}

/// Different seeds must produce different schedules — the seed is the
/// whole entropy source, not a label.
#[test]
fn different_seeds_produce_different_schedules() {
    let seed = chaos_seed();
    let a = run_scenario(&Scenario::ssd_chaos(seed)).expect("run a");
    let b = run_scenario(&Scenario::ssd_chaos(seed ^ 0x5555_5555)).expect("run b");
    assert!(!a.schedule.is_empty() && !b.schedule.is_empty());
    assert_ne!(
        a.schedule, b.schedule,
        "independent seeds rolled the identical schedule — entropy is not flowing"
    );
}
