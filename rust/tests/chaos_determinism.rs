//! Determinism of the fault plane: the same seed must replay the
//! identical fault schedule AND the identical per-request outcome
//! trace, run after run, fresh server each time.
//!
//! Wire-chaos scenarios are excluded here on purpose: their decision
//! streams are consumed per segment, and the *number* of segments
//! depends on ACK timing, so only their per-seed reproducibility within
//! one interleaving is meaningful — the SSD/engine/stall planes consume
//! decisions in request order and replay exactly.

use dds::fault::{run_scenario, Scenario};

#[path = "chaos_common.rs"]
mod chaos_common;
use chaos_common::chaos_seed;

/// Acceptance criterion: the same seed replays the identical fault
/// schedule (and outcome trace) across independent runs.
#[test]
fn same_seed_replays_identical_schedule_and_outcomes() {
    let seed = chaos_seed();
    for sc in [
        Scenario::ssd_chaos(seed),
        Scenario::engine_failover(seed),
        Scenario::engine_restart(seed),
        Scenario::group_stall(seed),
    ] {
        let a = run_scenario(&sc).unwrap_or_else(|e| panic!("{} run 1: {e}", sc.name));
        let b = run_scenario(&sc).unwrap_or_else(|e| panic!("{} run 2: {e}", sc.name));
        assert_eq!(
            a.schedule, b.schedule,
            "scenario '{}' (seed {seed}): fault schedule not reproducible",
            sc.name
        );
        assert_eq!(
            a.outcomes, b.outcomes,
            "scenario '{}' (seed {seed}): outcome trace not reproducible",
            sc.name
        );
        assert_eq!((a.ok, a.err), (b.ok, b.err), "scenario '{}' totals", sc.name);
        println!(
            "{}: replayed {} injections / {} outcomes identically",
            sc.name,
            a.schedule.len(),
            a.outcomes.len()
        );
    }
}

/// Different seeds must produce different schedules — the seed is the
/// whole entropy source, not a label.
#[test]
fn different_seeds_produce_different_schedules() {
    let seed = chaos_seed();
    let a = run_scenario(&Scenario::ssd_chaos(seed)).expect("run a");
    let b = run_scenario(&Scenario::ssd_chaos(seed ^ 0x5555_5555)).expect("run b");
    assert!(!a.schedule.is_empty() && !b.schedule.is_empty());
    assert_ne!(
        a.schedule, b.schedule,
        "independent seeds rolled the identical schedule — entropy is not flowing"
    );
}
