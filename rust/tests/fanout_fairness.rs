//! Fanout fairness suite: the readiness-driven flow table and the
//! tenant QoS plane under DBMS-grade connection counts.
//!
//! Each case drives the full functional plane (client TCP → RSS shard
//! → flow table → colocated engine → SSD) through the chaos harness
//! with `ssd_chaos`-grade faults, at 100 / 1k / 10k flows spread over
//! a zipfian tenant mix, and asserts the fanout plane's contract:
//!
//! * **Byte-exactness + bounded completion** — enforced by
//!   `run_scenario` itself: every OK response carries exactly the
//!   predicted fill bytes, every request resolves within the round
//!   timeout.
//! * **No starved tenant** — every tenant admits traffic, and every
//!   admitted request completes; per-tenant pending drains to zero.
//! * **Exact flow accounting** — the flow table holds exactly the open
//!   flows (state scales with connections, nothing leaks, nothing is
//!   double-created on re-delivery).
//! * **CPU plane intact at fanout** — after quiesce every pump settles
//!   into its park rung (`assert_parked` against the CpuLedger): ten
//!   thousand open-but-idle flows must not keep a single pump busy.

use std::collections::HashMap;
use std::time::Duration;

use dds::director::TenantPlaneConfig;
use dds::fault::{run_scenario, Scenario};
use dds::idle::IdlePolicy;
use dds::sim::Rng;

const TENANTS: u32 = 8;

/// Zipfian-ish tenant mix: tenant `r` drawn with weight ∝ 1/(r+1).
/// Returns one client IP per connection; the tenant plane keys tenants
/// on `client_ip % tenants`, so IP `0x0a00_0000 + t` bills tenant `t`.
fn zipf_ips(n: usize, seed: u64) -> Vec<u32> {
    let weights: Vec<u64> = (0..TENANTS as u64).map(|r| 840 / (r + 1)).collect();
    let total: u64 = weights.iter().sum();
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut draw = rng.next_range(total);
            let mut tenant = TENANTS - 1;
            for (r, &w) in weights.iter().enumerate() {
                if draw < w {
                    tenant = r as u32;
                    break;
                }
                draw -= w;
            }
            0x0a00_0000u32 + tenant
        })
        .collect()
}

fn fanout_scenario(flows: usize, rounds: usize, batch: usize, seed: u64) -> Scenario {
    let shards = 2;
    assert_eq!(flows % shards, 0);
    let cps = flows / shards;
    Scenario {
        conns_per_shard: cps,
        client_ips: zipf_ips(flows, seed ^ 0xFA00),
        tenants: TenantPlaneConfig {
            tenants: TENANTS,
            // Skewed weights so the weighted fair drain actually
            // bucketing-drains (any tenants > 1 does, but unequal
            // weights exercise the round arithmetic too).
            weights: vec![4, 2, 1, 1, 1, 1, 1, 1],
            // No eviction during the run: a slow CI round must never
            // tear down a live connection's PEP mid-conversation.
            flow_ttl_ms: 3_600_000,
            ..Default::default()
        },
        rounds,
        batch,
        // Tight spin budget so parks actually happen between bursts —
        // the post-quiesce park assert needs the ladder reachable.
        idle: IdlePolicy::Adaptive { spin_iters: 16, park_timeout: Duration::from_millis(2) },
        assert_parked: true,
        round_timeout: Duration::from_secs(180),
        ..Scenario::ssd_chaos(seed)
    }
}

fn run_fanout(flows: usize, rounds: usize, batch: usize, seed: u64) {
    let sc = fanout_scenario(flows, rounds, batch, seed);
    let report = run_scenario(&sc).expect("fanout scenario must complete");
    let total = sc.total_requests();
    assert_eq!(report.ok + report.err, total, "bounded completion: every request resolves");
    assert!(report.ok > 0, "chaos must not fail every request");

    // Exact flow accounting: one flow per connection, all still open
    // (the TTL is parked far out), none double-created.
    assert_eq!(report.stats.flows_created, flows as u64);
    assert_eq!(report.stats.flows, flows as u64);
    assert_eq!(report.stats.flows_closed, 0);

    // Tenant fairness: every tenant got service, every admitted
    // request completed, and with no QoS limits configured nothing was
    // rejected or throttled.
    let by_tenant: HashMap<u32, _> =
        report.tenants.iter().map(|t| (t.tenant, *t)).collect();
    let mut admitted_sum = 0u64;
    for t in 0..TENANTS {
        let c = by_tenant
            .get(&t)
            .unwrap_or_else(|| panic!("tenant {t} missing from tenant stats"));
        assert!(c.admitted > 0, "tenant {t} starved: nothing admitted");
        assert_eq!(c.completed, c.admitted, "tenant {t}: admitted != completed");
        assert_eq!(c.pending, 0, "tenant {t}: pending must drain to zero");
        assert_eq!(c.rejected_pending, 0, "tenant {t}: rejected with no limits set");
        assert_eq!(c.throttled, 0, "tenant {t}: throttled with no rate set");
        assert!(c.flows > 0, "tenant {t} owns no flows");
        admitted_sum += c.admitted;
    }
    assert_eq!(admitted_sum, total, "every request billed to exactly one tenant");
}

#[test]
fn fanout_100_flows() {
    run_fanout(100, 3, 4, 11);
}

#[test]
fn fanout_1k_flows() {
    run_fanout(1000, 2, 2, 12);
}

/// The full 10k-flow sweep. Heavyweight in debug builds, so it is
/// ignored by default — `cargo test -- --ignored` runs it, and the
/// release-mode fanout bench (`BENCH_fanout.json`) exercises 10k flows
/// on every CI run.
#[test]
#[ignore = "10k flows is heavyweight in debug builds; covered in release by the fanout bench"]
fn fanout_10k_flows() {
    run_fanout(10_000, 1, 1, 13);
}
