//! Property tests for the ring buffers (hand-rolled generator — this
//! offline environment has no proptest; `dds::sim::Rng` provides the
//! deterministic randomness, and every case prints its seed on failure).

use std::collections::VecDeque;

use dds::dma::DmaChannel;
use dds::ring::{FarmRing, LockedRing, ProgressRing, RequestRing, ResponseRing, RingStatus};
use dds::sim::Rng;

/// Model-based check: a ring driven by a random push/pop schedule must
/// behave exactly like a bounded FIFO queue.
fn check_against_model(ring: &dyn RequestRing, seed: u64, can_reject_any: bool) {
    let mut rng = Rng::new(seed);
    let mut model: VecDeque<Vec<u8>> = VecDeque::new();
    let mut next = 0u64;
    for step in 0..3000 {
        if rng.next_f64() < 0.6 {
            // Push a random-size message.
            let len = 1 + rng.next_range(64) as usize;
            let mut msg = vec![0u8; len];
            msg[..8.min(len)].copy_from_slice(&next.to_le_bytes()[..8.min(len)]);
            match ring.try_push(&msg) {
                RingStatus::Ok => {
                    model.push_back(msg);
                    next += 1;
                }
                RingStatus::Retry => {
                    // Backpressure is allowed; it must not lose data.
                    assert!(
                        can_reject_any || !model.is_empty(),
                        "seed {seed} step {step}: empty ring rejected a push"
                    );
                }
                RingStatus::Empty => unreachable!(),
            }
        } else {
            let mut got: Vec<Vec<u8>> = Vec::new();
            ring.pop_batch(&mut |m| got.push(m.to_vec()));
            for g in got {
                let want = model
                    .pop_front()
                    .unwrap_or_else(|| panic!("seed {seed} step {step}: spurious message"));
                assert_eq!(g, want, "seed {seed} step {step}: FIFO violated");
            }
        }
    }
    // Drain and confirm nothing is lost.
    let mut tail: Vec<Vec<u8>> = Vec::new();
    for _ in 0..1000 {
        ring.pop_batch(&mut |m| tail.push(m.to_vec()));
        if model.len() == tail.len() {
            break;
        }
    }
    assert_eq!(tail.len(), model.len(), "seed {seed}: lost messages at drain");
    for (g, want) in tail.iter().zip(model.iter()) {
        assert_eq!(g, want, "seed {seed}: tail drain mismatch");
    }
}

#[test]
fn progress_ring_matches_fifo_model() {
    for seed in 1..=20u64 {
        let ring = ProgressRing::new(1 << 12, 1 << 10);
        check_against_model(&ring, seed, false);
    }
}

#[test]
fn farm_ring_matches_fifo_model() {
    for seed in 1..=20u64 {
        let ring = FarmRing::new(64, 80);
        check_against_model(&ring, seed, false);
    }
}

#[test]
fn locked_ring_matches_fifo_model() {
    for seed in 1..=20u64 {
        let ring = LockedRing::new(256);
        check_against_model(&ring, seed, false);
    }
}

/// Invariant: the progress ring's backlog never exceeds M, for any
/// schedule.
#[test]
fn progress_backlog_bounded_by_max_progress() {
    for seed in 30..=45u64 {
        let m = 256usize;
        let ring = ProgressRing::new(1 << 12, m);
        let mut rng = Rng::new(seed);
        for _ in 0..2000 {
            if rng.next_f64() < 0.7 {
                let len = 1 + rng.next_range(32) as usize;
                let _ = ring.try_push(&vec![7u8; len]);
            } else {
                ring.pop_batch(&mut |_| {});
            }
            assert!(
                ring.backlog() <= m as u64,
                "seed {seed}: backlog {} > M {m}",
                ring.backlog()
            );
        }
    }
}

/// Invariant: a batched drain costs exactly 3 DMA ops regardless of
/// batch size (the §4.1 design claim).
#[test]
fn progress_drain_dma_cost_constant() {
    for batch in [1usize, 2, 7, 30] {
        let ring = ProgressRing::new(1 << 12, 1 << 10);
        for i in 0..batch {
            assert_eq!(ring.try_push(&[i as u8; 8]), RingStatus::Ok);
        }
        let dma = DmaChannel::new();
        let mut n = 0;
        ring.pop_batch_dma(&dma, &mut |_| n += 1);
        assert_eq!(n, batch);
        assert_eq!(dma.reads(), 2, "batch {batch}");
        assert_eq!(dma.writes(), 1, "batch {batch}");
    }
}

/// Response ring (SPMC): random interleavings of one producer and
/// model-checked claims; every record delivered exactly once, in order
/// for a single consumer.
#[test]
fn response_ring_fifo_and_exactly_once() {
    for seed in 50..=60u64 {
        let ring = ResponseRing::new(1 << 12);
        let mut rng = Rng::new(seed);
        let mut model: VecDeque<u64> = VecDeque::new();
        let mut next = 0u64;
        for _ in 0..2000 {
            if rng.next_f64() < 0.55 {
                if ring.push(&next.to_le_bytes()) == RingStatus::Ok {
                    model.push_back(next);
                    next += 1;
                }
            } else {
                let mut got = None;
                if ring.pop(&mut |m| got = Some(u64::from_le_bytes(m.try_into().unwrap())))
                    == RingStatus::Ok
                {
                    assert_eq!(got, model.pop_front(), "seed {seed}");
                }
            }
        }
        while ring.pop(&mut |m| {
            let v = u64::from_le_bytes(m.try_into().unwrap());
            assert_eq!(Some(v), model.pop_front());
        }) == RingStatus::Ok
        {}
        assert!(model.is_empty(), "seed {seed}: records lost");
    }
}

/// Concurrent smoke under the single-core scheduler: preemption still
/// interleaves producers mid-insert, exercising the progress-pointer
/// publish ordering.
#[test]
fn progress_ring_concurrent_interleavings() {
    use std::sync::Arc;
    let ring = Arc::new(ProgressRing::new(1 << 14, 1 << 10));
    let producers = 4;
    let per = 2_000u64;
    let mut handles = Vec::new();
    for p in 0..producers {
        let ring = ring.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..per {
                let v = (p as u64) << 32 | i;
                loop {
                    if ring.try_push(&v.to_le_bytes()) == RingStatus::Ok {
                        break;
                    }
                    std::thread::yield_now();
                }
            }
        }));
    }
    let consumer = {
        let ring = ring.clone();
        std::thread::spawn(move || {
            let mut seen = vec![0u64; producers];
            let mut total = 0u64;
            while total < per * producers as u64 {
                let n = ring.pop_batch(&mut |m| {
                    let v = u64::from_le_bytes(m.try_into().unwrap());
                    let p = (v >> 32) as usize;
                    assert_eq!(v & 0xffff_ffff, seen[p], "per-producer FIFO violated");
                    seen[p] += 1;
                });
                if n == 0 {
                    std::thread::yield_now();
                }
                total += n as u64;
            }
        })
    };
    for h in handles {
        h.join().unwrap();
    }
    consumer.join().unwrap();
}
