//! The copy ledger: cheap atomic accounting of the software copies and
//! heap allocations the data plane performs.
//!
//! DDS's design argument is stated in *counts*: how many DMA ops a ring
//! drain costs (§4.1), how many copies a read response suffers (§4.3,
//! §6.2 Fig 12). [`crate::dma::DmaChannel`] accounts the former — the
//! transfers real hardware would DMA. The `CopyLedger` accounts the
//! latter: heap allocations and bytes `memcpy`'d by *software* on the
//! data path, i.e. exactly the overhead the zero-copy design removes.
//! A DMA transfer is never double-counted here, and a ledger copy is
//! never a DMA: the two meters partition the data movement.
//!
//! Ledgers are cloneable handles over shared atomics, so a pool and the
//! layers that borrow from it can share one meter. Tests and benches
//! take [`CopyLedger::snapshot`]s around a steady-state window and
//! assert on the delta (e.g. "N offloaded reads performed 0 heap
//! allocations and copied 0 bytes").

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

#[derive(Default)]
struct Counters {
    allocs: AtomicU64,
    pool_hits: AtomicU64,
    fallbacks: AtomicU64,
    heap_allocs: AtomicU64,
    copies: AtomicU64,
    bytes_copied: AtomicU64,
}

/// Shared copy/allocation meter (clone = same underlying counters).
#[derive(Clone, Default)]
pub struct CopyLedger {
    inner: Arc<Counters>,
}

impl CopyLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// A buffer was requested (pool hit or not).
    #[inline]
    pub fn count_alloc_request(&self) {
        self.inner.allocs.fetch_add(1, Ordering::Relaxed);
    }

    /// A request was served from the pool free list.
    #[inline]
    pub fn count_pool_hit(&self) {
        self.inner.pool_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// A request fell back to an owned heap allocation (pool exhausted
    /// or oversize). Implies one heap allocation.
    #[inline]
    pub fn count_fallback(&self) {
        self.inner.fallbacks.fetch_add(1, Ordering::Relaxed);
        self.inner.heap_allocs.fetch_add(1, Ordering::Relaxed);
    }

    /// A heap allocation outside the pool (e.g. materializing an owned
    /// buffer on a copy path).
    #[inline]
    pub fn count_heap_alloc(&self) {
        self.inner.heap_allocs.fetch_add(1, Ordering::Relaxed);
    }

    /// `bytes` were memcpy'd by software (NOT a DMA transfer — those are
    /// metered by [`crate::dma::DmaChannel`]).
    #[inline]
    pub fn count_copy(&self, bytes: usize) {
        self.inner.copies.fetch_add(1, Ordering::Relaxed);
        self.inner.bytes_copied.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Point-in-time counter values.
    pub fn snapshot(&self) -> LedgerSnapshot {
        LedgerSnapshot {
            allocs: self.inner.allocs.load(Ordering::Relaxed),
            pool_hits: self.inner.pool_hits.load(Ordering::Relaxed),
            fallbacks: self.inner.fallbacks.load(Ordering::Relaxed),
            heap_allocs: self.inner.heap_allocs.load(Ordering::Relaxed),
            copies: self.inner.copies.load(Ordering::Relaxed),
            bytes_copied: self.inner.bytes_copied.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time ledger values; subtract two to get a window delta.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LedgerSnapshot {
    /// Buffer requests (pool hits + fallbacks).
    pub allocs: u64,
    /// Requests served from the pool free list.
    pub pool_hits: u64,
    /// Requests that fell back to owned heap memory.
    pub fallbacks: u64,
    /// Heap allocations (fallbacks + explicit copy-path allocations).
    pub heap_allocs: u64,
    /// memcpy operations.
    pub copies: u64,
    /// Bytes memcpy'd.
    pub bytes_copied: u64,
}

impl std::ops::Sub for LedgerSnapshot {
    type Output = LedgerSnapshot;

    fn sub(self, earlier: LedgerSnapshot) -> LedgerSnapshot {
        LedgerSnapshot {
            allocs: self.allocs.saturating_sub(earlier.allocs),
            pool_hits: self.pool_hits.saturating_sub(earlier.pool_hits),
            fallbacks: self.fallbacks.saturating_sub(earlier.fallbacks),
            heap_allocs: self.heap_allocs.saturating_sub(earlier.heap_allocs),
            copies: self.copies.saturating_sub(earlier.copies),
            bytes_copied: self.bytes_copied.saturating_sub(earlier.bytes_copied),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_delta() {
        let l = CopyLedger::new();
        l.count_alloc_request();
        l.count_pool_hit();
        let before = l.snapshot();
        l.count_alloc_request();
        l.count_fallback();
        l.count_copy(100);
        l.count_copy(28);
        let d = l.snapshot() - before;
        assert_eq!(d.allocs, 1);
        assert_eq!(d.pool_hits, 0);
        assert_eq!(d.fallbacks, 1);
        assert_eq!(d.heap_allocs, 1);
        assert_eq!(d.copies, 2);
        assert_eq!(d.bytes_copied, 128);
    }

    #[test]
    fn clones_share_counters() {
        let a = CopyLedger::new();
        let b = a.clone();
        b.count_copy(7);
        assert_eq!(a.snapshot().bytes_copied, 7);
    }
}
