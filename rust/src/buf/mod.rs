//! The zero-copy buffer plane: pooled, reference-counted buffers shared
//! by every layer of the data path.
//!
//! DDS "heavily uses DMA, zero-copy, and userspace I/O to minimize
//! overhead": the SSD DMA lands in a pre-allocated buffer and that same
//! buffer *is* the packet payload (§4.3, §6.2 Fig 12). This module is
//! the functional-plane embodiment of that discipline:
//!
//! * [`BufPool`] — a slab of fixed-size pre-allocated slots (the pinned
//!   DMA-able memory of Fig 12 ①). Allocation never fails: exhaustion
//!   and oversize requests fall back to owned heap memory, *counted* so
//!   benches and tests can assert the steady state never falls back.
//! * [`PooledBuf`] — an exclusively-owned, writable borrow of a slot
//!   (where a "device DMA" lands). [`PooledBuf::freeze`] converts it
//!   into a view.
//! * [`BufView`] — a cheap, clonable, read-only `(offset, len)` window
//!   into refcounted storage. Cloning or [`BufView::slice`]-ing is a
//!   refcount bump — never a copy. The slot returns to its pool only
//!   when the **last** view drops, so a recycled slot can never be
//!   observed through a stale view (aliasing safety by construction).
//! * [`ByteRope`] — an ordered sequence of views standing in for
//!   contiguous bytes (what a scatter-gather NIC would transmit);
//!   materializing it is an explicit, metered act.
//! * [`CopyLedger`] — the copy ledger: per-pool (and per-layer) atomic
//!   counters of heap allocations and bytes memcpy'd by software, the
//!   complement of [`crate::dma::DmaChannel`]'s DMA meter.
//!
//! This generalizes the old `offload::mempool` (which only the offload
//! engine used, and whose borrows could not be sliced or shared): the
//! same pool type now backs the offload engine's read buffers, the file
//! service's request-batch staging and response assembly, the SSD
//! completion path, and the TCP segment payloads.

pub mod ledger;

pub use ledger::{CopyLedger, LedgerSnapshot};

use std::ops::Range;
use std::sync::{Arc, Mutex, OnceLock};

struct PoolShared {
    free: Mutex<Vec<Vec<u8>>>,
    slot_size: usize,
    slots: usize,
    /// Heap-fallback buffers currently lent out (they never join the
    /// slab, but the leak invariant must see them too).
    fallbacks_out: std::sync::atomic::AtomicUsize,
    ledger: CopyLedger,
}

/// Slab-backed fixed-size-class buffer pool (clone = same pool).
#[derive(Clone)]
pub struct BufPool {
    inner: Arc<PoolShared>,
}

impl BufPool {
    /// Pre-allocate `slots` buffers of `slot_size` bytes each.
    pub fn new(slots: usize, slot_size: usize) -> Self {
        Self::with_ledger(slots, slot_size, CopyLedger::new())
    }

    /// Pre-allocate with an externally shared [`CopyLedger`].
    pub fn with_ledger(slots: usize, slot_size: usize, ledger: CopyLedger) -> Self {
        let free = (0..slots).map(|_| vec![0u8; slot_size]).collect();
        BufPool {
            inner: Arc::new(PoolShared {
                free: Mutex::new(free),
                slot_size,
                slots,
                fallbacks_out: std::sync::atomic::AtomicUsize::new(0),
                ledger,
            }),
        }
    }

    /// Borrow a writable buffer of exactly `len` usable bytes. Served
    /// from the slab when `len` fits the slot class and a slot is free;
    /// otherwise falls back to an owned heap buffer (counted — the pool
    /// keeps serving under exhaustion, it just stops being free).
    pub fn allocate(&self, len: usize) -> PooledBuf {
        self.inner.ledger.count_alloc_request();
        if len <= self.inner.slot_size {
            if let Some(slot) = self.inner.free.lock().unwrap().pop() {
                self.inner.ledger.count_pool_hit();
                return PooledBuf { data: slot, len, pool: Some(self.clone()), slab: true };
            }
        }
        self.inner.ledger.count_fallback();
        self.inner.fallbacks_out.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        PooledBuf { data: vec![0u8; len], len, pool: Some(self.clone()), slab: false }
    }

    /// The fixed slot size (the pool's size class).
    pub fn slot_size(&self) -> usize {
        self.inner.slot_size
    }

    /// Total slots the slab was built with.
    pub fn slots(&self) -> usize {
        self.inner.slots
    }

    /// Slots currently on the free list.
    pub fn available(&self) -> usize {
        self.inner.free.lock().unwrap().len()
    }

    /// Buffers currently lent out: slab slots off the free list PLUS
    /// outstanding heap-fallback buffers (0 when the plane is quiesced
    /// — the leak check of the chaos suite sees both kinds).
    pub fn in_use(&self) -> usize {
        (self.inner.slots - self.available())
            + self.inner.fallbacks_out.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// The pool's copy ledger.
    pub fn ledger(&self) -> &CopyLedger {
        &self.inner.ledger
    }

    /// Counter snapshot (allocs / pool hits / fallbacks / copies).
    pub fn stats(&self) -> LedgerSnapshot {
        self.inner.ledger.snapshot()
    }

    fn release(&self, data: Vec<u8>) {
        debug_assert_eq!(data.len(), self.inner.slot_size, "release of a non-slab buffer");
        let mut free = self.inner.free.lock().unwrap();
        if free.len() < self.inner.slots {
            free.push(data);
        }
    }

    fn note_fallback_returned(&self) {
        self.inner.fallbacks_out.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
    }
}

/// An exclusively-owned writable buffer borrowed from a [`BufPool`]
/// (or an owned fallback). Returns its slot on drop; [`Self::freeze`]
/// converts it into a sharable [`BufView`] instead.
pub struct PooledBuf {
    data: Vec<u8>,
    len: usize,
    /// The owning pool, if any. With `slab == true`, `data` is a slab
    /// slot that must go home on release; with `slab == false`, it is a
    /// counted heap-fallback whose return only decrements occupancy.
    pool: Option<BufPool>,
    slab: bool,
}

impl PooledBuf {
    /// Wrap an owned vector (no pool attachment, no copy).
    pub fn from_vec(v: Vec<u8>) -> Self {
        let len = v.len();
        PooledBuf { data: v, len, pool: None, slab: false }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.data[..self.len]
    }

    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        &mut self.data[..self.len]
    }

    /// Seal the buffer into an immutable, refcounted [`BufView`]. The
    /// underlying slot returns to the pool when the last view drops.
    pub fn freeze(mut self) -> BufView {
        let data = std::mem::take(&mut self.data);
        let pool = self.pool.take();
        let len = self.len;
        BufView {
            storage: Arc::new(SharedStorage { data, pool, slab: self.slab }),
            start: 0,
            len,
        }
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            if self.slab {
                pool.release(std::mem::take(&mut self.data));
            } else {
                pool.note_fallback_returned();
            }
        }
    }
}

impl std::fmt::Debug for PooledBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PooledBuf")
            .field("len", &self.len)
            .field("slab", &self.slab)
            .finish()
    }
}

/// Refcounted backing storage; the slot goes home when this drops.
struct SharedStorage {
    data: Vec<u8>,
    pool: Option<BufPool>,
    /// Whether `data` is a slab slot (goes home) or a counted
    /// heap-fallback (occupancy decrements, buffer freed).
    slab: bool,
}

impl Drop for SharedStorage {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            if self.slab {
                pool.release(std::mem::take(&mut self.data));
            } else {
                pool.note_fallback_returned();
            }
        }
    }
}

/// A cheap, clonable, read-only window into shared buffer storage.
/// Clone and [`Self::slice`] are refcount bumps, never copies.
#[derive(Clone)]
pub struct BufView {
    storage: Arc<SharedStorage>,
    start: usize,
    len: usize,
}

impl BufView {
    /// The canonical empty view (no allocation after first use).
    pub fn empty() -> BufView {
        static EMPTY: OnceLock<Arc<SharedStorage>> = OnceLock::new();
        BufView {
            storage: EMPTY
                .get_or_init(|| {
                    Arc::new(SharedStorage { data: Vec::new(), pool: None, slab: false })
                })
                .clone(),
            start: 0,
            len: 0,
        }
    }

    /// Wrap an owned vector without copying.
    pub fn from_vec(v: Vec<u8>) -> BufView {
        let len = v.len();
        BufView {
            storage: Arc::new(SharedStorage { data: v, pool: None, slab: false }),
            start: 0,
            len,
        }
    }

    /// Allocate from `pool` and copy `bytes` in — an *explicit*, metered
    /// copy (`bytes_copied` on the pool's ledger).
    pub fn copy_of(pool: &BufPool, bytes: &[u8]) -> BufView {
        let mut b = pool.allocate(bytes.len());
        b.as_mut_slice().copy_from_slice(bytes);
        pool.ledger().count_copy(bytes.len());
        b.freeze()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.storage.data[self.start..self.start + self.len]
    }

    /// Sub-view of `range` (relative to this view). Refcount bump only.
    pub fn slice(&self, range: Range<usize>) -> BufView {
        assert!(
            range.start <= range.end && range.end <= self.len,
            "slice {range:?} out of view of len {}",
            self.len
        );
        BufView {
            storage: self.storage.clone(),
            start: self.start + range.start,
            len: range.end - range.start,
        }
    }

    /// Materialize an owned copy (the explicit opposite of zero-copy).
    pub fn to_vec(&self) -> Vec<u8> {
        // LINT: copy-ok(the explicit materialization API; callers meter)
        self.as_slice().to_vec()
    }

    /// Whether two views window the same underlying storage (used by
    /// tests to prove sharing instead of duplication).
    pub fn shares_storage(&self, other: &BufView) -> bool {
        Arc::ptr_eq(&self.storage, &other.storage)
    }

    /// Live references to this view's storage.
    pub fn refcount(&self) -> usize {
        Arc::strong_count(&self.storage)
    }
}

impl std::ops::Deref for BufView {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl std::fmt::Debug for BufView {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BufView({:?})", self.as_slice())
    }
}

impl Default for BufView {
    fn default() -> Self {
        BufView::empty()
    }
}

impl PartialEq for BufView {
    fn eq(&self, other: &BufView) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for BufView {}

impl PartialEq<[u8]> for BufView {
    fn eq(&self, other: &[u8]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<&[u8]> for BufView {
    fn eq(&self, other: &&[u8]) -> bool {
        self.as_slice() == *other
    }
}

impl PartialEq<Vec<u8>> for BufView {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<BufView> for Vec<u8> {
    fn eq(&self, other: &BufView) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<const N: usize> PartialEq<[u8; N]> for BufView {
    fn eq(&self, other: &[u8; N]) -> bool {
        self.as_slice() == other
    }
}

impl From<Vec<u8>> for BufView {
    fn from(v: Vec<u8>) -> BufView {
        BufView::from_vec(v)
    }
}

/// An ordered sequence of [`BufView`]s standing in for contiguous
/// bytes — what a scatter-gather NIC/DMA engine would transmit without
/// ever concatenating. Empty views are dropped on push.
#[derive(Clone, Default)]
pub struct ByteRope {
    parts: Vec<BufView>,
    len: usize,
}

impl ByteRope {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: BufView) {
        if v.is_empty() {
            return;
        }
        self.len += v.len();
        self.parts.push(v);
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn parts(&self) -> &[BufView] {
        &self.parts
    }

    /// Materialize (explicit copy; meter at the call site if it is on a
    /// data path).
    pub fn to_vec(&self) -> Vec<u8> {
        let mut v = Vec::with_capacity(self.len);
        for p in &self.parts {
            // LINT: copy-ok(the explicit materialization API; callers meter)
            v.extend_from_slice(p.as_slice());
        }
        v
    }
}

impl std::fmt::Debug for ByteRope {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ByteRope")
            .field("parts", &self.parts.len())
            .field("len", &self.len)
            .finish()
    }
}

/// Exhaustive model check of view-clone/drop vs slab reclaim
/// (correctness plane; see DESIGN.md). `MiniSlab` is a colocated
/// SKELETON of the [`BufView`]/[`BufPool`] lifecycle: the production
/// refcount is `Arc`'s (the `fetch_sub(Release)` + `fence(Acquire)`
/// drop protocol this model reproduces by hand), and the slot payload
/// lives in a `loom::cell::UnsafeCell` so loom's cell checker can
/// catch a recycle racing a surviving reader — untrackable on the real
/// slab's plain byte buffers. Registered in invariants.toml as
/// `bufview.refs`. Run with
/// `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_`.
#[cfg(all(loom, test))]
mod loom_models {
    use loom::cell::UnsafeCell;
    use loom::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    struct MiniSlab {
        refs: AtomicUsize,
        slot: UnsafeCell<u64>,
    }

    // SAFETY: readers access `slot` only while holding a ref; the
    // recycling write runs only after the last ref is released, ordered
    // by the Release drop + Acquire fence below. loom's cell checker
    // verifies exactly this on every interleaving.
    unsafe impl Send for MiniSlab {}
    unsafe impl Sync for MiniSlab {}

    impl MiniSlab {
        /// One slot, `refs` views outstanding.
        fn new(refs: usize, v: u64) -> Arc<Self> {
            Arc::new(MiniSlab { refs: AtomicUsize::new(refs), slot: UnsafeCell::new(v) })
        }

        fn read(&self) -> u64 {
            self.slot.with(|p| unsafe { *p })
        }

        /// Drop one view; the last drop reclaims and scrubs the slot
        /// (the pool's recycle). Arc's drop protocol: Release on the
        /// decrement so every holder's reads are ordered before the
        /// reclaim, Acquire fence so the reclaimer sees all of them.
        fn drop_view(&self, dec_order: Ordering) {
            if self.refs.fetch_sub(1, dec_order) == 1 {
                loom::sync::atomic::fence(Ordering::Acquire);
                self.slot.with_mut(|p| unsafe { *p = 0xDEAD });
            }
        }
    }

    /// Protocol 4 — two views dropping concurrently: exactly one
    /// observes the final decrement and recycles, and no interleaving
    /// lets the recycle write race a reader's access.
    #[test]
    fn loom_bufview_last_drop_reclaims_safely() {
        loom::model(|| {
            let slab = MiniSlab::new(2, 42);
            let other = {
                let slab = slab.clone();
                loom::thread::spawn(move || {
                    assert_eq!(slab.read(), 42, "live view must never see a scrubbed slot");
                    slab.drop_view(Ordering::Release);
                })
            };
            assert_eq!(slab.read(), 42, "live view must never see a scrubbed slot");
            slab.drop_view(Ordering::Release);
            other.join().unwrap();
            // Whoever dropped last has scrubbed by now (join ordered).
            assert_eq!(slab.refs.load(Ordering::Acquire), 0);
        });
    }

    /// Mutation self-test: demote the drop decrement to Relaxed and
    /// the loser's slot reads are no longer ordered before the
    /// winner's recycle — loom's cell checker must flag the race and
    /// panic. If this stops panicking, the model has gone vacuous.
    #[test]
    #[should_panic]
    fn loom_bufview_mutation_relaxed_drop_races_reclaim() {
        loom::model(|| {
            let slab = MiniSlab::new(2, 42);
            let other = {
                let slab = slab.clone();
                loom::thread::spawn(move || {
                    let _ = slab.read();
                    slab.drop_view(Ordering::Relaxed);
                })
            };
            let _ = slab.read();
            slab.drop_view(Ordering::Relaxed);
            other.join().unwrap();
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pooled_alloc_freeze_and_return() {
        let pool = BufPool::new(2, 64);
        assert_eq!(pool.available(), 2);
        let mut b = pool.allocate(10);
        b.as_mut_slice().copy_from_slice(&[7u8; 10]);
        assert_eq!(pool.available(), 1);
        let v = b.freeze();
        assert_eq!(pool.available(), 1, "frozen view still holds the slot");
        assert_eq!(v, vec![7u8; 10]);
        let v2 = v.clone();
        drop(v);
        assert_eq!(pool.available(), 1, "second view still holds the slot");
        drop(v2);
        assert_eq!(pool.available(), 2, "last view returns the slot");
        let s = pool.stats();
        assert_eq!((s.allocs, s.pool_hits, s.fallbacks), (1, 1, 0));
    }

    #[test]
    fn unfrozen_drop_returns_slot() {
        let pool = BufPool::new(1, 32);
        drop(pool.allocate(8));
        assert_eq!(pool.available(), 1);
    }

    #[test]
    fn exhaustion_falls_back_and_keeps_serving() {
        let pool = BufPool::new(1, 32);
        let a = pool.allocate(16);
        let b = pool.allocate(16); // exhausted → owned heap
        let c = pool.allocate(64); // oversize → owned heap
        assert_eq!(pool.available(), 0);
        assert_eq!(pool.in_use(), 3, "occupancy counts outstanding fallbacks too");
        let s = pool.stats();
        assert_eq!((s.allocs, s.pool_hits, s.fallbacks), (3, 1, 2));
        assert_eq!(s.heap_allocs, 2);
        drop(a);
        drop(b);
        drop(c);
        assert_eq!(pool.available(), 1, "fallback buffers never join the slab");
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn stale_view_never_sees_recycled_slot() {
        let pool = BufPool::new(1, 16);
        let mut b = pool.allocate(4);
        b.as_mut_slice().copy_from_slice(&[1, 2, 3, 4]);
        let v = b.freeze();
        // The slot cannot recycle while `v` lives: this allocation must
        // fall back rather than alias.
        let mut b2 = pool.allocate(4);
        b2.as_mut_slice().copy_from_slice(&[9, 9, 9, 9]);
        assert_eq!(pool.stats().fallbacks, 1);
        assert_eq!(v, vec![1, 2, 3, 4]);
        drop(v);
        // Now the slot is free; a new borrow may carry stale bytes but
        // no *view* of the old content exists anymore.
        let b3 = pool.allocate(4);
        assert_eq!(pool.stats().pool_hits, 2);
        drop(b3);
        drop(b2);
    }

    #[test]
    fn slice_views_share_storage() {
        let v = BufView::from_vec((0u8..100).collect());
        let a = v.slice(10..20);
        let b = a.slice(5..8);
        assert!(a.shares_storage(&v) && b.shares_storage(&v));
        assert_eq!(a, (10u8..20).collect::<Vec<_>>());
        assert_eq!(b, vec![15u8, 16, 17]);
        assert_eq!(v.refcount(), 3);
    }

    #[test]
    #[should_panic(expected = "out of view")]
    fn slice_out_of_bounds_panics() {
        let v = BufView::from_vec(vec![0; 4]);
        let _ = v.slice(2..6);
    }

    #[test]
    fn copy_of_is_metered() {
        let pool = BufPool::new(2, 64);
        let v = BufView::copy_of(&pool, &[5u8; 48]);
        assert_eq!(v, vec![5u8; 48]);
        let s = pool.stats();
        assert_eq!(s.copies, 1);
        assert_eq!(s.bytes_copied, 48);
    }

    #[test]
    fn rope_concatenates() {
        let mut r = ByteRope::new();
        r.push(BufView::from_vec(vec![1, 2]));
        r.push(BufView::empty());
        r.push(BufView::from_vec(vec![3]));
        assert_eq!(r.len(), 3);
        assert_eq!(r.parts().len(), 2, "empty parts dropped");
        assert_eq!(r.to_vec(), vec![1, 2, 3]);
    }

    #[test]
    fn empty_view_is_shared_not_allocated() {
        let a = BufView::empty();
        let b = BufView::empty();
        assert!(a.shares_storage(&b));
        assert!(a.is_empty());
    }
}
