//! FASTER-style key-value store (§9.2).
//!
//! A miniature hybrid-log KV: records live in a log that spans main
//! memory and secondary storage. The in-memory tail supports in-place
//! updates; older records are flushed to an *IDevice* — here a DDS file
//! accessed through the front-end library, exactly the integration the
//! paper describes ("we first implement an IDevice with its front-end
//! library"). A hash index maps keys to memory or file addresses.
//!
//! The DDS offload logic caches `{key → (file id, file offset, record
//! size)}` on flush writes and invalidates keys the host reads back for
//! RMW, so remote `KvGet`s of storage-resident records execute entirely
//! on the DPU (§9.2: 970 K op/s with zero host CPU).
//!
//! On-device record layout: `[key u64 | len u32 | value…]`.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use crate::cache::{CacheItem, CuckooCache};
use crate::dpufs::FileId;
use crate::filelib::{DdsClient, DdsFile, PollGroup};
use crate::offload::{OffloadLogic, ReadOp, RoutedReq, WriteOp};
use crate::proto::{AppRequest, NetMsg, NetResp};

use super::HostApp;

/// Record header bytes on the device.
pub const REC_HEADER: usize = 12;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Addr {
    /// Index into the in-memory tail.
    Mem(usize),
    /// Location on the IDevice.
    Disk { offset: u64, len: u32 },
}

/// The mini FASTER store.
pub struct MiniFaster {
    pub client: DdsClient,
    pub file: DdsFile,
    pub group: Arc<PollGroup>,
    /// DPU cache table handle for explicit invalidation: when a record
    /// moves back into the mutable tail (disk read for RMW / re-upsert)
    /// the DPU must stop serving it (§9.2 invalidate-on-read). The
    /// generic offset-keyed `Invalidate` hook cannot recover the KV key
    /// from a raw read, so the integration invalidates by key here —
    /// same effect, same trigger (the host read).
    cache: Option<Arc<CuckooCache>>,
    index: HashMap<u64, Addr>,
    /// In-memory mutable tail: (key, value).
    tail: Vec<(u64, Vec<u8>)>,
    tail_bytes: usize,
    /// Flush the tail to the IDevice beyond this budget (a small budget
    /// forces the storage-resident behaviour of §9.2).
    pub mem_budget: usize,
    /// Next append offset on the device.
    log_end: u64,
    /// Stats.
    pub flushes: u64,
    pub disk_reads: u64,
    pub mem_hits: u64,
}

impl MiniFaster {
    pub fn new(
        client: DdsClient,
        mut file: DdsFile,
        group: Arc<PollGroup>,
        mem_budget: usize,
    ) -> Self {
        client.poll_add(&mut file, &group);
        MiniFaster {
            client,
            file,
            group,
            cache: None,
            index: HashMap::new(),
            tail: Vec::new(),
            tail_bytes: 0,
            mem_budget,
            log_end: 0,
            flushes: 0,
            disk_reads: 0,
            mem_hits: 0,
        }
    }

    /// Attach the DPU cache table for key invalidation (DDS mode).
    pub fn with_cache(mut self, cache: Arc<CuckooCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    fn wait_for(&self, req_id: u64) -> anyhow::Result<Vec<u8>> {
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            for ev in self.group.poll_wait(Duration::from_millis(50)) {
                if ev.req_id == req_id {
                    anyhow::ensure!(ev.ok, "IDevice op failed");
                    return Ok(ev.data);
                }
            }
            anyhow::ensure!(std::time::Instant::now() < deadline, "IDevice op timeout");
        }
    }

    /// Upsert: in-place if the record is in the mutable tail, otherwise
    /// append a new version.
    pub fn upsert(&mut self, key: u64, value: Vec<u8>) -> anyhow::Result<()> {
        // A storage-resident record is being superseded by an in-memory
        // version: the DPU must not serve the old image.
        if matches!(self.index.get(&key), Some(Addr::Disk { .. })) {
            if let Some(cache) = &self.cache {
                cache.remove(key);
            }
        }
        match self.index.get(&key) {
            Some(Addr::Mem(i)) => {
                let i = *i;
                self.tail_bytes = self.tail_bytes - self.tail[i].1.len() + value.len();
                self.tail[i].1 = value;
            }
            _ => {
                self.tail_bytes += value.len() + REC_HEADER;
                self.tail.push((key, value));
                self.index.insert(key, Addr::Mem(self.tail.len() - 1));
            }
        }
        if self.tail_bytes > self.mem_budget {
            self.flush()?;
        }
        Ok(())
    }

    /// Read-modify-write (the §2/Fig 5 workload): fetch (memory or
    /// IDevice), bump every byte, write back in place or re-append.
    pub fn rmw(&mut self, key: u64, f: impl FnOnce(&mut Vec<u8>)) -> anyhow::Result<bool> {
        match self.index.get(&key).copied() {
            Some(Addr::Mem(i)) => {
                self.mem_hits += 1;
                let before = self.tail[i].1.len();
                f(&mut self.tail[i].1);
                self.tail_bytes = self.tail_bytes - before + self.tail[i].1.len();
                Ok(true)
            }
            Some(Addr::Disk { offset, len }) => {
                let mut value = self.read_disk(key, offset, len)?;
                f(&mut value);
                self.upsert(key, value)?;
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Point read.
    pub fn get(&mut self, key: u64) -> anyhow::Result<Option<Vec<u8>>> {
        match self.index.get(&key).copied() {
            Some(Addr::Mem(i)) => {
                self.mem_hits += 1;
                Ok(Some(self.tail[i].1.clone()))
            }
            Some(Addr::Disk { offset, len }) => Ok(Some(self.read_disk(key, offset, len)?)),
            None => Ok(None),
        }
    }

    fn read_disk(&mut self, key: u64, offset: u64, len: u32) -> anyhow::Result<Vec<u8>> {
        // Invalidate-on-read (§9.2): the host pulling a record back is
        // the signal it may change.
        if let Some(cache) = &self.cache {
            cache.remove(key);
        }
        let req = self
            .client
            .read_file(&self.file, offset, len)
            .map_err(|e| anyhow::anyhow!("read_file: {e}"))?;
        let rec = self.wait_for(req)?;
        self.disk_reads += 1;
        anyhow::ensure!(rec.len() as u32 == len, "short read");
        let k = u64::from_le_bytes(rec[..8].try_into().unwrap());
        anyhow::ensure!(k == key, "index/record key mismatch");
        let vlen = u32::from_le_bytes(rec[8..12].try_into().unwrap()) as usize;
        Ok(rec[REC_HEADER..REC_HEADER + vlen].to_vec())
    }

    /// Flush the tail to the IDevice as one gathered write; records
    /// become storage-resident and the index is repointed (§9.2: "older
    /// records are flushed to IDevice if memory is insufficient").
    pub fn flush(&mut self) -> anyhow::Result<()> {
        if self.tail.is_empty() {
            return Ok(());
        }
        let mut blob = Vec::with_capacity(self.tail_bytes);
        let mut locations = Vec::with_capacity(self.tail.len());
        for (key, value) in &self.tail {
            let rec_off = self.log_end + blob.len() as u64;
            let rec_len = (REC_HEADER + value.len()) as u32;
            blob.extend_from_slice(&key.to_le_bytes());
            blob.extend_from_slice(&(value.len() as u32).to_le_bytes());
            blob.extend_from_slice(value);
            locations.push((*key, rec_off, rec_len));
        }
        let req = self
            .client
            .write_file(&self.file, self.log_end, &blob)
            .map_err(|e| anyhow::anyhow!("write_file: {e}"))?;
        self.wait_for(req)?;
        self.log_end += blob.len() as u64;
        for (key, offset, len) in locations {
            self.index.insert(key, Addr::Disk { offset, len });
        }
        self.tail.clear();
        self.tail_bytes = 0;
        self.flushes += 1;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }
}

impl HostApp for MiniFaster {
    fn handle(&mut self, msg: &NetMsg) -> Vec<NetResp> {
        let mut out = Vec::with_capacity(msg.requests.len());
        for (i, r) in msg.requests.iter().enumerate() {
            let idx = i as u16;
            let resp = match r {
                AppRequest::KvGet { key } => match self.get(*key) {
                    Ok(Some(v)) => NetResp { msg_id: msg.msg_id, idx, status: NetResp::OK, payload: v.into() },
                    _ => NetResp { msg_id: msg.msg_id, idx, status: NetResp::ERR, payload: crate::buf::BufView::empty() },
                },
                AppRequest::KvUpsert { key, value } => match self.upsert(*key, value.clone()) {
                    Ok(()) => NetResp { msg_id: msg.msg_id, idx, status: NetResp::OK, payload: crate::buf::BufView::empty() },
                    Err(_) => NetResp { msg_id: msg.msg_id, idx, status: NetResp::ERR, payload: crate::buf::BufView::empty() },
                },
                _ => NetResp { msg_id: msg.msg_id, idx, status: NetResp::ERR, payload: crate::buf::BufView::empty() },
            };
            out.push(resp);
        }
        out
    }
}

/// The §9.2 offload logic: cache `{key, file id, file offset, record
/// size}` on IDevice writes; offload `KvGet`s whose key is cached.
///
/// Cache item layout: `a = file_id`, `b = offset`, `c = record len`,
/// `d = unused`.
pub struct FasterOffload {
    pub idevice_file: FileId,
}

impl OffloadLogic for FasterOffload {
    fn off_pred(&self, msg: &NetMsg, cache: &CuckooCache) -> (Vec<RoutedReq>, Vec<RoutedReq>) {
        let mut host = Vec::new();
        let mut dpu = Vec::new();
        for (i, r) in msg.requests.iter().enumerate() {
            let routed = RoutedReq { msg_id: msg.msg_id, idx: i as u16, req: r.clone() };
            match r {
                AppRequest::KvGet { key } if cache.get(*key).is_some() => dpu.push(routed),
                _ => host.push(routed),
            }
        }
        (host, dpu)
    }

    fn off_func(&self, req: &AppRequest, cache: &CuckooCache) -> Option<ReadOp> {
        match req {
            AppRequest::KvGet { key } => {
                let item = cache.get(*key)?;
                Some(ReadOp { file_id: FileId(item.a as u32), offset: item.b, size: item.c as u32 })
            }
            _ => None,
        }
    }

    /// Cache-on-write: parse the flushed record blob.
    fn cache(&self, w: &WriteOp) -> Vec<(u64, CacheItem)> {
        if w.file_id != self.idevice_file {
            return Vec::new();
        }
        let mut out = Vec::new();
        let mut at = 0usize;
        while at + REC_HEADER <= w.data.len() {
            let key = u64::from_le_bytes(w.data[at..at + 8].try_into().unwrap());
            let vlen = u32::from_le_bytes(w.data[at + 8..at + 12].try_into().unwrap()) as usize;
            let rec_len = REC_HEADER + vlen;
            if at + rec_len > w.data.len() {
                break;
            }
            out.push((
                key,
                CacheItem::new(
                    self.idevice_file.0 as u64,
                    w.offset + at as u64,
                    rec_len as u64,
                    0,
                ),
            ));
            at += rec_len;
        }
        out
    }

    /// Invalidate-on-read: the host is pulling the record back (e.g. to
    /// RMW it) — stop serving it from the DPU.
    fn invalidate(&self, _r: &ReadOp) -> Vec<u64> {
        // Keys are not derivable from a raw (offset, size) read without
        // the record header; the host read path resolves this by reading
        // whole records, and the file service invalidates by scanning
        // the cache via the read offset is not possible in O(1). The
        // paper's FASTER integration invalidates the key it reads; we
        // model that in MiniFaster::read_disk via explicit removal in
        // integration wiring (see coordinator). Returning nothing here
        // keeps the hook total.
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flush_blob_roundtrips_through_cache_hook() {
        let off = FasterOffload { idevice_file: FileId(9) };
        // Build a blob of three records at base offset 1000.
        let mut blob = Vec::new();
        let mut expect = Vec::new();
        let mut at = 0usize;
        for (k, v) in [(1u64, vec![7u8; 5]), (2, vec![8u8; 3]), (3, vec![9u8; 11])] {
            blob.extend_from_slice(&k.to_le_bytes());
            blob.extend_from_slice(&(v.len() as u32).to_le_bytes());
            blob.extend_from_slice(&v);
            expect.push((k, 1000 + at as u64, (REC_HEADER + v.len()) as u64));
            at += REC_HEADER + v.len();
        }
        let items = off.cache(&WriteOp { file_id: FileId(9), offset: 1000, data: &blob });
        assert_eq!(items.len(), 3);
        for ((k, item), (ek, eoff, elen)) in items.iter().zip(&expect) {
            assert_eq!(k, ek);
            assert_eq!(item.b, *eoff);
            assert_eq!(item.c, *elen);
        }
    }

    #[test]
    fn off_pred_requires_cached_key() {
        let off = FasterOffload { idevice_file: FileId(9) };
        let cache = CuckooCache::new(64);
        cache.insert(42, CacheItem::new(9, 0, 20, 0));
        let msg = NetMsg {
            msg_id: 1,
            requests: vec![
                AppRequest::KvGet { key: 42 },
                AppRequest::KvGet { key: 43 },
                AppRequest::KvUpsert { key: 42, value: vec![1] },
            ],
        };
        let (host, dpu) = off.off_pred(&msg, &cache);
        assert_eq!(dpu.len(), 1);
        assert_eq!(dpu[0].idx, 0);
        assert_eq!(host.len(), 2);
    }

    #[test]
    fn truncated_blob_is_safe() {
        let off = FasterOffload { idevice_file: FileId(9) };
        let mut blob = Vec::new();
        blob.extend_from_slice(&7u64.to_le_bytes());
        blob.extend_from_slice(&100u32.to_le_bytes()); // claims 100 bytes
        blob.extend_from_slice(&[1, 2, 3]); // but only 3 present
        let items = off.cache(&WriteOp { file_id: FileId(9), offset: 0, data: &blob });
        assert!(items.is_empty());
    }
}
