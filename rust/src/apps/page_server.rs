//! Azure-SQL-Hyperscale-style page server (§9.1).
//!
//! Stores a partition of the database as 8 KB pages in an RBPEX-like
//! file managed through the DDS front-end library, replays log records
//! to refresh pages, and serves `GetPage@LSN` requests. The DDS
//! integration is exactly the paper's: `Cache` caches `(lsn, offset)`
//! keyed by page id on every RBPEX write; `Invalidate` drops the entry
//! when the host reads a page (it may be modified in the host buffer
//! pool); `OffPred` offloads a read when the cached LSN ≥ the requested
//! LSN; `OffFunc` builds the RBPEX file read.
//!
//! Page layout: `[page_id u64 | lsn u64 | payload…]` — the header is
//! what `Cache` parses out of the write payload.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use crate::cache::{CacheItem, CuckooCache};
use crate::dpufs::FileId;
use crate::filelib::{DdsClient, DdsFile, PollGroup};
use crate::offload::{OffloadLogic, ReadOp, RoutedReq, WriteOp};
use crate::proto::{AppRequest, NetMsg, NetResp};

use super::HostApp;

/// Database page size (Hyperscale uses 8 KB pages).
pub const PAGE_SIZE: usize = 8192;

/// Page-header length (page id + LSN).
pub const PAGE_HEADER: usize = 16;

/// The host-side page server.
pub struct PageServer {
    pub client: DdsClient,
    pub file: DdsFile,
    pub group: Arc<PollGroup>,
    /// page id -> latest applied LSN (host's authoritative view).
    pub page_lsn: HashMap<u64, u64>,
    pub n_pages: u64,
    /// Stats.
    pub host_served: u64,
    pub logs_replayed: u64,
}

impl PageServer {
    /// Create and initialize `n_pages` pages at LSN 1.
    pub fn new(
        client: DdsClient,
        mut file: DdsFile,
        group: Arc<PollGroup>,
        n_pages: u64,
    ) -> anyhow::Result<Self> {
        client.poll_add(&mut file, &group);
        let mut ps = PageServer {
            client,
            file,
            group,
            page_lsn: HashMap::new(),
            n_pages,
            host_served: 0,
            logs_replayed: 0,
        };
        for page in 0..n_pages {
            ps.write_page(page, 1, 0xA5)?;
        }
        Ok(ps)
    }

    fn page_offset(page_id: u64) -> u64 {
        page_id * PAGE_SIZE as u64
    }

    /// Materialize a full page image.
    pub fn page_image(page_id: u64, lsn: u64, fill: u8) -> Vec<u8> {
        let mut page = vec![fill; PAGE_SIZE];
        page[..8].copy_from_slice(&page_id.to_le_bytes());
        page[8..16].copy_from_slice(&lsn.to_le_bytes());
        page
    }

    fn write_page(&mut self, page_id: u64, lsn: u64, fill: u8) -> anyhow::Result<()> {
        let page = Self::page_image(page_id, lsn, fill);
        let req = self
            .client
            .write_file(&self.file, Self::page_offset(page_id), &page)
            .map_err(|e| anyhow::anyhow!("write_file: {e}"))?;
        self.wait_for(req)?;
        self.page_lsn.insert(page_id, lsn);
        Ok(())
    }

    /// Replay one log record: read-modify-write the page at a new LSN
    /// (§9.1: the page server "replays logs retrieved from the log
    /// servers to refresh the pages").
    pub fn replay_log(&mut self, page_id: u64, lsn: u64) -> anyhow::Result<()> {
        // Host read (this is what triggers invalidate-on-read on the
        // DPU — the page is now "hot" on the host).
        let req = self
            .client
            .read_file(&self.file, Self::page_offset(page_id), PAGE_SIZE as u32)
            .map_err(|e| anyhow::anyhow!("read_file: {e}"))?;
        let _old = self.wait_for(req)?;
        // Apply the update and write back at the new LSN (write-back
        // re-caches the page on the DPU via cache-on-write).
        self.write_page(page_id, lsn, (lsn % 251) as u8)?;
        self.logs_replayed += 1;
        Ok(())
    }

    fn wait_for(&self, req_id: u64) -> anyhow::Result<Vec<u8>> {
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            for ev in self.group.poll_wait(Duration::from_millis(50)) {
                if ev.req_id == req_id {
                    anyhow::ensure!(ev.ok, "file op failed");
                    return Ok(ev.data);
                }
            }
            anyhow::ensure!(std::time::Instant::now() < deadline, "file op timeout");
        }
    }

    /// Serve GetPage@LSN on the host path.
    fn get_page(&mut self, page_id: u64, lsn: u64) -> anyhow::Result<Vec<u8>> {
        let current = *self
            .page_lsn
            .get(&page_id)
            .ok_or_else(|| anyhow::anyhow!("no such page {page_id}"))?;
        anyhow::ensure!(current >= lsn, "page {page_id} behind requested LSN");
        let req = self
            .client
            .read_file(&self.file, Self::page_offset(page_id), PAGE_SIZE as u32)
            .map_err(|e| anyhow::anyhow!("read_file: {e}"))?;
        self.host_served += 1;
        self.wait_for(req)
    }
}

impl HostApp for PageServer {
    fn handle(&mut self, msg: &NetMsg) -> Vec<NetResp> {
        let mut out = Vec::with_capacity(msg.requests.len());
        for (i, r) in msg.requests.iter().enumerate() {
            let idx = i as u16;
            match r {
                AppRequest::GetPage { page_id, lsn } => match self.get_page(*page_id, *lsn) {
                    Ok(page) => out.push(NetResp {
                        msg_id: msg.msg_id,
                        idx,
                        status: NetResp::OK,
                        payload: page.into(),
                    }),
                    Err(_) => out.push(NetResp {
                        msg_id: msg.msg_id,
                        idx,
                        status: NetResp::ERR,
                        payload: crate::buf::BufView::empty(),
                    }),
                },
                _ => out.push(NetResp {
                    msg_id: msg.msg_id,
                    idx,
                    status: NetResp::ERR,
                    payload: crate::buf::BufView::empty(),
                }),
            }
        }
        out
    }
}

/// The §9.1 offload logic for the page server.
///
/// Cache item layout: `a = lsn`, `b = file_id`, `c = offset`,
/// `d = size`; key = page id.
pub struct PageServerOffload {
    pub rbpex_file: FileId,
}

impl OffloadLogic for PageServerOffload {
    fn off_pred(&self, msg: &NetMsg, cache: &CuckooCache) -> (Vec<RoutedReq>, Vec<RoutedReq>) {
        let mut host = Vec::new();
        let mut dpu = Vec::new();
        for (i, r) in msg.requests.iter().enumerate() {
            let routed = RoutedReq { msg_id: msg.msg_id, idx: i as u16, req: r.clone() };
            match r {
                AppRequest::GetPage { page_id, lsn } => {
                    // Offload iff the cached LSN is fresh enough (§9.1).
                    match cache.get(*page_id) {
                        Some(item) if item.a >= *lsn => dpu.push(routed),
                        _ => host.push(routed),
                    }
                }
                _ => host.push(routed),
            }
        }
        (host, dpu)
    }

    fn off_func(&self, req: &AppRequest, cache: &CuckooCache) -> Option<ReadOp> {
        match req {
            AppRequest::GetPage { page_id, .. } => {
                let item = cache.get(*page_id)?;
                Some(ReadOp {
                    file_id: FileId(item.b as u32),
                    offset: item.c,
                    size: item.d as u32,
                })
            }
            _ => None,
        }
    }

    /// Cache-on-write: parse `(page_id, lsn)` out of every page-aligned
    /// page image written to the RBPEX file.
    fn cache(&self, w: &WriteOp) -> Vec<(u64, CacheItem)> {
        if w.file_id != self.rbpex_file {
            return Vec::new();
        }
        let mut out = Vec::new();
        let mut at = 0usize;
        while at + PAGE_SIZE <= w.data.len() {
            let page_id = u64::from_le_bytes(w.data[at..at + 8].try_into().unwrap());
            let lsn = u64::from_le_bytes(w.data[at + 8..at + 16].try_into().unwrap());
            out.push((
                page_id,
                CacheItem::new(lsn, self.rbpex_file.0 as u64, w.offset + at as u64, PAGE_SIZE as u64),
            ));
            at += PAGE_SIZE;
        }
        out
    }

    /// Invalidate-on-read: a host read means the page may be about to
    /// change in the host buffer pool.
    fn invalidate(&self, r: &ReadOp) -> Vec<u64> {
        if r.file_id != self.rbpex_file {
            return Vec::new();
        }
        let first = r.offset / PAGE_SIZE as u64;
        let last = (r.offset + r.size as u64).div_ceil(PAGE_SIZE as u64);
        (first..last).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_on_write_parses_pages() {
        let off = PageServerOffload { rbpex_file: FileId(3) };
        let mut data = PageServer::page_image(7, 42, 1);
        data.extend(PageServer::page_image(8, 43, 2));
        let items = off.cache(&WriteOp { file_id: FileId(3), offset: 7 * PAGE_SIZE as u64, data: &data });
        assert_eq!(items.len(), 2);
        assert_eq!(items[0].0, 7);
        assert_eq!(items[0].1.a, 42);
        assert_eq!(items[0].1.c, 7 * PAGE_SIZE as u64);
        assert_eq!(items[1].0, 8);
        assert_eq!(items[1].1.c, 8 * PAGE_SIZE as u64);
    }

    #[test]
    fn cache_ignores_other_files() {
        let off = PageServerOffload { rbpex_file: FileId(3) };
        let data = PageServer::page_image(7, 42, 1);
        assert!(off.cache(&WriteOp { file_id: FileId(4), offset: 0, data: &data }).is_empty());
    }

    #[test]
    fn invalidate_covers_touched_pages() {
        let off = PageServerOffload { rbpex_file: FileId(3) };
        let keys = off.invalidate(&ReadOp {
            file_id: FileId(3),
            offset: PAGE_SIZE as u64 - 10,
            size: 20,
        });
        assert_eq!(keys, vec![0, 1]);
    }

    #[test]
    fn off_pred_honours_lsn() {
        let off = PageServerOffload { rbpex_file: FileId(3) };
        let cache = CuckooCache::new(64);
        cache.insert(5, CacheItem::new(10, 3, 5 * PAGE_SIZE as u64, PAGE_SIZE as u64));
        let msg = NetMsg {
            msg_id: 1,
            requests: vec![
                AppRequest::GetPage { page_id: 5, lsn: 9 },  // cached LSN 10 ≥ 9 → DPU
                AppRequest::GetPage { page_id: 5, lsn: 11 }, // too fresh → host
                AppRequest::GetPage { page_id: 6, lsn: 1 },  // not cached → host
            ],
        };
        let (host, dpu) = off.off_pred(&msg, &cache);
        assert_eq!(dpu.len(), 1);
        assert_eq!(dpu[0].idx, 0);
        assert_eq!(host.len(), 2);
    }

    #[test]
    fn off_func_builds_rbpex_read() {
        let off = PageServerOffload { rbpex_file: FileId(3) };
        let cache = CuckooCache::new(64);
        cache.insert(9, CacheItem::new(10, 3, 9 * PAGE_SIZE as u64, PAGE_SIZE as u64));
        let op = off
            .off_func(&AppRequest::GetPage { page_id: 9, lsn: 2 }, &cache)
            .unwrap();
        assert_eq!(op.file_id, FileId(3));
        assert_eq!(op.offset, 9 * PAGE_SIZE as u64);
        assert_eq!(op.size, PAGE_SIZE as u32);
    }
}
