//! Host-side storage applications (§8.1 benchmark app, §9 production
//! integrations) plus their DDS offload logic.

pub mod faster;
pub mod page_server;

pub use faster::{FasterOffload, MiniFaster};
pub use page_server::{PageServer, PageServerOffload, PAGE_SIZE};

use std::sync::Arc;
use std::time::Duration;

use crate::filelib::{DdsClient, DdsFile, PollGroup};
use crate::proto::{AppRequest, NetMsg, NetResp};

/// A host application: consumes application messages (from the traffic
/// director's host connection, or directly in baseline mode) and
/// produces responses.
pub trait HostApp {
    fn handle(&mut self, msg: &NetMsg) -> Vec<NetResp>;
}

/// The §8.1 benchmark application on the host: executes raw file
/// reads/writes with the DDS front-end library.
pub struct RawFileApp {
    pub client: DdsClient,
    pub file: DdsFile,
    pub group: Arc<PollGroup>,
}

impl RawFileApp {
    /// Total wall-clock budget for one batch before the missing
    /// completions are surfaced as errors instead of waiting forever.
    pub const BATCH_TIMEOUT: Duration = Duration::from_secs(5);

    /// The canonical host-app factory (one per shard in the sharded
    /// deployment): a fresh front end and a dedicated poll group over
    /// an existing file, so the file service gets one notification
    /// group per app instance to drain.
    pub fn over(
        storage: &crate::coordinator::StorageServer,
        file: &DdsFile,
    ) -> anyhow::Result<RawFileApp> {
        let client = storage.front_end();
        let mut file = file.clone();
        let group = client.create_poll().map_err(|e| anyhow::anyhow!("{e}"))?;
        client.poll_add(&mut file, &group);
        Ok(RawFileApp { client, file, group })
    }

    /// Issue a whole batch, then poll until every completion arrives
    /// (sleeping mode — zero CPU while waiting, §4.2).
    ///
    /// The wait is bounded: [`Self::BATCH_TIMEOUT`] without *any*
    /// progress (the budget resets on every completion, so a large but
    /// steadily-completing batch is never cut off) means the remaining
    /// operations are lost — they are reported as failed
    /// (`ok == false`) rather than spinning on `poll_wait` forever.
    fn run_batch(&mut self, ops: Vec<(u16, u64)>) -> Vec<(u16, bool, Vec<u8>)> {
        let mut remaining = ops.len();
        let mut by_req: std::collections::HashMap<u64, u16> =
            ops.into_iter().map(|(idx, req_id)| (req_id, idx)).collect();
        let mut out = Vec::with_capacity(remaining);
        let mut deadline = std::time::Instant::now() + Self::BATCH_TIMEOUT;
        while remaining > 0 {
            let now = std::time::Instant::now();
            if now >= deadline {
                // Stalled: surface an error per lost operation.
                for (_req_id, idx) in by_req.drain() {
                    out.push((idx, false, Vec::new()));
                }
                break;
            }
            let wait = (deadline - now).min(Duration::from_millis(250));
            let events = self.group.poll_wait(wait);
            if !events.is_empty() {
                // Progress: reset the stall budget.
                deadline = std::time::Instant::now() + Self::BATCH_TIMEOUT;
            }
            for ev in events {
                if let Some(idx) = by_req.remove(&ev.req_id) {
                    out.push((idx, ev.ok, ev.data));
                    remaining -= 1;
                }
            }
        }
        out
    }
}

impl HostApp for RawFileApp {
    fn handle(&mut self, msg: &NetMsg) -> Vec<NetResp> {
        let mut issued: Vec<(u16, u64)> = Vec::new();
        let mut immediate: Vec<NetResp> = Vec::new();
        for (i, r) in msg.requests.iter().enumerate() {
            let idx = i as u16;
            let res = match r {
                AppRequest::Read { offset, size, .. } => {
                    self.client.read_file(&self.file, *offset, *size)
                }
                AppRequest::Write { offset, data, .. } => {
                    self.client.write_file(&self.file, *offset, data)
                }
                _ => {
                    immediate.push(NetResp {
                        msg_id: msg.msg_id,
                        idx,
                        status: NetResp::ERR,
                        payload: crate::buf::BufView::empty(),
                    });
                    continue;
                }
            };
            match res {
                Ok(req_id) => issued.push((idx, req_id)),
                Err(_) => immediate.push(NetResp {
                    msg_id: msg.msg_id,
                    idx,
                    status: NetResp::ERR,
                    payload: crate::buf::BufView::empty(),
                }),
            }
        }
        let mut done = self.run_batch(issued);
        done.sort_by_key(|(idx, ..)| *idx);
        let mut out = immediate;
        for (idx, ok, data) in done {
            out.push(NetResp {
                msg_id: msg.msg_id,
                idx,
                status: if ok { NetResp::OK } else { NetResp::ERR },
                payload: data.into(),
            });
        }
        out.sort_by_key(|r| r.idx);
        out
    }
}
