//! The CPU plane: wake doorbells and the adaptive spin→park governor
//! (see DESIGN.md "The CPU plane").
//!
//! The paper optimizes CPU alongside latency — a DDS storage server
//! "saves up to tens of CPU cores" (Fig 14) because its service loops
//! do not burn a core when idle. This module is the reusable machinery
//! every pump in the functional plane threads through:
//!
//! * [`Doorbell`] — a sequence-numbered wake signal. Producers `ring`
//!   after publishing work; a consumer snapshots `seq()` BEFORE
//!   scanning for work and parks with `wait(seen, ..)`. Any ring that
//!   lands after the snapshot advances the sequence past `seen`, so
//!   the wait returns immediately — a wakeup can be *late* (bounded by
//!   the park timeout) but never *lost*.
//! * [`IdlePolicy`] — `Poll` (the SPDK busy-poll discipline: lowest
//!   latency, one core per pump, the Fig 14 worst case) or `Adaptive`
//!   (spin a configured number of empty iterations, yield, then park
//!   on the doorbell with bounded exponential backoff).
//! * [`IdleGovernor`] — the per-pump ladder state machine; writes the
//!   pump's [`CpuLedger`] so poll-vs-park economics are observable.
//!
//! Every park is *bounded* (the backoff caps at the policy's
//! `park_timeout`), so even a producer edge that forgets to ring only
//! costs bounded latency, never a hang — and the fault plane's
//! iteration-denominated machinery (pending timeouts, delayed
//! completions) keeps aging while the pump naps.

// Under `--cfg loom` the doorbell's synchronization primitives come
// from loom so the Dekker protocol below can be model-checked
// exhaustively (see `loom_models`). `Arc` stays std either way: loom
// does not model the refcount, and keeping the handle type stable
// means every `Arc<Doorbell>` field across the crate compiles
// unchanged under both cfgs.
#[cfg(loom)]
use loom::sync::atomic::{AtomicU64, Ordering};
#[cfg(loom)]
use loom::sync::{Condvar, Mutex};
#[cfg(not(loom))]
use std::sync::atomic::{AtomicU64, Ordering};
#[cfg(not(loom))]
use std::sync::{Condvar, Mutex};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::metrics::CpuLedger;

/// Doorbell used to wake sleeping pumps and `PollWait` callers (§4.2:
/// "the DPU driver generates an interrupt when the response is
/// DMA-written").
///
/// The sequence lives in an atomic so the producer-side `ring` is a
/// single `fetch_add` on the data path; the mutex + condvar are only
/// touched when a waiter is actually registered.
#[cfg_attr(not(loom), derive(Default))]
pub struct Doorbell {
    seq: AtomicU64,
    /// Registered waiters; a producer only takes the lock to notify
    /// when this is non-zero.
    sleepers: AtomicU64,
    lock: Mutex<()>,
    cv: Condvar,
}

// loom's primitives do not all derive Default; build the zero state by
// hand under the model cfg.
#[cfg(loom)]
impl Default for Doorbell {
    fn default() -> Self {
        Doorbell {
            seq: AtomicU64::new(0),
            sleepers: AtomicU64::new(0),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        }
    }
}

impl Doorbell {
    pub fn new() -> Arc<Self> {
        Arc::new(Doorbell::default())
    }

    /// Ring: advance the sequence and wake waiters.
    ///
    /// SeqCst pairs with the waiter's register-then-recheck (Dekker
    /// pattern): if this ring's sequence bump is not visible to a
    /// waiter's post-registration recheck, then the waiter's sleeper
    /// registration IS visible to the `sleepers` load below, so the
    /// notify fires — one side always sees the other.
    pub fn ring(&self) {
        self.seq.fetch_add(1, Ordering::SeqCst);
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            // Taking the lock orders the notify against the waiter's
            // registration window: the waiter holds the lock from
            // registering until it is atomically parked in the condvar
            // wait, so this notify cannot slip into that gap.
            let _g = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }

    /// Current sequence number (observe before sleeping).
    pub fn seq(&self) -> u64 {
        self.seq.load(Ordering::SeqCst)
    }

    /// Wait until the sequence passes `seen` or `timeout` elapses.
    /// Returns true if the sequence advanced.
    ///
    /// The verdict comes from re-checking the sequence, NOT from the
    /// condvar's timed-out flag: a ring that lands while a spurious
    /// wakeup has us near the timeout boundary must still report as a
    /// wake, and a spurious wakeup alone must never report one. The
    /// sequence is the ground truth; the timeout flag is not.
    #[cfg(not(loom))]
    pub fn wait(&self, seen: u64, timeout: Duration) -> bool {
        if self.seq.load(Ordering::SeqCst) > seen {
            return true;
        }
        let deadline = Instant::now() + timeout;
        let mut g = self.lock.lock().unwrap();
        self.sleepers.fetch_add(1, Ordering::SeqCst);
        // Re-check AFTER registering: a ring between the fast-path
        // check above and the registration skipped its notify (it saw
        // `sleepers == 0`) but bumped the sequence first — this load
        // must see it, or the wakeup would be lost.
        let woke = loop {
            if self.seq.load(Ordering::SeqCst) > seen {
                break true;
            }
            let now = Instant::now();
            if now >= deadline {
                break false;
            }
            let (g2, _timed_out) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = g2;
        };
        self.sleepers.fetch_sub(1, Ordering::SeqCst);
        drop(g);
        woke
    }

    /// Model-checked `wait`: same registration protocol, but the park
    /// is UNBOUNDED — under loom, wall-clock timeouts are meaningless
    /// and, crucially, removing the timeout escape hatch turns a lost
    /// wakeup into a deadlock that loom's scheduler detects and
    /// reports. The signature stays identical so every caller compiles
    /// under both cfgs.
    #[cfg(loom)]
    pub fn wait(&self, seen: u64, _timeout: Duration) -> bool {
        if self.seq.load(Ordering::SeqCst) > seen {
            return true;
        }
        let mut g = self.lock.lock().unwrap();
        self.sleepers.fetch_add(1, Ordering::SeqCst);
        // Re-check AFTER registering — the load the Dekker pair exists
        // to make correct (see the non-loom body).
        while self.seq.load(Ordering::SeqCst) <= seen {
            g = self.cv.wait(g).unwrap();
        }
        self.sleepers.fetch_sub(1, Ordering::SeqCst);
        drop(g);
        true
    }

    /// MUTATION SELF-TEST HOOK: `ring` with the Dekker pair demoted to
    /// Relaxed. Exists only under loom so
    /// `loom_doorbell_mutation_relaxed_ring_hangs` can prove the model
    /// is non-vacuous — this ordering loses wakeups, and loom catches
    /// it. Never compiled into production builds.
    #[cfg(loom)]
    pub(crate) fn ring_relaxed(&self) {
        self.seq.fetch_add(1, Ordering::Relaxed);
        if self.sleepers.load(Ordering::Relaxed) > 0 {
            let _g = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }
}

/// Exhaustive model checks of the doorbell's producer-races-park
/// protocol (correctness plane; see DESIGN.md). Run with
/// `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_`.
#[cfg(all(loom, test))]
mod loom_models {
    use super::Doorbell;
    use std::time::Duration;

    /// Protocol 1 — producer-races-park. The consumer snapshots the
    /// sequence, finds no work, and parks; the producer publishes and
    /// rings concurrently. Every interleaving must wake the consumer:
    /// under loom the park is unbounded, so a lost wakeup is a
    /// deadlock, and loom reports it.
    #[test]
    fn loom_doorbell_no_lost_wakeup() {
        loom::model(|| {
            let bell = Doorbell::new();
            let seen = bell.seq();
            let producer = {
                let bell = bell.clone();
                loom::thread::spawn(move || bell.ring())
            };
            // Snapshot-then-park: the ring may land before, during, or
            // after registration — all three windows are explored.
            let woke = bell.wait(seen, Duration::from_millis(1));
            assert!(woke, "wait must observe the ring");
            assert!(bell.seq() > seen);
            producer.join().unwrap();
        });
    }

    /// Two producers, one parked consumer: the batched notify (one
    /// lock + notify_all per ring) must still never strand the waiter.
    #[test]
    fn loom_doorbell_two_producers() {
        loom::model(|| {
            let bell = Doorbell::new();
            let seen = bell.seq();
            let p1 = {
                let bell = bell.clone();
                loom::thread::spawn(move || bell.ring())
            };
            let p2 = {
                let bell = bell.clone();
                loom::thread::spawn(move || bell.ring())
            };
            assert!(bell.wait(seen, Duration::from_millis(1)));
            p1.join().unwrap();
            p2.join().unwrap();
        });
    }

    /// Mutation self-test: with the ring's Dekker pair demoted to
    /// Relaxed (`ring_relaxed`), there is an interleaving where the
    /// producer reads `sleepers == 0` (skips the notify) while the
    /// consumer's post-registration re-check reads the stale sequence
    /// (parks forever) — the lost wakeup. loom must find it and panic;
    /// if this test ever stops panicking, the model has gone vacuous.
    #[test]
    #[should_panic]
    fn loom_doorbell_mutation_relaxed_ring_hangs() {
        loom::model(|| {
            let bell = Doorbell::new();
            let seen = bell.seq();
            let producer = {
                let bell = bell.clone();
                loom::thread::spawn(move || bell.ring_relaxed())
            };
            bell.wait(seen, Duration::from_millis(1));
            producer.join().unwrap();
        });
    }
}

/// How a pump behaves when an iteration finds no work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IdlePolicy {
    /// Busy-poll: never sleep. The SPDK polled-mode discipline — lowest
    /// wake latency, one full core per pump even when idle (the Fig 14
    /// baseline the paper's CPU numbers are measured against).
    Poll,
    /// The spin→yield→park ladder: spin `spin_iters` empty iterations,
    /// yield the core a few times, then park on the pump's doorbell
    /// with exponential backoff bounded by `park_timeout`.
    Adaptive {
        /// Empty iterations to spin before descending the ladder.
        spin_iters: u32,
        /// Upper bound on one park (and therefore on how stale any
        /// missed wake edge can make the pump).
        park_timeout: Duration,
    },
}

impl Default for IdlePolicy {
    /// Adaptive with a 1 ms park bound: microsecond reaction while
    /// traffic flows, ≥99% core savings at idle, and any missed ring
    /// edge degrades to at most 1 ms of latency.
    fn default() -> Self {
        IdlePolicy::Adaptive { spin_iters: 128, park_timeout: Duration::from_millis(1) }
    }
}

impl IdlePolicy {
    /// Parse the CLI surface: `poll`, `adaptive`, or
    /// `adaptive:<spin_iters>:<park_timeout_us>`.
    pub fn parse(s: &str) -> Option<IdlePolicy> {
        match s {
            "poll" => Some(IdlePolicy::Poll),
            "adaptive" => Some(IdlePolicy::default()),
            _ => {
                let rest = s.strip_prefix("adaptive:")?;
                let (spin, park_us) = rest.split_once(':')?;
                Some(IdlePolicy::Adaptive {
                    spin_iters: spin.parse().ok()?,
                    park_timeout: Duration::from_micros(park_us.parse().ok()?),
                })
            }
        }
    }

    /// Short label for reports and JSON.
    pub fn label(&self) -> &'static str {
        match self {
            IdlePolicy::Poll => "poll",
            IdlePolicy::Adaptive { .. } => "adaptive",
        }
    }

    /// Worst-case staleness one park can add: zero under `Poll` (which
    /// never sleeps), the policy's `park_timeout` under `Adaptive`.
    /// Callers sizing settle/quiesce windows — and the fanout plane's
    /// idle-flow TTL sweep, whose cadence at full idle is exactly one
    /// sweep per expired park — use this instead of matching on the
    /// variant.
    pub fn park_bound(&self) -> Duration {
        match self {
            IdlePolicy::Poll => Duration::ZERO,
            IdlePolicy::Adaptive { park_timeout, .. } => *park_timeout,
        }
    }
}

/// Yield rung length between spinning and parking.
const YIELD_ITERS: u32 = 16;
/// First park of an idle stretch (doubles per consecutive park up to
/// the policy's `park_timeout`): short, so work that arrives just
/// after a park begins is picked up quickly even without a ring.
const MIN_PARK: Duration = Duration::from_micros(64);
/// Cap on the bounded nap used when work is in flight but nothing is
/// pollable yet (no doorbell can ring a completion home) — polling for
/// completions must stay snappy.
const NAP_CAP: Duration = Duration::from_micros(100);
/// How many iterations may pass before the governor flushes the
/// running busy segment into the ledger (so `Poll` pumps, which never
/// park, still report busy time).
const FLUSH_EVERY: u32 = 1024;

/// Outcome of [`IdleGovernor::idle_recv`].
pub enum IdleRecv<T> {
    /// The park ended because a message arrived.
    Got(T),
    /// Still idle (spun, yielded, or the bounded park timed out).
    Empty,
    /// The channel's senders are gone.
    Disconnected,
}

/// Which rung of the ladder the current empty streak has reached —
/// the ONE dispatch shared by every idle entry point, so the three
/// park flavors (doorbell / channel / nap) can never drift apart on
/// the spin/yield thresholds.
enum Rung {
    Spin,
    Yield,
    /// Park with this bounded timeout.
    Park(Duration),
}

/// Per-pump ladder state machine. One governor per pump thread; it
/// owns the pump's position on the spin→yield→park ladder and writes
/// the pump's [`CpuLedger`].
pub struct IdleGovernor {
    policy: IdlePolicy,
    ledger: Arc<CpuLedger>,
    /// Consecutive empty iterations (the ladder rung index).
    empty_streak: u32,
    /// Consecutive parks in this idle stretch (the backoff exponent).
    park_streak: u32,
    /// Start of the current busy (non-parked) wall-time segment.
    segment: Instant,
    /// Iterations since the busy segment was last flushed.
    unflushed: u32,
}

impl IdleGovernor {
    pub fn new(policy: IdlePolicy, ledger: Arc<CpuLedger>) -> Self {
        IdleGovernor {
            policy,
            ledger,
            empty_streak: 0,
            park_streak: 0,
            segment: Instant::now(),
            unflushed: 0,
        }
    }

    pub fn policy(&self) -> IdlePolicy {
        self.policy
    }

    pub fn ledger(&self) -> &Arc<CpuLedger> {
        &self.ledger
    }

    /// Account one pump iteration; productive work resets the ladder.
    pub fn iteration(&mut self, productive: bool) {
        self.ledger.iteration(productive);
        if productive {
            self.empty_streak = 0;
            self.park_streak = 0;
        } else {
            self.empty_streak = self.empty_streak.saturating_add(1);
        }
        self.unflushed += 1;
        if self.unflushed >= FLUSH_EVERY {
            self.flush_busy();
        }
    }

    fn flush_busy(&mut self) {
        let now = Instant::now();
        self.ledger.add_busy(now - self.segment);
        self.segment = now;
        self.unflushed = 0;
    }

    /// The park timeout the ladder has escalated to: exponential from
    /// [`MIN_PARK`], bounded by the policy's `park_timeout`.
    fn backoff(&self, park_timeout: Duration) -> Duration {
        MIN_PARK.saturating_mul(1u32 << self.park_streak.min(16)).min(park_timeout)
    }

    /// Ladder dispatch for the current empty streak under `Adaptive`
    /// (`Poll` never reaches this): spin, then yield, then park with
    /// the escalated backoff. Executes the spin/yield rungs itself —
    /// callers only implement their park flavor.
    fn rung(&mut self, spin_iters: u32, park_timeout: Duration) -> Rung {
        if self.empty_streak <= spin_iters {
            std::hint::spin_loop();
            Rung::Spin
        } else if self.empty_streak <= spin_iters + YIELD_ITERS {
            std::thread::yield_now();
            Rung::Yield
        } else {
            Rung::Park(self.backoff(park_timeout))
        }
    }

    fn account_park(&mut self, parked: Duration, woke: bool) {
        self.ledger.park(parked, woke);
        self.park_streak = self.park_streak.saturating_add(1);
        self.segment = Instant::now();
    }

    /// A park ended with work already in hand (e.g. the channel park
    /// returned a message): book processing it as its own productive
    /// pass and reset the ladder. The pre-park scan stays an
    /// `empty_poll` — it genuinely found nothing — so every ledger
    /// counter remains monotonic and `productive <= iterations` holds,
    /// at the cost of one extra `iterations` tick per park-wake cycle.
    pub fn woke_with_work(&mut self) {
        self.iteration(true);
    }

    /// After an empty iteration: climb down the ladder — spin, yield,
    /// then park on `bell` until its sequence passes `seen` or the
    /// bounded backoff elapses. Returns true if the pump parked.
    ///
    /// `seen` MUST have been read from `bell` BEFORE the pump scanned
    /// for work: a producer that published after the scan has
    /// necessarily rung past it, so the wait returns immediately and
    /// the wakeup cannot be lost.
    pub fn idle(&mut self, bell: &Doorbell, seen: u64) -> bool {
        match self.policy {
            IdlePolicy::Poll => {
                std::thread::yield_now();
                false
            }
            IdlePolicy::Adaptive { spin_iters, park_timeout } => {
                match self.rung(spin_iters, park_timeout) {
                    Rung::Spin | Rung::Yield => false,
                    Rung::Park(timeout) => {
                        self.flush_busy();
                        let t0 = Instant::now();
                        let woke = bell.wait(seen, timeout);
                        self.account_park(t0.elapsed(), woke);
                        true
                    }
                }
            }
        }
    }

    /// Channel-park rung for pumps that sleep on an mpsc receiver
    /// instead of a doorbell (the shard loop): same ladder, but the
    /// park is a bounded blocking `recv` — the channel itself is the
    /// doorbell, so a send during the park wakes the pump and nothing
    /// can be lost. Under `Poll` this never blocks.
    pub fn idle_recv<T>(&mut self, rx: &mpsc::Receiver<T>) -> IdleRecv<T> {
        match self.policy {
            IdlePolicy::Poll => {
                std::thread::yield_now();
                IdleRecv::Empty
            }
            IdlePolicy::Adaptive { spin_iters, park_timeout } => {
                match self.rung(spin_iters, park_timeout) {
                    Rung::Spin | Rung::Yield => IdleRecv::Empty,
                    Rung::Park(timeout) => {
                        self.flush_busy();
                        let t0 = Instant::now();
                        match rx.recv_timeout(timeout) {
                            Ok(v) => {
                                self.account_park(t0.elapsed(), true);
                                IdleRecv::Got(v)
                            }
                            Err(mpsc::RecvTimeoutError::Timeout) => {
                                self.account_park(t0.elapsed(), false);
                                IdleRecv::Empty
                            }
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                self.account_park(t0.elapsed(), false);
                                IdleRecv::Disconnected
                            }
                        }
                    }
                }
            }
        }
    }

    /// Bounded nap for the "work in flight but nothing pollable yet"
    /// state (completions have no doorbell into this pump): spin and
    /// yield first, then sleep one short bounded step so the next poll
    /// is never far away.
    pub fn idle_nap(&mut self) {
        match self.policy {
            IdlePolicy::Poll => std::thread::yield_now(),
            IdlePolicy::Adaptive { spin_iters, park_timeout } => {
                match self.rung(spin_iters, park_timeout) {
                    Rung::Spin | Rung::Yield => {}
                    Rung::Park(timeout) => {
                        self.flush_busy();
                        let t0 = Instant::now();
                        // LINT: sleep-ok(bounded nap capped at NAP_CAP —
                        // completions have no doorbell into this pump, and
                        // the park is accounted to the governor below)
                        std::thread::sleep(timeout.min(NAP_CAP));
                        self.account_park(t0.elapsed(), false);
                    }
                }
            }
        }
    }
}

// Wall-clock tests are meaningless (and these would hang) under the
// model scheduler; loom builds run only the `loom_models` mod above.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn doorbell_wakes_waiter() {
        let db = Doorbell::new();
        let seen = db.seq();
        let db2 = db.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            db2.ring();
        });
        assert!(db.wait(seen, Duration::from_secs(2)));
        t.join().unwrap();
    }

    #[test]
    fn doorbell_timeout() {
        let db = Doorbell::new();
        let seen = db.seq();
        assert!(!db.wait(seen, Duration::from_millis(10)));
    }

    /// The wait verdict must be the sequence, not the condvar's
    /// timed-out flag: race rings right at the timeout boundary and
    /// check both directions of the implication on every outcome.
    #[test]
    fn doorbell_wait_verdict_tracks_sequence_at_timeout_boundary() {
        let db = Doorbell::new();
        for round in 0..60u64 {
            let seen = db.seq();
            let db2 = db.clone();
            // Ring somewhere in [0, 3) ms while the waiter uses ~1.5 ms,
            // so rings land before, around, and after the boundary.
            let delay = Duration::from_micros((round % 6) * 500);
            let t = std::thread::spawn(move || {
                std::thread::sleep(delay);
                db2.ring();
            });
            let woke = db.wait(seen, Duration::from_micros(1500));
            // `true` must mean the sequence really advanced…
            if woke {
                assert!(db.seq() > seen, "round {round}: woke without a ring");
            }
            t.join().unwrap();
            // …and once the ring has landed, a zero-timeout wait (all
            // boundary, no budget) must still see it.
            assert!(db.wait(seen, Duration::ZERO), "round {round}: ring lost at boundary");
        }
    }

    /// A stale `seen` from before earlier rings never blocks.
    #[test]
    fn doorbell_wait_returns_immediately_when_already_passed() {
        let db = Doorbell::new();
        db.ring();
        db.ring();
        let start = Instant::now();
        assert!(db.wait(0, Duration::from_secs(5)));
        assert!(start.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn policy_parse() {
        assert_eq!(IdlePolicy::parse("poll"), Some(IdlePolicy::Poll));
        assert_eq!(IdlePolicy::parse("adaptive"), Some(IdlePolicy::default()));
        assert_eq!(
            IdlePolicy::parse("adaptive:32:2500"),
            Some(IdlePolicy::Adaptive {
                spin_iters: 32,
                park_timeout: Duration::from_micros(2500),
            })
        );
        assert_eq!(IdlePolicy::parse("bogus"), None);
        assert_eq!(IdlePolicy::parse("adaptive:x:1"), None);
    }

    /// The governor must descend to the park rung on a long empty
    /// streak and climb back up on productive work.
    #[test]
    fn governor_ladder_parks_and_resets() {
        let ledger = CpuLedger::new();
        let mut gov = IdleGovernor::new(
            IdlePolicy::Adaptive { spin_iters: 2, park_timeout: Duration::from_millis(1) },
            ledger.clone(),
        );
        let bell = Doorbell::new();
        let mut parked = false;
        for _ in 0..64 {
            let seen = bell.seq();
            gov.iteration(false);
            parked |= gov.idle(&bell, seen);
        }
        assert!(parked, "long empty streak must reach the park rung");
        let s = ledger.snapshot();
        assert!(s.parks > 0 && s.parked_ns > 0);
        assert_eq!(s.wakes, 0, "nothing rang");
        // Productive work resets the ladder: the next idle spin, not
        // park.
        gov.iteration(true);
        let seen = bell.seq();
        gov.iteration(false);
        let p = ledger.snapshot().parks;
        assert!(!gov.idle(&bell, seen), "ladder must restart at the spin rung");
        assert_eq!(ledger.snapshot().parks, p);
    }

    /// Park backoff is bounded by the policy's park_timeout.
    #[test]
    fn governor_backoff_is_bounded() {
        let gov = IdleGovernor {
            policy: IdlePolicy::Poll,
            ledger: CpuLedger::new(),
            empty_streak: 0,
            park_streak: 40, // far past any shift width
            segment: Instant::now(),
            unflushed: 0,
        };
        let cap = Duration::from_millis(3);
        assert_eq!(gov.backoff(cap), cap);
        let gov0 = IdleGovernor { park_streak: 0, ..gov };
        assert_eq!(gov0.backoff(cap), MIN_PARK);
    }

    /// A ring captured before the work scan can never be slept
    /// through: the wait sees the advanced sequence immediately.
    #[test]
    fn ring_between_scan_and_park_is_not_lost() {
        let bell = Doorbell::new();
        for _ in 0..200 {
            let seen = bell.seq();
            // "Scan finds nothing"… then the producer publishes + rings.
            bell.ring();
            let t0 = Instant::now();
            assert!(bell.wait(seen, Duration::from_secs(10)));
            assert!(t0.elapsed() < Duration::from_secs(1), "wait must return immediately");
        }
    }
}
