//! DDS leader binary: CLI for running the functional server demo, the
//! kernel runtime smoke test, and quick testbed scenarios.
//!
//! (CLI parsing is hand-rolled: the build environment is offline and
//! has no clap.)

use std::sync::Arc;
use std::time::Duration;

use dds::apps::RawFileApp;
use dds::baselines::{run_stack, IoDir, StackKind};
use dds::coordinator::{
    run_request, ClientConn, DisaggregatedServer, StorageServer, StorageServerConfig,
};
use dds::director::AppSignature;
use dds::metrics::{fmt_ns, fmt_ops};
use dds::net::FiveTuple;
use dds::offload::{OffloadEngineConfig, RawFileOffload};
use dds::runtime::KernelRuntime;
use dds::sim::Params;
use dds::workload::RandomIoGen;

const USAGE: &str = "\
dds — DPU-optimized Disaggregated Storage (reproduction)

USAGE:
    dds serve [--requests N] [--batch B] [--io BYTES] [--no-offload]
              [--shards N] [--idle-policy poll|adaptive|adaptive:S:US]
              [--burst N] [--tenants T] [--rate R] [--max-flows F]
              [--durable-data] [--cache-mb N]
        run the full functional server (client → director → offload
        engine / host app → SSD) in-process and report throughput;
        --shards > 1 runs the RSS-sharded data plane (one shard
        thread per DPU core, one client pipeline per shard).
        --idle-policy sets the pump discipline: `poll` busy-polls
        (one core per pump, the Fig 14 baseline), `adaptive`
        (default) spins then parks on wake doorbells;
        `adaptive:S:US` = spin S empty iterations, park ≤ US µs.
        --burst caps how many packet batches a shard drains per
        pipeline pass (default 64) — larger bursts amortize more
        per-record overhead, smaller ones tighten latency.
        --tenants partitions flows into T QoS buckets (by client
        IP); --rate caps each tenant at R requests/s (token
        bucket, 0 = unlimited); --max-flows caps open flows per
        tenant per shard (0 = unlimited). Limits only apply on the
        sharded path; a per-tenant report prints at exit.
        --durable-data acks a WRITE only after its redirect-on-
        write remap record is journaled: a power cut never tears
        an acked WRITE (crash-atomic data path, slower acks).
        --cache-mb sizes the DPU read-cache tier in MiB (0 =
        disabled, the default): READ hits are served from DPU
        memory without touching the SSD, write-through
        invalidated on every WRITE ack; a per-tier counter
        report (hits, misses, fills, evictions) prints at exit.
        A CPU report (busy fraction, parks, wakes) prints at exit.
        The mount-time recovery summary (what crash recovery
        observed and repaired) prints at startup.
    dds kernels
        load artifacts/*.hlo.txt into the PJRT runtime and smoke-test
    dds stack <1..10> [--io BYTES] [--window W] [--write]
        run one §8.4 storage-stack configuration on the testbed
    dds help
";

fn arg_val(args: &[String], flag: &str) -> Option<String> {
    args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("serve") => serve(&args),
        Some("kernels") => kernels(),
        Some("stack") => stack(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn serve(args: &[String]) -> anyhow::Result<()> {
    use dds::idle::IdlePolicy;
    let n_requests: usize =
        arg_val(args, "--requests").map_or(2000, |v| v.parse().unwrap_or(2000));
    let batch: usize = arg_val(args, "--batch").map_or(8, |v| v.parse().unwrap_or(8));
    let io: u32 = arg_val(args, "--io").map_or(1024, |v| v.parse().unwrap_or(1024));
    let offload = !args.iter().any(|a| a == "--no-offload");
    let durable_data = args.iter().any(|a| a == "--durable-data");
    let cache_mb: u64 = arg_val(args, "--cache-mb").map_or(0, |v| v.parse().unwrap_or(0));
    let shards: usize = arg_val(args, "--shards").map_or(1, |v| v.parse().unwrap_or(1));
    let burst: usize =
        arg_val(args, "--burst").map_or(64, |v| v.parse().unwrap_or(64)).max(1);
    let idle = match arg_val(args, "--idle-policy") {
        Some(v) => IdlePolicy::parse(&v)
            .ok_or_else(|| anyhow::anyhow!("bad --idle-policy {v:?} (poll | adaptive | adaptive:S:US)"))?,
        None => IdlePolicy::default(),
    };
    let tenants = dds::director::TenantPlaneConfig {
        tenants: arg_val(args, "--tenants").map_or(1, |v| v.parse().unwrap_or(1)).max(1),
        rate: arg_val(args, "--rate").map_or(0, |v| v.parse().unwrap_or(0)),
        max_flows: arg_val(args, "--max-flows").map_or(0, |v| v.parse().unwrap_or(0)),
        ..Default::default()
    };

    println!(
        "building storage server (offload={offload}, io={io}B, batch={batch}, shards={shards}, burst={burst}, idle={}, durable_data={durable_data}, cache={cache_mb}MiB)…",
        idle.label()
    );
    let logic = Arc::new(RawFileOffload);
    let mut storage_cfg = StorageServerConfig::default();
    storage_cfg.service.idle = idle;
    storage_cfg.service.durable_data = durable_data;
    storage_cfg.cache_bytes = cache_mb << 20;
    let storage = StorageServer::build(storage_cfg, Some(logic.clone()))?;
    print_recovery(&storage.front_end());

    // Host application with a pre-filled data file.
    let file_bytes: u64 = 32 << 20;
    let file = storage.create_filled_file("bench", "data", file_bytes)?;
    let file_id = file.id;

    if shards > 1 {
        return serve_sharded(
            storage, logic, offload, file, n_requests, batch, io, file_bytes, shards, idle,
            burst, tenants,
        );
    }

    let app = RawFileApp::over(&storage, &file)?;
    let signature = AppSignature::server_port(5000);
    let mut server = if offload {
        DisaggregatedServer::new(storage, logic, signature, OffloadEngineConfig::default(), app)
    } else {
        DisaggregatedServer::baseline(storage, signature, app)
    };

    let tuple = FiveTuple::new(0x0a00_0001, 40001, 0x0a00_00ff, 5000);
    let mut client = ClientConn::new(tuple);
    let mut gen = RandomIoGen::new(file_id.0, file_bytes, io, 1.0, batch, 42);

    let t0 = std::time::Instant::now();
    let mut done = 0usize;
    while done < n_requests {
        let msg = gen.next_msg();
        let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(10))?;
        anyhow::ensure!(resps.iter().all(|r| r.status == 0), "request failed");
        done += resps.len();
    }
    let dt = t0.elapsed();
    let rate = done as f64 / dt.as_secs_f64();
    println!(
        "served {done} requests in {dt:.2?} → {} IOPS (functional in-proc path)",
        fmt_ops(rate)
    );
    println!(
        "director: offloaded={} to_host={}",
        server.director.reqs_offloaded, server.director.reqs_to_host
    );
    print_cpu("file-service", &server.storage.cpu_stats());
    print_latency(&server.storage.latency_stats());
    print_cache(server.storage.tier.as_deref());
    Ok(())
}

/// Read-cache tier exit report (only printed when a tier is attached).
fn print_cache(tier: Option<&dds::cache::ReadCacheTier>) {
    let Some(tier) = tier else { return };
    let s = tier.stats();
    let lookups = s.hits + s.misses;
    let ratio = if lookups > 0 { s.hits as f64 / lookups as f64 } else { 0.0 };
    println!(
        "cache: hit {:.1}% ({}/{} lookups)  fills={} (dropped={})  inval={} evict={}  \
         served={}B  resident={}B/{}B ({} entries)",
        ratio * 100.0,
        s.hits,
        lookups,
        s.fills,
        s.fill_drops,
        s.invalidations,
        s.evictions,
        s.bytes_served,
        s.bytes_cached,
        s.budget_bytes,
        s.entries
    );
}

/// Operator-facing mount summary: what crash recovery observed and
/// repaired, fetched over the control plane the same way an external
/// operator tool would (`DdsClient::recovery_report`).
fn print_recovery(fe: &dds::filelib::DdsClient) {
    match fe.recovery_report() {
        Ok(Some(r)) => println!(
            "recovery: mounted at seq {} (slots valid {:?}, superblock seq {:?}); \
             journal: {} records / {} commits{}; data path: {} remaps replayed, \
             {} torn extents quarantined{}{}{}",
            r.recovered_seq,
            r.valid_slots,
            r.superblock_seq,
            r.journal_records,
            r.journal_commits,
            if r.torn_tail { ", torn tail" } else { "" },
            r.remaps_applied,
            r.quarantined_extents,
            if r.rolled_forward { "; rolled forward" } else { "" },
            if r.repaired_superblock { "; superblock repaired" } else { "" },
            if r.counters_clamped { "; id counters clamped" } else { "" },
        ),
        Ok(None) => println!("recovery: freshly formatted volume (no crash recovery ran)"),
        Err(e) => println!("recovery: report unavailable ({e})"),
    }
}

/// The tracked tail-latency trajectory (p50/p99/p99.9) at exit.
fn print_latency(l: &dds::metrics::LatencyStats) {
    if l.count == 0 {
        return;
    }
    println!(
        "latency: n={} p50={} p99={} p99.9={} max={}",
        l.count,
        fmt_ns(l.p50_ns),
        fmt_ns(l.p99_ns),
        fmt_ns(l.p999_ns),
        fmt_ns(l.max_ns)
    );
}

/// One pump's CPU-plane line (the functional Fig 14 axis).
fn print_cpu(name: &str, c: &dds::metrics::CpuStats) {
    println!(
        "cpu[{name}]: busy {:.1}%  iterations={} (productive={})  parks={} wakes={}",
        c.busy_fraction() * 100.0,
        c.iterations,
        c.productive,
        c.parks,
        c.wakes
    );
}

/// The RSS-sharded serve path: N shard threads, one client pipeline
/// per shard, aggregate IOPS across all of them.
#[allow(clippy::too_many_arguments)]
fn serve_sharded(
    storage: StorageServer,
    logic: Arc<RawFileOffload>,
    offload: bool,
    file: dds::filelib::DdsFile,
    n_requests: usize,
    batch: usize,
    io: u32,
    file_bytes: u64,
    shards: usize,
    idle: dds::idle::IdlePolicy,
    burst: usize,
    tenants: dds::director::TenantPlaneConfig,
) -> anyhow::Result<()> {
    use dds::coordinator::{
        run_sharded_request, tuple_for_shard, ShardDriver, ShardedServer, ShardedServerConfig,
    };
    use dds::offload::{NoOffload, OffloadLogic};

    let logic_dyn: Arc<dyn OffloadLogic> =
        if offload { logic } else { Arc::new(NoOffload) };
    let cfg = ShardedServerConfig { shards, idle, burst, tenants, ..Default::default() };
    let server = ShardedServer::over(
        storage,
        cfg,
        logic_dyn,
        AppSignature::server_port(5000),
        |_shard, st| RawFileApp::over(st, &file),
    )?;

    let fid = file.id.0;
    let per_shard = n_requests.div_ceil(shards).max(1);
    let t0 = std::time::Instant::now();
    let total = std::thread::scope(|scope| -> anyhow::Result<u64> {
        let mut handles = Vec::new();
        for s in 0..shards {
            let server = &server;
            handles.push(scope.spawn(move || -> anyhow::Result<u64> {
                let mut driver = ShardDriver::new(s);
                let t = tuple_for_shard(
                    s,
                    shards,
                    0x0a00_0001,
                    40_001 + s as u16 * 131,
                    0x0a00_00ff,
                    5000,
                );
                driver.connect(server, t)?;
                let mut gen = RandomIoGen::new(fid, file_bytes, io, 1.0, batch, 42 + s as u64);
                let mut done = 0u64;
                while (done as usize) < per_shard {
                    let msg = gen.next_msg();
                    let resps = run_sharded_request(
                        server,
                        &mut driver,
                        &t,
                        &msg,
                        Duration::from_secs(10),
                    )?;
                    anyhow::ensure!(resps.iter().all(|r| r.status == 0), "request failed");
                    done += resps.len() as u64;
                }
                Ok(done)
            }));
        }
        let mut total = 0u64;
        for h in handles {
            total += h.join().expect("shard driver panicked")?;
        }
        Ok(total)
    })?;
    let dt = t0.elapsed();
    println!(
        "served {total} requests across {shards} shards in {dt:.2?} → {} IOPS (functional sharded path)",
        fmt_ops(total as f64 / dt.as_secs_f64())
    );
    let agg = server.stats();
    println!(
        "aggregate: offloaded={} to_host={} flows={}",
        agg.reqs_offloaded, agg.reqs_to_host, agg.flows
    );
    for st in server.shard_stats() {
        println!(
            "  shard {}: msgs={} offloaded={} to_host={}",
            st.shard, st.msgs_in, st.reqs_offloaded, st.reqs_to_host
        );
    }
    // all_cpu_stats is the canonical all-pumps view: index 0 is the
    // file service, the rest are shards (a future pump added there
    // shows up here automatically).
    for (i, c) in server.all_cpu_stats().iter().enumerate() {
        let name =
            if i == 0 { "file-service".to_string() } else { format!("shard-{}", i - 1) };
        print_cpu(&name, c);
    }
    print_latency(&server.latency_stats());
    print_cache(server.storage.tier.as_deref());
    for t in server.tenant_stats() {
        println!(
            "tenant {}: admitted={} completed={} rejected={} throttled={} flows={} (rejected={})",
            t.tenant,
            t.admitted,
            t.completed,
            t.rejected_pending,
            t.throttled,
            t.flows,
            t.flows_rejected
        );
    }
    Ok(())
}

fn kernels() -> anyhow::Result<()> {
    let dir = KernelRuntime::artifacts_dir();
    println!("loading kernels from {dir:?}…");
    let mut rt = KernelRuntime::cpu()?;
    let names = rt.load_dir(&dir)?;
    anyhow::ensure!(!names.is_empty(), "no artifacts found — run `make artifacts`");
    println!("loaded: {names:?}");
    // Smoke: run the checksum kernel against the rust reference.
    let pages: Vec<u8> = (0..dds::runtime::CHECKSUM_BATCH * dds::runtime::CHECKSUM_PAGE)
        .map(|i| (i % 251) as u8)
        .collect();
    let sums = rt.checksum_batch(&pages)?;
    for (i, chunk) in pages.chunks(dds::runtime::CHECKSUM_PAGE).enumerate() {
        anyhow::ensure!(
            sums[i] == dds::runtime::checksum_ref(chunk),
            "checksum mismatch on page {i}"
        );
    }
    println!("checksum kernel OK ({} pages)", sums.len());
    Ok(())
}

fn stack(args: &[String]) -> anyhow::Result<()> {
    let idx: usize = args
        .get(1)
        .and_then(|v| v.parse().ok())
        .filter(|v| (1..=10).contains(v))
        .ok_or_else(|| anyhow::anyhow!("stack index must be 1..10"))?;
    let io: usize = arg_val(args, "--io").map_or(1024, |v| v.parse().unwrap_or(1024));
    let window: usize = arg_val(args, "--window").map_or(256, |v| v.parse().unwrap_or(256));
    let dir = if args.iter().any(|a| a == "--write") { IoDir::Write } else { IoDir::Read };
    let kind = StackKind::ALL[idx - 1];
    let p = Params::paper();
    let r = run_stack(kind, dir, io, window, 8, &p);
    println!("{}", kind.label());
    println!("  throughput : {} IOPS", fmt_ops(r.throughput));
    println!("  p50 / p99  : {} / {}", fmt_ns(r.p50_ns), fmt_ns(r.p99_ns));
    println!(
        "  cores      : server {:.2}  client {:.2}  dpu {:.2}",
        r.server_cores, r.client_cores, r.dpu_cores
    );
    Ok(())
}
