//! DDS leader binary: CLI for running the functional server demo, the
//! kernel runtime smoke test, and quick testbed scenarios.
//!
//! (CLI parsing is hand-rolled: the build environment is offline and
//! has no clap.)

use std::sync::Arc;
use std::time::Duration;

use dds::apps::RawFileApp;
use dds::baselines::{run_stack, IoDir, StackKind};
use dds::coordinator::{
    run_request, ClientConn, DisaggregatedServer, StorageServer, StorageServerConfig,
};
use dds::director::AppSignature;
use dds::metrics::{fmt_ns, fmt_ops};
use dds::net::FiveTuple;
use dds::offload::{OffloadEngineConfig, RawFileOffload};
use dds::runtime::KernelRuntime;
use dds::sim::Params;
use dds::workload::RandomIoGen;

const USAGE: &str = "\
dds — DPU-optimized Disaggregated Storage (reproduction)

USAGE:
    dds serve [--requests N] [--batch B] [--io BYTES] [--no-offload]
        run the full functional server (client → director → offload
        engine / host app → SSD) in-process and report throughput
    dds kernels
        load artifacts/*.hlo.txt into the PJRT runtime and smoke-test
    dds stack <1..10> [--io BYTES] [--window W] [--write]
        run one §8.4 storage-stack configuration on the testbed
    dds help
";

fn arg_val(args: &[String], flag: &str) -> Option<String> {
    args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("serve") => serve(&args),
        Some("kernels") => kernels(),
        Some("stack") => stack(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn serve(args: &[String]) -> anyhow::Result<()> {
    let n_requests: usize =
        arg_val(args, "--requests").map_or(2000, |v| v.parse().unwrap_or(2000));
    let batch: usize = arg_val(args, "--batch").map_or(8, |v| v.parse().unwrap_or(8));
    let io: u32 = arg_val(args, "--io").map_or(1024, |v| v.parse().unwrap_or(1024));
    let offload = !args.iter().any(|a| a == "--no-offload");

    println!("building storage server (offload={offload}, io={io}B, batch={batch})…");
    let logic = Arc::new(RawFileOffload);
    let storage = StorageServer::build(StorageServerConfig::default(), Some(logic.clone()))?;

    // Host application with a data file.
    let fe = storage.front_end();
    let dir = fe.create_directory("bench").map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut file = fe.create_file(dir, "data").map_err(|e| anyhow::anyhow!("{e}"))?;
    let group = fe.create_poll().map_err(|e| anyhow::anyhow!("{e}"))?;
    fe.poll_add(&mut file, &group);
    let file_bytes: u64 = 32 << 20;
    // Fill the file in 128 KiB writes (inlined payloads must fit the
    // ring's max allowable progress).
    let chunk = 128 << 10;
    let mut pending = std::collections::HashSet::new();
    for off in (0..file_bytes).step_by(chunk) {
        let fill: Vec<u8> = (off..off + chunk as u64).map(|i| (i % 253) as u8).collect();
        // Non-blocking issue with RingFull backpressure: drain
        // completions until the ring admits the next write.
        loop {
            match fe.write_file(&file, off, &fill) {
                Ok(id) => {
                    pending.insert(id);
                    break;
                }
                Err(dds::filelib::LibError::RingFull) => {
                    for ev in group.poll_wait(Duration::from_millis(20)) {
                        pending.remove(&ev.req_id);
                    }
                }
                Err(e) => anyhow::bail!("write_file: {e}"),
            }
        }
    }
    while !pending.is_empty() {
        for ev in group.poll_wait(Duration::from_millis(100)) {
            pending.remove(&ev.req_id);
        }
    }
    let file_id = file.id;

    let app = RawFileApp { client: fe, file, group };
    let signature = AppSignature::server_port(5000);
    let mut server = if offload {
        DisaggregatedServer::new(storage, logic, signature, OffloadEngineConfig::default(), app)
    } else {
        DisaggregatedServer::baseline(storage, signature, app)
    };

    let tuple = FiveTuple::new(0x0a00_0001, 40001, 0x0a00_00ff, 5000);
    let mut client = ClientConn::new(tuple);
    let mut gen = RandomIoGen::new(file_id.0, file_bytes, io, 1.0, batch, 42);

    let t0 = std::time::Instant::now();
    let mut done = 0usize;
    while done < n_requests {
        let msg = gen.next_msg();
        let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(10))?;
        anyhow::ensure!(resps.iter().all(|r| r.status == 0), "request failed");
        done += resps.len();
    }
    let dt = t0.elapsed();
    let rate = done as f64 / dt.as_secs_f64();
    println!(
        "served {done} requests in {dt:.2?} → {} IOPS (functional in-proc path)",
        fmt_ops(rate)
    );
    println!(
        "director: offloaded={} to_host={}",
        server.director.reqs_offloaded, server.director.reqs_to_host
    );
    Ok(())
}

fn kernels() -> anyhow::Result<()> {
    let dir = KernelRuntime::artifacts_dir();
    println!("loading kernels from {dir:?}…");
    let mut rt = KernelRuntime::cpu()?;
    let names = rt.load_dir(&dir)?;
    anyhow::ensure!(!names.is_empty(), "no artifacts found — run `make artifacts`");
    println!("loaded: {names:?}");
    // Smoke: run the checksum kernel against the rust reference.
    let pages: Vec<u8> = (0..dds::runtime::CHECKSUM_BATCH * dds::runtime::CHECKSUM_PAGE)
        .map(|i| (i % 251) as u8)
        .collect();
    let sums = rt.checksum_batch(&pages)?;
    for (i, chunk) in pages.chunks(dds::runtime::CHECKSUM_PAGE).enumerate() {
        anyhow::ensure!(
            sums[i] == dds::runtime::checksum_ref(chunk),
            "checksum mismatch on page {i}"
        );
    }
    println!("checksum kernel OK ({} pages)", sums.len());
    Ok(())
}

fn stack(args: &[String]) -> anyhow::Result<()> {
    let idx: usize = args
        .get(1)
        .and_then(|v| v.parse().ok())
        .filter(|v| (1..=10).contains(v))
        .ok_or_else(|| anyhow::anyhow!("stack index must be 1..10"))?;
    let io: usize = arg_val(args, "--io").map_or(1024, |v| v.parse().unwrap_or(1024));
    let window: usize = arg_val(args, "--window").map_or(256, |v| v.parse().unwrap_or(256));
    let dir = if args.iter().any(|a| a == "--write") { IoDir::Write } else { IoDir::Read };
    let kind = StackKind::ALL[idx - 1];
    let p = Params::paper();
    let r = run_stack(kind, dir, io, window, 8, &p);
    println!("{}", kind.label());
    println!("  throughput : {} IOPS", fmt_ops(r.throughput));
    println!("  p50 / p99  : {} / {}", fmt_ns(r.p50_ns), fmt_ns(r.p99_ns));
    println!(
        "  cores      : server {:.2}  client {:.2}  dpu {:.2}",
        r.server_cores, r.client_cores, r.dpu_cores
    );
    Ok(())
}
