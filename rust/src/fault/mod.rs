//! The fault-injection plane: deterministic, seeded chaos for the
//! functional data path.
//!
//! DDS's reliability story is that the DPU fast path degrades
//! gracefully: an offload-engine miss or failure falls back to the host
//! slow path with no client-visible difference (§6.2 Fig 13 lines 5-7
//! generalized to whole-engine failure), and lost SSD completions
//! surface as bounded-time errors instead of hangs. This module makes
//! that story testable by injecting faults at explicit hook points,
//! all driven by one seed so every failing schedule replays exactly:
//!
//! * **SSD queues** ([`SsdFaultInjector`], consumed by
//!   [`crate::ssd::AsyncSsd`]) — completions can be *failed*
//!   (`Err(SsdError::Injected)`), *dropped* (the op executes but its
//!   completion never arrives), or *delayed* (held for N polls).
//! * **The wire** ([`WireChaos`]) — segment drop / duplication /
//!   reordering between a client and the DPU, exercising dup-ACK fast
//!   retransmit and the `retransmit_all` timeout path.
//! * **Offload engines** — a shard's engine can be marked failed
//!   ([`crate::coordinator::ShardedServer::set_engine_failed`]); its
//!   requests then bounce to the host file-service slow path.
//! * **File-service poll groups** — a group can be stalled for N
//!   service iterations
//!   ([`crate::fileservice::ControlMsg::InjectGroupStall`]).
//! * **The power rail** ([`FaultSite::PowerCut`], consumed by
//!   [`crate::ssd::Ssd::arm_power_cut`]) — one device write is torn
//!   after a seed-chosen byte count and the device stays dead until
//!   "reboot", exercising the metadata journal's crash recovery
//!   ([`scenario::crash_recovery`]).
//!
//! Every probabilistic decision comes from a per-site
//! [`crate::sim::Rng`] stream derived from the plane's seed, and every
//! injection is logged as a [`FaultEvent`]. [`FaultPlane::schedule`]
//! returns the log in a canonical order, so "same seed ⇒ same fault
//! schedule" is a testable property (see `rust/tests/chaos_determinism.rs`).
//!
//! [`scenario`] builds named end-to-end chaos scenarios on top.

pub mod scenario;

pub use scenario::{
    cache_chaos, crash_recovery, data_crash, run_scenario, CacheChaosReport,
    CrashRecoveryReport, DataCrashReport, Scenario, ScenarioReport,
};

use std::sync::{Arc, Mutex};

use crate::net::tcp::Segment;
use crate::sim::Rng;

/// A hook point where faults can be injected.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum FaultSite {
    /// Shard `i`'s private SSD submission queue (offload-engine path).
    SsdQueue(usize),
    /// The file service's SSD queue (host slow path).
    HostSsdQueue,
    /// One direction of one client connection's wire:
    /// `to_server == true` is client→DPU.
    Wire { channel: usize, to_server: bool },
    /// Shard `i`'s colocated offload engine.
    Engine(usize),
    /// File-service poll group `i`.
    PollGroup(usize),
    /// The shared SSD's power rail: a deterministic power cut tears one
    /// device write after N bytes and kills the device until reboot
    /// ([`crate::ssd::Ssd::arm_power_cut`]).
    PowerCut,
}

impl FaultSite {
    /// Stable code used to derive the site's RNG stream from the seed.
    fn code(self) -> u64 {
        match self {
            FaultSite::SsdQueue(i) => 0x1_0000 + i as u64,
            FaultSite::HostSsdQueue => 0x2_0000,
            FaultSite::Wire { channel, to_server } => {
                0x3_0000 + channel as u64 * 2 + to_server as u64
            }
            FaultSite::Engine(i) => 0x4_0000 + i as u64,
            FaultSite::PollGroup(i) => 0x5_0000 + i as u64,
            FaultSite::PowerCut => 0x6_0000,
        }
    }
}

/// What was injected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// SSD op completes with `Err(SsdError::Injected)`.
    SsdFail,
    /// SSD op executes but its completion is lost.
    SsdDrop,
    /// SSD completion held back for N polls.
    SsdDelay(u32),
    /// Wire segment dropped.
    NetDrop,
    /// Wire segment duplicated.
    NetDup,
    /// Wire batch shuffled.
    NetReorder,
    /// Offload engine marked failed (requests reroute to the host).
    EngineFail,
    /// Offload engine restored.
    EngineRestore,
    /// Poll group stalled for N service iterations.
    GroupStall(u32),
    /// Power cut during device write `write` (0-based since arm),
    /// persisting only its first `cut` bytes.
    PowerCut { write: u64, cut: u32 },
}

/// One recorded injection: the `op`-th decision at `site` chose
/// `action`. `op` is a per-site sequence number, so sorting by
/// `(site, op)` yields a canonical schedule regardless of thread
/// interleaving between sites.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub site: FaultSite,
    pub op: u64,
    pub action: FaultAction,
}

/// Per-op SSD fault probabilities. Ranges are disjoint:
/// `[0, fail_p)` fail, `[fail_p, fail_p+drop_p)` drop,
/// `[fail_p+drop_p, fail_p+drop_p+delay_p)` delay.
#[derive(Debug, Clone, Copy)]
pub struct SsdFaultConfig {
    pub fail_p: f64,
    pub drop_p: f64,
    pub delay_p: f64,
    /// Polls a delayed completion is held back for.
    pub delay_polls: u32,
}

impl Default for SsdFaultConfig {
    fn default() -> Self {
        SsdFaultConfig { fail_p: 0.0, drop_p: 0.0, delay_p: 0.0, delay_polls: 4 }
    }
}

impl SsdFaultConfig {
    fn is_off(&self) -> bool {
        self.fail_p <= 0.0 && self.drop_p <= 0.0 && self.delay_p <= 0.0
    }
}

/// Per-segment wire fault probabilities.
#[derive(Debug, Clone, Copy, Default)]
pub struct WireFaultConfig {
    pub drop_p: f64,
    pub dup_p: f64,
    /// Probability that a multi-segment batch is shuffled.
    pub reorder_p: f64,
}

impl WireFaultConfig {
    fn is_off(&self) -> bool {
        self.drop_p <= 0.0 && self.dup_p <= 0.0 && self.reorder_p <= 0.0
    }
}

/// The whole plane's configuration: one seed, per-class probabilities.
/// Engine failures and group stalls are *scheduled* by the scenario
/// (deterministic by construction) rather than rolled per-op.
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultConfig {
    pub seed: u64,
    /// Shard engine SSD queues.
    pub ssd: SsdFaultConfig,
    /// The file service's SSD queue (host slow path).
    pub host_ssd: SsdFaultConfig,
    /// Client→server wire (drops recovered by client retransmission).
    pub wire_up: WireFaultConfig,
    /// Server→client wire. Keep `drop_p == 0` here: nothing in the
    /// model retransmits server→client on a silent loss, so dropped
    /// responses would be unrecoverable (dup/reorder are fine).
    pub wire_down: WireFaultConfig,
}

type Log = Arc<Mutex<Vec<FaultEvent>>>;

/// The seeded fault plane. Hand out per-site injectors with
/// [`Self::ssd_injector`] / [`Self::wire_chaos`]; read the canonical
/// injection log back with [`Self::schedule`].
pub struct FaultPlane {
    cfg: FaultConfig,
    log: Log,
    /// Every SSD injector handed out, so scenarios can arm them all
    /// after the (fault-free) setup/fill phase.
    ssd_injectors: Mutex<Vec<SsdFaultInjector>>,
}

/// Derive a per-site seed; splitmix-style so nearby site codes give
/// unrelated streams.
fn derive_seed(seed: u64, code: u64) -> u64 {
    let mut x = seed ^ code.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl FaultPlane {
    pub fn new(cfg: FaultConfig) -> Arc<Self> {
        Arc::new(FaultPlane {
            cfg,
            log: Arc::new(Mutex::new(Vec::new())),
            ssd_injectors: Mutex::new(Vec::new()),
        })
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// An SSD fault injector for `site` (must be [`FaultSite::SsdQueue`]
    /// or [`FaultSite::HostSsdQueue`]). Created **disarmed** so setup
    /// I/O (file creation, fills) runs fault-free; call
    /// [`Self::arm_ssd`] when the workload starts.
    pub fn ssd_injector(&self, site: FaultSite) -> SsdFaultInjector {
        let cfg = match site {
            FaultSite::SsdQueue(_) => self.cfg.ssd,
            FaultSite::HostSsdQueue => self.cfg.host_ssd,
            other => panic!("not an SSD site: {other:?}"),
        };
        let inj = SsdFaultInjector {
            inner: Arc::new(Mutex::new(SsdInjectorState {
                site,
                cfg,
                rng: Rng::new(derive_seed(self.cfg.seed, site.code())),
                op: 0,
                armed: false,
                log: self.log.clone(),
            })),
        };
        self.ssd_injectors.lock().unwrap().push(inj.clone());
        inj
    }

    /// Arm every SSD injector handed out so far (setup is done; start
    /// injecting).
    pub fn arm_ssd(&self) {
        for inj in self.ssd_injectors.lock().unwrap().iter() {
            inj.inner.lock().unwrap().armed = true;
        }
    }

    /// A wire chaos channel for one direction of client connection
    /// `channel`.
    pub fn wire_chaos(&self, channel: usize, to_server: bool) -> WireChaos {
        let site = FaultSite::Wire { channel, to_server };
        WireChaos {
            site,
            cfg: if to_server { self.cfg.wire_up } else { self.cfg.wire_down },
            rng: Rng::new(derive_seed(self.cfg.seed, site.code())),
            op: 0,
            log: self.log.clone(),
        }
    }

    /// A deterministic RNG stream for `site` — for scheduled injections
    /// whose *parameters* (not just occurrence) derive from the seed,
    /// e.g. the power-cut write index and byte offset in the
    /// crash-recovery scenario.
    pub fn site_rng(&self, site: FaultSite) -> Rng {
        Rng::new(derive_seed(self.cfg.seed, site.code()))
    }

    /// Record a scheduled (non-probabilistic) injection — engine
    /// failures, group stalls — so it appears in the schedule.
    pub fn record(&self, site: FaultSite, action: FaultAction) {
        let mut log = self.log.lock().unwrap();
        let op = log.iter().filter(|e| e.site == site).count() as u64;
        log.push(FaultEvent { site, op, action });
    }

    /// The injection log in canonical `(site, op)` order — identical
    /// across runs with the same seed and workload, regardless of how
    /// threads interleaved *between* sites.
    pub fn schedule(&self) -> Vec<FaultEvent> {
        let mut log = self.log.lock().unwrap().clone();
        log.sort_by_key(|e| (e.site, e.op));
        log
    }

    /// Total injections so far.
    pub fn injected(&self) -> usize {
        self.log.lock().unwrap().len()
    }
}

/// An SSD fault decided at submit time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SsdFault {
    /// Complete with `Err(SsdError::Injected)` without executing.
    Fail,
    /// Execute, but lose the completion.
    Drop,
    /// Execute, but hold the completion for N polls.
    Delay(u32),
}

struct SsdInjectorState {
    site: FaultSite,
    cfg: SsdFaultConfig,
    rng: Rng,
    op: u64,
    armed: bool,
    log: Log,
}

/// Shared handle consumed by [`crate::ssd::AsyncSsd`] at submit time.
/// One RNG draw per submitted op (in submit order), so a single-driver
/// queue gets a fully deterministic decision stream.
#[derive(Clone)]
pub struct SsdFaultInjector {
    inner: Arc<Mutex<SsdInjectorState>>,
}

impl SsdFaultInjector {
    /// Decide the fate of the next submitted op. Disarmed injectors
    /// return `None` without consuming randomness, so the armed stream
    /// is independent of how much setup I/O preceded it.
    pub fn decide(&self) -> Option<SsdFault> {
        let mut st = self.inner.lock().unwrap();
        if !st.armed || st.cfg.is_off() {
            return None;
        }
        let op = st.op;
        st.op += 1;
        let roll = st.rng.next_f64();
        let (action, fault) = if roll < st.cfg.fail_p {
            (FaultAction::SsdFail, SsdFault::Fail)
        } else if roll < st.cfg.fail_p + st.cfg.drop_p {
            (FaultAction::SsdDrop, SsdFault::Drop)
        } else if roll < st.cfg.fail_p + st.cfg.drop_p + st.cfg.delay_p {
            let polls = st.cfg.delay_polls.max(1);
            (FaultAction::SsdDelay(polls), SsdFault::Delay(polls))
        } else {
            return None;
        };
        let site = st.site;
        st.log.lock().unwrap().push(FaultEvent { site, op, action });
        Some(fault)
    }

    /// Arm/disarm this injector only.
    pub fn set_armed(&self, armed: bool) {
        self.inner.lock().unwrap().armed = armed;
    }
}

/// Seeded wire chaos for one direction of one connection: applies
/// drop/duplicate decisions per segment and an occasional deterministic
/// shuffle per batch, logging every injection.
pub struct WireChaos {
    site: FaultSite,
    cfg: WireFaultConfig,
    rng: Rng,
    op: u64,
    log: Log,
}

impl WireChaos {
    /// Run a batch of segments through the chaos channel. The decision
    /// stream is deterministic in the *sequence of segments offered*.
    pub fn apply(&mut self, segs: Vec<Segment>) -> Vec<Segment> {
        if self.cfg.is_off() || segs.is_empty() {
            return segs;
        }
        let mut out = Vec::with_capacity(segs.len());
        for seg in segs {
            let op = self.op;
            self.op += 1;
            if self.rng.next_f64() < self.cfg.drop_p {
                self.note(op, FaultAction::NetDrop);
                continue;
            }
            if self.rng.next_f64() < self.cfg.dup_p {
                self.note(op, FaultAction::NetDup);
                out.push(seg.clone());
            }
            out.push(seg);
        }
        if out.len() > 1 && self.rng.next_f64() < self.cfg.reorder_p {
            let op = self.op;
            self.op += 1;
            self.note(op, FaultAction::NetReorder);
            // Deterministic Fisher-Yates.
            for i in (1..out.len()).rev() {
                let j = self.rng.next_range(i as u64 + 1) as usize;
                out.swap(i, j);
            }
        }
        out
    }

    fn note(&self, op: u64, action: FaultAction) {
        self.log.lock().unwrap().push(FaultEvent { site: self.site, op, action });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chaotic_cfg(seed: u64) -> FaultConfig {
        FaultConfig {
            seed,
            ssd: SsdFaultConfig { fail_p: 0.2, drop_p: 0.2, delay_p: 0.2, delay_polls: 3 },
            wire_up: WireFaultConfig { drop_p: 0.2, dup_p: 0.2, reorder_p: 0.5 },
            ..Default::default()
        }
    }

    #[test]
    fn ssd_decisions_replay_with_same_seed() {
        let runs: Vec<Vec<Option<SsdFault>>> = (0..2)
            .map(|_| {
                let plane = FaultPlane::new(chaotic_cfg(42));
                let inj = plane.ssd_injector(FaultSite::SsdQueue(0));
                plane.arm_ssd();
                (0..500).map(|_| inj.decide()).collect()
            })
            .collect();
        assert_eq!(runs[0], runs[1]);
        assert!(runs[0].iter().any(|d| d.is_some()), "probabilities must fire");
        assert!(runs[0].iter().any(|d| d.is_none()), "not every op faulted");
    }

    #[test]
    fn schedules_identical_across_runs_and_sites_independent() {
        let mk = || {
            let plane = FaultPlane::new(chaotic_cfg(7));
            let a = plane.ssd_injector(FaultSite::SsdQueue(0));
            let b = plane.ssd_injector(FaultSite::SsdQueue(1));
            plane.arm_ssd();
            for _ in 0..200 {
                a.decide();
                b.decide();
            }
            plane.schedule()
        };
        let (s1, s2) = (mk(), mk());
        assert_eq!(s1, s2);
        // Streams differ between sites (derived seeds are unrelated).
        let on_a: Vec<_> = s1.iter().filter(|e| e.site == FaultSite::SsdQueue(0)).collect();
        let on_b: Vec<_> = s1.iter().filter(|e| e.site == FaultSite::SsdQueue(1)).collect();
        assert!(!on_a.is_empty() && !on_b.is_empty());
        assert_ne!(
            on_a.iter().map(|e| e.op).collect::<Vec<_>>(),
            on_b.iter().map(|e| e.op).collect::<Vec<_>>(),
            "site streams should not be op-for-op identical"
        );
    }

    #[test]
    fn disarmed_injector_is_transparent_and_preserves_stream() {
        let plane = FaultPlane::new(chaotic_cfg(9));
        let inj = plane.ssd_injector(FaultSite::HostSsdQueue);
        // Setup phase: decisions are None and consume no randomness.
        for _ in 0..1000 {
            assert_eq!(inj.decide(), None);
        }
        plane.arm_ssd();
        let armed: Vec<_> = (0..100).map(|_| inj.decide()).collect();
        // A fresh plane armed immediately produces the same stream.
        let plane2 = FaultPlane::new(chaotic_cfg(9));
        let inj2 = plane2.ssd_injector(FaultSite::HostSsdQueue);
        plane2.arm_ssd();
        let immediate: Vec<_> = (0..100).map(|_| inj2.decide()).collect();
        assert_eq!(armed, immediate);
    }

    #[test]
    fn wire_chaos_deterministic_and_lossless_when_off() {
        let seg = |seq: u64| Segment { seq, payload: vec![seq as u8; 8].into(), ack: 0 };
        let run = || {
            let plane = FaultPlane::new(chaotic_cfg(21));
            let mut chaos = plane.wire_chaos(0, true);
            let mut all = Vec::new();
            for batch in 0..20u64 {
                let segs: Vec<Segment> = (0..5).map(|i| seg(batch * 5 + i)).collect();
                all.push(chaos.apply(segs));
            }
            (all, plane.schedule())
        };
        let (a, sa) = run();
        let (b, sb) = run();
        assert_eq!(a, b);
        assert_eq!(sa, sb);
        assert!(!sa.is_empty());
        // wire_down defaults to off: apply is the identity.
        let plane = FaultPlane::new(chaotic_cfg(21));
        let mut down = plane.wire_chaos(0, false);
        let segs: Vec<Segment> = (0..5).map(seg).collect();
        assert_eq!(down.apply(segs.clone()), segs);
    }

    #[test]
    fn recorded_events_take_per_site_sequence_numbers() {
        let plane = FaultPlane::new(FaultConfig { seed: 1, ..Default::default() });
        plane.record(FaultSite::Engine(0), FaultAction::EngineFail);
        plane.record(FaultSite::Engine(0), FaultAction::EngineRestore);
        plane.record(FaultSite::PollGroup(1), FaultAction::GroupStall(8));
        let s = plane.schedule();
        assert_eq!(s.len(), 3);
        assert_eq!(
            s[0],
            FaultEvent { site: FaultSite::Engine(0), op: 0, action: FaultAction::EngineFail }
        );
        assert_eq!(s[1].op, 1);
        assert_eq!(
            s[2],
            FaultEvent {
                site: FaultSite::PollGroup(1),
                op: 0,
                action: FaultAction::GroupStall(8)
            }
        );
    }
}
