//! Named, seeded end-to-end chaos scenarios against [`ShardedServer`].
//!
//! A [`Scenario`] describes a workload (N shards, one connection per
//! shard, `rounds` batches of reads per connection) plus a fault
//! recipe: probabilistic SSD/wire faults from a [`FaultConfig`] seed
//! and *scheduled* engine failures / poll-group stalls pinned to
//! rounds. [`run_scenario`] builds the whole functional plane, drives
//! every message to completion, and enforces the two invariants the
//! fault plane promises:
//!
//! * **Byte-exactness** — an OK response carries exactly the bytes the
//!   fill pattern predicts, on the issuing connection; an ERR response
//!   carries no payload. Wrong bytes abort the scenario.
//! * **Bounded completion** — every request resolves (OK or ERR)
//!   within the scenario timeout; lost completions surface through the
//!   engine/service pending timeouts, lost segments through dup-ACK
//!   fast retransmit and the client's `retransmit_all` timeout path.
//!
//! The returned [`ScenarioReport`] carries the canonical fault
//! schedule and the per-request outcome trace, which is what the
//! determinism suite replays (`rust/tests/chaos_determinism.rs`).
//!
//! [`crash_recovery`] is a separate scenario shape: instead of a
//! request workload it drives a seeded metadata op sequence, cuts
//! device power mid-write at a seed-chosen `(write, byte)` point,
//! and asserts the durability plane's contract — post-cut ops surface
//! as clean bounded errors, and a remount recovers exactly the state
//! committed by the metadata journal.

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::{FaultAction, FaultConfig, FaultEvent, FaultPlane, FaultSite, SsdFaultConfig,
            WireChaos, WireFaultConfig};
use crate::apps::RawFileApp;
use crate::cache::TierStats;
use crate::coordinator::{
    tuple_for_shard, ClientConn, ShardedServer, ShardedServerConfig, StorageServer,
    StorageServerConfig,
};
use crate::director::{AppSignature, DirectorShardStats, TenantPlaneConfig};
use crate::dpufs::RecoveryReport;
use crate::filelib::{DdsClient, DdsFile, PollGroup};
use crate::fileservice::{FileServiceConfig, GroupCounters};
use crate::idle::IdlePolicy;
use crate::metrics::CpuStats;
use crate::net::FiveTuple;
use crate::offload::{OffloadEngineConfig, RawFileOffload};
use crate::proto::{AppRequest, NetMsg, NetResp};
use crate::sim::Rng;
use crate::workload::RandomIoGen;

const SERVER_PORT: u16 = 5000;

/// A named, fully-seeded chaos scenario.
#[derive(Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub seed: u64,
    pub shards: usize,
    /// Connections per shard (the fanout multiplier; default 1 keeps
    /// the classic one-connection-per-shard shape).
    pub conns_per_shard: usize,
    /// Client IPs per connection (indexed by connection number; empty
    /// → every connection uses the default IP). The tenant plane keys
    /// on client IP, so a skewed IP list is how a scenario expresses a
    /// skewed tenant mix.
    pub client_ips: Vec<u32>,
    /// Per-tenant QoS configuration installed on every shard (default:
    /// one tenant, no limits).
    pub tenants: TenantPlaneConfig,
    /// Request batches per connection (one connection per shard).
    pub rounds: usize,
    /// Read requests per batch.
    pub batch: usize,
    pub read_size: u32,
    pub file_bytes: u64,
    /// Probabilistic faults (seeded).
    pub faults: FaultConfig,
    /// `(round, shard)`: mark that shard's engine failed before the
    /// round's batches are sent.
    pub fail_engines: Vec<(usize, usize)>,
    /// `(round, shard)`: restore that shard's engine.
    pub restore_engines: Vec<(usize, usize)>,
    /// `(round, iterations)`: stall every shard poll group before the
    /// round.
    pub stall_groups: Option<(usize, u32)>,
    /// Read-cache tier byte budget for the storage path (0 = no tier).
    /// Scenarios whose fault recipe draws from per-SSD-queue decision
    /// streams must run WITHOUT the tier: a cache hit skips an SSD op,
    /// and whether a cross-shard probe hits depends on fill timing, so
    /// the per-queue fault draws would shift run to run and break the
    /// same-seed outcome-trace replay (`chaos_determinism`). Cache ×
    /// SSD-fault coherence is covered by [`cache_chaos`] instead,
    /// which asserts byte-exactness, not trace equality.
    pub cache_bytes: u64,
    /// Wall-clock bound for one round of batches to fully resolve.
    pub round_timeout: Duration,
    /// Engine-context and service-staging pending timeout (how fast a
    /// lost completion surfaces as ERR).
    pub pending_timeout: Duration,
    /// Idle discipline of every pump (file service + shard loops).
    pub idle: IdlePolicy,
    /// When true (the `idle_wake` scenario), the harness additionally
    /// asserts that after the workload quiesces every pump settles
    /// into its park rung — parks keep advancing while productive
    /// iterations stop — per the CpuLedger.
    pub assert_parked: bool,
}

impl Scenario {
    /// Common shape shared by the named scenarios.
    fn base(name: &'static str, seed: u64) -> Scenario {
        Scenario {
            name,
            seed,
            shards: 2,
            conns_per_shard: 1,
            client_ips: Vec::new(),
            tenants: TenantPlaneConfig::default(),
            rounds: 5,
            batch: 4,
            read_size: 512,
            file_bytes: 1 << 20,
            faults: FaultConfig { seed, ..Default::default() },
            fail_engines: Vec::new(),
            restore_engines: Vec::new(),
            stall_groups: None,
            cache_bytes: 2 << 20,
            round_timeout: Duration::from_secs(30),
            // Lost-completion recovery latency. Deliberately ~1000x the
            // shard poll cadence (~1ms): a completion merely *delayed*
            // by the fault plane (or by a descheduled CI thread) must
            // never be misclassified as lost, or the outcome trace
            // would depend on wall-clock timing and break the
            // same-seed determinism contract.
            pending_timeout: Duration::from_secs(2),
            idle: IdlePolicy::default(),
            assert_parked: false,
        }
    }

    /// No faults at all — the harness itself must pass clean.
    pub fn nominal(seed: u64) -> Scenario {
        Scenario::base("nominal", seed)
    }

    /// One shard's engine dies after the first round; its traffic must
    /// fall back to the host slow path with byte-exact responses (the
    /// paper's fallback story).
    pub fn engine_failover(seed: u64) -> Scenario {
        Scenario { fail_engines: vec![(1, 0)], ..Scenario::base("engine_failover", seed) }
    }

    /// Engine dies, then comes back: offloading must resume.
    pub fn engine_restart(seed: u64) -> Scenario {
        Scenario {
            rounds: 6,
            fail_engines: vec![(1, 0)],
            restore_engines: vec![(4, 0)],
            ..Scenario::base("engine_restart", seed)
        }
    }

    /// Probabilistic failures, losses and delays on every shard's SSD
    /// queue: failed ops and lost completions must surface as ERR in
    /// bounded time, never as hangs or wrong bytes.
    pub fn ssd_chaos(seed: u64) -> Scenario {
        Scenario {
            rounds: 6,
            faults: FaultConfig {
                seed,
                ssd: SsdFaultConfig {
                    fail_p: 0.08,
                    drop_p: 0.08,
                    delay_p: 0.25,
                    delay_polls: 3,
                },
                ..Default::default()
            },
            cache_bytes: 0, // SSD fault streams: see `Scenario::cache_bytes`
            ..Scenario::base("ssd_chaos", seed)
        }
    }

    /// Segment drop/duplication/reordering on the client→server wire
    /// and duplication/reordering on the way back: TCP recovery
    /// (dup-ACK fast retransmit + `retransmit_all`) must make every
    /// response byte-exact with zero errors.
    pub fn wire_chaos(seed: u64) -> Scenario {
        Scenario {
            faults: FaultConfig {
                seed,
                wire_up: WireFaultConfig { drop_p: 0.15, dup_p: 0.15, reorder_p: 0.4 },
                // No server→client drops: nothing in the model
                // retransmits on a silent response loss.
                wire_down: WireFaultConfig { drop_p: 0.0, dup_p: 0.15, reorder_p: 0.4 },
                ..Default::default()
            },
            ..Scenario::base("wire_chaos", seed)
        }
    }

    /// Every engine failed (all traffic on the host slow path), then
    /// every poll group stalled mid-run: the file service must absorb
    /// the stall and drain the backlog with zero errors.
    pub fn group_stall(seed: u64) -> Scenario {
        let base = Scenario::base("group_stall", seed);
        Scenario {
            fail_engines: (0..base.shards).map(|s| (0, s)).collect(),
            stall_groups: Some((1, 3000)),
            ..base
        }
    }

    /// The CPU-plane scenario: adaptive spin→park pumps (tight spin
    /// budget, so parks actually happen between batches) under SSD
    /// chaos on both planes, one engine failure, and a poll-group
    /// stall — byte-exactness and bounded completion must survive
    /// every park point, and after quiesce every pump must actually be
    /// parked (asserted against the CpuLedger).
    pub fn idle_wake(seed: u64) -> Scenario {
        let base = Scenario::base("idle_wake", seed);
        Scenario {
            rounds: 6,
            idle: IdlePolicy::Adaptive {
                spin_iters: 16,
                park_timeout: Duration::from_millis(2),
            },
            assert_parked: true,
            faults: FaultConfig {
                seed,
                ssd: SsdFaultConfig { fail_p: 0.05, drop_p: 0.05, delay_p: 0.2, delay_polls: 3 },
                host_ssd: SsdFaultConfig {
                    fail_p: 0.05,
                    drop_p: 0.05,
                    delay_p: 0.2,
                    delay_polls: 3,
                },
                ..Default::default()
            },
            fail_engines: vec![(1, 0)],
            stall_groups: Some((3, 400)),
            cache_bytes: 0, // SSD fault streams: see `Scenario::cache_bytes`
            ..base
        }
    }

    /// Everything at once.
    pub fn everything(seed: u64) -> Scenario {
        let base = Scenario::base("everything", seed);
        Scenario {
            rounds: 6,
            faults: FaultConfig {
                seed,
                ssd: SsdFaultConfig {
                    fail_p: 0.05,
                    drop_p: 0.05,
                    delay_p: 0.2,
                    delay_polls: 3,
                },
                host_ssd: SsdFaultConfig {
                    fail_p: 0.05,
                    drop_p: 0.05,
                    delay_p: 0.2,
                    delay_polls: 3,
                },
                wire_up: WireFaultConfig { drop_p: 0.1, dup_p: 0.1, reorder_p: 0.3 },
                wire_down: WireFaultConfig { drop_p: 0.0, dup_p: 0.1, reorder_p: 0.3 },
            },
            fail_engines: vec![(2, 1)],
            stall_groups: Some((3, 1500)),
            cache_bytes: 0, // SSD fault streams: see `Scenario::cache_bytes`
            ..base
        }
    }

    /// The whole named suite for one seed.
    pub fn all(seed: u64) -> Vec<Scenario> {
        vec![
            Scenario::nominal(seed),
            Scenario::engine_failover(seed),
            Scenario::engine_restart(seed),
            Scenario::ssd_chaos(seed),
            Scenario::wire_chaos(seed),
            Scenario::group_stall(seed),
            Scenario::idle_wake(seed),
            Scenario::everything(seed),
        ]
    }

    /// Total requests the scenario issues.
    pub fn total_requests(&self) -> u64 {
        (self.rounds * self.shards * self.conns_per_shard * self.batch) as u64
    }
}

/// What a scenario run observed.
pub struct ScenarioReport {
    pub name: &'static str,
    pub seed: u64,
    /// OK responses (every one verified byte-exact).
    pub ok: u64,
    /// ERR responses (every one verified payload-free).
    pub err: u64,
    /// `(msg_id, idx, status)` per request, sorted — the deterministic
    /// outcome trace.
    pub outcomes: Vec<(u64, u16, u8)>,
    /// Canonical fault schedule ([`FaultPlane::schedule`]).
    pub schedule: Vec<FaultEvent>,
    pub stats: DirectorShardStats,
    pub per_shard: Vec<DirectorShardStats>,
    /// Per-tenant QoS counters merged across shards at scenario end.
    pub tenants: Vec<crate::metrics::TenantCounters>,
    pub group_stats: Vec<GroupCounters>,
    /// Pump CPU snapshots at scenario end: index 0 is the file
    /// service, then one per shard. (Timing-dependent — never part of
    /// the deterministic outcome trace.)
    pub cpu: Vec<CpuStats>,
    pub elapsed: Duration,
}

impl ScenarioReport {
    /// Injected SSD failures + drops in the schedule (the ones that
    /// must surface as ERR responses).
    pub fn ssd_fail_or_drop_events(&self) -> usize {
        self.schedule
            .iter()
            .filter(|e| matches!(e.action, FaultAction::SsdFail | FaultAction::SsdDrop))
            .count()
    }
}

/// One connection's client-side state, wrapped in wire chaos.
struct ChaosConn {
    shard: usize,
    tuple: FiveTuple,
    client: ClientConn,
    up: WireChaos,
    down: WireChaos,
    pending: Option<Pending>,
    last_rx: Instant,
}

struct Pending {
    msg_id: u64,
    expect: usize,
    seen: Vec<bool>,
    got: usize,
    expected: Vec<Vec<u8>>,
}

struct Acc {
    ok: u64,
    err: u64,
    outcomes: Vec<(u64, u16, u8)>,
}

/// Build the full plane and run one scenario to completion.
pub fn run_scenario(sc: &Scenario) -> anyhow::Result<ScenarioReport> {
    anyhow::ensure!(
        sc.faults.wire_down.drop_p == 0.0,
        "scenario '{}': server->client drops are unrecoverable in this model",
        sc.name
    );
    let started = Instant::now();
    let plane = FaultPlane::new(sc.faults);
    let logic = Arc::new(RawFileOffload);

    let mut service = FileServiceConfig {
        pending_timeout: sc.pending_timeout,
        idle: sc.idle,
        ..Default::default()
    };
    if !sc.faults.host_ssd.is_off() {
        service.ssd_faults = Some(plane.ssd_injector(FaultSite::HostSsdQueue));
    }
    let storage_cfg = StorageServerConfig {
        ssd_bytes: 32 << 20,
        cache_bytes: sc.cache_bytes,
        service,
        ..Default::default()
    };
    let storage = StorageServer::build(storage_cfg, Some(logic.clone()))?;
    let file = storage.create_filled_file("chaos", "data", sc.file_bytes)?;
    let fid = file.id.0;

    let cfg = ShardedServerConfig {
        shards: sc.shards,
        engine_total: OffloadEngineConfig {
            pending_timeout: sc.pending_timeout,
            ..Default::default()
        },
        faults: Some(plane.clone()),
        idle: sc.idle,
        tenants: sc.tenants.clone(),
        ..Default::default()
    };
    let server = ShardedServer::over(
        storage,
        cfg,
        logic,
        AppSignature::server_port(SERVER_PORT),
        |_shard, st| RawFileApp::over(st, &file),
    )?;
    // Setup/fill is done — start injecting.
    plane.arm_ssd();

    // Connection build-out: `conns_per_shard` connections per shard,
    // each with a unique tuple (port hints can collide at high fanout,
    // so tuples are deduped explicitly) and a client IP drawn from the
    // scenario's IP list (the tenant key).
    let cps = sc.conns_per_shard.max(1);
    let mut used = std::collections::HashSet::new();
    let mut conns: Vec<ChaosConn> = (0..sc.shards * cps)
        .map(|ci| {
            let s = ci / cps;
            let ip = sc.client_ips.get(ci).copied().unwrap_or(0x0a00_0001);
            let mut hint = 40_000u16.wrapping_add((ci as u16).wrapping_mul(101));
            let tuple = loop {
                let t = tuple_for_shard(s, sc.shards, ip, hint, 0x0a00_00ff, SERVER_PORT);
                if used.insert(t) {
                    break t;
                }
                hint = hint.wrapping_add(1);
            };
            ChaosConn {
                shard: s,
                tuple,
                client: ClientConn::new(tuple),
                up: plane.wire_chaos(ci, true),
                down: plane.wire_chaos(ci, false),
                pending: None,
                last_rx: Instant::now(),
            }
        })
        .collect();
    // Tuple → connection routing for pump_shard (at fanout a linear
    // scan per received batch would be quadratic).
    let index: std::collections::HashMap<FiveTuple, usize> =
        conns.iter().enumerate().map(|(i, c)| (c.tuple, i)).collect();

    let mut acc = Acc { ok: 0, err: 0, outcomes: Vec::new() };
    for round in 0..sc.rounds {
        // Scheduled injections pinned to this round.
        for &(r, shard) in &sc.fail_engines {
            if r == round {
                anyhow::ensure!(server.set_engine_failed(shard, true), "bad shard {shard}");
                plane.record(FaultSite::Engine(shard), FaultAction::EngineFail);
            }
        }
        for &(r, shard) in &sc.restore_engines {
            if r == round {
                anyhow::ensure!(server.set_engine_failed(shard, false), "bad shard {shard}");
                plane.record(FaultSite::Engine(shard), FaultAction::EngineRestore);
            }
        }
        if let Some((r, iterations)) = sc.stall_groups {
            if r == round {
                let fe = server.storage.front_end();
                let groups = fe.group_stats().map_err(|e| anyhow::anyhow!("{e}"))?.len();
                // Group 0 is the fill group; 1..=shards are the shard
                // host apps.
                for g in 1..groups {
                    fe.inject_group_stall(g, iterations)
                        .map_err(|e| anyhow::anyhow!("{e}"))?;
                    plane.record(FaultSite::PollGroup(g), FaultAction::GroupStall(iterations));
                }
            }
        }

        // Send one batch per connection (msg ids and offsets derive
        // from (seed, msg_id) alone, so the workload is identical run
        // to run regardless of timing).
        let n_conns = conns.len();
        for (ci, conn) in conns.iter_mut().enumerate() {
            let msg_id = (round * n_conns + ci) as u64 + 1;
            let mut mrng = Rng::new(sc.seed ^ msg_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let mut requests = Vec::with_capacity(sc.batch);
            let mut expected = Vec::with_capacity(sc.batch);
            for _ in 0..sc.batch {
                let offset = mrng.next_range(sc.file_bytes - sc.read_size as u64);
                requests.push(AppRequest::Read { file_id: fid, offset, size: sc.read_size });
                expected.push(RandomIoGen::expected_fill(offset, sc.read_size as usize));
            }
            let msg = NetMsg { msg_id, requests };
            let segs = conn.up.apply(conn.client.send_msg(&msg));
            if !segs.is_empty() {
                server.send(&conn.tuple, segs)?;
            }
            conn.pending = Some(Pending {
                msg_id,
                expect: sc.batch,
                seen: vec![false; sc.batch],
                got: 0,
                expected,
            });
            conn.last_rx = Instant::now();
        }

        // Drive every connection's batch to full resolution. Receives
        // are per shard and routed to the owning connection by tuple
        // (at fanout a shard interleaves many connections' segments on
        // one channel); per-shard unresolved-batch counters keep the
        // loop's bookkeeping O(1) per event.
        let mut unresolved: Vec<usize> = vec![cps; sc.shards];
        let deadline = Instant::now() + sc.round_timeout;
        loop {
            let mut all_done = true;
            for shard in 0..sc.shards {
                if unresolved[shard] > 0 {
                    all_done = false;
                    pump_shard(sc, &server, shard, &mut conns, &index, &mut unresolved, &mut acc)?;
                }
            }
            if all_done {
                break;
            }
            anyhow::ensure!(
                Instant::now() < deadline,
                "scenario '{}' (seed {}): round {round} did not complete in {:?}",
                sc.name,
                sc.seed,
                sc.round_timeout
            );
        }
    }

    let total = sc.total_requests();
    anyhow::ensure!(
        acc.ok + acc.err == total,
        "scenario '{}': {} + {} responses != {} requests",
        sc.name,
        acc.ok,
        acc.err,
        total
    );

    // CPU-plane quiesce check (idle_wake): once the workload is done,
    // every pump must settle into its park rung — parks keep advancing
    // while productive iterations stop. A pump still finding "work"
    // here means a wake edge is stuck open; a pump whose parks stopped
    // advancing is spinning (a busy-loop regression). Two windows so
    // the verdict is a delta, not an absolute count.
    if sc.assert_parked {
        anyhow::ensure!(
            matches!(sc.idle, IdlePolicy::Adaptive { .. }),
            "scenario '{}': assert_parked needs an Adaptive policy",
            sc.name
        );
        let settle = (sc.idle.park_bound() * 8).max(Duration::from_millis(50));
        std::thread::sleep(settle);
        let before = server.all_cpu_stats();
        std::thread::sleep(settle);
        let after = server.all_cpu_stats();
        for (i, (b, a)) in before.iter().zip(&after).enumerate() {
            let d = a.since(b);
            anyhow::ensure!(
                d.parks > 0,
                "scenario '{}' (seed {}): pump {i} is not parking after quiesce ({d:?})",
                sc.name,
                sc.seed
            );
            anyhow::ensure!(
                d.productive <= 4,
                "scenario '{}' (seed {}): pump {i} still productive after quiesce ({d:?})",
                sc.name,
                sc.seed
            );
        }
    }

    acc.outcomes.sort_unstable();
    let report = ScenarioReport {
        name: sc.name,
        seed: sc.seed,
        ok: acc.ok,
        err: acc.err,
        outcomes: acc.outcomes,
        schedule: plane.schedule(),
        stats: server.stats(),
        per_shard: server.shard_stats(),
        tenants: server.tenant_stats(),
        group_stats: server
            .storage
            .front_end()
            .group_stats()
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        cpu: server.all_cpu_stats(),
        elapsed: started.elapsed(),
    };
    // Buffer-plane leak check: whatever the fault schedule did — lost
    // completions, failed engines, stalled groups, duplicated segments —
    // every pooled buffer must come home once the plane quiesces. The
    // pool handles outlive the server; dropping it joins shard threads
    // and the file service, releasing every in-flight view.
    let engine_pools = server.engine_pools().to_vec();
    let service_pools =
        [server.storage.buf_pool.clone(), server.storage.read_buf_pool.clone()];
    drop(conns);
    drop(server);
    for (shard, pool) in engine_pools.iter().enumerate() {
        anyhow::ensure!(
            pool.in_use() == 0,
            "scenario '{}' (seed {}): shard {shard} engine pool leaked {} buffers",
            sc.name,
            sc.seed,
            pool.in_use()
        );
    }
    for pool in &service_pools {
        anyhow::ensure!(
            pool.in_use() == 0,
            "scenario '{}' (seed {}): file-service pool leaked {} buffers",
            sc.name,
            sc.seed,
            pool.in_use()
        );
    }
    Ok(report)
}

/// One pump step for one shard: absorb a server batch (through
/// downstream chaos), route it by tuple to the owning connection,
/// verify and account its responses, send ACKs back (through upstream
/// chaos); when the shard goes quiet, fire the timeout retransmission
/// of every stalled connection it owns.
fn pump_shard(
    sc: &Scenario,
    server: &ShardedServer,
    shard: usize,
    conns: &mut [ChaosConn],
    index: &std::collections::HashMap<FiveTuple, usize>,
    unresolved: &mut [usize],
    acc: &mut Acc,
) -> anyhow::Result<()> {
    match server.recv_timeout(shard, Duration::from_millis(5)) {
        Some((tuple, segs)) => {
            let ci = *index.get(&tuple).ok_or_else(|| {
                anyhow::anyhow!("shard {shard} emitted segments for an unknown connection")
            })?;
            let conn = &mut conns[ci];
            anyhow::ensure!(
                conn.shard == shard,
                "shard {shard} emitted segments for a connection it does not own"
            );
            conn.last_rx = Instant::now();
            let segs = conn.down.apply(segs);
            let mut acks = Vec::new();
            let resps = conn.client.on_segments(&segs, &mut acks);
            let acks = conn.up.apply(acks);
            if !acks.is_empty() {
                server.send(&conn.tuple, acks)?;
            }
            let Some(p) = conn.pending.as_mut() else { return Ok(()) };
            for r in resps {
                if r.msg_id != p.msg_id {
                    continue; // late response from an earlier round
                }
                let idx = r.idx as usize;
                if idx >= p.expect || p.seen[idx] {
                    continue; // duplicate (TCP retransmit)
                }
                p.seen[idx] = true;
                p.got += 1;
                if p.got == p.expect {
                    unresolved[shard] -= 1;
                }
                if r.status == NetResp::OK {
                    anyhow::ensure!(
                        r.payload == p.expected[idx],
                        "scenario '{}' (seed {}): OK response with WRONG BYTES \
                         (msg {} idx {idx})",
                        sc.name,
                        sc.seed,
                        r.msg_id
                    );
                    acc.ok += 1;
                } else {
                    anyhow::ensure!(
                        r.payload.is_empty(),
                        "scenario '{}': ERR response carried payload",
                        sc.name
                    );
                    acc.err += 1;
                }
                acc.outcomes.push((r.msg_id, r.idx, r.status));
            }
        }
        None => {
            // Nothing from the shard: any connection stalled past the
            // bound walks the timeout path — retransmit everything
            // outstanding on connection 1 (recovers upstream drops).
            for conn in conns.iter_mut().filter(|c| {
                c.shard == shard && c.pending.as_ref().is_some_and(|p| p.got < p.expect)
            }) {
                if conn.last_rx.elapsed() >= Duration::from_millis(50) {
                    let re = conn.up.apply(conn.client.ep.retransmit_all());
                    if !re.is_empty() {
                        server.send(&conn.tuple, re)?;
                    }
                    conn.last_rx = Instant::now();
                }
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// crash_recovery: seeded power-cut + remount scenario
// ---------------------------------------------------------------------

/// Segment size of the crash-recovery scenario's device (small, so the
/// metadata images and the journal stay byte-cheap).
const CRASH_SEG: u64 = 1 << 17;
const CRASH_SSD_BYTES: u64 = 8 << 20;
/// Metadata/data ops the scenario drives before the cut window closes.
const CRASH_OPS: usize = 20;

/// What the crash-recovery scenario observed.
#[derive(Debug)]
pub struct CrashRecoveryReport {
    pub seed: u64,
    /// The cut point: the op run's `cut_write`-th device write (0-based
    /// from arming) persisted only its first `cut_bytes` bytes.
    pub cut_write: u64,
    pub cut_bytes: usize,
    /// Control-plane metadata ops acknowledged (durably synced) before
    /// the cut.
    pub ops_acked: u64,
    /// Ops that surfaced the dead device as a clean error — ERR
    /// completion or control-call error, never a hang or panic.
    pub ops_failed: u64,
    /// What mount-time recovery found and repaired.
    pub recovery: RecoveryReport,
    /// Files visible after recovery.
    pub recovered_files: usize,
    /// Canonical fault schedule (the power-cut injection).
    pub schedule: Vec<FaultEvent>,
    pub elapsed: Duration,
}

/// In-memory model of the committed metadata state (what a sync at
/// that moment would persist). Shared with the crash-point enumeration
/// harness (`rust/tests/crash_recovery.rs`) so both check recovery
/// against one verifier ([`verify_recovered_fs`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetaModel {
    /// Directory names in creation order (mount lists by id, which is
    /// creation order).
    pub dirs: Vec<String>,
    /// `(dir, name, size)` per live file.
    pub files: Vec<(String, String, u64)>,
}

/// The deterministic op driver shared by the scout and chaos passes.
struct CrashOps {
    rng: Rng,
    fe: DdsClient,
    group: Arc<PollGroup>,
    /// Live files: handle + model coordinates.
    files: Vec<(DdsFile, String, String, u64)>,
    model: MetaModel,
    /// `(seq, model)` snapshots: seq 1 is the formatted-empty state,
    /// then one per *attempted* control-plane op (each control op
    /// attempts sequence `acked_seq + 1`).
    snapshots: Vec<(u64, MetaModel)>,
    acked: u64,
    acked_seq: u64,
    failed: u64,
    /// First device error seen: the device is dead, nothing later can
    /// reach the medium — freeze the model.
    dead: bool,
}

impl CrashOps {
    fn new(seed: u64, storage: &StorageServer) -> anyhow::Result<Self> {
        let fe = storage.front_end();
        let group = fe.create_poll().map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(CrashOps {
            rng: Rng::new(seed ^ 0xC4A5_4001),
            fe,
            group,
            files: Vec::new(),
            model: MetaModel::default(),
            snapshots: vec![(1, MetaModel::default())],
            acked: 0,
            acked_seq: 1,
            failed: 0,
            dead: false,
        })
    }

    /// Book-keep one control-plane attempt: snapshot the state the op's
    /// sync would commit, then fold in the outcome.
    fn control<T>(&mut self, with_op: MetaModel, r: Result<T, crate::filelib::LibError>) -> Option<T> {
        if !self.dead {
            self.snapshots.push((self.acked_seq + 1, with_op.clone()));
        }
        match r {
            Ok(v) => {
                self.model = with_op;
                self.acked += 1;
                self.acked_seq += 1;
                Some(v)
            }
            Err(_) => {
                self.dead = true;
                self.failed += 1;
                None
            }
        }
    }

    /// Drive the seeded op mix: create/remove directories, create/
    /// delete files (control plane, each durably synced), appends and
    /// explicit grows (data plane / `EnsureSize`).
    ///
    /// (This intentionally parallels `apply_ops` in
    /// `rust/tests/crash_recovery.rs`: same model bookkeeping, but this
    /// driver exercises the *service* layer — DdsClient control calls +
    /// poll-group data plane — while the test drives `DpuFs` directly
    /// to make byte-exhaustive crash enumeration affordable. Both feed
    /// the one shared [`verify_recovered_fs`].)
    fn drive(&mut self) -> anyhow::Result<()> {
        // Deterministic bootstrap: one committed dir + file regardless
        // of the seed's draw luck, so every branch has a target and the
        // cut window is never empty.
        let mut m = self.model.clone();
        m.dirs.push("d-base".into());
        let r = self.fe.create_directory("d-base");
        self.control(m, r);
        let mut m = self.model.clone();
        m.files.push(("d-base".into(), "f-base".into(), 0));
        let r = self.fe.create_file(crate::dpufs::DirId(1), "f-base");
        if let Some(mut f) = self.control(m, r) {
            self.fe.poll_add(&mut f, &self.group);
            self.files.push((f, "d-base".into(), "f-base".into(), 0));
        }

        for i in 0..CRASH_OPS {
            match self.rng.next_range(10) {
                0..=2 => {
                    let name = format!("d{i}");
                    let mut m = self.model.clone();
                    m.dirs.push(name.clone());
                    let r = self.fe.create_directory(&name);
                    self.control(m, r);
                }
                3..=5 => {
                    // Create a file in the most recent directory (skip
                    // until one exists). Directory ids are
                    // creation-ordered: 1-based index into `model.dirs`.
                    let Some(pos) = self.model.dirs.len().checked_sub(1) else { continue };
                    let dir_name = self.model.dirs[pos].clone();
                    let dir_id = crate::dpufs::DirId((pos + 1) as u32);
                    let name = format!("f{i}");
                    let mut m = self.model.clone();
                    m.files.push((dir_name.clone(), name.clone(), 0));
                    let r = self.fe.create_file(dir_id, &name);
                    if let Some(mut f) = self.control(m, r) {
                        self.fe.poll_add(&mut f, &self.group);
                        self.files.push((f, dir_name, name, 0));
                    }
                }
                6..=7 => {
                    // Append a small write (data plane: no sync).
                    if self.files.is_empty() || self.dead {
                        continue;
                    }
                    let fi = self.rng.next_range(self.files.len() as u64) as usize;
                    let len = 1 + self.rng.next_range(2000) as usize;
                    let off = self.files[fi].3;
                    let data: Vec<u8> = (0..len).map(|j| ((off as usize + j) % 251) as u8).collect();
                    let issued = self.fe.write_file(&self.files[fi].0, off, &data);
                    match issued {
                        Ok(req_id) => {
                            if wait_event(&self.group, req_id)?.ok {
                                self.files[fi].3 = off + len as u64;
                                let (_, ref d, ref n, sz) = self.files[fi];
                                let entry = self
                                    .model
                                    .files
                                    .iter_mut()
                                    .find(|(fd, fn_, _)| fd == d && fn_ == n)
                                    .expect("model tracks every live file");
                                entry.2 = sz;
                            } else {
                                self.dead = true;
                                self.failed += 1;
                            }
                        }
                        Err(_) => {
                            self.dead = true;
                            self.failed += 1;
                        }
                    }
                }
                8 => {
                    // Explicit grow (control plane: synced).
                    if self.files.is_empty() {
                        continue;
                    }
                    let fi = self.rng.next_range(self.files.len() as u64) as usize;
                    let grow = self.files[fi].3 + 1 + self.rng.next_range(8 << 10);
                    let mut m = self.model.clone();
                    let (_, ref d, ref n, _) = self.files[fi];
                    let entry =
                        m.files.iter_mut().find(|(fd, fn_, _)| fd == d && fn_ == n).unwrap();
                    entry.2 = entry.2.max(grow);
                    let new_size = entry.2;
                    let handle = &self.files[fi].0;
                    let r = self.fe.ensure_size(handle, grow);
                    if self.control(m, r).is_some() {
                        self.files[fi].3 = new_size;
                    }
                }
                _ => {
                    // Delete a file (control plane: synced).
                    if self.files.is_empty() {
                        continue;
                    }
                    let fi = self.rng.next_range(self.files.len() as u64) as usize;
                    let (f, d, n, _) = self.files.remove(fi);
                    let mut m = self.model.clone();
                    m.files.retain(|(fd, fn_, _)| !(fd == &d && fn_ == &n));
                    self.control(m, self.fe.delete_file(f));
                }
            }
        }
        Ok(())
    }

    fn model_at(&self, seq: u64) -> Option<&MetaModel> {
        self.snapshots.iter().rev().find(|(s, _)| *s == seq).map(|(_, m)| m)
    }
}

/// Bounded wait for one data-plane completion on `group` — an op must
/// resolve OK or ERR within the bound, never hang.
fn wait_event(
    group: &Arc<PollGroup>,
    req_id: u64,
) -> anyhow::Result<crate::filelib::CompletionEvent> {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        for ev in group.poll_wait(Duration::from_millis(20)) {
            if ev.req_id == req_id {
                return Ok(ev);
            }
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "data-plane completion neither OK nor ERR within bound (hang)"
        );
    }
}

fn crash_storage() -> anyhow::Result<StorageServer> {
    StorageServer::build(
        StorageServerConfig {
            ssd_bytes: CRASH_SSD_BYTES,
            segment_size: CRASH_SEG,
            ..Default::default()
        },
        None,
    )
}

/// The crash-recovery scenario: drive a seeded metadata op sequence
/// against a full storage server, cut power mid-write at a seed-chosen
/// `(write, byte)` point, verify every post-cut op surfaces as a clean
/// bounded error, "reboot" the device, remount through the coordinator
/// restart path, and check the recovered file system equals the model
/// at the last committed sequence — with working post-recovery service.
pub fn crash_recovery(seed: u64) -> anyhow::Result<CrashRecoveryReport> {
    let started = Instant::now();
    let plane = FaultPlane::new(FaultConfig { seed, ..Default::default() });

    // Scout pass (fault-free): learn the deterministic write schedule.
    let trace = {
        let storage = crash_storage()?;
        storage.ssd.start_write_trace();
        let mut ops = CrashOps::new(seed, &storage)?;
        ops.drive()?;
        anyhow::ensure!(ops.failed == 0, "scout pass must run fault-free");
        storage.ssd.take_write_trace()
    };
    anyhow::ensure!(!trace.is_empty(), "op sequence issued no device writes");

    // The cut point derives from the seed via the PowerCut site stream.
    let mut prng = plane.site_rng(FaultSite::PowerCut);
    let cut_write = prng.next_range(trace.len() as u64);
    let cut_bytes = prng.next_range(trace[cut_write as usize].1 as u64 + 1) as usize;
    plane.record(
        FaultSite::PowerCut,
        FaultAction::PowerCut { write: cut_write, cut: cut_bytes as u32 },
    );

    // Chaos pass: same ops, cut armed.
    let storage = crash_storage()?;
    let ssd = storage.ssd.clone();
    ssd.arm_power_cut(cut_write, cut_bytes);
    let mut ops = CrashOps::new(seed, &storage)?;
    ops.drive()?;
    anyhow::ensure!(ops.failed > 0, "the cut must fail at least the op it tears");
    anyhow::ensure!(ssd.is_dead(), "the armed cut must have fired");
    drop(storage); // the crash: the server is gone, the medium survives

    // Reboot + remount through the coordinator restart path.
    ssd.power_restore();
    let (storage, recovery) = StorageServer::remount(
        ssd,
        StorageServerConfig {
            ssd_bytes: CRASH_SSD_BYTES,
            segment_size: CRASH_SEG,
            ..Default::default()
        },
        None,
    )?;

    // Recovery invariants: no committed op lost, nothing from the
    // future invented, and the state equals the model at the recovered
    // sequence.
    anyhow::ensure!(
        recovery.recovered_seq >= ops.acked_seq,
        "metadata loss: recovered seq {} < last acked seq {} (seed {seed}, cut {cut_write}/{cut_bytes})",
        recovery.recovered_seq,
        ops.acked_seq
    );
    anyhow::ensure!(
        recovery.recovered_seq <= ops.acked_seq + 1,
        "recovered seq {} past the only attemptable seq {} (seed {seed})",
        recovery.recovered_seq,
        ops.acked_seq + 1
    );
    let model = ops.model_at(recovery.recovered_seq).ok_or_else(|| {
        anyhow::anyhow!("recovered seq {} was never attempted (seed {seed})", recovery.recovered_seq)
    })?;
    let recovered_files = {
        let fs = storage.dpufs.read().unwrap();
        verify_recovered_fs(&fs, model, &format!("seed {seed}"))?
    };

    // The recovered server must be a fully working storage path.
    let fe = storage.front_end();
    let dir = fe.create_directory("post-crash").map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut f = fe.create_file(dir, "alive").map_err(|e| anyhow::anyhow!("{e}"))?;
    let group = fe.create_poll().map_err(|e| anyhow::anyhow!("{e}"))?;
    fe.poll_add(&mut f, &group);
    let payload: Vec<u8> = (0..1200u32).map(|i| (i % 249) as u8).collect();
    let wid = fe.write_file(&f, 0, &payload).map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(wait_event(&group, wid)?.ok, "post-recovery write failed");
    let rid = fe.read_file(&f, 0, payload.len() as u32).map_err(|e| anyhow::anyhow!("{e}"))?;
    let ev = wait_event(&group, rid)?;
    anyhow::ensure!(ev.ok && ev.data == payload, "post-recovery read not byte-exact");

    Ok(CrashRecoveryReport {
        seed,
        cut_write,
        cut_bytes,
        ops_acked: ops.acked,
        ops_failed: ops.failed,
        recovery,
        recovered_files,
        schedule: plane.schedule(),
        elapsed: started.elapsed(),
    })
}

// ---------------------------------------------------------------------
// data_crash: durable-WRITE power-cut + remount scenario
// ---------------------------------------------------------------------

/// Tenants in the data-crash scenario (one file + poll group each).
const DATA_TENANTS: usize = 3;
/// Seeded WRITE ops after the per-tenant base fills.
const DATA_OPS: usize = 18;
/// Durable base image per tenant file — 1.5 segments, so every tenant
/// owns a segment boundary for writes to tear across.
const DATA_BASE: usize = (CRASH_SEG + CRASH_SEG / 2) as usize;

/// What the data-crash scenario observed.
#[derive(Debug)]
pub struct DataCrashReport {
    pub seed: u64,
    /// The cut point: the `cut_write`-th device write after arming
    /// persisted only its first `cut_bytes` bytes.
    pub cut_write: u64,
    pub cut_bytes: usize,
    /// Durable WRITEs acked (remap record journaled) before the cut.
    pub writes_acked: u64,
    /// WRITEs that surfaced as clean bounded ERRs (the torn op and
    /// everything after it, including the concurrent dead-device burst).
    pub writes_failed: u64,
    /// The tenant whose WRITE the cut tore, if any op failed: recovery
    /// may legally surface either side of THAT op (its remap record may
    /// have fully persisted before the ack was delivered) — but only
    /// that op, and never a byte mix.
    pub ambiguous_tenant: Option<usize>,
    /// Recovered per-tenant file sizes (deterministic per seed).
    pub recovered_sizes: Vec<u64>,
    /// What mount-time recovery found, replayed and quarantined.
    pub recovery: RecoveryReport,
    /// `(op index, tenant, acked)` per WRITE — the deterministic
    /// outcome trace the determinism suite replays.
    pub outcomes: Vec<(usize, usize, u8)>,
    /// Canonical fault schedule (the power-cut injection).
    pub schedule: Vec<FaultEvent>,
    pub elapsed: Duration,
}

/// Deterministic payload for `(tenant, op)` — recovery verification
/// recomputes expected images from these alone.
fn data_pattern(seed: u64, tenant: usize, op: usize, len: usize) -> Vec<u8> {
    let base = (seed as usize) ^ tenant.wrapping_mul(131) ^ op.wrapping_mul(17);
    (0..len).map(|j| (base.wrapping_add(j) % 251) as u8).collect()
}

/// The seeded durable-WRITE driver shared by the scout and chaos
/// passes: per-tenant committed byte images are the model the recovered
/// device is checked against.
struct DataOps {
    rng: Rng,
    seed: u64,
    fe: DdsClient,
    /// Per tenant: file handle, poll group, committed (acked) image.
    tenants: Vec<(DdsFile, Arc<PollGroup>, Vec<u8>)>,
    outcomes: Vec<(usize, usize, u8)>,
    acked: u64,
    failed: u64,
    dead: bool,
    /// `(tenant, image)` the torn op would have committed: its remap
    /// record may have fully persisted before the cut killed the ack
    /// path, so recovery may surface either side of this one op.
    ambiguous: Option<(usize, Vec<u8>)>,
}

impl DataOps {
    fn new(seed: u64, storage: &StorageServer) -> anyhow::Result<Self> {
        let fe = storage.front_end();
        let dir = fe.create_directory("tenants").map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut tenants = Vec::with_capacity(DATA_TENANTS);
        for t in 0..DATA_TENANTS {
            let mut f =
                fe.create_file(dir, &format!("t{t}")).map_err(|e| anyhow::anyhow!("{e}"))?;
            let group = fe.create_poll().map_err(|e| anyhow::anyhow!("{e}"))?;
            fe.poll_add(&mut f, &group);
            tenants.push((f, group, Vec::new()));
        }
        Ok(DataOps {
            rng: Rng::new(seed ^ 0xDA7A_4001),
            seed,
            fe,
            tenants,
            outcomes: Vec::new(),
            acked: 0,
            failed: 0,
            dead: false,
            ambiguous: None,
        })
    }

    /// Issue one durable WRITE for tenant `t` and fold the outcome into
    /// the committed image / ambiguity bookkeeping.
    fn write(&mut self, opi: usize, t: usize, offset: u64, data: Vec<u8>) -> anyhow::Result<()> {
        // The image this op would commit.
        let mut with_op = self.tenants[t].2.clone();
        let end = offset as usize + data.len();
        if with_op.len() < end {
            with_op.resize(end, 0);
        }
        with_op[offset as usize..end].copy_from_slice(&data);
        let ok = match self.fe.write_file(&self.tenants[t].0, offset, &data) {
            Ok(req_id) => wait_event(&self.tenants[t].1, req_id)?.ok,
            Err(_) => false,
        };
        if ok {
            anyhow::ensure!(
                !self.dead,
                "WRITE acked after the device died (seed {}, op {opi})",
                self.seed
            );
            self.tenants[t].2 = with_op;
            self.acked += 1;
        } else {
            self.failed += 1;
            if !self.dead {
                self.dead = true;
                self.ambiguous = Some((t, with_op));
            }
        }
        self.outcomes.push((opi, t, ok as u8));
        Ok(())
    }

    /// The seeded WRITE mix: base fills, in-place overwrites, segment-
    /// boundary straddles, and hole-leaving growth. Each op round-trips
    /// before the next issues — deliberately, so the device write
    /// schedule is identical run to run and the scout trace indexes the
    /// chaos pass's writes exactly (the same-seed determinism contract;
    /// concurrency against the dead device is exercised separately by
    /// [`Self::concurrent_burst`]).
    fn drive(&mut self) -> anyhow::Result<()> {
        for t in 0..DATA_TENANTS {
            let data = data_pattern(self.seed, t, t, DATA_BASE);
            self.write(t, t, 0, data)?;
        }
        for i in 0..DATA_OPS {
            let opi = DATA_TENANTS + i;
            let t = self.rng.next_range(DATA_TENANTS as u64) as usize;
            let len = 1 + self.rng.next_range(4096);
            let kind = self.rng.next_range(10);
            let cur = self.tenants[t].2.len() as u64;
            let offset = match kind {
                // In-place overwrite inside the committed image.
                0..=5 => self.rng.next_range(cur.saturating_sub(len).max(1)),
                // Straddle the first segment boundary (the torn-extent
                // sweet spot: two shadows, one commit record).
                6..=7 => CRASH_SEG.saturating_sub(len / 2),
                // Growth past EOF, sometimes leaving a zero hole.
                _ => cur + self.rng.next_range(CRASH_SEG / 2),
            };
            let data = data_pattern(self.seed, t, opi, len as usize);
            self.write(opi, t, offset, data)?;
        }
        Ok(())
    }

    /// Concurrent multi-tenant burst against the dead device (chaos
    /// pass only, after the cut): every tenant issues at once; each
    /// WRITE must resolve as a clean bounded ERR — never a hang, never
    /// an ack, never a device mutation.
    fn concurrent_burst(&mut self) -> anyhow::Result<()> {
        let base = DATA_TENANTS + DATA_OPS;
        let issued: Vec<_> = (0..DATA_TENANTS)
            .map(|t| {
                let data = data_pattern(self.seed, t, base + t, 777);
                (t, self.fe.write_file(&self.tenants[t].0, 0, &data).ok())
            })
            .collect();
        for (t, req) in issued {
            let ok = match req {
                Some(id) => wait_event(&self.tenants[t].1, id)?.ok,
                None => false,
            };
            anyhow::ensure!(
                !ok,
                "dead-device burst WRITE acked (tenant {t}, seed {})",
                self.seed
            );
            self.failed += 1;
            self.outcomes.push((base + t, t, 0));
        }
        Ok(())
    }
}

fn data_crash_storage() -> anyhow::Result<StorageServer> {
    StorageServer::build(
        StorageServerConfig {
            ssd_bytes: CRASH_SSD_BYTES,
            segment_size: CRASH_SEG,
            service: FileServiceConfig { durable_data: true, ..Default::default() },
            ..Default::default()
        },
        None,
    )
}

/// Read a file's full recovered content straight off the device
/// through its extent mapping.
fn read_device_file(
    fs: &crate::dpufs::DpuFs,
    ssd: &crate::ssd::Ssd,
    id: crate::dpufs::FileId,
    size: u64,
) -> anyhow::Result<Vec<u8>> {
    let extents = fs.map_extents(id, 0, size).map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let mut out = Vec::with_capacity(size as usize);
    for e in extents {
        let mut buf = vec![0u8; e.len as usize];
        ssd.read_into(e.addr, &mut buf).map_err(|e| anyhow::anyhow!("{e}"))?;
        out.extend_from_slice(&buf);
    }
    Ok(out)
}

/// The data-path crash scenario: seeded multi-tenant durable WRITE load
/// with `durable_data` on, a power cut torn mid-write at a seed-chosen
/// `(write, byte)` point, a concurrent dead-device burst, then remount
/// through the coordinator restart path and the torn-write-proof
/// verdict: every acked WRITE reads back byte-exact, the torn op is
/// all-old or all-new (never a mix), nothing later is visible, no
/// segment leaks, and the recovered server serves durable WRITEs again.
pub fn data_crash(seed: u64) -> anyhow::Result<DataCrashReport> {
    let started = Instant::now();
    let plane = FaultPlane::new(FaultConfig { seed, ..Default::default() });

    // Scout pass (fault-free): learn the durable-write device schedule.
    let trace = {
        let storage = data_crash_storage()?;
        let mut ops = DataOps::new(seed, &storage)?;
        storage.ssd.start_write_trace();
        ops.drive()?;
        anyhow::ensure!(ops.failed == 0, "scout pass must run fault-free");
        anyhow::ensure!(ops.acked > 0, "scout pass acked nothing");
        storage.ssd.take_write_trace()
    };
    anyhow::ensure!(!trace.is_empty(), "durable WRITEs issued no device writes");

    // The cut point derives from the seed via the PowerCut site stream.
    let mut prng = plane.site_rng(FaultSite::PowerCut);
    let cut_write = prng.next_range(trace.len() as u64);
    let cut_bytes = prng.next_range(trace[cut_write as usize].1 as u64 + 1) as usize;
    plane.record(
        FaultSite::PowerCut,
        FaultAction::PowerCut { write: cut_write, cut: cut_bytes as u32 },
    );

    // Chaos pass: same setup and ops, cut armed after setup (the same
    // point the scout reset its write counter at, so indices align).
    let storage = data_crash_storage()?;
    let ssd = storage.ssd.clone();
    let mut ops = DataOps::new(seed, &storage)?;
    ssd.arm_power_cut(cut_write, cut_bytes);
    ops.drive()?;
    anyhow::ensure!(ssd.is_dead(), "the armed cut must have fired");
    anyhow::ensure!(ops.failed > 0, "the cut must fail at least the op it tears");
    ops.concurrent_burst()?;
    drop(storage); // the crash: the server is gone, the medium survives

    // Reboot + remount through the coordinator restart path.
    ssd.power_restore();
    let (storage, recovery) = StorageServer::remount(
        ssd.clone(),
        StorageServerConfig {
            ssd_bytes: CRASH_SSD_BYTES,
            segment_size: CRASH_SEG,
            service: FileServiceConfig { durable_data: true, ..Default::default() },
            ..Default::default()
        },
        None,
    )?;

    // Torn-write-proof verdict, per tenant: the recovered bytes equal
    // the committed image — or, for the ONE ambiguous (torn) op, its
    // fully-applied target. Anything else is a durability violation:
    // a lost acked WRITE, a half-applied extent, or invented bytes.
    let ctx = format!("seed {seed} cut {cut_write}/{cut_bytes}");
    let mut sizes = Vec::with_capacity(DATA_TENANTS);
    {
        let fs = storage.dpufs.read().unwrap();
        for (t, (_, _, committed)) in ops.tenants.iter().enumerate() {
            // File ids are creation-ordered: t0 is FileId(1).
            let id = crate::dpufs::FileId(t as u32 + 1);
            let size = fs.file_meta(id).map_err(|e| anyhow::anyhow!("{ctx}: {e:?}"))?.size;
            let got = read_device_file(&fs, &ssd, id, size)?;
            let mut candidates: Vec<&Vec<u8>> = vec![committed];
            if let Some((at, alt)) = ops.ambiguous.as_ref() {
                if *at == t {
                    candidates.push(alt);
                }
            }
            anyhow::ensure!(
                candidates.iter().any(|c| got == **c),
                "{ctx}: tenant {t} recovered {} bytes matching neither the committed \
                 image ({} B) nor the torn op's target — torn-write atomicity violated",
                got.len(),
                committed.len()
            );
            sizes.push(size);
        }
        // Structural invariants: mapping lengths, segment uniqueness,
        // bitmap accounting (no leaked shadow segments), id counters.
        let model = MetaModel {
            dirs: vec!["tenants".into()],
            files: (0..DATA_TENANTS)
                .map(|t| ("tenants".to_string(), format!("t{t}"), sizes[t]))
                .collect(),
        };
        verify_recovered_fs(&fs, &model, &ctx)?;
    }

    // The operator surface must report the same recovery the mount ran.
    let fe = storage.front_end();
    let reported = fe.recovery_report().map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(
        reported.as_ref() == Some(&recovery),
        "{ctx}: control-plane recovery report disagrees with the mount's"
    );

    // The recovered server must serve durable WRITEs again, byte-exact.
    let dir = fe.create_directory("post-crash").map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut f = fe.create_file(dir, "alive").map_err(|e| anyhow::anyhow!("{e}"))?;
    let group = fe.create_poll().map_err(|e| anyhow::anyhow!("{e}"))?;
    fe.poll_add(&mut f, &group);
    let payload: Vec<u8> = (0..2048u32).map(|i| (i % 241) as u8).collect();
    let wid = fe.write_file(&f, 0, &payload).map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(wait_event(&group, wid)?.ok, "post-recovery durable write failed");
    let rid = fe.read_file(&f, 0, payload.len() as u32).map_err(|e| anyhow::anyhow!("{e}"))?;
    let ev = wait_event(&group, rid)?;
    anyhow::ensure!(ev.ok && ev.data == payload, "post-recovery read not byte-exact");

    Ok(DataCrashReport {
        seed,
        cut_write,
        cut_bytes,
        writes_acked: ops.acked,
        writes_failed: ops.failed,
        ambiguous_tenant: ops.ambiguous.as_ref().map(|(t, _)| *t),
        recovered_sizes: sizes,
        recovery,
        outcomes: ops.outcomes,
        schedule: plane.schedule(),
        elapsed: started.elapsed(),
    })
}

/// Compare a recovered file system against the committed model; also
/// check the allocation invariants (segment uniqueness/range, bitmap
/// accounting, file-mapping lengths, id-counter safety). Returns the
/// live file count. The ONE recovery verifier — used here and by the
/// crash-point enumeration harness (`rust/tests/crash_recovery.rs`).
pub fn verify_recovered_fs(
    fs: &crate::dpufs::DpuFs,
    model: &MetaModel,
    ctx: &str,
) -> anyhow::Result<usize> {
    let dirs = fs.list_dirs();
    let got_dirs: Vec<String> = dirs.iter().map(|(_, n)| n.to_string()).collect();
    anyhow::ensure!(
        got_dirs == model.dirs,
        "{ctx}: recovered dirs {got_dirs:?} != model {:?}",
        model.dirs
    );
    let mut got_files: Vec<(String, String, u64)> = Vec::new();
    let mut seen_segments = std::collections::HashSet::new();
    let mut total_segments = 0usize;
    let mut max_file_id = 0u32;
    let mut max_dir_id = 0u32;
    for (dir_id, dir_name) in &dirs {
        max_dir_id = max_dir_id.max(dir_id.0);
        for meta in fs.list_dir(*dir_id) {
            got_files.push((dir_name.to_string(), meta.name.clone(), meta.size));
            max_file_id = max_file_id.max(meta.id.0);
            anyhow::ensure!(
                meta.segments.len() as u64 == meta.size.div_ceil(fs.segment_size()),
                "{ctx}: file {:?} maps {} segments for {} bytes",
                meta.name,
                meta.segments.len(),
                meta.size
            );
            for &s in &meta.segments {
                anyhow::ensure!(
                    (s as usize) >= crate::dpufs::RESERVED_SEGMENTS
                        && (s as usize) < fs.num_segments(),
                    "{ctx}: segment {s} out of range / reserved"
                );
                anyhow::ensure!(
                    seen_segments.insert(s),
                    "{ctx}: segment {s} double-allocated"
                );
                total_segments += 1;
            }
        }
    }
    let mut want: Vec<(String, String, u64)> = model.files.clone();
    want.sort();
    got_files.sort();
    anyhow::ensure!(
        got_files == want,
        "{ctx}: recovered files {got_files:?} != model {want:?}"
    );
    anyhow::ensure!(
        fs.free_segments()
            == fs.num_segments() - crate::dpufs::RESERVED_SEGMENTS - total_segments,
        "{ctx}: bitmap accounting broken"
    );
    let (next_dir, next_file) = fs.counters();
    anyhow::ensure!(
        next_file > max_file_id,
        "{ctx}: next_file {next_file} could reuse live id {max_file_id}"
    );
    anyhow::ensure!(
        next_dir > max_dir_id,
        "{ctx}: next_dir {next_dir} could reuse live id {max_dir_id}"
    );
    Ok(got_files.len())
}

/// Block size the cache-chaos workload reads and writes at.
const CACHE_BLOCK: u64 = 1 << 10;
/// Blocks in the hot file (a 64 KiB image — all of it fits the tier,
/// so a stale entry would really be SERVED, not masked by eviction).
const CACHE_FILE_BLOCKS: u64 = 64;
/// Seeded READ/WRITE ops after the base fill.
const CACHE_OPS: usize = 160;
/// Tier byte budget for the cache-chaos server.
const CACHE_TIER_BYTES: u64 = 1 << 20;

/// What the cache-chaos scenario observed.
#[derive(Debug)]
pub struct CacheChaosReport {
    pub seed: u64,
    /// The `cut_write`-th device write after arming tore the power.
    pub cut_write: u64,
    /// Durable WRITEs acked (and folded into the byte model).
    pub writes_acked: u64,
    /// OK READs byte-checked against the model (tier hits and SSD
    /// reads alike — the check cannot tell them apart, by design).
    pub reads_ok: u64,
    /// Ops that surfaced as clean bounded ERRs (injected SSD failures
    /// plus everything at/after the cut).
    pub ops_failed: u64,
    /// Tier counters at the instant of the crash.
    pub pre_cut: TierStats,
    /// What mount-time recovery found, replayed and quarantined.
    pub recovery: RecoveryReport,
    /// Tier counters after the post-remount exercise (fresh tier).
    pub post_remount: TierStats,
    /// Canonical fault schedule (the power-cut injection).
    pub schedule: Vec<FaultEvent>,
    pub elapsed: Duration,
}

/// Shared by the chaos mount and the remount — the tier must be
/// configured on BOTH so the scenario proves remount cold-starts it.
fn cache_chaos_cfg() -> StorageServerConfig {
    StorageServerConfig {
        ssd_bytes: CRASH_SSD_BYTES,
        segment_size: CRASH_SEG,
        cache_bytes: CACHE_TIER_BYTES,
        service: FileServiceConfig { durable_data: true, ..Default::default() },
        ..Default::default()
    }
}

/// The cache-coherence crash scenario: a durable-data server with the
/// read-cache tier on runs a seeded READ/WRITE mix under host-SSD
/// faults (fail + delay — never drop: a dropped journal completion
/// means the record LANDED and recovery replays it, which would make
/// every faulted WRITE ambiguous instead of exactly the torn one) with
/// a power cut armed at a seed-chosen device write. The property under
/// test the whole way: an OK READ byte-equals the last *acked* WRITE's
/// image for that block — a tier serving bytes from before an acked
/// overwrite, or surviving the remap-commit invalidation, fails here.
/// After the cut: the crash must leak no pooled buffers through the
/// tier, and a remount must cold-start the tier (empty-but-consistent)
/// while the device carries exactly the committed image, modulo the
/// one torn op (all-old or all-new, never a mix).
pub fn cache_chaos(seed: u64) -> anyhow::Result<CacheChaosReport> {
    let started = Instant::now();
    let plane = FaultPlane::new(FaultConfig {
        seed,
        host_ssd: SsdFaultConfig { fail_p: 0.08, drop_p: 0.0, delay_p: 0.25, delay_polls: 3 },
        ..Default::default()
    });

    let mut cfg = cache_chaos_cfg();
    cfg.service.ssd_faults = Some(plane.ssd_injector(FaultSite::HostSsdQueue));
    let storage = StorageServer::build(cfg, None)?;
    let ssd = storage.ssd.clone();
    let tier = storage.tier.clone().expect("cache_chaos runs with the tier on");

    // Setup (injector disarmed, cut unarmed): one hot file, durably
    // base-filled block by block; `image` mirrors every acked byte
    // from here on — it is the model OK READs are checked against.
    let fe = storage.front_end();
    let dir = fe.create_directory("cache").map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut f = fe.create_file(dir, "hot").map_err(|e| anyhow::anyhow!("{e}"))?;
    let group = fe.create_poll().map_err(|e| anyhow::anyhow!("{e}"))?;
    fe.poll_add(&mut f, &group);
    let mut image = vec![0u8; (CACHE_FILE_BLOCKS * CACHE_BLOCK) as usize];
    for b in 0..CACHE_FILE_BLOCKS {
        let data = data_pattern(seed, 0, b as usize, CACHE_BLOCK as usize);
        let wid =
            fe.write_file(&f, b * CACHE_BLOCK, &data).map_err(|e| anyhow::anyhow!("{e}"))?;
        anyhow::ensure!(wait_event(&group, wid)?.ok, "base fill write failed (block {b})");
        image[(b * CACHE_BLOCK) as usize..((b + 1) * CACHE_BLOCK) as usize]
            .copy_from_slice(&data);
    }
    // Sanity: the tier actually participates (first read fills it,
    // second is served from it) before any fault can mask a dead tier.
    for pass in 0..2 {
        let rid = fe.read_file(&f, 0, CACHE_BLOCK as u32).map_err(|e| anyhow::anyhow!("{e}"))?;
        let ev = wait_event(&group, rid)?;
        anyhow::ensure!(
            ev.ok && ev.data[..] == image[..CACHE_BLOCK as usize],
            "warm-up read wrong (pass {pass})"
        );
    }
    anyhow::ensure!(tier.stats().hits >= 1, "warm-up reads never hit the tier");

    // Arm the chaos: probabilistic SSD faults plus a power cut a
    // seed-chosen number of device writes out. The torn-byte count is
    // arbitrary — `power_gate` clamps it per write.
    let mut prng = plane.site_rng(FaultSite::PowerCut);
    let cut_write = 10 + prng.next_range(50);
    let cut_bytes = prng.next_range(CRASH_SEG) as usize;
    plane.record(
        FaultSite::PowerCut,
        FaultAction::PowerCut { write: cut_write, cut: cut_bytes as u32 },
    );
    plane.arm_ssd();
    ssd.arm_power_cut(cut_write, cut_bytes);

    // Seeded mix: 40% durable block WRITEs, 60% block READs, each op
    // round-tripping before the next. OK READs must byte-equal the
    // model whether the tier or the SSD served them (post-cut tier
    // hits returning committed bytes are legal OKs; post-cut SSD ops
    // fail clean). An injected Fail never reaches the medium, so an
    // ERR WRITE commits nothing — except the ONE op the cut tears,
    // whose journal record may have fully persisted before the ack
    // path died; recovery may surface either side of that op only.
    let mut rng = Rng::new(seed ^ 0xCAC4_E001);
    let (mut acked, mut reads_ok, mut failed) = (0u64, 0u64, 0u64);
    let mut ambiguous: Option<Vec<u8>> = None;
    for op in 0..CACHE_OPS {
        let b = rng.next_range(CACHE_FILE_BLOCKS);
        let (lo, hi) = ((b * CACHE_BLOCK) as usize, ((b + 1) * CACHE_BLOCK) as usize);
        let was_dead = ssd.is_dead();
        if rng.next_range(10) < 4 {
            let data =
                data_pattern(seed, 1, CACHE_FILE_BLOCKS as usize + op, CACHE_BLOCK as usize);
            let ok = match fe.write_file(&f, b * CACHE_BLOCK, &data) {
                Ok(id) => wait_event(&group, id)?.ok,
                Err(_) => false,
            };
            if ok {
                anyhow::ensure!(
                    !was_dead,
                    "WRITE acked on a dead device (seed {seed}, op {op})"
                );
                image[lo..hi].copy_from_slice(&data);
                acked += 1;
            } else {
                failed += 1;
                if !was_dead && ssd.is_dead() {
                    // The torn op — the either-or candidate.
                    let mut alt = image.clone();
                    alt[lo..hi].copy_from_slice(&data);
                    ambiguous = Some(alt);
                }
            }
        } else {
            let (ok, data) = match fe.read_file(&f, b * CACHE_BLOCK, CACHE_BLOCK as u32) {
                Ok(id) => {
                    let ev = wait_event(&group, id)?;
                    (ev.ok, ev.data)
                }
                Err(_) => (false, Vec::new()),
            };
            if ok {
                anyhow::ensure!(
                    data[..] == image[lo..hi],
                    "stale READ: block {b} returned bytes older than the last acked \
                     WRITE (seed {seed}, op {op}, tier {:?})",
                    tier.stats()
                );
                reads_ok += 1;
            } else {
                failed += 1;
            }
        }
    }
    anyhow::ensure!(ssd.is_dead(), "the armed cut must have fired (seed {seed})");
    anyhow::ensure!(reads_ok > 0, "no READ completed OK before the cut (seed {seed})");
    let pre_cut = tier.stats();
    anyhow::ensure!(pre_cut.invalidations > 0, "acked WRITEs never invalidated the tier");

    // The crash. Joining the service drops its tier handle and staging
    // slots; clearing ours must return every cached view to its pool —
    // a leak here means the tier pins completion buffers past death.
    let pools = [storage.buf_pool.clone(), storage.read_buf_pool.clone()];
    drop(storage);
    tier.clear();
    for (i, p) in pools.iter().enumerate() {
        anyhow::ensure!(
            p.in_use() == 0,
            "pool {i} leaks {} buffers across the crash (seed {seed})",
            p.in_use()
        );
    }

    // Reboot + remount through the coordinator restart path, tier
    // configured on: it must cold-start empty, never rehydrate.
    ssd.power_restore();
    let (storage, recovery) = StorageServer::remount(ssd.clone(), cache_chaos_cfg(), None)?;
    let tier2 = storage.tier.clone().expect("remount config keeps the tier on");
    let cold = tier2.stats();
    anyhow::ensure!(
        cold.entries == 0 && cold.bytes_cached == 0,
        "remounted tier must cold-start empty (found {} entries / {} bytes)",
        cold.entries,
        cold.bytes_cached
    );

    // Device truth: the recovered bytes equal the committed image — or
    // the torn op's fully-applied target, never a mix. Plus the usual
    // structural invariants.
    let ctx = format!("cache_chaos seed {seed} cut {cut_write}");
    {
        let fs = storage.dpufs.read().unwrap();
        let id = crate::dpufs::FileId(1);
        let size = fs.file_meta(id).map_err(|e| anyhow::anyhow!("{ctx}: {e:?}"))?.size;
        anyhow::ensure!(size == image.len() as u64, "{ctx}: recovered size {size}");
        let got = read_device_file(&fs, &ssd, id, size)?;
        let mut candidates: Vec<&Vec<u8>> = vec![&image];
        if let Some(alt) = ambiguous.as_ref() {
            candidates.push(alt);
        }
        anyhow::ensure!(
            candidates.iter().any(|c| got == **c),
            "{ctx}: recovered bytes match neither the committed image nor the torn \
             op's target — torn-write atomicity violated"
        );
        let model = MetaModel {
            dirs: vec!["cache".into()],
            files: vec![("cache".into(), "hot".into(), size)],
        };
        verify_recovered_fs(&fs, &model, &ctx)?;
    }

    // The fresh tier must fill and serve again, byte-exact.
    let fe = storage.front_end();
    let dir = fe.create_directory("post-crash").map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut f2 = fe.create_file(dir, "alive").map_err(|e| anyhow::anyhow!("{e}"))?;
    let group = fe.create_poll().map_err(|e| anyhow::anyhow!("{e}"))?;
    fe.poll_add(&mut f2, &group);
    let payload = data_pattern(seed, 2, 0, 2 * CACHE_BLOCK as usize);
    let wid = fe.write_file(&f2, 0, &payload).map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(wait_event(&group, wid)?.ok, "{ctx}: post-recovery write failed");
    for pass in 0..2 {
        let rid =
            fe.read_file(&f2, 0, payload.len() as u32).map_err(|e| anyhow::anyhow!("{e}"))?;
        let ev = wait_event(&group, rid)?;
        anyhow::ensure!(
            ev.ok && ev.data == payload,
            "{ctx}: post-recovery read not byte-exact (pass {pass})"
        );
    }
    let post_remount = tier2.stats();
    anyhow::ensure!(
        post_remount.fills >= 1 && post_remount.hits >= 1,
        "{ctx}: the remounted tier never filled/served"
    );

    // Final leak check: quiesce, then every pool slot accounted for.
    let pools = [storage.buf_pool.clone(), storage.read_buf_pool.clone()];
    drop(storage);
    tier2.clear();
    for (i, p) in pools.iter().enumerate() {
        anyhow::ensure!(
            p.in_use() == 0,
            "{ctx}: pool {i} leaks {} buffers after recovery",
            p.in_use()
        );
    }

    Ok(CacheChaosReport {
        seed,
        cut_write,
        writes_acked: acked,
        reads_ok,
        ops_failed: failed,
        pre_cut,
        recovery,
        post_remount,
        schedule: plane.schedule(),
        elapsed: started.elapsed(),
    })
}
