//! The DDS host file library (§4.2) — the front end of the unified
//! storage path.
//!
//! Offers the familiar file API the paper describes so that adopting
//! DDS "requires minimal DBMS modification": `CreateDirectory`,
//! `CreateFile`, `CreatePoll`, `PollAdd`, `ReadFile`, `WriteFile`,
//! gathered writes / scattered reads, and `PollWait` with both
//! *non-blocking* (zero wait) and *sleeping* (driver-interrupt) modes.
//!
//! All data-plane operations are non-blocking: a read/write is
//! book-kept in its notification group, encoded per Fig 9, and inserted
//! into the group's DMA-registered request ring; completions are pulled
//! from the response ring by `PollWait`. The host never executes file
//! I/O — that is the DPU file service's job.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::cache::TierStats;
use crate::dpufs::{DirId, FileId, FsError};
use crate::fileservice::{ControlMsg, Doorbell, GroupChannel, GroupCounters};
use crate::metrics::{CpuStats, LatencyStats, TenantCounters};
use crate::proto::{FileOpKind, FileRequest, FileResponse, Status};
use crate::ring::{ProgressRing, RequestRing, ResponseRing, RingStatus};

/// Library errors.
#[derive(Debug)]
pub enum LibError {
    Fs(FsError),
    ServiceGone,
    RingFull,
    NotInGroup,
    /// Request record exceeds the ring's maximum allowable progress —
    /// split the I/O (write payloads are inlined per Fig 9).
    TooLarge { bytes: usize, max: usize },
}

impl std::fmt::Display for LibError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for LibError {}

impl From<FsError> for LibError {
    fn from(e: FsError) -> Self {
        LibError::Fs(e)
    }
}

/// A completed file operation returned by `PollWait`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CompletionEvent {
    pub req_id: u64,
    pub file_id: FileId,
    pub kind: FileOpKind,
    pub ok: bool,
    /// Read payload (empty for writes). For scattered reads, use
    /// [`CompletionEvent::scatter`] to split it back.
    pub data: Vec<u8>,
    /// Scatter sizes recorded at issue time (scattered reads only).
    pub scatter_sizes: Vec<u32>,
}

impl CompletionEvent {
    /// Split a scattered read's payload into the caller's buffers.
    pub fn scatter(&self) -> Vec<&[u8]> {
        if self.scatter_sizes.is_empty() {
            return vec![&self.data[..]];
        }
        let mut out = Vec::with_capacity(self.scatter_sizes.len());
        let mut at = 0usize;
        for &s in &self.scatter_sizes {
            let end = (at + s as usize).min(self.data.len());
            out.push(&self.data[at..end]);
            at = end;
        }
        out
    }
}

struct PendingOp {
    file_id: FileId,
    kind: FileOpKind,
    scatter_sizes: Vec<u32>,
}

/// An epoll-like notification group (§4.2): owns a request ring and a
/// response ring, pre-registered for DPU DMA at creation.
pub struct PollGroup {
    chan: Arc<GroupChannel>,
    pending: Mutex<HashMap<u64, PendingOp>>,
    next_id: AtomicU64,
    /// Response-ring records that failed to decode. Each one is
    /// surfaced as an ERR completion for its salvaged request id (or
    /// counted here when even the id is gone) — never silently
    /// dropped, which used to leak the pending entry and wedge
    /// `in_flight()`-based quiesce loops forever.
    bad_records: AtomicU64,
    /// Well-formed responses whose request id matched nothing pending
    /// (stale duplicates): dropped, but counted.
    orphans: AtomicU64,
}

impl PollGroup {
    /// Poll completions (§4.2 "Polling responses").
    ///
    /// * `timeout == 0` → non-blocking mode: return whatever is ready.
    /// * `timeout > 0` → sleeping mode: block on the doorbell (the DPU
    ///   driver interrupt) until a response arrives or timeout.
    pub fn poll_wait(&self, timeout: Duration) -> Vec<CompletionEvent> {
        let mut out = self.drain();
        if !out.is_empty() || timeout.is_zero() {
            return out;
        }
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let seen = self.chan.doorbell.seq();
            out = self.drain();
            if !out.is_empty() {
                return out;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return out;
            }
            self.chan.doorbell.wait(seen, deadline - now);
        }
    }

    fn drain(&self) -> Vec<CompletionEvent> {
        let mut out = Vec::new();
        let mut popped = false;
        loop {
            let mut got: Option<FileResponse> = None;
            let mut salvaged: Option<u64> = None;
            let st = self.chan.resp_ring.pop(&mut |bytes| {
                got = FileResponse::decode(bytes);
                if got.is_none() {
                    salvaged = FileResponse::peek_req_id(bytes);
                }
            });
            if st != RingStatus::Ok {
                break;
            }
            popped = true;
            let Some(resp) = got else {
                // Malformed record. The ring slot is consumed either
                // way, so skipping silently would leak a pending entry
                // and `in_flight()` would never reach 0 (wedging every
                // quiesce loop): salvage the request id from the fixed
                // header and surface an ERR completion. When even the
                // header is gone, fail the OLDEST pending op instead —
                // the service delivers in request order, so the
                // mangled record almost surely belonged to the lowest
                // outstanding id. Both attributions are best-effort
                // (no checksum in the golden-pinned layout); the
                // `bad_records`/`orphan_responses` counters keep any
                // misattribution observable.
                self.bad_records.fetch_add(1, Ordering::Relaxed);
                let op = {
                    let mut pending = self.pending.lock().unwrap();
                    match salvaged {
                        // Intact id, nothing pending under it: a
                        // corrupted STALE DUPLICATE — same disposition
                        // as an intact orphan (dropped, counted);
                        // failing some healthy op for it would report
                        // a false ERR for work that succeeded.
                        Some(id) if !pending.contains_key(&id) => {
                            self.orphans.fetch_add(1, Ordering::Relaxed);
                            None
                        }
                        Some(id) => pending.remove(&id).map(|op| (id, op)),
                        // Even the id bytes are gone: only here does
                        // the oldest-pending attribution apply.
                        None => pending
                            .keys()
                            .min()
                            .copied()
                            .and_then(|id| pending.remove(&id).map(|op| (id, op))),
                    }
                };
                if let Some((req_id, op)) = op {
                    out.push(CompletionEvent {
                        req_id,
                        file_id: op.file_id,
                        kind: op.kind,
                        ok: false,
                        data: Vec::new(),
                        scatter_sizes: op.scatter_sizes,
                    });
                }
                continue;
            };
            // Locate the book-kept operation by request id (§4.2).
            let op = self.pending.lock().unwrap().remove(&resp.req_id);
            let Some(op) = op else {
                // Response for nothing we issued (stale duplicate):
                // dropped, but visible in the counter.
                self.orphans.fetch_add(1, Ordering::Relaxed);
                continue;
            };
            out.push(CompletionEvent {
                req_id: resp.req_id,
                file_id: op.file_id,
                kind: op.kind,
                ok: resp.status == Status::Ok,
                data: resp.data,
                scatter_sizes: op.scatter_sizes,
            });
        }
        if popped {
            // Freed response-ring space: a service delivery blocked on
            // a full host ring may be parked — ring it to retry now
            // instead of after its bounded park expires.
            self.chan.wake.ring();
        }
        out
    }

    /// Operations issued but not yet completed.
    pub fn in_flight(&self) -> usize {
        self.pending.lock().unwrap().len()
    }

    /// Malformed response-ring records seen so far (each surfaced as
    /// an ERR completion when its request id was salvageable).
    pub fn bad_records(&self) -> u64 {
        self.bad_records.load(Ordering::Relaxed)
    }

    /// Well-formed responses that matched no pending operation.
    pub fn orphan_responses(&self) -> u64 {
        self.orphans.load(Ordering::Relaxed)
    }

    fn issue(&self, req: FileRequest, op: PendingOp) -> Result<u64, LibError> {
        let id = req.req_id;
        let encoded = req.encode();
        let max = self.chan.req_ring.max_progress() as usize;
        if encoded.len() + 12 > max {
            return Err(LibError::TooLarge { bytes: encoded.len(), max });
        }
        self.pending.lock().unwrap().insert(id, op);
        // Non-blocking insert; on RETRY (backlog at max allowable
        // progress) undo the bookkeeping and surface RingFull.
        match self.chan.req_ring.try_push(&encoded) {
            RingStatus::Ok => {
                // Request published — ring the service pump awake
                // (ring AFTER the push: the pump snapshots the
                // sequence before scanning, so this edge can never be
                // slept through).
                self.chan.wake.ring();
                Ok(id)
            }
            _ => {
                self.pending.lock().unwrap().remove(&id);
                Err(LibError::RingFull)
            }
        }
    }
}

/// A DDS file handle. Data-plane ops go through the file's poll group
/// (set with [`DdsClient::poll_add`]).
#[derive(Clone)]
pub struct DdsFile {
    pub id: FileId,
    group: Option<Arc<PollGroup>>,
}

/// The host-side client: control-plane calls to the DPU file service
/// plus poll-group management.
pub struct DdsClient {
    ctrl: mpsc::Sender<ControlMsg>,
    /// The service pump's wake doorbell: control sends and poll-group
    /// request pushes ring it so a parked service reacts immediately
    /// instead of after its bounded park expires.
    wake: Arc<Doorbell>,
    /// Ring sizing for new poll groups: (req ring bytes, max progress,
    /// resp ring bytes).
    pub req_ring_bytes: usize,
    pub max_progress: usize,
    pub resp_ring_bytes: usize,
}

macro_rules! ctrl_call {
    ($self:expr, $variant:ident { $($field:ident : $value:expr),* }) => {{
        let (tx, rx) = mpsc::channel();
        $self
            .ctrl
            .send(ControlMsg::$variant { $($field: $value,)* reply: tx })
            .map_err(|_| LibError::ServiceGone)?;
        // The service may be parked: ring it so the control call is
        // served now, not after the bounded park expires.
        $self.wake.ring();
        rx.recv().map_err(|_| LibError::ServiceGone)?
    }};
}

impl DdsClient {
    pub fn new(ctrl: mpsc::Sender<ControlMsg>, wake: Arc<Doorbell>) -> Self {
        DdsClient {
            ctrl,
            wake,
            req_ring_bytes: 1 << 20,
            max_progress: 1 << 18,
            resp_ring_bytes: 1 << 22,
        }
    }

    /// `CreateDirectory` (§4.2).
    pub fn create_directory(&self, name: &str) -> Result<DirId, LibError> {
        Ok(ctrl_call!(self, CreateDirectory { name: name.to_string() })?)
    }

    /// `CreateFile` — returns a file handle (§4.2).
    pub fn create_file(&self, dir: DirId, name: &str) -> Result<DdsFile, LibError> {
        let id = ctrl_call!(self, CreateFile { dir: dir, name: name.to_string() })?;
        Ok(DdsFile { id, group: None })
    }

    /// Pre-size a file (convenience for apps that preallocate).
    pub fn ensure_size(&self, file: &DdsFile, size: u64) -> Result<(), LibError> {
        Ok(ctrl_call!(self, EnsureSize { file: file.id, size: size })?)
    }

    /// Current file size.
    pub fn file_size(&self, file: &DdsFile) -> Result<u64, LibError> {
        Ok(ctrl_call!(self, FileSize { file: file.id })?)
    }

    pub fn delete_file(&self, file: DdsFile) -> Result<(), LibError> {
        Ok(ctrl_call!(self, DeleteFile { file: file.id })?)
    }

    pub fn remove_directory(&self, dir: DirId) -> Result<(), LibError> {
        Ok(ctrl_call!(self, RemoveDirectory { dir: dir })?)
    }

    /// Persist DPU file-system metadata.
    pub fn sync_metadata(&self) -> Result<(), LibError> {
        Ok(ctrl_call!(self, SyncMetadata {})?)
    }

    /// What mount-time crash recovery observed and repaired (`None`
    /// when the server was freshly formatted rather than remounted).
    pub fn recovery_report(
        &self,
    ) -> Result<Option<crate::dpufs::RecoveryReport>, LibError> {
        Ok(ctrl_call!(self, RecoveryReport {}))
    }

    /// Per-poll-group service counters (requests drained, responses
    /// delivered, outstanding), indexed by registration order. Lets
    /// multi-group deployments (one group per shard/thread) verify the
    /// service is draining every group.
    pub fn group_stats(&self) -> Result<Vec<GroupCounters>, LibError> {
        Ok(ctrl_call!(self, GroupStats {}))
    }

    /// Fault plane: stall poll group `group` (by registration index)
    /// for `iterations` service iterations. Returns whether the group
    /// exists.
    pub fn inject_group_stall(&self, group: usize, iterations: u32) -> Result<bool, LibError> {
        Ok(ctrl_call!(self, InjectGroupStall { group: group, iterations: iterations }))
    }

    /// CPU ledger of the service pump: iterations, parks, wakes, and
    /// the busy fraction — the functional analogue of the per-core
    /// utilisation the paper's Fig 14 charts.
    pub fn cpu_stats(&self) -> Result<CpuStats, LibError> {
        Ok(ctrl_call!(self, CpuStats {}))
    }

    /// Tail-latency summary (p50/p99/p99.9/max) of the deployment's
    /// request path: the file service's staging-to-delivery recorder
    /// merged with every registered peer recorder (director shards).
    pub fn latency_stats(&self) -> Result<LatencyStats, LibError> {
        Ok(ctrl_call!(self, LatencyStats {}))
    }

    /// Per-tenant QoS counters (admitted / completed / rejected /
    /// throttled / open flows), merged across every director shard
    /// registered with the service — the fanout plane's fairness
    /// picture in one control round trip.
    pub fn tenant_stats(&self) -> Result<Vec<TenantCounters>, LibError> {
        Ok(ctrl_call!(self, TenantStats {}))
    }

    /// Read-cache tier counters (hits / misses / fills / dropped
    /// fills / invalidations / evictions / bytes served, plus
    /// occupancy). All-zero when the server runs without a tier
    /// (`cache_bytes == 0`).
    pub fn cache_stats(&self) -> Result<TierStats, LibError> {
        Ok(ctrl_call!(self, CacheStats {}))
    }

    /// `CreatePoll` (§4.2): allocate request/response rings for the
    /// group and register them with the DPU driver for DMA.
    pub fn create_poll(&self) -> Result<Arc<PollGroup>, LibError> {
        let chan = Arc::new(GroupChannel {
            req_ring: ProgressRing::new(self.req_ring_bytes, self.max_progress),
            resp_ring: ResponseRing::new(self.resp_ring_bytes),
            doorbell: Doorbell::new(),
            wake: self.wake.clone(),
        });
        let (tx, rx) = mpsc::channel();
        self.ctrl
            .send(ControlMsg::CreatePoll { group: chan.clone(), reply: tx })
            .map_err(|_| LibError::ServiceGone)?;
        self.wake.ring();
        let _gid = rx.recv().map_err(|_| LibError::ServiceGone)?;
        Ok(Arc::new(PollGroup {
            chan,
            pending: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            bad_records: AtomicU64::new(0),
            orphans: AtomicU64::new(0),
        }))
    }

    /// `PollAdd`: attach a file to a notification group (§4.2).
    pub fn poll_add(&self, file: &mut DdsFile, group: &Arc<PollGroup>) {
        file.group = Some(group.clone());
    }

    /// `ReadFile`: non-blocking scattered/normal read (§4.2). Returns
    /// the request id for matching the completion.
    pub fn read_file(&self, file: &DdsFile, offset: u64, size: u32) -> Result<u64, LibError> {
        let group = file.group.as_ref().ok_or(LibError::NotInGroup)?;
        let id = group.next_id.fetch_add(1, Ordering::Relaxed);
        group.issue(
            FileRequest::read(id, file.id.0, offset, size),
            PendingOp { file_id: file.id, kind: FileOpKind::Read, scatter_sizes: Vec::new() },
        )
    }

    /// Scattered read: one file I/O whose payload is later split into
    /// the given destination sizes (§4.2 "scattered reads").
    pub fn scatter_read(
        &self,
        file: &DdsFile,
        offset: u64,
        sizes: &[u32],
    ) -> Result<u64, LibError> {
        let group = file.group.as_ref().ok_or(LibError::NotInGroup)?;
        let id = group.next_id.fetch_add(1, Ordering::Relaxed);
        let total: u32 = sizes.iter().sum();
        group.issue(
            FileRequest::read(id, file.id.0, offset, total),
            PendingOp {
                file_id: file.id,
                kind: FileOpKind::Read,
                scatter_sizes: sizes.to_vec(),
            },
        )
    }

    /// `WriteFile`: non-blocking write; the payload is inlined in the
    /// ring record so one DMA-read moves the whole request (Fig 9).
    pub fn write_file(&self, file: &DdsFile, offset: u64, data: &[u8]) -> Result<u64, LibError> {
        let group = file.group.as_ref().ok_or(LibError::NotInGroup)?;
        let id = group.next_id.fetch_add(1, Ordering::Relaxed);
        group.issue(
            FileRequest::write(id, file.id.0, offset, data.to_vec()),
            PendingOp { file_id: file.id, kind: FileOpKind::Write, scatter_sizes: Vec::new() },
        )
    }

    /// Gathered write: an array of source buffers written as one file
    /// I/O (§4.2 "gathered writes").
    pub fn gather_write(
        &self,
        file: &DdsFile,
        offset: u64,
        bufs: &[&[u8]],
    ) -> Result<u64, LibError> {
        let group = file.group.as_ref().ok_or(LibError::NotInGroup)?;
        let id = group.next_id.fetch_add(1, Ordering::Relaxed);
        let total: usize = bufs.iter().map(|b| b.len()).sum();
        let mut data = Vec::with_capacity(total);
        for b in bufs {
            data.extend_from_slice(b);
        }
        group.issue(
            FileRequest::write(id, file.id.0, offset, data),
            PendingOp { file_id: file.id, kind: FileOpKind::Write, scatter_sizes: Vec::new() },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A PollGroup over fresh rings with no service behind it (the
    /// drain-side machinery is all that is under test).
    fn lone_group() -> PollGroup {
        PollGroup {
            chan: Arc::new(GroupChannel {
                req_ring: ProgressRing::new(1 << 16, 1 << 12),
                resp_ring: ResponseRing::new(1 << 16),
                doorbell: Doorbell::new(),
                wake: Doorbell::new(),
            }),
            pending: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            bad_records: AtomicU64::new(0),
            orphans: AtomicU64::new(0),
        }
    }

    fn add_pending(g: &PollGroup, req_id: u64) {
        g.pending.lock().unwrap().insert(
            req_id,
            PendingOp { file_id: FileId(1), kind: FileOpKind::Read, scatter_sizes: Vec::new() },
        );
    }

    /// Regression (PR 5): a response that failed to decode used to be
    /// consumed silently, leaking its pending entry — `in_flight()`
    /// never reached 0 and every quiesce loop over it wedged. It must
    /// surface as an ERR completion for the salvaged request id.
    #[test]
    fn malformed_response_surfaces_err_and_unleaks_pending() {
        let g = lone_group();
        add_pending(&g, 7);
        assert_eq!(g.in_flight(), 1);
        // Corrupt status byte: full decode fails, but the fixed header
        // still carries the request id.
        let mut rec = FileResponse::encode_header(7, Status::Ok, 0).to_vec();
        rec[8] = 0xEE;
        assert_eq!(g.chan.resp_ring.push(&rec), RingStatus::Ok);
        let evs = g.poll_wait(Duration::ZERO);
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].req_id, 7);
        assert!(!evs[0].ok, "malformed response must surface as ERR");
        assert!(evs[0].data.is_empty());
        assert_eq!(g.in_flight(), 0, "pending entry leaked");
        assert_eq!(g.bad_records(), 1);
    }

    /// A record too short to even salvage an id still must not wedge
    /// quiesce: the oldest pending op is failed in its stead (delivery
    /// is in request order, so the mangled record almost surely
    /// belonged to the lowest outstanding id).
    #[test]
    fn truncated_response_fails_oldest_pending() {
        let g = lone_group();
        add_pending(&g, 9);
        add_pending(&g, 12);
        assert_eq!(g.chan.resp_ring.push(&[1, 2, 3]), RingStatus::Ok);
        let evs = g.poll_wait(Duration::ZERO);
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].req_id, 9, "oldest outstanding op takes the ERR");
        assert!(!evs[0].ok);
        assert_eq!(g.bad_records(), 1);
        assert_eq!(g.in_flight(), 1, "only the attributed op is failed");
        // The newer op is untouched and completes normally.
        let ok = FileResponse { req_id: 12, status: Status::Ok, data: Vec::new() };
        assert_eq!(g.chan.resp_ring.push(&ok.encode()), RingStatus::Ok);
        let evs = g.poll_wait(Duration::ZERO);
        assert!(evs.iter().any(|e| e.req_id == 12 && e.ok));
        assert_eq!(g.in_flight(), 0, "quiesce loop can always drain to zero");
    }

    /// A corrupted record whose intact header id matches nothing
    /// pending is a corrupted stale duplicate: dropped and counted
    /// like an intact orphan — it must NOT pull the oldest healthy op
    /// into a false ERR.
    #[test]
    fn corrupted_orphan_does_not_fail_healthy_ops() {
        let g = lone_group();
        add_pending(&g, 9);
        // req 5 is long done; its duplicate arrives with a corrupt
        // status byte but readable id.
        let mut rec = FileResponse::encode_header(5, Status::Ok, 0).to_vec();
        rec[8] = 0xEE;
        assert_eq!(g.chan.resp_ring.push(&rec), RingStatus::Ok);
        assert!(g.poll_wait(Duration::ZERO).is_empty(), "no ERR may be invented");
        assert_eq!(g.in_flight(), 1, "healthy op must stay pending");
        assert_eq!((g.bad_records(), g.orphan_responses()), (1, 1));
    }

    /// Regression (PR 5): a well-formed response matching nothing
    /// pending (stale duplicate) is dropped — but counted, never
    /// invisible.
    #[test]
    fn orphan_response_is_counted_not_invented() {
        let g = lone_group();
        let resp = FileResponse { req_id: 42, status: Status::Ok, data: vec![1, 2] };
        assert_eq!(g.chan.resp_ring.push(&resp.encode()), RingStatus::Ok);
        assert!(g.poll_wait(Duration::ZERO).is_empty());
        assert_eq!(g.orphan_responses(), 1);
        assert_eq!(g.in_flight(), 0);
    }

    /// Draining the response ring rings the service-side wake doorbell
    /// (the response-ring-full retry edge of the wake graph).
    #[test]
    fn drain_rings_service_wake() {
        let g = lone_group();
        let resp = FileResponse { req_id: 1, status: Status::Ok, data: Vec::new() };
        assert_eq!(g.chan.resp_ring.push(&resp.encode()), RingStatus::Ok);
        let seen = g.chan.wake.seq();
        let _ = g.poll_wait(Duration::ZERO);
        assert!(g.chan.wake.seq() > seen, "drain must ring the service wake");
    }
}
