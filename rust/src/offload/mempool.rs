//! Pre-allocated DMA-able buffer pool (§6.2, Fig 12).
//!
//! The offload engine reserves a pool of DMA-accessible huge pages at
//! startup; each offloaded read borrows a buffer sized for the read so
//! the SSD DMA lands directly where the packet payload will point —
//! no allocation and no copies on the data path.

use std::sync::{Arc, Mutex};

struct PoolInner {
    free: Vec<Vec<u8>>,
    buf_size: usize,
    total: usize,
    /// Stats: how many allocations were served from the free list.
    reuses: u64,
    allocs: u64,
}

/// Fixed-size-class buffer pool.
#[derive(Clone)]
pub struct MemPool {
    inner: Arc<Mutex<PoolInner>>,
}

/// A buffer borrowed from the pool; returns on drop.
pub struct PooledBuf {
    pool: MemPool,
    buf: Vec<u8>,
    len: usize,
}

impl MemPool {
    /// Pre-allocate `count` buffers of `buf_size` bytes each.
    pub fn new(count: usize, buf_size: usize) -> Self {
        let free = (0..count).map(|_| vec![0u8; buf_size]).collect();
        MemPool {
            inner: Arc::new(Mutex::new(PoolInner {
                free,
                buf_size,
                total: count,
                reuses: 0,
                allocs: 0,
            })),
        }
    }

    /// Borrow a buffer of at least `size` usable bytes. Returns `None`
    /// if `size` exceeds the pool's class (caller bounces to the host).
    pub fn allocate(&self, size: usize) -> Option<PooledBuf> {
        let mut inner = self.inner.lock().unwrap();
        if size > inner.buf_size {
            return None;
        }
        inner.allocs += 1;
        let buf = if let Some(b) = inner.free.pop() {
            inner.reuses += 1;
            b
        } else {
            // Pool exhausted: grow (counted so benches can verify the
            // steady state never hits this).
            inner.total += 1;
            let cap = inner.buf_size;
            vec![0u8; cap]
        };
        Some(PooledBuf { pool: self.clone(), buf, len: size })
    }

    /// (allocations, served-from-freelist) counters.
    pub fn stats(&self) -> (u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.allocs, g.reuses)
    }

    /// Buffers currently available.
    pub fn available(&self) -> usize {
        self.inner.lock().unwrap().free.len()
    }
}

impl PooledBuf {
    pub fn as_slice(&self) -> &[u8] {
        &self.buf[..self.len]
    }

    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        &mut self.buf[..self.len]
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Take the payload out, consuming the borrow **without returning
    /// the buffer to the pool** (used only by the copy-mode baseline in
    /// the zero-copy ablation).
    pub fn take_copy(&self) -> Vec<u8> {
        self.as_slice().to_vec()
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        let mut inner = self.pool.inner.lock().unwrap();
        let buf = std::mem::take(&mut self.buf);
        if inner.free.len() < inner.total {
            inner.free.push(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_after_drop() {
        let pool = MemPool::new(2, 1024);
        assert_eq!(pool.available(), 2);
        {
            let _a = pool.allocate(100).unwrap();
            let _b = pool.allocate(200).unwrap();
            assert_eq!(pool.available(), 0);
        }
        assert_eq!(pool.available(), 2);
        let (allocs, reuses) = pool.stats();
        assert_eq!(allocs, 2);
        assert_eq!(reuses, 2);
    }

    #[test]
    fn oversize_rejected() {
        let pool = MemPool::new(1, 512);
        assert!(pool.allocate(513).is_none());
        assert!(pool.allocate(512).is_some());
    }

    #[test]
    fn exhaustion_grows_and_counts() {
        let pool = MemPool::new(1, 64);
        let a = pool.allocate(64).unwrap();
        let b = pool.allocate(64).unwrap(); // grows
        drop(a);
        drop(b);
        let (allocs, reuses) = pool.stats();
        assert_eq!(allocs, 2);
        assert_eq!(reuses, 1);
        assert_eq!(pool.available(), 2);
    }

    #[test]
    fn buffer_len_tracks_request() {
        let pool = MemPool::new(1, 1024);
        let mut b = pool.allocate(10).unwrap();
        b.as_mut_slice().copy_from_slice(&[7; 10]);
        assert_eq!(b.len(), 10);
        assert_eq!(b.as_slice(), &[7; 10]);
    }
}
