//! Pre-allocated DMA-able buffer pool (§6.2, Fig 12) — superseded by
//! the repo-wide zero-copy buffer plane in [`crate::buf`].
//!
//! The original `MemPool` was private to the offload engine and its
//! borrows could be neither sliced nor shared, so every layer above the
//! engine still copied. [`crate::buf::BufPool`] generalizes it:
//! refcounted views ([`crate::buf::BufView`]), explicit
//! pool-exhaustion fallback to owned heap memory, and a per-pool copy
//! ledger. This module remains as an alias so `offload::MemPool` keeps
//! naming the engine's pool type.

pub use crate::buf::{BufPool as MemPool, BufView, PooledBuf};
