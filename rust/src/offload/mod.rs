//! DPU offloading (§6): the user-facing offload API and the execution
//! engine that runs offloaded reads on the DPU with zero copies.

pub mod api;
pub mod engine;
pub mod mempool;

pub use api::{NoOffload, OffloadLogic, RawFileOffload, ReadOp, RoutedReq, WriteOp};
pub use engine::{OffloadEngine, OffloadEngineConfig};
pub use mempool::MemPool;
