//! The offload execution engine (§6.2, Figs 12 & 13).
//!
//! Receives offloadable requests from the traffic director, translates
//! them to file reads with the user's `OffFunc`, executes them against
//! the DPU file system/SSD asynchronously, and emits client responses
//! **in request order** via a ring of contexts:
//!
//! * a context bookkeeps `{client (msg_id, idx), ReadOp, completion
//!   status, read buffer}` (Fig 13 lines 8-12);
//! * if the context ring is full the request — and the rest of the
//!   batch — bounces to the host (lines 5-7);
//! * completions are processed from the head and stop at the first
//!   pending context, enforcing ordered responses (lines 18-27).
//!
//! Zero-copy (Fig 12): read buffers come from the engine's
//! pre-allocated [`crate::buf::BufPool`] — the SSD completion *is* a
//! view of a pool slot, and that view is referenced through the context
//! ring into the client response without intermediate copies;
//! `copy_mode` adds the straw-man's extra copy for the §8.5 ablation
//! (Fig 23), metered on the pool's copy ledger.

use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use super::api::{OffloadLogic, RoutedReq};
use crate::buf::{BufPool, BufView, PooledBuf};
use crate::cache::{CuckooCache, FillTicket, Probe, ReadCacheTier};
use crate::dpufs::DpuFs;
use crate::proto::NetResp;
use crate::ssd::{AsyncSsd, Completion, SsdOp};

/// Completion status of a context (§6.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContextStatus {
    Pending,
    Complete,
    Failed,
}

struct Context {
    msg_id: u64,
    idx: u16,
    /// Multi-extent assembly buffer (pool-backed). Single-extent reads
    /// — the overwhelmingly common case — skip it: the pooled buffer
    /// the "device DMA" wrote is referenced straight into `payload`
    /// (perf pass L3-4: the staging copy was pure overhead; the
    /// completion buffer IS the pre-allocated read buffer of Fig 12).
    buf: Option<PooledBuf>,
    /// Zero-copy payload for the single-extent path: a view of the SSD
    /// completion buffer, carried by reference to the client response.
    payload: Option<BufView>,
    status: ContextStatus,
    extents_remaining: usize,
    /// Start position of each extent's bytes within `buf`.
    extent_offsets: Vec<usize>,
    /// Armed on a read-cache-tier miss (single-extent reads): the
    /// completion's pooled view fills the tier under this probe-time
    /// ticket (dropped if a WRITE invalidated the range in between).
    fill: Option<FillTicket>,
    /// When the context was booked — the reference point of the
    /// pending-timeout recovery (a lost SSD completion must surface as
    /// ERR, never as a stuck ring head).
    issued_at: Instant,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct OffloadEngineConfig {
    /// Context-ring capacity (outstanding offloaded reads).
    pub contexts: usize,
    /// Buffers in the mem pool (Fig 12 ①).
    pub pool_bufs: usize,
    /// Pool buffer size — also the largest offloadable read.
    pub pool_buf_size: usize,
    /// Straw-man mode with the extra data copy (Fig 23 ablation).
    pub copy_mode: bool,
    /// How long the ring head may sit pending before the engine gives
    /// up on its SSD completion and emits ERR (lost-completion
    /// recovery; ordered emission would otherwise stall forever).
    pub pending_timeout: Duration,
}

impl Default for OffloadEngineConfig {
    fn default() -> Self {
        OffloadEngineConfig {
            contexts: 256,
            pool_bufs: 256,
            pool_buf_size: 64 << 10,
            copy_mode: false,
            pending_timeout: Duration::from_secs(5),
        }
    }
}

impl OffloadEngineConfig {
    /// Floor for the per-shard context-ring / pool partitions, so a
    /// high shard count can't starve a shard below a useful batch.
    pub const MIN_PER_SHARD: usize = 8;

    /// Partition a whole-DPU configuration across `shards` engines.
    ///
    /// The context ring and the mem pool model fixed DPU resources
    /// (pinned DMA-able memory, §6.2), so N shards each get `1/N` of
    /// them rather than N copies of the whole budget; the buffer size
    /// class and copy-mode ablation flag apply to every shard alike.
    pub fn per_shard(&self, shards: usize) -> OffloadEngineConfig {
        assert!(shards >= 1);
        OffloadEngineConfig {
            contexts: (self.contexts / shards).max(Self::MIN_PER_SHARD),
            pool_bufs: (self.pool_bufs / shards).max(Self::MIN_PER_SHARD),
            pool_buf_size: self.pool_buf_size,
            copy_mode: self.copy_mode,
            pending_timeout: self.pending_timeout,
        }
    }
}

/// The offload engine. Single-threaded by design — it colocates with
/// the traffic director on one DPU core (§7 "Resource utilization").
pub struct OffloadEngine {
    logic: Arc<dyn OffloadLogic>,
    cache: Arc<CuckooCache>,
    dpufs: Arc<RwLock<DpuFs>>,
    aio: AsyncSsd,
    pool: BufPool,
    pool_buf_size: usize,
    ring: Vec<Option<Context>>,
    head: u64,
    tail: u64,
    /// The colocated read-cache tier, if attached (shared with the
    /// file service — one tier per server). Single-extent offloaded
    /// reads probe it before touching the SSD; a hit books a context
    /// that is Complete on arrival, payload = the cached view.
    tier: Option<Arc<ReadCacheTier>>,
    copy_mode: bool,
    pending_timeout: Duration,
    /// Failure-injected state: a failed engine accepts nothing — every
    /// request bounces to the host slow path (the paper's fallback) and
    /// in-flight contexts are aborted as ERR.
    failed: bool,
    /// Stats.
    pub offloaded: u64,
    pub bounced_full: u64,
    pub bounced_untranslatable: u64,
    /// Requests bounced because the engine was marked failed.
    pub bounced_engine_failed: u64,
    /// Contexts aborted by the pending-timeout (lost completions).
    pub timed_out: u64,
    /// Reused burst buffers (batch pipeline): per-extent ops staged and
    /// submitted as one batch per request, completions polled into a
    /// caller-owned buffer — steady state allocates nothing.
    submit_buf: Vec<(u64, SsdOp)>,
    comp_buf: Vec<Completion>,
}

impl OffloadEngine {
    pub fn new(
        logic: Arc<dyn OffloadLogic>,
        cache: Arc<CuckooCache>,
        dpufs: Arc<RwLock<DpuFs>>,
        aio: AsyncSsd,
        cfg: OffloadEngineConfig,
    ) -> Self {
        let mut ring = Vec::with_capacity(cfg.contexts);
        ring.resize_with(cfg.contexts, || None);
        let pool = BufPool::new(cfg.pool_bufs, cfg.pool_buf_size);
        // The SSD "DMA" lands directly in this engine's pool (Fig 12 ①):
        // completions arrive as views of pre-allocated slots.
        aio.attach_read_pool(pool.clone());
        OffloadEngine {
            logic,
            cache,
            dpufs,
            aio,
            pool,
            pool_buf_size: cfg.pool_buf_size,
            ring,
            head: 0,
            tail: 0,
            tier: None,
            copy_mode: cfg.copy_mode,
            pending_timeout: cfg.pending_timeout,
            failed: false,
            offloaded: 0,
            bounced_full: 0,
            bounced_untranslatable: 0,
            bounced_engine_failed: 0,
            timed_out: 0,
            submit_buf: Vec::new(),
            comp_buf: Vec::new(),
        }
    }

    /// Attach the server's read-cache tier (shared with the file
    /// service — DPU memory is one resource). Opt-in: an engine with
    /// no tier behaves exactly as before, so the steady-state
    /// zero-copy contract of the pool path is unchanged.
    pub fn attach_tier(&mut self, tier: Arc<ReadCacheTier>) {
        self.tier = Some(tier);
    }

    /// The attached read-cache tier, if any.
    pub fn tier(&self) -> Option<&Arc<ReadCacheTier>> {
        self.tier.as_ref()
    }

    /// Inject or clear engine failure. Failing aborts every in-flight
    /// context (emitted as ERR by the next `complete_pending`), and all
    /// subsequent requests bounce to the host until restored.
    pub fn set_failed(&mut self, failed: bool) {
        if self.failed == failed {
            return;
        }
        self.failed = failed;
        if failed {
            for idx in self.head..self.tail {
                let slot = (idx % self.cap()) as usize;
                if let Some(ctx) = self.ring[slot].as_mut() {
                    ctx.status = ContextStatus::Failed;
                }
            }
        }
    }

    pub fn is_failed(&self) -> bool {
        self.failed
    }

    fn cap(&self) -> u64 {
        self.ring.len() as u64
    }

    /// Fig 13 main loop body for one batch of requests from the traffic
    /// director. Returns `(responses, host_bounces)` — responses emitted
    /// by completions processed this round, plus any requests that must
    /// go to the host instead.
    pub fn execute(
        &mut self,
        reqs: Vec<RoutedReq>,
        responses: &mut Vec<NetResp>,
    ) -> Vec<RoutedReq> {
        if self.failed {
            // Whole-engine failure (§ fault plane): drain whatever the
            // ring still owes, then route the entire batch to the host
            // slow path — the client must see no difference beyond
            // latency.
            self.complete_pending(responses);
            self.bounced_engine_failed += reqs.len() as u64;
            return reqs;
        }
        let mut bounced = Vec::new();
        let mut reqs = reqs.into_iter();
        while let Some(routed) = reqs.next() {
            // Fig 13 line 4: make room by processing completions first.
            self.complete_pending(responses);
            // Lines 5-7: ring full → current and remaining requests go
            // to the host.
            if self.tail - self.head >= self.cap() {
                self.bounced_full += 1;
                bounced.push(routed);
                bounced.extend(reqs);
                break;
            }
            // Line 8: OffFunc.
            let Some(op) = self.logic.off_func(&routed.req, &self.cache) else {
                self.bounced_untranslatable += 1;
                bounced.push(routed);
                continue;
            };
            // Map through the file system; per-extent SSD reads with the
            // context index as the completion tag.
            let extents = {
                let fs = self.dpufs.read().unwrap();
                match fs.map_extents(op.file_id, op.offset, op.size as u64) {
                    Ok(e) => e,
                    Err(_) => {
                        self.bounced_untranslatable += 1;
                        bounced.push(routed);
                        continue;
                    }
                }
            };
            // Oversize requests bounce: the pool size class is the
            // largest offloadable read (Fig 12).
            if op.size as usize > self.pool_buf_size() {
                self.bounced_untranslatable += 1;
                bounced.push(routed);
                continue;
            }
            // Colocated read-cache tier probe (single-extent reads —
            // the common case; multi-extent payloads are gathered
            // copies, not cacheable views). A hit books a context that
            // is Complete on arrival: the cached view IS the payload
            // and the SSD is never touched. A miss arms a probe-time
            // fill ticket; the completion's pooled view fills the tier
            // unless an invalidation intervened.
            let mut tier_hit = None;
            let mut tier_fill = None;
            if extents.len() == 1 {
                if let Some(tier) = &self.tier {
                    match tier.probe(op.file_id.0 as u64, op.offset, op.size as u64) {
                        Probe::Hit(view) => tier_hit = Some(view),
                        Probe::Miss(ticket) => tier_fill = Some(ticket),
                    }
                }
            }
            // Line 9: pre-allocated read buffer — only needed for
            // multi-extent assembly; single-extent reads use the
            // completion buffer directly (see Context docs). Under pool
            // exhaustion the allocation falls back to owned heap memory
            // (counted on the ledger) instead of bouncing.
            let buf = if extents.len() > 1 && tier_hit.is_none() {
                Some(self.pool.allocate(op.size as usize))
            } else {
                None
            };
            // Lines 10-13: bookkeep in the context at tail, mark
            // pending, advance tail.
            let slot = (self.tail % self.cap()) as usize;
            let ctx_idx = self.tail;
            let mut extent_offsets = Vec::with_capacity(extents.len());
            let mut acc = 0usize;
            for e in &extents {
                extent_offsets.push(acc);
                acc += e.len as usize;
            }
            let hit = tier_hit.is_some();
            self.ring[slot] = Some(Context {
                msg_id: routed.msg_id,
                idx: routed.idx,
                buf,
                payload: tier_hit,
                status: if hit { ContextStatus::Complete } else { ContextStatus::Pending },
                extents_remaining: if hit { 0 } else { extents.len() },
                extent_offsets,
                fill: tier_fill,
                issued_at: Instant::now(),
            });
            self.tail += 1;
            self.offloaded += 1;
            if hit {
                // Cache hit: nothing to submit — the context is already
                // Complete and emits (in order) on the next drain.
                continue;
            }
            // Line 14: submit to the file service (extent reads) — all
            // of a request's extents go down as one batch: one fault
            // decide pass, one channel send, one doorbell.
            for (ei, e) in extents.iter().enumerate() {
                let tag = ctx_idx << 16 | ei as u64;
                self.submit_buf
                    .push((tag, SsdOp::Read { addr: e.addr, len: e.len as usize }));
            }
            self.aio.submit_batch(&mut self.submit_buf);
        }
        // Line 16: keep draining completions.
        self.complete_pending(responses);
        bounced
    }

    /// Fig 13 `CompletePending()`: absorb SSD completions, then emit
    /// responses from the head of the context ring, stopping at the
    /// first still-pending context (ordering guarantee).
    pub fn complete_pending(&mut self, responses: &mut Vec<NetResp>) {
        // Absorb SSD completions into contexts — polled into the
        // reused buffer, so an idle pass costs a relaxed load and a
        // busy one reuses last round's capacity.
        let mut comps = std::mem::take(&mut self.comp_buf);
        self.aio.poll_into(&mut comps, usize::MAX.min(1 << 14));
        for c in comps.drain(..) {
            let ctx_idx = c.tag >> 16;
            let extent = (c.tag & 0xffff) as usize;
            if ctx_idx < self.head || ctx_idx >= self.tail {
                continue; // stale
            }
            let slot = (ctx_idx % self.cap()) as usize;
            let Some(ctx) = self.ring[slot].as_mut() else { continue };
            if c.result.is_err() {
                ctx.status = ContextStatus::Failed;
                ctx.extents_remaining = ctx.extents_remaining.saturating_sub(1);
                continue;
            }
            // Zero-copy: the SSD "DMA" landed in a pooled buffer
            // (Fig 12 ②) — referenced for single-extent reads, gathered
            // at the extent's recorded position otherwise (the gather is
            // a real software copy in this model, so it is metered).
            if let Some(buf) = ctx.buf.as_mut() {
                let start = ctx.extent_offsets.get(extent).copied().unwrap_or(0);
                let end = (start + c.data.len()).min(buf.len());
                if start < end {
                    buf.as_mut_slice()[start..end]
                        .copy_from_slice(&c.data[..end - start]);
                    self.pool.ledger().count_copy(end - start);
                }
            } else {
                // Single-extent miss with a tier attached: fill the tier
                // from the same pooled view that becomes the payload —
                // a refcount, not a copy. The probe-time ticket makes
                // the fill epoch-guarded: if a WRITE invalidated the
                // range since the probe, the fill is dropped.
                if let (Some(ticket), Some(tier)) = (ctx.fill.take(), self.tier.as_ref()) {
                    tier.fill(&ticket, &c.data);
                }
                ctx.payload = Some(c.data);
            }
            if ctx.status != ContextStatus::Failed {
                ctx.extents_remaining -= 1;
                if ctx.extents_remaining == 0 {
                    ctx.status = ContextStatus::Complete;
                }
            }
        }
        self.comp_buf = comps;
        // Emit in order from the head (Fig 13 lines 19-27). A head
        // context whose completion never arrived (dropped by a fault,
        // device gone) is aborted once it exceeds the pending timeout —
        // ordered emission must surface ERR, not a hang.
        while self.head < self.tail {
            let slot = (self.head % self.cap()) as usize;
            let done = match self.ring[slot].as_mut() {
                Some(ctx) => {
                    if ctx.status == ContextStatus::Pending
                        && ctx.issued_at.elapsed() >= self.pending_timeout
                    {
                        ctx.status = ContextStatus::Failed;
                        self.timed_out += 1;
                    }
                    ctx.status != ContextStatus::Pending
                }
                None => false,
            };
            if !done {
                break;
            }
            let ctx = self.ring[slot].take().unwrap();
            let payload = match ctx.status {
                ContextStatus::Complete => {
                    let base = match ctx.buf {
                        // Multi-extent: seal the assembly buffer into a
                        // view — a refcount, not a materialization.
                        Some(buf) => buf.freeze(),
                        // Single-extent zero-copy: the packet payload IS
                        // the read buffer (Fig 12 ③) — referenced, never
                        // duplicated.
                        None => ctx.payload.unwrap_or_else(BufView::empty),
                    };
                    if self.copy_mode {
                        // Straw-man ablation: the §6.2 extra copy
                        // (metered — this is what Fig 23 measures).
                        self.pool.ledger().count_heap_alloc();
                        self.pool.ledger().count_copy(base.len());
                        // LINT: copy-ok(deliberate ablation copy, metered above)
                        BufView::from_vec(base.to_vec())
                    } else {
                        base
                    }
                }
                _ => BufView::empty(),
            };
            responses.push(NetResp {
                msg_id: ctx.msg_id,
                idx: ctx.idx,
                status: if ctx.status == ContextStatus::Complete {
                    NetResp::OK
                } else {
                    NetResp::ERR
                },
                payload,
            });
            self.head += 1;
        }
    }

    fn pool_buf_size(&self) -> usize {
        self.pool_buf_size
    }

    /// The engine's buffer pool (read buffers + multi-extent assembly;
    /// its ledger is the copy meter of the offloaded read path).
    pub fn pool(&self) -> &BufPool {
        &self.pool
    }

    /// Outstanding offloaded reads.
    pub fn outstanding(&self) -> u64 {
        self.tail - self.head
    }

    /// The configured pending timeout (how long a lost completion may
    /// keep a context in flight before it aborts as ERR) — the bound
    /// shutdown drains wait against.
    pub fn pending_timeout(&self) -> std::time::Duration {
        self.pending_timeout
    }

    /// The engine's cache table handle (shared with director/service).
    pub fn cache(&self) -> &Arc<CuckooCache> {
        &self.cache
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpufs::{DpuFs, FsConfig};
    use crate::offload::api::RawFileOffload;
    use crate::proto::AppRequest;
    use crate::ssd::Ssd;

    fn setup(contexts: usize) -> (OffloadEngine, u32) {
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        let mut fs = DpuFs::format(ssd.clone(), FsConfig::default()).unwrap();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        let data: Vec<u8> = (0..1 << 20).map(|i| (i % 253) as u8).collect();
        fs.write(f, 0, &data).unwrap();
        let dpufs = Arc::new(RwLock::new(fs));
        let aio = AsyncSsd::new(ssd, 2);
        let engine = OffloadEngine::new(
            Arc::new(RawFileOffload),
            Arc::new(CuckooCache::new(1024)),
            dpufs,
            aio,
            OffloadEngineConfig { contexts, ..Default::default() },
        );
        (engine, f.0)
    }

    fn wait_responses(
        engine: &mut OffloadEngine,
        responses: &mut Vec<NetResp>,
        n: usize,
    ) {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while responses.len() < n {
            engine.complete_pending(responses);
            assert!(std::time::Instant::now() < deadline, "timed out");
            std::thread::yield_now();
        }
    }

    #[test]
    fn config_partitions_across_shards() {
        let total = OffloadEngineConfig { contexts: 256, pool_bufs: 64, ..Default::default() };
        let per = total.per_shard(4);
        assert_eq!(per.contexts, 64);
        assert_eq!(per.pool_bufs, 16);
        assert_eq!(per.pool_buf_size, total.pool_buf_size);
        assert_eq!(total.per_shard(1).contexts, 256);
        // Division never starves a shard below the floor.
        let tiny = total.per_shard(1000);
        assert_eq!(tiny.contexts, OffloadEngineConfig::MIN_PER_SHARD);
        assert_eq!(tiny.pool_bufs, OffloadEngineConfig::MIN_PER_SHARD);
    }

    #[test]
    fn offloaded_read_returns_correct_bytes() {
        let (mut engine, f) = setup(64);
        let mut responses = Vec::new();
        let reqs = vec![RoutedReq {
            msg_id: 1,
            idx: 0,
            req: AppRequest::Read { file_id: f, offset: 1000, size: 512 },
        }];
        let bounced = engine.execute(reqs, &mut responses);
        assert!(bounced.is_empty());
        wait_responses(&mut engine, &mut responses, 1);
        assert_eq!(responses[0].status, NetResp::OK);
        let expect: Vec<u8> = (1000..1512u64).map(|i| (i % 253) as u8).collect();
        assert_eq!(responses[0].payload, expect);
    }

    #[test]
    fn responses_preserve_request_order() {
        let (mut engine, f) = setup(128);
        let mut responses = Vec::new();
        let reqs: Vec<RoutedReq> = (0..64u16)
            .map(|i| RoutedReq {
                msg_id: 9,
                idx: i,
                req: AppRequest::Read {
                    file_id: f,
                    offset: (i as u64) * 777,
                    size: 256,
                },
            })
            .collect();
        let bounced = engine.execute(reqs, &mut responses);
        assert!(bounced.is_empty());
        wait_responses(&mut engine, &mut responses, 64);
        // Ordered emission despite out-of-order SSD completions.
        let idxs: Vec<u16> = responses.iter().map(|r| r.idx).collect();
        assert_eq!(idxs, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn full_ring_bounces_remainder_to_host() {
        let (mut engine, f) = setup(4);
        let mut responses = Vec::new();
        let reqs: Vec<RoutedReq> = (0..16u16)
            .map(|i| RoutedReq {
                msg_id: 1,
                idx: i,
                req: AppRequest::Read { file_id: f, offset: 0, size: 128 },
            })
            .collect();
        let bounced = engine.execute(reqs, &mut responses);
        // With a 4-slot ring and slow completion draining, at least one
        // request bounces once the ring is full; order preserved in the
        // bounce list.
        wait_responses(&mut engine, &mut responses, 16 - bounced.len());
        if !bounced.is_empty() {
            assert!(engine.bounced_full > 0);
            for w in bounced.windows(2) {
                assert!(w[0].idx < w[1].idx);
            }
        }
    }

    #[test]
    fn failed_engine_bounces_batch_and_aborts_in_flight() {
        use crate::fault::{FaultConfig, FaultPlane, FaultSite, SsdFaultConfig};
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        let mut fs = DpuFs::format(ssd.clone(), FsConfig::default()).unwrap();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        fs.write(f, 0, &vec![3u8; 4096]).unwrap();
        let f = f.0;
        // Drop the first request's completion so it is deterministically
        // still in flight when the engine dies.
        let plane = FaultPlane::new(FaultConfig {
            seed: 1,
            ssd: SsdFaultConfig { drop_p: 1.0, ..Default::default() },
            ..Default::default()
        });
        let inj = plane.ssd_injector(FaultSite::SsdQueue(0));
        let mut aio = AsyncSsd::new_inline(ssd);
        aio.attach_faults(inj.clone());
        plane.arm_ssd();
        let mut engine = OffloadEngine::new(
            Arc::new(RawFileOffload),
            Arc::new(CuckooCache::new(64)),
            Arc::new(RwLock::new(fs)),
            aio,
            OffloadEngineConfig::default(),
        );
        let mut responses = Vec::new();
        let bounced = engine.execute(
            vec![RoutedReq {
                msg_id: 1,
                idx: 0,
                req: AppRequest::Read { file_id: f, offset: 0, size: 128 },
            }],
            &mut responses,
        );
        assert!(bounced.is_empty());
        assert_eq!(engine.outstanding(), 1);
        engine.set_failed(true);
        assert!(engine.is_failed());
        // The whole next batch reroutes to the host, order preserved.
        let reqs: Vec<RoutedReq> = (0..4u16)
            .map(|i| RoutedReq {
                msg_id: 2,
                idx: i,
                req: AppRequest::Read { file_id: f, offset: 0, size: 128 },
            })
            .collect();
        let bounced = engine.execute(reqs.clone(), &mut responses);
        assert_eq!(bounced, reqs);
        assert_eq!(engine.bounced_engine_failed, 4);
        // The in-flight context was aborted as ERR (no hang).
        wait_responses(&mut engine, &mut responses, 1);
        let aborted: Vec<_> = responses.iter().filter(|r| r.msg_id == 1).collect();
        assert_eq!(aborted.len(), 1);
        assert_eq!(aborted[0].status, NetResp::ERR);
        assert_eq!(engine.outstanding(), 0);
        // Restoring the engine (faults gone) resumes offloading.
        inj.set_armed(false);
        engine.set_failed(false);
        let mut responses = Vec::new();
        let bounced = engine.execute(
            vec![RoutedReq {
                msg_id: 3,
                idx: 0,
                req: AppRequest::Read { file_id: f, offset: 512, size: 64 },
            }],
            &mut responses,
        );
        assert!(bounced.is_empty());
        wait_responses(&mut engine, &mut responses, 1);
        assert_eq!(responses[0].status, NetResp::OK);
    }

    #[test]
    fn lost_completion_times_out_as_err_not_hang() {
        use crate::fault::{FaultConfig, FaultPlane, FaultSite, SsdFaultConfig};
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        let mut fs = DpuFs::format(ssd.clone(), FsConfig::default()).unwrap();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        fs.write(f, 0, &vec![3u8; 4096]).unwrap();
        let plane = FaultPlane::new(FaultConfig {
            seed: 1,
            ssd: SsdFaultConfig { drop_p: 1.0, ..Default::default() },
            ..Default::default()
        });
        let mut aio = AsyncSsd::new_inline(ssd);
        aio.attach_faults(plane.ssd_injector(FaultSite::SsdQueue(0)));
        plane.arm_ssd();
        let mut engine = OffloadEngine::new(
            Arc::new(RawFileOffload),
            Arc::new(CuckooCache::new(64)),
            Arc::new(RwLock::new(fs)),
            aio,
            OffloadEngineConfig {
                pending_timeout: std::time::Duration::from_millis(50),
                ..Default::default()
            },
        );
        let mut responses = Vec::new();
        let bounced = engine.execute(
            vec![RoutedReq {
                msg_id: 9,
                idx: 0,
                req: AppRequest::Read { file_id: f.0, offset: 0, size: 512 },
            }],
            &mut responses,
        );
        assert!(bounced.is_empty());
        assert!(responses.is_empty(), "completion was dropped");
        wait_responses(&mut engine, &mut responses, 1);
        assert_eq!(responses[0].status, NetResp::ERR);
        assert!(responses[0].payload.is_empty());
        assert_eq!(engine.timed_out, 1);
        assert_eq!(engine.outstanding(), 0, "ring head advanced past the lost context");
    }

    /// The Fig 12 discipline, asserted: after warm-up, an offloaded
    /// single-extent read performs ZERO heap allocations and ZERO
    /// software copies — every buffer request is a pool hit and the
    /// completion view IS the response payload.
    #[test]
    fn steady_state_read_zero_allocs_zero_copies() {
        let (mut engine, f) = setup(128);
        let run = |engine: &mut OffloadEngine, base: u64, n: u16| {
            let mut responses = Vec::new();
            let reqs: Vec<RoutedReq> = (0..n)
                .map(|i| RoutedReq {
                    msg_id: base,
                    idx: i,
                    req: AppRequest::Read {
                        file_id: f,
                        offset: base + i as u64 * 600,
                        size: 512,
                    },
                })
                .collect();
            let bounced = engine.execute(reqs, &mut responses);
            assert!(bounced.is_empty());
            wait_responses(engine, &mut responses, n as usize);
            responses
        };
        // Warm-up: populates the pool's working set.
        let warm = run(&mut engine, 1, 16);
        drop(warm);
        let before = engine.pool().stats();
        let resps = run(&mut engine, 2, 64);
        let d = engine.pool().stats() - before;
        assert_eq!(d.allocs, 64, "one pooled read buffer per request");
        assert_eq!(d.pool_hits, 64, "every buffer request served from the slab");
        assert_eq!(d.fallbacks, 0, "steady state never falls back to the heap");
        assert_eq!(d.heap_allocs, 0, "zero heap allocations per request");
        assert_eq!(d.bytes_copied, 0, "zero bytes memcpy'd per request");
        // And the data is still right.
        let expect: Vec<u8> = (2..514u64).map(|i| (i % 253) as u8).collect();
        assert_eq!(resps[0].payload, expect);
        drop(resps);
        assert_eq!(engine.pool().in_use(), 0, "all slots home after responses drop");
    }

    #[test]
    fn copy_mode_meters_the_straw_man_copy() {
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        let mut fs = DpuFs::format(ssd.clone(), FsConfig::default()).unwrap();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        fs.write(f, 0, &vec![7u8; 1 << 20]).unwrap();
        let mut engine = OffloadEngine::new(
            Arc::new(RawFileOffload),
            Arc::new(CuckooCache::new(64)),
            Arc::new(RwLock::new(fs)),
            AsyncSsd::new_inline(ssd),
            OffloadEngineConfig { copy_mode: true, ..Default::default() },
        );
        let mut responses = Vec::new();
        let bounced = engine.execute(
            vec![RoutedReq {
                msg_id: 1,
                idx: 0,
                req: AppRequest::Read { file_id: f.0, offset: 0, size: 4096 },
            }],
            &mut responses,
        );
        assert!(bounced.is_empty());
        wait_responses(&mut engine, &mut responses, 1);
        assert_eq!(responses[0].payload, vec![7u8; 4096]);
        let s = engine.pool().stats();
        assert_eq!(s.heap_allocs, 1, "the straw-man's extra buffer");
        assert_eq!(s.bytes_copied, 4096, "the straw-man's extra copy");
    }

    #[test]
    fn untranslatable_bounces() {
        let (mut engine, _) = setup(8);
        let mut responses = Vec::new();
        let reqs = vec![RoutedReq {
            msg_id: 1,
            idx: 0,
            req: AppRequest::KvGet { key: 1 }, // RawFileOffload can't map it
        }];
        let bounced = engine.execute(reqs, &mut responses);
        assert_eq!(bounced.len(), 1);
        assert_eq!(engine.bounced_untranslatable, 1);
    }

    /// The colocated cache path: the first read of an extent fills the
    /// tier from its completion view; the second is served straight
    /// from DPU memory — no SSD round trip, no pool traffic, no copy.
    #[test]
    fn tier_hit_skips_the_ssd_and_allocates_nothing() {
        let (mut engine, f) = setup(64);
        let tier = Arc::new(ReadCacheTier::new(1 << 20));
        engine.attach_tier(tier.clone());
        let req = |i: u16| RoutedReq {
            msg_id: 1,
            idx: i,
            req: AppRequest::Read { file_id: f, offset: 4096, size: 512 },
        };
        let mut responses = Vec::new();
        let bounced = engine.execute(vec![req(0)], &mut responses);
        assert!(bounced.is_empty());
        wait_responses(&mut engine, &mut responses, 1);
        assert_eq!(responses[0].status, NetResp::OK);
        assert_eq!(tier.stats().misses, 1);
        assert_eq!(tier.stats().fills, 1, "completion view filled the tier");
        drop(responses);
        let before = engine.pool().stats();
        let mut responses = Vec::new();
        let bounced = engine.execute(vec![req(1)], &mut responses);
        assert!(bounced.is_empty());
        assert_eq!(responses.len(), 1, "hit completes without an SSD round trip");
        assert_eq!(responses[0].status, NetResp::OK);
        let expect: Vec<u8> = (4096..4608u64).map(|i| (i % 253) as u8).collect();
        assert_eq!(responses[0].payload, expect);
        let d = engine.pool().stats() - before;
        assert_eq!(d.allocs, 0, "hit path books no buffers");
        assert_eq!(d.bytes_copied, 0, "hit path copies nothing");
        let s = tier.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.bytes_served, 512);
    }

    #[test]
    fn out_of_range_read_bounces_not_crashes() {
        let (mut engine, f) = setup(8);
        let mut responses = Vec::new();
        let reqs = vec![RoutedReq {
            msg_id: 1,
            idx: 0,
            req: AppRequest::Read { file_id: f, offset: 1 << 40, size: 128 },
        }];
        let bounced = engine.execute(reqs, &mut responses);
        assert_eq!(bounced.len(), 1);
    }
}
