//! The DDS offload API (§6.1, Table 1).
//!
//! Users customize offloading by implementing [`OffloadLogic`], the
//! four functions of Table 1:
//!
//! | Function            | Return                 | Paper name  |
//! |---------------------|------------------------|-------------|
//! | offload predicate   | (HostReqs, DPUReqs)    | `OffPred`   |
//! | offload function    | `Option<ReadOp>`       | `OffFunc`   |
//! | cache-on-write      | items to insert        | `Cache`     |
//! | invalidate-on-read  | keys to remove         | `Invalidate`|
//!
//! `OffPred` splits a network message (which may batch many requests)
//! into a host list and a DPU list. `OffFunc` translates an offloadable
//! request into a concrete file read. `Cache`/`Invalidate` maintain the
//! DPU cache table as the host writes/reads files. Like the paper's
//! offload functions, implementations are expected to be small,
//! allocation-free and non-blocking — they run on the DPU packet path.

use crate::cache::{CacheItem, CuckooCache};
use crate::dpufs::FileId;
use crate::proto::{AppRequest, NetMsg};

/// A file read operation produced by `OffFunc`:
/// `ReadOp {FileId, Offset, Size}` (§6.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReadOp {
    pub file_id: FileId,
    pub offset: u64,
    pub size: u32,
}

/// A host file write, as seen by `Cache` (cache-on-write).
#[derive(Debug, Clone)]
pub struct WriteOp<'a> {
    pub file_id: FileId,
    pub offset: u64,
    pub data: &'a [u8],
}

/// One request routed by the offload predicate, tagged with its position
/// in the originating message so responses can be matched up.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoutedReq {
    pub msg_id: u64,
    pub idx: u16,
    pub req: AppRequest,
}

/// User-supplied offloading logic (Table 1).
pub trait OffloadLogic: Send + Sync {
    /// `OffPred(Msg, CacheTable)` → `(HostReqs, DPUReqs)`. Either list
    /// may be empty. Batched messages are split request by request.
    fn off_pred(
        &self,
        msg: &NetMsg,
        cache: &CuckooCache,
    ) -> (Vec<RoutedReq>, Vec<RoutedReq>);

    /// `OffFunc(Req, CacheTable)` → `ReadOp`. `None` means "cannot
    /// translate after all — bounce to the host".
    fn off_func(&self, req: &AppRequest, cache: &CuckooCache) -> Option<ReadOp>;

    /// `Cache(WriteOp)` → keys + items to insert on a host file write.
    fn cache(&self, _w: &WriteOp) -> Vec<(u64, CacheItem)> {
        Vec::new()
    }

    /// `Invalidate(ReadOp)` → keys to remove on a host file read.
    fn invalidate(&self, _r: &ReadOp) -> Vec<u64> {
        Vec::new()
    }
}

/// Offloading disabled: every request goes to the host (the baseline
/// configurations of §8).
pub struct NoOffload;

impl OffloadLogic for NoOffload {
    fn off_pred(&self, msg: &NetMsg, _cache: &CuckooCache) -> (Vec<RoutedReq>, Vec<RoutedReq>) {
        let host = msg
            .requests
            .iter()
            .enumerate()
            .map(|(i, r)| RoutedReq { msg_id: msg.msg_id, idx: i as u16, req: r.clone() })
            .collect();
        (host, Vec::new())
    }

    fn off_func(&self, _req: &AppRequest, _cache: &CuckooCache) -> Option<ReadOp> {
        None
    }
}

/// The benchmark application's logic (§8.2): the request itself encodes
/// file id, offset and size, so reads offload unconditionally and
/// writes go to the host — "a 30-line OffloadPred and a 20-line
/// OffloadFunc", with `Cache`/`Invalidate` not needed.
pub struct RawFileOffload;

impl OffloadLogic for RawFileOffload {
    fn off_pred(&self, msg: &NetMsg, _cache: &CuckooCache) -> (Vec<RoutedReq>, Vec<RoutedReq>) {
        let mut host = Vec::new();
        let mut dpu = Vec::new();
        for (i, r) in msg.requests.iter().enumerate() {
            let routed = RoutedReq { msg_id: msg.msg_id, idx: i as u16, req: r.clone() };
            match r {
                AppRequest::Read { .. } => dpu.push(routed),
                _ => host.push(routed),
            }
        }
        (host, dpu)
    }

    fn off_func(&self, req: &AppRequest, _cache: &CuckooCache) -> Option<ReadOp> {
        match req {
            AppRequest::Read { file_id, offset, size } => {
                Some(ReadOp { file_id: FileId(*file_id), offset: *offset, size: *size })
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg() -> NetMsg {
        NetMsg {
            msg_id: 1,
            requests: vec![
                AppRequest::Read { file_id: 1, offset: 0, size: 512 },
                AppRequest::Write { file_id: 1, offset: 0, data: vec![0; 8] },
                AppRequest::Read { file_id: 2, offset: 1024, size: 256 },
            ],
        }
    }

    #[test]
    fn no_offload_sends_everything_to_host() {
        let cache = CuckooCache::new(16);
        let (host, dpu) = NoOffload.off_pred(&msg(), &cache);
        assert_eq!(host.len(), 3);
        assert!(dpu.is_empty());
        assert_eq!(host[2].idx, 2);
    }

    #[test]
    fn raw_offload_splits_reads_from_writes() {
        let cache = CuckooCache::new(16);
        let (host, dpu) = RawFileOffload.off_pred(&msg(), &cache);
        assert_eq!(host.len(), 1);
        assert_eq!(dpu.len(), 2);
        assert!(matches!(host[0].req, AppRequest::Write { .. }));
        // Positions inside the message are preserved for response
        // matching.
        assert_eq!(dpu[0].idx, 0);
        assert_eq!(dpu[1].idx, 2);
    }

    #[test]
    fn raw_off_func_translates_directly() {
        let cache = CuckooCache::new(16);
        let op = RawFileOffload
            .off_func(&AppRequest::Read { file_id: 3, offset: 64, size: 128 }, &cache)
            .unwrap();
        assert_eq!(op, ReadOp { file_id: FileId(3), offset: 64, size: 128 });
        assert!(RawFileOffload
            .off_func(&AppRequest::KvUpsert { key: 1, value: vec![] }, &cache)
            .is_none());
    }
}
