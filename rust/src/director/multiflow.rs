//! Multi-flow, multi-core traffic direction (§7).
//!
//! A real storage server terminates many client connections at once —
//! the §8.1 client's third load knob is "the number of concurrent
//! connections". [`MultiFlowDirector`] is the single-threaded core
//! array: it owns one [`DirectorShard`] per DPU core (each with its own
//! per-flow PEPs *and* its own colocated offload engine, §7) and steers
//! every packet with the symmetric RSS hash so a core never touches
//! another core's connection state.
//!
//! This type drives all shards from one thread (benches, tests, the
//! deterministic examples). The threaded deployment — one OS thread per
//! shard — is [`crate::coordinator::ShardedServer`], which owns its
//! `DirectorShard`s directly.

use std::sync::Arc;

use super::rss::rss_core;
use super::shard::{DirectorShard, DirectorShardStats};
use super::{AppSignature, DirectorOut};
use crate::cache::CuckooCache;
use crate::net::tcp::Segment;
use crate::net::FiveTuple;
use crate::offload::{OffloadEngine, OffloadLogic};

/// Director array across DPU cores; one shard (director + engine) per
/// core.
pub struct MultiFlowDirector {
    shards: Vec<DirectorShard>,
}

impl MultiFlowDirector {
    /// One shard per engine; `engines[i]` becomes the engine colocated
    /// with core `i`.
    pub fn new(
        signature: AppSignature,
        logic: Arc<dyn OffloadLogic>,
        cache: Arc<CuckooCache>,
        engines: Vec<OffloadEngine>,
    ) -> Self {
        assert!(!engines.is_empty(), "at least one core");
        MultiFlowDirector {
            shards: engines
                .into_iter()
                .enumerate()
                .map(|(id, engine)| {
                    DirectorShard::new(id, signature, logic.clone(), cache.clone(), engine)
                })
                .collect(),
        }
    }

    /// Install one tenant QoS configuration on every core (call before
    /// traffic; per-shard caps apply per core).
    pub fn configure_tenants(&mut self, cfg: super::tenant::TenantPlaneConfig) {
        for shard in &mut self.shards {
            shard.configure_tenants(cfg.clone());
        }
    }

    /// Run one idle-flow sweep increment on every core; returns flows
    /// reclaimed.
    pub fn evict_idle_flows(&mut self, now: std::time::Instant, max_scan: usize) -> usize {
        self.shards.iter_mut().map(|s| s.evict_idle_flows(now, max_scan).len()).sum()
    }

    /// Per-tenant counters merged across cores.
    pub fn tenant_stats(&self) -> Vec<crate::metrics::TenantCounters> {
        let tables: Vec<_> = self.shards.iter().map(|s| s.tenant_counters()).collect();
        crate::metrics::merge_tenant_tables(&tables)
    }

    /// Number of DPU cores configured.
    pub fn num_cores(&self) -> usize {
        self.shards.len()
    }

    /// RSS core for a tuple (exposed for tests / client steering).
    pub fn core_of(&self, tuple: &FiveTuple) -> usize {
        rss_core(tuple, self.shards.len())
    }

    /// Ingress from the client NIC: steer to the flow's shard, create
    /// the PEP on first contact, process with that shard's engine.
    pub fn on_client_packets(&mut self, tuple: &FiveTuple, segs: Vec<Segment>) -> DirectorOut {
        let core = self.core_of(tuple);
        self.shards[core].on_client_packets(tuple, segs)
    }

    /// Host-side packets for one flow's split connection.
    pub fn on_host_packets(&mut self, tuple: &FiveTuple, segs: Vec<Segment>) -> DirectorOut {
        let core = self.core_of(tuple);
        self.shards[core].on_host_packets(tuple, segs)
    }

    /// Drain late engine completions for every flow on every core.
    pub fn pump_completions(&mut self) -> Vec<(FiveTuple, DirectorOut)> {
        let mut outs = Vec::new();
        for shard in &mut self.shards {
            outs.extend(shard.pump_completions());
        }
        outs
    }

    /// Direct access to one core's shard.
    pub fn shard(&self, core: usize) -> &DirectorShard {
        &self.shards[core]
    }

    pub fn shard_mut(&mut self, core: usize) -> &mut DirectorShard {
        &mut self.shards[core]
    }

    /// Flow count per core (load-balance introspection).
    pub fn flows_per_core(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.num_flows()).collect()
    }

    /// Total flows created across cores.
    pub fn flows_created(&self) -> u64 {
        self.shards.iter().map(|s| s.stats().flows_created).sum()
    }

    /// Total stage-1 misses forwarded verbatim.
    pub fn forwarded_packets(&self) -> u64 {
        self.shards.iter().map(|s| s.stats().forwarded_packets).sum()
    }

    /// Aggregate counters across all cores.
    pub fn stats(&self) -> DirectorShardStats {
        let mut acc = DirectorShardStats::default();
        for s in &self.shards {
            acc = acc.merge(&s.stats());
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpufs::{DpuFs, FsConfig};
    use crate::offload::{NoOffload, OffloadEngineConfig};
    use crate::ssd::{AsyncSsd, Ssd};
    use std::sync::RwLock;

    fn engines(cores: usize) -> Vec<OffloadEngine> {
        let cache = Arc::new(CuckooCache::new(16));
        (0..cores)
            .map(|_| {
                let ssd = Arc::new(Ssd::new(4 << 20, 512));
                let fs = DpuFs::format(ssd.clone(), FsConfig::default()).unwrap();
                OffloadEngine::new(
                    Arc::new(NoOffload),
                    cache.clone(),
                    Arc::new(RwLock::new(fs)),
                    AsyncSsd::new_inline(ssd),
                    OffloadEngineConfig::default(),
                )
            })
            .collect()
    }

    fn mfd(cores: usize) -> MultiFlowDirector {
        MultiFlowDirector::new(
            AppSignature::server_port(5000),
            Arc::new(NoOffload),
            Arc::new(CuckooCache::new(64)),
            engines(cores),
        )
    }

    #[test]
    fn flows_steered_consistently() {
        let d = mfd(4);
        for i in 0..100u32 {
            let t = FiveTuple::new(0x0a000000 + i, 40000 + i as u16, 0x0a0000ff, 5000);
            let c = d.core_of(&t);
            assert!(c < 4);
            assert_eq!(c, d.core_of(&t), "steering must be stable");
        }
    }

    #[test]
    fn non_matching_flows_forwarded_without_flow_state() {
        let mut d = mfd(2);
        let other = FiveTuple::new(1, 2, 3, 9999);
        let seg = Segment { seq: 0, payload: vec![1, 2, 3].into(), ack: 0 };
        let out = d.on_client_packets(&other, vec![seg]);
        assert_eq!(out.forwarded, 1);
        assert_eq!(out.to_host.len(), 1);
        assert_eq!(d.flows_created(), 0, "no PEP state for uninteresting flows");
        assert_eq!(d.forwarded_packets(), 1);
    }

    #[test]
    fn flow_created_once_per_tuple_on_its_core() {
        let mut d = mfd(2);
        let t = FiveTuple::new(10, 20, 30, 5000);
        for _ in 0..5 {
            let seg = Segment { seq: 0, payload: crate::buf::BufView::empty(), ack: 0 };
            d.on_client_packets(&t, vec![seg]);
        }
        assert_eq!(d.flows_created(), 1);
        assert_eq!(d.flows_per_core().iter().sum::<usize>(), 1);
        // The flow lives on exactly the RSS core.
        assert_eq!(d.flows_per_core()[d.core_of(&t)], 1);
    }

    #[test]
    fn stats_aggregate_across_cores() {
        let mut d = mfd(3);
        for i in 0..12u32 {
            let t = FiveTuple::new(100 + i, 200, 300, 5000);
            let seg = Segment { seq: 0, payload: crate::buf::BufView::empty(), ack: 0 };
            d.on_client_packets(&t, vec![seg]);
        }
        let st = d.stats();
        assert_eq!(st.flows_created, 12);
        assert_eq!(st.flows, 12);
        assert_eq!(
            d.flows_per_core().iter().sum::<usize>(),
            12,
            "every flow landed on some core"
        );
    }
}
