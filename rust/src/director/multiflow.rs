//! Multi-flow traffic direction (§7).
//!
//! A real storage server terminates many client connections at once —
//! the §8.1 client's third load knob is "the number of concurrent
//! connections". [`MultiFlowDirector`] owns one PEP
//! ([`TrafficDirector`]) per matching flow, created on first packet,
//! and steers each flow to a DPU core with the symmetric RSS hash so
//! a core never touches another core's connection state (§7: "avoids
//! sharing connection states between cores on the DPU").
//!
//! The offload engine is per-core too (one engine colocated with each
//! director core, §7), so the whole packet path is share-nothing
//! across cores.

use std::collections::HashMap;
use std::sync::Arc;

use super::rss::rss_core;
use super::{AppSignature, DirectorOut, TrafficDirector};
use crate::cache::CuckooCache;
use crate::net::tcp::Segment;
use crate::net::FiveTuple;
use crate::offload::OffloadEngine;
use crate::offload::OffloadLogic;

/// Per-core state: the flows steered to this core.
struct CoreState {
    flows: HashMap<FiveTuple, TrafficDirector>,
}

/// Director array across DPU cores.
pub struct MultiFlowDirector {
    signature: AppSignature,
    logic: Arc<dyn OffloadLogic>,
    cache: Arc<CuckooCache>,
    cores: Vec<CoreState>,
    /// Stats.
    pub flows_created: u64,
    pub forwarded_packets: u64,
}

impl MultiFlowDirector {
    pub fn new(
        signature: AppSignature,
        logic: Arc<dyn OffloadLogic>,
        cache: Arc<CuckooCache>,
        cores: usize,
    ) -> Self {
        assert!(cores >= 1);
        MultiFlowDirector {
            signature,
            logic,
            cache,
            cores: (0..cores).map(|_| CoreState { flows: HashMap::new() }).collect(),
            flows_created: 0,
            forwarded_packets: 0,
        }
    }

    /// Number of DPU cores configured.
    pub fn num_cores(&self) -> usize {
        self.cores.len()
    }

    /// RSS core for a tuple (exposed for tests / engines-per-core
    /// wiring).
    pub fn core_of(&self, tuple: &FiveTuple) -> usize {
        rss_core(tuple, self.cores.len())
    }

    /// Ingress from the client NIC: steer to the flow's core, create
    /// the PEP on first contact, process. `engines[core_of(tuple)]`
    /// must be the engine colocated with that core.
    pub fn on_client_packets(
        &mut self,
        tuple: &FiveTuple,
        segs: Vec<Segment>,
        engines: &mut [OffloadEngine],
    ) -> DirectorOut {
        assert_eq!(engines.len(), self.cores.len(), "one engine per core");
        if !self.signature.matches(tuple) {
            self.forwarded_packets += segs.len() as u64;
            return DirectorOut { to_host: segs, forwarded: 1, ..Default::default() };
        }
        let core = self.core_of(tuple);
        let dir = match self.cores[core].flows.entry(*tuple) {
            std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::hash_map::Entry::Vacant(e) => {
                self.flows_created += 1;
                e.insert(TrafficDirector::new(
                    self.signature,
                    self.logic.clone(),
                    self.cache.clone(),
                ))
            }
        };
        dir.on_client_packets(tuple, segs, &mut engines[core])
    }

    /// Host-side packets for one flow's split connection.
    pub fn on_host_packets(&mut self, tuple: &FiveTuple, segs: Vec<Segment>) -> DirectorOut {
        let core = self.core_of(tuple);
        match self.cores[core].flows.get_mut(tuple) {
            Some(dir) => dir.on_host_packets(segs),
            None => DirectorOut::default(),
        }
    }

    /// Drain late engine completions for every flow on every core.
    pub fn pump_completions(&mut self, engines: &mut [OffloadEngine]) -> Vec<(FiveTuple, DirectorOut)> {
        let mut outs = Vec::new();
        for (core, state) in self.cores.iter_mut().enumerate() {
            for (tuple, dir) in state.flows.iter_mut() {
                let out = dir.pump_completions(&mut engines[core]);
                if !out.to_client.is_empty() || !out.to_host.is_empty() {
                    outs.push((*tuple, out));
                }
            }
        }
        outs
    }

    /// Flow count per core (load-balance introspection).
    pub fn flows_per_core(&self) -> Vec<usize> {
        self.cores.iter().map(|c| c.flows.len()).collect()
    }

    /// Aggregate director stats across flows: (msgs_in, offloaded,
    /// to_host).
    pub fn stats(&self) -> (u64, u64, u64) {
        let mut acc = (0, 0, 0);
        for c in &self.cores {
            for d in c.flows.values() {
                acc.0 += d.msgs_in;
                acc.1 += d.reqs_offloaded;
                acc.2 += d.reqs_to_host;
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offload::NoOffload;

    fn mfd(cores: usize) -> MultiFlowDirector {
        MultiFlowDirector::new(
            AppSignature::server_port(5000),
            Arc::new(NoOffload),
            Arc::new(CuckooCache::new(64)),
            cores,
        )
    }

    #[test]
    fn flows_steered_consistently() {
        let d = mfd(4);
        for i in 0..100u32 {
            let t = FiveTuple::new(0x0a000000 + i, 40000 + i as u16, 0x0a0000ff, 5000);
            let c = d.core_of(&t);
            assert!(c < 4);
            assert_eq!(c, d.core_of(&t), "steering must be stable");
        }
    }

    #[test]
    fn non_matching_flows_forwarded_without_flow_state() {
        let mut d = mfd(2);
        let mut engines = Vec::new(); // unused on forward path? we must pass correct len
        let cache = Arc::new(CuckooCache::new(16));
        let ssd = Arc::new(crate::ssd::Ssd::new(4 << 20, 512));
        let fs = crate::dpufs::DpuFs::format(ssd.clone(), Default::default()).unwrap();
        for _ in 0..2 {
            engines.push(OffloadEngine::new(
                Arc::new(NoOffload),
                cache.clone(),
                Arc::new(std::sync::RwLock::new(
                    crate::dpufs::DpuFs::format(
                        Arc::new(crate::ssd::Ssd::new(4 << 20, 512)),
                        Default::default(),
                    )
                    .unwrap(),
                )),
                crate::ssd::AsyncSsd::new_inline(ssd.clone()),
                Default::default(),
            ));
        }
        drop(fs);
        let other = FiveTuple::new(1, 2, 3, 9999);
        let seg = Segment { seq: 0, payload: vec![1, 2, 3], ack: 0 };
        let out = d.on_client_packets(&other, vec![seg], &mut engines);
        assert_eq!(out.forwarded, 1);
        assert_eq!(out.to_host.len(), 1);
        assert_eq!(d.flows_created, 0, "no PEP state for uninteresting flows");
        assert_eq!(d.forwarded_packets, 1);
    }

    #[test]
    fn flow_created_once_per_tuple() {
        let mut d = mfd(2);
        let cache = Arc::new(CuckooCache::new(16));
        let ssd = Arc::new(crate::ssd::Ssd::new(4 << 20, 512));
        let mut engines: Vec<OffloadEngine> = (0..2)
            .map(|_| {
                OffloadEngine::new(
                    Arc::new(NoOffload),
                    cache.clone(),
                    Arc::new(std::sync::RwLock::new(
                        crate::dpufs::DpuFs::format(
                            Arc::new(crate::ssd::Ssd::new(4 << 20, 512)),
                            Default::default(),
                        )
                        .unwrap(),
                    )),
                    crate::ssd::AsyncSsd::new_inline(ssd.clone()),
                    Default::default(),
                )
            })
            .collect();
        let t = FiveTuple::new(10, 20, 30, 5000);
        for _ in 0..5 {
            let seg = Segment { seq: 0, payload: Vec::new(), ack: 0 };
            d.on_client_packets(&t, vec![seg], &mut engines);
        }
        assert_eq!(d.flows_created, 1);
        assert_eq!(d.flows_per_core().iter().sum::<usize>(), 1);
    }
}
