//! Receive Side Scaling with a symmetric Toeplitz-style hash (§7).
//!
//! The paper: "Scaling up the traffic director to multiple Arm cores is
//! realized using RSS ... We carefully design the hash function for RSS
//! to achieve symmetric TCP splitting" — i.e. both directions of a
//! connection (and the response path of the split host connection) hash
//! to the same core, so no connection state is shared across cores.
//!
//! Symmetry is obtained the standard way: order-normalize the
//! (ip, port) endpoint pairs before hashing, so (A→B) and (B→A)
//! produce identical input bytes.

use crate::net::FiveTuple;

/// Toeplitz hash over `data` with a fixed 40-byte key (the Microsoft
/// RSS reference key).
pub fn toeplitz_hash(data: &[u8]) -> u32 {
    const KEY: [u8; 40] = [
        0x6d, 0x5a, 0x56, 0xda, 0x25, 0x5b, 0x0e, 0xc2, 0x41, 0x67, 0x25, 0x3d, 0x43, 0xa3,
        0x8f, 0xb0, 0xd0, 0xca, 0x2b, 0xcb, 0xae, 0x7b, 0x30, 0xb4, 0x77, 0xcb, 0x2d, 0xa3,
        0x80, 0x30, 0xf2, 0x0c, 0x6a, 0x42, 0xb7, 0x3b, 0xbe, 0xac, 0x01, 0xfa,
    ];
    let mut result: u32 = 0;
    // Sliding 32-bit window over the key, one shift per input bit.
    let mut window: u32 = u32::from_be_bytes([KEY[0], KEY[1], KEY[2], KEY[3]]);
    let mut next_key_bit = 32usize;
    for &byte in data {
        for bit in (0..8).rev() {
            if byte >> bit & 1 == 1 {
                result ^= window;
            }
            // Shift the window left by one key bit.
            let kb = if next_key_bit < KEY.len() * 8 {
                KEY[next_key_bit / 8] >> (7 - next_key_bit % 8) & 1
            } else {
                0
            };
            window = window << 1 | kb as u32;
            next_key_bit += 1;
        }
    }
    result
}

/// Map a flow to one of `cores` DPU cores, symmetrically.
pub fn rss_core(t: &FiveTuple, cores: usize) -> usize {
    assert!(cores > 0);
    // Normalize endpoint order for symmetry.
    let a = (t.client_ip, t.client_port);
    let b = (t.server_ip, t.server_port);
    let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
    let mut bytes = [0u8; 12];
    bytes[0..4].copy_from_slice(&lo.0.to_be_bytes());
    bytes[4..8].copy_from_slice(&hi.0.to_be_bytes());
    bytes[8..10].copy_from_slice(&lo.1.to_be_bytes());
    bytes[10..12].copy_from_slice(&hi.1.to_be_bytes());
    (toeplitz_hash(&bytes) as usize) % cores
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_both_directions() {
        for i in 0..200u32 {
            let fwd = FiveTuple::new(0x0a000001 + i, 4000 + i as u16, 0x0a0000ff, 5000);
            let rev = FiveTuple::new(0x0a0000ff, 5000, 0x0a000001 + i, 4000 + i as u16);
            assert_eq!(rss_core(&fwd, 8), rss_core(&rev, 8), "flow {i}");
        }
    }

    #[test]
    fn spreads_across_cores() {
        let cores = 8;
        let mut counts = vec![0usize; cores];
        for i in 0..4000u32 {
            let t = FiveTuple::new(0x0a000000 + i, (1000 + i * 7) as u16, 0x0a0000ff, 5000);
            counts[rss_core(&t, cores)] += 1;
        }
        for (c, &n) in counts.iter().enumerate() {
            assert!(n > 4000 / cores / 3, "core {c} starved: {n}");
        }
    }

    #[test]
    fn toeplitz_reference_vector() {
        // Verified property: deterministic, non-trivial.
        let h1 = toeplitz_hash(&[0x42; 12]);
        let h2 = toeplitz_hash(&[0x42; 12]);
        assert_eq!(h1, h2);
        assert_ne!(h1, toeplitz_hash(&[0x43; 12]));
    }
}
