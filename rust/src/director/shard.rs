//! One DPU core's share of the data plane (§7).
//!
//! A [`DirectorShard`] is the unit of scaling for the traffic director:
//! it owns the split-TCP state of every flow RSS steers to its core
//! *and* the offload engine colocated with that core, so nothing on the
//! packet path is shared between shards — the paper's "avoids sharing
//! connection states between cores on the DPU". The only cross-shard
//! structures are the read-mostly ones the design shares deliberately:
//! the cache table (§6.1), the file-system mapping, and the SSD device
//! behind each shard's private submission queue.
//!
//! Steering is the symmetric Toeplitz [`rss_core`] hash of the 5-tuple,
//! so both directions of a connection — and the split host connection —
//! land on the same shard (verified in `fig21_scaling.rs` and the
//! steering tests).
//!
//! ## The fanout plane
//!
//! Flows live in a readiness-driven [`FlowTable`] rather than a plain
//! map that gets walked per pump iteration. Client segments are staged
//! on their flow and the flow is pushed onto the ready ring;
//! [`DirectorShard::service_burst`] drains only the ring — with a
//! weighted-fair round-robin across tenants when more than one is
//! configured — so per-iteration work scales with *active* flows, not
//! open ones. Idle flows are reclaimed by an incremental TTL sweep
//! ([`DirectorShard::evict_idle_flows`]).
//!
//! Because every flow on the core shares ONE engine ring, completions
//! must be attributed back to the flow that submitted them. The engine
//! emits exactly one response per accepted context in strict ring
//! (submission) order, so a shard-level FIFO of slab indices — pushed
//! once per accepted context, popped once per emitted response — gives
//! exact attribution. (The previous per-flow pump framed *all* engine
//! completions onto whichever flow happened to poll first; with one
//! flow that is invisible, with 10k flows it cross-delivers responses
//! between connections whose clients reuse msg_ids.)

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use super::flowtable::{FlowTable, Readiness};
use super::rss::rss_core;
use super::tenant::{Quota, TenantPlane, TenantPlaneConfig};
use super::{AppSignature, DirectorOut, TrafficDirector};
use crate::cache::CuckooCache;
use crate::metrics::{LatencyHistogram, TenantCounters};
use crate::net::tcp::Segment;
use crate::net::FiveTuple;
use crate::offload::{OffloadEngine, OffloadLogic};
use crate::proto::NetResp;

/// Point-in-time counters of one shard (all monotonic).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DirectorShardStats {
    pub shard: usize,
    /// Live flows steered to this shard.
    pub flows: u64,
    pub flows_created: u64,
    /// Idle flows reclaimed by the TTL sweep.
    pub flows_closed: u64,
    pub msgs_in: u64,
    pub reqs_offloaded: u64,
    pub reqs_to_host: u64,
    /// Stage-1 misses forwarded verbatim (§5.1).
    pub forwarded_packets: u64,
    /// Requests rerouted to the host because the shard's engine was
    /// marked failed (fault plane).
    pub reqs_failed_over: u64,
    /// Engine contexts aborted by the pending-timeout (lost SSD
    /// completions surfaced as ERR).
    pub reqs_timed_out: u64,
}

impl DirectorShardStats {
    /// Element-wise sum (for aggregating across shards; `shard` keeps
    /// the left-hand side's id and is meaningless on aggregates).
    pub fn merge(&self, other: &DirectorShardStats) -> DirectorShardStats {
        DirectorShardStats {
            shard: self.shard,
            flows: self.flows + other.flows,
            flows_created: self.flows_created + other.flows_created,
            flows_closed: self.flows_closed + other.flows_closed,
            msgs_in: self.msgs_in + other.msgs_in,
            reqs_offloaded: self.reqs_offloaded + other.reqs_offloaded,
            reqs_to_host: self.reqs_to_host + other.reqs_to_host,
            forwarded_packets: self.forwarded_packets + other.forwarded_packets,
            reqs_failed_over: self.reqs_failed_over + other.reqs_failed_over,
            reqs_timed_out: self.reqs_timed_out + other.reqs_timed_out,
        }
    }
}

/// Reusable carrier for one input burst: every packet batch a shard
/// pump drained before servicing any of them. The pipeline stages
/// (drain → decode/service → host exchange → SSD → respond) each
/// process the whole carrier before handing it on, so per-record
/// bookkeeping — fault-flag sync, completion drains, stats publishes,
/// CpuLedger updates, output flushes — is paid once per burst. The
/// carrier is drained in place and its capacity survives across
/// bursts: steady-state pumping allocates nothing.
#[derive(Default)]
pub struct Burst {
    batches: Vec<(FiveTuple, Vec<Segment>)>,
}

impl Burst {
    pub fn with_capacity(cap: usize) -> Self {
        Burst { batches: Vec::with_capacity(cap) }
    }

    #[inline]
    pub fn push(&mut self, tuple: FiveTuple, segs: Vec<Segment>) {
        self.batches.push((tuple, segs));
    }

    pub fn len(&self) -> usize {
        self.batches.len()
    }

    pub fn is_empty(&self) -> bool {
        self.batches.is_empty()
    }
}

/// One core's traffic director + offload engine: per-flow PEPs created
/// on first packet, all state shard-local.
pub struct DirectorShard {
    id: usize,
    signature: AppSignature,
    logic: Arc<dyn OffloadLogic>,
    cache: Arc<CuckooCache>,
    engine: OffloadEngine,
    /// Readiness-driven flow table (slab + ready ring).
    table: FlowTable,
    /// Per-tenant QoS: token buckets, pending bounds, flow caps.
    plane: TenantPlane,
    /// Submission-order completion FIFO: one slab index per engine
    /// context accepted, popped once per response the engine emits.
    inflight: VecDeque<usize>,
    flows_created: u64,
    forwarded_packets: u64,
    /// Shard-level running sums of the per-flow counters, maintained
    /// incrementally so `stats()` is O(1) on the packet path (no
    /// per-call iteration over the flow table).
    agg_msgs_in: u64,
    agg_reqs_offloaded: u64,
    agg_reqs_to_host: u64,
    /// Shard-wide latency recorder, shared by every flow PEP on this
    /// shard (one writer thread — the shard pump — so the relaxed adds
    /// never bounce a cache line between cores). `None` until attached.
    lat: Option<Arc<LatencyHistogram>>,
    /// Scratch buffers: steady-state servicing allocates nothing.
    resp_scratch: Vec<NetResp>,
    outs_scratch: Vec<(FiveTuple, DirectorOut)>,
    /// Foreign-flow outputs produced on the single-batch path (engine
    /// completions for OTHER flows drained during a call that can only
    /// return one flow's output); delivered by the next completion
    /// pump.
    deferred: Vec<(FiveTuple, DirectorOut)>,
    /// Per-tenant drain queues for the weighted-fair scheduler
    /// (reused across bursts).
    fair_queues: Vec<VecDeque<usize>>,
}

impl DirectorShard {
    pub fn new(
        id: usize,
        signature: AppSignature,
        logic: Arc<dyn OffloadLogic>,
        cache: Arc<CuckooCache>,
        engine: OffloadEngine,
    ) -> Self {
        DirectorShard {
            id,
            signature,
            logic,
            cache,
            engine,
            table: FlowTable::new(),
            plane: TenantPlane::new(TenantPlaneConfig::default()),
            inflight: VecDeque::new(),
            flows_created: 0,
            forwarded_packets: 0,
            agg_msgs_in: 0,
            agg_reqs_offloaded: 0,
            agg_reqs_to_host: 0,
            lat: None,
            resp_scratch: Vec::new(),
            outs_scratch: Vec::new(),
            deferred: Vec::new(),
            fair_queues: Vec::new(),
        }
    }

    /// Install the tenant QoS configuration. Call before any traffic:
    /// the per-tenant counter table is rebuilt from scratch.
    pub fn configure_tenants(&mut self, cfg: TenantPlaneConfig) {
        debug_assert!(self.table.is_empty(), "configure_tenants after traffic started");
        self.plane = TenantPlane::new(cfg);
    }

    /// Attach the shard's service-latency recorder; propagated to every
    /// flow PEP (existing and future) so each admitted request is timed
    /// through to its client-bound response.
    pub fn attach_latency(&mut self, lat: Arc<LatencyHistogram>) {
        for slot in self.table.iter_mut() {
            slot.dir.attach_latency(lat.clone());
        }
        self.lat = Some(lat);
    }

    /// This shard's core index.
    pub fn id(&self) -> usize {
        self.id
    }

    /// First-stage signature match (§5.1).
    pub fn matches(&self, tuple: &FiveTuple) -> bool {
        self.signature.matches(tuple)
    }

    /// Whether RSS steers `tuple` to this shard in an `shards`-wide
    /// deployment (sanity check for steering layers above).
    pub fn owns(&self, tuple: &FiveTuple, shards: usize) -> bool {
        rss_core(tuple, shards) == self.id
    }

    /// Look up or create the slab slot for a matching flow. `None`
    /// means the shard is at its flow cap: the caller degrades the flow
    /// to the forwarded (un-accelerated) path instead of black-holing
    /// it.
    fn slot_for(&mut self, tuple: &FiveTuple) -> Option<usize> {
        if let Some(idx) = self.table.lookup(tuple) {
            return Some(idx);
        }
        let tenant = self.plane.tenant_of(tuple);
        if !self.plane.admit_flow(tenant, self.table.len()) {
            return None;
        }
        self.flows_created += 1;
        let mut dir = TrafficDirector::new(self.signature, self.logic.clone(), self.cache.clone());
        if let Some(lat) = &self.lat {
            dir.attach_latency(lat.clone());
        }
        Some(self.table.insert(*tuple, tenant, dir))
    }

    /// Ingress from the client NIC for a flow steered to this shard.
    /// Creates the flow's PEP on first contact; non-matching flows are
    /// forwarded verbatim without creating flow state.
    ///
    /// Single-batch path (tests, the unsharded server shim): services
    /// the flow immediately. Engine completions that belong to OTHER
    /// flows — drained opportunistically by the engine — cannot ride
    /// this call's return value; they are framed onto their own
    /// connections and parked in `deferred` until the next completion
    /// pump.
    pub fn on_client_packets(&mut self, tuple: &FiveTuple, segs: Vec<Segment>) -> DirectorOut {
        if !self.signature.matches(tuple) {
            // `forwarded` counts PACKETS, matching TrafficDirector.
            let n = segs.len() as u64;
            self.forwarded_packets += n;
            return DirectorOut { to_host: segs, forwarded: n, ..Default::default() };
        }
        let Some(idx) = self.slot_for(tuple) else {
            // Flow cap: degrade to the stage-1-miss path.
            let n = segs.len() as u64;
            self.forwarded_packets += n;
            return DirectorOut { to_host: segs, forwarded: n, ..Default::default() };
        };
        let mut collected = std::mem::take(&mut self.outs_scratch);
        self.service_slot(idx, segs, Instant::now(), &mut collected);
        let mut out = DirectorOut::default();
        for (t, o) in collected.drain(..) {
            if t == *tuple {
                out.to_client.extend(o.to_client);
                out.to_host.extend(o.to_host);
                out.forwarded += o.forwarded;
            } else {
                self.deferred.push((t, o));
            }
        }
        self.outs_scratch = collected;
        out
    }

    /// Service a whole [`Burst`] as a unit (decode/service stage of the
    /// batch pipeline). Two phases:
    ///
    /// 1. **Stage**: every batch is parked on its flow's slot and the
    ///    flow is marked ready (stage-1 misses and over-cap flows are
    ///    counted and forwarded outside the model, exactly like the
    ///    single-batch path — no PEP, no host connection, no state).
    /// 2. **Drain**: the ready ring is serviced — in arrival order for
    ///    a single tenant, weighted-fair round-robin across tenants
    ///    otherwise — so one chatty tenant cannot starve the others'
    ///    flows within a burst.
    ///
    /// Only matching flows emit entries into `outs` for the
    /// host-exchange stage. Drains the carrier in place, leaving its
    /// capacity.
    pub fn service_burst(
        &mut self,
        burst: &mut Burst,
        outs: &mut Vec<(FiveTuple, DirectorOut)>,
    ) {
        for (tuple, segs) in burst.batches.drain(..) {
            if !self.signature.matches(&tuple) {
                self.forwarded_packets += segs.len() as u64;
                continue;
            }
            let Some(idx) = self.slot_for(&tuple) else {
                self.forwarded_packets += segs.len() as u64;
                continue;
            };
            let slot = self.table.slot_mut(idx).expect("just resolved");
            if slot.staged.is_empty() {
                slot.staged = segs;
            } else {
                // Same flow appeared twice in one burst: append in
                // arrival order.
                slot.staged.extend(segs);
            }
            self.table.mark_ready(idx, Readiness::CLIENT);
        }
        self.drain_ready(outs);
    }

    /// Drain the ready ring (snapshot: flows that become ready while
    /// draining — e.g. via foreign completions — wait for the next
    /// burst, keeping one drain bounded).
    fn drain_ready(&mut self, outs: &mut Vec<(FiveTuple, DirectorOut)>) {
        let mut scheduled = self.table.ready_len();
        if scheduled == 0 {
            return;
        }
        // One clock read per drained burst: quota refill and activity
        // stamps all use the same instant.
        let now = Instant::now();
        if self.plane.config().tenants <= 1 {
            while scheduled > 0 {
                scheduled -= 1;
                let Some((idx, _bits)) = self.table.pop_ready() else { break };
                let segs = {
                    let slot = self.table.slot_mut(idx).expect("ready flow is live");
                    std::mem::take(&mut slot.staged)
                };
                if segs.is_empty() {
                    continue; // ENGINE/HOST wakeup: nothing staged.
                }
                self.service_slot(idx, segs, now, outs);
            }
            return;
        }
        // Multi-tenant: bucket the scheduled flows per tenant, then
        // serve `weight(t)` flows per tenant per round until dry.
        let mut queues = std::mem::take(&mut self.fair_queues);
        let tenants = self.plane.counters().len();
        if queues.len() < tenants {
            queues.resize_with(tenants, VecDeque::new);
        }
        while scheduled > 0 {
            scheduled -= 1;
            let Some((idx, _bits)) = self.table.pop_ready() else { break };
            let t = self.table.slot(idx).expect("ready flow is live").tenant as usize;
            queues[t].push_back(idx);
        }
        loop {
            let mut any = false;
            for t in 0..queues.len() {
                if queues[t].is_empty() {
                    continue;
                }
                let weight = self.plane.weight(t as u32);
                for _ in 0..weight {
                    let Some(idx) = queues[t].pop_front() else { break };
                    any = true;
                    let segs = {
                        let slot = self.table.slot_mut(idx).expect("ready flow is live");
                        std::mem::take(&mut slot.staged)
                    };
                    if segs.is_empty() {
                        continue;
                    }
                    self.service_slot(idx, segs, now, outs);
                }
            }
            if !any {
                break;
            }
        }
        self.fair_queues = queues;
    }

    /// Run one flow's staged segments through its PEP and the shared
    /// engine: ingest (with the tenant's admission quota), execute,
    /// forward, frame. Engine completions surfaced by the execute call
    /// are routed by the completion FIFO — they may belong to other
    /// flows and produce their own `outs` entries.
    fn service_slot(
        &mut self,
        idx: usize,
        segs: Vec<Segment>,
        now: Instant,
        outs: &mut Vec<(FiveTuple, DirectorOut)>,
    ) {
        let (tuple, tenant) = {
            let slot = self.table.slot(idx).expect("serviced slot is live");
            (slot.tuple, slot.tenant)
        };
        let quota = if self.plane.limited() {
            Some(self.plane.quota(tenant, now))
        } else {
            None
        };
        let mut out = DirectorOut::default();
        let slot = self.table.slot_mut(idx).expect("serviced slot is live");
        slot.last_active = now;
        let before = (slot.dir.msgs_in, slot.dir.reqs_offloaded, slot.dir.reqs_to_host);
        let ingest = slot.dir.ingest_client(segs, quota.map(|q| q.allow), &mut out);
        let admitted = (ingest.host_reqs.len() + ingest.dpu_reqs.len()) as u64;
        let rejected = ingest.rejected.len() as u64;
        slot.pending += admitted;
        // Execute on the shared engine with submission-order
        // attribution: the FIFO gains one entry per context the engine
        // actually accepted (bounces never enter the ring).
        let off_before = self.engine.offloaded;
        let mut resps = std::mem::take(&mut self.resp_scratch);
        let bounced = self.engine.execute(ingest.dpu_reqs, &mut resps);
        let accepted = (self.engine.offloaded - off_before) as usize;
        self.inflight.extend(std::iter::repeat(idx).take(accepted));
        let slot = self.table.slot_mut(idx).expect("serviced slot is live");
        let mut host_reqs = ingest.host_reqs;
        host_reqs.extend(bounced);
        slot.dir.forward_to_host(host_reqs, &mut out);
        slot.dir.frame_rejects(ingest.rejected, &mut out);
        // Fold this call's counter deltas into the shard-level sums.
        let after = (slot.dir.msgs_in, slot.dir.reqs_offloaded, slot.dir.reqs_to_host);
        self.agg_msgs_in += after.0 - before.0;
        self.agg_reqs_offloaded += after.1 - before.1;
        self.agg_reqs_to_host += after.2 - before.2;
        self.plane.settle(tenant, quota.unwrap_or_else(Quota::open), admitted, rejected);
        outs.push((tuple, out));
        // Responses the execute call surfaced: this flow's (inline
        // engines) and any earlier flow's late completions, in strict
        // submission order.
        self.route_responses(&mut resps, outs);
        self.resp_scratch = resps;
    }

    /// Attribute engine responses to their submitting flows via the
    /// completion FIFO and frame them on the right connections.
    fn route_responses(
        &mut self,
        resps: &mut Vec<NetResp>,
        outs: &mut Vec<(FiveTuple, DirectorOut)>,
    ) {
        if resps.is_empty() {
            return;
        }
        let mut cur: Option<usize> = None;
        let mut group: Vec<NetResp> = Vec::new();
        for resp in resps.drain(..) {
            let idx = self
                .inflight
                .pop_front()
                .expect("engine emitted more completions than submissions");
            if cur != Some(idx) {
                if let Some(prev) = cur {
                    self.flush_group(prev, &mut group, outs);
                }
                cur = Some(idx);
            }
            group.push(resp);
        }
        if let Some(prev) = cur {
            self.flush_group(prev, &mut group, outs);
        }
    }

    fn flush_group(
        &mut self,
        idx: usize,
        group: &mut Vec<NetResp>,
        outs: &mut Vec<(FiveTuple, DirectorOut)>,
    ) {
        let n = group.len() as u64;
        let slot = self
            .table
            .slot_mut(idx)
            .expect("completion for an evicted flow (eviction gate broken)");
        let tuple = slot.tuple;
        let tenant = slot.tenant;
        slot.pending = slot.pending.saturating_sub(n);
        let mut out = DirectorOut::default();
        slot.dir.frame_responses(std::mem::take(group), &mut out);
        self.plane.on_completed(tenant, n);
        // ENGINE readiness: refreshes the activity stamp and keeps the
        // flow visible to the scheduler (a cheap no-op pop if nothing
        // else arrives).
        self.table.mark_ready(idx, Readiness::ENGINE);
        outs.push((tuple, out));
    }

    /// Host-side packets of one flow's split connection. Responses the
    /// PEP frames here settle the flow's pending count and the tenant's
    /// pending gauge (the host leg of the completion accounting; the
    /// engine leg runs through the FIFO).
    pub fn on_host_packets(&mut self, tuple: &FiveTuple, segs: Vec<Segment>) -> DirectorOut {
        let Some(idx) = self.table.lookup(tuple) else {
            return DirectorOut::default();
        };
        let slot = self.table.slot_mut(idx).expect("looked up");
        let before = slot.dir.resps_out;
        let out = slot.dir.on_host_packets(segs);
        let n = slot.dir.resps_out - before;
        let tenant = slot.tenant;
        slot.pending = slot.pending.saturating_sub(n);
        if n > 0 {
            self.plane.on_completed(tenant, n);
        }
        self.table.mark_ready(idx, Readiness::HOST);
        out
    }

    /// Drain late engine completions. O(completions), not O(flows):
    /// the FIFO knows who submitted what, so quiet flows are never
    /// touched.
    pub fn pump_completions(&mut self) -> Vec<(FiveTuple, DirectorOut)> {
        let mut outs = Vec::new();
        self.pump_completions_into(&mut outs);
        outs
    }

    /// Buffer-reusing variant: appends `(tuple, out)` pairs to `outs`
    /// so the shard pump's steady-state completion drain allocates
    /// nothing. Also delivers foreign-flow outputs deferred by the
    /// single-batch path.
    pub fn pump_completions_into(&mut self, outs: &mut Vec<(FiveTuple, DirectorOut)>) {
        outs.append(&mut self.deferred);
        let mut resps = std::mem::take(&mut self.resp_scratch);
        self.engine.complete_pending(&mut resps);
        self.route_responses(&mut resps, outs);
        self.resp_scratch = resps;
    }

    /// Incremental idle-flow sweep: examine up to `max_scan` slots and
    /// evict flows idle past the tenant plane's TTL that have nothing
    /// pending anywhere. Returns the evicted tuples so the layer above
    /// can drop the matching host-connection state. Call from the
    /// pump's idle moments; the persistent cursor makes a 10k-flow
    /// table cost `max_scan` comparisons per call, not 10k.
    pub fn evict_idle_flows(&mut self, now: Instant, max_scan: usize) -> Vec<FiveTuple> {
        if self.table.is_empty() {
            return Vec::new();
        }
        let ttl = self.plane.flow_ttl();
        let evicted = self.table.evict_idle(now, ttl, max_scan);
        let mut tuples = Vec::with_capacity(evicted.len());
        for (tuple, tenant) in evicted {
            self.plane.flow_closed(tenant);
            tuples.push(tuple);
        }
        tuples
    }

    /// Per-tenant counter table (indexed by tenant id).
    pub fn tenant_counters(&self) -> Vec<TenantCounters> {
        // LINT: copy-ok(stats snapshot of u64 counter structs, not payload)
        self.plane.counters().to_vec()
    }

    /// Allocation-reusing variant for the pump's stats publish: clears
    /// `out` and copies the current table into it.
    pub fn publish_tenant_counters(&self, out: &mut Vec<TenantCounters>) {
        out.clear();
        // LINT: copy-ok(stats snapshot of u64 counter structs, not payload)
        out.extend_from_slice(self.plane.counters());
    }

    /// The engine colocated with this shard.
    pub fn engine(&self) -> &OffloadEngine {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut OffloadEngine {
        &mut self.engine
    }

    /// Inject or clear failure of this shard's engine (fault plane):
    /// failed engines route every request through the host slow path.
    pub fn set_engine_failed(&mut self, failed: bool) {
        self.engine.set_failed(failed);
    }

    pub fn engine_failed(&self) -> bool {
        self.engine.is_failed()
    }

    /// Live flow count.
    pub fn num_flows(&self) -> usize {
        self.table.len()
    }

    /// Counter snapshot. O(1): the per-flow counters are folded into
    /// shard-level sums as they advance, so this is safe to call on
    /// every packet batch.
    pub fn stats(&self) -> DirectorShardStats {
        DirectorShardStats {
            shard: self.id,
            flows: self.table.len() as u64,
            flows_created: self.flows_created,
            flows_closed: self.table.flows_closed,
            forwarded_packets: self.forwarded_packets,
            msgs_in: self.agg_msgs_in,
            reqs_offloaded: self.agg_reqs_offloaded,
            reqs_to_host: self.agg_reqs_to_host,
            reqs_failed_over: self.engine.bounced_engine_failed,
            reqs_timed_out: self.engine.timed_out,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpufs::{DpuFs, FsConfig};
    use crate::net::tcp::TcpEndpoint;
    use crate::offload::{NoOffload, OffloadEngineConfig};
    use crate::proto::{framing, AppRequest, NetMsg};
    use crate::ssd::{AsyncSsd, Ssd};
    use std::sync::RwLock;
    use std::time::Duration;

    fn shard(id: usize) -> DirectorShard {
        let ssd = Arc::new(Ssd::new(4 << 20, 512));
        let fs = DpuFs::format(ssd.clone(), FsConfig::default()).unwrap();
        let engine = OffloadEngine::new(
            Arc::new(NoOffload),
            Arc::new(CuckooCache::new(64)),
            Arc::new(RwLock::new(fs)),
            AsyncSsd::new_inline(ssd),
            OffloadEngineConfig::default(),
        );
        DirectorShard::new(
            id,
            AppSignature::server_port(5000),
            Arc::new(NoOffload),
            Arc::new(CuckooCache::new(64)),
            engine,
        )
    }

    /// Frame `msg` through a client-side TCP endpoint into wire
    /// segments.
    fn client_segs(client: &mut TcpEndpoint, msg: &NetMsg) -> Vec<Segment> {
        let mut stream = Vec::new();
        framing::write_frame(&mut stream, &msg.encode());
        client.send(&stream)
    }

    #[test]
    fn non_matching_forwarded_without_flow_state() {
        let mut s = shard(0);
        let other = FiveTuple::new(1, 2, 3, 9999);
        let seg = Segment { seq: 0, payload: vec![1, 2, 3].into(), ack: 0 };
        let out = s.on_client_packets(&other, vec![seg]);
        assert_eq!(out.forwarded, 1);
        assert_eq!(out.to_host.len(), 1);
        assert_eq!(s.num_flows(), 0, "no PEP state for uninteresting flows");
        assert_eq!(s.stats().forwarded_packets, 1);
    }

    #[test]
    fn flow_created_once_and_counted() {
        let mut s = shard(0);
        let t = FiveTuple::new(10, 20, 30, 5000);
        for _ in 0..5 {
            let seg = Segment { seq: 0, payload: crate::buf::BufView::empty(), ack: 0 };
            s.on_client_packets(&t, vec![seg]);
        }
        let st = s.stats();
        assert_eq!(st.flows_created, 1);
        assert_eq!(st.flows, 1);
        assert_eq!(s.num_flows(), 1);
    }

    #[test]
    fn ownership_follows_rss() {
        let shards = 4usize;
        let t = FiveTuple::new(0x0a000001, 41000, 0x0a0000ff, 5000);
        let core = rss_core(&t, shards);
        for id in 0..shards {
            let s = shard(id);
            assert_eq!(s.owns(&t, shards), id == core);
        }
    }

    #[test]
    fn idle_flows_evicted_and_counted() {
        let mut s = shard(0);
        s.configure_tenants(TenantPlaneConfig { flow_ttl_ms: 0, ..Default::default() });
        for port in 0..3u16 {
            let t = FiveTuple::new(10, 20 + port, 30, 5000);
            let seg = Segment { seq: 0, payload: crate::buf::BufView::empty(), ack: 0 };
            s.on_client_packets(&t, vec![seg]);
        }
        assert_eq!(s.num_flows(), 3);
        // All flows are quiescent (nothing admitted), so a zero TTL
        // reclaims every one of them; churned tables return to steady
        // state instead of growing without bound.
        let evicted = s.evict_idle_flows(Instant::now() + Duration::from_millis(1), 16);
        assert_eq!(evicted.len(), 3);
        assert_eq!(s.num_flows(), 0);
        let st = s.stats();
        assert_eq!(st.flows_closed, 3);
        assert_eq!(st.flows_created, 3, "creation history survives eviction");
        // Reconnecting after eviction builds fresh state.
        let t = FiveTuple::new(10, 20, 30, 5000);
        let seg = Segment { seq: 0, payload: crate::buf::BufView::empty(), ack: 0 };
        s.on_client_packets(&t, vec![seg]);
        assert_eq!(s.num_flows(), 1);
        assert_eq!(s.stats().flows_created, 4);
    }

    #[test]
    fn flow_cap_degrades_to_forwarding() {
        let mut s = shard(0);
        s.configure_tenants(TenantPlaneConfig { max_flows: 1, ..Default::default() });
        let t0 = FiveTuple::new(10, 1, 30, 5000);
        let t1 = FiveTuple::new(10, 2, 30, 5000);
        let seg = |b: &[u8]| Segment { seq: 0, payload: b.to_vec().into(), ack: 0 };
        s.on_client_packets(&t0, vec![seg(b"x")]);
        let out = s.on_client_packets(&t1, vec![seg(b"y")]);
        assert_eq!(out.forwarded, 1, "over-cap flow is forwarded, not dropped");
        assert_eq!(out.to_host.len(), 1);
        assert_eq!(s.num_flows(), 1);
        let tc = s.tenant_counters();
        assert_eq!(tc[0].flows, 1);
        assert_eq!(tc[0].flows_rejected, 1);
    }

    #[test]
    fn pending_bound_rejects_with_clean_err() {
        let mut s = shard(0);
        s.configure_tenants(TenantPlaneConfig { max_pending: 2, ..Default::default() });
        let t = FiveTuple::new(10, 20, 30, 5000);
        let mut client = TcpEndpoint::new();
        // NoOffload routes everything to the host, so admitted requests
        // stay pending until a host exchange happens (never, here).
        let msg = NetMsg {
            msg_id: 7,
            requests: (0..5).map(|k| AppRequest::KvGet { key: k }).collect(),
        };
        let segs = client_segs(&mut client, &msg);
        let out = s.on_client_packets(&t, segs);
        let tc = s.tenant_counters();
        assert_eq!(tc[0].admitted, 2);
        assert_eq!(tc[0].pending, 2);
        assert_eq!(tc[0].rejected_pending, 3);
        assert_eq!(tc[0].throttled, 0);
        // The three rejects came back as framed ERR responses on
        // connection 1 (clean refusal, not a black hole).
        assert!(!out.to_client.is_empty());
        let mut resps = Vec::new();
        for seg in &out.to_client {
            client.on_segment(seg);
        }
        let delivered = client.deliver_rope();
        let mut rx = framing::StreamBuf::new();
        rx.extend_rope(&delivered, client.ledger());
        while let Some(frame) = rx.read_frame() {
            if let Some(r) = crate::proto::NetResp::decode(&frame) {
                resps.push(r);
            }
        }
        assert_eq!(resps.len(), 3);
        assert!(resps.iter().all(|r| r.status == crate::proto::NetResp::ERR));
        // Rejected indexes are the tail of the admission order.
        let mut idxs: Vec<u16> = resps.iter().map(|r| r.idx).collect();
        idxs.sort_unstable();
        assert_eq!(idxs, vec![2, 3, 4]);
    }
}
