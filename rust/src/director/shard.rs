//! One DPU core's share of the data plane (§7).
//!
//! A [`DirectorShard`] is the unit of scaling for the traffic director:
//! it owns the split-TCP state of every flow RSS steers to its core
//! *and* the offload engine colocated with that core, so nothing on the
//! packet path is shared between shards — the paper's "avoids sharing
//! connection states between cores on the DPU". The only cross-shard
//! structures are the read-mostly ones the design shares deliberately:
//! the cache table (§6.1), the file-system mapping, and the SSD device
//! behind each shard's private submission queue.
//!
//! Steering is the symmetric Toeplitz [`rss_core`] hash of the 5-tuple,
//! so both directions of a connection — and the split host connection —
//! land on the same shard (verified in `fig21_scaling.rs` and the
//! steering tests).

use std::collections::HashMap;
use std::sync::Arc;

use super::rss::rss_core;
use super::{AppSignature, DirectorOut, TrafficDirector};
use crate::cache::CuckooCache;
use crate::metrics::LatencyHistogram;
use crate::net::tcp::Segment;
use crate::net::FiveTuple;
use crate::offload::{OffloadEngine, OffloadLogic};

/// Point-in-time counters of one shard (all monotonic).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DirectorShardStats {
    pub shard: usize,
    /// Live flows steered to this shard.
    pub flows: u64,
    pub flows_created: u64,
    pub msgs_in: u64,
    pub reqs_offloaded: u64,
    pub reqs_to_host: u64,
    /// Stage-1 misses forwarded verbatim (§5.1).
    pub forwarded_packets: u64,
    /// Requests rerouted to the host because the shard's engine was
    /// marked failed (fault plane).
    pub reqs_failed_over: u64,
    /// Engine contexts aborted by the pending-timeout (lost SSD
    /// completions surfaced as ERR).
    pub reqs_timed_out: u64,
}

impl DirectorShardStats {
    /// Element-wise sum (for aggregating across shards; `shard` keeps
    /// the left-hand side's id and is meaningless on aggregates).
    pub fn merge(&self, other: &DirectorShardStats) -> DirectorShardStats {
        DirectorShardStats {
            shard: self.shard,
            flows: self.flows + other.flows,
            flows_created: self.flows_created + other.flows_created,
            msgs_in: self.msgs_in + other.msgs_in,
            reqs_offloaded: self.reqs_offloaded + other.reqs_offloaded,
            reqs_to_host: self.reqs_to_host + other.reqs_to_host,
            forwarded_packets: self.forwarded_packets + other.forwarded_packets,
            reqs_failed_over: self.reqs_failed_over + other.reqs_failed_over,
            reqs_timed_out: self.reqs_timed_out + other.reqs_timed_out,
        }
    }
}

/// Reusable carrier for one input burst: every packet batch a shard
/// pump drained before servicing any of them. The pipeline stages
/// (drain → decode/service → host exchange → SSD → respond) each
/// process the whole carrier before handing it on, so per-record
/// bookkeeping — fault-flag sync, completion drains, stats publishes,
/// CpuLedger updates, output flushes — is paid once per burst. The
/// carrier is drained in place and its capacity survives across
/// bursts: steady-state pumping allocates nothing.
#[derive(Default)]
pub struct Burst {
    batches: Vec<(FiveTuple, Vec<Segment>)>,
}

impl Burst {
    pub fn with_capacity(cap: usize) -> Self {
        Burst { batches: Vec::with_capacity(cap) }
    }

    #[inline]
    pub fn push(&mut self, tuple: FiveTuple, segs: Vec<Segment>) {
        self.batches.push((tuple, segs));
    }

    pub fn len(&self) -> usize {
        self.batches.len()
    }

    pub fn is_empty(&self) -> bool {
        self.batches.is_empty()
    }
}

/// One core's traffic director + offload engine: per-flow PEPs created
/// on first packet, all state shard-local.
pub struct DirectorShard {
    id: usize,
    signature: AppSignature,
    logic: Arc<dyn OffloadLogic>,
    cache: Arc<CuckooCache>,
    engine: OffloadEngine,
    flows: HashMap<FiveTuple, TrafficDirector>,
    flows_created: u64,
    forwarded_packets: u64,
    /// Shard-level running sums of the per-flow counters, maintained
    /// incrementally so `stats()` is O(1) on the packet path (no
    /// per-call iteration over the flow table).
    agg_msgs_in: u64,
    agg_reqs_offloaded: u64,
    agg_reqs_to_host: u64,
    /// Shard-wide latency recorder, shared by every flow PEP on this
    /// shard (one writer thread — the shard pump — so the relaxed adds
    /// never bounce a cache line between cores). `None` until attached.
    lat: Option<Arc<LatencyHistogram>>,
}

impl DirectorShard {
    pub fn new(
        id: usize,
        signature: AppSignature,
        logic: Arc<dyn OffloadLogic>,
        cache: Arc<CuckooCache>,
        engine: OffloadEngine,
    ) -> Self {
        DirectorShard {
            id,
            signature,
            logic,
            cache,
            engine,
            flows: HashMap::new(),
            flows_created: 0,
            forwarded_packets: 0,
            agg_msgs_in: 0,
            agg_reqs_offloaded: 0,
            agg_reqs_to_host: 0,
            lat: None,
        }
    }

    /// Attach the shard's service-latency recorder; propagated to every
    /// flow PEP (existing and future) so each admitted request is timed
    /// through to its client-bound response.
    pub fn attach_latency(&mut self, lat: Arc<LatencyHistogram>) {
        for dir in self.flows.values_mut() {
            dir.attach_latency(lat.clone());
        }
        self.lat = Some(lat);
    }

    /// This shard's core index.
    pub fn id(&self) -> usize {
        self.id
    }

    /// First-stage signature match (§5.1).
    pub fn matches(&self, tuple: &FiveTuple) -> bool {
        self.signature.matches(tuple)
    }

    /// Whether RSS steers `tuple` to this shard in an `shards`-wide
    /// deployment (sanity check for steering layers above).
    pub fn owns(&self, tuple: &FiveTuple, shards: usize) -> bool {
        rss_core(tuple, shards) == self.id
    }

    /// Ingress from the client NIC for a flow steered to this shard.
    /// Creates the flow's PEP on first contact; non-matching flows are
    /// forwarded verbatim without creating flow state.
    pub fn on_client_packets(&mut self, tuple: &FiveTuple, segs: Vec<Segment>) -> DirectorOut {
        if !self.signature.matches(tuple) {
            // `forwarded` counts PACKETS, matching TrafficDirector.
            let n = segs.len() as u64;
            self.forwarded_packets += n;
            return DirectorOut { to_host: segs, forwarded: n, ..Default::default() };
        }
        let dir = match self.flows.entry(*tuple) {
            std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::hash_map::Entry::Vacant(e) => {
                self.flows_created += 1;
                let mut dir =
                    TrafficDirector::new(self.signature, self.logic.clone(), self.cache.clone());
                if let Some(lat) = &self.lat {
                    dir.attach_latency(lat.clone());
                }
                e.insert(dir)
            }
        };
        // Fold this call's counter deltas into the shard-level sums
        // (only on_client_packets ever advances them).
        let before = (dir.msgs_in, dir.reqs_offloaded, dir.reqs_to_host);
        let out = dir.on_client_packets(tuple, segs, &mut self.engine);
        self.agg_msgs_in += dir.msgs_in - before.0;
        self.agg_reqs_offloaded += dir.reqs_offloaded - before.1;
        self.agg_reqs_to_host += dir.reqs_to_host - before.2;
        out
    }

    /// Service a whole [`Burst`] as a unit (decode/service stage of the
    /// batch pipeline): every batch runs through its flow's PEP and the
    /// colocated engine back-to-back, and only *matching* flows emit an
    /// entry into `outs` for the host-exchange stage — stage-1 misses
    /// are counted and forwarded outside the model, exactly like the
    /// single-batch path (no PEP, no host connection, no per-flow
    /// state). Drains the carrier in place, leaving its capacity.
    pub fn service_burst(
        &mut self,
        burst: &mut Burst,
        outs: &mut Vec<(FiveTuple, DirectorOut)>,
    ) {
        for (tuple, segs) in burst.batches.drain(..) {
            let matched = self.matches(&tuple);
            let out = self.on_client_packets(&tuple, segs);
            if matched {
                outs.push((tuple, out));
            }
        }
    }

    /// Host-side packets of one flow's split connection.
    pub fn on_host_packets(&mut self, tuple: &FiveTuple, segs: Vec<Segment>) -> DirectorOut {
        match self.flows.get_mut(tuple) {
            Some(dir) => dir.on_host_packets(segs),
            None => DirectorOut::default(),
        }
    }

    /// Drain late engine completions for every flow on this shard.
    pub fn pump_completions(&mut self) -> Vec<(FiveTuple, DirectorOut)> {
        let mut outs = Vec::new();
        self.pump_completions_into(&mut outs);
        outs
    }

    /// Buffer-reusing variant: appends `(tuple, out)` pairs to `outs`
    /// so the shard pump's steady-state completion drain allocates
    /// nothing.
    pub fn pump_completions_into(&mut self, outs: &mut Vec<(FiveTuple, DirectorOut)>) {
        for (tuple, dir) in self.flows.iter_mut() {
            let out = dir.pump_completions(&mut self.engine);
            if !out.to_client.is_empty() || !out.to_host.is_empty() {
                outs.push((*tuple, out));
            }
        }
    }

    /// The engine colocated with this shard.
    pub fn engine(&self) -> &OffloadEngine {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut OffloadEngine {
        &mut self.engine
    }

    /// Inject or clear failure of this shard's engine (fault plane):
    /// failed engines route every request through the host slow path.
    pub fn set_engine_failed(&mut self, failed: bool) {
        self.engine.set_failed(failed);
    }

    pub fn engine_failed(&self) -> bool {
        self.engine.is_failed()
    }

    /// Live flow count.
    pub fn num_flows(&self) -> usize {
        self.flows.len()
    }

    /// Counter snapshot. O(1): the per-flow counters are folded into
    /// shard-level sums as they advance, so this is safe to call on
    /// every packet batch.
    pub fn stats(&self) -> DirectorShardStats {
        DirectorShardStats {
            shard: self.id,
            flows: self.flows.len() as u64,
            flows_created: self.flows_created,
            forwarded_packets: self.forwarded_packets,
            msgs_in: self.agg_msgs_in,
            reqs_offloaded: self.agg_reqs_offloaded,
            reqs_to_host: self.agg_reqs_to_host,
            reqs_failed_over: self.engine.bounced_engine_failed,
            reqs_timed_out: self.engine.timed_out,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpufs::{DpuFs, FsConfig};
    use crate::offload::{NoOffload, OffloadEngineConfig};
    use crate::ssd::{AsyncSsd, Ssd};
    use std::sync::RwLock;

    fn shard(id: usize) -> DirectorShard {
        let ssd = Arc::new(Ssd::new(4 << 20, 512));
        let fs = DpuFs::format(ssd.clone(), FsConfig::default()).unwrap();
        let engine = OffloadEngine::new(
            Arc::new(NoOffload),
            Arc::new(CuckooCache::new(64)),
            Arc::new(RwLock::new(fs)),
            AsyncSsd::new_inline(ssd),
            OffloadEngineConfig::default(),
        );
        DirectorShard::new(
            id,
            AppSignature::server_port(5000),
            Arc::new(NoOffload),
            Arc::new(CuckooCache::new(64)),
            engine,
        )
    }

    #[test]
    fn non_matching_forwarded_without_flow_state() {
        let mut s = shard(0);
        let other = FiveTuple::new(1, 2, 3, 9999);
        let seg = Segment { seq: 0, payload: vec![1, 2, 3].into(), ack: 0 };
        let out = s.on_client_packets(&other, vec![seg]);
        assert_eq!(out.forwarded, 1);
        assert_eq!(out.to_host.len(), 1);
        assert_eq!(s.num_flows(), 0, "no PEP state for uninteresting flows");
        assert_eq!(s.stats().forwarded_packets, 1);
    }

    #[test]
    fn flow_created_once_and_counted() {
        let mut s = shard(0);
        let t = FiveTuple::new(10, 20, 30, 5000);
        for _ in 0..5 {
            let seg = Segment { seq: 0, payload: crate::buf::BufView::empty(), ack: 0 };
            s.on_client_packets(&t, vec![seg]);
        }
        let st = s.stats();
        assert_eq!(st.flows_created, 1);
        assert_eq!(st.flows, 1);
        assert_eq!(s.num_flows(), 1);
    }

    #[test]
    fn ownership_follows_rss() {
        let shards = 4usize;
        let t = FiveTuple::new(0x0a000001, 41000, 0x0a0000ff, 5000);
        let core = rss_core(&t, shards);
        for id in 0..shards {
            let s = shard(id);
            assert_eq!(s.owns(&t, shards), id == core);
        }
    }
}
