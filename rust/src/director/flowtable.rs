//! Readiness-driven flow table: the epoll idiom over the simulated TCP
//! stack.
//!
//! With a handful of connections, walking every flow per pump iteration
//! is free. At DBMS fanout — thousands of mostly-idle connections per
//! shard (the disaggregation economics the extended report cites) — it
//! is the difference between work scaling with *active* flows and work
//! scaling with *open* flows. The table therefore keeps per-flow PEP
//! state in a slab (stable indices, O(1) lookup by 5-tuple) and a
//! **ready ring**: flows get a readiness bit when something actually
//! happens to them — client segments arrive, the colocated engine
//! completes one of their requests, the host exchange returns responses
//! — and the shard pump drains only the ring. A flow that stays quiet
//! costs nothing per iteration and, once past its idle TTL, not even
//! memory: the table sweeps expired flows incrementally and recycles
//! their slots.
//!
//! Eviction is deliberately conservative: a slot is only reclaimed when
//! the flow has zero admitted requests in flight (`pending == 0`), is
//! not sitting in the ready ring, and its PEP reports
//! [`TrafficDirector::quiescent`] — no host remapping entries, no
//! latency stamps, nothing unacknowledged on either split connection.
//! That gate is what makes the shard's submission-order completion FIFO
//! safe: a slab index in that FIFO always refers to the flow that
//! submitted the request, never to a recycled slot.

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

use super::TrafficDirector;
use crate::net::tcp::Segment;
use crate::net::FiveTuple;

/// Readiness bits (reasons a flow is in the ready ring).
pub struct Readiness;

impl Readiness {
    /// Client segments staged for ingest.
    pub const CLIENT: u8 = 1 << 0;
    /// The host exchange produced activity on this flow.
    pub const HOST: u8 = 1 << 1;
    /// The colocated engine completed one of this flow's requests.
    pub const ENGINE: u8 = 1 << 2;
}

/// One open flow: its PEP, staged input, and scheduling state.
pub struct FlowSlot {
    pub tuple: FiveTuple,
    /// Tenant bucket this flow bills to (derived once at creation).
    pub tenant: u32,
    /// The flow's split-TCP PEP.
    pub dir: TrafficDirector,
    /// Client segments staged by the drain stage, consumed by the
    /// service stage when the flow is popped from the ready ring.
    pub staged: Vec<Segment>,
    /// Admitted requests in flight (engine or host side). Balanced by
    /// response framing; gates eviction and the tenant pending bound.
    pub pending: u64,
    /// Last time anything happened to this flow (feeds the idle TTL).
    pub last_active: Instant,
    /// Pending readiness bits (meaningful while `in_ring`).
    ready: u8,
    in_ring: bool,
}

/// Slab of flows + ready ring. Indices returned by [`FlowTable::insert`]
/// / [`FlowTable::lookup`] stay valid until the flow is evicted.
pub struct FlowTable {
    index: HashMap<FiveTuple, usize>,
    slots: Vec<Option<FlowSlot>>,
    free: Vec<usize>,
    ready_ring: VecDeque<usize>,
    /// Incremental eviction cursor (the sweep resumes where it left off
    /// so a 10k-flow table is never walked in one pump iteration).
    sweep: usize,
    /// Flows evicted over the table's lifetime.
    pub flows_closed: u64,
}

impl FlowTable {
    pub fn new() -> Self {
        FlowTable {
            index: HashMap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            ready_ring: VecDeque::new(),
            sweep: 0,
            flows_closed: 0,
        }
    }

    /// Open flows.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Flows currently scheduled in the ready ring.
    pub fn ready_len(&self) -> usize {
        self.ready_ring.len()
    }

    /// Slab index of an open flow.
    pub fn lookup(&self, tuple: &FiveTuple) -> Option<usize> {
        self.index.get(tuple).copied()
    }

    /// Insert a new flow (caller has already applied flow admission).
    /// Returns its slab index.
    pub fn insert(&mut self, tuple: FiveTuple, tenant: u32, dir: TrafficDirector) -> usize {
        debug_assert!(!self.index.contains_key(&tuple), "flow inserted twice");
        let slot = FlowSlot {
            tuple,
            tenant,
            dir,
            staged: Vec::new(),
            pending: 0,
            last_active: Instant::now(),
            ready: 0,
            in_ring: false,
        };
        let idx = match self.free.pop() {
            Some(idx) => {
                self.slots[idx] = Some(slot);
                idx
            }
            None => {
                self.slots.push(Some(slot));
                self.slots.len() - 1
            }
        };
        self.index.insert(tuple, idx);
        idx
    }

    pub fn slot(&self, idx: usize) -> Option<&FlowSlot> {
        self.slots.get(idx).and_then(|s| s.as_ref())
    }

    pub fn slot_mut(&mut self, idx: usize) -> Option<&mut FlowSlot> {
        self.slots.get_mut(idx).and_then(|s| s.as_mut())
    }

    /// Every open flow (order is slab order, not arrival order).
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut FlowSlot> {
        self.slots.iter_mut().filter_map(|s| s.as_mut())
    }

    /// Set readiness bits on a flow and schedule it if it is not
    /// already in the ring (level-triggered: bits accumulate until the
    /// pump pops the flow). Also refreshes the activity stamp — a flow
    /// with work is never idle.
    pub fn mark_ready(&mut self, idx: usize, bits: u8) {
        let Some(slot) = self.slots.get_mut(idx).and_then(|s| s.as_mut()) else {
            return;
        };
        slot.ready |= bits;
        slot.last_active = Instant::now();
        if !slot.in_ring {
            slot.in_ring = true;
            self.ready_ring.push_back(idx);
        }
    }

    /// Pop the next ready flow: `(slab index, readiness bits)`. The
    /// bits are cleared and the flow leaves the ring — new events after
    /// this call re-schedule it.
    pub fn pop_ready(&mut self) -> Option<(usize, u8)> {
        while let Some(idx) = self.ready_ring.pop_front() {
            if let Some(slot) = self.slots.get_mut(idx).and_then(|s| s.as_mut()) {
                let bits = slot.ready;
                slot.ready = 0;
                slot.in_ring = false;
                return Some((idx, bits));
            }
            // Slot vanished while queued (cannot happen through the
            // eviction gate, but a stale index must not wedge the ring).
        }
        None
    }

    /// Incremental idle sweep: examine up to `max_scan` slots from the
    /// persistent cursor and evict flows idle for at least `ttl` that
    /// are safe to drop (nothing pending, not scheduled, PEP
    /// quiescent). Returns `(tuple, tenant)` of each evicted flow so
    /// the caller can settle tenant gauges.
    pub fn evict_idle(
        &mut self,
        now: Instant,
        ttl: Duration,
        max_scan: usize,
    ) -> Vec<(FiveTuple, u32)> {
        let mut evicted = Vec::new();
        if self.slots.is_empty() {
            return evicted;
        }
        let scan = max_scan.min(self.slots.len());
        for _ in 0..scan {
            if self.sweep >= self.slots.len() {
                self.sweep = 0;
            }
            let idx = self.sweep;
            self.sweep += 1;
            let expired = match &self.slots[idx] {
                Some(s) => {
                    s.pending == 0
                        && !s.in_ring
                        && s.staged.is_empty()
                        && now.duration_since(s.last_active) >= ttl
                        && s.dir.quiescent()
                }
                None => false,
            };
            if expired {
                let slot = self.slots[idx].take().expect("checked occupied");
                self.index.remove(&slot.tuple);
                self.free.push(idx);
                self.flows_closed += 1;
                evicted.push((slot.tuple, slot.tenant));
            }
        }
        evicted
    }
}

impl Default for FlowTable {
    fn default() -> Self {
        FlowTable::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CuckooCache;
    use crate::director::AppSignature;
    use crate::offload::NoOffload;
    use std::sync::Arc;

    fn dir() -> TrafficDirector {
        TrafficDirector::new(
            AppSignature::server_port(5000),
            Arc::new(NoOffload),
            Arc::new(CuckooCache::new(64)),
        )
    }

    fn tuple(port: u16) -> FiveTuple {
        FiveTuple::new(0x0a000001, port, 0x0a0000ff, 5000)
    }

    #[test]
    fn ready_ring_dedups_and_accumulates_bits() {
        let mut tab = FlowTable::new();
        let a = tab.insert(tuple(1), 0, dir());
        let b = tab.insert(tuple(2), 0, dir());
        tab.mark_ready(a, Readiness::CLIENT);
        tab.mark_ready(a, Readiness::ENGINE); // second mark: no second entry
        tab.mark_ready(b, Readiness::HOST);
        assert_eq!(tab.ready_len(), 2);
        let (idx, bits) = tab.pop_ready().unwrap();
        assert_eq!(idx, a);
        assert_eq!(bits, Readiness::CLIENT | Readiness::ENGINE);
        let (idx, bits) = tab.pop_ready().unwrap();
        assert_eq!(idx, b);
        assert_eq!(bits, Readiness::HOST);
        assert!(tab.pop_ready().is_none());
        // Popped flows can be re-armed.
        tab.mark_ready(a, Readiness::CLIENT);
        assert_eq!(tab.ready_len(), 1);
    }

    #[test]
    fn eviction_recycles_slots_and_respects_gates() {
        let mut tab = FlowTable::new();
        let ttl = Duration::from_millis(0); // everything is "idle"
        let a = tab.insert(tuple(1), 3, dir());
        let b = tab.insert(tuple(2), 4, dir());
        // `a` has an admitted request in flight: must survive the sweep.
        tab.slot_mut(a).unwrap().pending = 1;
        let now = Instant::now() + Duration::from_secs(1);
        let evicted = tab.evict_idle(now, ttl, 16);
        assert_eq!(evicted, vec![(tuple(2), 4)]);
        assert_eq!(tab.len(), 1);
        assert!(tab.lookup(&tuple(2)).is_none());
        assert_eq!(tab.flows_closed, 1);
        // Once `a` settles, it goes too.
        tab.slot_mut(a).unwrap().pending = 0;
        let evicted = tab.evict_idle(now, ttl, 16);
        assert_eq!(evicted, vec![(tuple(1), 3)]);
        assert_eq!(tab.flows_closed, 2);
        // Freed slots are recycled (LIFO): the next insert reuses `a`'s.
        let c = tab.insert(tuple(3), 0, dir());
        assert_eq!(c, a);
        assert_eq!(tab.len(), 1);
    }

    #[test]
    fn scheduled_or_staged_flows_are_not_evicted() {
        let mut tab = FlowTable::new();
        let a = tab.insert(tuple(1), 0, dir());
        tab.mark_ready(a, Readiness::CLIENT);
        let now = Instant::now() + Duration::from_secs(60);
        assert!(tab.evict_idle(now, Duration::from_millis(1), 8).is_empty());
        // Popping clears scheduling; with nothing staged it may now go.
        tab.pop_ready();
        // mark_ready refreshed last_active, so use a far-future clock.
        let later = Instant::now() + Duration::from_secs(120);
        assert_eq!(tab.evict_idle(later, Duration::from_secs(1), 8).len(), 1);
    }
}
