//! The traffic director (§5): a bump-in-the-wire on the DPU.
//!
//! Packet inspection happens in two stages (§5.1): the user-defined
//! [`AppSignature`] filters flows by 5-tuple (pushed down to NIC
//! hardware on real BF-2 — line rate, zero Arm latency), then the
//! offload predicate inspects payloads of matching flows.
//!
//! For matching flows the director is a TCP-splitting
//! performance-enhancing proxy (§5.2): it terminates the client
//! connection on the DPU and re-originates a second connection to the
//! host, so consuming (offloading) requests on the DPU never perturbs
//! the host's sequence space (the Fig 11 pathology).
//!
//! Scaling (§7): packets are steered to DPU cores with a symmetric RSS
//! hash of the 5-tuple so both directions of a connection — and the
//! split host connection — land on the same core, avoiding cross-core
//! connection state.

pub mod flowtable;
pub mod multiflow;
pub mod rss;
pub mod shard;
pub mod tenant;

pub use flowtable::{FlowTable, Readiness};
pub use multiflow::MultiFlowDirector;
pub use rss::{rss_core, toeplitz_hash};
pub use shard::{Burst, DirectorShard, DirectorShardStats};
pub use tenant::{TenantPlane, TenantPlaneConfig};

use std::sync::Arc;
use std::time::Instant;

use crate::buf::ByteRope;
use crate::cache::CuckooCache;
use crate::metrics::LatencyHistogram;
use crate::net::tcp::{Segment, TcpEndpoint};
use crate::net::FiveTuple;
use crate::offload::{OffloadEngine, OffloadLogic, RoutedReq};
use crate::proto::{framing, AppRequest, NetMsg, NetResp};

/// User-supplied application signature (§5.1): 5-tuple filter with
/// wildcards. The paper's example matches any client against a local
/// server port over TCP.
#[derive(Debug, Clone, Copy, Default)]
pub struct AppSignature {
    pub client_ip: Option<u32>,
    pub client_port: Option<u16>,
    pub server_ip: Option<u32>,
    pub server_port: Option<u16>,
}

impl AppSignature {
    /// The paper's canonical example: `any client -> local:port, TCP`.
    pub fn server_port(port: u16) -> Self {
        AppSignature { server_port: Some(port), ..Default::default() }
    }

    /// First-stage match on the packet header (L3/L4 only).
    pub fn matches(&self, t: &FiveTuple) -> bool {
        self.client_ip.map_or(true, |v| v == t.client_ip)
            && self.client_port.map_or(true, |v| v == t.client_port)
            && self.server_ip.map_or(true, |v| v == t.server_ip)
            && self.server_port.map_or(true, |v| v == t.server_port)
    }
}

/// Output of one director step.
#[derive(Debug, Default)]
pub struct DirectorOut {
    /// Segments to put on the wire toward the client (connection 1).
    pub to_client: Vec<Segment>,
    /// Segments to put on the wire toward the host (connection 2).
    pub to_host: Vec<Segment>,
    /// Packets of non-matching flows forwarded verbatim (§5.1 stage 1
    /// miss; costs `dpu_forward_ns` on off-path DPUs).
    pub forwarded: u64,
}

/// Per-flow PEP state: the two split connections.
pub struct TrafficDirector {
    signature: AppSignature,
    logic: Arc<dyn OffloadLogic>,
    cache: Arc<CuckooCache>,
    /// DPU terminus of the client connection (connection 1).
    client_ep: TcpEndpoint,
    /// DPU originator of the host connection (connection 2).
    host_ep: TcpEndpoint,
    /// Reassembly buffers for message framing.
    client_rx: framing::StreamBuf,
    host_rx: framing::StreamBuf,
    /// PEP index remapping: requests forwarded to the host are
    /// re-packed positionally into a new message, so the host responds
    /// with the *forwarded* index. This maps `msg_id -> original idx of
    /// each forwarded position` (plus a remaining-responses counter for
    /// cleanup).
    host_idx_map: std::collections::HashMap<u64, (Vec<u16>, usize)>,
    /// Per-request service-latency recorder (the tail trajectory is
    /// measured AT the director: request admitted → response framed to
    /// the client, spanning offload execute, SSD round trip and host
    /// slow path alike). `None` keeps the packet path entirely free of
    /// timing bookkeeping.
    lat: Option<Arc<LatencyHistogram>>,
    /// Admission timestamps of in-flight requests, keyed by
    /// `(msg_id, original idx)`; removed when the response is framed.
    started: std::collections::HashMap<(u64, u16), Instant>,
    /// Stats.
    pub msgs_in: u64,
    pub reqs_offloaded: u64,
    pub reqs_to_host: u64,
    /// Responses framed toward the client for ADMITTED requests (OK and
    /// ERR alike; admission rejects are framed separately and not
    /// counted here — the shard's tenant plane balances this against
    /// its per-tenant pending gauge).
    pub resps_out: u64,
}

/// Decoded client ingress with engine execution deferred to the caller:
/// the sharded data plane owns ONE engine per core shared by every flow
/// on it, and must attribute completions across flows itself.
pub(crate) struct ClientIngest {
    pub host_reqs: Vec<RoutedReq>,
    pub dpu_reqs: Vec<RoutedReq>,
    /// Requests refused by admission control, already shaped as clean
    /// ERR responses for the caller to frame.
    pub rejected: Vec<NetResp>,
}

impl TrafficDirector {
    pub fn new(
        signature: AppSignature,
        logic: Arc<dyn OffloadLogic>,
        cache: Arc<CuckooCache>,
    ) -> Self {
        TrafficDirector {
            signature,
            logic,
            cache,
            client_ep: TcpEndpoint::new(),
            host_ep: TcpEndpoint::new(),
            client_rx: framing::StreamBuf::new(),
            host_rx: framing::StreamBuf::new(),
            host_idx_map: std::collections::HashMap::new(),
            lat: None,
            started: std::collections::HashMap::new(),
            msgs_in: 0,
            reqs_offloaded: 0,
            reqs_to_host: 0,
            resps_out: 0,
        }
    }

    /// Attach the shard's latency recorder; every subsequent request is
    /// timed from admission to response framing.
    pub fn attach_latency(&mut self, lat: Arc<LatencyHistogram>) {
        self.lat = Some(lat);
    }

    /// Process packets arriving from the client NIC port.
    ///
    /// Non-matching flows are forwarded to the host untouched. Matching
    /// flows terminate at the PEP: payload is reassembled, messages are
    /// split by the offload predicate, DPU-able requests are executed by
    /// `engine`, host requests are re-sent on connection 2.
    pub fn on_client_packets(
        &mut self,
        tuple: &FiveTuple,
        segs: Vec<Segment>,
        engine: &mut OffloadEngine,
    ) -> DirectorOut {
        let mut out = DirectorOut::default();
        if !self.signature.matches(tuple) {
            // Stage-1 miss: straight to the host (hardware match keeps
            // this off the Arm cores for on-NIC signatures, §5.3).
            out.forwarded = segs.len() as u64;
            out.to_host = segs;
            return out;
        }
        // Single-flow path (this flow owns `engine`): ingest with no
        // admission quota, execute, forward, frame — the same pieces
        // the sharded plane composes with cross-flow attribution.
        let ingest = self.ingest_client(segs, None, &mut out);
        let mut host_reqs = ingest.host_reqs;
        // Execute offloadable requests; bounced ones join the host list.
        let mut responses = Vec::new();
        let bounced = engine.execute(ingest.dpu_reqs, &mut responses);
        host_reqs.extend(bounced);
        self.forward_to_host(host_reqs, &mut out);
        // Responses completed by the engine go straight to the client
        // (Fig 12 ④).
        self.send_responses(responses, &mut out);
        out
    }

    /// PEP ingress without engine execution: terminate connection 1,
    /// reassemble frames, split by the offload predicate, and apply the
    /// caller's admission quota. At most `quota` requests (in intra-
    /// message index order) are admitted and latency-stamped; the rest
    /// come back as ready-to-frame clean ERR responses — the overload
    /// contract of the tenant plane ("bounded pending per tenant, clean
    /// ERR on reject"). `None` admits everything.
    pub(crate) fn ingest_client(
        &mut self,
        segs: Vec<Segment>,
        quota: Option<u64>,
        out: &mut DirectorOut,
    ) -> ClientIngest {
        for s in &segs {
            out.to_client.extend(self.client_ep.on_segment(s));
        }
        let delivered = self.client_ep.deliver_rope();
        self.client_rx.extend_rope(&delivered, self.client_ep.ledger());
        let mut ingest = ClientIngest {
            host_reqs: Vec::new(),
            dpu_reqs: Vec::new(),
            rejected: Vec::new(),
        };
        let mut quota = quota.unwrap_or(u64::MAX);
        while let Some(frame) = self.client_rx.read_frame() {
            let Some(msg) = NetMsg::decode(&frame) else { continue };
            self.msgs_in += 1;
            let (h, d) = self.logic.off_pred(&msg, &self.cache);
            if quota >= (h.len() + d.len()) as u64 {
                // Fast path (the only path in single-tenant runs): no
                // re-sorting, no rejects.
                quota -= (h.len() + d.len()) as u64;
                ingest.host_reqs.extend(h);
                ingest.dpu_reqs.extend(d);
                continue;
            }
            // Admission boundary inside this message: admit in index
            // order so the rejected suffix is deterministic.
            let mut merged: Vec<(bool, RoutedReq)> = h
                .into_iter()
                .map(|r| (false, r))
                .chain(d.into_iter().map(|r| (true, r)))
                .collect();
            merged.sort_by_key(|(_, r)| r.idx);
            for (is_dpu, r) in merged {
                if quota > 0 {
                    quota -= 1;
                    if is_dpu {
                        ingest.dpu_reqs.push(r);
                    } else {
                        ingest.host_reqs.push(r);
                    }
                } else {
                    ingest.rejected.push(NetResp {
                        msg_id: r.msg_id,
                        idx: r.idx,
                        status: NetResp::ERR,
                        payload: crate::buf::BufView::empty(),
                    });
                }
            }
        }
        self.reqs_offloaded += ingest.dpu_reqs.len() as u64;
        // One timestamp per burst stamps every admitted request (engine
        // bounces keep their dpu stamp — the client's clock does not
        // restart because the engine said no). Rejected requests are
        // never stamped: an overload ERR is not a service latency.
        if self.lat.is_some() && (!ingest.host_reqs.is_empty() || !ingest.dpu_reqs.is_empty())
        {
            let now = Instant::now();
            for r in ingest.host_reqs.iter().chain(ingest.dpu_reqs.iter()) {
                self.started.insert((r.msg_id, r.idx), now);
            }
        }
        ingest
    }

    /// Ship host-bound requests on connection 2 (grouped back into
    /// per-message batches to preserve the app protocol), recording the
    /// index remapping for the responses.
    pub(crate) fn forward_to_host(&mut self, host_reqs: Vec<RoutedReq>, out: &mut DirectorOut) {
        self.reqs_to_host += host_reqs.len() as u64;
        if !host_reqs.is_empty() {
            let mut stream = Vec::new();
            for (chunk, originals) in regroup(host_reqs) {
                let n = originals.len();
                self.host_idx_map.insert(chunk.msg_id, (originals, n));
                framing::write_frame(&mut stream, &chunk.encode());
            }
            out.to_host.extend(self.host_ep.send(&stream));
        }
    }

    /// Frame completed responses for admitted requests toward the
    /// client (latency-recorded, counted in `resps_out`). The sharded
    /// plane calls this with engine completions it attributed to this
    /// flow.
    pub(crate) fn frame_responses(&mut self, responses: Vec<NetResp>, out: &mut DirectorOut) {
        self.send_responses(responses, out);
    }

    /// Frame admission-reject ERRs: not latency-recorded (an overload
    /// bounce is not a service time) and not counted in `resps_out`
    /// (they were never admitted, so they must not drain the tenant's
    /// pending gauge).
    pub(crate) fn frame_rejects(&mut self, rejects: Vec<NetResp>, out: &mut DirectorOut) {
        if rejects.is_empty() {
            return;
        }
        let mut rope = ByteRope::new();
        for r in rejects {
            r.frame_into_rope(&mut rope);
        }
        out.to_client.extend(self.client_ep.send_rope(rope));
    }

    /// Whether this PEP is safe to evict: no admitted request awaiting
    /// a host response, no latency stamp outstanding, and nothing
    /// unacknowledged on either split connection. (Engine in-flight is
    /// tracked by the owning shard's flow table, which also gates
    /// eviction on it.)
    pub(crate) fn quiescent(&self) -> bool {
        self.host_idx_map.is_empty()
            && self.started.is_empty()
            && self.client_ep.bytes_in_flight() == 0
            && self.host_ep.bytes_in_flight() == 0
    }

    /// Process packets arriving from the host (connection 2 responses).
    pub fn on_host_packets(&mut self, segs: Vec<Segment>) -> DirectorOut {
        let mut out = DirectorOut::default();
        for s in &segs {
            out.to_host.extend(self.host_ep.on_segment(s));
        }
        let delivered = self.host_ep.deliver_rope();
        self.host_rx.extend_rope(&delivered, self.host_ep.ledger());
        let mut responses = Vec::new();
        while let Some(frame) = self.host_rx.read_frame() {
            if let Some(mut resp) = NetResp::decode(&frame) {
                // Translate the forwarded position back to the
                // original in-message index.
                if let Some((originals, remaining)) =
                    self.host_idx_map.get_mut(&resp.msg_id)
                {
                    if let Some(&orig) = originals.get(resp.idx as usize) {
                        resp.idx = orig;
                    }
                    *remaining -= 1;
                    if *remaining == 0 {
                        self.host_idx_map.remove(&resp.msg_id);
                    }
                }
                responses.push(resp);
            }
        }
        self.send_responses(responses, &mut out);
        out
    }

    /// Drain engine completions that finished after their batch (call
    /// periodically — Fig 13 line 16).
    pub fn pump_completions(&mut self, engine: &mut OffloadEngine) -> DirectorOut {
        let mut out = DirectorOut::default();
        let mut responses = Vec::new();
        engine.complete_pending(&mut responses);
        self.send_responses(responses, &mut out);
        out
    }

    /// Frame responses toward the client with zero payload copies
    /// (Fig 12 ④): each payload rides as the view the engine (or host
    /// decode) produced; the tiny frame headers become owned views that
    /// the TCP layer's small-part coalescer MSS-packs, so they never
    /// turn into per-response wire segments on all-small workloads.
    fn send_responses(&mut self, responses: Vec<NetResp>, out: &mut DirectorOut) {
        if responses.is_empty() {
            return;
        }
        // One clock read per response burst: the whole burst completes
        // "now" (sub-burst skew is below bucket resolution by design —
        // burst service is run-to-completion).
        let done = self.lat.as_ref().map(|l| (l.clone(), Instant::now()));
        self.resps_out += responses.len() as u64;
        let mut rope = ByteRope::new();
        for r in responses {
            if let Some((lat, now)) = &done {
                if let Some(t0) = self.started.remove(&(r.msg_id, r.idx)) {
                    lat.record_duration(now.duration_since(t0));
                }
            }
            r.frame_into_rope(&mut rope);
        }
        out.to_client.extend(self.client_ep.send_rope(rope));
    }
}

/// Regroup routed requests into messages by original msg_id, preserving
/// intra-message order, so the host application sees well-formed
/// batches. Returns each message together with the original index of
/// every forwarded position (for PEP response remapping).
fn regroup(reqs: Vec<RoutedReq>) -> Vec<(NetMsg, Vec<u16>)> {
    let mut msgs: Vec<(NetMsg, Vec<u16>)> = Vec::new();
    let mut by_id: std::collections::HashMap<u64, usize> = std::collections::HashMap::new();
    for r in reqs {
        // Engine bounces can interleave with predicate-routed requests,
        // so group by id (order within a message stays stable because
        // both sources preserve it).
        let at = *by_id.entry(r.msg_id).or_insert_with(|| {
            msgs.push((NetMsg { msg_id: r.msg_id, requests: Vec::new() }, Vec::new()));
            msgs.len() - 1
        });
        msgs[at].0.requests.push(r.req);
        msgs[at].1.push(r.idx);
    }
    // Forwarded batches must be index-sorted so positional responses
    // map back deterministically.
    for (msg, originals) in &mut msgs {
        let mut paired: Vec<(u16, AppRequest)> =
            originals.iter().copied().zip(msg.requests.drain(..)).collect();
        paired.sort_by_key(|(i, _)| *i);
        *originals = paired.iter().map(|(i, _)| *i).collect();
        msg.requests = paired.into_iter().map(|(_, r)| r).collect();
    }
    msgs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::AppRequest;

    #[test]
    fn signature_wildcards() {
        let sig = AppSignature::server_port(5000);
        let t = FiveTuple::new(0x0a000001, 33333, 0x0a000002, 5000);
        assert!(sig.matches(&t));
        let other = FiveTuple::new(0x0a000001, 33333, 0x0a000002, 5001);
        assert!(!sig.matches(&other));
        let exact = AppSignature {
            client_ip: Some(1),
            client_port: Some(2),
            server_ip: Some(3),
            server_port: Some(4),
        };
        assert!(exact.matches(&FiveTuple::new(1, 2, 3, 4)));
        assert!(!exact.matches(&FiveTuple::new(9, 2, 3, 4)));
    }

    #[test]
    fn regroup_preserves_batches_and_maps_indices() {
        let reqs = vec![
            RoutedReq { msg_id: 1, idx: 2, req: AppRequest::KvGet { key: 2 } },
            RoutedReq { msg_id: 2, idx: 0, req: AppRequest::KvGet { key: 3 } },
            // Engine bounce interleaved after another message:
            RoutedReq { msg_id: 1, idx: 0, req: AppRequest::KvGet { key: 1 } },
        ];
        let msgs = regroup(reqs);
        assert_eq!(msgs.len(), 2);
        let (m1, orig1) = &msgs[0];
        assert_eq!(m1.msg_id, 1);
        assert_eq!(m1.requests.len(), 2);
        // Sorted by original idx so positional responses map back.
        assert_eq!(orig1, &vec![0, 2]);
        assert_eq!(m1.requests[0], AppRequest::KvGet { key: 1 });
        let (m2, orig2) = &msgs[1];
        assert_eq!(m2.msg_id, 2);
        assert_eq!(orig2, &vec![0]);
    }
}
