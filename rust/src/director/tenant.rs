//! Per-tenant QoS for the fanout plane: token-bucket rate limiting,
//! bounded pending (admission control under overload), per-shard flow
//! caps, and the drain weights the shard's fair scheduler consumes.
//!
//! Everything here defaults to OFF: `TenantPlaneConfig::default()` is a
//! single tenant with no rate, pending or flow bounds, so the
//! single-tenant benchmarks and the deterministic chaos harness pay one
//! counter update per burst and never touch the wall clock. Limits only
//! engage when the operator asks for them (`--tenants/--rate/
//! --max-flows`), and rejections are *clean*: the request is answered
//! with a protocol-level ERR, never silently dropped, so clients under
//! overload see bounded latency instead of a hung connection.

use std::time::{Duration, Instant};

use crate::metrics::TenantCounters;
use crate::net::FiveTuple;

/// Knobs of the tenant plane (per shard; caps are per-shard too, so a
/// deployment-wide bound is `shards * max_*`).
#[derive(Debug, Clone)]
pub struct TenantPlaneConfig {
    /// Tenant buckets flows are folded into (0/1 = single tenant).
    pub tenants: u32,
    /// Token-bucket refill per tenant, requests/second. 0 = unlimited
    /// (no bucket state, no clock reads).
    pub rate: u64,
    /// Bucket depth (burst allowance). 0 derives one second of `rate`.
    pub burst: u64,
    /// Per-tenant cap on admitted requests in flight. 0 = unlimited.
    pub max_pending: u64,
    /// Per-shard cap on open flows. 0 = unlimited.
    pub max_flows: usize,
    /// Idle-flow eviction TTL in milliseconds.
    pub flow_ttl_ms: u64,
    /// Fair-drain weights by tenant id (missing/zero entries count as
    /// 1). Empty = equal weights.
    pub weights: Vec<u32>,
}

impl Default for TenantPlaneConfig {
    fn default() -> Self {
        TenantPlaneConfig {
            tenants: 1,
            rate: 0,
            burst: 0,
            max_pending: 0,
            max_flows: 0,
            // Long enough that no existing test or bench ever evicts a
            // flow it still cares about; short enough that a churned
            // 10k-flow run returns to steady state.
            flow_ttl_ms: 10_000,
            weights: Vec::new(),
        }
    }
}

/// Admission answer for one tenant at one instant: how many requests
/// may enter, and which bound was the binding one (so rejects are
/// attributed to the right counter).
#[derive(Debug, Clone, Copy)]
pub struct Quota {
    pub allow: u64,
    rate_bound: bool,
}

impl Quota {
    /// Unlimited (used by the fast path when no limits are configured).
    pub fn open() -> Quota {
        Quota { allow: u64::MAX, rate_bound: false }
    }
}

struct Bucket {
    tokens: f64,
    /// Lazily armed on first refill so construction never reads the
    /// clock.
    last: Option<Instant>,
}

/// Per-shard tenant state: buckets + the counter table published to the
/// control plane.
pub struct TenantPlane {
    cfg: TenantPlaneConfig,
    buckets: Vec<Bucket>,
    table: Vec<TenantCounters>,
}

impl TenantPlane {
    pub fn new(cfg: TenantPlaneConfig) -> Self {
        let n = cfg.tenants.max(1) as usize;
        let depth = Self::depth_of(&cfg);
        let buckets = if cfg.rate > 0 {
            (0..n).map(|_| Bucket { tokens: depth, last: None }).collect()
        } else {
            Vec::new()
        };
        let table = (0..n).map(|t| TenantCounters::new(t as u32)).collect();
        TenantPlane { cfg, buckets, table }
    }

    fn depth_of(cfg: &TenantPlaneConfig) -> f64 {
        if cfg.burst > 0 { cfg.burst as f64 } else { cfg.rate.max(1) as f64 }
    }

    pub fn config(&self) -> &TenantPlaneConfig {
        &self.cfg
    }

    /// Whether any per-request limit is configured (fast-path check:
    /// when false, ingest runs with an open quota and the only tenant
    /// cost is counter arithmetic).
    pub fn limited(&self) -> bool {
        self.cfg.rate > 0 || self.cfg.max_pending > 0
    }

    pub fn tenant_of(&self, tuple: &FiveTuple) -> u32 {
        tuple.tenant(self.cfg.tenants)
    }

    pub fn flow_ttl(&self) -> Duration {
        Duration::from_millis(self.cfg.flow_ttl_ms)
    }

    /// Fair-drain weight of a tenant (≥ 1).
    pub fn weight(&self, tenant: u32) -> u64 {
        self.cfg.weights.get(tenant as usize).copied().unwrap_or(1).max(1) as u64
    }

    /// Flow admission: called before creating PEP state for a new flow.
    /// On refusal the counter is charged and the caller forwards the
    /// flow's packets to the host untouched (the stage-1-miss path), so
    /// an over-cap client degrades to un-accelerated service rather
    /// than a black hole.
    pub fn admit_flow(&mut self, tenant: u32, open_flows: usize) -> bool {
        let t = &mut self.table[tenant as usize];
        if self.cfg.max_flows > 0 && open_flows >= self.cfg.max_flows {
            t.flows_rejected += 1;
            false
        } else {
            t.flows += 1;
            true
        }
    }

    pub fn flow_closed(&mut self, tenant: u32) {
        let t = &mut self.table[tenant as usize];
        t.flows = t.flows.saturating_sub(1);
    }

    /// How many requests tenant `tenant` may admit right now.
    pub fn quota(&mut self, tenant: u32, now: Instant) -> Quota {
        if !self.limited() {
            return Quota::open();
        }
        let pending = self.table[tenant as usize].pending;
        let pending_room = if self.cfg.max_pending == 0 {
            u64::MAX
        } else {
            self.cfg.max_pending.saturating_sub(pending)
        };
        let rate_room = if self.cfg.rate == 0 {
            u64::MAX
        } else {
            let depth = Self::depth_of(&self.cfg);
            let b = &mut self.buckets[tenant as usize];
            if let Some(last) = b.last {
                let dt = now.saturating_duration_since(last).as_secs_f64();
                b.tokens = (b.tokens + dt * self.cfg.rate as f64).min(depth);
            }
            b.last = Some(now);
            b.tokens as u64
        };
        Quota {
            allow: rate_room.min(pending_room),
            rate_bound: rate_room < pending_room,
        }
    }

    /// Settle one ingest against the quota it was given: `admitted`
    /// requests consume tokens and raise the pending gauge; `rejected`
    /// requests are charged to whichever bound was binding.
    pub fn settle(&mut self, tenant: u32, quota: Quota, admitted: u64, rejected: u64) {
        let t = &mut self.table[tenant as usize];
        t.admitted += admitted;
        t.pending += admitted;
        if rejected > 0 {
            if quota.rate_bound {
                t.throttled += rejected;
            } else {
                t.rejected_pending += rejected;
            }
        }
        if self.cfg.rate > 0 && admitted > 0 {
            let b = &mut self.buckets[tenant as usize];
            b.tokens = (b.tokens - admitted as f64).max(0.0);
        }
    }

    /// Responses framed for admitted requests drain the pending gauge.
    pub fn on_completed(&mut self, tenant: u32, n: u64) {
        let t = &mut self.table[tenant as usize];
        t.completed += n;
        t.pending = t.pending.saturating_sub(n);
    }

    /// The counter table (indexed by tenant id) for publication.
    pub fn counters(&self) -> &[TenantCounters] {
        &self.table
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_plane_is_open_and_still_counts() {
        let mut p = TenantPlane::new(TenantPlaneConfig::default());
        assert!(!p.limited());
        let q = p.quota(0, Instant::now());
        assert_eq!(q.allow, u64::MAX);
        p.settle(0, q, 3, 0);
        p.on_completed(0, 2);
        let t = &p.counters()[0];
        assert_eq!((t.admitted, t.completed, t.pending), (3, 2, 1));
    }

    #[test]
    fn pending_bound_limits_and_attributes_rejects() {
        let cfg = TenantPlaneConfig { tenants: 2, max_pending: 4, ..Default::default() };
        let mut p = TenantPlane::new(cfg);
        let now = Instant::now();
        let q = p.quota(1, now);
        assert_eq!(q.allow, 4);
        p.settle(1, q, 4, 2); // 4 admitted, 2 bounced over the bound
        let t = &p.counters()[1];
        assert_eq!(t.rejected_pending, 2);
        assert_eq!(t.throttled, 0);
        assert_eq!(p.quota(1, now).allow, 0, "bound reached");
        p.on_completed(1, 4);
        assert_eq!(p.quota(1, now).allow, 4, "completions reopen the bound");
        // Tenant 0 is unaffected.
        assert_eq!(p.quota(0, now).allow, 4);
    }

    #[test]
    fn token_bucket_refills_with_time_and_marks_throttles() {
        let cfg = TenantPlaneConfig { tenants: 1, rate: 100, burst: 10, ..Default::default() };
        let mut p = TenantPlane::new(cfg);
        let t0 = Instant::now();
        let q = p.quota(0, t0);
        assert_eq!(q.allow, 10, "bucket starts full at burst depth");
        p.settle(0, q, 10, 5);
        assert_eq!(p.counters()[0].throttled, 5);
        assert_eq!(p.quota(0, t0).allow, 0, "bucket drained");
        // 55ms at 100 req/s refills 5.5 tokens -> 5 whole ones (the
        // half-token headroom keeps float truncation off the assert).
        let q = p.quota(0, t0 + Duration::from_millis(55));
        assert_eq!(q.allow, 5);
        // Refill never exceeds the depth.
        assert_eq!(p.quota(0, t0 + Duration::from_secs(60)).allow, 10);
    }

    #[test]
    fn flow_cap_rejects_and_gauges_track() {
        let cfg = TenantPlaneConfig { tenants: 1, max_flows: 2, ..Default::default() };
        let mut p = TenantPlane::new(cfg);
        assert!(p.admit_flow(0, 0));
        assert!(p.admit_flow(0, 1));
        assert!(!p.admit_flow(0, 2), "at the cap");
        let t = &p.counters()[0];
        assert_eq!((t.flows, t.flows_rejected), (2, 1));
        p.flow_closed(0);
        assert_eq!(p.counters()[0].flows, 1);
        assert!(p.admit_flow(0, 1));
    }

    #[test]
    fn weights_default_to_one() {
        let cfg = TenantPlaneConfig {
            tenants: 3,
            weights: vec![4, 0],
            ..Default::default()
        };
        let p = TenantPlane::new(cfg);
        assert_eq!(p.weight(0), 4);
        assert_eq!(p.weight(1), 1, "zero weight clamps to 1");
        assert_eq!(p.weight(2), 1, "missing weight defaults to 1");
    }
}
