//! Lock-based ring baseline (§8.5, Fig 17).
//!
//! Producers take a mutex to append; the consumer takes the mutex and
//! drains the whole backlog as one batch (so single-producer throughput
//! is high — Fig 17 shows 22 M op/s — but collapses under producer
//! contention to ~1.4 M op/s at 64 threads).

use std::collections::VecDeque;
use std::sync::Mutex;

use super::{RequestRing, RingStatus};

/// Mutex-protected message ring with batched drain.
pub struct LockedRing {
    inner: Mutex<VecDeque<Vec<u8>>>,
    capacity: usize,
}

impl LockedRing {
    pub fn new(capacity: usize) -> Self {
        LockedRing { inner: Mutex::new(VecDeque::with_capacity(capacity)), capacity }
    }
}

impl RequestRing for LockedRing {
    fn try_push(&self, msg: &[u8]) -> RingStatus {
        let mut q = self.inner.lock().unwrap();
        if q.len() >= self.capacity {
            return RingStatus::Retry;
        }
        // LINT: copy-ok(lock-based BASELINE ring — the copy is the point of
        // the §8.5 comparison; the zero-copy path is ProgressRing)
        q.push_back(msg.to_vec());
        RingStatus::Ok
    }

    fn pop_batch(&self, f: &mut dyn FnMut(&[u8])) -> usize {
        let batch: Vec<Vec<u8>> = {
            let mut q = self.inner.lock().unwrap();
            q.drain(..).collect()
        };
        for m in &batch {
            f(m);
        }
        batch.len()
    }

    fn name(&self) -> &'static str {
        "lock-based"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn capacity_bound() {
        let r = LockedRing::new(2);
        assert_eq!(r.try_push(b"a"), RingStatus::Ok);
        assert_eq!(r.try_push(b"b"), RingStatus::Ok);
        assert_eq!(r.try_push(b"c"), RingStatus::Retry);
    }

    #[test]
    fn drains_in_order() {
        let r = LockedRing::new(16);
        for i in 0..5u8 {
            r.try_push(&[i]);
        }
        let mut got = Vec::new();
        assert_eq!(r.pop_batch(&mut |m| got.push(m[0])), 5);
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn concurrent_producers() {
        let r = Arc::new(LockedRing::new(1 << 14));
        // Shrunk under Miri — lock-contention shape over volume.
        let per = if cfg!(miri) { 50u32 } else { 1000u32 };
        let mut handles = Vec::new();
        for _ in 0..8 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    while r.try_push(&i.to_le_bytes()) != RingStatus::Ok {}
                }
            }));
        }
        let consumer = {
            let r = r.clone();
            std::thread::spawn(move || {
                let mut total = 0usize;
                while total < 8 * per as usize {
                    total += r.pop_batch(&mut |_| {});
                }
                total
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(consumer.join().unwrap(), 8 * per as usize);
    }
}
