//! Response ring: single DPU producer, multiple host consumers (§4.1:
//! "Response rings are similarly designed: the DPU is the single
//! producer, and the host application threads are the consumers").
//!
//! Records are length-prefixed like the request ring. Consumers claim
//! records by CAS on the head offset; the producer (the DPU DMA thread)
//! appends batches and advances the tail with a single release store —
//! on hardware that store is the completion of a batched DMA-write
//! (§4.3 TailC advance).

use std::sync::atomic::{AtomicU64, Ordering};

use super::{align8, CacheLine, RingStatus};
use crate::dma::{DmaChannel, DmaDir};

/// SPMC byte ring for responses.
pub struct ResponseRing {
    head: CacheLine<AtomicU64>,
    tail: CacheLine<AtomicU64>,
    buf: Box<[std::cell::UnsafeCell<u8>]>,
    mask: u64,
}

// SAFETY: the producer writes only [tail, tail+need) before publishing
// via the tail store; consumers read only below tail, and each record is
// claimed by exactly one consumer through the head CAS. Claimed space is
// not reused until head passes it (capacity check on push).
unsafe impl Send for ResponseRing {}
unsafe impl Sync for ResponseRing {}

impl ResponseRing {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity.is_power_of_two());
        ResponseRing {
            head: CacheLine(AtomicU64::new(0)),
            tail: CacheLine(AtomicU64::new(0)),
            buf: (0..capacity)
                .map(|_| std::cell::UnsafeCell::new(0u8))
                .collect::<Vec<_>>()
                .into_boxed_slice(),
            mask: capacity as u64 - 1,
        }
    }

    fn capacity(&self) -> u64 {
        self.mask + 1
    }

    /// Wrap-splitting memcpy (perf pass L3-1; see
    /// `ProgressRing::write_bytes`).
    #[inline]
    fn write_bytes(&self, at: u64, data: &[u8]) {
        let cap = self.buf.len();
        let start = (at & self.mask) as usize;
        let first = data.len().min(cap - start);
        // SAFETY: only the single producer calls this, on [tail, tail+need)
        // which the capacity check proved unclaimed; `start`/`first` are
        // mask-bounded so both copies stay inside `buf` (struct invariants).
        unsafe {
            let base = self.buf.as_ptr() as *mut u8;
            std::ptr::copy_nonoverlapping(data.as_ptr(), base.add(start), first);
            if first < data.len() {
                std::ptr::copy_nonoverlapping(
                    data.as_ptr().add(first),
                    base,
                    data.len() - first,
                );
            }
        }
    }

    #[inline]
    fn read_bytes(&self, at: u64, out: &mut [u8]) {
        let cap = self.buf.len();
        let start = (at & self.mask) as usize;
        let first = out.len().min(cap - start);
        // SAFETY: consumers call this only on records below the Acquire-read
        // tail (payload writes ordered-before by the producer's Release
        // publish); `start`/`first` are mask-bounded so both copies stay
        // inside `buf` (struct invariants).
        unsafe {
            let base = self.buf.as_ptr() as *const u8;
            std::ptr::copy_nonoverlapping(base.add(start), out.as_mut_ptr(), first);
            if first < out.len() {
                std::ptr::copy_nonoverlapping(base, out.as_mut_ptr().add(first), out.len() - first);
            }
        }
    }

    /// Producer (DPU DMA thread): append one response; `dma` accounts the
    /// DMA write of the record.
    pub fn push_dma(&self, dma: &DmaChannel, msg: &[u8]) -> RingStatus {
        self.push_vectored_dma(dma, &[msg])
    }

    /// Vectored producer push: one record assembled from `parts` written
    /// back-to-back — the scatter-gather DMA of §4.3 (response header +
    /// pre-allocated read buffer), with no intermediate concatenation
    /// buffer. One DMA write regardless of part count.
    pub fn push_vectored_dma(&self, dma: &DmaChannel, parts: &[&[u8]]) -> RingStatus {
        let msg_len: usize = parts.iter().map(|p| p.len()).sum();
        let need = align8(4 + msg_len) as u64;
        // LINT: relaxed-ok(single producer owns tail; the Release store below is the publish)
        let tail = self.tail.0.load(Ordering::Relaxed);
        let head = self.head.0.load(Ordering::Acquire);
        if tail - head + need > self.capacity() {
            return RingStatus::Retry;
        }
        dma.op(DmaDir::Write, need as usize);
        self.write_bytes(tail, &(msg_len as u32).to_le_bytes());
        let mut at = tail + 4;
        for p in parts {
            self.write_bytes(at, p);
            at += p.len() as u64;
        }
        self.tail.0.store(tail + need, Ordering::Release);
        RingStatus::Ok
    }

    /// Burst producer push: each item is one vectored record (e.g.
    /// response header + payload view). Writes as many whole records as
    /// fit — record bytes land past the published tail, which the
    /// single producer owns — then accounts ONE batched DMA write for
    /// the burst and publishes with a single tail release store (§4.3:
    /// responses are DMA-written "in batches"; the tail advance IS the
    /// batch completion). Returns how many records were pushed; a
    /// shortfall means the ring filled mid-burst and the rest should be
    /// retried after the consumers drain.
    pub fn push_burst_vectored_dma<'a>(
        &self,
        dma: &DmaChannel,
        records: impl Iterator<Item = [&'a [u8]; 2]>,
    ) -> usize {
        let head = self.head.0.load(Ordering::Acquire);
        // LINT: relaxed-ok(single producer owns tail; the Release store below is the publish)
        let tail0 = self.tail.0.load(Ordering::Relaxed);
        let mut tail = tail0;
        let mut pushed = 0usize;
        for parts in records {
            let msg_len: usize = parts.iter().map(|p| p.len()).sum();
            let need = align8(4 + msg_len) as u64;
            if tail - head + need > self.capacity() {
                break;
            }
            self.write_bytes(tail, &(msg_len as u32).to_le_bytes());
            let mut at = tail + 4;
            for p in parts {
                self.write_bytes(at, p);
                at += p.len() as u64;
            }
            tail += need;
            pushed += 1;
        }
        if pushed > 0 {
            dma.op(DmaDir::Write, (tail - tail0) as usize);
            self.tail.0.store(tail, Ordering::Release);
        }
        pushed
    }

    /// Non-DMA producer path (tests / host-local use).
    pub fn push(&self, msg: &[u8]) -> RingStatus {
        thread_local! {
            static NULL_DMA: DmaChannel = DmaChannel::new();
        }
        NULL_DMA.with(|d| self.push_dma(d, msg))
    }

    /// Consumer (host application thread): claim and read one response.
    /// Purely local memory operations on the host — no DMA, no locks
    /// (§4.1 goal 2).
    pub fn pop(&self, f: &mut dyn FnMut(&[u8])) -> RingStatus {
        loop {
            let head = self.head.0.load(Ordering::Acquire);
            let tail = self.tail.0.load(Ordering::Acquire);
            if head == tail {
                return RingStatus::Empty;
            }
            let mut len4 = [0u8; 4];
            self.read_bytes(head, &mut len4);
            let len = u32::from_le_bytes(len4) as usize;
            let need = align8(4 + len) as u64;
            // Claim the record before reading the payload.
            // LINT: relaxed-ok(CAS failure ordering; the retry re-loads head with Acquire)
            if self
                .head
                .0
                .compare_exchange_weak(head, head + need, Ordering::AcqRel, Ordering::Relaxed)
                .is_err()
            {
                continue;
            }
            let mut payload = vec![0u8; len];
            self.read_bytes(head + 4, &mut payload);
            f(&payload);
            return RingStatus::Ok;
        }
    }
}

/// Exhaustive model checks of the SPMC publish/claim protocol
/// (correctness plane; see DESIGN.md). `MiniRing` is a colocated
/// SKELETON of [`ResponseRing`]'s ordering — payload slots in
/// `loom::cell::UnsafeCell` (loom cannot track the production ring's
/// raw byte buffer, and the cell checker is what makes the race
/// detection non-vacuous), tail Release-published by a single
/// producer, records claimed by head CAS. Run with
/// `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_`.
#[cfg(all(loom, test))]
mod loom_models {
    use loom::cell::UnsafeCell;
    use loom::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    struct MiniRing {
        head: AtomicU64,
        tail: AtomicU64,
        slots: [UnsafeCell<u64>; 2],
    }

    // SAFETY: same shape as ResponseRing's — the producer writes only
    // slots at/past the published tail; consumers read only below an
    // Acquire-loaded tail, each slot claimed by exactly one head CAS.
    // loom's cell checker verifies this claim on every interleaving.
    unsafe impl Send for MiniRing {}
    unsafe impl Sync for MiniRing {}

    impl MiniRing {
        fn new() -> Arc<Self> {
            Arc::new(MiniRing {
                head: AtomicU64::new(0),
                tail: AtomicU64::new(0),
                slots: [UnsafeCell::new(0), UnsafeCell::new(0)],
            })
        }

        /// Producer: write the record, then publish — the Release
        /// store IS the §4.3 TailC advance.
        fn push(&self, slot: usize, v: u64, publish_order: Ordering) {
            self.slots[slot].with_mut(|p| unsafe { *p = v });
            self.tail.store(slot as u64 + 1, publish_order);
        }

        /// Consumer: one claim attempt. `None` = empty or lost the
        /// CAS; the caller's loop stays bounded because head only
        /// advances.
        fn try_pop(&self) -> Option<(u64, u64)> {
            let head = self.head.load(Ordering::Acquire);
            let tail = self.tail.load(Ordering::Acquire);
            if head == tail {
                return None;
            }
            if self
                .head
                .compare_exchange(head, head + 1, Ordering::AcqRel, Ordering::Relaxed)
                .is_err()
            {
                return None;
            }
            let v = self.slots[head as usize].with(|p| unsafe { *p });
            Some((head, v))
        }
    }

    /// Protocol 3 (soundness) — tail publish vs consumer snapshot. A
    /// consumer that observes the advanced tail must also observe the
    /// record bytes written before the Release store; loom's cell
    /// checker fails any interleaving where the payload read is not
    /// happens-before ordered against the producer's write.
    #[test]
    fn loom_response_ring_publish_is_release() {
        loom::model(|| {
            let ring = MiniRing::new();
            let producer = {
                let ring = ring.clone();
                loom::thread::spawn(move || ring.push(0, 7, Ordering::Release))
            };
            // One attempt per interleaving: seeing tail == 1 without the
            // payload ordered behind it would be the bug.
            if let Some((slot, v)) = ring.try_pop() {
                assert_eq!((slot, v), (0, 7));
            }
            producer.join().unwrap();
        });
    }

    /// Protocol 3 (exclusivity) — two consumers racing head CAS over
    /// two published records: every record claimed exactly once,
    /// payloads intact.
    #[test]
    fn loom_response_ring_unique_claim() {
        loom::model(|| {
            let ring = MiniRing::new();
            ring.push(0, 100, Ordering::Release);
            ring.push(1, 101, Ordering::Release);
            let consumers: Vec<_> = (0..2)
                .map(|_| {
                    let ring = ring.clone();
                    loom::thread::spawn(move || {
                        let mut got = Vec::new();
                        // Bounded: each iteration claims, loses a CAS
                        // another consumer won (head advanced), or
                        // exits on empty.
                        for _ in 0..3 {
                            if let Some(rec) = ring.try_pop() {
                                got.push(rec);
                            }
                        }
                        got
                    })
                })
                .collect();
            let mut all: Vec<(u64, u64)> =
                consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
            all.sort_unstable();
            assert_eq!(all, vec![(0, 100), (1, 101)], "each record claimed exactly once");
        });
    }

    /// Mutation self-test: demote the tail publish to Relaxed and the
    /// consumer can observe the advanced tail with the payload write
    /// unordered behind it — loom's cell checker must flag the
    /// concurrent unsynchronized access and panic. If this stops
    /// panicking, the model has gone vacuous.
    #[test]
    #[should_panic]
    fn loom_response_ring_mutation_relaxed_publish_races() {
        loom::model(|| {
            let ring = MiniRing::new();
            let producer = {
                let ring = ring.clone();
                loom::thread::spawn(move || ring.push(0, 7, Ordering::Relaxed))
            };
            if let Some((slot, v)) = ring.try_pop() {
                assert_eq!((slot, v), (0, 7));
            }
            producer.join().unwrap();
        });
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn spmc_roundtrip() {
        let r = ResponseRing::new(1024);
        for i in 0..10u32 {
            assert_eq!(r.push(&i.to_le_bytes()), RingStatus::Ok);
        }
        let mut got = Vec::new();
        while r.pop(&mut |m| got.push(u32::from_le_bytes(m.try_into().unwrap())))
            == RingStatus::Ok
        {}
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn full_retry() {
        let r = ResponseRing::new(64);
        let mut pushed = 0;
        while r.push(&[0u8; 8]) == RingStatus::Ok {
            pushed += 1;
        }
        assert_eq!(pushed, 4); // 64 / align8(12)=16
    }

    #[test]
    fn vectored_push_matches_contiguous_record() {
        let r = ResponseRing::new(1024);
        let dma = DmaChannel::new();
        let header = [1u8, 2, 3];
        let payload = [9u8; 40];
        assert_eq!(r.push_vectored_dma(&dma, &[&header, &payload]), RingStatus::Ok);
        assert_eq!(dma.writes(), 1, "one DMA write for the whole record");
        let mut got = Vec::new();
        r.pop(&mut |m| got.push(m.to_vec()));
        let mut expect = header.to_vec();
        expect.extend_from_slice(&payload);
        assert_eq!(got, vec![expect]);
    }

    #[test]
    fn burst_push_one_dma_write_one_publish() {
        let r = ResponseRing::new(1024);
        let dma = DmaChannel::new();
        let payloads: Vec<[u8; 4]> = (0..8u32).map(|i| i.to_le_bytes()).collect();
        let header = [7u8; 3];
        let pushed = r.push_burst_vectored_dma(
            &dma,
            payloads.iter().map(|p| [&header[..], &p[..]]),
        );
        assert_eq!(pushed, 8);
        assert_eq!(dma.writes(), 1, "one batched DMA write for the whole burst");
        let mut got = Vec::new();
        while r.pop(&mut |m| got.push(m.to_vec())) == RingStatus::Ok {}
        assert_eq!(got.len(), 8, "every record delivered");
        for (i, rec) in got.iter().enumerate() {
            assert_eq!(&rec[..3], &header, "record {i} header");
            assert_eq!(&rec[3..], &(i as u32).to_le_bytes(), "record {i} payload");
        }
    }

    #[test]
    fn burst_push_partial_on_full_ring() {
        let r = ResponseRing::new(64); // fits 4 records of align8(4+8)=16
        let recs: Vec<[u8; 8]> = (0..6u64).map(|i| i.to_le_bytes()).collect();
        let empty: &[u8] = &[];
        let pushed =
            r.push_burst_vectored_dma(&DmaChannel::new(), recs.iter().map(|p| [&p[..], empty]));
        assert_eq!(pushed, 4, "stops at the first record that does not fit");
        let mut got = Vec::new();
        while r.pop(&mut |m| got.push(u64::from_le_bytes(m.try_into().unwrap())))
            == RingStatus::Ok
        {}
        assert_eq!(got, vec![0, 1, 2, 3], "pushed prefix is intact and in order");
    }

    #[test]
    fn vectored_push_respects_capacity() {
        let r = ResponseRing::new(64);
        let big = [0u8; 61]; // align8(4 + 61) = 72 > 64
        assert_eq!(r.push_vectored_dma(&DmaChannel::new(), &[&big]), RingStatus::Retry);
    }

    #[test]
    fn concurrent_consumers_unique_claims() {
        use std::sync::atomic::AtomicU32;
        let r = Arc::new(ResponseRing::new(1 << 16));
        // Volume shrunk under Miri (interpreter overhead); the SPMC
        // claim-race shape — 1 producer, 4 CAS-racing consumers — is
        // what the UB check needs, not the byte count.
        let total = if cfg!(miri) { 200u32 } else { 20_000u32 };
        let consumed = Arc::new(AtomicU32::new(0));
        let producer = {
            let r = r.clone();
            std::thread::spawn(move || {
                for i in 0..total {
                    while r.push(&i.to_le_bytes()) != RingStatus::Ok {
                        std::hint::spin_loop();
                    }
                }
            })
        };
        let mut consumers = Vec::new();
        for _ in 0..4 {
            let r = r.clone();
            let consumed = consumed.clone();
            consumers.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while consumed.load(Ordering::Relaxed) < total {
                    if r.pop(&mut |m| got.push(u32::from_le_bytes(m.try_into().unwrap())))
                        == RingStatus::Ok
                    {
                        consumed.fetch_add(1, Ordering::Relaxed);
                    } else {
                        std::hint::spin_loop();
                    }
                }
                got
            }));
        }
        producer.join().unwrap();
        let mut all: Vec<u32> =
            consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        // Every record delivered exactly once across consumers.
        assert_eq!(all, (0..total).collect::<Vec<_>>());
    }
}
