//! The progress-pointer lock-free ring (§4.1, Figs 7 & 8).
//!
//! Layout mirrors Figure 7 (right): a pointer area holding `Head`,
//! `Progress`, `Tail` — each cache-line aligned, with **`P` placed
//! immediately before `T`** so the consumer's `P == T` check (Fig 8b)
//! needs a single DMA read of one contiguous region — followed by the
//! data buffer.
//!
//! Pointers are monotonically increasing byte offsets (never wrapped);
//! the data index is `offset & (capacity-1)`. Records are
//! `u32 len | payload | pad-to-8`.
//!
//! Producer (Fig 8a): check `Tail - Head < M` (M = max allowable
//! progress — bounds both backlog and batch size), CAS-reserve `Tail`,
//! copy the record, then publish by CAS-advancing `Progress` from the
//! reserved start to its end — which naturally spins until all earlier
//! reservations have published, giving in-order visibility without locks.
//!
//! Consumer (Fig 8b, single thread, DPU side): load `P` and `T` (one DMA
//! read), if `P != T` some producer is mid-insert → RETRY; otherwise read
//! `[H, P)` in one DMA and advance `H`.

use std::sync::atomic::{AtomicU64, Ordering};

use super::{align8, CacheLine, RequestRing, RingStatus};
use crate::buf::{BufPool, BufView};
use crate::dma::{DmaChannel, DmaDir};

/// DMA-backed lock-free MPSC byte ring with a progress pointer.
pub struct ProgressRing {
    /// Consumer-owned: start of unconsumed data.
    head: CacheLine<AtomicU64>,
    /// Publish frontier: everything below is fully written.
    /// NOTE: laid out before `tail` (see module docs).
    progress: CacheLine<AtomicU64>,
    /// Reservation frontier.
    tail: CacheLine<AtomicU64>,
    buf: Box<[std::cell::UnsafeCell<u8>]>,
    mask: u64,
    /// Maximum allowable progress (bytes of outstanding backlog).
    max_progress: u64,
}

// SAFETY: all mutable buffer accesses are disjoint by construction —
// producers write only their CAS-reserved [start, end) slice before
// publishing it via `progress`, and the consumer reads only fully
// published regions `[head, progress)`.
unsafe impl Send for ProgressRing {}
unsafe impl Sync for ProgressRing {}

impl ProgressRing {
    /// `capacity` must be a power of two; `max_progress` (the paper's M)
    /// bounds outstanding bytes and must be ≤ capacity.
    pub fn new(capacity: usize, max_progress: usize) -> Self {
        assert!(capacity.is_power_of_two(), "capacity must be a power of two");
        assert!(max_progress <= capacity && max_progress >= 16);
        let buf = (0..capacity)
            .map(|_| std::cell::UnsafeCell::new(0u8))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        ProgressRing {
            head: CacheLine(AtomicU64::new(0)),
            progress: CacheLine(AtomicU64::new(0)),
            tail: CacheLine(AtomicU64::new(0)),
            buf,
            mask: capacity as u64 - 1,
            max_progress: max_progress as u64,
        }
    }

    /// Copy into the ring with at most two `memcpy`s (wrap split).
    /// Perf pass L3-1: the original byte-at-a-time loop with per-byte
    /// masking capped 8 KB messages at ~1 GB/s (EXPERIMENTS.md §Perf).
    #[inline]
    fn write_bytes(&self, at: u64, data: &[u8]) {
        let cap = self.buf.len();
        let start = (at & self.mask) as usize;
        let first = data.len().min(cap - start);
        // SAFETY: region [at, at+len) is exclusively reserved by the
        // caller's successful tail CAS; UnsafeCell<u8> slices are
        // layout-compatible with u8.
        unsafe {
            let base = self.buf.as_ptr() as *mut u8;
            std::ptr::copy_nonoverlapping(data.as_ptr(), base.add(start), first);
            if first < data.len() {
                std::ptr::copy_nonoverlapping(
                    data.as_ptr().add(first),
                    base,
                    data.len() - first,
                );
            }
        }
    }

    #[inline]
    fn read_bytes(&self, at: u64, out: &mut [u8]) {
        let cap = self.buf.len();
        let start = (at & self.mask) as usize;
        let first = out.len().min(cap - start);
        // SAFETY: region is published (below progress) and unreleased
        // (above head); producers cannot touch it until head passes.
        unsafe {
            let base = self.buf.as_ptr() as *const u8;
            std::ptr::copy_nonoverlapping(base.add(start), out.as_mut_ptr(), first);
            if first < out.len() {
                std::ptr::copy_nonoverlapping(base, out.as_mut_ptr().add(first), out.len() - first);
            }
        }
    }

    /// Fig 8a with an explicit DMA channel (host side: plain loads —
    /// channel unused; kept for symmetric benches).
    pub fn try_push_inner(&self, msg: &[u8]) -> RingStatus {
        let need = align8(4 + msg.len()) as u64;
        assert!(need <= self.max_progress, "message larger than max progress");
        loop {
            // NOTE: Fig 8a lists `LoadTail` before `LoadHead`; we load
            // head FIRST. With the paper's order, a concurrent consumer
            // can advance `head` past our stale `tail` snapshot between
            // the two loads and `tail - head` underflows. Loading head
            // first keeps the snapshot conservative (head only moves
            // forward, so we may see *more* backlog than exists — never
            // less) and the check sound.
            let head = self.head.0.load(Ordering::Acquire);
            let tail = self.tail.0.load(Ordering::Acquire);
            // Fig 8a line 3: backlog / batch bound.
            if tail - head + need > self.max_progress {
                return RingStatus::Retry;
            }
            // Fig 8a line 4: IncTail(N) — reserve.
            // LINT: relaxed-ok(CAS failure ordering; the retry re-loads with Acquire)
            if self
                .tail
                .0
                .compare_exchange_weak(tail, tail + need, Ordering::AcqRel, Ordering::Relaxed)
                .is_err()
            {
                continue;
            }
            // Fig 8a line 5: insert the request.
            let len = (msg.len() as u32).to_le_bytes();
            self.write_bytes(tail, &len);
            self.write_bytes(tail + 4, msg);
            // Fig 8a line 6: IncProg(N) — publish in order. CAS spins
            // until progress reaches our start.
            // LINT: relaxed-ok(CAS failure ordering on the publish spin — failures only spin)
            while self
                .progress
                .0
                .compare_exchange_weak(tail, tail + need, Ordering::AcqRel, Ordering::Relaxed)
                .is_err()
            {
                std::hint::spin_loop();
            }
            return RingStatus::Ok;
        }
    }

    /// Fig 8b: consume the full published batch via a DMA channel.
    ///
    /// Counts exactly the DMA ops the paper's design performs: one read
    /// covering `P`+`T` (adjacent lines), one read for the batch data,
    /// one write for the head update.
    pub fn pop_batch_dma(&self, dma: &DmaChannel, f: &mut dyn FnMut(&[u8])) -> usize {
        // One DMA read fetches both P and T (layout: P immediately
        // before T).
        dma.op(DmaDir::Read, 16);
        let prog = self.progress.0.load(Ordering::Acquire);
        let tail = self.tail.0.load(Ordering::Acquire);
        let head = self.head.0.load(Ordering::Acquire); // DPU-local copy
        if prog != tail {
            // Fig 8b: reservation in flight — RETRY.
            return 0;
        }
        if prog == head {
            return 0;
        }
        let batch = (prog - head) as usize;
        dma.op(DmaDir::Read, batch);
        // Perf pass L3-2: reuse the DPU-side staging buffer across
        // drains (the copy itself is semantic — it IS the DMA read into
        // DPU memory — but reallocating it per batch is not).
        thread_local! {
            static SCRATCH: std::cell::RefCell<Vec<u8>> = const { std::cell::RefCell::new(Vec::new()) };
        }
        SCRATCH.with(|s| {
            let mut tmp = s.borrow_mut();
            if tmp.len() < batch {
                tmp.resize(batch, 0);
            }
            let tmp = &mut tmp[..batch];
            self.read_bytes(head, tmp);
            let mut consumed = 0usize;
            let mut n = 0usize;
            while consumed < batch {
                let len =
                    u32::from_le_bytes(tmp[consumed..consumed + 4].try_into().unwrap()) as usize;
                f(&tmp[consumed + 4..consumed + 4 + len]);
                consumed += align8(4 + len);
                n += 1;
            }
            // Fig 8b line 6: IncHead — one DMA write of the head word.
            dma.op(DmaDir::Write, 8);
            self.head.0.store(prog, Ordering::Release);
            n
        })
    }

    /// Fig 8b drain into a *pooled* DPU-side buffer: the one DMA read
    /// of the batch lands in a borrowed [`BufPool`] slot, and each
    /// record is handed to `f` as a refcounted sub-view of it — zero
    /// per-record copies and, in steady state, zero heap allocations
    /// (the pool hit replaces `pop_batch_dma`'s thread-local scratch).
    /// DMA accounting is identical to [`Self::pop_batch_dma`].
    pub fn pop_batch_views_dma(
        &self,
        dma: &DmaChannel,
        pool: &BufPool,
        f: &mut dyn FnMut(BufView),
    ) -> usize {
        dma.op(DmaDir::Read, 16);
        let prog = self.progress.0.load(Ordering::Acquire);
        let tail = self.tail.0.load(Ordering::Acquire);
        let head = self.head.0.load(Ordering::Acquire); // DPU-local copy
        if prog != tail || prog == head {
            return 0;
        }
        let batch = (prog - head) as usize;
        dma.op(DmaDir::Read, batch);
        let mut buf = pool.allocate(batch);
        self.read_bytes(head, buf.as_mut_slice());
        let batch_view = buf.freeze();
        let bytes = batch_view.as_slice();
        let mut consumed = 0usize;
        let mut n = 0usize;
        while consumed < batch {
            let len =
                u32::from_le_bytes(bytes[consumed..consumed + 4].try_into().unwrap()) as usize;
            f(batch_view.slice(consumed + 4..consumed + 4 + len));
            consumed += align8(4 + len);
            n += 1;
        }
        // Fig 8b line 6: IncHead — one DMA write of the head word.
        dma.op(DmaDir::Write, 8);
        self.head.0.store(prog, Ordering::Release);
        n
    }

    /// Bytes currently reserved but unconsumed.
    pub fn backlog(&self) -> u64 {
        self.tail.0.load(Ordering::Acquire) - self.head.0.load(Ordering::Acquire)
    }

    /// The configured maximum allowable progress (M).
    pub fn max_progress(&self) -> u64 {
        self.max_progress
    }
}

impl RequestRing for ProgressRing {
    fn try_push(&self, msg: &[u8]) -> RingStatus {
        self.try_push_inner(msg)
    }

    fn pop_batch(&self, f: &mut dyn FnMut(&[u8])) -> usize {
        // Accounting-only channel for the trait path.
        thread_local! {
            static NULL_DMA: DmaChannel = DmaChannel::new();
        }
        NULL_DMA.with(|d| self.pop_batch_dma(d, f))
    }

    fn name(&self) -> &'static str {
        "progress-lockfree"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn push_pop_single() {
        let r = ProgressRing::new(1024, 256);
        assert_eq!(r.try_push(b"hello"), RingStatus::Ok);
        assert_eq!(r.try_push(b"world!"), RingStatus::Ok);
        let mut got = Vec::new();
        let n = r.pop_batch(&mut |m| got.push(m.to_vec()));
        assert_eq!(n, 2);
        assert_eq!(got, vec![b"hello".to_vec(), b"world!".to_vec()]);
    }

    #[test]
    fn batch_limit_returns_retry() {
        let r = ProgressRing::new(1024, 64);
        // Each 8-byte msg occupies align8(12)=16 bytes; 4 fit in M=64.
        for _ in 0..4 {
            assert_eq!(r.try_push(&[7u8; 8]), RingStatus::Ok);
        }
        assert_eq!(r.try_push(&[7u8; 8]), RingStatus::Retry);
        // Drain unblocks producers.
        let mut cnt = 0;
        r.pop_batch(&mut |_| cnt += 1);
        assert_eq!(cnt, 4);
        assert_eq!(r.try_push(&[7u8; 8]), RingStatus::Ok);
    }

    #[test]
    fn wraparound_preserves_data() {
        let r = ProgressRing::new(128, 64);
        for round in 0..100u32 {
            let msg = [round as u8; 24];
            assert_eq!(r.try_push(&msg), RingStatus::Ok);
            let mut got = Vec::new();
            assert_eq!(r.pop_batch(&mut |m| got.push(m.to_vec())), 1);
            assert_eq!(got[0], msg);
        }
    }

    #[test]
    fn dma_op_counts_match_design() {
        // One batched drain = 1 pointer read + 1 data read + 1 head write,
        // regardless of how many messages are in the batch (§4.1).
        let r = ProgressRing::new(4096, 1024);
        for _ in 0..10 {
            r.try_push(&[1u8; 8]);
        }
        let dma = DmaChannel::new();
        let mut n = 0;
        r.pop_batch_dma(&dma, &mut |_| n += 1);
        assert_eq!(n, 10);
        assert_eq!(dma.reads(), 2);
        assert_eq!(dma.writes(), 1);
    }

    #[test]
    fn pooled_view_drain_matches_copy_drain() {
        let r = ProgressRing::new(4096, 1024);
        let msgs: Vec<Vec<u8>> = (0..7u8).map(|i| vec![i; 3 + i as usize * 5]).collect();
        for m in &msgs {
            assert_eq!(r.try_push(m), RingStatus::Ok);
        }
        let pool = crate::buf::BufPool::new(2, 4096);
        let dma = DmaChannel::new();
        let mut got: Vec<BufView> = Vec::new();
        let n = r.pop_batch_views_dma(&dma, &pool, &mut |v| got.push(v));
        assert_eq!(n, msgs.len());
        for (g, m) in got.iter().zip(&msgs) {
            assert_eq!(g, m);
        }
        // All records alias the single batch buffer.
        for w in got.windows(2) {
            assert!(w[0].shares_storage(&w[1]));
        }
        // Same DMA shape as the copying drain: 2 reads + 1 write.
        assert_eq!((dma.reads(), dma.writes()), (2, 1));
        // One pool hit for the whole batch; slot returns when views go.
        let s = pool.stats();
        assert_eq!((s.allocs, s.pool_hits, s.fallbacks), (1, 1, 0));
        drop(got);
        assert_eq!(pool.available(), 2);
    }

    #[test]
    fn mpsc_no_loss_no_dup() {
        let r = Arc::new(ProgressRing::new(1 << 16, 1 << 12));
        let producers = 8;
        // Miri's interpreter pays ~1000x per instruction; keep the
        // interleaving shape (8 producers racing one batch consumer)
        // but shrink the volume so the aliasing/UB check stays tractable.
        let per = if cfg!(miri) { 50u64 } else { 5_000u64 };
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for p in 0..producers {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    let v = (p as u64) << 32 | i;
                    loop {
                        if r.try_push(&v.to_le_bytes()) == RingStatus::Ok {
                            break;
                        }
                        std::hint::spin_loop();
                    }
                }
            }));
        }
        let consumer = {
            let r = r.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut seen = vec![0u64; producers]; // next expected per producer
                let mut total = 0u64;
                while total < per * producers as u64 {
                    // Read `stop` BEFORE popping: stop ⇒ all producers
                    // joined ⇒ everything is published, so an empty pop
                    // now really means the ring is drained. (Checking
                    // stop after an empty pop races with in-flight
                    // insertions and can exit early.)
                    let stopped = stop.load(Ordering::Relaxed);
                    let n = r.pop_batch(&mut |m| {
                        let v = u64::from_le_bytes(m.try_into().unwrap());
                        let p = (v >> 32) as usize;
                        let i = v & 0xffff_ffff;
                        assert_eq!(i, seen[p], "per-producer FIFO order violated");
                        seen[p] += 1;
                    });
                    total += n as u64;
                    if stopped && n == 0 {
                        break;
                    }
                }
                total
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        let total = consumer.join().unwrap();
        assert_eq!(total, per * producers as u64);
    }
}
