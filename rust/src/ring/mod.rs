//! Host↔DPU message rings (§4.1, Figs 7/8/17).
//!
//! Three implementations share the [`RequestRing`] interface so the
//! Fig 17 bench can compare them head-to-head:
//!
//! * [`ProgressRing`] — the paper's contribution: a DMA-backed lock-free
//!   MPSC ring with a third *progress* pointer that lets concurrent
//!   producers publish in order and lets the single consumer drain whole
//!   batches with a single pointer check (one DMA read covers both
//!   `P` and `T` because `P` is laid out immediately before `T`).
//! * [`FarmRing`] — FaRM-style baseline: per-message valid flags, no
//!   batching, consumer polls flags and must DMA-write to release each
//!   slot.
//! * [`LockedRing`] — mutex-protected ring with batching.
//!
//! The response direction (single DPU producer, multiple host consumers)
//! is provided by [`ResponseRing`].

mod farm;
mod locked;
mod progress;
mod response;

pub use farm::FarmRing;
pub use locked::LockedRing;
pub use progress::ProgressRing;
pub use response::ResponseRing;

/// Result of a non-blocking ring operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RingStatus {
    Ok,
    /// Ring full / batch limit reached / consumer should retry.
    Retry,
    /// Nothing to consume.
    Empty,
}

/// Common interface of the three request-ring designs (host-side
/// producers, one DPU-side consumer).
pub trait RequestRing: Send + Sync {
    /// Try to insert one message; non-blocking.
    fn try_push(&self, msg: &[u8]) -> RingStatus;

    /// Drain available messages into `f`; returns the number consumed.
    /// Non-blocking; `Retry` conditions yield 0.
    fn pop_batch(&self, f: &mut dyn FnMut(&[u8])) -> usize;

    /// Ring name for reports.
    fn name(&self) -> &'static str;
}

/// Pad-to-cache-line wrapper used by all ring pointer words.
#[repr(align(64))]
#[derive(Default)]
pub(crate) struct CacheLine<T>(pub T);

/// Round a record length up to 8-byte alignment.
#[inline]
pub(crate) fn align8(n: usize) -> usize {
    (n + 7) & !7
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn roundtrip(ring: Arc<dyn RequestRing>) {
        // Simple single-thread roundtrip for every implementation.
        for i in 0..100u32 {
            let msg = i.to_le_bytes();
            assert_eq!(ring.try_push(&msg), RingStatus::Ok, "push {i}");
            let mut got = Vec::new();
            while ring.pop_batch(&mut |m| got.push(u32::from_le_bytes(m.try_into().unwrap())))
                == 0
            {}
            assert_eq!(got, vec![i]);
        }
    }

    #[test]
    fn roundtrip_all_designs() {
        roundtrip(Arc::new(ProgressRing::new(1 << 12, 1 << 10)));
        roundtrip(Arc::new(FarmRing::new(64, 64)));
        roundtrip(Arc::new(LockedRing::new(1 << 10)));
    }

    #[test]
    fn align8_works() {
        assert_eq!(align8(0), 0);
        assert_eq!(align8(1), 8);
        assert_eq!(align8(8), 8);
        assert_eq!(align8(9), 16);
    }
}
