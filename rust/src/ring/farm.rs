//! FaRM-style ring baseline (§8.5, Fig 17).
//!
//! Fixed-size slots; each carries a valid flag the producer sets after
//! writing the message. The consumer polls the flag of the head slot
//! (a DMA read per poll — hits and misses alike), copies the message,
//! and must DMA-write the slot header back to zero to release it for
//! reuse ("the DPU ... releases the space on the host ring buffer ...
//! by clearing its bits"). No batching: every message costs at least
//! one DMA read + one DMA write, which is why Fig 17 shows it peaking
//! at ~64 K op/s.

use std::sync::atomic::{AtomicU64, Ordering};

use super::{CacheLine, RequestRing, RingStatus};
use crate::dma::{DmaChannel, DmaDir};

struct Slot {
    /// 0 = free; otherwise `len + 1` of the stored message.
    hdr: AtomicU64,
    data: std::cell::UnsafeCell<Box<[u8]>>,
}

/// FaRM-style flag-per-slot MPSC ring.
pub struct FarmRing {
    slots: Box<[Slot]>,
    tail: CacheLine<AtomicU64>,
    head: CacheLine<AtomicU64>,
    slot_size: usize,
}

// SAFETY: a slot's data is written only by the producer that claimed it
// (hdr == 0 -> claimed via tail CAS) and read only by the single consumer
// after observing hdr != 0 with Acquire.
unsafe impl Send for FarmRing {}
unsafe impl Sync for FarmRing {}

impl FarmRing {
    pub fn new(num_slots: usize, slot_size: usize) -> Self {
        assert!(num_slots.is_power_of_two());
        let slots = (0..num_slots)
            .map(|_| Slot {
                hdr: AtomicU64::new(0),
                data: std::cell::UnsafeCell::new(vec![0u8; slot_size].into_boxed_slice()),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        FarmRing {
            slots,
            tail: CacheLine(AtomicU64::new(0)),
            head: CacheLine(AtomicU64::new(0)),
            slot_size,
        }
    }

    fn mask(&self) -> u64 {
        self.slots.len() as u64 - 1
    }

    /// Consume up to one message through the DMA channel (poll → read →
    /// release). Returns messages consumed (0 or 1).
    pub fn pop_one_dma(&self, dma: &DmaChannel, f: &mut dyn FnMut(&[u8])) -> usize {
        // LINT: relaxed-ok(single consumer owns head; payload visibility
        // comes from the hdr Acquire load below, not from head)
        let head = self.head.0.load(Ordering::Relaxed);
        let slot = &self.slots[(head & self.mask()) as usize];
        // Poll the flag: costs a DMA read whether or not it is set.
        dma.op(DmaDir::Read, 8);
        let hdr = slot.hdr.load(Ordering::Acquire);
        if hdr == 0 {
            return 0;
        }
        let len = (hdr - 1) as usize;
        dma.op(DmaDir::Read, len);
        // SAFETY: hdr != 0 ⇒ producer finished writing (Release store).
        let data = unsafe { &*slot.data.get() };
        f(&data[..len]);
        // Release: clear the flag with a DMA write.
        dma.op(DmaDir::Write, 8);
        slot.hdr.store(0, Ordering::Release);
        // LINT: relaxed-ok(single consumer owns head; producers gate on the
        // hdr Release clear above, head is only a cursor)
        self.head.0.store(head + 1, Ordering::Relaxed);
        1
    }
}

impl RequestRing for FarmRing {
    fn try_push(&self, msg: &[u8]) -> RingStatus {
        assert!(msg.len() <= self.slot_size);
        loop {
            // Head loaded before tail — see ProgressRing::try_push_inner
            // for why the opposite order can underflow.
            let head = self.head.0.load(Ordering::Acquire);
            let tail = self.tail.0.load(Ordering::Acquire);
            if tail - head >= self.slots.len() as u64 {
                return RingStatus::Retry;
            }
            let slot = &self.slots[(tail & self.mask()) as usize];
            if slot.hdr.load(Ordering::Acquire) != 0 {
                // Slot not yet released by the consumer.
                return RingStatus::Retry;
            }
            // LINT: relaxed-ok(CAS failure ordering; the retry re-loads with Acquire)
            if self
                .tail
                .0
                .compare_exchange_weak(tail, tail + 1, Ordering::AcqRel, Ordering::Relaxed)
                .is_err()
            {
                continue;
            }
            // SAFETY: tail CAS gives us exclusive claim on this slot.
            let data = unsafe { &mut *slot.data.get() };
            data[..msg.len()].copy_from_slice(msg);
            slot.hdr.store(msg.len() as u64 + 1, Ordering::Release);
            return RingStatus::Ok;
        }
    }

    fn pop_batch(&self, f: &mut dyn FnMut(&[u8])) -> usize {
        thread_local! {
            static NULL_DMA: DmaChannel = DmaChannel::new();
        }
        NULL_DMA.with(|d| self.pop_one_dma(d, f))
    }

    fn name(&self) -> &'static str {
        "farm-style"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn per_message_dma_cost() {
        // Each message costs ≥2 DMA ops (poll-read + release-write) —
        // the design deficiency Fig 17 exposes.
        let r = FarmRing::new(16, 64);
        let dma = DmaChannel::new();
        for _ in 0..4 {
            r.try_push(&[9u8; 8]);
        }
        let mut n = 0;
        while r.pop_one_dma(&dma, &mut |_| n += 1) == 1 {}
        assert_eq!(n, 4);
        assert!(dma.reads() >= 8); // 4 polls-with-data + 4 payload reads + 1 empty poll
        assert_eq!(dma.writes(), 4);
    }

    #[test]
    fn full_ring_retries() {
        let r = FarmRing::new(4, 16);
        for _ in 0..4 {
            assert_eq!(r.try_push(&[1u8; 4]), RingStatus::Ok);
        }
        assert_eq!(r.try_push(&[1u8; 4]), RingStatus::Retry);
    }

    #[test]
    fn mpsc_roundtrip() {
        let r = Arc::new(FarmRing::new(256, 16));
        // Shrunk under Miri: the 4-producer claim race over a tiny
        // (256-slot) farm is the shape; volume just repeats it.
        let per = if cfg!(miri) { 50u64 } else { 1000u64 };
        let mut handles = Vec::new();
        for p in 0..4u64 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    let v = p << 32 | i;
                    while r.try_push(&v.to_le_bytes()) != RingStatus::Ok {
                        std::hint::spin_loop();
                    }
                }
            }));
        }
        let consumer = {
            let r = r.clone();
            std::thread::spawn(move || {
                let mut total = 0;
                let mut seen = [0u64; 4];
                while (total as u64) < 4 * per {
                    total += r.pop_batch(&mut |m| {
                        let v = u64::from_le_bytes(m.try_into().unwrap());
                        let p = (v >> 32) as usize;
                        assert_eq!(v & 0xffff_ffff, seen[p]);
                        seen[p] += 1;
                    });
                }
                total as u64
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(consumer.join().unwrap(), 4 * per);
    }
}
