//! k-server FIFO queueing resources in virtual time.
//!
//! A [`Resource`] models a pool of identical servers (CPU cores, NVMe
//! queue-pair engines, a NIC pipe, a DMA channel). Tokens acquire it in
//! non-decreasing virtual-time order (guaranteed by the engine's event
//! heap), so "earliest free server" bookkeeping is an exact FIFO k-server
//! queue without simulating each server explicitly.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::Ns;

/// Index of a resource inside an [`crate::sim::Engine`].
pub type ResourceId = usize;

/// A k-server FIFO queueing station with busy-time accounting.
#[derive(Debug, Clone)]
pub struct Resource {
    name: String,
    servers: usize,
    /// Next-free time of each server (min-heap).
    free_at: BinaryHeap<Reverse<Ns>>,
    /// Total busy nanoseconds accumulated across all servers.
    busy_ns: u128,
    /// Number of acquisitions.
    ops: u64,
}

impl Resource {
    /// Create a resource with `servers` identical servers.
    pub fn new(name: impl Into<String>, servers: usize) -> Self {
        assert!(servers > 0, "resource needs at least one server");
        let mut free_at = BinaryHeap::with_capacity(servers);
        for _ in 0..servers {
            free_at.push(Reverse(0));
        }
        Resource { name: name.into(), servers, free_at, busy_ns: 0, ops: 0 }
    }

    /// Acquire one server at `now` for `service_ns`.
    ///
    /// Returns `(start, end)`: the token waits in FIFO order until a
    /// server frees up, holds it for `service_ns`, and leaves at `end`.
    pub fn acquire(&mut self, now: Ns, service_ns: Ns) -> (Ns, Ns) {
        let Reverse(free) = self.free_at.pop().expect("non-empty heap");
        let start = now.max(free);
        let end = start + service_ns;
        self.free_at.push(Reverse(end));
        self.busy_ns += service_ns as u128;
        self.ops += 1;
        (start, end)
    }

    /// Earliest time at which a server is free (no state change).
    pub fn earliest_free(&self) -> Ns {
        self.free_at.peek().map(|Reverse(t)| *t).unwrap_or(0)
    }

    /// Resource name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Number of servers.
    pub fn servers(&self) -> usize {
        self.servers
    }

    /// Total acquisitions.
    pub fn ops(&self) -> u64 {
        self.ops
    }

    /// Total busy time across servers, ns.
    pub fn busy_ns(&self) -> u128 {
        self.busy_ns
    }

    /// "Cores consumed" over a horizon: busy time / horizon.
    ///
    /// This is the paper's CPU metric (§8.1): the number of fully-busy
    /// cores the accumulated work corresponds to.
    pub fn cores_consumed(&self, horizon_ns: Ns) -> f64 {
        if horizon_ns == 0 {
            return 0.0;
        }
        self.busy_ns as f64 / horizon_ns as f64
    }

    /// Utilization in `[0, 1]` over a horizon.
    pub fn utilization(&self, horizon_ns: Ns) -> f64 {
        self.cores_consumed(horizon_ns) / self.servers as f64
    }

    /// Reset accounting (keeps server next-free state).
    pub fn reset_accounting(&mut self) {
        self.busy_ns = 0;
        self.ops = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_server_fifo() {
        let mut r = Resource::new("cpu", 1);
        let (s1, e1) = r.acquire(0, 100);
        assert_eq!((s1, e1), (0, 100));
        // Arrives at 50 but server busy until 100.
        let (s2, e2) = r.acquire(50, 100);
        assert_eq!((s2, e2), (100, 200));
        // Arrives after idle gap.
        let (s3, e3) = r.acquire(500, 10);
        assert_eq!((s3, e3), (500, 510));
    }

    #[test]
    fn multi_server_parallelism() {
        let mut r = Resource::new("cpu", 2);
        assert_eq!(r.acquire(0, 100), (0, 100));
        assert_eq!(r.acquire(0, 100), (0, 100));
        // Third waits for first free server.
        assert_eq!(r.acquire(0, 100), (100, 200));
    }

    #[test]
    fn busy_accounting() {
        let mut r = Resource::new("cpu", 4);
        for _ in 0..10 {
            r.acquire(0, 1_000);
        }
        assert_eq!(r.busy_ns(), 10_000);
        assert!((r.cores_consumed(10_000) - 1.0).abs() < 1e-9);
        assert!((r.utilization(10_000) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn saturation_throughput_cap() {
        // 1 server, 1 µs service => 1 M op/s cap regardless of arrivals.
        let mut r = Resource::new("x", 1);
        let mut end = 0;
        for _ in 0..1000 {
            end = r.acquire(0, 1_000).1;
        }
        assert_eq!(end, 1_000_000);
    }
}
