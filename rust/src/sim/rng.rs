//! Deterministic pseudo-random numbers for the testbed.
//!
//! xorshift64* — fast, no external deps, and fully deterministic so every
//! figure regenerates identically run to run.

/// Deterministic xorshift64* generator.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    /// Create a generator from a non-zero seed (zero is remapped).
    pub fn new(seed: u64) -> Self {
        Rng(if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed })
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in `[0, n)`. `n` must be non-zero.
    #[inline]
    pub fn next_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift; bias is negligible for simulation purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Exponentially distributed value with the given mean.
    #[inline]
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // avoid ln(0)
        -mean * u.ln()
    }

    /// Exponential service time in ns, clamped to at least 1 ns.
    #[inline]
    pub fn exp_ns(&mut self, mean_ns: f64) -> u64 {
        self.exp(mean_ns).max(1.0) as u64
    }

    /// Zipf-ish skewed choice used by YCSB-style workloads: with
    /// probability `hot_frac_access` pick uniformly among the first
    /// `hot_n` items, otherwise uniformly among the rest.
    pub fn hotcold(&mut self, n: u64, hot_n: u64, hot_access: f64) -> u64 {
        if n <= 1 {
            return 0;
        }
        let hot_n = hot_n.clamp(1, n);
        if self.next_f64() < hot_access {
            self.next_range(hot_n)
        } else {
            hot_n + self.next_range(n - hot_n)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(100.0)).sum::<f64>() / n as f64;
        assert!((mean - 100.0).abs() < 2.0, "mean={mean}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.next_range(17) < 17);
        }
    }

    #[test]
    fn hotcold_skew() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let hot = (0..n)
            .map(|_| r.hotcold(1000, 100, 0.9))
            .filter(|&k| k < 100)
            .count();
        let frac = hot as f64 / n as f64;
        assert!((frac - 0.9).abs() < 0.01, "frac={frac}");
    }
}
