//! Closed-loop tandem-queue engine.
//!
//! Each *flow* keeps a fixed window of outstanding request tokens (the
//! paper controls load with "number of outstanding messages" and
//! "concurrent connections", §8.1). A token repeatedly: asks its flow for
//! the next [`StageChain`], walks the chain through the shared
//! [`Resource`]s, records its end-to-end latency, and immediately issues
//! the next request. Tokens advance in non-decreasing virtual-time order
//! via a global event heap, so resource acquisition order equals arrival
//! order and the FIFO queueing model in [`Resource`] is exact.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::resource::{Resource, ResourceId};
use super::rng::Rng;
use super::Ns;
use crate::metrics::Histogram;

/// One step of a request's journey.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Occupy one server of `res` for `ns` of service.
    Use { res: ResourceId, ns: Ns },
    /// Pure delay (wire propagation, fixed hardware latency); no queueing.
    Delay(Ns),
}

/// A request: an ordered chain of stages plus a class label for metrics.
#[derive(Debug, Clone)]
pub struct StageChain {
    /// Metric class; latency/throughput are reported per class.
    pub class: usize,
    pub stages: Vec<Stage>,
}

impl StageChain {
    pub fn new(class: usize, stages: Vec<Stage>) -> Self {
        StageChain { class, stages }
    }
}

/// A load generator: a window of tokens plus a request factory.
pub struct FlowSpec {
    /// Number of outstanding tokens (closed-loop window).
    pub window: usize,
    /// Produces the next request chain. Receives the engine RNG.
    pub gen: Box<dyn FnMut(&mut Rng) -> StageChain>,
    /// Optional think time between a completion and the next issue.
    pub think_ns: Ns,
}

impl FlowSpec {
    pub fn new(window: usize, gen: impl FnMut(&mut Rng) -> StageChain + 'static) -> Self {
        FlowSpec { window, gen: Box::new(gen), think_ns: 0 }
    }

    pub fn with_think(mut self, think_ns: Ns) -> Self {
        self.think_ns = think_ns;
        self
    }
}

/// Result of an engine run.
#[derive(Debug)]
pub struct RunReport {
    /// Virtual horizon actually simulated, ns.
    pub horizon_ns: Ns,
    /// Completions per class.
    pub completions: Vec<u64>,
    /// Latency histogram per class (ns).
    pub latency: Vec<Histogram>,
    /// (name, busy_ns, servers, ops) per resource.
    pub resources: Vec<(String, u128, usize, u64)>,
}

impl RunReport {
    /// Throughput of a class in operations per second of virtual time.
    pub fn throughput(&self, class: usize) -> f64 {
        if self.horizon_ns == 0 {
            return 0.0;
        }
        self.completions[class] as f64 * 1e9 / self.horizon_ns as f64
    }

    /// Total throughput across classes, op/s.
    pub fn total_throughput(&self) -> f64 {
        if self.horizon_ns == 0 {
            return 0.0;
        }
        self.completions.iter().sum::<u64>() as f64 * 1e9 / self.horizon_ns as f64
    }

    /// Cores consumed by a resource (busy / horizon).
    pub fn cores(&self, name: &str) -> f64 {
        self.resources
            .iter()
            .filter(|(n, ..)| n == name)
            .map(|(_, busy, ..)| *busy as f64 / self.horizon_ns as f64)
            .sum()
    }

    /// Sum of cores over resources whose name starts with `prefix`.
    pub fn cores_prefix(&self, prefix: &str) -> f64 {
        self.resources
            .iter()
            .filter(|(n, ..)| n.starts_with(prefix))
            .map(|(_, busy, ..)| *busy as f64 / self.horizon_ns as f64)
            .sum()
    }
}

struct Token {
    flow: usize,
    class: usize,
    stages: std::vec::IntoIter<Stage>,
    issued_at: Ns,
    now: Ns,
}

/// The closed-loop engine: resources + flows + event heap.
pub struct Engine {
    resources: Vec<Resource>,
    rng: Rng,
    /// Warm-up time excluded from accounting.
    warmup_ns: Ns,
}

impl Engine {
    pub fn new(seed: u64) -> Self {
        Engine { resources: Vec::new(), rng: Rng::new(seed), warmup_ns: 0 }
    }

    /// Exclude the first `ns` of virtual time from latency/CPU accounting.
    pub fn with_warmup(mut self, ns: Ns) -> Self {
        self.warmup_ns = ns;
        self
    }

    /// Register a resource; returns its id for use in [`Stage::Use`].
    pub fn add_resource(&mut self, name: impl Into<String>, servers: usize) -> ResourceId {
        self.resources.push(Resource::new(name, servers));
        self.resources.len() - 1
    }

    /// Access a registered resource (e.g. to tune accounting).
    pub fn resource(&self, id: ResourceId) -> &Resource {
        &self.resources[id]
    }

    /// Run the flows for `horizon_ns` of virtual time.
    ///
    /// `classes` is the number of metric classes used by the chains.
    pub fn run(mut self, mut flows: Vec<FlowSpec>, classes: usize, horizon_ns: Ns) -> RunReport {
        assert!(classes > 0);
        let mut heap: BinaryHeap<Reverse<(Ns, u64, usize)>> = BinaryHeap::new();
        let mut tokens: Vec<Token> = Vec::new();
        let mut seq: u64 = 0;

        // Seed the windows. Stagger initial issues a little so that all
        // tokens do not hit the first resource at exactly t=0.
        for (fi, f) in flows.iter_mut().enumerate() {
            for w in 0..f.window {
                let chain = (f.gen)(&mut self.rng);
                let start = (w as Ns) * 10; // 10 ns stagger
                tokens.push(Token {
                    flow: fi,
                    class: chain.class,
                    stages: chain.stages.into_iter(),
                    issued_at: start,
                    now: start,
                });
                heap.push(Reverse((start, seq, tokens.len() - 1)));
                seq += 1;
            }
        }

        let mut completions = vec![0u64; classes];
        let mut latency: Vec<Histogram> = (0..classes).map(|_| Histogram::new()).collect();
        let mut warm_reset_done = self.warmup_ns == 0;

        while let Some(Reverse((t, _, ti))) = heap.pop() {
            if t >= horizon_ns + self.warmup_ns {
                break;
            }
            if !warm_reset_done && t >= self.warmup_ns {
                for r in &mut self.resources {
                    r.reset_accounting();
                }
                for c in &mut completions {
                    *c = 0;
                }
                for h in &mut latency {
                    *h = Histogram::new();
                }
                warm_reset_done = true;
            }
            let tok = &mut tokens[ti];
            debug_assert_eq!(tok.now, t);
            match tok.stages.next() {
                Some(Stage::Use { res, ns }) => {
                    let (_start, end) = self.resources[res].acquire(t, ns);
                    tok.now = end;
                    heap.push(Reverse((end, seq, ti)));
                    seq += 1;
                }
                Some(Stage::Delay(ns)) => {
                    tok.now = t + ns;
                    heap.push(Reverse((tok.now, seq, ti)));
                    seq += 1;
                }
                None => {
                    // Request complete: record and reissue.
                    completions[tok.class] += 1;
                    latency[tok.class].record(t - tok.issued_at);
                    let fi = tok.flow;
                    let think = flows[fi].think_ns;
                    let chain = (flows[fi].gen)(&mut self.rng);
                    let tok = &mut tokens[ti];
                    tok.class = chain.class;
                    tok.stages = chain.stages.into_iter();
                    tok.issued_at = t + think;
                    tok.now = tok.issued_at;
                    heap.push(Reverse((tok.now, seq, ti)));
                    seq += 1;
                }
            }
        }

        RunReport {
            horizon_ns,
            completions,
            latency,
            resources: self
                .resources
                .iter()
                .map(|r| (r.name().to_string(), r.busy_ns(), r.servers(), r.ops()))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{MS, SEC, US};

    /// Single-server M/D/1-ish sanity: throughput capped by service rate.
    #[test]
    fn throughput_cap() {
        let mut e = Engine::new(1);
        let cpu = e.add_resource("cpu", 1);
        // 1 µs of service per request => cap 1 M op/s.
        let flow = FlowSpec::new(16, move |_| {
            StageChain::new(0, vec![Stage::Use { res: cpu, ns: US }])
        });
        let rep = e.run(vec![flow], 1, SEC / 10);
        let x = rep.throughput(0);
        assert!((x - 1e6).abs() / 1e6 < 0.01, "x={x}");
    }

    /// Closed-loop Little's law: W tokens, service s => latency ≈ W*s at
    /// saturation.
    #[test]
    fn littles_law() {
        let mut e = Engine::new(2);
        let cpu = e.add_resource("cpu", 1);
        let w = 32;
        let flow = FlowSpec::new(w, move |_| {
            StageChain::new(0, vec![Stage::Use { res: cpu, ns: 10 * US }])
        });
        let rep = e.run(vec![flow], 1, SEC / 10);
        let p50 = rep.latency[0].quantile(0.5);
        let expect = w as u64 * 10 * US;
        assert!(
            (p50 as f64 - expect as f64).abs() / (expect as f64) < 0.05,
            "p50={p50} expect={expect}"
        );
    }

    /// Two parallel servers double the cap.
    #[test]
    fn two_servers() {
        let mut e = Engine::new(3);
        let cpu = e.add_resource("cpu", 2);
        let flow = FlowSpec::new(64, move |_| {
            StageChain::new(0, vec![Stage::Use { res: cpu, ns: US }])
        });
        let rep = e.run(vec![flow], 1, SEC / 10);
        assert!((rep.throughput(0) - 2e6).abs() / 2e6 < 0.01);
    }

    /// Delay stages add latency but consume no resource.
    #[test]
    fn delay_only() {
        let e = Engine::new(4);
        let flow = FlowSpec::new(1, move |_| StageChain::new(0, vec![Stage::Delay(MS)]));
        let rep = e.run(vec![flow], 1, SEC / 10);
        assert_eq!(rep.latency[0].quantile(0.5), MS);
        assert!((rep.throughput(0) - 1000.0).abs() < 20.0);
    }

    /// Cores-consumed accounting matches offered work.
    #[test]
    fn cores_metric() {
        let mut e = Engine::new(5);
        let cpu = e.add_resource("host_cpu", 8);
        // 4 tokens each keeping ~1 core busy (service == think 0, window 4
        // on an 8-way pool => utilization 0.5 core-fraction? No: 4 tokens
        // always in service => 4 busy cores).
        let flow = FlowSpec::new(4, move |_| {
            StageChain::new(0, vec![Stage::Use { res: cpu, ns: US }])
        });
        let rep = e.run(vec![flow], 1, SEC / 10);
        let cores = rep.cores("host_cpu");
        assert!((cores - 4.0).abs() < 0.05, "cores={cores}");
    }

    /// Warm-up slice is excluded from accounting.
    #[test]
    fn warmup_excluded() {
        let mut e = Engine::new(6).with_warmup(10 * MS);
        let cpu = e.add_resource("cpu", 1);
        let flow = FlowSpec::new(1, move |_| {
            StageChain::new(0, vec![Stage::Use { res: cpu, ns: US }])
        });
        let rep = e.run(vec![flow], 1, SEC / 10);
        // Still roughly 1 core * (window-limited) utilization, and
        // completions only counted post warm-up.
        assert!(rep.completions[0] > 0);
        assert!(rep.cores("cpu") <= 1.01);
    }
}
