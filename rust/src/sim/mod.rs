//! Discrete-virtual-time queueing testbed.
//!
//! The paper's testbed (two EPYC-7325 servers, a BlueField-2 DPU, a 1 TB
//! NVMe SSD, 100 GbE) is not available here, so every experiment that
//! depends on hardware latencies or CPU burn is run on this calibrated
//! simulator instead (DESIGN.md §1). The model is a tandem queueing
//! network: each request is a *token* that walks a chain of [`Stage`]s
//! through k-server [`Resource`]s; tokens are advanced in non-decreasing
//! virtual-time order by the closed-loop [`Engine`]. CPU pools account
//! busy time, which divided by the horizon yields the paper's
//! "CPU cores consumed" metric.

pub mod cpu;
pub mod engine;
pub mod params;
pub mod resource;
pub mod rng;

pub use cpu::CpuPool;
pub use engine::{Engine, FlowSpec, RunReport, Stage, StageChain};
pub use params::Params;
pub use resource::{Resource, ResourceId};
pub use rng::Rng;

/// Virtual time in nanoseconds.
pub type Ns = u64;

/// One second in virtual nanoseconds.
pub const SEC: Ns = 1_000_000_000;

/// One millisecond in virtual nanoseconds.
pub const MS: Ns = 1_000_000;

/// One microsecond in virtual nanoseconds.
pub const US: Ns = 1_000;
