//! CPU pools: host (fast EPYC cores) vs DPU (wimpy Arm cores).
//!
//! A [`CpuPool`] is a thin typed layer over [`Resource`] that applies the
//! wimpy-core slowdown when work calibrated in host-ns runs on the DPU,
//! and converts busy time into the paper's "CPU cores consumed" metric.

use super::params::Params;
use super::resource::Resource;
use super::Ns;

/// Which silicon the pool models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CpuKind {
    /// Host server cores (service times used as-is).
    Host,
    /// DPU Arm cores (host-calibrated service times are stretched by
    /// `Params::dpu_slowdown`).
    Dpu,
}

/// A pool of cores with busy-time accounting.
#[derive(Debug, Clone)]
pub struct CpuPool {
    res: Resource,
    kind: CpuKind,
    slowdown: f64,
}

impl CpuPool {
    pub fn new(name: impl Into<String>, cores: usize, kind: CpuKind, p: &Params) -> Self {
        CpuPool {
            res: Resource::new(name, cores),
            kind,
            slowdown: p.dpu_slowdown,
        }
    }

    /// Scale host-calibrated work to this pool's cycle time.
    #[inline]
    pub fn scaled(&self, host_ns: Ns) -> Ns {
        match self.kind {
            CpuKind::Host => host_ns,
            CpuKind::Dpu => (host_ns as f64 * self.slowdown) as Ns,
        }
    }

    /// Execute `host_ns` of host-calibrated work starting no earlier than
    /// `now`; returns `(start, end)`.
    pub fn exec(&mut self, now: Ns, host_ns: Ns) -> (Ns, Ns) {
        let ns = self.scaled(host_ns);
        self.res.acquire(now, ns)
    }

    pub fn kind(&self) -> CpuKind {
        self.kind
    }

    pub fn cores_consumed(&self, horizon_ns: Ns) -> f64 {
        self.res.cores_consumed(horizon_ns)
    }

    pub fn utilization(&self, horizon_ns: Ns) -> f64 {
        self.res.utilization(horizon_ns)
    }

    pub fn resource(&self) -> &Resource {
        &self.res
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dpu_is_slower() {
        let p = Params::paper();
        let mut host = CpuPool::new("h", 4, CpuKind::Host, &p);
        let mut dpu = CpuPool::new("d", 4, CpuKind::Dpu, &p);
        let (_, he) = host.exec(0, 1000);
        let (_, de) = dpu.exec(0, 1000);
        assert_eq!(he, 1000);
        assert_eq!(de, (1000.0 * p.dpu_slowdown) as u64);
    }

    #[test]
    fn cores_metric_passthrough() {
        let p = Params::paper();
        let mut pool = CpuPool::new("h", 8, CpuKind::Host, &p);
        for _ in 0..1000 {
            pool.exec(0, 1_000);
        }
        let cores = pool.cores_consumed(1_000_000);
        assert!((cores - 1.0).abs() < 1e-9);
    }
}
