//! Calibration constants for the testbed.
//!
//! Single source of truth for every hardware/stack cost the simulator
//! uses. Each constant is annotated with the paper statement it is
//! calibrated against (§ = DDS paper section). Benches must take these
//! from [`Params::paper()`] — never inline magic numbers — so the whole
//! reproduction can be re-calibrated in one place.

use super::Ns;

/// Testbed calibration. All `*_ns` values are one-core service times on
/// the HOST unless the name says `dpu`.
#[derive(Debug, Clone)]
pub struct Params {
    // ----- topology (§8.1) -----
    /// Host cores per server (2 × AMD EPYC 7325 24-core).
    pub host_cores: usize,
    /// DPU Arm cores (BlueField-2: 8 × Cortex-A72), §7.
    pub dpu_cores: usize,
    /// DPU cores DDS actually uses (1 DMA + 1 file service + 1
    /// director+offload), §7 "Resource utilization".
    pub dds_dpu_cores: usize,
    /// Wimpy-core slowdown: FASTER runs up to 4.5× slower on the DPU
    /// (§2, Fig 5) — we use it as the general Arm/EPYC IPC ratio.
    pub dpu_slowdown: f64,

    // ----- network (§8.1: 100 GbE, ConnectX-6 client NIC) -----
    /// NIC line rate, bytes/ns (100 Gbps = 12.5 GB/s).
    pub nic_bytes_per_ns: f64,
    /// One-way wire + switch propagation.
    pub wire_delay_ns: Ns,
    /// Host kernel TCP/IP per-packet CPU (send or recv path), §1: 14
    /// cores to send 2 GB/s (~244 K 8 KB msg/s ≈ 3.8 µs/pkt/side over
    /// ~1500 B segments).
    pub host_tcp_pkt_ns: Ns,
    /// Kernel cores effectively usable for network softirq work
    /// (scalability limit of the kernel stack per flow set).
    pub host_tcp_parallel: usize,
    /// Data-system internal network module per request (Fig 2 shows it
    /// is the largest component on the page server).
    pub dbms_net_req_ns: Ns,
    /// Linux TCP on the DPU's Arm core: per-message base + per-segment
    /// cost (§8.5 Fig 19: kernel overhead "further exacerbated by
    /// weaker DPU cores").
    pub dpu_linux_tcp_msg_ns: Ns,
    pub dpu_linux_per_seg_ns: Ns,
    /// TLDK userspace TCP on the DPU, per-message base (§5.3, Fig 19:
    /// 3× lower than Linux TCP on the DPU, 2.5× under the vanilla host
    /// echo).
    pub dpu_tldk_msg_ns: Ns,
    /// TLDK per-segment cost (same on host and DPU — the stack is the
    /// same code; the host's advantage is core speed in the base cost).
    pub tldk_per_seg_ns: Ns,
    /// TLDK on the HOST, per-message base (Fig 20 comparison).
    pub host_tldk_msg_ns: Ns,
    /// Host-DDR inefficiency for NIC-fed payload processing relative to
    /// DPU on-board memory, ns per byte (§8.5: "DPU memory is generally
    /// more efficient than host memory").
    pub host_mem_penalty_ns_per_byte: f64,
    /// Off-path forward of a packet via a BF-2 Arm core to the host
    /// (§5.3: "about 6 µs").
    pub dpu_forward_ns: Ns,
    /// Hardware signature match at the NIC: line-rate, no Arm latency
    /// (§5.3 push-down).
    pub nic_hw_match_ns: Ns,
    /// Per-byte copy cost of DPU memory, bytes/ns (single A72 memcpy,
    /// read+write traffic, ~2.5 GB/s effective; the modest DDR4 of §2.
    /// Calibrated so the Fig 18 zero-copy gain peaks at the paper's
    /// ~93%).
    pub dpu_memcpy_bytes_per_ns: f64,
    /// RDMA per-message CPU on one side (kernel bypass, §8.4).
    pub rdma_msg_ns: Ns,
    /// RDMA one-way hardware latency.
    pub rdma_wire_ns: Ns,
    /// Redy-style RPC: dedicated polling cores per side (§8.4: "burning
    /// a few CPU cores on both client and server").
    pub redy_poll_cores: usize,

    // ----- storage (§8.1: 1 TB NVMe SSD) -----
    /// Unloaded SSD read latency for ≤4 KB (local page read is
    /// 100–200 µs end-to-end, §1).
    pub ssd_read_lat_ns: Ns,
    /// Unloaded SSD write latency (cached NVMe write).
    pub ssd_write_lat_ns: Ns,
    /// Internal parallelism (queue-pair service engines).
    pub ssd_channels: usize,
    /// Read IOPS cap for small IO (Fig 14a: DDS saturates at 730 K).
    pub ssd_read_iops_cap: f64,
    /// Write IOPS cap for small IO (Fig 14b: DDS files peak ~290 K).
    pub ssd_write_iops_cap: f64,
    /// Sequential read bandwidth bytes/ns.
    pub ssd_read_bw_bytes_per_ns: f64,
    /// Sequential write bandwidth bytes/ns.
    pub ssd_write_bw_bytes_per_ns: f64,

    // ----- host storage stacks -----
    /// NTFS + Windows IO stack CPU per read IO (calibrated so the
    /// baseline hits 10.7 cores @ 390 K IOPS, Fig 14a).
    pub ntfs_read_ns: Ns,
    /// NTFS write path CPU per IO (journaling etc.; Fig 14b).
    pub ntfs_write_ns: Ns,
    /// Serialized portion of the Windows IO path (completion ports /
    /// storage stack locks) — limits baseline peak to ~390 K IOPS.
    pub win_io_parallel: usize,
    pub win_io_serial_ns: Ns,
    /// Same serialization for writes (baseline writes peak ~210 K).
    pub win_io_serial_write_ns: Ns,
    /// DDS file library CPU per IO on the host (§4.2: non-blocking,
    /// lock-free insert + poll — sub-µs).
    pub filelib_req_ns: Ns,
    /// SMB adds protocol CPU + a per-IO mount overhead (§8.4).
    pub smb_req_ns: Ns,
    pub smb_parallel: usize,
    /// SMB-Direct replaces TCP with RDMA but keeps the SMB server path.
    pub smbd_req_ns: Ns,

    // ----- DMA / rings (§4.1, §8.5) -----
    /// One DPU-issued DMA op (PCIe Gen4 round trip incl. doorbell).
    pub dma_op_ns: Ns,
    /// DMA bandwidth bytes/ns (PCIe Gen4 ×16 usable).
    pub dma_bytes_per_ns: f64,
    /// Ring batch size the DMA thread moves per op (maximum allowable
    /// progress M, §4.1).
    pub ring_batch: usize,

    // ----- DPU file service (§4.3) -----
    // NOTE: the `dpu_*_ns` service costs below are DPU-NATIVE
    // nanoseconds (measured-on-Arm calibration), NOT host-ns — do not
    // wrap them in `on_dpu()`.
    /// File-service CPU per IO on a DPU core: translate mapping, submit
    /// via SPDK, handle completion. SPDK userspace IO is ~1-2 µs/IO even
    /// on wimpy cores; one core must sustain the 580 K IOPS of Fig 14a.
    pub dpu_file_svc_ns: Ns,
    /// Offload engine CPU per request on a DPU core (OffFunc + context
    /// ring + zero-copy packetization), §6.2.
    pub dpu_offload_req_ns: Ns,
    /// Traffic-director CPU per request (predicate eval, split
    /// bookkeeping), §5; Fig 21: 6.4 Gbps per core for ~1 KB responses
    /// (~800 K req/s → ~1.25 µs/req including TLDK).
    pub dpu_director_req_ns: Ns,
    /// TLDK per-segment processing on a DPU core (throughput cost;
    /// amortized over the requests a segment carries).
    pub dpu_tldk_seg_ns: Ns,

    // ----- applications -----
    /// Hyperscale page-server SQL/network module CPU per 8 KB page read
    /// (Fig 2: 17 cores @ 156 K pages/s ≈ 109 µs total; net module is
    /// the largest share).
    pub hs_dbms_net_ns: Ns,
    pub hs_os_net_ns: Ns,
    pub hs_file_ns: Ns,
    pub hs_parallel: usize,
    /// FASTER in-memory RMW CPU per op on the host (§2, Fig 5).
    pub faster_rmw_ns: Ns,
    /// RMW slowdown on the DPU (§2, Fig 5: "up to 4.5× slower").
    pub rmw_dpu_slowdown: f64,
    /// FASTER server request handling per YCSB read (network module +
    /// index + IDevice issue), §9.2: 340 K op/s costs 20 cores.
    pub faster_net_ns: Ns,
    pub faster_core_ns: Ns,
    pub faster_idevice_ns: Ns,
}

impl Params {
    /// The calibration used by every figure bench.
    pub fn paper() -> Self {
        Params {
            host_cores: 48,
            dpu_cores: 8,
            dds_dpu_cores: 3,
            dpu_slowdown: 2.8,

            nic_bytes_per_ns: 12.5,
            wire_delay_ns: 2_500,
            host_tcp_pkt_ns: 3_200,
            host_tcp_parallel: 8,
            dbms_net_req_ns: 5_000,
            dpu_linux_tcp_msg_ns: 12_500,
            dpu_linux_per_seg_ns: 1_000,
            dpu_tldk_msg_ns: 2_500,
            tldk_per_seg_ns: 150,
            host_tldk_msg_ns: 1_200,
            host_mem_penalty_ns_per_byte: 0.15,
            dpu_forward_ns: 6_000,
            nic_hw_match_ns: 0,
            dpu_memcpy_bytes_per_ns: 2.5,
            rdma_msg_ns: 700,
            rdma_wire_ns: 2_000,
            redy_poll_cores: 2,

            ssd_read_lat_ns: 85_000,
            ssd_write_lat_ns: 22_000,
            ssd_channels: 32,
            ssd_read_iops_cap: 760_000.0,
            ssd_write_iops_cap: 305_000.0,
            ssd_read_bw_bytes_per_ns: 3.2,
            ssd_write_bw_bytes_per_ns: 1.9,

            ntfs_read_ns: 16_000,
            ntfs_write_ns: 21_000,
            win_io_parallel: 4,
            win_io_serial_ns: 10_000,
            win_io_serial_write_ns: 19_000,
            filelib_req_ns: 500,
            smb_req_ns: 45_000,
            smb_parallel: 6,
            smbd_req_ns: 22_000,

            dma_op_ns: 900,
            dma_bytes_per_ns: 20.0,
            ring_batch: 32,

            dpu_file_svc_ns: 1_700,
            dpu_offload_req_ns: 1_000,
            dpu_director_req_ns: 1_100,
            dpu_tldk_seg_ns: 1_600,

            hs_dbms_net_ns: 48_000,
            hs_os_net_ns: 34_000,
            hs_file_ns: 27_000,
            hs_parallel: 8,
            faster_rmw_ns: 550,
            rmw_dpu_slowdown: 4.5,
            faster_net_ns: 40_000,
            faster_core_ns: 6_000,
            faster_idevice_ns: 13_000,
        }
    }

    /// Service time of `ns` of host work executed on a wimpy DPU core.
    pub fn on_dpu(&self, host_ns: Ns) -> Ns {
        (host_ns as f64 * self.dpu_slowdown) as Ns
    }

    /// Wire transfer time for `bytes` at NIC line rate.
    pub fn wire_ns(&self, bytes: usize) -> Ns {
        (bytes as f64 / self.nic_bytes_per_ns) as Ns
    }

    /// Number of ~1500 B segments for a message of `bytes`.
    pub fn segments(&self, bytes: usize) -> usize {
        bytes.div_ceil(1460).max(1)
    }

    /// SSD service time for one read of `bytes` such that the channel
    /// pool saturates at `ssd_read_iops_cap` for small IO and at the
    /// bandwidth cap for large IO.
    pub fn ssd_read_service_ns(&self, bytes: usize) -> Ns {
        let mut iops_bound = self.ssd_channels as f64 / self.ssd_read_iops_cap * 1e9;
        if bytes <= 256 {
            // Sub-block reads (tiny KV records, §9.2) are cheaper per
            // op: the device transfers a fraction of a block per
            // command. Calibrated so FASTER-DDS approaches ~1 M op/s
            // (Fig 25: 970 K).
            iops_bound *= 0.75;
        }
        // Pool-wide bandwidth cap: channels / service * bytes = bw.
        let bw_bound =
            bytes as f64 * self.ssd_channels as f64 / self.ssd_read_bw_bytes_per_ns;
        iops_bound.max(bw_bound) as Ns
    }

    /// SSD service time for one write of `bytes`.
    pub fn ssd_write_service_ns(&self, bytes: usize) -> Ns {
        let iops_bound = self.ssd_channels as f64 / self.ssd_write_iops_cap * 1e9;
        let bw_bound =
            bytes as f64 * self.ssd_channels as f64 / self.ssd_write_bw_bytes_per_ns;
        iops_bound.max(bw_bound) as Ns
    }

    /// DMA transfer time for `bytes` (latency + bandwidth).
    pub fn dma_ns(&self, bytes: usize) -> Ns {
        self.dma_op_ns + (bytes as f64 / self.dma_bytes_per_ns) as Ns
    }

    /// DPU memcpy time for `bytes`.
    pub fn dpu_memcpy_ns(&self, bytes: usize) -> Ns {
        (bytes as f64 / self.dpu_memcpy_bytes_per_ns) as Ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_read_cpu_matches_fig14a() {
        // Baseline: 390 K IOPS at ~10.7 cores => ~27.4 µs of host CPU/IO.
        let p = Params::paper();
        let per_io =
            p.host_tcp_pkt_ns * 2 + p.dbms_net_req_ns + p.ntfs_read_ns;
        let cores = per_io as f64 * 390_000.0 / 1e9;
        assert!((cores - 10.7).abs() < 1.0, "cores={cores}");
    }

    #[test]
    fn dds_files_read_cpu_matches_fig14a() {
        // DDS files: 580 K IOPS at ~6.5 cores => ~11.2 µs host CPU/IO.
        let p = Params::paper();
        let per_io = p.host_tcp_pkt_ns * 2 + p.dbms_net_req_ns + p.filelib_req_ns;
        let cores = per_io as f64 * 580_000.0 / 1e9;
        assert!((cores - 6.5).abs() < 0.8, "cores={cores}");
    }

    #[test]
    fn ssd_caps() {
        let p = Params::paper();
        // Small-read service time yields the IOPS cap through the pool.
        let s = p.ssd_read_service_ns(1024);
        let cap = p.ssd_channels as f64 / s as f64 * 1e9;
        assert!((cap - p.ssd_read_iops_cap).abs() / p.ssd_read_iops_cap < 0.02);
        // Large reads become bandwidth bound.
        let s64k = p.ssd_read_service_ns(65536);
        assert!(s64k > s);
    }

    #[test]
    fn dpu_scaling() {
        let p = Params::paper();
        assert_eq!(p.on_dpu(1000), 2800);
        assert!(p.segments(1024) == 1 && p.segments(4000) == 3);
    }

    #[test]
    fn hyperscale_fig2_anchor() {
        // Fig 2: ~17 cores at 156 K 8 KB pages/s.
        let p = Params::paper();
        let per_page = p.hs_dbms_net_ns + p.hs_os_net_ns + p.hs_file_ns;
        let cores = per_page as f64 * 156_000.0 / 1e9;
        assert!((cores - 17.0).abs() < 1.5, "cores={cores}");
    }

    #[test]
    fn faster_fig25_anchor() {
        // Fig 25: 340 K op/s costs ~20 host cores.
        let p = Params::paper();
        let per_op = p.faster_net_ns + p.faster_core_ns + p.faster_idevice_ns;
        let cores = per_op as f64 * 340_000.0 / 1e9;
        assert!((cores - 20.0).abs() < 1.5, "cores={cores}");
    }
}
