//! Metadata encoding for segment 0 (§4.3: "One of the segments is
//! reserved to persistently store the metadata of directories and files,
//! as well as the file mapping").
//!
//! Simple length-checked binary format:
//! `magic u32 | next_dir u32 | next_file u32 | ndirs u32 | nfiles u32 |
//!  dirs[] | files[]`.

use std::collections::HashMap;

use super::FsError;

/// Directory identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DirId(pub u32);

/// File identifier — what request encodings carry on the wire (Fig 9).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FileId(pub u32);

/// Per-file metadata including the file mapping (segment vector).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileMeta {
    pub id: FileId,
    pub dir: DirId,
    pub name: String,
    pub size: u64,
    /// The file mapping: i-th file segment -> SSD segment index.
    pub segments: Vec<u32>,
}

const MAGIC: u32 = 0xDD5_F500;

struct Writer(Vec<u8>);

impl Writer {
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], FsError> {
        if self.at + n > self.buf.len() {
            return Err(FsError::Corrupt("truncated metadata".into()));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32, FsError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, FsError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn str(&mut self) -> Result<String, FsError> {
        let n = self.u32()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|_| FsError::Corrupt("bad utf8".into()))
    }
}

/// Serialize metadata; fails if it does not fit the metadata segment.
pub fn encode(
    dirs: &HashMap<DirId, String>,
    files: &HashMap<FileId, FileMeta>,
    next_dir: u32,
    next_file: u32,
    segment_size: usize,
) -> Result<Vec<u8>, FsError> {
    let mut w = Writer(Vec::new());
    w.u32(MAGIC);
    w.u32(next_dir);
    w.u32(next_file);
    w.u32(dirs.len() as u32);
    w.u32(files.len() as u32);
    // Deterministic order for reproducible images.
    let mut ds: Vec<_> = dirs.iter().collect();
    ds.sort_by_key(|(id, _)| **id);
    for (id, name) in ds {
        w.u32(id.0);
        w.str(name);
    }
    let mut fsv: Vec<_> = files.values().collect();
    fsv.sort_by_key(|f| f.id);
    for f in fsv {
        w.u32(f.id.0);
        w.u32(f.dir.0);
        w.str(&f.name);
        w.u64(f.size);
        w.u32(f.segments.len() as u32);
        for &s in &f.segments {
            w.u32(s);
        }
    }
    if w.0.len() > segment_size {
        return Err(FsError::NoSpace);
    }
    Ok(w.0)
}

/// Deserialize metadata from a segment-0 image.
#[allow(clippy::type_complexity)]
pub fn decode(
    buf: &[u8],
) -> Result<(HashMap<DirId, String>, HashMap<FileId, FileMeta>, u32, u32), FsError> {
    let mut r = Reader { buf, at: 0 };
    if r.u32()? != MAGIC {
        return Err(FsError::Corrupt("bad magic (not a DDS filesystem)".into()));
    }
    let next_dir = r.u32()?;
    let next_file = r.u32()?;
    let ndirs = r.u32()? as usize;
    let nfiles = r.u32()? as usize;
    let mut dirs = HashMap::with_capacity(ndirs);
    for _ in 0..ndirs {
        let id = DirId(r.u32()?);
        dirs.insert(id, r.str()?);
    }
    let mut files = HashMap::with_capacity(nfiles);
    for _ in 0..nfiles {
        let id = FileId(r.u32()?);
        let dir = DirId(r.u32()?);
        let name = r.str()?;
        let size = r.u64()?;
        let nseg = r.u32()? as usize;
        let mut segments = Vec::with_capacity(nseg);
        for _ in 0..nseg {
            segments.push(r.u32()?);
        }
        files.insert(id, FileMeta { id, dir, name, size, segments });
    }
    Ok((dirs, files, next_dir, next_file))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut dirs = HashMap::new();
        dirs.insert(DirId(1), "db".to_string());
        let mut files = HashMap::new();
        files.insert(
            FileId(7),
            FileMeta {
                id: FileId(7),
                dir: DirId(1),
                name: "rbpex".into(),
                size: 123456,
                segments: vec![3, 9, 12],
            },
        );
        let buf = encode(&dirs, &files, 2, 8, 1 << 20).unwrap();
        let (d2, f2, nd, nf) = decode(&buf).unwrap();
        assert_eq!(d2, dirs);
        assert_eq!(f2, files);
        assert_eq!((nd, nf), (2, 8));
    }

    #[test]
    fn bad_magic_rejected() {
        let buf = vec![0u8; 64];
        assert!(matches!(decode(&buf), Err(FsError::Corrupt(_))));
    }

    #[test]
    fn truncation_rejected() {
        let mut dirs = HashMap::new();
        dirs.insert(DirId(1), "a-directory-name".to_string());
        let buf = encode(&dirs, &HashMap::new(), 2, 1, 1 << 20).unwrap();
        assert!(matches!(decode(&buf[..buf.len() - 4]), Err(FsError::Corrupt(_))));
    }

    #[test]
    fn size_limit_enforced() {
        let mut dirs = HashMap::new();
        dirs.insert(DirId(1), "x".repeat(100));
        assert!(matches!(encode(&dirs, &HashMap::new(), 2, 1, 64), Err(FsError::NoSpace)));
    }
}
