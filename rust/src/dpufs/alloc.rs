//! Segment bitmap allocator (§4.3: "use a bitmap to track their
//! availability").

/// Fixed-size bitmap with first-fit allocation and a rotating cursor to
/// avoid rescanning the full prefix on every alloc.
#[derive(Debug, Clone)]
pub struct SegmentBitmap {
    words: Vec<u64>,
    len: usize,
    used: usize,
    cursor: usize,
}

impl SegmentBitmap {
    pub fn new(len: usize) -> Self {
        SegmentBitmap { words: vec![0; len.div_ceil(64)], len, used: 0, cursor: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn used(&self) -> usize {
        self.used
    }

    pub fn free(&self) -> usize {
        self.len - self.used
    }

    pub fn get(&self, i: usize) -> bool {
        assert!(i < self.len);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }

    pub fn set(&mut self, i: usize, v: bool) {
        assert!(i < self.len);
        let was = self.get(i);
        if v {
            self.words[i / 64] |= 1 << (i % 64);
        } else {
            self.words[i / 64] &= !(1 << (i % 64));
        }
        match (was, v) {
            (false, true) => self.used += 1,
            (true, false) => self.used -= 1,
            _ => {}
        }
    }

    /// Allocate the next free segment, or `None` when full.
    pub fn alloc(&mut self) -> Option<usize> {
        if self.used == self.len {
            return None;
        }
        for step in 0..self.len {
            let i = (self.cursor + step) % self.len;
            if !self.get(i) {
                self.set(i, true);
                self.cursor = (i + 1) % self.len;
                return Some(i);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_all_then_none() {
        let mut b = SegmentBitmap::new(130);
        let mut got = Vec::new();
        while let Some(i) = b.alloc() {
            got.push(i);
        }
        assert_eq!(got.len(), 130);
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), 130);
        assert_eq!(b.alloc(), None);
        assert_eq!(b.free(), 0);
    }

    #[test]
    fn free_and_realloc() {
        let mut b = SegmentBitmap::new(8);
        for _ in 0..8 {
            b.alloc();
        }
        b.set(3, false);
        b.set(5, false);
        assert_eq!(b.free(), 2);
        let a = b.alloc().unwrap();
        let c = b.alloc().unwrap();
        let mut pair = vec![a, c];
        pair.sort_unstable();
        assert_eq!(pair, vec![3, 5]);
    }

    #[test]
    fn counts_track_sets() {
        let mut b = SegmentBitmap::new(64);
        b.set(0, true);
        b.set(0, true); // idempotent
        assert_eq!(b.used(), 1);
        b.set(0, false);
        b.set(0, false);
        assert_eq!(b.used(), 0);
    }
}
