//! The durability plane: checksummed metadata write-ahead journal +
//! shadow superblock (crash-consistent §4.3 "persistently store the
//! metadata ... as well as the file mapping").
//!
//! Segment 0 is the **superblock segment**, split into two shadow
//! slots of `segment_size / 2` bytes each; slot `seq % 2` holds the
//! checksummed metadata image committed at sequence `seq`, so
//! successive syncs alternate slots and a torn slot write can never
//! destroy the last committed image. Segment 1 is the **journal
//! segment**: an append-only log of checksummed, sequence-numbered
//! frames that wraps to offset 0 when full (safe, because by then the
//! superblock holds a newer committed image than anything overwritten).
//!
//! Every on-disk structure is one [`encode_frame`] frame:
//!
//! ```text
//! offset  0  magic        u32 LE   (SUPER / JOURNAL_DATA / JOURNAL_COMMIT)
//! offset  4  seq          u64 LE   metadata sequence number
//! offset 12  len          u32 LE   payload length in bytes
//! offset 16  payload_crc  u32 LE   crc32(payload)
//! offset 20  header_crc   u32 LE   crc32(bytes 0..20)
//! offset 24  payload      len bytes (the segment-0 metadata image)
//! ```
//!
//! A torn write of any single frame is always detected: a cut inside
//! the header fails `header_crc`, a cut inside the payload fails
//! `payload_crc`, and a bit flip anywhere fails one of the two. The
//! commit protocol and the mount-time recovery that consumes these
//! frames live in [`super::DpuFs::sync_metadata`] /
//! [`super::DpuFs::mount_with_report`].

use super::FsError;
use crate::ssd::Ssd;

/// Frame header length in bytes (see module docs for the layout).
pub const FRAME_HEADER_LEN: usize = 24;

/// Superblock-slot frame (the checksummed shadow metadata image).
pub const SUPER_MAGIC: u32 = 0x0DD5_5B01;
/// Journal data frame: the WAL record carrying a full metadata image.
pub const JOURNAL_DATA_MAGIC: u32 = 0x0DD5_3D01;
/// Journal commit frame: checkpoint marker — the superblock write for
/// `seq` completed. Diagnostic/reporting only: recovery's
/// roll-forward/roll-back decision rests entirely on DATA records vs
/// superblock sequence numbers (every crash window resolves without
/// it — see the DESIGN.md recovery table); the marker records protocol
/// step 3 for the `RecoveryReport` and for offline forensics.
pub const JOURNAL_COMMIT_MAGIC: u32 = 0x0DD5_3C01;
/// Journal extent-remap frame: the data-path commit record. Carries a
/// [`RemapRecord`] — one file's segment flips from old (shadow) extents
/// to freshly written ones. Appending this frame IS the durable-WRITE
/// ack point: recovery replays remap records with `seq` newer than the
/// base metadata image, and a torn remap append simply rolls the WRITE
/// back (the old segments were never touched).
pub const JOURNAL_REMAP_MAGIC: u32 = 0x0DD5_3E01;

/// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320), nibble-table
/// implementation — no deps, fast enough that the crash-point
/// enumeration harness can checksum thousands of replayed images in a
/// debug build. Pinned against published check values in the tests.
pub fn crc32(bytes: &[u8]) -> u32 {
    const TAB: [u32; 16] = [
        0x0000_0000, 0x1DB7_1064, 0x3B6E_20C8, 0x26D9_30AC,
        0x76DC_4190, 0x6B6B_51F4, 0x4DB2_6158, 0x5005_713C,
        0xEDB8_8320, 0xF00F_9344, 0xD6D6_A3E8, 0xCB61_B38C,
        0x9B64_C2B0, 0x86D3_D2D4, 0xA00A_E278, 0xBDBD_F21C,
    ];
    let mut crc: u32 = !0;
    for &b in bytes {
        crc ^= b as u32;
        crc = TAB[(crc & 0xF) as usize] ^ (crc >> 4);
        crc = TAB[(crc & 0xF) as usize] ^ (crc >> 4);
    }
    !crc
}

/// Bytes available to one superblock slot (two slots per segment).
pub fn slot_capacity(segment_size: u64) -> usize {
    (segment_size / 2) as usize
}

/// Largest metadata image the durability plane can persist: it must
/// fit one superblock slot behind a frame header (the journal segment
/// is larger, so the slot is the binding constraint).
pub fn max_image_len(segment_size: u64) -> usize {
    slot_capacity(segment_size).saturating_sub(FRAME_HEADER_LEN)
}

/// Encode one frame: header (with both checksums) + payload.
pub fn encode_frame(magic: u32, seq: u64, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER_LEN + payload.len());
    out.extend_from_slice(&magic.to_le_bytes());
    out.extend_from_slice(&seq.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    let header_crc = crc32(&out[..20]);
    out.extend_from_slice(&header_crc.to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Decode the frame at the head of `buf`. Returns
/// `(magic, seq, payload, total_frame_len)`, or `None` for anything
/// torn, truncated, bit-flipped, or not a known frame magic.
pub fn decode_frame(buf: &[u8]) -> Option<(u32, u64, &[u8], usize)> {
    if buf.len() < FRAME_HEADER_LEN {
        return None;
    }
    let header_crc = u32::from_le_bytes(buf[20..24].try_into().unwrap());
    if crc32(&buf[..20]) != header_crc {
        return None;
    }
    let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    if !matches!(
        magic,
        SUPER_MAGIC | JOURNAL_DATA_MAGIC | JOURNAL_COMMIT_MAGIC | JOURNAL_REMAP_MAGIC
    ) {
        return None;
    }
    let seq = u64::from_le_bytes(buf[4..12].try_into().unwrap());
    let len = u32::from_le_bytes(buf[12..16].try_into().unwrap()) as usize;
    let payload_crc = u32::from_le_bytes(buf[16..20].try_into().unwrap());
    let total = FRAME_HEADER_LEN.checked_add(len)?;
    if total > buf.len() {
        return None;
    }
    let payload = &buf[FRAME_HEADER_LEN..total];
    if crc32(payload) != payload_crc {
        return None;
    }
    Some((magic, seq, payload, total))
}

fn dev(e: crate::ssd::SsdError) -> FsError {
    FsError::Device(e.to_string())
}

/// In an extent-remap entry, this `old_seg` value marks a growth entry:
/// the file had no segment at that index before the WRITE (the shadow
/// extends the mapping instead of replacing a segment).
pub const REMAP_GROWTH: u32 = u32::MAX;

/// One segment flip inside a [`RemapRecord`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RemapEntry {
    /// Index into the file's segment vector.
    pub seg_idx: u32,
    /// Segment previously mapped at `seg_idx`, or [`REMAP_GROWTH`] when
    /// the WRITE grew the file past its old mapping.
    pub old_seg: u32,
    /// Freshly written shadow segment that replaces (or extends) it.
    pub new_seg: u32,
}

/// The payload of a [`JOURNAL_REMAP_MAGIC`] frame: one committed
/// durable WRITE, expressed as the file's new size plus the per-index
/// segment flips.
///
/// ```text
/// offset  0  file_id   u32 LE
/// offset  4  new_size  u64 LE   (file size after the WRITE)
/// offset 12  nentries  u32 LE
/// offset 16  entries   nentries × (seg_idx u32 | old_seg u32 | new_seg u32) LE
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RemapRecord {
    pub file_id: u32,
    pub new_size: u64,
    pub entries: Vec<RemapEntry>,
}

impl RemapRecord {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.entries.len() * 12);
        out.extend_from_slice(&self.file_id.to_le_bytes());
        out.extend_from_slice(&self.new_size.to_le_bytes());
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for e in &self.entries {
            out.extend_from_slice(&e.seg_idx.to_le_bytes());
            out.extend_from_slice(&e.old_seg.to_le_bytes());
            out.extend_from_slice(&e.new_seg.to_le_bytes());
        }
        out
    }

    /// Decode a remap payload (the frame CRCs already vouched for the
    /// bytes; this only rejects structural nonsense like a length that
    /// does not match `nentries`).
    pub fn decode(payload: &[u8]) -> Result<Self, FsError> {
        let bad = |why: &str| FsError::Corrupt(format!("remap record: {why}"));
        if payload.len() < 16 {
            return Err(bad("truncated header"));
        }
        let file_id = u32::from_le_bytes(payload[0..4].try_into().unwrap());
        let new_size = u64::from_le_bytes(payload[4..12].try_into().unwrap());
        let nentries = u32::from_le_bytes(payload[12..16].try_into().unwrap()) as usize;
        if payload.len() != 16 + nentries * 12 {
            return Err(bad("entry count disagrees with payload length"));
        }
        let mut entries = Vec::with_capacity(nentries);
        for i in 0..nentries {
            let at = 16 + i * 12;
            entries.push(RemapEntry {
                seg_idx: u32::from_le_bytes(payload[at..at + 4].try_into().unwrap()),
                old_seg: u32::from_le_bytes(payload[at + 4..at + 8].try_into().unwrap()),
                new_seg: u32::from_le_bytes(payload[at + 8..at + 12].try_into().unwrap()),
            });
        }
        Ok(RemapRecord { file_id, new_size, entries })
    }
}

/// Write the checksummed metadata image for `seq` into its shadow slot
/// (`seq % 2`) of segment 0.
pub fn write_slot(ssd: &Ssd, segment_size: u64, seq: u64, image: &[u8]) -> Result<(), FsError> {
    let cap = slot_capacity(segment_size);
    let frame = encode_frame(SUPER_MAGIC, seq, image);
    if frame.len() > cap {
        return Err(FsError::NoSpace);
    }
    ssd.write_from((seq % 2) * cap as u64, &frame).map_err(dev)
}

/// Parse both superblock slots out of a segment-0 image; each valid
/// slot yields `(seq, metadata image)`.
pub fn read_slots(superblock: &[u8]) -> [Option<(u64, Vec<u8>)>; 2] {
    let cap = superblock.len() / 2;
    let parse = |slot: &[u8]| {
        decode_frame(slot)
            .and_then(|(m, seq, p, _)| (m == SUPER_MAGIC).then(|| (seq, p.to_vec())))
    };
    [parse(&superblock[..cap]), parse(&superblock[cap..])]
}

/// Append one frame to the journal (segment 1), wrapping to offset 0
/// when the segment tail cannot hold it. `write_off` is the caller's
/// persistent cursor within the segment.
pub fn append(
    ssd: &Ssd,
    segment_size: u64,
    write_off: &mut u64,
    magic: u32,
    seq: u64,
    payload: &[u8],
) -> Result<(), FsError> {
    let frame = encode_frame(magic, seq, payload);
    if frame.len() as u64 > segment_size {
        return Err(FsError::NoSpace);
    }
    if *write_off + frame.len() as u64 > segment_size {
        // Wrap: everything overwritten is older than the committed
        // superblock image, so it can never be needed for recovery.
        *write_off = 0;
    }
    ssd.write_from(segment_size + *write_off, &frame).map_err(dev)?;
    *write_off += frame.len() as u64;
    Ok(())
}

/// What a journal scan found.
#[derive(Debug, Clone)]
pub struct JournalScan {
    /// Valid data records `(seq, metadata image)` in chain order.
    pub records: Vec<(u64, Vec<u8>)>,
    /// Sequence numbers of valid commit markers, in chain order.
    pub commits: Vec<u64>,
    /// Valid extent-remap payloads `(seq, remap payload)` in chain
    /// order. Recovery replays the subset with `seq` newer than the
    /// chosen base image; stale wrapped residue carries older seqs and
    /// is filtered out there.
    pub remaps: Vec<(u64, Vec<u8>)>,
    /// Offset just past the last valid frame — where the next append
    /// goes.
    pub end_off: usize,
    /// The chain ended on non-zero bytes: a torn append (or stale
    /// wrapped residue) sits at the tail. Informational.
    pub torn_tail: bool,
}

/// Walk the journal chain from offset 0, stopping at the first invalid
/// frame. A torn append is by construction the *last* write of the
/// chain, so stopping there is exactly "ignore the uncommitted tail";
/// stale pre-wrap frames that happen to parse carry strictly older
/// sequence numbers and are harmless to collect.
pub fn scan(journal: &[u8]) -> JournalScan {
    let mut records = Vec::new();
    let mut commits = Vec::new();
    let mut remaps = Vec::new();
    let mut at = 0usize;
    while at + FRAME_HEADER_LEN <= journal.len() {
        match decode_frame(&journal[at..]) {
            Some((JOURNAL_DATA_MAGIC, seq, payload, total)) => {
                records.push((seq, payload.to_vec()));
                at += total;
            }
            Some((JOURNAL_COMMIT_MAGIC, seq, _, total)) => {
                commits.push(seq);
                at += total;
            }
            Some((JOURNAL_REMAP_MAGIC, seq, payload, total)) => {
                remaps.push((seq, payload.to_vec()));
                at += total;
            }
            _ => break,
        }
    }
    let tail_end = (at + FRAME_HEADER_LEN).min(journal.len());
    let torn_tail = journal[at..tail_end].iter().any(|&b| b != 0);
    JournalScan { records, commits, remaps, end_off: at, torn_tail }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// Published CRC-32 (IEEE) check values — pins the polynomial,
    /// reflection, and init/final-xor conventions.
    #[test]
    fn crc32_known_vectors() {
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn frame_roundtrip_and_total_len() {
        let f = encode_frame(JOURNAL_DATA_MAGIC, 42, b"hello");
        assert_eq!(f.len(), FRAME_HEADER_LEN + 5);
        let (magic, seq, payload, total) = decode_frame(&f).expect("valid frame");
        assert_eq!((magic, seq, payload, total), (JOURNAL_DATA_MAGIC, 42, &b"hello"[..], f.len()));
    }

    #[test]
    fn every_truncation_and_bit_flip_rejected() {
        let f = encode_frame(SUPER_MAGIC, 7, b"image-bytes");
        for cut in 0..f.len() {
            assert!(decode_frame(&f[..cut]).is_none(), "prefix {cut} accepted");
        }
        for byte in 0..f.len() {
            for bit in 0..8 {
                let mut bad = f.clone();
                bad[byte] ^= 1 << bit;
                assert!(
                    decode_frame(&bad).is_none(),
                    "flip of byte {byte} bit {bit} accepted"
                );
            }
        }
    }

    #[test]
    fn unknown_magic_rejected_even_with_valid_checksums() {
        let mut f = encode_frame(SUPER_MAGIC, 1, b"x");
        f[0..4].copy_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
        let crc = crc32(&f[..20]);
        f[20..24].copy_from_slice(&crc.to_le_bytes());
        assert!(decode_frame(&f).is_none());
    }

    #[test]
    fn journal_append_scan_and_wrap() {
        let seg = 1u64 << 13;
        let ssd = Arc::new(Ssd::new(4 * seg, 512));
        let mut off = 0u64;
        append(&ssd, seg, &mut off, JOURNAL_DATA_MAGIC, 1, &[0xAA; 100]).unwrap();
        append(&ssd, seg, &mut off, JOURNAL_COMMIT_MAGIC, 1, &[]).unwrap();
        append(&ssd, seg, &mut off, JOURNAL_DATA_MAGIC, 2, &[0xBB; 100]).unwrap();
        let mut buf = vec![0u8; seg as usize];
        ssd.read_into(seg, &mut buf).unwrap();
        let s = scan(&buf);
        assert_eq!(s.records.len(), 2);
        assert_eq!(s.records[1].0, 2);
        assert_eq!(s.commits, vec![1]);
        assert_eq!(s.end_off as u64, off);
        assert!(!s.torn_tail, "fresh device: zeroed tail");
        // Fill until the cursor wraps; the record at offset 0 must then
        // lead the chain.
        let mut seq = 3u64;
        while off + (FRAME_HEADER_LEN as u64 + 100) <= seg {
            append(&ssd, seg, &mut off, JOURNAL_DATA_MAGIC, seq, &[0xCC; 100]).unwrap();
            seq += 1;
        }
        append(&ssd, seg, &mut off, JOURNAL_DATA_MAGIC, seq, &[0xDD; 100]).unwrap();
        assert_eq!(off, FRAME_HEADER_LEN as u64 + 100, "cursor wrapped to the front");
        ssd.read_into(seg, &mut buf).unwrap();
        let s = scan(&buf);
        assert_eq!(s.records[0].0, seq, "wrapped record leads the chain");
        assert_eq!(s.records[0].1, vec![0xDD; 100]);
    }

    #[test]
    fn remap_record_roundtrip_and_scan_order() {
        let rec = RemapRecord {
            file_id: 7,
            new_size: 123_456,
            entries: vec![
                RemapEntry { seg_idx: 0, old_seg: 4, new_seg: 9 },
                RemapEntry { seg_idx: 2, old_seg: REMAP_GROWTH, new_seg: 10 },
            ],
        };
        let payload = rec.encode();
        assert_eq!(RemapRecord::decode(&payload).unwrap(), rec);
        // Structural rejection: mismatched entry count and truncation.
        assert!(RemapRecord::decode(&payload[..payload.len() - 1]).is_err());
        assert!(RemapRecord::decode(&payload[..8]).is_err());
        let mut lying = payload.clone();
        lying[12..16].copy_from_slice(&9u32.to_le_bytes());
        assert!(RemapRecord::decode(&lying).is_err());
        // Remap frames interleave with data/commit frames without
        // terminating the chain, and come back in chain order.
        let seg = 1u64 << 13;
        let ssd = Arc::new(Ssd::new(4 * seg, 512));
        let mut off = 0u64;
        append(&ssd, seg, &mut off, JOURNAL_DATA_MAGIC, 1, &[0xAA; 50]).unwrap();
        append(&ssd, seg, &mut off, JOURNAL_REMAP_MAGIC, 2, &payload).unwrap();
        append(&ssd, seg, &mut off, JOURNAL_COMMIT_MAGIC, 1, &[]).unwrap();
        append(&ssd, seg, &mut off, JOURNAL_REMAP_MAGIC, 3, &payload).unwrap();
        let mut buf = vec![0u8; seg as usize];
        ssd.read_into(seg, &mut buf).unwrap();
        let s = scan(&buf);
        assert_eq!(s.records.len(), 1);
        assert_eq!(s.commits, vec![1]);
        assert_eq!(s.remaps.iter().map(|(q, _)| *q).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(RemapRecord::decode(&s.remaps[0].1).unwrap(), rec);
        assert_eq!(s.end_off as u64, off);
    }

    #[test]
    fn superblock_slots_alternate_and_parse() {
        let seg = 1u64 << 13;
        let ssd = Arc::new(Ssd::new(4 * seg, 512));
        write_slot(&ssd, seg, 6, b"even").unwrap();
        write_slot(&ssd, seg, 7, b"odd").unwrap();
        let mut buf = vec![0u8; seg as usize];
        ssd.read_into(0, &mut buf).unwrap();
        let slots = read_slots(&buf);
        assert_eq!(slots[0], Some((6, b"even".to_vec())));
        assert_eq!(slots[1], Some((7, b"odd".to_vec())));
        // Oversized image refused before touching the device.
        assert_eq!(
            write_slot(&ssd, seg, 8, &vec![0u8; slot_capacity(seg)]),
            Err(FsError::NoSpace)
        );
    }
}
