//! DPU flat file system (§4.3 "Low-latency file access").
//!
//! Exactly the paper's design: SSD space is divided into fixed-length
//! segments (block-aligned); a bitmap tracks segment availability; files
//! are allocated segments on demand; directories are flat; segment 0 is
//! reserved to persistently store directory/file metadata and the *file
//! mapping* (the per-file vector of segments). File I/O translates a
//! `(file, offset, len)` into per-segment extents and issues device ops.
//!
//! Metadata persistence is **crash-consistent**: segment 0 holds two
//! checksummed shadow superblock slots and segment 1 a checksummed,
//! sequence-numbered write-ahead journal ([`journal`]). Every
//! [`DpuFs::sync_metadata`] runs journal-append → shadow-superblock
//! write → commit marker, so a power cut tearing any single device
//! write is detected by checksum at [`DpuFs::mount`] and rolled
//! forward (journal committed, superblock torn) or back (journal
//! append torn) — never silently corrupted.
//!
//! The **data path** gets the same contract through redirect-on-write
//! ([`DpuFs::redirect_prepare`] / [`DpuFs::redirect_commit`]): a
//! durable WRITE lands in freshly allocated shadow segments, and a
//! single journaled extent-remap record flips the file mapping — the
//! append is the ack point, so recovery always sees either the
//! complete old extent or the complete new one. Segment 2 holds a
//! per-segment epoch + CRC trailer table so mount can detect and
//! quarantine shadows that crashed pre-commit.

mod alloc;
pub mod journal;
pub mod meta;

pub use alloc::SegmentBitmap;
pub use meta::{DirId, FileId, FileMeta};

use std::collections::HashMap;
use std::sync::Arc;

use crate::ssd::Ssd;

/// Segments reserved at the front of the device: segment 0 =
/// superblock (two shadow slots), segment 1 = metadata journal,
/// segment 2 = per-segment extent epoch/CRC trailer table.
pub const RESERVED_SEGMENTS: usize = 3;

/// Bytes per entry in the segment-2 extent trailer table:
/// `epoch u64 LE | data_crc u32 LE | rec_crc u32 LE`, where `rec_crc`
/// checksums the first 12 bytes. Entry `s` lives at device address
/// `2 * segment_size + s * 16`. `epoch` is the journal sequence the
/// segment's remap record burns; a valid trailer whose epoch exceeds
/// the recovered sequence is a shadow extent that crashed pre-commit
/// and gets quarantined at mount.
pub const TRAILER_LEN: usize = 16;

/// File-system errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FsError {
    NoSpace,
    NoSuchDir,
    NoSuchFile,
    DirNotEmpty,
    AlreadyExists,
    OutOfBounds,
    Corrupt(String),
    Device(String),
}

impl std::fmt::Display for FsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for FsError {}

/// Configuration of the on-SSD layout.
#[derive(Debug, Clone)]
pub struct FsConfig {
    /// Segment length in bytes; must be a multiple of the block size.
    pub segment_size: u64,
}

impl Default for FsConfig {
    fn default() -> Self {
        // 1 MiB segments: big enough that an 8 KB-page file is a short
        // segment vector, small enough for fine-grained allocation.
        FsConfig { segment_size: 1 << 20 }
    }
}

/// A byte extent on the device, produced by the file mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Extent {
    pub addr: u64,
    pub len: u64,
}

/// What mount-time crash recovery observed and repaired.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecoveryReport {
    /// The metadata sequence number the file system recovered to.
    pub recovered_seq: u64,
    /// The journal held a committed image newer than any superblock
    /// slot (the superblock write was lost or torn): recovery replayed
    /// the journal record forward.
    pub rolled_forward: bool,
    /// Recovery rewrote the stale/torn superblock slot from the
    /// journal (implies `rolled_forward`).
    pub repaired_superblock: bool,
    /// Persisted `next_dir`/`next_file` counters were at or below a
    /// live id and were clamped to `max live id + 1` (would otherwise
    /// let `create_file` silently reuse a live id).
    pub counters_clamped: bool,
    /// Checksum validity of superblock slots 0 and 1.
    pub valid_slots: [bool; 2],
    /// Highest valid superblock sequence, if any slot was valid.
    pub superblock_seq: Option<u64>,
    /// Valid journal data records in the chain.
    pub journal_records: usize,
    /// Valid journal commit markers in the chain.
    pub journal_commits: usize,
    /// Highest sequence among valid journal data records.
    pub highest_journal_seq: Option<u64>,
    /// The journal chain ended on non-zero bytes (a torn append or
    /// stale wrapped residue).
    pub torn_tail: bool,
    /// Committed extent-remap records (durable WRITEs newer than the
    /// base metadata image) replayed onto the file mapping.
    pub remaps_applied: usize,
    /// Shadow extents whose trailer carried an epoch newer than the
    /// recovered sequence — torn pre-commit WRITEs. Their trailers
    /// were zeroed and the segments returned to the free pool.
    pub quarantined_extents: usize,
}

/// An owned copy of the in-memory metadata state (see
/// [`DpuFs::meta_snapshot`] / [`DpuFs::restore_snapshot`]).
pub struct MetaSnapshot {
    dirs: HashMap<DirId, String>,
    files: HashMap<FileId, FileMeta>,
    next_dir: u32,
    next_file: u32,
    bitmap: SegmentBitmap,
}

/// The DPU file system. All metadata lives on the DPU (which is what
/// enables read offloading — the offload engine resolves file reads
/// without consulting the host, §3).
pub struct DpuFs {
    ssd: Arc<Ssd>,
    cfg: FsConfig,
    bitmap: SegmentBitmap,
    dirs: HashMap<DirId, String>,
    files: HashMap<FileId, FileMeta>,
    next_dir: u32,
    next_file: u32,
    /// Last committed metadata sequence number.
    seq: u64,
    /// Journal append cursor within segment 1.
    journal_off: u64,
    /// Committed extent-remap records not yet superseded by a full
    /// metadata image in a superblock slot. While nonzero, a journal
    /// wrap would overwrite the only durable copy of acked WRITEs, so
    /// any append that would wrap first checkpoints the image
    /// ([`Self::checkpoint_slot`]).
    live_remaps: usize,
    /// Sequence of the newest image written to a superblock slot —
    /// the checkpoint picks a sequence of the *other* parity so a torn
    /// checkpoint write can never destroy the only committed image.
    last_slot_seq: u64,
    /// Invoked immediately after a remap record commits (the mapping
    /// flip), with the `(file, offset, len)` byte range the WRITE
    /// replaced. The read-cache tier registers here: the flip is the
    /// exact instant pre-overwrite bytes become stale, for both the
    /// file-service durable path and [`DpuFs::write_durable`].
    remap_commit_hook: Option<Arc<dyn Fn(FileId, u64, u64) + Send + Sync>>,
}

/// A prepared redirect-on-write: shadow segments are allocated and
/// pre-imaged (old contents copied, growth segments zeroed), and
/// `extents` address the caller's payload bytes *inside the shadows*.
/// Nothing is durable until [`DpuFs::redirect_commit`] journals the
/// remap record; [`DpuFs::redirect_abort`] returns the shadows to the
/// free pool.
#[derive(Debug, Clone)]
pub struct RedirectPlan {
    pub file: FileId,
    /// File size after the WRITE commits (never shrinks).
    pub new_size: u64,
    /// Segment flips the commit record will journal, in `seg_idx`
    /// order.
    pub entries: Vec<journal::RemapEntry>,
    /// Shadow-addressed device extents covering the payload, in write
    /// order (the redirect-on-write analogue of
    /// [`DpuFs::map_extents`]).
    pub extents: Vec<Extent>,
}

impl DpuFs {
    /// Format a fresh file system on the device.
    pub fn format(ssd: Arc<Ssd>, cfg: FsConfig) -> Result<Self, FsError> {
        assert!(cfg.segment_size % ssd.block_size() as u64 == 0);
        let num_segments = (ssd.capacity() / cfg.segment_size) as usize;
        if num_segments < RESERVED_SEGMENTS + 1 {
            return Err(FsError::NoSpace);
        }
        // The trailer table (one 16-byte entry per segment) must fit
        // its reserved segment.
        if (num_segments * TRAILER_LEN) as u64 > cfg.segment_size {
            return Err(FsError::NoSpace);
        }
        // Invalidate any stale superblock/journal frames from a
        // previous file system so recovery can never resurrect them.
        let zeros = vec![0u8; (RESERVED_SEGMENTS as u64 * cfg.segment_size) as usize];
        ssd.write_from(0, &zeros).map_err(|e| FsError::Device(e.to_string()))?;
        let mut bitmap = SegmentBitmap::new(num_segments);
        for s in 0..RESERVED_SEGMENTS {
            bitmap.set(s, true); // superblock + journal (§4.3)
        }
        let mut fs = DpuFs {
            ssd,
            cfg,
            bitmap,
            dirs: HashMap::new(),
            files: HashMap::new(),
            next_dir: 1,
            next_file: 1,
            seq: 0,
            journal_off: 0,
            live_remaps: 0,
            last_slot_seq: 0,
            remap_commit_hook: None,
        };
        fs.sync_metadata()?;
        Ok(fs)
    }

    /// Mount an existing file system, running crash recovery (see
    /// [`Self::mount_with_report`]).
    pub fn mount(ssd: Arc<Ssd>, cfg: FsConfig) -> Result<Self, FsError> {
        Self::mount_with_report(ssd, cfg).map(|(fs, _)| fs)
    }

    /// Mount with full crash recovery:
    ///
    /// 1. checksum-verify both superblock slots and the journal chain;
    /// 2. pick the newest committed image — roll *forward* when the
    ///    journal holds a fully-written record newer than any valid
    ///    slot (repairing the superblock, idempotently: a re-crash
    ///    during the repair leaves the journal record intact and the
    ///    next mount repeats it), roll *back* past any torn journal
    ///    tail otherwise;
    /// 3. replay committed extent-remap records newer than that image
    ///    onto the file mapping (durable WRITEs whose ack point was
    ///    the journal append — an acked WRITE is never lost);
    /// 4. reject double-allocated/out-of-range segments, clamp stale
    ///    `next_dir`/`next_file` counters, rebuild the bitmap (which
    ///    also reclaims any unreferenced shadow segments);
    /// 5. quarantine shadow extents whose trailer epoch outruns the
    ///    recovered sequence — WRITEs that tore pre-commit: their
    ///    trailers are zeroed and the un-acked data is invisible;
    ///
    /// and report everything observed in a [`RecoveryReport`].
    pub fn mount_with_report(
        ssd: Arc<Ssd>,
        cfg: FsConfig,
    ) -> Result<(Self, RecoveryReport), FsError> {
        let seg = cfg.segment_size;
        let num_segments = (ssd.capacity() / seg) as usize;
        if num_segments < RESERVED_SEGMENTS + 1 {
            return Err(FsError::Corrupt("device too small for a DDS filesystem".into()));
        }
        if (num_segments * TRAILER_LEN) as u64 > seg {
            return Err(FsError::Corrupt(
                "trailer table does not fit its reserved segment".into(),
            ));
        }
        let mut sb = vec![0u8; seg as usize];
        ssd.read_into(0, &mut sb).map_err(|e| FsError::Device(e.to_string()))?;
        let slots = journal::read_slots(&sb);
        let mut jb = vec![0u8; seg as usize];
        ssd.read_into(seg, &mut jb).map_err(|e| FsError::Device(e.to_string()))?;
        let scan = journal::scan(&jb);

        let super_best: Option<(u64, Vec<u8>)> =
            slots.iter().flatten().max_by_key(|(s, _)| *s).cloned();
        let journal_best: Option<(u64, Vec<u8>)> =
            scan.records.iter().max_by_key(|(s, _)| *s).cloned();
        let (rolled_forward, seq, image) = match (&super_best, &journal_best) {
            (Some((ss, _)), Some((js, ji))) if js > ss => (true, *js, ji.clone()),
            (Some((ss, si)), _) => (false, *ss, si.clone()),
            (None, Some((js, ji))) => (true, *js, ji.clone()),
            (None, None) => {
                return Err(FsError::Corrupt(
                    "no valid superblock slot or journal record (not a DDS \
                     filesystem, or torn beyond recovery)"
                        .into(),
                ))
            }
        };

        // Validate the chosen image FIRST — all pure checks — so a
        // CRC-valid but semantically corrupt record can never cause the
        // failing mount path to mutate the device (repair writes happen
        // only once the image is known good).
        let (dirs, mut files, mut next_dir, mut next_file) = meta::decode(&image)?;

        // Replay committed durable WRITEs: remap records with a
        // sequence newer than the base image. Stale wrapped residue is
        // filtered out here — the wrap guard guarantees every remap
        // written before a journal wrap was checkpointed into a slot,
        // so its sequence is ≤ the base. Structural mismatches mean a
        // corrupt journal, never a silent wrong mapping.
        let mut replay: Vec<(u64, journal::RemapRecord)> = Vec::new();
        for (rseq, payload) in &scan.remaps {
            if *rseq > seq {
                replay.push((*rseq, journal::RemapRecord::decode(payload)?));
            }
        }
        replay.sort_by_key(|(rseq, _)| *rseq);
        let remaps_applied = replay.len();
        let mut recovered_seq = seq;
        for (rseq, rec) in replay {
            let meta = files.get_mut(&FileId(rec.file_id)).ok_or_else(|| {
                FsError::Corrupt(format!(
                    "remap record seq {rseq} references nonexistent file {}",
                    rec.file_id
                ))
            })?;
            for e in &rec.entries {
                let idx = e.seg_idx as usize;
                if idx < meta.segments.len() {
                    if e.old_seg == journal::REMAP_GROWTH || meta.segments[idx] != e.old_seg {
                        return Err(FsError::Corrupt(format!(
                            "remap record seq {rseq} disagrees with the file \
                             mapping at segment index {idx}"
                        )));
                    }
                    meta.segments[idx] = e.new_seg;
                } else if idx == meta.segments.len() && e.old_seg == journal::REMAP_GROWTH {
                    meta.segments.push(e.new_seg);
                } else {
                    return Err(FsError::Corrupt(format!(
                        "remap record seq {rseq} grows file {} out of order",
                        rec.file_id
                    )));
                }
            }
            meta.size = meta.size.max(rec.new_size);
            recovered_seq = recovered_seq.max(rseq);
        }
        // A committed image can still carry counters at/below a live id
        // (e.g. hand-built or pre-durability images): clamp, or
        // `create_file` would silently reuse a live `FileId`.
        let max_dir = dirs.keys().map(|d| d.0).max().unwrap_or(0);
        let max_file = files.keys().map(|f| f.0).max().unwrap_or(0);
        let mut counters_clamped = false;
        if next_dir <= max_dir {
            next_dir = max_dir + 1;
            counters_clamped = true;
        }
        if next_file <= max_file {
            next_file = max_file + 1;
            counters_clamped = true;
        }

        let mut bitmap = SegmentBitmap::new(num_segments);
        for s in 0..RESERVED_SEGMENTS {
            bitmap.set(s, true);
        }
        for f in files.values() {
            if !dirs.contains_key(&f.dir) {
                return Err(FsError::Corrupt(format!(
                    "file {} references nonexistent directory {}",
                    f.id.0, f.dir.0
                )));
            }
            for &s in &f.segments {
                if s as usize >= num_segments || bitmap.get(s as usize) {
                    return Err(FsError::Corrupt(format!(
                        "segment {s} double-allocated or out of range"
                    )));
                }
                bitmap.set(s as usize, true);
            }
        }

        // Scan the trailer table for orphan shadows (pure — the repair
        // writes that zero them come only after every validation above
        // held). A trailer that fails its own CRC is a torn trailer
        // write and is simply ignored: the shadow it described was
        // never committed and the bitmap rebuild already reclaimed it.
        let mut trailers = vec![0u8; num_segments * TRAILER_LEN];
        ssd.read_into(2 * seg, &mut trailers)
            .map_err(|e| FsError::Device(e.to_string()))?;
        let mut quarantine: Vec<usize> = Vec::new();
        for s in RESERVED_SEGMENTS..num_segments {
            let t = &trailers[s * TRAILER_LEN..(s + 1) * TRAILER_LEN];
            let rec_crc = u32::from_le_bytes(t[12..16].try_into().unwrap());
            if rec_crc != journal::crc32(&t[..12]) {
                continue;
            }
            let epoch = u64::from_le_bytes(t[0..8].try_into().unwrap());
            if epoch > recovered_seq {
                quarantine.push(s);
            }
        }

        let mut fs = DpuFs {
            ssd,
            cfg,
            bitmap,
            dirs,
            files,
            next_dir,
            next_file,
            seq: recovered_seq,
            journal_off: scan.end_off as u64,
            // Replayed remaps live only in the journal until the next
            // full image supersedes them — the wrap guard must keep
            // protecting them (mount deliberately writes no merged
            // image: a torn merge could destroy the only committed
            // base).
            live_remaps: remaps_applied,
            last_slot_seq: super_best.as_ref().map(|(s, _)| *s).unwrap_or(0),
            remap_commit_hook: None,
        };

        let mut repaired_superblock = false;
        if rolled_forward {
            // The WAL committed `seq` but the superblock write was lost
            // or torn: repair it now (the image validated above). If a
            // power cut tears THIS write, the journal record is still
            // intact and the next mount repeats the repair — replay is
            // idempotent.
            journal::write_slot(&fs.ssd, seg, seq, &image)?;
            fs.last_slot_seq = seq;
            fs.journal_append_guarded(journal::JOURNAL_COMMIT_MAGIC, seq, &[])?;
            repaired_superblock = true;
        }
        for s in &quarantine {
            // Zero the orphan trailer so the burned-but-lost epoch can
            // never shadow a future WRITE that reuses this sequence
            // range. Errors propagate: a re-crash here leaves a valid
            // trailer and the next mount repeats the quarantine.
            fs.write_trailer_raw(*s, &[0u8; TRAILER_LEN])?;
        }

        let report = RecoveryReport {
            recovered_seq,
            rolled_forward,
            repaired_superblock,
            counters_clamped,
            valid_slots: [slots[0].is_some(), slots[1].is_some()],
            superblock_seq: super_best.map(|(s, _)| s),
            journal_records: scan.records.len(),
            journal_commits: scan.commits.len(),
            highest_journal_seq: journal_best.map(|(s, _)| s),
            torn_tail: scan.torn_tail,
            remaps_applied,
            quarantined_extents: quarantine.len(),
        };
        Ok((fs, report))
    }

    /// Persist metadata + file mapping (§4.3), crash-consistently:
    ///
    /// 1. **Journal append** — the checksummed WAL record for sequence
    ///    `seq + 1` carrying the full metadata image. Once this write
    ///    completes, the new state survives any later torn write (roll
    ///    forward); if this write itself is torn, recovery rolls back
    ///    to the previous committed state.
    /// 2. **Shadow superblock** — the checksummed image into slot
    ///    `seq % 2`, never overwriting the last committed slot.
    /// 3. **Commit marker** — a journal checkpoint noting the
    ///    superblock now reflects `seq`.
    pub fn sync_metadata(&mut self) -> Result<(), FsError> {
        let seg = self.cfg.segment_size;
        let image = meta::encode(
            &self.dirs,
            &self.files,
            self.next_dir,
            self.next_file,
            journal::max_image_len(seg),
        )?;
        // Wrap check BEFORE burning the sequence: the guard's
        // checkpoint burns sequences of its own, and the DATA record
        // must stay newer than any checkpoint. A torn wrapping append
        // would otherwise decapitate the journal chain and lose the
        // acked remaps living in it.
        let flen = (journal::FRAME_HEADER_LEN + image.len()) as u64;
        if self.journal_off + flen > seg && self.live_remaps > 0 {
            self.checkpoint_slot()?;
        }
        let seq = self.seq + 1;
        // Burn the sequence number whether or not the protocol
        // completes: a failed attempt may already have landed its DATA
        // record, and a retried sync reusing the number could put two
        // different images with EQUAL seq in the journal — recovery's
        // max-seq rule must never face that tie.
        self.seq = seq;
        journal::append(
            &self.ssd,
            seg,
            &mut self.journal_off,
            journal::JOURNAL_DATA_MAGIC,
            seq,
            &image,
        )?;
        journal::write_slot(&self.ssd, seg, seq, &image)?;
        // The slot now holds a full image including every committed
        // remap: the journal's remap records are superseded and a
        // wrap is safe again.
        self.last_slot_seq = seq;
        self.live_remaps = 0;
        journal::append(
            &self.ssd,
            seg,
            &mut self.journal_off,
            journal::JOURNAL_COMMIT_MAGIC,
            seq,
            &[],
        )?;
        Ok(())
    }

    /// Checkpoint the current metadata image into a superblock slot
    /// without journaling it — the wrap guard's escape hatch. Burns a
    /// sequence whose parity differs from [`Self::last_slot_seq`]'s so
    /// the write lands in the *other* slot: if it tears, the newest
    /// committed image survives untouched and the journal (which the
    /// pending wrap has not yet overwritten) still reconstructs
    /// everything.
    fn checkpoint_slot(&mut self) -> Result<(), FsError> {
        let seg = self.cfg.segment_size;
        let image = meta::encode(
            &self.dirs,
            &self.files,
            self.next_dir,
            self.next_file,
            journal::max_image_len(seg),
        )?;
        let mut seq = self.seq + 1;
        if seq % 2 == self.last_slot_seq % 2 {
            seq += 1;
        }
        self.seq = seq;
        journal::write_slot(&self.ssd, seg, seq, &image)?;
        self.last_slot_seq = seq;
        self.live_remaps = 0;
        Ok(())
    }

    /// Journal append that runs the wrap guard first: an append that
    /// would wrap the journal while committed remap records are still
    /// live in it checkpoints the metadata image into a slot before
    /// the wrap can overwrite them.
    fn journal_append_guarded(
        &mut self,
        magic: u32,
        seq: u64,
        payload: &[u8],
    ) -> Result<(), FsError> {
        let seg = self.cfg.segment_size;
        let flen = (journal::FRAME_HEADER_LEN + payload.len()) as u64;
        if self.journal_off + flen > seg && self.live_remaps > 0 {
            self.checkpoint_slot()?;
        }
        journal::append(&self.ssd, seg, &mut self.journal_off, magic, seq, payload)
    }

    /// Raw 16-byte write into the segment-2 trailer table.
    fn write_trailer_raw(&self, segment: usize, bytes: &[u8; TRAILER_LEN]) -> Result<(), FsError> {
        let addr = 2 * self.cfg.segment_size + (segment * TRAILER_LEN) as u64;
        self.ssd.write_from(addr, bytes).map_err(|e| FsError::Device(e.to_string()))
    }

    /// Write segment `segment`'s epoch/CRC trailer.
    fn write_trailer(&self, segment: usize, epoch: u64, data_crc: u32) -> Result<(), FsError> {
        let mut t = [0u8; TRAILER_LEN];
        t[0..8].copy_from_slice(&epoch.to_le_bytes());
        t[8..12].copy_from_slice(&data_crc.to_le_bytes());
        let rec_crc = journal::crc32(&t[..12]);
        t[12..16].copy_from_slice(&rec_crc.to_le_bytes());
        self.write_trailer_raw(segment, &t)
    }

    pub fn segment_size(&self) -> u64 {
        self.cfg.segment_size
    }

    pub fn free_segments(&self) -> usize {
        self.bitmap.free()
    }

    /// Total segments on the device (including the reserved ones).
    pub fn num_segments(&self) -> usize {
        self.bitmap.len()
    }

    /// Last committed metadata sequence number.
    pub fn metadata_seq(&self) -> u64 {
        self.seq
    }

    /// The `(next_dir, next_file)` id counters (recovery invariants).
    pub fn counters(&self) -> (u32, u32) {
        (self.next_dir, self.next_file)
    }

    /// All directories, sorted by id.
    pub fn list_dirs(&self) -> Vec<(DirId, &str)> {
        let mut v: Vec<_> = self.dirs.iter().map(|(d, n)| (*d, n.as_str())).collect();
        v.sort_by_key(|(d, _)| *d);
        v
    }

    /// Capture the in-memory metadata state — the rollback unit for
    /// "apply + sync, or neither" control-plane semantics
    /// ([`crate::fileservice::FileServiceConfig::durable_metadata`]).
    /// Cheap relative to the sync it guards: control ops are rare.
    pub fn meta_snapshot(&self) -> MetaSnapshot {
        MetaSnapshot {
            dirs: self.dirs.clone(),
            files: self.files.clone(),
            next_dir: self.next_dir,
            next_file: self.next_file,
            bitmap: self.bitmap.clone(),
        }
    }

    /// Restore a snapshot taken by [`Self::meta_snapshot`] — rolls back
    /// a mutation whose durability sync failed, so a refused op can
    /// never be silently persisted by a later op's successful sync.
    /// The on-disk cursor state (`seq`, journal offset) is deliberately
    /// NOT restored: a torn append stays ignored on the device, and the
    /// failed attempt's sequence number stays burnt (see
    /// [`Self::sync_metadata`]) so a retry can never collide with it.
    pub fn restore_snapshot(&mut self, s: MetaSnapshot) {
        self.dirs = s.dirs;
        self.files = s.files;
        self.next_dir = s.next_dir;
        self.next_file = s.next_file;
        self.bitmap = s.bitmap;
    }

    // ----- control plane (§4.2: directory/file management) -----

    pub fn create_directory(&mut self, name: &str) -> Result<DirId, FsError> {
        if self.dirs.values().any(|n| n == name) {
            return Err(FsError::AlreadyExists);
        }
        let id = DirId(self.next_dir);
        self.next_dir += 1;
        self.dirs.insert(id, name.to_string());
        Ok(id)
    }

    pub fn remove_directory(&mut self, dir: DirId) -> Result<(), FsError> {
        if !self.dirs.contains_key(&dir) {
            return Err(FsError::NoSuchDir);
        }
        if self.files.values().any(|f| f.dir == dir) {
            return Err(FsError::DirNotEmpty);
        }
        self.dirs.remove(&dir);
        Ok(())
    }

    pub fn create_file(&mut self, dir: DirId, name: &str) -> Result<FileId, FsError> {
        if !self.dirs.contains_key(&dir) {
            return Err(FsError::NoSuchDir);
        }
        if self.files.values().any(|f| f.dir == dir && f.name == name) {
            return Err(FsError::AlreadyExists);
        }
        let id = FileId(self.next_file);
        self.next_file += 1;
        self.files.insert(
            id,
            FileMeta { id, dir, name: name.to_string(), size: 0, segments: Vec::new() },
        );
        Ok(id)
    }

    pub fn delete_file(&mut self, file: FileId) -> Result<(), FsError> {
        let meta = self.files.remove(&file).ok_or(FsError::NoSuchFile)?;
        for s in meta.segments {
            self.bitmap.set(s as usize, false);
        }
        Ok(())
    }

    pub fn file_meta(&self, file: FileId) -> Result<&FileMeta, FsError> {
        self.files.get(&file).ok_or(FsError::NoSuchFile)
    }

    pub fn list_dir(&self, dir: DirId) -> Vec<&FileMeta> {
        self.files.values().filter(|f| f.dir == dir).collect()
    }

    /// Grow (or keep) a file so `size` bytes are addressable, allocating
    /// segments from the bitmap. Atomic on failure: a refused grow
    /// frees everything it allocated and changes neither the mapping
    /// nor the size — half-mapped segments would otherwise sit
    /// unreachable in the file mapping and be persisted by the next
    /// metadata sync.
    pub fn ensure_size(&mut self, file: FileId, size: u64) -> Result<(), FsError> {
        let seg = self.cfg.segment_size;
        let need = size.div_ceil(seg) as usize;
        let meta = self.files.get_mut(&file).ok_or(FsError::NoSuchFile)?;
        let mut fresh: Vec<u32> = Vec::new();
        while meta.segments.len() + fresh.len() < need {
            match self.bitmap.alloc() {
                Some(s) => fresh.push(s as u32),
                None => {
                    for s in fresh {
                        self.bitmap.set(s as usize, false);
                    }
                    return Err(FsError::NoSpace);
                }
            }
        }
        meta.segments.extend(fresh);
        meta.size = meta.size.max(size);
        Ok(())
    }

    // ----- data plane -----

    /// Translate `(file, offset, len)` through the file mapping into
    /// device extents (§4.3: "translates the file address into a disk
    /// block address using the file mapping").
    pub fn map_extents(&self, file: FileId, offset: u64, len: u64) -> Result<Vec<Extent>, FsError> {
        let meta = self.files.get(&file).ok_or(FsError::NoSuchFile)?;
        if offset + len > meta.size {
            return Err(FsError::OutOfBounds);
        }
        let seg = self.cfg.segment_size;
        let mut extents = Vec::new();
        let mut cur = offset;
        let end = offset + len;
        while cur < end {
            let seg_idx = (cur / seg) as usize;
            let in_seg = cur % seg;
            let take = (seg - in_seg).min(end - cur);
            let phys = meta.segments[seg_idx] as u64 * seg + in_seg;
            extents.push(Extent { addr: phys, len: take });
            cur += take;
        }
        Ok(extents)
    }

    /// Synchronous read into the caller's buffer.
    pub fn read(&self, file: FileId, offset: u64, buf: &mut [u8]) -> Result<(), FsError> {
        let extents = self.map_extents(file, offset, buf.len() as u64)?;
        let mut at = 0usize;
        for e in extents {
            self.ssd
                .read_into(e.addr, &mut buf[at..at + e.len as usize])
                .map_err(|err| FsError::Device(err.to_string()))?;
            at += e.len as usize;
        }
        Ok(())
    }

    /// Synchronous write; grows the file as needed.
    pub fn write(&mut self, file: FileId, offset: u64, data: &[u8]) -> Result<(), FsError> {
        self.ensure_size(file, offset + data.len() as u64)?;
        let extents = self.map_extents(file, offset, data.len() as u64)?;
        let mut at = 0usize;
        for e in extents {
            self.ssd
                .write_from(e.addr, &data[at..at + e.len as usize])
                .map_err(|err| FsError::Device(err.to_string()))?;
            at += e.len as usize;
        }
        Ok(())
    }

    // ----- durable data plane (redirect-on-write) -----

    /// Committed remap records not yet superseded by a full metadata
    /// image in a superblock slot (the wrap guard's trigger).
    pub fn live_remaps(&self) -> usize {
        self.live_remaps
    }

    /// Stage a durable WRITE: allocate a shadow segment for every
    /// segment the write touches (plus any growth segments), pre-image
    /// them (old contents copied in full, growth segments zeroed so a
    /// recycled segment can't leak stale bytes), and return the
    /// shadow-addressed extents the payload goes to. The file mapping
    /// is untouched — readers keep seeing the old bytes until
    /// [`Self::redirect_commit`], and a crash before commit leaves the
    /// shadows unreferenced (reclaimed by the next mount's bitmap
    /// rebuild).
    pub fn redirect_prepare(
        &mut self,
        file: FileId,
        offset: u64,
        len: u64,
    ) -> Result<RedirectPlan, FsError> {
        let seg = self.cfg.segment_size;
        let meta = self.files.get(&file).ok_or(FsError::NoSuchFile)?;
        let old_segments = meta.segments.clone();
        let new_size = meta.size.max(offset + len);
        let need = new_size.div_ceil(seg) as usize;
        let first = (offset / seg) as usize;
        let last = if len == 0 { 0 } else { ((offset + len - 1) / seg) as usize };
        let mut entries: Vec<journal::RemapEntry> = Vec::new();
        for idx in 0..need {
            let is_data = len > 0 && idx >= first && idx <= last;
            let is_growth = idx >= old_segments.len();
            if !is_data && !is_growth {
                continue;
            }
            match self.bitmap.alloc() {
                Some(s) => entries.push(journal::RemapEntry {
                    seg_idx: idx as u32,
                    old_seg: if is_growth { journal::REMAP_GROWTH } else { old_segments[idx] },
                    new_seg: s as u32,
                }),
                None => {
                    // Atomic on refusal, like `ensure_size`: free
                    // everything this plan allocated.
                    for e in &entries {
                        self.bitmap.set(e.new_seg as usize, false);
                    }
                    return Err(FsError::NoSpace);
                }
            }
        }
        let mut seg_buf = vec![0u8; seg as usize];
        for e in &entries {
            let imaged = if e.old_seg == journal::REMAP_GROWTH {
                seg_buf.fill(0);
                Ok(())
            } else {
                self.ssd.read_into(e.old_seg as u64 * seg, &mut seg_buf)
            }
            .and_then(|()| self.ssd.write_from(e.new_seg as u64 * seg, &seg_buf));
            if let Err(err) = imaged {
                for e in &entries {
                    self.bitmap.set(e.new_seg as usize, false);
                }
                return Err(FsError::Device(err.to_string()));
            }
        }
        // The payload's device extents, resolved through the shadow
        // mapping (every data segment has an entry by construction).
        let mut extents = Vec::new();
        let mut cur = offset;
        let end = offset + len;
        while cur < end {
            let idx = (cur / seg) as usize;
            let in_seg = cur % seg;
            let take = (seg - in_seg).min(end - cur);
            let shadow = entries
                .iter()
                .find(|e| e.seg_idx as usize == idx)
                .expect("data segment has a shadow entry")
                .new_seg;
            extents.push(Extent { addr: shadow as u64 * seg + in_seg, len: take });
            cur += take;
        }
        Ok(RedirectPlan { file, new_size, entries, extents })
    }

    /// Commit a durable WRITE whose payload now sits in the plan's
    /// shadow extents. Protocol: read back + checksum each shadow →
    /// wrap-guard the journal → burn the commit sequence → write each
    /// shadow's epoch/CRC trailer → **append the remap record (the
    /// ack point)** → flip the file mapping and free the replaced
    /// segments. Every pre-append failure aborts the plan (shadows
    /// freed, mapping untouched) so the un-acked WRITE surfaces as a
    /// clean error; a crash inside the window leaves either no remap
    /// record (WRITE invisible, shadows quarantined/reclaimed at
    /// mount) or a complete one (WRITE fully visible).
    pub fn redirect_commit(&mut self, plan: RedirectPlan) -> Result<(), FsError> {
        let seg = self.cfg.segment_size;
        // A size-only grow inside already-mapped segments still needs
        // the record; a true no-op doesn't.
        let cur_size = self.files.get(&plan.file).map(|m| m.size);
        if plan.entries.is_empty() && cur_size == Some(plan.new_size) {
            return Ok(());
        }
        // Validate against the *current* mapping: a concurrent durable
        // WRITE that committed first may have flipped a segment this
        // plan also replaces — committing over it would silently revert
        // those bytes, so refuse cleanly instead.
        let valid = match self.files.get(&plan.file) {
            None => false,
            Some(meta) => {
                let mut expect_len = meta.segments.len();
                plan.entries.iter().all(|e| {
                    let idx = e.seg_idx as usize;
                    if e.old_seg == journal::REMAP_GROWTH {
                        let ok = idx == expect_len;
                        expect_len += 1;
                        ok
                    } else {
                        idx < meta.segments.len() && meta.segments[idx] == e.old_seg
                    }
                })
            }
        };
        if !valid {
            self.redirect_abort(&plan);
            return Err(FsError::Corrupt(
                "remap plan superseded by a concurrent commit".into(),
            ));
        }
        // Checksum what actually persisted, not what was intended.
        let mut crcs = Vec::with_capacity(plan.entries.len());
        let mut seg_buf = vec![0u8; seg as usize];
        for e in &plan.entries {
            if let Err(err) = self.ssd.read_into(e.new_seg as u64 * seg, &mut seg_buf) {
                self.redirect_abort(&plan);
                return Err(FsError::Device(err.to_string()));
            }
            crcs.push(journal::crc32(&seg_buf));
        }
        let record = journal::RemapRecord {
            file_id: plan.file.0,
            new_size: plan.new_size,
            entries: plan.entries.clone(),
        };
        let payload = record.encode();
        // Wrap check BEFORE burning the commit sequence — the guard's
        // checkpoint burns sequences, and the remap must stay newer
        // than any base image recovery might choose.
        let flen = (journal::FRAME_HEADER_LEN + payload.len()) as u64;
        if self.journal_off + flen > seg && self.live_remaps > 0 {
            if let Err(e) = self.checkpoint_slot() {
                self.redirect_abort(&plan);
                return Err(e);
            }
        }
        let epoch = self.seq + 1;
        self.seq = epoch;
        for (e, crc) in plan.entries.iter().zip(&crcs) {
            if let Err(err) = self.write_trailer(e.new_seg as usize, epoch, *crc) {
                self.redirect_abort(&plan);
                return Err(err);
            }
        }
        if let Err(err) = journal::append(
            &self.ssd,
            seg,
            &mut self.journal_off,
            journal::JOURNAL_REMAP_MAGIC,
            epoch,
            &payload,
        ) {
            self.redirect_abort(&plan);
            return Err(err);
        }
        // === commit point: the append succeeded, the WRITE is durable ===
        let meta = self.files.get_mut(&plan.file).expect("validated above");
        for e in &plan.entries {
            if e.old_seg == journal::REMAP_GROWTH {
                meta.segments.push(e.new_seg);
            } else {
                meta.segments[e.seg_idx as usize] = e.new_seg;
                self.bitmap.set(e.old_seg as usize, false);
            }
        }
        meta.size = meta.size.max(plan.new_size);
        self.live_remaps += 1;
        // The mapping just flipped: every cached view of the replaced
        // segments is now pre-overwrite. Invalidate per whole segment
        // (wider than the exact write range — safe, never narrower).
        if let Some(hook) = self.remap_commit_hook.clone() {
            for e in &plan.entries {
                hook(plan.file, e.seg_idx as u64 * seg, seg);
            }
        }
        Ok(())
    }

    /// Register the remap-commit invalidation hook (see the field doc).
    pub fn set_remap_commit_hook(
        &mut self,
        hook: Arc<dyn Fn(FileId, u64, u64) + Send + Sync>,
    ) {
        self.remap_commit_hook = Some(hook);
    }

    /// Abandon a prepared redirect: return its shadow segments to the
    /// free pool. The mapping was never touched and nothing about the
    /// plan was journaled, so this is purely an in-memory release.
    pub fn redirect_abort(&mut self, plan: &RedirectPlan) {
        for e in &plan.entries {
            self.bitmap.set(e.new_seg as usize, false);
        }
    }

    /// Synchronous durable write: prepare → payload into shadows →
    /// commit. The crash contract: once this returns `Ok`, the bytes
    /// survive any power cut; if it returns `Err` (or never returns),
    /// readers after recovery see the complete old contents.
    pub fn write_durable(&mut self, file: FileId, offset: u64, data: &[u8]) -> Result<(), FsError> {
        let plan = self.redirect_prepare(file, offset, data.len() as u64)?;
        let mut at = 0usize;
        for e in &plan.extents {
            if let Err(err) = self.ssd.write_from(e.addr, &data[at..at + e.len as usize]) {
                self.redirect_abort(&plan);
                return Err(FsError::Device(err.to_string()));
            }
            at += e.len as usize;
        }
        self.redirect_commit(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fs() -> DpuFs {
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        DpuFs::format(ssd, FsConfig { segment_size: 1 << 20 }).unwrap()
    }

    #[test]
    fn create_write_read() {
        let mut fs = fs();
        let d = fs.create_directory("db").unwrap();
        let f = fs.create_file(d, "pages").unwrap();
        let data: Vec<u8> = (0..10_000).map(|i| (i % 255) as u8).collect();
        fs.write(f, 100, &data).unwrap();
        let mut out = vec![0u8; data.len()];
        fs.read(f, 100, &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn cross_segment_io() {
        let mut fs = fs();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        let seg = fs.segment_size();
        // Write spanning three segments.
        let data = vec![7u8; (2 * seg + 500) as usize];
        fs.write(f, seg - 250, &data).unwrap();
        let mut out = vec![0u8; data.len()];
        fs.read(f, seg - 250, &mut out).unwrap();
        assert_eq!(out, data);
        let extents = fs.map_extents(f, seg - 250, data.len() as u64).unwrap();
        assert_eq!(extents.len(), 4); // tail of seg0 + seg1 + seg2 + head of seg3
    }

    #[test]
    fn segment_zero_reserved() {
        let fs = fs();
        // The superblock, journal, and trailer-table segments must
        // never be handed to files.
        assert!(fs.bitmap.get(0));
        assert!(fs.bitmap.get(1));
        assert!(fs.bitmap.get(2));
        assert_eq!(fs.free_segments(), fs.num_segments() - RESERVED_SEGMENTS);
    }

    #[test]
    fn delete_frees_segments() {
        let mut fs = fs();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        fs.write(f, 0, &vec![1u8; 3 << 20]).unwrap();
        let free_before = fs.free_segments();
        fs.delete_file(f).unwrap();
        assert_eq!(fs.free_segments(), free_before + 3);
        assert_eq!(fs.read(f, 0, &mut [0u8; 1]), Err(FsError::NoSuchFile));
    }

    #[test]
    fn out_of_bounds_read_rejected() {
        let mut fs = fs();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        fs.write(f, 0, &[1u8; 100]).unwrap();
        assert_eq!(fs.read(f, 90, &mut [0u8; 20]), Err(FsError::OutOfBounds));
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut fs = fs();
        let d = fs.create_directory("d").unwrap();
        fs.create_file(d, "f").unwrap();
        assert_eq!(fs.create_file(d, "f"), Err(FsError::AlreadyExists));
        assert_eq!(fs.create_directory("d"), Err(FsError::AlreadyExists));
    }

    #[test]
    fn dir_lifecycle() {
        let mut fs = fs();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        assert_eq!(fs.remove_directory(d), Err(FsError::DirNotEmpty));
        fs.delete_file(f).unwrap();
        assert_eq!(fs.remove_directory(d), Ok(()));
        assert_eq!(fs.remove_directory(d), Err(FsError::NoSuchDir));
    }

    #[test]
    fn persistence_roundtrip() {
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        let file_id;
        let data = vec![0xabu8; 5000];
        {
            let mut fs = DpuFs::format(ssd.clone(), FsConfig::default()).unwrap();
            let d = fs.create_directory("db").unwrap();
            file_id = fs.create_file(d, "rbpex").unwrap();
            fs.write(file_id, 4096, &data).unwrap();
            fs.sync_metadata().unwrap();
        }
        // Re-mount from the device and read the same bytes back.
        let fs = DpuFs::mount(ssd, FsConfig::default()).unwrap();
        let mut out = vec![0u8; data.len()];
        fs.read(file_id, 4096, &mut out).unwrap();
        assert_eq!(out, data);
        let meta = fs.file_meta(file_id).unwrap();
        assert_eq!(meta.name, "rbpex");
    }

    #[test]
    fn no_space_surfaces_and_refused_grow_is_atomic() {
        let ssd = Arc::new(Ssd::new(4 << 20, 512)); // 4 segments, 3 reserved
        let mut fs = DpuFs::format(ssd, FsConfig::default()).unwrap();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        let free_before = fs.free_segments();
        assert_eq!(fs.write(f, 0, &vec![0u8; 4 << 20]), Err(FsError::NoSpace));
        // The refused grow must not leave half-mapped segments behind
        // (the next sync would persist them as an inconsistent image).
        assert_eq!(fs.free_segments(), free_before);
        let meta = fs.file_meta(f).unwrap();
        assert_eq!((meta.size, meta.segments.len()), (0, 0));
    }

    #[test]
    fn clean_mount_reports_no_recovery_work() {
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        {
            let mut fs = DpuFs::format(ssd.clone(), FsConfig::default()).unwrap();
            let d = fs.create_directory("db").unwrap();
            fs.create_file(d, "f").unwrap();
            fs.sync_metadata().unwrap();
        }
        let (fs, report) = DpuFs::mount_with_report(ssd, FsConfig::default()).unwrap();
        assert_eq!(report.recovered_seq, 2, "format sync + explicit sync");
        assert!(!report.rolled_forward);
        assert!(!report.repaired_superblock);
        assert!(!report.counters_clamped);
        assert!(!report.torn_tail);
        assert_eq!(report.superblock_seq, Some(2));
        assert_eq!(report.highest_journal_seq, Some(2));
        assert_eq!(fs.metadata_seq(), 2);
    }

    /// Crash window between protocol steps 1 and 2: the WAL record for
    /// the new sequence is committed but the superblock write never
    /// happened. Mount must roll forward and repair the superblock.
    #[test]
    fn committed_journal_record_rolls_forward_and_repairs() {
        let cfg = FsConfig::default();
        let seg = cfg.segment_size;
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        let mut fs = DpuFs::format(ssd.clone(), cfg.clone()).unwrap();
        let d = fs.create_directory("db").unwrap();
        fs.sync_metadata().unwrap(); // seq 2
        let mut dirs = HashMap::new();
        dirs.insert(d, "db".to_string());
        dirs.insert(DirId(2), "wal-only".to_string());
        let image =
            meta::encode(&dirs, &HashMap::new(), 3, 1, journal::max_image_len(seg)).unwrap();
        let mut off = fs.journal_off;
        journal::append(&ssd, seg, &mut off, journal::JOURNAL_DATA_MAGIC, 3, &image).unwrap();
        drop(fs);

        let (fs, report) = DpuFs::mount_with_report(ssd.clone(), cfg.clone()).unwrap();
        assert!(report.rolled_forward);
        assert!(report.repaired_superblock);
        assert_eq!(report.recovered_seq, 3);
        assert_eq!(fs.list_dirs().len(), 2);
        drop(fs);
        // Replay is idempotent: a second mount finds the repaired
        // superblock and does no further recovery work.
        let (fs, report) = DpuFs::mount_with_report(ssd, cfg).unwrap();
        assert!(!report.rolled_forward);
        assert_eq!(report.recovered_seq, 3);
        assert_eq!(fs.list_dirs().len(), 2);
    }

    /// Crash window inside protocol step 1: a torn WAL append must be
    /// detected and rolled back to the previous committed state.
    #[test]
    fn torn_journal_append_rolls_back() {
        let cfg = FsConfig::default();
        let seg = cfg.segment_size;
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        let mut fs = DpuFs::format(ssd.clone(), cfg.clone()).unwrap();
        fs.create_directory("db").unwrap();
        fs.sync_metadata().unwrap(); // seq 2
        let image = meta::encode(
            &HashMap::new(),
            &HashMap::new(),
            9,
            9,
            journal::max_image_len(seg),
        )
        .unwrap();
        let frame = journal::encode_frame(journal::JOURNAL_DATA_MAGIC, 3, &image);
        // Tear the append halfway through the payload.
        ssd.write_from(seg + fs.journal_off, &frame[..frame.len() / 2]).unwrap();
        drop(fs);

        let (fs, report) = DpuFs::mount_with_report(ssd, cfg).unwrap();
        assert_eq!(report.recovered_seq, 2, "torn record ignored");
        assert!(!report.rolled_forward);
        assert!(report.torn_tail, "torn bytes sit at the chain tail");
        assert_eq!(fs.list_dirs().len(), 1, "rolled back to the committed state");
    }

    /// Regression (satellite): a persisted image whose `next_file` is
    /// at/below a live id must be clamped at mount — `create_file`
    /// would otherwise hand out a live `FileId` and clobber it.
    #[test]
    fn stale_id_counters_clamped_on_mount() {
        let cfg = FsConfig::default();
        let seg = cfg.segment_size;
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        drop(DpuFs::format(ssd.clone(), cfg.clone()).unwrap()); // seq 1
        let mut dirs = HashMap::new();
        dirs.insert(DirId(1), "d".to_string());
        let mut files = HashMap::new();
        files.insert(
            FileId(5),
            FileMeta {
                id: FileId(5),
                dir: DirId(1),
                name: "live".into(),
                size: 10,
                segments: vec![3],
            },
        );
        // Stale counters: next_dir = 1 ≤ live dir 1, next_file = 1 ≤
        // live file 5.
        let image = meta::encode(&dirs, &files, 1, 1, journal::max_image_len(seg)).unwrap();
        journal::write_slot(&ssd, seg, 8, &image).unwrap();

        let (mut fs, report) = DpuFs::mount_with_report(ssd, cfg).unwrap();
        assert!(report.counters_clamped);
        assert_eq!(fs.counters(), (2, 6));
        let d2 = fs.create_directory("fresh").unwrap();
        assert_eq!(d2, DirId(2));
        let f2 = fs.create_file(DirId(1), "new").unwrap();
        assert_eq!(f2, FileId(6), "must not reuse live FileId(5)");
        assert_eq!(fs.file_meta(FileId(5)).unwrap().name, "live");
    }

    #[test]
    fn double_allocated_segments_rejected_at_mount() {
        let cfg = FsConfig::default();
        let seg = cfg.segment_size;
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        drop(DpuFs::format(ssd.clone(), cfg.clone()).unwrap());
        let mut dirs = HashMap::new();
        dirs.insert(DirId(1), "d".to_string());
        let mut files = HashMap::new();
        for id in [7u32, 8u32] {
            files.insert(
                FileId(id),
                FileMeta {
                    id: FileId(id),
                    dir: DirId(1),
                    name: format!("f{id}"),
                    size: 10,
                    segments: vec![3], // both claim segment 3
                },
            );
        }
        let image = meta::encode(&dirs, &files, 2, 9, journal::max_image_len(seg)).unwrap();
        journal::write_slot(&ssd, seg, 8, &image).unwrap();
        assert!(matches!(
            DpuFs::mount_with_report(ssd, cfg),
            Err(FsError::Corrupt(_))
        ));
    }

    #[test]
    fn dangling_directory_reference_rejected_at_mount() {
        let cfg = FsConfig::default();
        let seg = cfg.segment_size;
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        drop(DpuFs::format(ssd.clone(), cfg.clone()).unwrap());
        let mut files = HashMap::new();
        files.insert(
            FileId(1),
            FileMeta {
                id: FileId(1),
                dir: DirId(9), // no such directory
                name: "orphan".into(),
                size: 0,
                segments: Vec::new(),
            },
        );
        let image =
            meta::encode(&HashMap::new(), &files, 1, 2, journal::max_image_len(seg)).unwrap();
        journal::write_slot(&ssd, seg, 8, &image).unwrap();
        assert!(matches!(
            DpuFs::mount_with_report(ssd, cfg),
            Err(FsError::Corrupt(_))
        ));
    }

    /// A CRC-valid but semantically corrupt journal record must fail
    /// the mount WITHOUT mutating the device: validation runs before
    /// the roll-forward repair, so retried mounts can't burn journal
    /// space or stamp the corrupt image into a superblock slot.
    #[test]
    fn failing_mount_never_mutates_the_device() {
        let cfg = FsConfig::default();
        let seg = cfg.segment_size;
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        let mut fs = DpuFs::format(ssd.clone(), cfg.clone()).unwrap();
        fs.create_directory("d").unwrap();
        fs.sync_metadata().unwrap(); // seq 2 committed
        let mut dirs = HashMap::new();
        dirs.insert(DirId(1), "d".to_string());
        let mut files = HashMap::new();
        for id in [7u32, 8u32] {
            files.insert(
                FileId(id),
                FileMeta {
                    id: FileId(id),
                    dir: DirId(1),
                    name: format!("f{id}"),
                    size: 10,
                    segments: vec![3], // both claim segment 3
                },
            );
        }
        let image = meta::encode(&dirs, &files, 2, 9, journal::max_image_len(seg)).unwrap();
        let mut off = fs.journal_off;
        journal::append(&ssd, seg, &mut off, journal::JOURNAL_DATA_MAGIC, 3, &image).unwrap();
        drop(fs);
        let mut before = vec![0u8; 3 * seg as usize];
        ssd.read_into(0, &mut before).unwrap();
        for _ in 0..3 {
            assert!(matches!(
                DpuFs::mount_with_report(ssd.clone(), cfg.clone()),
                Err(FsError::Corrupt(_))
            ));
        }
        let mut after = vec![0u8; 3 * seg as usize];
        ssd.read_into(0, &mut after).unwrap();
        assert_eq!(before, after, "failed mounts must not write to the device");
    }

    // ----- durable data plane (redirect-on-write) -----

    #[test]
    fn durable_write_roundtrips_and_conserves_segments() {
        let mut fs = fs();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        fs.write(f, 0, &vec![1u8; (2 << 20) + 100]).unwrap();
        let free_before = fs.free_segments();
        let old_segs = fs.file_meta(f).unwrap().segments.clone();
        // Overwrite crossing a segment boundary: both touched segments
        // must move to shadows, the old ones must come back free.
        let data: Vec<u8> = (0..(1 << 20) + 999).map(|i| (i % 241) as u8).collect();
        fs.write_durable(f, (1 << 20) - 500, &data).unwrap();
        assert_eq!(fs.free_segments(), free_before, "shadow alloc exactly offsets old free");
        assert_eq!(fs.live_remaps(), 1);
        let new_segs = &fs.file_meta(f).unwrap().segments;
        assert_ne!(new_segs[1], old_segs[1], "touched segment was redirected");
        assert_eq!(new_segs[0], old_segs[0], "untouched segment kept its mapping");
        let mut out = vec![0u8; data.len()];
        fs.read(f, (1 << 20) - 500, &mut out).unwrap();
        assert_eq!(out, data);
        // Bytes before the write are the old contents, not shadow junk.
        let mut head = vec![0u8; 100];
        fs.read(f, 0, &mut head).unwrap();
        assert_eq!(head, vec![1u8; 100]);
    }

    #[test]
    fn durable_growth_zeroes_holes_and_extends_mapping() {
        let mut fs = fs();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        fs.write(f, 0, &[9u8; 10]).unwrap();
        // Durable write far past the end: the hole segments must read
        // zero even though the device could hand back recycled bytes.
        fs.write_durable(f, (3 << 20) + 7, &[5u8; 100]).unwrap();
        let meta = fs.file_meta(f).unwrap();
        assert_eq!(meta.segments.len(), 4);
        assert_eq!(meta.size, (3 << 20) + 107);
        let mut hole = vec![0xffu8; 64];
        fs.read(f, 2 << 20, &mut hole).unwrap();
        assert!(hole.iter().all(|&b| b == 0), "growth hole reads zero");
        let mut tail = vec![0u8; 100];
        fs.read(f, (3 << 20) + 7, &mut tail).unwrap();
        assert_eq!(tail, [5u8; 100]);
    }

    /// An acked durable WRITE with no metadata sync afterward must
    /// survive remount via remap replay — the journal append was the
    /// ack point.
    #[test]
    fn committed_remap_replays_at_mount_byte_exact() {
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        let cfg = FsConfig::default();
        let f;
        let data: Vec<u8> = (0..5000u32).map(|i| (i % 199) as u8).collect();
        {
            let mut fs = DpuFs::format(ssd.clone(), cfg.clone()).unwrap();
            let d = fs.create_directory("d").unwrap();
            f = fs.create_file(d, "f").unwrap();
            fs.write(f, 0, &vec![3u8; 8000]).unwrap();
            fs.sync_metadata().unwrap(); // seq 2: base image
            fs.write_durable(f, 1000, &data).unwrap(); // seq 3: remap only
        }
        let (fs, report) = DpuFs::mount_with_report(ssd.clone(), cfg.clone()).unwrap();
        assert_eq!(report.remaps_applied, 1);
        assert_eq!(report.quarantined_extents, 0);
        assert_eq!(report.recovered_seq, 3, "remap advanced the recovered sequence");
        assert!(!report.rolled_forward);
        let mut out = vec![0u8; data.len()];
        fs.read(f, 1000, &mut out).unwrap();
        assert_eq!(out, data, "acked WRITE is never lost");
        let mut head = vec![0u8; 1000];
        fs.read(f, 0, &mut head).unwrap();
        assert_eq!(head, vec![3u8; 1000], "bytes around the WRITE are the old contents");
        assert_eq!(fs.live_remaps(), 1, "replayed remap stays wrap-guarded");
        drop(fs);
        // Replay is stable: a second mount reaches the same state.
        let (fs, report) = DpuFs::mount_with_report(ssd, cfg).unwrap();
        assert_eq!(report.remaps_applied, 1);
        let mut out2 = vec![0u8; data.len()];
        fs.read(f, 1000, &mut out2).unwrap();
        assert_eq!(out2, data);
    }

    /// Power cut after the shadow data + trailer landed but before the
    /// remap append: the WRITE was never acked, so recovery must show
    /// the complete old bytes, quarantine the orphan trailer, and leak
    /// no segments.
    #[test]
    fn precommit_power_cut_rolls_back_quarantines_and_leaks_nothing() {
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        let cfg = FsConfig::default();
        let mut fs = DpuFs::format(ssd.clone(), cfg.clone()).unwrap();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        fs.write(f, 0, &vec![8u8; 4000]).unwrap();
        fs.sync_metadata().unwrap();
        let free_committed = fs.free_segments();
        // Single-segment overwrite writes: #0 shadow pre-image, #1
        // payload, #2 trailer, #3 remap append. Cut the append at 0
        // bytes: everything before it persisted, the ack never
        // happened.
        ssd.arm_power_cut(3, 0);
        let err = fs.write_durable(f, 100, &vec![9u8; 200]).unwrap_err();
        assert!(matches!(err, FsError::Device(_)));
        drop(fs);
        ssd.power_restore();
        let (fs, report) = DpuFs::mount_with_report(ssd.clone(), cfg.clone()).unwrap();
        assert_eq!(report.remaps_applied, 0);
        assert_eq!(report.quarantined_extents, 1, "orphan trailer detected");
        let mut out = vec![0u8; 4000];
        fs.read(f, 0, &mut out).unwrap();
        assert_eq!(out, vec![8u8; 4000], "un-acked WRITE is invisible");
        assert_eq!(fs.free_segments(), free_committed, "shadow segment reclaimed");
        drop(fs);
        // The quarantine zeroed the trailer: a re-mount finds nothing.
        let (_, report) = DpuFs::mount_with_report(ssd, cfg).unwrap();
        assert_eq!(report.quarantined_extents, 0, "quarantine repair is durable");
    }

    /// A torn trailer write (cut mid-trailer) fails its own CRC and is
    /// simply ignored — no quarantine entry, shadow still reclaimed.
    #[test]
    fn torn_trailer_is_ignored_not_quarantined() {
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        let cfg = FsConfig::default();
        let mut fs = DpuFs::format(ssd.clone(), cfg.clone()).unwrap();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        fs.write(f, 0, &vec![8u8; 4000]).unwrap();
        fs.sync_metadata().unwrap();
        let free_committed = fs.free_segments();
        ssd.arm_power_cut(2, 7); // tear the trailer write mid-bytes
        assert!(fs.write_durable(f, 100, &vec![9u8; 200]).is_err());
        drop(fs);
        ssd.power_restore();
        let (fs, report) = DpuFs::mount_with_report(ssd, cfg).unwrap();
        assert_eq!(report.quarantined_extents, 0);
        assert_eq!(report.remaps_applied, 0);
        assert_eq!(fs.free_segments(), free_committed);
        let mut out = vec![0u8; 4000];
        fs.read(f, 0, &mut out).unwrap();
        assert_eq!(out, vec![8u8; 4000]);
    }

    /// The wrap guard: remap appends that would wrap the journal first
    /// checkpoint the image into a superblock slot, so a long run of
    /// durable WRITEs with no metadata sync never loses an acked WRITE
    /// to the wrap.
    #[test]
    fn journal_wrap_under_durable_writes_checkpoints_and_loses_nothing() {
        // Small segments so the journal wraps quickly.
        let seg = 1u64 << 13;
        let ssd = Arc::new(Ssd::new(128 * seg, 512));
        let cfg = FsConfig { segment_size: seg };
        let mut fs = DpuFs::format(ssd.clone(), cfg.clone()).unwrap();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        fs.write(f, 0, &vec![1u8; seg as usize]).unwrap();
        fs.sync_metadata().unwrap();
        // Each remap frame is ~60 bytes; push enough durable WRITEs
        // through to wrap the 8 KiB journal several times.
        let mut expect = vec![1u8; seg as usize];
        for i in 0..400u32 {
            let off = (i % 64) as u64 * 100;
            let byte = (i % 251) as u8;
            fs.write_durable(f, off, &[byte; 100]).unwrap();
            expect[off as usize..off as usize + 100].fill(byte);
        }
        drop(fs);
        let (fs, report) = DpuFs::mount_with_report(ssd, cfg).unwrap();
        let mut out = vec![0u8; seg as usize];
        fs.read(f, 0, &mut out).unwrap();
        assert_eq!(out, expect, "every acked WRITE survives journal wraps");
        assert_eq!(report.quarantined_extents, 0);
    }
}
