//! DPU flat file system (§4.3 "Low-latency file access").
//!
//! Exactly the paper's design: SSD space is divided into fixed-length
//! segments (block-aligned); a bitmap tracks segment availability; files
//! are allocated segments on demand; directories are flat; segment 0 is
//! reserved to persistently store directory/file metadata and the *file
//! mapping* (the per-file vector of segments). File I/O translates a
//! `(file, offset, len)` into per-segment extents and issues device ops.

mod alloc;
mod meta;

pub use alloc::SegmentBitmap;
pub use meta::{DirId, FileId, FileMeta};

use std::collections::HashMap;
use std::sync::Arc;

use crate::ssd::Ssd;

/// File-system errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FsError {
    NoSpace,
    NoSuchDir,
    NoSuchFile,
    DirNotEmpty,
    AlreadyExists,
    OutOfBounds,
    Corrupt(String),
    Device(String),
}

impl std::fmt::Display for FsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for FsError {}

/// Configuration of the on-SSD layout.
#[derive(Debug, Clone)]
pub struct FsConfig {
    /// Segment length in bytes; must be a multiple of the block size.
    pub segment_size: u64,
}

impl Default for FsConfig {
    fn default() -> Self {
        // 1 MiB segments: big enough that an 8 KB-page file is a short
        // segment vector, small enough for fine-grained allocation.
        FsConfig { segment_size: 1 << 20 }
    }
}

/// A byte extent on the device, produced by the file mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Extent {
    pub addr: u64,
    pub len: u64,
}

/// The DPU file system. All metadata lives on the DPU (which is what
/// enables read offloading — the offload engine resolves file reads
/// without consulting the host, §3).
pub struct DpuFs {
    ssd: Arc<Ssd>,
    cfg: FsConfig,
    bitmap: SegmentBitmap,
    dirs: HashMap<DirId, String>,
    files: HashMap<FileId, FileMeta>,
    next_dir: u32,
    next_file: u32,
}

impl DpuFs {
    /// Format a fresh file system on the device.
    pub fn format(ssd: Arc<Ssd>, cfg: FsConfig) -> Result<Self, FsError> {
        assert!(cfg.segment_size % ssd.block_size() as u64 == 0);
        let num_segments = (ssd.capacity() / cfg.segment_size) as usize;
        if num_segments < 2 {
            return Err(FsError::NoSpace);
        }
        let mut bitmap = SegmentBitmap::new(num_segments);
        bitmap.set(0, true); // segment 0 = metadata (§4.3)
        let mut fs = DpuFs {
            ssd,
            cfg,
            bitmap,
            dirs: HashMap::new(),
            files: HashMap::new(),
            next_dir: 1,
            next_file: 1,
        };
        fs.sync_metadata()?;
        Ok(fs)
    }

    /// Mount an existing file system: load metadata from segment 0.
    pub fn mount(ssd: Arc<Ssd>, cfg: FsConfig) -> Result<Self, FsError> {
        let num_segments = (ssd.capacity() / cfg.segment_size) as usize;
        let mut buf = vec![0u8; cfg.segment_size as usize];
        ssd.read_into(0, &mut buf).map_err(|e| FsError::Device(e.to_string()))?;
        let (dirs, files, next_dir, next_file) = meta::decode(&buf)?;
        let mut bitmap = SegmentBitmap::new(num_segments);
        bitmap.set(0, true);
        for f in files.values() {
            for &s in &f.segments {
                if s as usize >= num_segments || bitmap.get(s as usize) {
                    return Err(FsError::Corrupt(format!("segment {s} double-allocated")));
                }
                bitmap.set(s as usize, true);
            }
        }
        Ok(DpuFs { ssd, cfg, bitmap, dirs, files, next_dir, next_file })
    }

    /// Persist metadata + file mapping into segment 0 (§4.3).
    pub fn sync_metadata(&mut self) -> Result<(), FsError> {
        let buf = meta::encode(
            &self.dirs,
            &self.files,
            self.next_dir,
            self.next_file,
            self.cfg.segment_size as usize,
        )?;
        self.ssd.write_from(0, &buf).map_err(|e| FsError::Device(e.to_string()))
    }

    pub fn segment_size(&self) -> u64 {
        self.cfg.segment_size
    }

    pub fn free_segments(&self) -> usize {
        self.bitmap.free()
    }

    // ----- control plane (§4.2: directory/file management) -----

    pub fn create_directory(&mut self, name: &str) -> Result<DirId, FsError> {
        if self.dirs.values().any(|n| n == name) {
            return Err(FsError::AlreadyExists);
        }
        let id = DirId(self.next_dir);
        self.next_dir += 1;
        self.dirs.insert(id, name.to_string());
        Ok(id)
    }

    pub fn remove_directory(&mut self, dir: DirId) -> Result<(), FsError> {
        if !self.dirs.contains_key(&dir) {
            return Err(FsError::NoSuchDir);
        }
        if self.files.values().any(|f| f.dir == dir) {
            return Err(FsError::DirNotEmpty);
        }
        self.dirs.remove(&dir);
        Ok(())
    }

    pub fn create_file(&mut self, dir: DirId, name: &str) -> Result<FileId, FsError> {
        if !self.dirs.contains_key(&dir) {
            return Err(FsError::NoSuchDir);
        }
        if self.files.values().any(|f| f.dir == dir && f.name == name) {
            return Err(FsError::AlreadyExists);
        }
        let id = FileId(self.next_file);
        self.next_file += 1;
        self.files.insert(
            id,
            FileMeta { id, dir, name: name.to_string(), size: 0, segments: Vec::new() },
        );
        Ok(id)
    }

    pub fn delete_file(&mut self, file: FileId) -> Result<(), FsError> {
        let meta = self.files.remove(&file).ok_or(FsError::NoSuchFile)?;
        for s in meta.segments {
            self.bitmap.set(s as usize, false);
        }
        Ok(())
    }

    pub fn file_meta(&self, file: FileId) -> Result<&FileMeta, FsError> {
        self.files.get(&file).ok_or(FsError::NoSuchFile)
    }

    pub fn list_dir(&self, dir: DirId) -> Vec<&FileMeta> {
        self.files.values().filter(|f| f.dir == dir).collect()
    }

    /// Grow (or keep) a file so `size` bytes are addressable, allocating
    /// segments from the bitmap.
    pub fn ensure_size(&mut self, file: FileId, size: u64) -> Result<(), FsError> {
        let seg = self.cfg.segment_size;
        let need = size.div_ceil(seg) as usize;
        let meta = self.files.get_mut(&file).ok_or(FsError::NoSuchFile)?;
        while meta.segments.len() < need {
            let s = self.bitmap.alloc().ok_or(FsError::NoSpace)?;
            meta.segments.push(s as u32);
        }
        meta.size = meta.size.max(size);
        Ok(())
    }

    // ----- data plane -----

    /// Translate `(file, offset, len)` through the file mapping into
    /// device extents (§4.3: "translates the file address into a disk
    /// block address using the file mapping").
    pub fn map_extents(&self, file: FileId, offset: u64, len: u64) -> Result<Vec<Extent>, FsError> {
        let meta = self.files.get(&file).ok_or(FsError::NoSuchFile)?;
        if offset + len > meta.size {
            return Err(FsError::OutOfBounds);
        }
        let seg = self.cfg.segment_size;
        let mut extents = Vec::new();
        let mut cur = offset;
        let end = offset + len;
        while cur < end {
            let seg_idx = (cur / seg) as usize;
            let in_seg = cur % seg;
            let take = (seg - in_seg).min(end - cur);
            let phys = meta.segments[seg_idx] as u64 * seg + in_seg;
            extents.push(Extent { addr: phys, len: take });
            cur += take;
        }
        Ok(extents)
    }

    /// Synchronous read into the caller's buffer.
    pub fn read(&self, file: FileId, offset: u64, buf: &mut [u8]) -> Result<(), FsError> {
        let extents = self.map_extents(file, offset, buf.len() as u64)?;
        let mut at = 0usize;
        for e in extents {
            self.ssd
                .read_into(e.addr, &mut buf[at..at + e.len as usize])
                .map_err(|err| FsError::Device(err.to_string()))?;
            at += e.len as usize;
        }
        Ok(())
    }

    /// Synchronous write; grows the file as needed.
    pub fn write(&mut self, file: FileId, offset: u64, data: &[u8]) -> Result<(), FsError> {
        self.ensure_size(file, offset + data.len() as u64)?;
        let extents = self.map_extents(file, offset, data.len() as u64)?;
        let mut at = 0usize;
        for e in extents {
            self.ssd
                .write_from(e.addr, &data[at..at + e.len as usize])
                .map_err(|err| FsError::Device(err.to_string()))?;
            at += e.len as usize;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fs() -> DpuFs {
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        DpuFs::format(ssd, FsConfig { segment_size: 1 << 20 }).unwrap()
    }

    #[test]
    fn create_write_read() {
        let mut fs = fs();
        let d = fs.create_directory("db").unwrap();
        let f = fs.create_file(d, "pages").unwrap();
        let data: Vec<u8> = (0..10_000).map(|i| (i % 255) as u8).collect();
        fs.write(f, 100, &data).unwrap();
        let mut out = vec![0u8; data.len()];
        fs.read(f, 100, &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn cross_segment_io() {
        let mut fs = fs();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        let seg = fs.segment_size();
        // Write spanning three segments.
        let data = vec![7u8; (2 * seg + 500) as usize];
        fs.write(f, seg - 250, &data).unwrap();
        let mut out = vec![0u8; data.len()];
        fs.read(f, seg - 250, &mut out).unwrap();
        assert_eq!(out, data);
        let extents = fs.map_extents(f, seg - 250, data.len() as u64).unwrap();
        assert_eq!(extents.len(), 4); // tail of seg0 + seg1 + seg2 + head of seg3
    }

    #[test]
    fn segment_zero_reserved() {
        let fs = fs();
        // Segment 0 must never be handed to files.
        assert!(fs.bitmap.get(0));
    }

    #[test]
    fn delete_frees_segments() {
        let mut fs = fs();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        fs.write(f, 0, &vec![1u8; 3 << 20]).unwrap();
        let free_before = fs.free_segments();
        fs.delete_file(f).unwrap();
        assert_eq!(fs.free_segments(), free_before + 3);
        assert_eq!(fs.read(f, 0, &mut [0u8; 1]), Err(FsError::NoSuchFile));
    }

    #[test]
    fn out_of_bounds_read_rejected() {
        let mut fs = fs();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        fs.write(f, 0, &[1u8; 100]).unwrap();
        assert_eq!(fs.read(f, 90, &mut [0u8; 20]), Err(FsError::OutOfBounds));
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut fs = fs();
        let d = fs.create_directory("d").unwrap();
        fs.create_file(d, "f").unwrap();
        assert_eq!(fs.create_file(d, "f"), Err(FsError::AlreadyExists));
        assert_eq!(fs.create_directory("d"), Err(FsError::AlreadyExists));
    }

    #[test]
    fn dir_lifecycle() {
        let mut fs = fs();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        assert_eq!(fs.remove_directory(d), Err(FsError::DirNotEmpty));
        fs.delete_file(f).unwrap();
        assert_eq!(fs.remove_directory(d), Ok(()));
        assert_eq!(fs.remove_directory(d), Err(FsError::NoSuchDir));
    }

    #[test]
    fn persistence_roundtrip() {
        let ssd = Arc::new(Ssd::new(64 << 20, 512));
        let file_id;
        let data = vec![0xabu8; 5000];
        {
            let mut fs = DpuFs::format(ssd.clone(), FsConfig::default()).unwrap();
            let d = fs.create_directory("db").unwrap();
            file_id = fs.create_file(d, "rbpex").unwrap();
            fs.write(file_id, 4096, &data).unwrap();
            fs.sync_metadata().unwrap();
        }
        // Re-mount from the device and read the same bytes back.
        let fs = DpuFs::mount(ssd, FsConfig::default()).unwrap();
        let mut out = vec![0u8; data.len()];
        fs.read(file_id, 4096, &mut out).unwrap();
        assert_eq!(out, data);
        let meta = fs.file_meta(file_id).unwrap();
        assert_eq!(meta.name, "rbpex");
    }

    #[test]
    fn no_space_surfaces() {
        let ssd = Arc::new(Ssd::new(4 << 20, 512)); // 4 segments, 1 reserved
        let mut fs = DpuFs::format(ssd, FsConfig::default()).unwrap();
        let d = fs.create_directory("d").unwrap();
        let f = fs.create_file(d, "f").unwrap();
        assert_eq!(fs.write(f, 0, &vec![0u8; 4 << 20]), Err(FsError::NoSpace));
    }
}
