//! # DDS: DPU-optimized Disaggregated Storage — reproduction
//!
//! Reproduction of *DDS: DPU-optimized Disaggregated Storage* (Zhang,
//! Bernstein, Chandramouli, Hu, Zheng — VLDB 2024, extended report).
//!
//! The library is organised in two planes that share wire formats,
//! workloads, and calibration constants:
//!
//! * **Functional plane** — real bytes end to end: the progress-pointer
//!   DMA ring buffers ([`ring`]), the DPU flat file system ([`dpufs`])
//!   with its crash-consistent metadata journal ([`dpufs::journal`]) over
//!   an in-memory NVMe model ([`ssd`]) with torn-write power-cut
//!   injection, the host file library ([`filelib`])
//!   and DPU file service ([`fileservice`]), the sequenced-transport
//!   network with a TCP-splitting PEP ([`net`], [`director`]), the offload
//!   engine with its context ring and user-supplied offload logic
//!   ([`offload`], [`cache`]), the PJRT runtime that executes the
//!   AOT-compiled Pallas kernels from the hot path ([`runtime`]), and
//!   the RSS-sharded deployment that runs the whole data path once per
//!   DPU core ([`director::shard`], [`coordinator::sharded`]), and the
//!   seeded fault-injection plane with its chaos scenario harness
//!   ([`fault`], [`fault::scenario`]), all sharing the zero-copy buffer
//!   plane ([`buf`]): pooled refcounted buffers referenced — never
//!   copied — from SSD completion to wire segment, with a per-pool copy
//!   ledger metering every software copy the design is supposed to have
//!   eliminated.
//! * **Calibrated testbed plane** ([`sim`], [`baselines`]) — a
//!   discrete-virtual-time queueing testbed standing in for the paper's
//!   BlueField-2 + EPYC + NVMe + 100 GbE hardware, calibrated against the
//!   constants the paper itself reports. Every figure of the evaluation
//!   (§8, §9) is regenerated from this plane by the `rust/benches/fig*`
//!   targets.
//!
//! See `DESIGN.md` (repo root) for the substitution ledger, the shard
//! architecture, and the experiment index.

// Accepted-style ledger for the correctness plane's blocking
// `clippy -D warnings` gate (DESIGN.md "The correctness plane"): the
// allows below are deliberate idioms of this codebase, not suppressed
// findings. Everything else — including every ddslint invariant — is
// enforced at deny level.
#![allow(clippy::too_many_arguments)] // burst publish/submit helpers thread the full wiring explicitly
#![allow(clippy::type_complexity)] // queue/channel types are spelled out at their construction sites
#![allow(clippy::needless_range_loop)] // ring/slab code is index-centric by design

pub mod apps;
pub mod baselines;
pub mod buf;
pub mod cache;
pub mod coordinator;
pub mod director;
pub mod dma;
pub mod dpufs;
pub mod fault;
pub mod filelib;
pub mod fileservice;
pub mod idle;
pub mod metrics;
pub mod net;
pub mod offload;
pub mod proto;
pub mod ring;
pub mod runtime;
pub mod sim;
pub mod ssd;
pub mod workload;
