//! Stage-chain compositions for the ten storage stacks of §8.4 plus the
//! Fig 14/15 read/write paths.

use crate::sim::{Engine, FlowSpec, Params, RunReport, Stage, StageChain, Ns, MS, SEC};

/// Which §8 configuration to simulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StackKind {
    /// ① Windows files on local SSD (no network).
    LocalNtfs,
    /// ② DDS files on local SSD (host front end + DPU execution).
    LocalDds,
    /// ③ SMB remote mount.
    Smb,
    /// ④ SMB Direct (SMB over RDMA).
    SmbDirect,
    /// ⑤ TCP + Windows files (the Fig 14/15 baseline).
    TcpNtfs,
    /// ⑥ TCP + DDS files (Fig 14/15 "DDS file").
    TcpDds,
    /// ⑦ Redy RPC + Windows files.
    RedyNtfs,
    /// ⑧ Redy RPC + DDS files.
    RedyDds,
    /// ⑨ DDS offloading, TCP transport (Fig 14/15 "DDS offload").
    DdsOffloadTcp,
    /// ⑩ DDS offloading, RDMA transport.
    DdsOffloadRdma,
}

impl StackKind {
    pub const ALL: [StackKind; 10] = [
        StackKind::LocalNtfs,
        StackKind::LocalDds,
        StackKind::Smb,
        StackKind::SmbDirect,
        StackKind::TcpNtfs,
        StackKind::TcpDds,
        StackKind::RedyNtfs,
        StackKind::RedyDds,
        StackKind::DdsOffloadTcp,
        StackKind::DdsOffloadRdma,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            StackKind::LocalNtfs => "1 local Windows files",
            StackKind::LocalDds => "2 local DDS files",
            StackKind::Smb => "3 SMB",
            StackKind::SmbDirect => "4 SMB Direct",
            StackKind::TcpNtfs => "5 TCP + Windows files",
            StackKind::TcpDds => "6 TCP + DDS files",
            StackKind::RedyNtfs => "7 Redy + Windows files",
            StackKind::RedyDds => "8 Redy + DDS files",
            StackKind::DdsOffloadTcp => "9 DDS offload (TCP)",
            StackKind::DdsOffloadRdma => "10 DDS offload (RDMA)",
        }
    }

    /// Does this stack burn dedicated polling cores (Redy, §8.4)?
    pub fn polling_cores(&self, p: &Params) -> (f64, f64) {
        match self {
            StackKind::RedyNtfs | StackKind::RedyDds => {
                (p.redy_poll_cores as f64, p.redy_poll_cores as f64)
            }
            _ => (0.0, 0.0),
        }
    }
}

/// Read or write workload (Fig 14a/b, 15a/b).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoDir {
    Read,
    Write,
}

/// Aggregated result of one (stack, load) run.
#[derive(Debug, Clone)]
pub struct StackReport {
    pub kind: StackKind,
    pub throughput: f64,
    pub p50_ns: u64,
    pub p99_ns: u64,
    /// Host CPU cores consumed on the storage server.
    pub server_cores: f64,
    /// CPU cores consumed on the client.
    pub client_cores: f64,
    /// DPU Arm cores consumed.
    pub dpu_cores: f64,
}

/// Run one stack at one load point.
///
/// `window`: total outstanding requests (the client's load knob).
/// `batch`: requests per network message (amortizes per-message costs).
pub fn run_stack(
    kind: StackKind,
    dir: IoDir,
    io_bytes: usize,
    window: usize,
    batch: usize,
    p: &Params,
) -> StackReport {
    let mut e = Engine::new(0xD5).with_warmup(20 * MS);

    // --- resources ---
    let client_cpu = e.add_resource("cli_cpu", p.host_cores);
    let server_cpu = e.add_resource("srv_cpu", p.host_cores);
    // Kernel TCP processing has limited scalability.
    let srv_net = e.add_resource("srv_net", p.host_tcp_parallel);
    let cli_net = e.add_resource("cli_net", p.host_tcp_parallel);
    // Serialized portion of the Windows IO path.
    let win_io = e.add_resource("srv_winio", p.win_io_parallel);
    let smb_srv = e.add_resource("srv_smb", p.smb_parallel);
    // SSD channel pool.
    let ssd = e.add_resource("ssd", p.ssd_channels);
    // NIC pipes (bandwidth).
    let srv_nic = e.add_resource("srv_nic", 1);
    let cli_nic = e.add_resource("cli_nic", 1);
    // DPU cores used by DDS (§7: DMA, file service, director+offload).
    let dpu_dma = e.add_resource("dpu_dma", 1);
    let dpu_svc = e.add_resource("dpu_svc", 1);
    let dpu_dir = e.add_resource("dpu_dir", 1);
    // PCIe DMA channel.
    let pcie = e.add_resource("pcie", 1);

    let io = io_bytes;
    let params = p.clone();
    let kindc = kind;
    let dirc = dir;
    let per_req_amort = move |total: Ns| -> Ns { total / batch.max(1) as Ns };

    let flow = FlowSpec::new(window, move |rng| {
        let p = &params;
        let mut st: Vec<Stage> = Vec::new();
        let wire_bytes_req = 64 + if dirc == IoDir::Write { io } else { 0 };
        let wire_bytes_resp = 32 + if dirc == IoDir::Read { io } else { 0 };
        let ssd_service = match dirc {
            IoDir::Read => p.ssd_read_service_ns(io),
            IoDir::Write => p.ssd_write_service_ns(io),
        };
        let ssd_lat = {
            let base = match dirc {
                IoDir::Read => p.ssd_read_lat_ns,
                IoDir::Write => p.ssd_write_lat_ns,
            };
            // Device latency is long-tailed; jitter ~25% of the mean so
            // p99 separates from p50 like real NVMe.
            base * 3 / 4 + rng.exp_ns(base as f64 / 4.0)
        };
        // Small wire jitter.
        let jitter = rng.next_range(200);

        // Helper fragments -------------------------------------------------
        // Host TCP/DBMS costs are per *request* (Fig 14 shows cores
        // growing linearly with IOPS at the paper's own batching);
        // only DMA doorbells and TLDK ingress amortize over a batch.
        let tcp_req_client = p.host_tcp_pkt_ns * p.segments(wire_bytes_req) as Ns;
        let tcp_resp_client = p.host_tcp_pkt_ns * p.segments(wire_bytes_resp) as Ns;
        let net_wire_req = Stage::Delay(p.wire_delay_ns + p.wire_ns(wire_bytes_req) + jitter);
        let net_wire_resp = Stage::Delay(p.wire_delay_ns + p.wire_ns(wire_bytes_resp));
        let nic_req = Stage::Use { res: srv_nic, ns: p.wire_ns(wire_bytes_req) };
        let nic_resp = Stage::Use { res: srv_nic, ns: p.wire_ns(wire_bytes_resp) };
        let _ = cli_nic;

        // Host file-stack fragments ----------------------------------------
        let ntfs_cpu = match dirc {
            IoDir::Read => p.ntfs_read_ns,
            IoDir::Write => p.ntfs_write_ns,
        };
        let win_serial = match dirc {
            IoDir::Read => p.win_io_serial_ns,
            IoDir::Write => p.win_io_serial_write_ns,
        };
        // DDS storage path: host library insert + DMA hop + DPU file
        // service execution + DMA back.
        let dds_file_stages = |st: &mut Vec<Stage>| {
            st.push(Stage::Use { res: server_cpu, ns: p.filelib_req_ns });
            st.push(Stage::Use { res: dpu_dma, ns: per_req_amort(p.dma_op_ns) });
            st.push(Stage::Use {
                res: pcie,
                ns: p.dma_ns(if dirc == IoDir::Write { io } else { 64 }),
            });
            // DPU-native service cost (see Params note).
            st.push(Stage::Use { res: dpu_svc, ns: p.dpu_file_svc_ns });
            st.push(Stage::Delay(ssd_lat));
            st.push(Stage::Use { res: ssd, ns: ssd_service });
            st.push(Stage::Use {
                res: pcie,
                ns: p.dma_ns(if dirc == IoDir::Read { io } else { 16 }),
            });
            st.push(Stage::Use { res: dpu_dma, ns: per_req_amort(p.dma_op_ns) });
        };
        let ntfs_stages = |st: &mut Vec<Stage>| {
            st.push(Stage::Use { res: server_cpu, ns: ntfs_cpu });
            st.push(Stage::Use { res: win_io, ns: win_serial });
            st.push(Stage::Delay(ssd_lat));
            st.push(Stage::Use { res: ssd, ns: ssd_service });
        };

        match kindc {
            StackKind::LocalNtfs => {
                st.push(Stage::Use { res: server_cpu, ns: 500 }); // app issue
                ntfs_stages(&mut st);
                st.push(Stage::Use { res: server_cpu, ns: 500 }); // completion
            }
            StackKind::LocalDds => {
                st.push(Stage::Use { res: server_cpu, ns: 500 });
                dds_file_stages(&mut st);
                st.push(Stage::Use { res: server_cpu, ns: 300 });
            }
            StackKind::Smb | StackKind::SmbDirect => {
                let (net_cost, extra_wire) = if kindc == StackKind::Smb {
                    (tcp_req_client, p.wire_delay_ns)
                } else {
                    (per_req_amort(p.rdma_msg_ns), p.rdma_wire_ns)
                };
                st.push(Stage::Use { res: client_cpu, ns: net_cost + 2_000 });
                st.push(Stage::Delay(extra_wire + p.wire_ns(wire_bytes_req) + jitter));
                st.push(nic_req);
                if kindc == StackKind::Smb {
                    st.push(Stage::Use { res: srv_net, ns: tcp_req_client });
                }
                // SMB server path is heavyweight and serialized; SMB
                // Direct's RDMA transport shortens the protocol path.
                let smb_cost = if kindc == StackKind::Smb { p.smb_req_ns } else { p.smbd_req_ns };
                st.push(Stage::Use { res: smb_srv, ns: smb_cost });
                st.push(Stage::Use { res: server_cpu, ns: smb_cost });
                ntfs_stages(&mut st);
                st.push(nic_resp);
                st.push(Stage::Delay(extra_wire + p.wire_ns(wire_bytes_resp)));
                st.push(Stage::Use { res: client_cpu, ns: net_cost });
            }
            StackKind::TcpNtfs | StackKind::TcpDds => {
                st.push(Stage::Use { res: client_cpu, ns: tcp_req_client + 300 });
                st.push(Stage::Use { res: cli_net, ns: tcp_req_client });
                st.push(net_wire_req);
                st.push(nic_req);
                st.push(Stage::Use { res: srv_net, ns: tcp_req_client });
                st.push(Stage::Use { res: server_cpu, ns: p.dbms_net_req_ns });
                if kindc == StackKind::TcpNtfs {
                    ntfs_stages(&mut st);
                } else {
                    dds_file_stages(&mut st);
                }
                st.push(Stage::Use { res: srv_net, ns: tcp_resp_client });
                st.push(nic_resp);
                st.push(net_wire_resp);
                st.push(Stage::Use { res: cli_net, ns: tcp_resp_client });
                st.push(Stage::Use { res: client_cpu, ns: tcp_resp_client });
            }
            StackKind::RedyNtfs | StackKind::RedyDds => {
                // RDMA-based RPC: tiny CPU, low latency; polling cores
                // accounted separately in the report.
                st.push(Stage::Use { res: client_cpu, ns: per_req_amort(p.rdma_msg_ns) });
                st.push(Stage::Delay(p.rdma_wire_ns + p.wire_ns(wire_bytes_req) + jitter));
                st.push(nic_req);
                st.push(Stage::Use { res: server_cpu, ns: per_req_amort(p.rdma_msg_ns) + 800 });
                if kindc == StackKind::RedyNtfs {
                    ntfs_stages(&mut st);
                } else {
                    dds_file_stages(&mut st);
                }
                st.push(nic_resp);
                st.push(Stage::Delay(p.rdma_wire_ns + p.wire_ns(wire_bytes_resp)));
                st.push(Stage::Use { res: client_cpu, ns: per_req_amort(p.rdma_msg_ns) });
            }
            StackKind::DdsOffloadTcp | StackKind::DdsOffloadRdma => {
                // Client still speaks TCP (or RDMA); the DPU terminates
                // the connection and the host is never involved.
                let (cli_cost, wire_extra) = if kindc == StackKind::DdsOffloadTcp {
                    (tcp_req_client, p.wire_delay_ns)
                } else {
                    (per_req_amort(p.rdma_msg_ns), p.rdma_wire_ns)
                };
                st.push(Stage::Use { res: client_cpu, ns: cli_cost + 300 });
                st.push(Stage::Delay(wire_extra + p.wire_ns(wire_bytes_req) + jitter));
                st.push(nic_req);
                // Traffic director, DPU-native ns. Fig 21 anchors the
                // all-in per-request cost at ~1.25 µs for ~1 KB
                // responses (6.4 Gbps/core); larger responses pay per
                // extra TLDK segment. RDMA transport skips the TCP
                // split and costs roughly half.
                let dir_in = if kindc == StackKind::DdsOffloadTcp {
                    p.dpu_director_req_ns / 2
                        + per_req_amort(p.dpu_tldk_seg_ns * p.segments(wire_bytes_req) as Ns)
                } else {
                    p.dpu_director_req_ns / 4
                };
                st.push(Stage::Use { res: dpu_dir, ns: dir_in });
                // Offload engine + file service on the DPU.
                st.push(Stage::Use { res: dpu_svc, ns: p.dpu_offload_req_ns });
                st.push(Stage::Delay(ssd_lat));
                st.push(Stage::Use { res: ssd, ns: ssd_service });
                // Zero-copy packetization + egress on the director core.
                let dir_out = if kindc == StackKind::DdsOffloadTcp {
                    p.dpu_director_req_ns / 2
                        + (p.segments(wire_bytes_resp) as Ns - 1) * p.dpu_tldk_seg_ns / 4
                } else {
                    p.dpu_director_req_ns / 4
                };
                st.push(Stage::Use { res: dpu_dir, ns: dir_out });
                st.push(nic_resp);
                st.push(Stage::Delay(wire_extra + p.wire_ns(wire_bytes_resp)));
                st.push(Stage::Use { res: client_cpu, ns: cli_cost });
            }
        }
        StageChain::new(0, st)
    });

    let horizon = SEC / 2;
    let rep: RunReport = e.run(vec![flow], 1, horizon);
    let (cli_poll, srv_poll) = kind.polling_cores(p);
    StackReport {
        kind,
        throughput: rep.throughput(0),
        p50_ns: rep.latency[0].p50(),
        p99_ns: rep.latency[0].p99(),
        server_cores: rep.cores_prefix("srv_") + srv_poll,
        client_cores: rep.cores_prefix("cli_") + cli_poll,
        dpu_cores: rep.cores_prefix("dpu_"),
    }
}

/// Sweep load (window) and return the run at the *knee*: the smallest
/// window within 2% of the best throughput — "peak throughput" in
/// Fig 16, with the latency the system exhibits when just saturated
/// (deeper queues only inflate latency without throughput).
pub fn peak(kind: StackKind, dir: IoDir, io_bytes: usize, batch: usize, p: &Params) -> StackReport {
    let runs: Vec<StackReport> = [16usize, 64, 256, 1024, 4096]
        .iter()
        .map(|&w| run_stack(kind, dir, io_bytes, w, batch, p))
        .collect();
    let best = runs.iter().map(|r| r.throughput).fold(0.0f64, f64::max);
    runs.into_iter().find(|r| r.throughput >= 0.98 * best).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> Params {
        Params::paper()
    }

    /// Fig 14a anchor: the baseline reaches ~390 K IOPS around ~10.7
    /// host cores; DDS files beats it with fewer cores; offload uses
    /// ~zero host cores at higher throughput.
    #[test]
    fn fig14a_shape() {
        let base = peak(StackKind::TcpNtfs, IoDir::Read, 1024, 8, &p());
        let files = peak(StackKind::TcpDds, IoDir::Read, 1024, 8, &p());
        let off = peak(StackKind::DdsOffloadTcp, IoDir::Read, 1024, 8, &p());
        assert!(
            base.throughput > 300_000.0 && base.throughput < 500_000.0,
            "baseline {:.0}",
            base.throughput
        );
        assert!(files.throughput > base.throughput, "DDS files must beat baseline");
        assert!(off.throughput > 650_000.0, "offload {:.0}", off.throughput);
        assert!(base.server_cores > 8.0, "baseline cores {:.1}", base.server_cores);
        assert!(files.server_cores < base.server_cores);
        assert!(off.server_cores < 0.5, "offload host cores {:.2}", off.server_cores);
    }

    /// Fig 15a anchor: order-of-magnitude latency reduction at peak.
    #[test]
    fn fig15a_latency_ordering() {
        let base = run_stack(StackKind::TcpNtfs, IoDir::Read, 1024, 4096, 8, &p());
        let off = run_stack(StackKind::DdsOffloadTcp, IoDir::Read, 1024, 512, 8, &p());
        assert!(base.p50_ns > 5 * crate::sim::MS, "baseline p50 {}", base.p50_ns);
        assert!(off.p50_ns < crate::sim::MS, "offload p50 {}", off.p50_ns);
        assert!(base.p50_ns / off.p50_ns.max(1) >= 8, "≥~10x gap");
    }

    /// Fig 16 shape: SMB ≪ application stacks; kernel-bypass peaks
    /// match local storage; offload stacks burn no host cores.
    #[test]
    fn fig16_shape() {
        let pp = p();
        let smb = peak(StackKind::Smb, IoDir::Read, 1024, 8, &pp);
        let tcp_ntfs = peak(StackKind::TcpNtfs, IoDir::Read, 1024, 8, &pp);
        let local_dds = peak(StackKind::LocalDds, IoDir::Read, 1024, 8, &pp);
        let redy_dds = peak(StackKind::RedyDds, IoDir::Read, 1024, 8, &pp);
        let off_rdma = peak(StackKind::DdsOffloadRdma, IoDir::Read, 1024, 8, &pp);
        assert!(smb.throughput < tcp_ntfs.throughput);
        // Kernel bypass reaches local-storage peak (§8.4).
        assert!(redy_dds.throughput > 0.9 * local_dds.throughput);
        assert!(off_rdma.throughput > 0.9 * local_dds.throughput);
        // Redy burns polling cores; DDS offload does not.
        assert!(redy_dds.server_cores > off_rdma.server_cores + 1.0);
        // Offload latency close to local.
        assert!(off_rdma.p50_ns < 2 * local_dds.p50_ns + 200_000);
    }

    /// Fig 14b anchor: writes are slower and never offloaded.
    #[test]
    fn fig14b_write_shape() {
        let base = peak(StackKind::TcpNtfs, IoDir::Write, 1024, 8, &p());
        let files = peak(StackKind::TcpDds, IoDir::Write, 1024, 8, &p());
        assert!(base.throughput < 260_000.0, "baseline writes {:.0}", base.throughput);
        assert!(files.throughput > base.throughput);
        // >5 cores saved above 200 K IOPS (§8.2).
        assert!(base.server_cores - files.server_cores > 5.0);
    }
}
