//! Network-path latency scenarios (Figs 4, 19, 20) on the testbed.
//!
//! These are latency-only experiments (one outstanding message): a
//! client sends a TCP message, the server echoes it back; the question
//! is *who* echoes — the host through the kernel stack, or the DPU via
//! Linux TCP / TLDK (§2 Fig 4, §8.5 Figs 19-20).

use crate::sim::{Ns, Params};

/// Who terminates and echoes the message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EchoMode {
    /// Forwarded through the DPU to the host; host kernel TCP echoes.
    Host,
    /// DPU echoes using Linux kernel TCP on the Arm cores (Fig 19 "OS").
    DpuLinuxTcp,
    /// DPU echoes using userspace TLDK (Fig 19 "userspace").
    DpuTldk,
    /// TLDK running on the HOST (Fig 20 comparison).
    HostTldk,
}

/// Round-trip time of one echo of `msg_bytes` (unloaded, p50).
pub fn echo_rtt(mode: EchoMode, msg_bytes: usize, p: &Params) -> Ns {
    let segs = p.segments(msg_bytes) as Ns;
    let wire = 2 * (p.wire_delay_ns + p.wire_ns(msg_bytes)); // both ways
    match mode {
        EchoMode::Host => {
            // NIC → (off-path DPU forwards via Arm core, §5.3) → host
            // kernel TCP rx, app echo, tx. Per-segment cost is
            // sublinear (GRO/LRO coalesce bursts).
            let fwd = 2 * p.dpu_forward_ns;
            let per_msg = (p.host_tcp_pkt_ns as f64 * (0.75 + 0.25 * segs as f64)) as Ns;
            wire + fwd + 2 * per_msg + 2_000
        }
        EchoMode::DpuLinuxTcp => {
            // Kernel overhead exacerbated by wimpy cores (§5.3): worse
            // than forwarding to the host for small messages.
            wire + 2 * (p.dpu_linux_tcp_msg_ns + segs * p.dpu_linux_per_seg_ns)
        }
        EchoMode::DpuTldk => {
            // Userspace stack on the DPU: ~3× cheaper than Linux-on-DPU.
            wire + 2 * (p.dpu_tldk_msg_ns + segs * p.tldk_per_seg_ns)
        }
        EchoMode::HostTldk => {
            // TLDK on the host: faster cores (lower base), but pays the
            // NIC→host PCIe hop and host-DDR payload processing
            // (§8.5: the DPU wins when memory-intensive).
            let pcie = 2 * (p.dma_op_ns + (msg_bytes as f64 / p.dma_bytes_per_ns) as Ns);
            let mem_penalty = (msg_bytes as f64 * p.host_mem_penalty_ns_per_byte) as Ns;
            wire + pcie + 2 * (p.host_tldk_msg_ns + segs * p.tldk_per_seg_ns) + mem_penalty
        }
    }
}

/// Fig 4 series: host-respond vs DPU-respond (TLDK) across sizes.
pub fn fig4_series(p: &Params) -> Vec<(usize, Ns, Ns)> {
    [64usize, 256, 1024, 4096, 16384]
        .iter()
        .map(|&s| (s, echo_rtt(EchoMode::Host, s, p), echo_rtt(EchoMode::DpuTldk, s, p)))
        .collect()
}

/// Fig 19 series: vanilla host vs DPU(Linux) vs DPU(TLDK).
pub fn fig19_series(p: &Params) -> Vec<(usize, Ns, Ns, Ns)> {
    [64usize, 512, 1460, 4096, 16384]
        .iter()
        .map(|&s| {
            (
                s,
                echo_rtt(EchoMode::Host, s, p),
                echo_rtt(EchoMode::DpuLinuxTcp, s, p),
                echo_rtt(EchoMode::DpuTldk, s, p),
            )
        })
        .collect()
}

/// Fig 20 series: TLDK on host vs TLDK on DPU.
pub fn fig20_series(p: &Params) -> Vec<(usize, Ns, Ns)> {
    [64usize, 1460, 8192, 65536, 262144]
        .iter()
        .map(|&s| (s, echo_rtt(EchoMode::HostTldk, s, p), echo_rtt(EchoMode::DpuTldk, s, p)))
        .collect()
}

/// Fig 21: traffic-director Gbps vs number of DPU cores (RSS scaling).
/// Derived from the per-request director cost; linear by construction
/// of RSS (no shared state across cores, §7).
pub fn fig21_series(p: &Params, resp_bytes: usize) -> Vec<(usize, f64)> {
    let per_req_ns = p.dpu_director_req_ns + p.dpu_tldk_seg_ns / 4;
    let per_core_reqs = 1e9 / per_req_ns as f64;
    let gbps_per_core = per_core_reqs * (resp_bytes as f64 * 8.0) / 1e9;
    (1..=8).map(|c| (c, gbps_per_core * c as f64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> Params {
        Params::paper()
    }

    /// Fig 4 shape: the DPU halves the RTT by not forwarding to host.
    #[test]
    fn fig4_dpu_halves_latency() {
        for (sz, host, dpu) in fig4_series(&p()) {
            assert!(dpu < host, "size {sz}: dpu {dpu} !< host {host}");
            let ratio = host as f64 / dpu as f64;
            assert!(ratio > 1.5 && ratio < 4.0, "size {sz}: ratio {ratio:.2}");
        }
    }

    /// Fig 19 shape: Linux-on-DPU is WORSE than the vanilla host path
    /// for small messages; TLDK beats both (≈3× under Linux TCP,
    /// ≈2.5× under vanilla).
    #[test]
    fn fig19_shape() {
        let rows = fig19_series(&p());
        let (_, host, linux, tldk) = rows[0];
        assert!(linux > host, "Linux TCP on DPU must offset the offload benefit");
        let vs_linux = linux as f64 / tldk as f64;
        let vs_host = host as f64 / tldk as f64;
        assert!((2.0..5.0).contains(&vs_linux), "vs linux {vs_linux:.2}");
        assert!((1.7..4.0).contains(&vs_host), "vs host {vs_host:.2}");
    }

    /// Fig 20 shape: TLDK-on-DPU wins for LARGE (memory-intensive)
    /// messages; small messages are comparable.
    #[test]
    fn fig20_shape() {
        let rows = fig20_series(&p());
        let (_, host_small, dpu_small) = rows[0];
        let (_, host_big, dpu_big) = rows[rows.len() - 1];
        let small_gap = (host_small as f64 - dpu_small as f64).abs() / host_small as f64;
        assert!(small_gap < 0.5, "small messages comparable: {small_gap:.2}");
        assert!(dpu_big < host_big, "DPU must win for large messages");
    }

    /// Fig 21 shape: ~6.4 Gbps on one core, linear scaling to 8.
    #[test]
    fn fig21_linear_scaling() {
        let rows = fig21_series(&p(), 1024);
        let (c1, g1) = rows[0];
        assert_eq!(c1, 1);
        assert!((4.0..9.0).contains(&g1), "one-core Gbps {g1:.1}");
        for (c, g) in &rows {
            let lin = g1 * *c as f64;
            assert!((g - lin).abs() / lin < 1e-9, "non-linear at {c} cores");
        }
    }
}
