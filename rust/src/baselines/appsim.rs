//! Production-integration scenarios on the testbed: the Hyperscale
//! page server (Figs 2, 24), FASTER (Figs 5, 25, 26), and the §8.5
//! component ablations that need hardware timing (Figs 18, 23).

use crate::sim::{Engine, FlowSpec, Params, Stage, StageChain, Ns, MS, SEC};

// ---------------------------------------------------------------- Fig 2

/// One Fig 2 sample: achieved page throughput and the CPU split the
/// figure stacks (DBMS network module / OS network stack / file+other).
#[derive(Debug, Clone)]
pub struct HyperscaleCpuPoint {
    pub throughput: f64,
    pub dbms_net_cores: f64,
    pub os_net_cores: f64,
    pub file_cores: f64,
}

impl HyperscaleCpuPoint {
    pub fn total(&self) -> f64 {
        self.dbms_net_cores + self.os_net_cores + self.file_cores
    }
}

/// Run the baseline Hyperscale page server at one load point
/// (8 KB random page reads over TCP + Windows files, §1/§9.1).
pub fn hyperscale_baseline(window: usize, p: &Params) -> (HyperscaleCpuPoint, u64, u64) {
    let mut e = Engine::new(2).with_warmup(20 * MS);
    let dbms = e.add_resource("hs_dbms", p.hs_parallel);
    let osnet = e.add_resource("hs_osnet", p.host_tcp_parallel);
    let file = e.add_resource("hs_file", p.win_io_parallel * 2);
    let ssd = e.add_resource("ssd", p.ssd_channels);
    let page = 8192usize;
    let params = p.clone();
    let flow = FlowSpec::new(window, move |rng| {
        let p = &params;
        let jit = rng.next_range(2_000);
        StageChain::new(
            0,
            vec![
                Stage::Delay(p.wire_delay_ns + p.wire_ns(64) + jit),
                Stage::Use { res: osnet, ns: p.hs_os_net_ns / 2 },
                Stage::Use { res: dbms, ns: p.hs_dbms_net_ns },
                Stage::Use { res: file, ns: p.hs_file_ns },
                Stage::Delay(p.ssd_read_lat_ns * 3 / 4 + rng.exp_ns(p.ssd_read_lat_ns as f64 / 4.0)),
                Stage::Use { res: ssd, ns: p.ssd_read_service_ns(page) },
                Stage::Use { res: osnet, ns: p.hs_os_net_ns / 2 },
                Stage::Delay(p.wire_delay_ns + p.wire_ns(page)),
            ],
        )
    });
    let rep = e.run(vec![flow], 1, SEC / 2);
    (
        HyperscaleCpuPoint {
            throughput: rep.throughput(0),
            dbms_net_cores: rep.cores("hs_dbms"),
            os_net_cores: rep.cores("hs_osnet"),
            file_cores: rep.cores("hs_file"),
        },
        rep.latency[0].p50(),
        rep.latency[0].p99(),
    )
}

/// The DDS page server (§9.1): GetPage@LSN offloaded to the DPU.
/// `offload_frac` is the fraction of requests whose cached LSN is fresh
/// (the rest bounce to the host path).
pub fn pageserver_dds(window: usize, offload_frac: f64, p: &Params) -> (f64, u64, u64, f64) {
    let mut e = Engine::new(3).with_warmup(20 * MS);
    let dir = e.add_resource("dpu_dir", 1);
    let svc = e.add_resource("dpu_svc", 1);
    let ssd = e.add_resource("ssd", p.ssd_channels);
    let dbms = e.add_resource("hs_dbms", p.hs_parallel);
    let osnet = e.add_resource("hs_osnet", p.host_tcp_parallel);
    let page = 8192usize;
    let params = p.clone();
    let flow = FlowSpec::new(window, move |rng| {
        let p = &params;
        let offloaded = rng.next_f64() < offload_frac;
        let ssd_lat =
            p.ssd_read_lat_ns * 3 / 4 + rng.exp_ns(p.ssd_read_lat_ns as f64 / 4.0);
        let mut st = vec![Stage::Delay(p.wire_delay_ns + p.wire_ns(64))];
        if offloaded {
            st.push(Stage::Use {
                res: dir,
                ns: p.dpu_director_req_ns / 2 + p.dpu_tldk_seg_ns / 4,
            });
            st.push(Stage::Use { res: svc, ns: p.dpu_offload_req_ns });
            st.push(Stage::Delay(ssd_lat));
            st.push(Stage::Use { res: ssd, ns: p.ssd_read_service_ns(page) });
            // 8 KB responses cross the director as ~6 TLDK segments.
            st.push(Stage::Use {
                res: dir,
                ns: p.dpu_director_req_ns / 2
                    + (p.segments(page) as Ns - 1) * p.dpu_tldk_seg_ns / 4,
            });
        } else {
            // Bounced to the host over the PEP's second connection.
            st.push(Stage::Use { res: dir, ns: p.dpu_director_req_ns });
            st.push(Stage::Use { res: osnet, ns: p.hs_os_net_ns / 2 });
            st.push(Stage::Use { res: dbms, ns: p.hs_dbms_net_ns });
            st.push(Stage::Delay(ssd_lat));
            st.push(Stage::Use { res: ssd, ns: p.ssd_read_service_ns(page) });
            st.push(Stage::Use { res: osnet, ns: p.hs_os_net_ns / 2 });
        }
        st.push(Stage::Delay(p.wire_delay_ns + p.wire_ns(page)));
        StageChain::new(0, st)
    });
    let rep = e.run(vec![flow], 1, SEC / 2);
    (
        rep.throughput(0),
        rep.latency[0].p50(),
        rep.latency[0].p99(),
        rep.cores_prefix("hs_"),
    )
}

// ---------------------------------------------------------------- Fig 5

/// FASTER in-memory RMW throughput at `threads` (YCSB RMW, §2).
/// Returns (host_ops, dpu_ops); the DPU caps at its 8 wimpy cores and
/// runs each op `rmw_dpu_slowdown`× slower.
pub fn faster_rmw(threads: usize, p: &Params) -> (f64, f64) {
    let host_threads = threads.min(p.host_cores) as f64;
    // Mild contention: beyond 32 threads each extra thread yields 60%.
    let host_eff = if host_threads <= 32.0 {
        host_threads
    } else {
        32.0 + (host_threads - 32.0) * 0.6
    };
    let host = host_eff * 1e9 / p.faster_rmw_ns as f64;
    let dpu_threads = threads.min(p.dpu_cores) as f64;
    let dpu = dpu_threads * 1e9 / (p.faster_rmw_ns as f64 * p.rmw_dpu_slowdown);
    (host, dpu)
}

// ----------------------------------------------------------- Figs 25/26

/// Disaggregated FASTER under YCSB uniform reads (§9.2).
/// Returns (throughput, p50, p99, host_cores).
pub fn faster_disaggregated(window: usize, dds: bool, p: &Params) -> (f64, u64, u64, f64) {
    let mut e = Engine::new(4).with_warmup(20 * MS);
    let record = 64usize; // 8 B key + 8 B value + header, block-rounded
    let params = p.clone();
    if dds {
        let dir = e.add_resource("dpu_dir", 1);
        let svc = e.add_resource("dpu_svc", 1);
        let ssd = e.add_resource("ssd", p.ssd_channels);
        let flow = FlowSpec::new(window, move |rng| {
            let p = &params;
            let ssd_lat =
                p.ssd_read_lat_ns * 3 / 4 + rng.exp_ns(p.ssd_read_lat_ns as f64 / 4.0);
            StageChain::new(
                0,
                vec![
                    Stage::Delay(p.wire_delay_ns + p.wire_ns(32)),
                    Stage::Use { res: dir, ns: p.dpu_director_req_ns / 2 },
                    Stage::Use { res: svc, ns: p.dpu_offload_req_ns / 2 },
                    Stage::Delay(ssd_lat),
                    Stage::Use { res: ssd, ns: p.ssd_read_service_ns(record) },
                    Stage::Use { res: dir, ns: p.dpu_director_req_ns / 2 },
                    Stage::Delay(p.wire_delay_ns + p.wire_ns(record)),
                ],
            )
        });
        let rep = e.run(vec![flow], 1, SEC / 2);
        (rep.throughput(0), rep.latency[0].p50(), rep.latency[0].p99(), rep.cores_prefix("srv_"))
    } else {
        // Host FASTER: network module + index + IDevice via NTFS path.
        let srv = e.add_resource("srv_faster", 20);
        let ssd = e.add_resource("ssd", p.ssd_channels);
        let flow = FlowSpec::new(window, move |rng| {
            let p = &params;
            let ssd_lat =
                p.ssd_read_lat_ns * 3 / 4 + rng.exp_ns(p.ssd_read_lat_ns as f64 / 4.0);
            StageChain::new(
                0,
                vec![
                    Stage::Delay(p.wire_delay_ns + p.wire_ns(32)),
                    Stage::Use {
                        res: srv,
                        ns: p.faster_net_ns + p.faster_core_ns + p.faster_idevice_ns,
                    },
                    Stage::Delay(ssd_lat),
                    Stage::Use { res: ssd, ns: p.ssd_read_service_ns(record) },
                    Stage::Delay(p.wire_delay_ns + p.wire_ns(record)),
                ],
            )
        });
        let rep = e.run(vec![flow], 1, SEC / 2);
        (rep.throughput(0), rep.latency[0].p50(), rep.latency[0].p99(), rep.cores("srv_faster"))
    }
}

// ------------------------------------------------------------ Figs 18/23

/// Fig 18: DPU-backed file I/O throughput vs request size, zero-copy vs
/// extra-copy. Returns IOPS.
pub fn fileio_throughput(io_bytes: usize, zero_copy: bool, window: usize, p: &Params) -> f64 {
    let mut e = Engine::new(5).with_warmup(10 * MS);
    let dma = e.add_resource("dpu_dma", 1);
    let svc = e.add_resource("dpu_svc", 1);
    let ssd = e.add_resource("ssd", p.ssd_channels);
    let pcie = e.add_resource("pcie", 1);
    let params = p.clone();
    let flow = FlowSpec::new(window, move |rng| {
        let p = &params;
        let mut svc_ns = p.dpu_file_svc_ns;
        if !zero_copy {
            // Straw-man: the service core memcpys the payload between
            // the DMA buffer and the I/O buffer (both directions of the
            // §4.3 argument).
            svc_ns += p.dpu_memcpy_ns(io_bytes);
        }
        StageChain::new(
            0,
            vec![
                Stage::Use { res: dma, ns: p.dma_op_ns / 8 },
                Stage::Use { res: pcie, ns: p.dma_ns(64) },
                Stage::Use { res: svc, ns: svc_ns },
                Stage::Delay(
                    p.ssd_read_lat_ns * 3 / 4 + rng.exp_ns(p.ssd_read_lat_ns as f64 / 4.0),
                ),
                Stage::Use { res: ssd, ns: p.ssd_read_service_ns(io_bytes) },
                Stage::Use { res: pcie, ns: p.dma_ns(io_bytes) },
                Stage::Use { res: dma, ns: p.dma_op_ns / 8 },
            ],
        )
    });
    let rep = e.run(vec![flow], 1, SEC / 4);
    rep.throughput(0)
}

/// Fig 23: offload-engine zero-copy ablation. Returns (IOPS, p50 ns).
pub fn offload_zero_copy(zero_copy: bool, window: usize, p: &Params) -> (f64, u64) {
    let mut e = Engine::new(6).with_warmup(10 * MS);
    let dir = e.add_resource("dpu_dir", 1);
    let svc = e.add_resource("dpu_svc", 1);
    let ssd = e.add_resource("ssd", p.ssd_channels);
    let io = 1024usize;
    let params = p.clone();
    let flow = FlowSpec::new(window, move |rng| {
        let p = &params;
        let mut engine_ns = p.dpu_offload_req_ns;
        if !zero_copy {
            // Straw-man of §6.2: copy file service → read buffer, then
            // read buffer → packet buffer (two copies).
            engine_ns += 2 * p.dpu_memcpy_ns(io);
        }
        StageChain::new(
            0,
            vec![
                Stage::Delay(p.wire_delay_ns + p.wire_ns(64)),
                Stage::Use { res: dir, ns: p.dpu_director_req_ns / 2 },
                Stage::Use { res: svc, ns: engine_ns },
                Stage::Delay(
                    p.ssd_read_lat_ns * 3 / 4 + rng.exp_ns(p.ssd_read_lat_ns as f64 / 4.0),
                ),
                Stage::Use { res: ssd, ns: p.ssd_read_service_ns(io) },
                Stage::Use { res: dir, ns: p.dpu_director_req_ns / 2 },
                Stage::Delay(p.wire_delay_ns + p.wire_ns(io)),
            ],
        )
    });
    let rep = e.run(vec![flow], 1, SEC / 4);
    (rep.throughput(0), rep.latency[0].p50())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> Params {
        Params::paper()
    }

    /// Fig 2 anchors: ~17 cores at ~156 K pages/s, DBMS net module the
    /// largest component.
    #[test]
    fn fig2_anchor() {
        let (pt, _, _) = hyperscale_baseline(4096, &p());
        assert!(pt.throughput > 130_000.0, "tput {:.0}", pt.throughput);
        assert!((pt.total() - 17.0).abs() < 3.0, "total {:.1}", pt.total());
        assert!(pt.dbms_net_cores > pt.os_net_cores);
        assert!(pt.dbms_net_cores > pt.file_cores);
    }

    /// Fig 24 anchors: baseline ~90 K @ ~4.4 ms p99 vs DDS ~160 K @
    /// ~1.3 ms p99.
    #[test]
    fn fig24_shape() {
        let (_, _, base_p99) = hyperscale_baseline(512, &p());
        let base = hyperscale_baseline(512, &p()).0.throughput;
        let (dds_tput, _, dds_p99, host_cores) = pageserver_dds(256, 0.95, &p());
        assert!(dds_tput > base, "dds {dds_tput:.0} !> base {base:.0}");
        assert!(dds_p99 < base_p99, "dds p99 {dds_p99} !< base {base_p99}");
        assert!(host_cores < 2.0, "host cores {host_cores:.1}");
    }

    /// Fig 5 anchors: DPU ≈4.5× slower per thread, capped at 8 threads.
    #[test]
    fn fig5_shape() {
        let pp = p();
        let (h8, d8) = faster_rmw(8, &pp);
        assert!((h8 / d8 - pp.rmw_dpu_slowdown).abs() < 0.1);
        let (_, d16) = faster_rmw(16, &pp);
        assert_eq!(d8, d16, "DPU cannot scale past 8 threads");
        let (h48, _) = faster_rmw(48, &pp);
        assert!(h48 > h8 * 4.0);
    }

    /// Fig 25/26 anchors: baseline ~340 K @ ~20 cores, ms-scale
    /// latency; DDS near 1 M with ~0 host cores, µs-scale latency.
    #[test]
    fn fig25_26_shape() {
        let pp = p();
        let (bt, bp50, _, bc) = faster_disaggregated(4096, false, &pp);
        assert!((300_000.0..400_000.0).contains(&bt), "baseline {bt:.0}");
        assert!((bc - 20.0).abs() < 3.0, "baseline cores {bc:.1}");
        assert!(bp50 > 5 * crate::sim::MS, "baseline p50 {bp50}");
        let (dt, dp50, _, dc) = faster_disaggregated(256, true, &pp);
        assert!(dt > 900_000.0, "dds {dt:.0}");
        assert!(dc < 0.1, "dds host cores {dc:.2}");
        assert!(dp50 < crate::sim::MS, "dds p50 {dp50}");
    }

    /// Fig 18 anchor: zero-copy wins up to ~93% at large sizes.
    #[test]
    fn fig18_shape() {
        let pp = p();
        let mut best_gain = 0.0f64;
        for io in [1 << 10, 4 << 10, 16 << 10, 64 << 10] {
            let zc = fileio_throughput(io, true, 512, &pp);
            let cp = fileio_throughput(io, false, 512, &pp);
            assert!(zc >= cp * 0.99, "zero-copy can't lose (io {io})");
            best_gain = best_gain.max(zc / cp - 1.0);
        }
        assert!((0.5..1.5).contains(&best_gain), "peak gain {best_gain:.2}");
    }

    /// Fig 23 anchors: ~520 K→730 K IOPS and lower latency at peak.
    #[test]
    fn fig23_shape() {
        let pp = p();
        let (zc_t, zc_l) = offload_zero_copy(true, 512, &pp);
        let (cp_t, cp_l) = offload_zero_copy(false, 512, &pp);
        assert!(zc_t > cp_t * 1.2, "zc {zc_t:.0} vs copy {cp_t:.0}");
        assert!(zc_l < cp_l, "zc lat {zc_l} vs {cp_l}");
        assert!((650_000.0..800_000.0).contains(&zc_t));
        assert!((380_000.0..620_000.0).contains(&cp_t));
    }
}
