//! Baseline storage stacks and the testbed scenario builders (§8.4).
//!
//! Every disaggregated-storage configuration the evaluation compares —
//! local NTFS, local DDS files, SMB, SMB Direct, TCP/Redy × Windows/DDS
//! files, and DDS offloading over TCP/RDMA — is expressed as a
//! composition of stage chains over the calibrated queueing testbed
//! ([`crate::sim`]). The figure benches sweep load (window size) and
//! report achieved throughput, latency and CPU cores, exactly like the
//! paper's client does with batching/outstanding-message knobs (§8.1).

pub mod appsim;
pub mod netlat;
pub mod stacks;

pub use netlat::EchoMode;
pub use stacks::{peak, run_stack, IoDir, StackKind, StackReport};
