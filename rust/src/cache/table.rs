//! Cuckoo hash table with per-bucket seqlocks and overflow chains.
//!
//! Concurrency contract (the displacement bugs of PR 9 live here):
//!
//! * Readers (`get`) are lock-free. A key that is present must be
//!   observable at every instant — the kick path may *move* it between
//!   its two buckets, but never through a window where it is in
//!   neither. Displacements therefore execute as single moves that
//!   hold BOTH bucket seqlocks (ordered by bucket index), and the
//!   reader re-validates its first bucket after a double miss: a
//!   displacement that ran h2→h1 between the two probes is the one
//!   interleaving per-bucket validation cannot see.
//! * Writers (`insert`, `remove`, `export_dense`) serialize on
//!   `write_lock`. An invalidation can therefore never interleave with
//!   an in-flight displacement of the same key; `remove` additionally
//!   clears every occurrence in both buckets (slots and chains) so a
//!   duplicate — however it arose — cannot resurrect a dead mapping.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Slots per bucket (common cuckoo arrangement).
pub const SLOTS: usize = 4;
/// Maximum cuckoo displacement path before falling back to chaining.
const MAX_KICKS: usize = 64;
/// Reserved key meaning "empty slot".
pub const EMPTY: u64 = u64::MAX;

// Hash constants — shared verbatim with the Pallas kernel
// (`python/compile/kernels/cuckoo.py`), which evaluates the same
// two-choice lookup on the DPU data path.
pub const H1_MUL: u64 = 0x9E37_79B9_7F4A_7C15;
pub const H1_SHIFT: u32 = 17;
pub const H2_MUL: u64 = 0xC2B2_AE3D_27D4_EB4F;
pub const H2_SHIFT: u32 = 13;
pub const H2_XOR_SHIFT: u32 = 33;

/// A fixed 32-byte cache item — in the Hyperscale integration `(lsn,
/// file_id, offset, size)` keyed by page id; in the FASTER integration
/// `(file_id, offset, record_size, _)` keyed by the KV key (§9).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheItem {
    pub a: u64,
    pub b: u64,
    pub c: u64,
    pub d: u64,
}

impl CacheItem {
    pub fn new(a: u64, b: u64, c: u64, d: u64) -> Self {
        CacheItem { a, b, c, d }
    }
}

struct Bucket {
    /// Seqlock version: odd = write in progress.
    version: AtomicU64,
    keys: [AtomicU64; SLOTS],
    items: UnsafeCell<[CacheItem; SLOTS]>,
    /// Overflow chain (§6.1 "chain items in a bucket"). Guarded by the
    /// bucket seqlock for readers and the writer mutex for writers.
    chain: UnsafeCell<Vec<(u64, CacheItem)>>,
}

// SAFETY: readers validate every access with the seqlock version;
// writers are serialized by `CuckooCache::write_lock` and publish via
// version bumps with Release ordering.
unsafe impl Send for Bucket {}
unsafe impl Sync for Bucket {}

impl Bucket {
    fn new() -> Self {
        Bucket {
            version: AtomicU64::new(0),
            keys: std::array::from_fn(|_| AtomicU64::new(EMPTY)),
            items: UnsafeCell::new([CacheItem::default(); SLOTS]),
            chain: UnsafeCell::new(Vec::new()),
        }
    }
}

/// Dense slot-array snapshot consumed by the AOT predicate kernel.
#[derive(Debug, Clone)]
pub struct DenseTable {
    /// `buckets * SLOTS` keys; EMPTY marks a free slot.
    pub keys: Vec<u64>,
    /// `buckets * SLOTS * 4` item words (a,b,c,d per slot).
    pub items: Vec<u64>,
    pub buckets: usize,
}

/// Table occupancy statistics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub items: usize,
    pub slot_items: usize,
    pub chain_items: usize,
    pub buckets: usize,
    pub capacity: usize,
}

/// The concurrent cuckoo cache table.
pub struct CuckooCache {
    buckets: Box<[Bucket]>,
    mask: u64,
    capacity: usize,
    len: AtomicUsize,
    chain_len: AtomicUsize,
    /// Single writer at a time (the DPU file service, Table 2).
    write_lock: Mutex<()>,
}

impl CuckooCache {
    /// Create a table that can hold up to `capacity` items. Memory is
    /// reserved up front — the table never resizes (§6.1).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= SLOTS);
        // Bucket count sized for ~50% slot load factor at capacity, so
        // most items live in slots and chains stay short.
        let nbuckets = (2 * capacity / SLOTS).next_power_of_two();
        let buckets = (0..nbuckets).map(|_| Bucket::new()).collect::<Vec<_>>().into_boxed_slice();
        CuckooCache {
            buckets,
            mask: nbuckets as u64 - 1,
            capacity,
            len: AtomicUsize::new(0),
            chain_len: AtomicUsize::new(0),
            write_lock: Mutex::new(()),
        }
    }

    #[inline]
    fn h1(&self, key: u64) -> usize {
        (key.wrapping_mul(H1_MUL) >> H1_SHIFT & self.mask) as usize
    }

    #[inline]
    fn h2(&self, key: u64) -> usize {
        // Independent multiply-shift; xor-fold for avalanche.
        let x = key ^ (key >> H2_XOR_SHIFT);
        (x.wrapping_mul(H2_MUL) >> H2_SHIFT & self.mask) as usize
    }

    /// Seqlock-validated scan of one bucket. Returns the item (if the
    /// key is present) and the version at which the consistent read
    /// was taken.
    fn probe_bucket(&self, bi: usize, key: u64) -> (Option<CacheItem>, u64) {
        let b = &self.buckets[bi];
        loop {
            let v0 = b.version.load(Ordering::Acquire);
            if v0 & 1 == 1 {
                std::hint::spin_loop();
                continue; // write in progress
            }
            let mut found: Option<CacheItem> = None;
            for s in 0..SLOTS {
                if b.keys[s].load(Ordering::Acquire) == key {
                    // SAFETY: validated by the seqlock re-check below.
                    found = Some(unsafe { (*b.items.get())[s] });
                    break;
                }
            }
            if found.is_none() {
                // SAFETY: chain reads validated by the version
                // re-check below; writers only mutate the chain
                // while the version is odd.
                let chain = unsafe { &*b.chain.get() };
                found = chain.iter().find(|(k, _)| *k == key).map(|(_, v)| *v);
            }
            let v1 = b.version.load(Ordering::Acquire);
            if v0 == v1 {
                return (found, v0);
            }
            // Torn read; retry this bucket.
        }
    }

    /// Lock-free lookup with worst-case-constant bucket probes.
    ///
    /// A per-bucket seqlock alone does NOT make the two-bucket probe
    /// atomic: a displacement that moves the key from its h2 bucket
    /// into its h1 bucket between our two probes leaves both probes
    /// individually consistent yet both missing (the probe order
    /// opposes the move direction). Every displacement bumps both
    /// bucket versions inside one critical section, so after a double
    /// miss we re-check the first bucket's version — if it moved, a
    /// displacement may have raced us and we restart the whole probe.
    pub fn get(&self, key: u64) -> Option<CacheItem> {
        debug_assert_ne!(key, EMPTY);
        let b1 = self.h1(key);
        let b2 = self.h2(key);
        loop {
            let (found, v1) = self.probe_bucket(b1, key);
            if found.is_some() {
                return found;
            }
            if b2 != b1 {
                let (found, _) = self.probe_bucket(b2, key);
                if found.is_some() {
                    return found;
                }
            }
            if self.buckets[b1].version.load(Ordering::Acquire) == v1 {
                return None; // no displacement raced the probe pair
            }
            // b1 changed since we scanned it — restart both probes.
        }
    }

    fn begin_write(b: &Bucket) -> u64 {
        let v = b.version.fetch_add(1, Ordering::AcqRel);
        debug_assert_eq!(v & 1, 0, "nested bucket write");
        v + 1
    }

    fn end_write(b: &Bucket) {
        b.version.fetch_add(1, Ordering::AcqRel);
    }

    /// First free slot of bucket `bi`, if any. Writer-mutex holders
    /// only (the answer is stable while the mutex is held).
    fn free_slot(&self, bi: usize) -> Option<usize> {
        let b = &self.buckets[bi];
        // LINT: relaxed-ok(stable read under the writer mutex; publication
        // to readers goes through the seqlock version word)
        (0..SLOTS).find(|&s| b.keys[s].load(Ordering::Relaxed) == EMPTY)
    }

    /// Plan a displacement path for `key` without touching the table:
    /// a sequence of `(bucket, slot)` where the occupant of `path[i]`
    /// moves to `path[i+1]` and the final slot is free. Returns None
    /// when the walk exceeds MAX_KICKS or revisits a slot (a cycle —
    /// executing it move-by-move would overwrite a live entry).
    ///
    /// Read-only simulation is sound because the caller holds
    /// `write_lock`: nothing can mutate the table mid-plan.
    fn plan_path(&self, key: u64) -> Option<Vec<(usize, usize)>> {
        let mut path: Vec<(usize, usize)> = Vec::with_capacity(8);
        let mut bi = self.h1(key);
        for kick in 0..MAX_KICKS {
            if let Some(s) = self.free_slot(bi) {
                path.push((bi, s));
                return Some(path);
            }
            let victim = kick % SLOTS;
            if path.contains(&(bi, victim)) {
                return None; // cycle
            }
            // LINT: relaxed-ok(stable read under the writer mutex; see fn doc)
            let vk = self.buckets[bi].keys[victim].load(Ordering::Relaxed);
            debug_assert_ne!(vk, EMPTY);
            path.push((bi, victim));
            bi = if self.h1(vk) == bi { self.h2(vk) } else { self.h1(vk) };
        }
        None
    }

    /// Move the occupant of `from` into the (free) slot `to`, holding
    /// BOTH bucket seqlocks for the whole move, acquired in bucket
    /// index order (one lock when the buckets coincide — `begin_write`
    /// asserts non-nesting). Readers spinning on either version see
    /// the key in exactly one bucket before the move and exactly one
    /// after; there is no in-neither window.
    fn move_slot(&self, from: (usize, usize), to: (usize, usize)) {
        let (fb, fs) = from;
        let (tb, ts) = to;
        // Stable reads: write_lock is held by the caller.
        // LINT: relaxed-ok(writer-mutex-serialized read; readers never see
        // this value except through the Release stores below)
        let k = self.buckets[fb].keys[fs].load(Ordering::Relaxed);
        debug_assert_ne!(k, EMPTY);
        // SAFETY: serialized writer.
        let it = unsafe { (*self.buckets[fb].items.get())[fs] };
        let (lo, hi) = (fb.min(tb), fb.max(tb));
        Self::begin_write(&self.buckets[lo]);
        if hi != lo {
            Self::begin_write(&self.buckets[hi]);
        }
        // SAFETY: serialized writer, both seqlocks held (odd).
        unsafe { (*self.buckets[tb].items.get())[ts] = it };
        self.buckets[tb].keys[ts].store(k, Ordering::Release);
        self.buckets[fb].keys[fs].store(EMPTY, Ordering::Release);
        if hi != lo {
            Self::end_write(&self.buckets[hi]);
        }
        Self::end_write(&self.buckets[lo]);
    }

    /// Insert or update. Returns false only when the table is at
    /// capacity (and the key is not already present).
    pub fn insert(&self, key: u64, item: CacheItem) -> bool {
        debug_assert_ne!(key, EMPTY);
        let _g = self.write_lock.lock().unwrap();

        // Update in place if present (either bucket, slot or chain).
        for &bi in &[self.h1(key), self.h2(key)] {
            let b = &self.buckets[bi];
            for s in 0..SLOTS {
                // LINT: relaxed-ok(stable read under the writer mutex)
                if b.keys[s].load(Ordering::Relaxed) == key {
                    Self::begin_write(b);
                    // SAFETY: serialized writer, seqlock held (odd).
                    unsafe { (*b.items.get())[s] = item };
                    Self::end_write(b);
                    return true;
                }
            }
            // SAFETY: serialized writer.
            let chain = unsafe { &mut *b.chain.get() };
            if let Some(e) = chain.iter_mut().find(|(k, _)| *k == key) {
                Self::begin_write(b);
                e.1 = item;
                Self::end_write(b);
                return true;
            }
        }

        if self.len.load(Ordering::Relaxed) >= self.capacity {
            return false;
        }

        // Try an empty slot in either bucket.
        for &bi in &[self.h1(key), self.h2(key)] {
            if self.try_place(bi, key, item) {
                self.len.fetch_add(1, Ordering::Relaxed);
                return true;
            }
        }

        // Cuckoo displacement, two-phase. The historical single-phase
        // loop swapped the victim OUT of its bucket and carried it in
        // hand to its alternate bucket under a separate seqlock — a
        // concurrent `get` that had already passed the destination
        // bucket saw the victim in neither (false miss). Phase 1 plans
        // the whole path read-only; phase 2 executes it BACKWARD from
        // the free slot, every hop a both-buckets-locked `move_slot`,
        // so each displaced key stays continuously reachable.
        if let Some(path) = self.plan_path(key) {
            for w in path.windows(2).rev() {
                self.move_slot(w[0], w[1]);
            }
            let (b0, s0) = path[0];
            let b = &self.buckets[b0];
            Self::begin_write(b);
            // SAFETY: serialized writer, seqlock held.
            unsafe { (*b.items.get())[s0] = item };
            b.keys[s0].store(key, Ordering::Release);
            Self::end_write(b);
            self.len.fetch_add(1, Ordering::Relaxed);
            return true;
        }

        // Chain fallback (§6.1): no displacement was executed — the
        // NEW key chains into its h1 bucket, where `get` scans for it.
        let b = &self.buckets[self.h1(key)];
        Self::begin_write(b);
        // SAFETY: serialized writer, seqlock held.
        unsafe { (*b.chain.get()).push((key, item)) };
        Self::end_write(b);
        self.chain_len.fetch_add(1, Ordering::Relaxed);
        self.len.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Place into an empty slot of bucket `bi` if one exists.
    fn try_place(&self, bi: usize, key: u64, item: CacheItem) -> bool {
        let b = &self.buckets[bi];
        for s in 0..SLOTS {
            // LINT: relaxed-ok(stable read under the writer mutex)
            if b.keys[s].load(Ordering::Relaxed) == EMPTY {
                Self::begin_write(b);
                // SAFETY: serialized writer, seqlock held.
                unsafe { (*b.items.get())[s] = item };
                b.keys[s].store(key, Ordering::Release);
                Self::end_write(b);
                return true;
            }
        }
        false
    }

    /// Remove a key (invalidate). Returns whether it existed.
    ///
    /// Taken under the writer mutex, so a removal can never interleave
    /// with an in-flight displacement of the same key — the
    /// remove-after-copy-landed resurrection is structurally excluded.
    /// Defensively, EVERY occurrence across both candidate buckets
    /// (slots and chains) is cleared rather than the first match: a
    /// duplicate, however introduced, must not outlive an invalidation
    /// — once the read-cache tier maps keys to cached bytes, a
    /// resurrected mapping is a stale read.
    pub fn remove(&self, key: u64) -> bool {
        debug_assert_ne!(key, EMPTY);
        let _g = self.write_lock.lock().unwrap();
        let b1 = self.h1(key);
        let b2 = self.h2(key);
        let n = if b2 == b1 { 1 } else { 2 };
        let mut slot_removed = 0usize;
        let mut chain_removed = 0usize;
        for &bi in &[b1, b2][..n] {
            let b = &self.buckets[bi];
            Self::begin_write(b);
            for s in 0..SLOTS {
                // LINT: relaxed-ok(stable read under the writer mutex)
                if b.keys[s].load(Ordering::Relaxed) == key {
                    b.keys[s].store(EMPTY, Ordering::Release);
                    slot_removed += 1;
                }
            }
            // SAFETY: serialized writer, seqlock held.
            let chain = unsafe { &mut *b.chain.get() };
            let before = chain.len();
            chain.retain(|(k, _)| *k != key);
            chain_removed += before - chain.len();
            Self::end_write(b);
        }
        let removed = slot_removed + chain_removed;
        if removed > 0 {
            self.chain_len.fetch_sub(chain_removed, Ordering::Relaxed);
            self.len.fetch_sub(removed, Ordering::Relaxed);
        }
        removed > 0
    }

    pub fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of buckets (the kernel's table size is `buckets * SLOTS`).
    pub fn num_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// Export the slot arrays densely for the AOT predicate kernel:
    /// `keys[b*SLOTS+s]` (EMPTY for free slots) and flattened 4-word
    /// items. Chained items are *not* exported — kernel misses on them
    /// fall back to the host path, preserving correctness.
    pub fn export_dense(&self) -> DenseTable {
        let _g = self.write_lock.lock().unwrap(); // quiesce writers
        let n = self.buckets.len() * SLOTS;
        let mut keys = vec![EMPTY; n];
        let mut items = vec![0u64; n * 4];
        for (bi, b) in self.buckets.iter().enumerate() {
            for s in 0..SLOTS {
                let k = b.keys[s].load(Ordering::Acquire);
                if k != EMPTY {
                    let flat = bi * SLOTS + s;
                    keys[flat] = k;
                    // SAFETY: writer lock held; no concurrent mutation.
                    let item = unsafe { (*b.items.get())[s] };
                    items[flat * 4] = item.a;
                    items[flat * 4 + 1] = item.b;
                    items[flat * 4 + 2] = item.c;
                    items[flat * 4 + 3] = item.d;
                }
            }
        }
        DenseTable { keys, items, buckets: self.buckets.len() }
    }

    pub fn stats(&self) -> CacheStats {
        let chain_items = self.chain_len.load(Ordering::Relaxed);
        let items = self.len();
        CacheStats {
            items,
            slot_items: items - chain_items,
            chain_items,
            buckets: self.buckets.len(),
            capacity: self.capacity,
        }
    }
}
