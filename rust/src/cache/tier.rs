//! The DPU-side read cache **tier**: cached bytes, not just a lookup
//! table.
//!
//! `CuckooCache` maps keys to 32-byte items; this module puts a sized
//! byte cache behind it. Entries are pooled [`BufView`]s keyed by
//! `(file_id, offset, len)` — the logical extent a READ was split
//! into — so a hit is served by a refcount bump on the already-pooled
//! view: zero copies, zero allocations, no `AsyncSsd` round trip.
//!
//! Layout:
//!
//! * the **index** is a `CuckooCache` (lock-free probes, serialized
//!   writers): item = `(file, offset, slot_idx, generation)`;
//! * the **arena** is a fixed array of slots, each a small mutex over
//!   an optional entry holding the cached view. The generation stamp
//!   makes an index hit self-verifying: if the slot was recycled, the
//!   generations disagree and the probe is a miss;
//! * **invalidation** is epoch-based: a fixed array of per-`(file,
//!   64 KiB region)` epoch counters. A WRITE bumps every region it
//!   overlaps; entries remember the epoch *sum* over their byte range
//!   at fill time and every probe re-sums — a bumped region makes the
//!   sums disagree, so stale bytes are unreachable the instant the
//!   invalidation lands. Region cells are hash-indexed, so two hot
//!   files can collide on a cell; a collision only widens
//!   invalidation (spurious misses), never narrows it.
//!
//! The fill path is guarded against the invalidate-before-fill race:
//! a probe miss captures the epoch sum in a [`FillTicket`], and
//! `fill` re-checks it under the fill lock — if a WRITE invalidated
//! the range while the SSD read was in flight, the fill is dropped
//! instead of pinning pre-overwrite bytes until eviction.
//!
//! Eviction is CLOCK under a byte budget: hits set a reference bit,
//! the hand clears one bit per pass and reclaims the first unset
//! entry, so a warm working set survives a zipfian scan.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::buf::BufView;
use crate::cache::{CacheItem, CuckooCache, EMPTY, H1_MUL, H2_MUL};

/// Epoch granularity: one epoch cell covers a 64 KiB file region.
const EPOCH_SHIFT: u32 = 16;
/// Epoch cells (hash-indexed by `(file, region)`); power of two.
const EPOCH_CELLS: usize = 4096;
/// Arena sizing: one slot per this many budget bytes.
const BYTES_PER_SLOT: u64 = 4096;
const MIN_SLOTS: usize = 8;
const MAX_SLOTS: usize = 8192;

/// Per-tier counters (the `hits/misses/...` row of the control plane).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierStats {
    pub hits: u64,
    pub misses: u64,
    pub fills: u64,
    /// Fills dropped because an invalidation intervened between the
    /// probe and the SSD completion (the invalidate-before-fill race).
    pub fill_drops: u64,
    pub invalidations: u64,
    pub evictions: u64,
    /// Bytes handed out by hits (each a zero-copy refcount bump).
    pub bytes_served: u64,
    /// Bytes currently pinned by cached views (warm-up gauge).
    pub bytes_cached: u64,
    /// Configured byte budget.
    pub budget_bytes: u64,
    /// Live entries.
    pub entries: u64,
}

/// Result of a probe: a zero-copy view, or a ticket that arms the
/// epoch guard for the eventual fill.
pub enum Probe {
    Hit(BufView),
    Miss(FillTicket),
}

/// Captured at probe time; `fill` drops the bytes if the epoch sum
/// moved (an invalidation ran) since the ticket was issued.
#[derive(Debug, Clone, Copy)]
pub struct FillTicket {
    file: u64,
    offset: u64,
    len: u64,
    esum: u64,
}

impl FillTicket {
    pub fn file(&self) -> u64 {
        self.file
    }
    pub fn offset(&self) -> u64 {
        self.offset
    }
    pub fn len(&self) -> u64 {
        self.len
    }
}

struct SlotEntry {
    key: u64,
    file: u64,
    offset: u64,
    /// Epoch sum over the entry's byte range at fill time.
    esum: u64,
    /// Generation stamp; must match the index item's `d` word.
    gen: u64,
    /// CLOCK reference bit: set on hit, cleared by the hand.
    ref_bit: bool,
    view: BufView,
}

/// Fill/eviction state, serialized by one mutex (the miss path; hits
/// never take it).
struct FillState {
    free: Vec<usize>,
    hand: usize,
    gen: u64,
}

/// A sized DPU-side read cache serving pooled views in front of the
/// SSD.
pub struct ReadCacheTier {
    index: CuckooCache,
    slots: Box<[Mutex<Option<SlotEntry>>]>,
    epochs: Box<[AtomicU64]>,
    fill_state: Mutex<FillState>,
    budget: u64,
    hits: AtomicU64,
    misses: AtomicU64,
    fills: AtomicU64,
    fill_drops: AtomicU64,
    invalidations: AtomicU64,
    evictions: AtomicU64,
    bytes_served: AtomicU64,
    bytes_cached: AtomicU64,
}

impl ReadCacheTier {
    /// A tier holding at most `budget_bytes` of cached views.
    pub fn new(budget_bytes: u64) -> Self {
        let nslots =
            ((budget_bytes / BYTES_PER_SLOT) as usize).clamp(MIN_SLOTS, MAX_SLOTS);
        let slots = (0..nslots)
            .map(|_| Mutex::new(None))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        let epochs = (0..EPOCH_CELLS)
            .map(|_| AtomicU64::new(0))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        ReadCacheTier {
            // 2x headroom: the index should never be the reason a
            // fill fails before the byte budget is.
            index: CuckooCache::new(nslots * 2),
            slots,
            epochs,
            fill_state: Mutex::new(FillState {
                // Reversed so pop() hands out slot 0 first (the CLOCK
                // hand also starts at 0 — keeps eviction order
                // deterministic for the tests).
                free: (0..nslots).rev().collect(),
                hand: 0,
                gen: 0,
            }),
            budget: budget_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            fills: AtomicU64::new(0),
            fill_drops: AtomicU64::new(0),
            invalidations: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            bytes_served: AtomicU64::new(0),
            bytes_cached: AtomicU64::new(0),
        }
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget
    }

    fn key_hash(file: u64, offset: u64, len: u64) -> u64 {
        // splitmix64 finalizer over the mixed triple.
        let mut x =
            file.wrapping_mul(H1_MUL) ^ offset.rotate_left(21) ^ len.rotate_left(42);
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
        if x == EMPTY {
            0x1EA7_CAFE_F00D_D00D
        } else {
            x
        }
    }

    #[inline]
    fn epoch_cell(file: u64, region: u64) -> usize {
        let x = file.wrapping_mul(H1_MUL) ^ region.wrapping_mul(H2_MUL);
        (x >> 17) as usize & (EPOCH_CELLS - 1)
    }

    /// Sum of the epoch counters covering `[offset, offset+len)` of
    /// `file`. Counters only grow, so equal sums ⇔ no region in the
    /// range was invalidated in between.
    fn epoch_sum(&self, file: u64, offset: u64, len: u64) -> u64 {
        let lo = offset >> EPOCH_SHIFT;
        let hi = if len == 0 {
            lo
        } else {
            (offset + len - 1) >> EPOCH_SHIFT
        };
        let mut sum = 0u64;
        for region in lo..=hi {
            sum = sum.wrapping_add(
                self.epochs[Self::epoch_cell(file, region)].load(Ordering::SeqCst),
            );
        }
        sum
    }

    /// Look up the cached view for `(file, offset, len)`. A hit is a
    /// refcount bump on the stored view — zero copies, zero
    /// allocations. A miss returns the ticket that a later `fill`
    /// must present.
    pub fn probe(&self, file: u64, offset: u64, len: u64) -> Probe {
        let key = Self::key_hash(file, offset, len);
        let esum = self.epoch_sum(file, offset, len);
        if let Some(item) = self.index.get(key) {
            let si = item.c as usize;
            if si < self.slots.len() {
                let mut g = self.slots[si].lock().unwrap();
                if let Some(e) = g.as_mut() {
                    if e.gen == item.d
                        && e.key == key
                        && e.file == file
                        && e.offset == offset
                        && e.view.len() as u64 == len
                        && e.esum == esum
                    {
                        e.ref_bit = true;
                        let view = e.view.clone();
                        drop(g);
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        self.bytes_served.fetch_add(len, Ordering::Relaxed);
                        return Probe::Hit(view);
                    }
                }
                // Generation/epoch mismatch: a recycled slot or stale
                // bytes. Fall through to a miss; the stale entry stays
                // unreachable and the CLOCK hand reclaims it.
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        Probe::Miss(FillTicket { file, offset, len, esum })
    }

    /// Install the SSD completion's view under the ticket taken at
    /// probe time. Returns false when the fill was dropped: an
    /// invalidation intervened (the stale-fill guard), the view
    /// doesn't span the ticketed range, or no room could be made.
    pub fn fill(&self, ticket: &FillTicket, view: &BufView) -> bool {
        let len = view.len() as u64;
        if len != ticket.len || len == 0 || len > self.budget {
            return false;
        }
        let mut st = self.fill_state.lock().unwrap();
        // The invalidate-before-fill guard: if a WRITE bumped any
        // epoch in the range after the probe, these bytes predate the
        // overwrite — installing them would pin a stale read.
        if self.epoch_sum(ticket.file, ticket.offset, ticket.len) != ticket.esum {
            self.fill_drops.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let key = Self::key_hash(ticket.file, ticket.offset, ticket.len);
        // Re-fill of a key whose old entry went stale: reclaim the old
        // slot first so one key never pins two views.
        if let Some(item) = self.index.get(key) {
            let si = item.c as usize;
            if si < self.slots.len() {
                let mut g = self.slots[si].lock().unwrap();
                if let Some(e) = g.as_ref() {
                    if e.gen == item.d && e.key == key {
                        let old = g.take().unwrap();
                        self.bytes_cached
                            .fetch_sub(old.view.len() as u64, Ordering::Relaxed);
                        st.free.push(si);
                    }
                }
            }
            self.index.remove(key);
        }
        // Make room: a free arena slot AND headroom under the budget.
        while st.free.is_empty()
            || self.bytes_cached.load(Ordering::Relaxed) + len > self.budget
        {
            if !self.evict_one(&mut st) {
                return false; // arena empty yet no room — oversized view
            }
        }
        let si = st.free.pop().unwrap();
        st.gen += 1;
        let gen = st.gen;
        *self.slots[si].lock().unwrap() = Some(SlotEntry {
            key,
            file: ticket.file,
            offset: ticket.offset,
            esum: ticket.esum,
            gen,
            ref_bit: false,
            view: view.clone(),
        });
        if !self.index.insert(key, CacheItem::new(ticket.file, ticket.offset, si as u64, gen)) {
            // Index at capacity (2x arena — effectively unreachable).
            *self.slots[si].lock().unwrap() = None;
            st.free.push(si);
            return false;
        }
        self.bytes_cached.fetch_add(len, Ordering::Relaxed);
        self.fills.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// CLOCK sweep: clear one reference bit per occupied slot, evict
    /// the first entry found with the bit unset. Caller holds the fill
    /// lock, so the index check-then-remove below is atomic with
    /// respect to every index writer.
    fn evict_one(&self, st: &mut FillState) -> bool {
        let n = self.slots.len();
        for _ in 0..2 * n {
            let si = st.hand;
            st.hand = (st.hand + 1) % n;
            let mut g = self.slots[si].lock().unwrap();
            match g.as_mut() {
                None => continue,
                Some(e) if e.ref_bit => {
                    e.ref_bit = false; // second chance
                }
                Some(_) => {
                    let e = g.take().unwrap();
                    drop(g);
                    self.index.remove(e.key);
                    self.bytes_cached
                        .fetch_sub(e.view.len() as u64, Ordering::Relaxed);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                    st.free.push(si);
                    return true;
                }
            }
        }
        false
    }

    /// Invalidate every cached byte overlapping `[offset, offset+len)`
    /// of `file`. Called at the WRITE apply point (non-durable) and
    /// the remap commit point (durable) — after this returns, no probe
    /// can serve pre-overwrite bytes and no in-flight fill ticketed
    /// before it can install them.
    pub fn invalidate(&self, file: u64, offset: u64, len: u64) {
        let lo = offset >> EPOCH_SHIFT;
        let hi = if len == 0 {
            lo
        } else {
            (offset + len - 1) >> EPOCH_SHIFT
        };
        for region in lo..=hi {
            self.epochs[Self::epoch_cell(file, region)].fetch_add(1, Ordering::SeqCst);
        }
        self.invalidations.fetch_add(1, Ordering::Relaxed);
    }

    /// Drop every cached view (remount/shutdown path; also lets leak
    /// checks assert the pools drain once intentional pins are gone).
    pub fn clear(&self) {
        let mut st = self.fill_state.lock().unwrap();
        for slot in self.slots.iter() {
            let mut g = slot.lock().unwrap();
            if let Some(e) = g.take() {
                self.index.remove(e.key);
                self.bytes_cached
                    .fetch_sub(e.view.len() as u64, Ordering::Relaxed);
            }
        }
        st.free = (0..self.slots.len()).rev().collect();
        st.hand = 0;
    }

    /// Fraction of the byte budget currently warm (0.0 cold → 1.0).
    pub fn warm_fraction(&self) -> f64 {
        if self.budget == 0 {
            return 0.0;
        }
        self.bytes_cached.load(Ordering::Relaxed) as f64 / self.budget as f64
    }

    pub fn stats(&self) -> TierStats {
        TierStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            fills: self.fills.load(Ordering::Relaxed),
            fill_drops: self.fill_drops.load(Ordering::Relaxed),
            invalidations: self.invalidations.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            bytes_served: self.bytes_served.load(Ordering::Relaxed),
            bytes_cached: self.bytes_cached.load(Ordering::Relaxed),
            budget_bytes: self.budget,
            entries: self.index.len() as u64,
        }
    }
}

/// Exhaustive model check of the probe/fill/invalidate epoch ticket
/// (correctness plane; see DESIGN.md). `MiniTier` is a colocated
/// SKELETON of [`CacheTier`]'s coherence protocol: a SeqCst epoch
/// counter, a mutex-guarded device, and one mutex-guarded slot — the
/// hash index, CLOCK arena, and budget machinery are orthogonal to the
/// ordering claim and elided. The claim: because the writer commits
/// device bytes STRICTLY BEFORE bumping the epoch, a fill whose ticket
/// still matches at install time can only carry fresh bytes, so a hit
/// (entry esum == current esum) never serves pre-overwrite data. Run
/// with `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_`.
#[cfg(all(loom, test))]
mod loom_models {
    use loom::sync::atomic::{AtomicU64, Ordering};
    use loom::sync::Mutex;
    use std::sync::Arc;

    const OLD: u64 = 1;
    const NEW: u64 = 7;

    struct MiniTier {
        /// The invalidation epoch (`CacheTier::epochs`, one cell).
        epoch: AtomicU64,
        /// The device — `Ssd` serializes access internally, so a
        /// mutex is the faithful model.
        device: Mutex<u64>,
        /// One cache slot: `(esum, bytes)` — slots are mutex-guarded
        /// in the real tier too.
        slot: Mutex<Option<(u64, u64)>>,
    }

    impl MiniTier {
        fn new() -> Arc<Self> {
            Arc::new(MiniTier {
                epoch: AtomicU64::new(0),
                device: Mutex::new(OLD),
                slot: Mutex::new(None),
            })
        }

        /// WRITE apply: commit to the device, THEN invalidate. The
        /// order is the protocol — `invalidate`'s contract is "after
        /// this returns ... no in-flight fill ticketed before it can
        /// install" pre-overwrite bytes.
        fn write_commit_then_bump(&self) {
            *self.device.lock().unwrap() = NEW;
            self.epoch.fetch_add(1, Ordering::SeqCst);
        }

        /// MUTATION: bump first, commit after — opens the window where
        /// a fill ticketed AFTER the bump reads pre-overwrite bytes
        /// yet passes the staleness re-check.
        fn write_bump_then_commit(&self) {
            self.epoch.fetch_add(1, Ordering::SeqCst);
            *self.device.lock().unwrap() = NEW;
        }

        /// Miss path: take a ticket, read the device, install iff the
        /// epoch is unchanged (`CacheTier::fill`'s stale-fill guard).
        fn probe_miss_and_fill(&self) {
            let esum = self.epoch.load(Ordering::SeqCst);
            let bytes = *self.device.lock().unwrap();
            let mut s = self.slot.lock().unwrap();
            if self.epoch.load(Ordering::SeqCst) == esum {
                *s = Some((esum, bytes));
            }
        }

        /// Probe: a hit requires the entry's esum to match the CURRENT
        /// epoch sum — stale entries fall through to a miss.
        fn probe(&self) -> Option<u64> {
            let esum = self.epoch.load(Ordering::SeqCst);
            (*self.slot.lock().unwrap())
                .and_then(|(e, b)| if e == esum { Some(b) } else { None })
        }
    }

    fn race_fill_against(write: fn(&MiniTier)) {
        loom::model(move || {
            let tier = MiniTier::new();
            let filler = {
                let tier = tier.clone();
                loom::thread::spawn(move || tier.probe_miss_and_fill())
            };
            let writer = {
                let tier = tier.clone();
                loom::thread::spawn(move || write(&tier))
            };
            filler.join().unwrap();
            writer.join().unwrap();
            // The coherence claim, checked on every interleaving: a
            // post-write hit may only serve the overwrite's bytes.
            if let Some(bytes) = tier.probe() {
                assert_eq!(bytes, NEW, "hit served pre-overwrite bytes");
            }
        });
    }

    /// Protocol 5 — commit-then-bump is coherent under every
    /// fill/invalidate interleaving.
    #[test]
    fn loom_tier_hit_implies_fresh_bytes() {
        race_fill_against(MiniTier::write_commit_then_bump);
    }

    /// Mutation self-test: flip the writer's program order and there
    /// is an interleaving where the filler tickets AFTER the bump,
    /// reads the device BEFORE the commit, passes the re-check, and
    /// installs stale bytes that then hit. loom must find it and
    /// panic; if this stops panicking, the model has gone vacuous.
    #[test]
    #[should_panic]
    fn loom_tier_mutation_bump_before_commit_serves_stale() {
        race_fill_against(MiniTier::write_bump_then_commit);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buf::BufPool;

    fn pooled_view(pool: &BufPool, len: usize, fill: u8) -> BufView {
        let mut b = pool.allocate(len);
        b.as_mut_slice().fill(fill);
        b.freeze()
    }

    #[test]
    fn fill_then_hit_is_zero_copy() {
        let pool = BufPool::new(8, 4096);
        let tier = ReadCacheTier::new(64 * 1024);
        let view = pooled_view(&pool, 512, 7);
        let ticket = match tier.probe(1, 0, 512) {
            Probe::Miss(t) => t,
            Probe::Hit(_) => panic!("cold tier cannot hit"),
        };
        assert!(tier.fill(&ticket, &view));
        let before = pool.stats();
        let hit = match tier.probe(1, 0, 512) {
            Probe::Hit(v) => v,
            Probe::Miss(_) => panic!("filled key must hit"),
        };
        let after = pool.stats();
        // The hit is a refcount bump on the pooled storage: no new
        // allocations, no copies.
        assert!(hit.shares_storage(&view));
        assert_eq!(hit.as_slice(), &[7u8; 512][..]);
        assert_eq!(after.allocs, before.allocs);
        assert_eq!(after.copies, before.copies);
        assert_eq!(after.bytes_copied, before.bytes_copied);
        let s = tier.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.fills, 1);
        assert_eq!(s.bytes_served, 512);
    }

    #[test]
    fn invalidation_blocks_stale_hit() {
        let pool = BufPool::new(8, 4096);
        let tier = ReadCacheTier::new(64 * 1024);
        let view = pooled_view(&pool, 256, 1);
        let t = match tier.probe(3, 1024, 256) {
            Probe::Miss(t) => t,
            _ => panic!(),
        };
        assert!(tier.fill(&t, &view));
        assert!(matches!(tier.probe(3, 1024, 256), Probe::Hit(_)));
        // Overlapping WRITE invalidates; the next probe must miss.
        tier.invalidate(3, 1100, 64);
        assert!(matches!(tier.probe(3, 1024, 256), Probe::Miss(_)));
        assert_eq!(tier.stats().invalidations, 1);
    }

    /// Satellite regression: the invalidate-before-fill interleaving.
    /// probe(miss) → WRITE invalidates → SSD read completes → fill.
    /// The fill must be dropped, and the subsequent probe must miss.
    #[test]
    fn invalidate_between_probe_and_fill_drops_the_fill() {
        let pool = BufPool::new(8, 4096);
        let tier = ReadCacheTier::new(64 * 1024);
        let stale = pooled_view(&pool, 128, 0xAA);
        let t = match tier.probe(9, 0, 128) {
            Probe::Miss(t) => t,
            _ => panic!(),
        };
        tier.invalidate(9, 0, 128); // WRITE landed while the read was in flight
        assert!(!tier.fill(&t, &stale), "stale fill must be dropped");
        assert_eq!(tier.stats().fill_drops, 1);
        assert_eq!(tier.stats().fills, 0);
        assert!(matches!(tier.probe(9, 0, 128), Probe::Miss(_)));
        // A fresh probe→fill cycle (post-invalidate epoch) installs fine.
        let fresh = pooled_view(&pool, 128, 0xBB);
        let t2 = match tier.probe(9, 0, 128) {
            Probe::Miss(t) => t,
            _ => panic!(),
        };
        assert!(tier.fill(&t2, &fresh));
        match tier.probe(9, 0, 128) {
            Probe::Hit(v) => assert_eq!(v.as_slice(), &[0xBBu8; 128][..]),
            Probe::Miss(_) => panic!("fresh fill must hit"),
        }
    }

    #[test]
    fn eviction_keeps_bytes_under_budget() {
        let pool = BufPool::new(64, 4096);
        // Budget fits exactly 4 one-KiB views.
        let tier = ReadCacheTier::new(4 * 1024);
        for i in 0..16u64 {
            let v = pooled_view(&pool, 1024, i as u8);
            let t = match tier.probe(1, i * 1024, 1024) {
                Probe::Miss(t) => t,
                _ => panic!(),
            };
            assert!(tier.fill(&t, &v));
            assert!(tier.stats().bytes_cached <= 4 * 1024);
        }
        let s = tier.stats();
        assert_eq!(s.fills, 16);
        assert_eq!(s.evictions, 12);
        assert_eq!(s.entries, 4);
        assert!((tier.warm_fraction() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn clock_gives_hit_entries_a_second_chance() {
        let pool = BufPool::new(64, 4096);
        // Budget fits exactly two entries; arena floor is 8 slots.
        let tier = ReadCacheTier::new(2 * 1024);
        let fill_at = |off: u64, pat: u8| {
            let v = pooled_view(&pool, 1024, pat);
            match tier.probe(1, off, 1024) {
                Probe::Miss(t) => assert!(tier.fill(&t, &v)),
                _ => panic!("expected cold miss at {off}"),
            }
        };
        fill_at(0, 1); // slot 0
        fill_at(1024, 2); // slot 1
        // Touch entry A: its ref bit shields it from the next sweep.
        assert!(matches!(tier.probe(1, 0, 1024), Probe::Hit(_)));
        fill_at(2048, 3); // forces one eviction: B (no ref bit) goes
        assert!(matches!(tier.probe(1, 0, 1024), Probe::Hit(_)), "A survives");
        assert!(matches!(tier.probe(1, 1024, 1024), Probe::Miss(_)), "B evicted");
    }

    #[test]
    fn clear_drops_all_views_and_releases_pool_slots() {
        let pool = BufPool::new(8, 4096);
        let tier = ReadCacheTier::new(64 * 1024);
        for i in 0..4u64 {
            let v = pooled_view(&pool, 512, i as u8);
            match tier.probe(2, i * 512, 512) {
                Probe::Miss(t) => assert!(tier.fill(&t, &v)),
                _ => panic!(),
            }
        }
        assert!(pool.in_use() > 0);
        tier.clear();
        assert_eq!(tier.stats().entries, 0);
        assert_eq!(tier.stats().bytes_cached, 0);
        assert_eq!(pool.in_use(), 0, "cleared tier must release every pooled view");
        assert!(matches!(tier.probe(2, 0, 512), Probe::Miss(_)));
    }
}
