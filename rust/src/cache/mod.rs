//! The DPU cache table (§6.1).
//!
//! An in-memory hash table on the DPU that user offload logic populates
//! via *cache-on-write* and prunes via *invalidate-on-read*. Design
//! constraints from Table 2: the single writer (the file service) needs
//! millions of insertions/s; readers (offload engine and traffic
//! director) need tens of millions of lookups/s and must never block the
//! packet path. Hence (§6.1):
//!
//! * **cuckoo hashing** — two candidate buckets per key give worst-case
//!   constant lookup time;
//! * **chained buckets** — an overflow chain per bucket absorbs insert
//!   collisions instead of failing or resizing;
//! * **fixed capacity** — the user supplies the item budget up front so
//!   DPU memory is reserved once and the table never resizes at runtime.
//!
//! Concurrency: readers are lock-free (per-bucket seqlock); writers
//! serialize on a single mutex, which matches the paper's single-writer
//! (file service) usage.

mod table;

pub use table::{
    CacheItem, CacheStats, CuckooCache, DenseTable, EMPTY, H1_MUL, H1_SHIFT, H2_MUL, H2_SHIFT,
    H2_XOR_SHIFT, SLOTS,
};

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn insert_get_remove() {
        let t = CuckooCache::new(1024);
        let item = CacheItem::new(100, 7, 4096, 8192);
        assert!(t.insert(42, item));
        assert_eq!(t.get(42), Some(item));
        assert_eq!(t.len(), 1);
        assert!(t.remove(42));
        assert_eq!(t.get(42), None);
        assert!(!t.remove(42));
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn update_in_place() {
        let t = CuckooCache::new(64);
        t.insert(1, CacheItem::new(1, 0, 0, 0));
        t.insert(1, CacheItem::new(2, 0, 0, 0));
        assert_eq!(t.get(1).unwrap().a, 2);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn fills_to_capacity_with_chains() {
        // Insert far more colliding keys than slot space per bucket —
        // chains must absorb them all (up to the configured capacity).
        let cap = 4096;
        let t = CuckooCache::new(cap);
        let mut inserted = 0;
        for k in 0..cap as u64 {
            if t.insert(k, CacheItem::new(k, 0, 0, 0)) {
                inserted += 1;
            }
        }
        assert_eq!(inserted, cap);
        for k in 0..cap as u64 {
            assert_eq!(t.get(k).map(|i| i.a), Some(k), "lost key {k}");
        }
    }

    #[test]
    fn capacity_is_enforced() {
        let t = CuckooCache::new(128);
        let mut n = 0u64;
        while t.insert(n, CacheItem::new(n, 0, 0, 0)) {
            n += 1;
            assert!(n < 10_000, "capacity never enforced");
        }
        assert!(n >= 128, "rejected before reaching capacity: {n}");
        // Removing one admits one more.
        assert!(t.remove(0));
        assert!(t.insert(999_999, CacheItem::new(1, 0, 0, 0)));
    }

    #[test]
    fn concurrent_readers_see_consistent_items() {
        // Writers mutate (k, v) pairs where v encodes k; readers must
        // never observe a torn item.
        let t = Arc::new(CuckooCache::new(1 << 14));
        for k in 0..1000u64 {
            t.insert(k, CacheItem::new(k, k + 1, k + 2, k + 3));
        }
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let writer = {
            let t = t.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut round = 1u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    for k in 0..1000u64 {
                        let base = k.wrapping_mul(round);
                        t.insert(k, CacheItem::new(base, base + 1, base + 2, base + 3));
                    }
                    round += 1;
                }
            })
        };
        let mut readers = Vec::new();
        for _ in 0..4 {
            let t = t.clone();
            let stop = stop.clone();
            readers.push(std::thread::spawn(move || {
                let mut checks = 0u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    for k in 0..1000u64 {
                        if let Some(item) = t.get(k) {
                            assert_eq!(item.b, item.a + 1, "torn read");
                            assert_eq!(item.c, item.a + 2, "torn read");
                            assert_eq!(item.d, item.a + 3, "torn read");
                            checks += 1;
                        }
                    }
                }
                checks
            }));
        }
        std::thread::sleep(std::time::Duration::from_millis(300));
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        writer.join().unwrap();
        for r in readers {
            assert!(r.join().unwrap() > 0);
        }
    }

    #[test]
    fn stats_reflect_chain_usage() {
        let t = CuckooCache::new(1 << 12);
        for k in 0..(1 << 12) as u64 {
            t.insert(k, CacheItem::new(k, 0, 0, 0));
        }
        let s = t.stats();
        assert_eq!(s.items, 1 << 12);
        // At ~50% of bucket-slot capacity most items sit in slots.
        assert!(s.slot_items > s.chain_items);
    }
}
