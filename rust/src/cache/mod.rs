//! The DPU cache table (§6.1).
//!
//! An in-memory hash table on the DPU that user offload logic populates
//! via *cache-on-write* and prunes via *invalidate-on-read*. Design
//! constraints from Table 2: the single writer (the file service) needs
//! millions of insertions/s; readers (offload engine and traffic
//! director) need tens of millions of lookups/s and must never block the
//! packet path. Hence (§6.1):
//!
//! * **cuckoo hashing** — two candidate buckets per key give worst-case
//!   constant lookup time;
//! * **chained buckets** — an overflow chain per bucket absorbs insert
//!   collisions instead of failing or resizing;
//! * **fixed capacity** — the user supplies the item budget up front so
//!   DPU memory is reserved once and the table never resizes at runtime.
//!
//! Concurrency: readers are lock-free (per-bucket seqlock); writers
//! serialize on a single mutex, which matches the paper's single-writer
//! (file service) usage.

mod table;
mod tier;

pub use table::{
    CacheItem, CacheStats, CuckooCache, DenseTable, EMPTY, H1_MUL, H1_SHIFT, H2_MUL, H2_SHIFT,
    H2_XOR_SHIFT, SLOTS,
};
pub use tier::{FillTicket, Probe, ReadCacheTier, TierStats};

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn insert_get_remove() {
        let t = CuckooCache::new(1024);
        let item = CacheItem::new(100, 7, 4096, 8192);
        assert!(t.insert(42, item));
        assert_eq!(t.get(42), Some(item));
        assert_eq!(t.len(), 1);
        assert!(t.remove(42));
        assert_eq!(t.get(42), None);
        assert!(!t.remove(42));
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn update_in_place() {
        let t = CuckooCache::new(64);
        t.insert(1, CacheItem::new(1, 0, 0, 0));
        t.insert(1, CacheItem::new(2, 0, 0, 0));
        assert_eq!(t.get(1).unwrap().a, 2);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn fills_to_capacity_with_chains() {
        // Insert far more colliding keys than slot space per bucket —
        // chains must absorb them all (up to the configured capacity).
        let cap = 4096;
        let t = CuckooCache::new(cap);
        let mut inserted = 0;
        for k in 0..cap as u64 {
            if t.insert(k, CacheItem::new(k, 0, 0, 0)) {
                inserted += 1;
            }
        }
        assert_eq!(inserted, cap);
        for k in 0..cap as u64 {
            assert_eq!(t.get(k).map(|i| i.a), Some(k), "lost key {k}");
        }
    }

    #[test]
    fn capacity_is_enforced() {
        let t = CuckooCache::new(128);
        let mut n = 0u64;
        while t.insert(n, CacheItem::new(n, 0, 0, 0)) {
            n += 1;
            assert!(n < 10_000, "capacity never enforced");
        }
        assert!(n >= 128, "rejected before reaching capacity: {n}");
        // Removing one admits one more.
        assert!(t.remove(0));
        assert!(t.insert(999_999, CacheItem::new(1, 0, 0, 0)));
    }

    #[test]
    fn concurrent_readers_see_consistent_items() {
        // Writers mutate (k, v) pairs where v encodes k; readers must
        // never observe a torn item.
        let t = Arc::new(CuckooCache::new(1 << 14));
        // Shrunk under Miri (interpreter overhead): the seqlock torn-read
        // window is per-key, so fewer keys and a shorter run keep the
        // shape while the UB check stays tractable.
        let keys = if cfg!(miri) { 64u64 } else { 1000u64 };
        for k in 0..keys {
            t.insert(k, CacheItem::new(k, k + 1, k + 2, k + 3));
        }
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let writer = {
            let t = t.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut round = 1u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    for k in 0..keys {
                        let base = k.wrapping_mul(round);
                        t.insert(k, CacheItem::new(base, base + 1, base + 2, base + 3));
                    }
                    round += 1;
                }
            })
        };
        let mut readers = Vec::new();
        for _ in 0..4 {
            let t = t.clone();
            let stop = stop.clone();
            readers.push(std::thread::spawn(move || {
                let mut checks = 0u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    for k in 0..keys {
                        if let Some(item) = t.get(k) {
                            assert_eq!(item.b, item.a + 1, "torn read");
                            assert_eq!(item.c, item.a + 2, "torn read");
                            assert_eq!(item.d, item.a + 3, "torn read");
                            checks += 1;
                        }
                    }
                }
                checks
            }));
        }
        let run = if cfg!(miri) { 50 } else { 300 };
        std::thread::sleep(std::time::Duration::from_millis(run));
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        writer.join().unwrap();
        for r in readers {
            assert!(r.join().unwrap() > 0);
        }
    }

    /// Seeded churn against a near-capacity table: every insert of a
    /// fresh key has a real chance of displacing a resident along its
    /// cuckoo path. Readers hammer the residents the whole time — a
    /// present key observed in *neither* bucket (the historical
    /// victim-in-hand window, or the probe-order race the reader-side
    /// restart covers) trips the assert.
    #[test]
    fn get_during_kick_never_false_misses() {
        use std::collections::VecDeque;
        use std::sync::atomic::{AtomicBool, Ordering};

        let t = Arc::new(CuckooCache::new(256));
        let resident: Vec<u64> = (1..=128).collect();
        for &k in &resident {
            assert!(t.insert(k, CacheItem::new(k, k, k, k)));
        }
        let stop = Arc::new(AtomicBool::new(false));
        let writer = {
            let t = t.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                // Deterministic key stream (LCG, fixed seed).
                let mut s = 0x00C0_FFEE_u64;
                let mut live: VecDeque<u64> = VecDeque::new();
                let mut kicks_possible = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    s = s
                        .wrapping_mul(6364136223846793005)
                        .wrapping_add(1442695040888963407);
                    let k = 1_000 + (s >> 16) % 1_000_000;
                    if t.insert(k, CacheItem::new(k, k, k, k)) {
                        live.push_back(k);
                        kicks_possible += 1;
                    }
                    // Churn window keeps the table near capacity (max
                    // displacement pressure) without pinning it there.
                    while live.len() > 100 {
                        t.remove(live.pop_front().unwrap());
                    }
                }
                kicks_possible
            })
        };
        let mut readers = Vec::new();
        for _ in 0..3 {
            let t = t.clone();
            let stop = stop.clone();
            let resident = resident.clone();
            readers.push(std::thread::spawn(move || {
                let mut gets = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    for &k in &resident {
                        let got = t.get(k);
                        assert!(
                            got.is_some(),
                            "false miss: resident key {k} vanished mid-displacement"
                        );
                        assert_eq!(got.unwrap().a, k, "wrong item for key {k}");
                        gets += 1;
                    }
                }
                gets
            }));
        }
        let run = if cfg!(miri) { 50 } else { 300 };
        std::thread::sleep(std::time::Duration::from_millis(run));
        stop.store(true, Ordering::Relaxed);
        assert!(writer.join().unwrap() > 0);
        for r in readers {
            assert!(r.join().unwrap() > 0);
        }
    }

    /// Invalidate racing displacement-heavy churn: once `remove(k)`
    /// returns, no later lookup may see k again (nothing reinserts
    /// these keys). A resurrected mapping here is exactly the
    /// stale-read bug the cache tier cannot tolerate.
    #[test]
    fn invalidate_during_kick_stays_removed() {
        use std::collections::VecDeque;
        use std::sync::atomic::{AtomicBool, Ordering};

        let t = Arc::new(CuckooCache::new(256));
        let stop = Arc::new(AtomicBool::new(false));
        // Kick pressure: same churn recipe as above, disjoint key range.
        let writer = {
            let t = t.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut s = 0xDEAD_BEEF_u64;
                let mut live: VecDeque<u64> = VecDeque::new();
                while !stop.load(Ordering::Relaxed) {
                    s = s
                        .wrapping_mul(6364136223846793005)
                        .wrapping_add(1442695040888963407);
                    let k = 1_000 + (s >> 16) % 1_000_000;
                    if t.insert(k, CacheItem::new(k, k, k, k)) {
                        live.push_back(k);
                    }
                    while live.len() > 100 {
                        t.remove(live.pop_front().unwrap());
                    }
                }
            })
        };
        // Invalidator: insert a key from a disjoint range, remove it,
        // and verify it STAYS gone while displacements rage on.
        let mut dead: Vec<u64> = Vec::new();
        {
            let base = 10_000_000u64;
            // Shrunk under Miri: each round is one full
            // insert→remove→verify cycle; 100 cycles still cross many
            // displacement windows.
            let rounds = if cfg!(miri) { 100u64 } else { 2_000u64 };
            for i in 0..rounds {
                let k = base + i;
                assert!(t.insert(k, CacheItem::new(k, k, k, k)));
                // Let the churn writer interleave a few ops.
                std::thread::yield_now();
                assert!(t.remove(k));
                assert!(
                    t.get(k).is_none(),
                    "invalidated key {k} resurrected by a concurrent displacement"
                );
                dead.push(k);
            }
        }
        stop.store(true, Ordering::Relaxed);
        writer.join().unwrap();
        for k in dead {
            assert!(t.get(k).is_none(), "key {k} came back after the dust settled");
        }
    }

    #[test]
    fn stats_reflect_chain_usage() {
        let t = CuckooCache::new(1 << 12);
        for k in 0..(1 << 12) as u64 {
            t.insert(k, CacheItem::new(k, 0, 0, 0));
        }
        let s = t.stats();
        assert_eq!(s.items, 1 << 12);
        // At ~50% of bucket-slot capacity most items sit in slots.
        assert!(s.slot_items > s.chain_items);
    }
}
