//! Per-pump CPU accounting — the functional plane's Fig 14 axis.
//!
//! The paper's second headline (next to latency) is CPU: DDS "saves up
//! to tens of CPU cores per storage server" because its pumps do not
//! burn a core when there is nothing to do. Every pump in this
//! reproduction (the file-service loop, each shard loop) owns one
//! [`CpuLedger`] its [`crate::idle::IdleGovernor`] writes, so the
//! poll-vs-park economics are observable instead of anecdotal:
//!
//! * `iterations` / `productive` / `empty_polls` — how often the pump
//!   ran and how often that was for nothing;
//! * `parks` / `wakes` — how often it gave the core back, and how many
//!   of those sleeps ended because a doorbell rang (vs the bounded
//!   backoff expiring);
//! * `busy_ns` / `parked_ns` — the wall-time split the busy-fraction is
//!   computed from. A pump under `IdlePolicy::Poll` never parks and is
//!   100% busy by definition; an idle pump under `Adaptive` should sit
//!   in the low single digits.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Point-in-time snapshot of one pump's [`CpuLedger`] (all counters
/// monotonic; subtract two snapshots with [`CpuStats::since`] to meter
/// a window).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CpuStats {
    /// Pump loop iterations.
    pub iterations: u64,
    /// Iterations that found work.
    pub productive: u64,
    /// Iterations that found nothing.
    pub empty_polls: u64,
    /// Times the pump blocked (doorbell wait, channel recv, or a
    /// bounded nap).
    pub parks: u64,
    /// Parks that ended with a wake signal (doorbell ring / channel
    /// send) rather than the bounded backoff expiring.
    pub wakes: u64,
    /// Wall time attributed to running — spinning, yielding, or doing
    /// work — in nanoseconds.
    pub busy_ns: u64,
    /// Wall time spent parked, in nanoseconds.
    pub parked_ns: u64,
}

impl CpuStats {
    /// Fraction of wall time spent running rather than parked. A pump
    /// that has never parked is 100% busy by definition (that is the
    /// polling discipline's cost).
    pub fn busy_fraction(&self) -> f64 {
        let total = self.busy_ns + self.parked_ns;
        if total == 0 {
            return 1.0;
        }
        self.busy_ns as f64 / total as f64
    }

    /// Counter deltas since an earlier snapshot (window metering).
    pub fn since(&self, earlier: &CpuStats) -> CpuStats {
        CpuStats {
            iterations: self.iterations.saturating_sub(earlier.iterations),
            productive: self.productive.saturating_sub(earlier.productive),
            empty_polls: self.empty_polls.saturating_sub(earlier.empty_polls),
            parks: self.parks.saturating_sub(earlier.parks),
            wakes: self.wakes.saturating_sub(earlier.wakes),
            busy_ns: self.busy_ns.saturating_sub(earlier.busy_ns),
            parked_ns: self.parked_ns.saturating_sub(earlier.parked_ns),
        }
    }
}

/// Lock-free counters one pump writes and anyone may snapshot (shared
/// as `Arc<CpuLedger>`; the writer is the pump's governor, readers are
/// stats queries and the bench emitters).
#[derive(Default)]
pub struct CpuLedger {
    iterations: AtomicU64,
    productive: AtomicU64,
    empty_polls: AtomicU64,
    parks: AtomicU64,
    wakes: AtomicU64,
    busy_ns: AtomicU64,
    parked_ns: AtomicU64,
}

impl CpuLedger {
    pub fn new() -> Arc<CpuLedger> {
        Arc::new(CpuLedger::default())
    }

    /// Account one pump iteration.
    pub fn iteration(&self, productive: bool) {
        self.iterations.fetch_add(1, Ordering::Relaxed);
        if productive {
            self.productive.fetch_add(1, Ordering::Relaxed);
        } else {
            self.empty_polls.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Attribute a stretch of wall time to running.
    pub fn add_busy(&self, d: Duration) {
        self.busy_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Account one park: how long the pump was blocked and whether a
    /// wake signal (not the backoff timeout) ended it.
    pub fn park(&self, parked: Duration, woke: bool) {
        self.parks.fetch_add(1, Ordering::Relaxed);
        if woke {
            self.wakes.fetch_add(1, Ordering::Relaxed);
        }
        self.parked_ns.fetch_add(parked.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CpuStats {
        CpuStats {
            iterations: self.iterations.load(Ordering::Relaxed),
            productive: self.productive.load(Ordering::Relaxed),
            empty_polls: self.empty_polls.load(Ordering::Relaxed),
            parks: self.parks.load(Ordering::Relaxed),
            wakes: self.wakes.load(Ordering::Relaxed),
            busy_ns: self.busy_ns.load(Ordering::Relaxed),
            parked_ns: self.parked_ns.load(Ordering::Relaxed),
        }
    }

    /// Shorthand for `snapshot().busy_fraction()`.
    pub fn busy_fraction(&self) -> f64 {
        self.snapshot().busy_fraction()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn busy_fraction_definitions() {
        let l = CpuLedger::new();
        // Never parked, never ran: busy by definition (polling pump
        // that has not flushed yet).
        assert_eq!(l.busy_fraction(), 1.0);
        l.add_busy(Duration::from_millis(10));
        assert_eq!(l.busy_fraction(), 1.0);
        l.park(Duration::from_millis(90), true);
        let s = l.snapshot();
        assert!((s.busy_fraction() - 0.1).abs() < 1e-9);
        assert_eq!((s.parks, s.wakes), (1, 1));
    }

    #[test]
    fn window_delta() {
        let l = CpuLedger::new();
        l.iteration(true);
        l.iteration(false);
        let a = l.snapshot();
        l.iteration(false);
        l.park(Duration::from_millis(1), false);
        let d = l.snapshot().since(&a);
        assert_eq!((d.iterations, d.productive, d.empty_polls), (1, 0, 1));
        assert_eq!((d.parks, d.wakes), (1, 0));
        assert!(d.parked_ns >= 1_000_000);
    }
}
