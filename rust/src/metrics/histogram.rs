//! HDR-style log-bucketed histogram for nanosecond latencies.
//!
//! Buckets have ~1.5 % relative width (64 sub-buckets per power of two),
//! which is plenty for p50/p99 reporting, with O(1) record.

/// Log-bucketed histogram over `u64` values (typically ns).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// counts[b*SUB + s]: bucket b = floor(log2(v)), sub-bucket s.
    counts: Vec<u64>,
    total: u64,
    min: u64,
    max: u64,
    sum: u128,
}

const SUB_BITS: u32 = 6;
const SUB: usize = 1 << SUB_BITS; // 64 sub-buckets per octave
const OCTAVES: usize = 64;
/// Flat bucket count — shared with the lock-free latency histogram
/// (`metrics::latency`), which reuses this module's bucketing so the
/// two can never disagree on layout.
pub(crate) const BUCKETS: usize = OCTAVES * SUB;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; OCTAVES * SUB],
            total: 0,
            min: u64::MAX,
            max: 0,
            sum: 0,
        }
    }

    #[inline]
    pub(crate) fn index(v: u64) -> usize {
        let v = v.max(1);
        let b = 63 - v.leading_zeros() as usize; // floor(log2 v)
        let s = if b >= SUB_BITS as usize {
            ((v >> (b - SUB_BITS as usize)) as usize) & (SUB - 1)
        } else {
            // Small values: spread over low sub-buckets.
            (v as usize) & (SUB - 1)
        };
        b * SUB + s
    }

    /// Lower bound of the bucket at flat index `i`.
    pub(crate) fn bucket_value(i: usize) -> u64 {
        let b = i / SUB;
        let s = (i % SUB) as u64;
        if b >= SUB_BITS as usize {
            (1u64 << b) + (s << (b - SUB_BITS as usize))
        } else {
            s.max(1)
        }
    }

    /// Record one observation.
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[Self::index(v)] += 1;
        self.total += 1;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.sum += v as u128;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Value at quantile `q` in `[0, 1]` (bucket lower bound; exact min/max
    /// at the extremes).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        if q <= 0.0 {
            return self.min();
        }
        let target = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::bucket_value(i).clamp(self.min(), self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.5)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += *b;
        }
        self.total += other.total;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.sum += other.sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn constant_values() {
        let mut h = Histogram::new();
        for _ in 0..1000 {
            h.record(5000);
        }
        let p50 = h.p50();
        assert!((p50 as f64 - 5000.0).abs() / 5000.0 < 0.02, "p50={p50}");
        assert_eq!(h.min(), 5000);
        assert_eq!(h.max(), 5000);
    }

    #[test]
    fn quantiles_monotone_and_accurate() {
        let mut h = Histogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!((p50 as f64 - 50_000.0).abs() / 50_000.0 < 0.03, "p50={p50}");
        assert!((p99 as f64 - 99_000.0).abs() / 99_000.0 < 0.03, "p99={p99}");
        assert!(p50 <= p99);
    }

    #[test]
    fn mean_exact() {
        let mut h = Histogram::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        assert!((h.mean() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for v in 1..=1000u64 {
            a.record(v);
            b.record(v + 1000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 2000);
        let p50 = a.quantile(0.5);
        assert!((p50 as f64 - 1000.0).abs() / 1000.0 < 0.05, "p50={p50}");
    }

    #[test]
    fn extremes() {
        let mut h = Histogram::new();
        h.record(1);
        h.record(u64::MAX / 2);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), u64::MAX / 2);
        assert!(h.quantile(1.0) >= h.quantile(0.0));
    }
}
