//! Lock-free per-pump latency histogram — the tail-latency trajectory
//! behind the paper's order-of-magnitude claim (Fig 14 reports means;
//! tails are where per-request software overhead actually shows).
//!
//! Each pump (director shard, file-service loop) owns an
//! [`LatencyHistogram`] it records into with relaxed atomic adds — no
//! locks on the hot path, no cross-pump cache-line traffic beyond the
//! shared counts array each writer mostly owns. Readers take a
//! [`LatencySnapshot`] at any time and merge snapshots across pumps;
//! two snapshots subtract ([`LatencySnapshot::since`]) so a bench can
//! meter one load window out of a monotonic recorder.
//!
//! Bucketing is shared verbatim with [`Histogram`] (64 sub-buckets per
//! octave, ~1.5 % relative width) so the locked and lock-free variants
//! can never disagree on layout.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::histogram::BUCKETS;
use super::Histogram;

/// Compact quantile summary, cheap to ship over a control channel
/// (the `ControlMsg::LatencyStats` reply and the bench JSON row).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencyStats {
    /// Observations recorded.
    pub count: u64,
    /// Arithmetic mean, ns.
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p99_ns: u64,
    pub p999_ns: u64,
    pub max_ns: u64,
}

/// Lock-free log-bucketed histogram: one writer pump, any readers.
/// Multiple writers are also safe (relaxed adds) — merge precision is
/// exact because every counter is monotonic.
pub struct LatencyHistogram {
    counts: Box<[AtomicU64]>,
    total: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    pub fn new() -> Arc<LatencyHistogram> {
        Arc::new(LatencyHistogram::default())
    }

    /// Record one observation in nanoseconds. O(1), lock-free.
    #[inline]
    pub fn record(&self, ns: u64) {
        self.counts[Histogram::index(ns)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(ns, Ordering::Relaxed);
        self.max.fetch_max(ns, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of the counters. Not atomic as a whole (a
    /// racing record may straddle the copy by one observation) — fine
    /// for metering, which is what snapshots are for.
    pub fn snapshot(&self) -> LatencySnapshot {
        LatencySnapshot {
            counts: self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            total: self.total.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }

    /// Shorthand: snapshot and summarize.
    pub fn stats(&self) -> LatencyStats {
        self.snapshot().stats()
    }
}

/// Plain-data copy of a [`LatencyHistogram`]: mergeable across pumps,
/// subtractable across time.
#[derive(Debug, Clone)]
pub struct LatencySnapshot {
    counts: Vec<u64>,
    total: u64,
    sum: u64,
    max: u64,
}

impl Default for LatencySnapshot {
    fn default() -> Self {
        LatencySnapshot { counts: vec![0; BUCKETS], total: 0, sum: 0, max: 0 }
    }
}

impl LatencySnapshot {
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Fold another pump's snapshot into this one.
    pub fn merge(&mut self, other: &LatencySnapshot) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += *b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// Counter deltas since an earlier snapshot of the same (merged)
    /// recorder set — the window a bench phase meters. `max` cannot be
    /// windowed from monotonic counters, so the later snapshot's max is
    /// kept (an upper bound for the window).
    pub fn since(&self, earlier: &LatencySnapshot) -> LatencySnapshot {
        LatencySnapshot {
            counts: self
                .counts
                .iter()
                .zip(&earlier.counts)
                .map(|(a, b)| a.saturating_sub(*b))
                .collect(),
            total: self.total.saturating_sub(earlier.total),
            sum: self.sum.saturating_sub(earlier.sum),
            max: self.max,
        }
    }

    /// Value at quantile `q` in `[0, 1]` (bucket lower bound).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Histogram::bucket_value(i).min(self.max);
            }
        }
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    pub fn stats(&self) -> LatencyStats {
        LatencyStats {
            count: self.total,
            mean_ns: self.mean(),
            p50_ns: self.quantile(0.50),
            p99_ns: self.quantile(0.99),
            p999_ns: self.quantile(0.999),
            max_ns: self.max,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_stats() {
        let h = LatencyHistogram::new();
        let s = h.stats();
        assert_eq!(s.count, 0);
        assert_eq!(s.p50_ns, 0);
        assert_eq!(s.p999_ns, 0);
        assert_eq!(s.mean_ns, 0.0);
    }

    #[test]
    fn quantiles_match_locked_histogram() {
        let lockfree = LatencyHistogram::new();
        let mut locked = Histogram::new();
        for v in 1..=100_000u64 {
            lockfree.record(v);
            locked.record(v);
        }
        let s = lockfree.snapshot();
        assert_eq!(s.count(), locked.count());
        assert_eq!(s.quantile(0.5), locked.quantile(0.5), "identical bucketing");
        assert_eq!(s.quantile(0.99), locked.quantile(0.99));
        assert!((s.mean() - locked.mean()).abs() < 1e-6);
        let p999 = s.quantile(0.999);
        assert!((p999 as f64 - 99_900.0).abs() / 99_900.0 < 0.03, "p999={p999}");
    }

    #[test]
    fn concurrent_recorders_lose_nothing() {
        let h = LatencyHistogram::new();
        let threads: Vec<_> = (0..4u64)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record(1 + (i ^ (t * 7919)));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
    }

    #[test]
    fn merge_and_window() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        for _ in 0..100 {
            a.record(1_000);
        }
        let before = {
            let mut m = a.snapshot();
            m.merge(&b.snapshot());
            m
        };
        for _ in 0..100 {
            a.record(1_000_000);
            b.record(1_000_000);
        }
        let mut after = a.snapshot();
        after.merge(&b.snapshot());
        let window = after.since(&before);
        assert_eq!(window.count(), 200, "window sees only the new observations");
        let p50 = window.quantile(0.5);
        assert!(
            (p50 as f64 - 1_000_000.0).abs() / 1_000_000.0 < 0.02,
            "window p50 must ignore pre-window records (p50={p50})"
        );
        assert_eq!(after.count(), 300);
    }

    #[test]
    fn stats_are_ordered() {
        let h = LatencyHistogram::new();
        for v in [10u64, 100, 1_000, 10_000, 100_000] {
            for _ in 0..100 {
                h.record(v);
            }
        }
        let s = h.stats();
        assert!(s.p50_ns <= s.p99_ns);
        assert!(s.p99_ns <= s.p999_ns);
        assert!(s.p999_ns <= s.max_ns);
        assert_eq!(s.max_ns, 100_000);
    }
}
