//! Functional-plane zero-copy probe: drives REAL bytes through the
//! offload engine (SSD → pooled completion → context ring → response
//! views) and reports ops/s plus the copy ledger's per-request numbers.
//!
//! Shared by `fig23_zerocopy` (which prints it next to the calibrated
//! testbed's Fig 23 reproduction) and the `bench_summary` emitter
//! (which records it in `BENCH_zerocopy.json` so the perf trajectory of
//! the buffer plane is tracked across PRs).

use std::sync::{Arc, RwLock};
use std::time::Instant;

use crate::buf::LedgerSnapshot;
use crate::cache::{CuckooCache, ReadCacheTier};
use crate::dpufs::{DpuFs, FsConfig};
use crate::offload::{OffloadEngine, OffloadEngineConfig, RawFileOffload, RoutedReq};
use crate::proto::{AppRequest, NetResp};
use crate::ssd::{AsyncSsd, Ssd};

/// One probe measurement.
#[derive(Debug, Clone, Copy)]
pub struct ZeroCopyProbe {
    /// `"zero-copy"` or `"copy"` (the Fig 23 straw-man).
    pub mode: &'static str,
    /// Measured read requests.
    pub reads: u64,
    pub read_size: u32,
    pub ops_per_sec: f64,
    /// Software bytes memcpy'd per request (the ledger's meter — DMA
    /// transfers are excluded by construction).
    pub bytes_copied_per_req: f64,
    /// Heap allocations per request (0 in steady state for zero-copy).
    pub heap_allocs_per_req: f64,
    /// Fraction of buffer requests served from the slab.
    pub pool_hit_rate: f64,
    /// Raw ledger delta over the measurement window.
    pub delta: LedgerSnapshot,
}

/// Measure the offloaded READ path for one mode. `copy_mode` selects
/// the §6.2 straw-man (extra copy per response, metered); reads are
/// 4 KiB-aligned so the single-extent fast path is exercised.
pub fn probe_engine_read_path(
    copy_mode: bool,
    reads: u64,
    read_size: u32,
    batch: usize,
) -> ZeroCopyProbe {
    let file_bytes: u64 = 4 << 20;
    let ssd = Arc::new(Ssd::new(64 << 20, 512));
    let mut fs = DpuFs::format(ssd.clone(), FsConfig::default()).expect("format");
    let dir = fs.create_directory("bench").expect("dir");
    let file = fs.create_file(dir, "data").expect("file");
    let data: Vec<u8> = (0..file_bytes).map(|i| (i % 253) as u8).collect();
    fs.write(file, 0, &data).expect("fill");
    let mut engine = OffloadEngine::new(
        Arc::new(RawFileOffload),
        Arc::new(CuckooCache::new(1 << 10)),
        Arc::new(RwLock::new(fs)),
        AsyncSsd::new_inline(ssd),
        OffloadEngineConfig { copy_mode, ..Default::default() },
    );
    let fid = file.0;
    let offsets = (file_bytes / read_size as u64).max(1);
    let run = |engine: &mut OffloadEngine, msg_id: u64, n: usize| {
        let reqs: Vec<RoutedReq> = (0..n as u64)
            .map(|i| RoutedReq {
                msg_id,
                idx: i as u16,
                req: AppRequest::Read {
                    file_id: fid,
                    offset: ((msg_id * n as u64 + i) % offsets) * read_size as u64,
                    size: read_size,
                },
            })
            .collect();
        let mut responses: Vec<NetResp> = Vec::with_capacity(n);
        let bounced = engine.execute(reqs, &mut responses);
        assert!(bounced.is_empty(), "probe reads must offload");
        // Inline polled SSD: completions drain within execute/poll.
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        while responses.len() < n {
            engine.complete_pending(&mut responses);
            assert!(Instant::now() < deadline, "probe timed out");
        }
        responses
    };
    // Warm-up: pool working set.
    for m in 0..4 {
        run(&mut engine, m, batch);
    }
    let before = engine.pool().stats();
    let t0 = Instant::now();
    let mut done = 0u64;
    let mut msg_id = 100u64;
    while done < reads {
        let n = batch.min((reads - done) as usize);
        let responses = run(&mut engine, msg_id, n);
        done += responses.len() as u64;
        msg_id += 1;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let delta = engine.pool().stats() - before;
    ZeroCopyProbe {
        mode: if copy_mode { "copy" } else { "zero-copy" },
        reads: done,
        read_size,
        ops_per_sec: done as f64 / elapsed.max(1e-9),
        bytes_copied_per_req: delta.bytes_copied as f64 / done as f64,
        heap_allocs_per_req: delta.heap_allocs as f64 / done as f64,
        pool_hit_rate: if delta.allocs == 0 {
            1.0
        } else {
            delta.pool_hits as f64 / delta.allocs as f64
        },
        delta,
    }
}

/// One point of the read-cache-tier sweep.
#[derive(Debug, Clone, Copy)]
pub struct CacheTierProbe {
    pub cache_bytes: u64,
    /// Measured reads (after the warm phase).
    pub reads: u64,
    pub read_size: u32,
    /// Hit ratio over the measured window.
    pub hit_ratio: f64,
    pub ops_per_sec: f64,
    /// Bytes the tier served over the measured window.
    pub bytes_served: u64,
    /// Tier residency (bytes_cached / budget) at the end of the run.
    pub warm_fraction: f64,
    /// Engine-pool ledger delta over the measured window. Hits add
    /// nothing to it — no copy, no allocation, not even a pool slot —
    /// and misses stay on the pooled zero-copy path, so the delta's
    /// copy/heap columns must be zero at every sweep point.
    pub delta: LedgerSnapshot,
}

/// Measure the offloaded READ path with the colocated read-cache tier
/// attached, under a zipf(1) page popularity over an 8 MiB file. The
/// warm phase (one sequential pass + one zipfian pass, unmeasured)
/// settles the tier's hot set, so the measured hit ratio is the
/// steady-state one for this `cache_bytes`. The engine pool is sized
/// so even a whole-file tier pins pooled views, never heap ones —
/// the ledger stays a pure meter of the read path itself.
pub fn probe_cache_tier(
    cache_bytes: u64,
    reads: u64,
    read_size: u32,
    batch: usize,
) -> CacheTierProbe {
    let file_bytes: u64 = 8 << 20;
    let pages = file_bytes / read_size as u64;
    let ssd = Arc::new(Ssd::new(64 << 20, 512));
    let mut fs = DpuFs::format(ssd.clone(), FsConfig::default()).expect("format");
    let dir = fs.create_directory("bench").expect("dir");
    let file = fs.create_file(dir, "data").expect("file");
    let data: Vec<u8> = (0..file_bytes).map(|i| (i % 253) as u8).collect();
    fs.write(file, 0, &data).expect("fill");
    let mut engine = OffloadEngine::new(
        Arc::new(RawFileOffload),
        Arc::new(CuckooCache::new(1 << 10)),
        Arc::new(RwLock::new(fs)),
        AsyncSsd::new_inline(ssd),
        OffloadEngineConfig {
            // Slots for a whole-file tier plus in-flight completions.
            pool_bufs: pages as usize + 256,
            pool_buf_size: read_size as usize,
            ..Default::default()
        },
    );
    let tier = Arc::new(ReadCacheTier::new(cache_bytes));
    engine.attach_tier(tier.clone());
    let fid = file.0;

    // zipf(1) over pages: cumulative harmonic weights, binary-searched
    // per draw. Page 0 is the hottest.
    let mut cum = Vec::with_capacity(pages as usize);
    let mut acc = 0.0f64;
    for r in 0..pages {
        acc += 1.0 / (r + 1) as f64;
        cum.push(acc);
    }
    let mut rng = crate::sim::Rng::new(0x21BF ^ cache_bytes);
    let mut zipf_page = move || {
        let u = rng.next_f64() * acc;
        cum.partition_point(|&c| c < u) as u64
    };

    let run = |engine: &mut OffloadEngine, msg_id: u64, offsets: &[u64]| {
        let reqs: Vec<RoutedReq> = offsets
            .iter()
            .enumerate()
            .map(|(i, &offset)| RoutedReq {
                msg_id,
                idx: i as u16,
                req: AppRequest::Read { file_id: fid, offset, size: read_size },
            })
            .collect();
        let mut responses: Vec<NetResp> = Vec::with_capacity(offsets.len());
        let bounced = engine.execute(reqs, &mut responses);
        assert!(bounced.is_empty(), "sweep reads must offload");
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        while responses.len() < offsets.len() {
            engine.complete_pending(&mut responses);
            assert!(Instant::now() < deadline, "cache sweep timed out");
        }
        responses
    };

    // Warm phase: one sequential pass (every page once — the whole-file
    // point ends it fully resident) then one zipfian pass (smaller
    // tiers settle on their hot set under CLOCK).
    let mut msg_id = 1u64;
    for chunk in (0..pages).collect::<Vec<_>>().chunks(batch) {
        let offsets: Vec<u64> = chunk.iter().map(|p| p * read_size as u64).collect();
        run(&mut engine, msg_id, &offsets);
        msg_id += 1;
    }
    let mut warmed = 0u64;
    while warmed < pages {
        let n = batch.min((pages - warmed) as usize);
        let offsets: Vec<u64> = (0..n).map(|_| zipf_page() * read_size as u64).collect();
        run(&mut engine, msg_id, &offsets);
        warmed += n as u64;
        msg_id += 1;
    }

    // Measured window.
    let tier_before = tier.stats();
    let pool_before = engine.pool().stats();
    let t0 = Instant::now();
    let mut done = 0u64;
    while done < reads {
        let n = batch.min((reads - done) as usize);
        let offsets: Vec<u64> = (0..n).map(|_| zipf_page() * read_size as u64).collect();
        let responses = run(&mut engine, msg_id, &offsets);
        done += responses.len() as u64;
        msg_id += 1;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let delta = engine.pool().stats() - pool_before;
    let ts = tier.stats();
    let (hits, misses) = (ts.hits - tier_before.hits, ts.misses - tier_before.misses);
    CacheTierProbe {
        cache_bytes,
        reads: done,
        read_size,
        hit_ratio: if hits + misses == 0 { 0.0 } else { hits as f64 / (hits + misses) as f64 },
        ops_per_sec: done as f64 / elapsed.max(1e-9),
        bytes_served: ts.bytes_served - tier_before.bytes_served,
        warm_fraction: tier.warm_fraction(),
        delta,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_contract_zero_copy_vs_straw_man() {
        let z = probe_engine_read_path(false, 64, 4096, 16);
        assert_eq!(z.reads, 64);
        assert_eq!(z.bytes_copied_per_req, 0.0, "zero-copy path copies nothing");
        assert_eq!(z.heap_allocs_per_req, 0.0);
        assert_eq!(z.pool_hit_rate, 1.0);
        let c = probe_engine_read_path(true, 64, 4096, 16);
        assert!(
            c.bytes_copied_per_req >= 4096.0,
            "straw-man copies each 4 KiB response (got {})",
            c.bytes_copied_per_req
        );
        assert!(c.heap_allocs_per_req >= 1.0);
    }

    #[test]
    fn cache_sweep_full_tier_serves_everything_zero_copy() {
        // Whole-file tier: after the warm pass the measured window is
        // all hits — no copies, no allocations, not even a pool slot.
        let p = probe_cache_tier(8 << 20, 256, 4096, 32);
        assert_eq!(p.reads, 256);
        assert_eq!(p.hit_ratio, 1.0, "whole-file tier must serve every read: {p:?}");
        assert_eq!(p.delta.allocs, 0, "hits must not touch the pool: {:?}", p.delta);
        assert_eq!(p.delta.bytes_copied, 0);
        assert_eq!(p.delta.heap_allocs, 0);
        assert!(p.bytes_served >= 256 * 4096);
        // An eighth of the file: real zipfian hit ratio, strictly
        // between the extremes, and still copy/heap-clean.
        let small = probe_cache_tier(1 << 20, 256, 4096, 32);
        assert!(
            small.hit_ratio > 0.0 && small.hit_ratio < 1.0,
            "1 MiB tier over an 8 MiB zipfian set must partially hit: {small:?}"
        );
        assert_eq!(small.delta.bytes_copied, 0);
        assert_eq!(small.delta.heap_allocs, 0);
    }
}
