//! Functional-plane zero-copy probe: drives REAL bytes through the
//! offload engine (SSD → pooled completion → context ring → response
//! views) and reports ops/s plus the copy ledger's per-request numbers.
//!
//! Shared by `fig23_zerocopy` (which prints it next to the calibrated
//! testbed's Fig 23 reproduction) and the `bench_summary` emitter
//! (which records it in `BENCH_zerocopy.json` so the perf trajectory of
//! the buffer plane is tracked across PRs).

use std::sync::{Arc, RwLock};
use std::time::Instant;

use crate::buf::LedgerSnapshot;
use crate::cache::CuckooCache;
use crate::dpufs::{DpuFs, FsConfig};
use crate::offload::{OffloadEngine, OffloadEngineConfig, RawFileOffload, RoutedReq};
use crate::proto::{AppRequest, NetResp};
use crate::ssd::{AsyncSsd, Ssd};

/// One probe measurement.
#[derive(Debug, Clone, Copy)]
pub struct ZeroCopyProbe {
    /// `"zero-copy"` or `"copy"` (the Fig 23 straw-man).
    pub mode: &'static str,
    /// Measured read requests.
    pub reads: u64,
    pub read_size: u32,
    pub ops_per_sec: f64,
    /// Software bytes memcpy'd per request (the ledger's meter — DMA
    /// transfers are excluded by construction).
    pub bytes_copied_per_req: f64,
    /// Heap allocations per request (0 in steady state for zero-copy).
    pub heap_allocs_per_req: f64,
    /// Fraction of buffer requests served from the slab.
    pub pool_hit_rate: f64,
    /// Raw ledger delta over the measurement window.
    pub delta: LedgerSnapshot,
}

/// Measure the offloaded READ path for one mode. `copy_mode` selects
/// the §6.2 straw-man (extra copy per response, metered); reads are
/// 4 KiB-aligned so the single-extent fast path is exercised.
pub fn probe_engine_read_path(
    copy_mode: bool,
    reads: u64,
    read_size: u32,
    batch: usize,
) -> ZeroCopyProbe {
    let file_bytes: u64 = 4 << 20;
    let ssd = Arc::new(Ssd::new(64 << 20, 512));
    let mut fs = DpuFs::format(ssd.clone(), FsConfig::default()).expect("format");
    let dir = fs.create_directory("bench").expect("dir");
    let file = fs.create_file(dir, "data").expect("file");
    let data: Vec<u8> = (0..file_bytes).map(|i| (i % 253) as u8).collect();
    fs.write(file, 0, &data).expect("fill");
    let mut engine = OffloadEngine::new(
        Arc::new(RawFileOffload),
        Arc::new(CuckooCache::new(1 << 10)),
        Arc::new(RwLock::new(fs)),
        AsyncSsd::new_inline(ssd),
        OffloadEngineConfig { copy_mode, ..Default::default() },
    );
    let fid = file.0;
    let offsets = (file_bytes / read_size as u64).max(1);
    let run = |engine: &mut OffloadEngine, msg_id: u64, n: usize| {
        let reqs: Vec<RoutedReq> = (0..n as u64)
            .map(|i| RoutedReq {
                msg_id,
                idx: i as u16,
                req: AppRequest::Read {
                    file_id: fid,
                    offset: ((msg_id * n as u64 + i) % offsets) * read_size as u64,
                    size: read_size,
                },
            })
            .collect();
        let mut responses: Vec<NetResp> = Vec::with_capacity(n);
        let bounced = engine.execute(reqs, &mut responses);
        assert!(bounced.is_empty(), "probe reads must offload");
        // Inline polled SSD: completions drain within execute/poll.
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        while responses.len() < n {
            engine.complete_pending(&mut responses);
            assert!(Instant::now() < deadline, "probe timed out");
        }
        responses
    };
    // Warm-up: pool working set.
    for m in 0..4 {
        run(&mut engine, m, batch);
    }
    let before = engine.pool().stats();
    let t0 = Instant::now();
    let mut done = 0u64;
    let mut msg_id = 100u64;
    while done < reads {
        let n = batch.min((reads - done) as usize);
        let responses = run(&mut engine, msg_id, n);
        done += responses.len() as u64;
        msg_id += 1;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let delta = engine.pool().stats() - before;
    ZeroCopyProbe {
        mode: if copy_mode { "copy" } else { "zero-copy" },
        reads: done,
        read_size,
        ops_per_sec: done as f64 / elapsed.max(1e-9),
        bytes_copied_per_req: delta.bytes_copied as f64 / done as f64,
        heap_allocs_per_req: delta.heap_allocs as f64 / done as f64,
        pool_hit_rate: if delta.allocs == 0 {
            1.0
        } else {
            delta.pool_hits as f64 / delta.allocs as f64
        },
        delta,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_contract_zero_copy_vs_straw_man() {
        let z = probe_engine_read_path(false, 64, 4096, 16);
        assert_eq!(z.reads, 64);
        assert_eq!(z.bytes_copied_per_req, 0.0, "zero-copy path copies nothing");
        assert_eq!(z.heap_allocs_per_req, 0.0);
        assert_eq!(z.pool_hit_rate, 1.0);
        let c = probe_engine_read_path(true, 64, 4096, 16);
        assert!(
            c.bytes_copied_per_req >= 4096.0,
            "straw-man copies each 4 KiB response (got {})",
            c.bytes_copied_per_req
        );
        assert!(c.heap_allocs_per_req >= 1.0);
    }
}
