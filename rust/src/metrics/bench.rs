//! Minimal wallclock micro-bench harness (criterion is unavailable in
//! this offline environment).
//!
//! Used by the `rust/benches/*` targets for the *real* (non-simulated)
//! measurements: ring throughput, cache-table ops, kernel dispatch.

use std::time::{Duration, Instant};

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // Stable-rust black box.
    std::hint::black_box(x)
}

/// Result of a timed run.
#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub iters: u64,
    pub elapsed: Duration,
}

impl BenchResult {
    pub fn ops_per_sec(&self) -> f64 {
        self.iters as f64 / self.elapsed.as_secs_f64()
    }

    pub fn ns_per_op(&self) -> f64 {
        self.elapsed.as_nanos() as f64 / self.iters as f64
    }
}

/// Run `f` repeatedly for at least `min_time`, after a warm-up; returns
/// the measured result. `f` receives the iteration index.
pub fn time_for(min_time: Duration, mut f: impl FnMut(u64)) -> BenchResult {
    // Warm-up ~10% of budget.
    let warm_until = Instant::now() + min_time / 10;
    let mut i = 0u64;
    while Instant::now() < warm_until {
        f(i);
        i += 1;
    }
    let start = Instant::now();
    let until = start + min_time;
    let mut iters = 0u64;
    while Instant::now() < until {
        // Batch 64 calls between clock reads to amortize Instant cost.
        for _ in 0..64 {
            f(iters);
            iters += 1;
        }
    }
    BenchResult { iters, elapsed: start.elapsed() }
}

/// Time a fixed number of iterations.
pub fn time_n(iters: u64, mut f: impl FnMut(u64)) -> BenchResult {
    let start = Instant::now();
    for i in 0..iters {
        f(i);
    }
    BenchResult { iters, elapsed: start.elapsed() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_n_counts() {
        let mut n = 0u64;
        let r = time_n(1000, |_| n += 1);
        assert_eq!(n, 1000);
        assert_eq!(r.iters, 1000);
        assert!(r.ops_per_sec() > 0.0);
    }

    #[test]
    fn time_for_runs_at_least_budget() {
        let r = time_for(Duration::from_millis(30), |i| {
            black_box(i * 2);
        });
        assert!(r.elapsed >= Duration::from_millis(30));
        assert!(r.iters > 0);
    }
}
