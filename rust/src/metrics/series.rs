//! Paper-style table/series formatting for the figure benches.
//!
//! Each `fig*` bench prints the series a paper figure plots, one row per
//! x-value, so `cargo bench` output can be diffed against the paper.

/// Human-readable ns: "780 µs", "11.0 ms", "450 ns".
pub fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.2} s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.1} µs", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

/// Human-readable op/s: "730 K", "15.7 M".
pub fn fmt_ops(ops: f64) -> String {
    if ops >= 1e9 {
        format!("{:.2} G", ops / 1e9)
    } else if ops >= 1e6 {
        format!("{:.2} M", ops / 1e6)
    } else if ops >= 1e3 {
        format!("{:.0} K", ops / 1e3)
    } else {
        format!("{ops:.0}")
    }
}

/// One row of a printed series.
#[derive(Debug, Clone)]
pub struct Row {
    pub cells: Vec<String>,
}

/// A named series table printed in aligned columns.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Row>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(Row { cells: cells.to_vec() });
    }

    pub fn rows(&self) -> &[Row] {
        &self.rows
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.cells.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n", self.title));
        let fmt_line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_line(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_line(&r.cells, &widths));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ns(450), "450 ns");
        assert_eq!(fmt_ns(780_000), "780.0 µs");
        assert_eq!(fmt_ns(11_000_000), "11.00 ms");
        assert_eq!(fmt_ops(730_000.0), "730 K");
        assert_eq!(fmt_ops(15_700_000.0), "15.70 M");
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("Fig X", &["x", "y"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["10".into(), "20".into()]);
        let s = t.render();
        assert!(s.contains("Fig X"));
        assert!(s.contains("10  20"));
        assert_eq!(t.rows().len(), 2);
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
