//! Measurement utilities: log-bucketed latency histograms and series
//! formatting shared by the functional plane and the testbed.

pub mod bench;
mod cpu;
mod histogram;
mod latency;
mod series;
mod tenant;
pub mod zerocopy;

pub use cpu::{CpuLedger, CpuStats};
// Observability types defined next to their subsystem but part of the
// stats surface (the `ControlMsg` stats pattern).
pub use crate::cache::TierStats;
pub use histogram::Histogram;
pub use latency::{LatencyHistogram, LatencySnapshot, LatencyStats};
pub use tenant::{merge_tenant_tables, TenantCounters};
pub use series::{fmt_ns, fmt_ops, Row, Table};
pub use zerocopy::{probe_cache_tier, probe_engine_read_path, CacheTierProbe, ZeroCopyProbe};
