//! Per-tenant accounting for the massive-fanout connection plane.
//!
//! A production DPU storage server fronts thousands of DBMS
//! connections owned by a much smaller set of *tenants* (the
//! disaggregated-DBMS economics the extended report cites: per-server
//! tenancy is the deciding factor for the architecture). The director
//! shards meter admission, throttling, rejection and completion per
//! tenant; these counters are published lock-free-ish (one writer — the
//! shard pump — behind an uncontended mutex) and surfaced through the
//! control plane (`ControlMsg::TenantStats` / `DdsClient`).

/// Monotonic counters (plus two gauges: `pending`, `flows`) of one
/// tenant on one shard. Aggregate across shards with [`Self::absorb`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantCounters {
    /// Tenant id (derived from the flow's client IP).
    pub tenant: u32,
    /// Requests admitted to the data path (offload engine or host).
    pub admitted: u64,
    /// Responses framed back to this tenant's clients for admitted
    /// requests (OK and ERR alike).
    pub completed: u64,
    /// Requests rejected with a clean ERR because the tenant was over
    /// its pending bound (admission control under overload).
    pub rejected_pending: u64,
    /// Requests rejected with a clean ERR by the tenant's token-bucket
    /// rate limit.
    pub throttled: u64,
    /// Gauge: admitted requests currently in flight.
    pub pending: u64,
    /// Gauge: open flows owned by this tenant.
    pub flows: u64,
    /// New flows refused because the shard was at its flow cap.
    pub flows_rejected: u64,
}

impl TenantCounters {
    pub fn new(tenant: u32) -> Self {
        TenantCounters { tenant, ..Default::default() }
    }

    /// Fold another shard's view of the SAME tenant into this one
    /// (counters and gauges both sum: each shard owns disjoint flows).
    pub fn absorb(&mut self, other: &TenantCounters) {
        self.admitted += other.admitted;
        self.completed += other.completed;
        self.rejected_pending += other.rejected_pending;
        self.throttled += other.throttled;
        self.pending += other.pending;
        self.flows += other.flows;
        self.flows_rejected += other.flows_rejected;
    }
}

/// Merge per-shard tenant tables into one table indexed by tenant id
/// (ascending). The canonical aggregation used by the sharded server,
/// the control plane and the fanout bench alike.
pub fn merge_tenant_tables(tables: &[Vec<TenantCounters>]) -> Vec<TenantCounters> {
    let mut by_id: std::collections::BTreeMap<u32, TenantCounters> =
        std::collections::BTreeMap::new();
    for table in tables {
        for t in table {
            by_id.entry(t.tenant).or_insert_with(|| TenantCounters::new(t.tenant)).absorb(t);
        }
    }
    by_id.into_values().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_sums_per_tenant_across_shards() {
        let shard0 = vec![
            TenantCounters { tenant: 0, admitted: 5, pending: 1, ..Default::default() },
            TenantCounters { tenant: 2, admitted: 3, flows: 2, ..Default::default() },
        ];
        let shard1 = vec![TenantCounters {
            tenant: 0,
            admitted: 7,
            throttled: 4,
            ..Default::default()
        }];
        let merged = merge_tenant_tables(&[shard0, shard1]);
        assert_eq!(merged.len(), 2);
        assert_eq!(merged[0].tenant, 0);
        assert_eq!(merged[0].admitted, 12);
        assert_eq!(merged[0].pending, 1);
        assert_eq!(merged[0].throttled, 4);
        assert_eq!(merged[1].tenant, 2);
        assert_eq!(merged[1].flows, 2);
    }
}
